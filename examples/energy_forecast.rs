//! Wind-power forecasting with a sparse Gaussian CRF — the application that
//! motivated CGGMs in Wytock & Kolter (2013). Fits the farm network + lag
//! mapping, then uses the model predictively:
//!
//!   ŷ(x) = -Λ̂⁻¹Θ̂ᵀx
//!
//! and reports test MSE against (a) predicting zero and (b) the same fit
//! with the output network zeroed (independent outputs) — showing the
//! structured model's advantage on spatially-coupled farms.
//!
//! ```bash
//! cargo run --release --example energy_forecast -- [--farms 36] [--n 300]
//! ```

use cggm::cggm::factor::{CholKind, LambdaFactor};
use cggm::datagen::energy::{self, EnergyOptions};
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::util::cli::Args;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let farms = args.get_usize("farms", 36);
    let n_train = args.get_usize("n", 300);
    let n_test = args.get_usize("n-test", 200);
    let opts_gen = EnergyOptions::default();
    let engine = NativeGemm::new(args.get_usize("threads", 1));

    println!("== wind-farm forecasting: {farms} farms, {n_train} train / {n_test} test hours ==");
    let train = energy::generate(farms, n_train, 7, &opts_gen);
    let test = energy::generate(farms, n_test, 8, &opts_gen);
    let p = train.p();
    let q = train.q();

    let lam = args.get_f64("lambda", 0.12);
    let opts = SolveOptions {
        lam_l: lam,
        lam_t: lam,
        max_iter: args.get_usize("max-iter", 80),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let res = solve(SolverKind::AltNewtonCd, &train.data, &opts, &engine).expect("solve");
    println!(
        "fitted sparse CGGM in {:.2}s ({} iters, converged={}): {} network edges, {} lag weights",
        t0.elapsed().as_secs_f64(),
        res.trace.records.len(),
        res.trace.converged,
        res.model.lambda_edges(),
        res.model.theta_nnz()
    );

    // Predict: ŷ = -Λ̂⁻¹ Θ̂ᵀ x per test sample.
    let factor = LambdaFactor::factor(&res.model.lambda, CholKind::Dense, &engine).unwrap();
    // Independent-outputs baseline: same Θ̂ but diagonal Λ̂ (no network).
    let mut diag_lambda = cggm::linalg::sparse::SpRowMat::zeros(q, q);
    for j in 0..q {
        diag_lambda.set(j, j, res.model.lambda.get(j, j).max(1e-6));
    }
    let diag_factor = LambdaFactor::factor(&diag_lambda, CholKind::Dense, &engine).unwrap();
    let mut mse_cggm = 0.0;
    let mut mse_zero = 0.0;
    let mut mse_marg = 0.0;
    for k in 0..test.data.n() {
        // t = Θ̂ᵀ x.
        let mut t = vec![0.0; q];
        for i in 0..p {
            let xi = test.data.xt[(i, k)];
            if xi == 0.0 {
                continue;
            }
            for &(j, v) in res.model.theta.row(i) {
                t[j] += v * xi;
            }
        }
        let yhat = factor.solve(&t); // prediction = -yhat
        let yhat_marg = diag_factor.solve(&t);
        for j in 0..q {
            let y = test.data.yt[(j, k)];
            mse_cggm += (y + yhat[j]).powi(2);
            mse_marg += (y + yhat_marg[j]).powi(2);
            mse_zero += y * y;
        }
    }
    let denom = (test.data.n() * q) as f64;
    println!("\nforecast test MSE (lower is better):");
    println!("  predict-zero baseline : {:.4}", mse_zero / denom);
    println!("  independent outputs   : {:.4}", mse_marg / denom);
    println!("  sparse CGGM (network) : {:.4}", mse_cggm / denom);
    let gain = 1.0 - (mse_cggm / mse_marg);
    println!(
        "network-aware forecasting gain over independent outputs: {:.1}%",
        100.0 * gain
    );
    assert!(mse_cggm < mse_zero, "model must beat the zero predictor");
}
