//! Live wind-power forecasting with a sparse Gaussian CRF — the
//! application that motivated CGGMs in Wytock & Kolter (2013), run the way
//! an operator would: a sliding window over the hour stream, with
//! append → refit cycles instead of cold re-fits.
//!
//! Each round forecasts the next hour-batch with the current model
//! (honest one-step-ahead evaluation, the batch is not yet in the window):
//!
//!   ŷ(x) = -Λ̂⁻¹Θ̂ᵀx
//!
//! then slides the window — the batch is appended, the oldest hours are
//! evicted, the cached Gram statistics get a rank-k correction, and the
//! solver re-fits warm from the previous model. A from-scratch cold fit on
//! the identical window runs alongside as the control: same optimum, more
//! iterations, full statistics rebuild.
//!
//! ```bash
//! cargo run --release --example energy_forecast -- \
//!     [--farms 36] [--window 300] [--batch 24] [--rounds 6]
//! ```

use cggm::cggm::factor::{CholKind, LambdaFactor};
use cggm::cggm::{CggmModel, Dataset, SampleBlock, WindowDelta};
use cggm::datagen::energy::{self, EnergyOptions};
use cggm::gemm::native::NativeGemm;
use cggm::linalg::dense::Mat;
use cggm::solvers::{solve_in_context, SolveOptions, SolverContext, SolverKind};
use cggm::util::cli::Args;

/// Forecast MSE of `model` on stream hours `[start, start + k)`, against
/// the predict-zero baseline.
fn forecast_mse(
    model: &CggmModel,
    xt: &Mat,
    yt: &Mat,
    start: usize,
    k: usize,
    engine: &NativeGemm,
) -> (f64, f64) {
    let (p, q) = (xt.rows(), yt.rows());
    let factor = LambdaFactor::factor(&model.lambda, CholKind::Dense, engine).unwrap();
    let (mut mse, mut mse_zero) = (0.0, 0.0);
    for s in start..start + k {
        // t = Θ̂ᵀ x.
        let mut t = vec![0.0; q];
        for i in 0..p {
            let xi = xt[(i, s)];
            if xi == 0.0 {
                continue;
            }
            for &(j, v) in model.theta.row(i) {
                t[j] += v * xi;
            }
        }
        let yhat = factor.solve(&t); // prediction = -yhat
        for j in 0..q {
            let y = yt[(j, s)];
            mse += (y + yhat[j]).powi(2);
            mse_zero += y * y;
        }
    }
    let denom = (k * q) as f64;
    (mse / denom, mse_zero / denom)
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let farms = args.get_usize("farms", 36);
    let window = args.get_usize("window", 300);
    let batch = args.get_usize("batch", 24); // one day of hours per cycle
    let rounds = args.get_usize("rounds", 6);
    let engine = NativeGemm::new(args.get_usize("threads", 1));
    let opts_gen = EnergyOptions::default();

    // One long hour stream; the model only ever holds `window` hours of it.
    let stream = energy::generate(farms, window + batch * rounds, 7, &opts_gen);
    let (p, q) = (stream.p(), stream.q());
    println!(
        "== live wind-farm forecasting: {farms} farms, {window}-hour window, \
         {rounds} x {batch}-hour batches =="
    );

    let lam = args.get_f64("lambda", 0.12);
    let opts = SolveOptions {
        lam_l: lam,
        lam_t: lam,
        max_iter: args.get_usize("max-iter", 80),
        tol: args.get_f64("tol", 0.0001),
        ..Default::default()
    };

    let mut data = Dataset::new(
        Mat::from_fn(p, window, |i, j| stream.data.xt[(i, j)]),
        Mat::from_fn(q, window, |i, j| stream.data.yt[(i, j)]),
    );
    let ctx = SolverContext::new(&data, &opts, &engine);
    let t0 = std::time::Instant::now();
    let mut res = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).expect("cold fit");
    let base_computes = ctx.stat_computes();
    println!(
        "initial cold fit: {:.2}s, {} iters, {} network edges, {} lag weights",
        t0.elapsed().as_secs_f64(),
        res.trace.records.len(),
        res.model.lambda_edges(),
        res.model.theta_nnz()
    );
    let mut carry = ctx.into_carry();

    // Per-round statistics work: a rebuild recomputes every Gram entry from
    // all `window` samples; the incremental path touches the same entries
    // once per appended/evicted sample.
    let entries = (p * p + q * q + p * q) as f64;
    println!(
        "\nstat work per round: incremental ~{:.1}M entry-updates vs rebuild ~{:.1}M (x{:.1} less)",
        2.0 * (batch as f64) * entries / 1e6,
        (window as f64) * entries / 1e6,
        window as f64 / (2.0 * batch as f64)
    );
    println!(
        "\n{:>5} {:>10} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "round", "mse", "mse-zero", "warm-iters", "cold-iters", "stat-updates", "refit-secs"
    );

    let (mut warm_total, mut cold_total) = (0usize, 0usize);
    let (mut mse_sum, mut zero_sum) = (0.0, 0.0);
    for r in 0..rounds {
        let start = window + r * batch;
        // Honest forecast: the batch is not yet in the window.
        let (mse, mse_zero) =
            forecast_mse(&res.model, &stream.data.xt, &stream.data.yt, start, batch, &engine);
        mse_sum += mse;
        zero_sum += mse_zero;

        // Slide the window: batch in, oldest `batch` hours out.
        let xa = Mat::from_fn(p, batch, |i, j| stream.data.xt[(i, start + j)]);
        let ya = Mat::from_fn(q, batch, |i, j| stream.data.yt[(i, start + j)]);
        let mut delta = WindowDelta::new(data.n());
        data.append_samples(&xa, &ya);
        delta.record_append(SampleBlock::new(xa, ya));
        delta.record_evict(data.evict_oldest(batch));

        let mut ctx = SolverContext::with_carry(&data, &opts, &engine, carry);
        let updates_before = ctx.stat_updates();
        ctx.update_stats(&delta).expect("incremental stat correction");
        let t1 = std::time::Instant::now();
        let warm = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, Some(&res.model))
            .expect("warm refit");
        let secs = t1.elapsed().as_secs_f64();
        assert!(warm.trace.warm_started);
        assert_eq!(
            ctx.stat_computes(),
            base_computes,
            "refit must not rebuild statistics from scratch"
        );

        // Control: cold fit on the identical window.
        let fresh = SolverContext::new(&data, &opts, &engine);
        let cold = solve_in_context(SolverKind::AltNewtonCd, &fresh, &opts, None).expect("cold");
        let (fw, fc) = (
            warm.trace.final_f().unwrap(),
            cold.trace.final_f().unwrap(),
        );
        assert!(
            (fw - fc).abs() <= 1e-6 * fc.abs().max(1.0),
            "warm refit diverged from cold control: {fw} vs {fc}"
        );

        let (wi, ci) = (warm.trace.records.len(), cold.trace.records.len());
        warm_total += wi;
        cold_total += ci;
        println!(
            "{:>5} {:>10.4} {:>10.4} {:>10} {:>10} {:>12} {:>12.3}",
            r + 1,
            mse,
            mse_zero,
            wi,
            ci,
            ctx.stat_updates() - updates_before,
            secs
        );
        res = warm;
        carry = ctx.into_carry();
    }

    println!(
        "\nforecast MSE over {} held-out hours: {:.4} (predict-zero {:.4})",
        rounds * batch,
        mse_sum / rounds as f64,
        zero_sum / rounds as f64
    );
    println!(
        "solver iterations: {warm_total} warm across {rounds} refits vs {cold_total} cold \
         ({:.0}% saved); statistics were rebuilt 0 times after the initial fit",
        100.0 * (1.0 - warm_total as f64 / cold_total.max(1) as f64)
    );
    assert!(mse_sum < zero_sum, "model must beat the zero predictor");
    assert!(
        warm_total <= cold_total,
        "warm refits must not cost more iterations than cold fits"
    );
}
