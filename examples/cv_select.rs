//! Cross-validated λ selection demo: K-fold CV over the regularization
//! path picks λ by held-out negative log-likelihood, refits on the full
//! training data, and sanity-checks the winner against every single-λ fit
//! on a fresh evaluation split.
//!
//! ```bash
//! cargo run --release --example cv_select -- [--q 40] [--n 300] [--folds 5] \
//!     [--points 8] [--cv-threads 4]
//! ```

use cggm::cggm::objective::heldout_nll;
use cggm::coordinator::{cross_validate, CvOptions, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::util::cli::Args;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let q = args.get_usize("q", 40);
    let p = args.get_usize("p", q);
    let n = args.get_usize("n", 300);
    let n_eval = args.get_usize("n-eval", n);
    let folds = args.get_usize("folds", 5);
    let points = args.get_usize("points", 8);
    let fold_threads = args.get_usize("cv-threads", folds.min(4));
    let seed = args.get_u64("seed", 1);

    // One generator run, then a train/eval split: CV only ever sees the
    // training half; the evaluation half stays untouched until the end.
    let prob = datagen::chain::generate(p, q, n + n_eval, seed);
    let train_idx: Vec<usize> = (0..n).collect();
    let eval_idx: Vec<usize> = (n..n + n_eval).collect();
    let train = prob.data.select_samples(&train_idx);
    let eval = prob.data.select_samples(&eval_idx);

    println!("== CV λ selection: chain graph, p={p} q={q}, n={n} train + {n_eval} eval ==");
    let engine = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: args.get_usize("max-iter", 100),
        ..Default::default()
    };
    let popts = PathOptions {
        points,
        min_ratio: args.get_f64("min-ratio", 0.05),
        ..Default::default()
    };
    let cvo = CvOptions {
        folds,
        fold_threads,
        ..Default::default()
    };
    let res = cross_validate(
        SolverKind::AltNewtonCd,
        &train,
        &base,
        &popts,
        &cvo,
        &engine,
    )
    .expect("cross-validation failed");

    println!(
        "{:<10} {:>12} {:>10} {:>12} {:>6}",
        "lambda", "cv mean NLL", "± se", "eval NLL", "best"
    );
    for (k, pt) in res.points.iter().enumerate() {
        // Independent check: fit the full training data at this λ alone and
        // score on the held-back evaluation split.
        let opts = SolveOptions {
            lam_l: pt.lam_l,
            lam_t: pt.lam_t,
            ..base.clone()
        };
        let fit = solve(SolverKind::AltNewtonCd, &train, &opts, &engine).expect("fit failed");
        let eval_nll = heldout_nll(&fit.model, &eval, &engine).unwrap_or(f64::INFINITY);
        println!(
            "{:<10.4} {:>12.4} {:>10.4} {:>12.4} {:>6}",
            pt.lam_l,
            pt.mean_nll,
            pt.se_nll,
            eval_nll,
            if k == res.best { "<==" } else { "" }
        );
    }
    let refit = res.refit.as_ref().expect("refit requested");
    let model = res.model().expect("refit model");
    let refit_eval = heldout_nll(model, &eval, &engine).unwrap_or(f64::INFINITY);
    println!(
        "\nselected λ = ({:.4}, {:.4}); refit nnz(Λ) = {}, nnz(Θ) = {}, \
         eval NLL = {:.4}",
        res.best_lambda.0,
        res.best_lambda.1,
        model.lambda_nnz(),
        model.theta_nnz(),
        refit_eval,
    );
    println!(
        "cv: {} folds × {} points in {:.2}s ({} fold threads, {} KKT fallbacks, \
         refit path {} iters)",
        res.folds,
        res.points.len(),
        res.total_seconds,
        fold_threads,
        res.screen_fallbacks,
        refit.total_iters(),
    );
}
