//! λ-path demo: fit a decreasing regularization path with warm starts and
//! compare against cold starts — the support grows smoothly along the path,
//! covariance statistics are computed once, and each warm-started point
//! converges in a fraction of the cold-start iterations.
//!
//! ```bash
//! cargo run --release --example lambda_path -- [--q 200] [--n 100] [--points 10]
//! ```

use cggm::coordinator::{fit_path, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{SolveOptions, SolverKind};
use cggm::util::cli::Args;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let q = args.get_usize("q", 200);
    let p = args.get_usize("p", q);
    let n = args.get_usize("n", 100);
    let points = args.get_usize("points", 10);
    let min_ratio = args.get_f64("min-ratio", 0.05);
    let seed = args.get_u64("seed", 1);
    let kind = args
        .opt("solver")
        .map(|s| SolverKind::parse(s).expect("unknown solver"))
        .unwrap_or(SolverKind::AltNewtonCd);

    println!("== λ path: chain graph, p={p} q={q} n={n}, {points} points ==");
    let prob = datagen::chain::generate(p, q, n, seed);
    let engine = NativeGemm::new(args.get_usize("threads", 1));
    let base = SolveOptions {
        max_iter: args.get_usize("max-iter", 100),
        threads: args.get_usize("threads", 1),
        ..Default::default()
    };

    // Both legs pin ScreenRule::Full so the printed savings isolate warm
    // starts alone (the cold leg cannot screen, so leaving the default
    // strong rule on would conflate the two effects; the screening win is
    // bench_path's comparison).
    let warm_opts = PathOptions {
        points,
        min_ratio,
        lambdas: None,
        warm_start: true,
        screen: cggm::cggm::active::ScreenRule::Full,
        ..Default::default()
    };
    let cold_opts = PathOptions {
        warm_start: false,
        ..warm_opts.clone()
    };
    let warm = fit_path(kind, &prob.data, &base, &warm_opts, &engine).expect("warm path failed");
    let cold = fit_path(kind, &prob.data, &base, &cold_opts, &engine).expect("cold path failed");

    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>8} {:>14}",
        "lambda", "warm iters", "cold iters", "nnz(L)", "nnz(T)", "objective"
    );
    for (w, c) in warm.points.iter().zip(&cold.points) {
        println!(
            "{:<10.4} {:>10} {:>10} {:>8} {:>8} {:>14.4}",
            w.lam_l, w.iters, c.iters, w.lambda_nnz, w.theta_nnz, w.f
        );
    }
    println!(
        "\ntotals: warm {} iters in {:.2}s vs cold {} iters in {:.2}s ({:.2}x iteration savings)",
        warm.total_iters(),
        warm.total_seconds,
        cold.total_iters(),
        cold.total_seconds,
        if warm.total_iters() > 0 {
            cold.total_iters() as f64 / warm.total_iters() as f64
        } else {
            f64::NAN
        },
    );
}
