//! Serve-session walkthrough: one in-process [`ServeEngine`], one dataset,
//! repeat fits — showing what the warm registry buys (zero statistic
//! recomputation + model warm starts on every fit after the first) and
//! what admission control refuses.
//!
//! ```bash
//! cargo run --release --example serve_session -- [--p 200] [--n 120] [--jobs 4]
//! ```
//!
//! The same session over the wire:
//!
//! ```bash
//! printf '%s\n' \
//!   '{"op":"load","id":1,"name":"d","workload":"chain","p":200,"q":200,"n":120}' \
//!   '{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.4}' \
//!   '{"op":"fit","id":3,"dataset":"d","solver":"alt","lambda":0.4}' \
//!   '{"op":"stat","id":4}' | cggm serve --max-jobs 1
//! ```

use cggm::coordinator::RunConfig;
use cggm::gemm::native::NativeGemm;
use cggm::serve::{Request, ServeEngine};
use cggm::util::cli::Args;
use cggm::util::membudget::fmt_bytes;
use std::sync::Arc;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let p = args.get_usize("p", 200);
    let q = args.get_usize("q", p);
    let n = args.get_usize("n", 120);
    let jobs = args.get_usize("jobs", 4);

    let cfg = RunConfig {
        serve_max_jobs: 1,
        ..RunConfig::default()
    };
    let engine = ServeEngine::new(cfg, Arc::new(NativeGemm::new(args.get_usize("threads", 1))));

    println!("== cggm serve session: chain p={p} q={q} n={n}, {jobs} repeat fits ==");
    let load = engine.request(
        Request::parse_line(&format!(
            r#"{{"op":"load","id":1,"name":"d","workload":"chain","p":{p},"q":{q},"n":{n},"seed":1}}"#
        ))
        .unwrap(),
    );
    let lres = load.result().expect("load failed");
    println!(
        "load: warmed {} statistics, {} pinned, {:.2}s",
        lres.get("stat_computes").unwrap().as_f64().unwrap(),
        fmt_bytes(lres.get("pinned_bytes").unwrap().as_f64().unwrap() as usize),
        lres.get("seconds").unwrap().as_f64().unwrap(),
    );

    println!(
        "{:<6} {:>9} {:>12} {:>14} {:>13} {:>10}",
        "fit", "time(s)", "warm_start", "stat_computes", "registry_hit", "f"
    );
    for k in 0..jobs {
        let resp = engine.request(
            Request::parse_line(&format!(
                r#"{{"op":"fit","id":{},"dataset":"d","solver":"alt","lambda":0.4}}"#,
                k + 2
            ))
            .unwrap(),
        );
        let r = resp.result().expect("fit failed");
        println!(
            "{:<6} {:>9.3} {:>12} {:>14} {:>13} {:>10.4}",
            k + 1,
            r.get("seconds").unwrap().as_f64().unwrap(),
            r.get("warm_started").unwrap().as_bool().unwrap(),
            r.get("stat_computes").unwrap().as_f64().unwrap(),
            r.get("registry_hit").unwrap().as_bool().unwrap(),
            r.get("summary").unwrap().get("f").unwrap().as_f64().unwrap(),
        );
    }

    let stat = engine.request(Request::parse_line(r#"{"op":"stat","id":99}"#).unwrap());
    let sres = stat.result().expect("stat failed");
    let reg = sres.get("registry").unwrap();
    let budget = sres.get("budget").unwrap();
    println!(
        "stat: registry hits={} misses={} evictions={}; budget live={} peak={}",
        reg.get("hits").unwrap().as_f64().unwrap(),
        reg.get("misses").unwrap().as_f64().unwrap(),
        reg.get("evictions").unwrap().as_f64().unwrap(),
        fmt_bytes(budget.get("live").unwrap().as_f64().unwrap() as usize),
        fmt_bytes(budget.get("peak").unwrap().as_f64().unwrap() as usize),
    );
    engine.join();
    println!("session closed; every fit after the first reused the warm context.");
}
