//! End-to-end driver on a realistic workload — the §5.2 genomic analysis in
//! miniature, exercising every layer of the stack:
//!
//! 1. simulate an eQTL dataset (LD-blocked SNPs → clustered gene network);
//! 2. fit with all three solvers (the block solver under a memory budget,
//!    optionally on the PJRT/XLA engine) and report Table-1-style rows;
//! 3. validate: solvers agree on the objective; structure recovered.
//!
//! ```bash
//! cargo run --release --example genomic_e2e -- [--p 4000 --q 400] [--engine xla]
//! ```
//! The recorded run lives in EXPERIMENTS.md §End-to-end.

use cggm::coordinator::run_fit;
use cggm::datagen::genomic::{self, GenomicOptions};
use cggm::gemm::GemmEngine;
use cggm::metrics::{f1_edges_sym, f1_entries};
use cggm::runtime;
use cggm::solvers::{SolveOptions, SolverKind};
use cggm::util::cli::Args;
use cggm::util::membudget::{fmt_bytes, MemBudget};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["verbose"]);
    let p = args.get_usize("p", 3000);
    let q = args.get_usize("q", 300);
    let n = args.get_usize("n", 171);
    let seed = args.get_u64("seed", 42);
    let engine: std::sync::Arc<dyn GemmEngine> = match runtime::make_engine(
        &args.get_str("engine", "native"),
        args.get_usize("threads", 1),
        args.get_usize("tile", 256),
    ) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine unavailable ({e}); using native");
            std::sync::Arc::new(cggm::gemm::native::NativeGemm::new(1))
        }
    };

    println!("== genomic end-to-end: p={p} SNPs, q={q} genes, n={n} individuals ==");
    let t0 = std::time::Instant::now();
    let prob = genomic::generate(p, q, n, seed, &GenomicOptions::default());
    println!(
        "simulated dataset in {:.1}s (truth: {} network edges, {} eQTLs, {} non-empty SNP rows)",
        t0.elapsed().as_secs_f64(),
        prob.truth.lambda_edges(),
        prob.truth.theta_nnz(),
        prob.truth.theta.nonempty_rows()
    );

    let lam = args.get_f64("lambda", 0.14);
    let budget_bytes =
        cggm::util::membudget::parse_bytes(&args.get_str("mem-budget", "256MB")).unwrap();

    println!(
        "\n{:<16} {:>9} {:>7} {:>14} {:>8} {:>8} {:>7} {:>7} {:>10}",
        "solver", "time(s)", "iters", "objective", "nnz(L)", "nnz(T)", "F1(L)", "F1(T)", "peak mem"
    );
    let mut objectives = Vec::new();
    for kind in [
        SolverKind::NewtonCd,
        SolverKind::AltNewtonCd,
        SolverKind::AltNewtonBcd,
    ] {
        let budget = if kind == SolverKind::AltNewtonBcd {
            MemBudget::new(budget_bytes)
        } else {
            MemBudget::unlimited()
        };
        let opts = SolveOptions {
            lam_l: lam,
            lam_t: lam,
            max_iter: args.get_usize("max-iter", 60),
            threads: args.get_usize("threads", 1),
            time_limit: args.get_f64("time-limit", 1200.0),
            budget: budget.clone(),
            ..Default::default()
        };
        match run_fit(kind, &prob, &opts, engine.as_ref(), None) {
            Ok((sum, res)) => {
                let f1l = f1_edges_sym(&res.model.lambda, &prob.truth.lambda);
                let f1t = f1_entries(&res.model.theta, &prob.truth.theta);
                println!(
                    "{:<16} {:>9.2} {:>7} {:>14.4} {:>8} {:>8} {:>7.3} {:>7.3} {:>10}",
                    kind.name(),
                    sum.seconds,
                    sum.iters,
                    sum.f,
                    sum.lambda_nnz,
                    sum.theta_nnz,
                    f1l.f1,
                    f1t.f1,
                    if kind == SolverKind::AltNewtonBcd {
                        fmt_bytes(budget.peak())
                    } else {
                        "dense".into()
                    },
                );
                objectives.push(sum.f);
            }
            Err(e) => println!("{:<16} failed: {e}", kind.name()),
        }
    }
    // Validation: all solvers minimized the same convex objective.
    if objectives.len() >= 2 {
        let fmin = objectives.iter().cloned().fold(f64::INFINITY, f64::min);
        let fmax = objectives.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let spread = (fmax - fmin) / fmin.abs().max(1.0);
        println!("\nobjective agreement across solvers: relative spread {spread:.2e}");
        assert!(spread < 1e-2, "solvers disagree!");
        println!("e2e validation PASSED");
    }
}
