//! Quickstart: generate a chain-graph CGGM problem, fit it with the paper's
//! three solvers (pass `--with-prox` to add the FISTA baseline), and compare
//! time / objective / recovered structure.
//!
//! ```bash
//! cargo run --release --example quickstart -- [--q 500] [--n 100] [--solver alt]
//! ```

use cggm::cggm::Dataset;
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::metrics::f1_edges_sym;
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::util::cli::Args;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["verbose", "with-prox"]);
    let q = args.get_usize("q", 400);
    let p = args.get_usize("p", q);
    let n = args.get_usize("n", 100);
    let lam = args.get_f64("lambda", 0.3);
    let seed = args.get_u64("seed", 1);

    println!("== cggm quickstart: chain graph, p={p} q={q} n={n}, lambda={lam} ==");
    let t0 = std::time::Instant::now();
    let prob = datagen::chain::generate(p, q, n, seed);
    println!("data generated in {:.2}s", t0.elapsed().as_secs_f64());
    let data: &Dataset = &prob.data;
    let engine = NativeGemm::new(args.get_usize("threads", 1));

    let solvers: Vec<SolverKind> = match args.opt("solver") {
        Some(s) => vec![SolverKind::parse(s).expect("unknown solver")],
        None if args.flag("with-prox") => SolverKind::all().to_vec(),
        None => SolverKind::paper_three().to_vec(),
    };
    println!(
        "{:<16} {:>9} {:>7} {:>14} {:>8} {:>8} {:>6}",
        "solver", "time(s)", "iters", "objective", "nnz(L)", "nnz(T)", "F1(L)"
    );
    for kind in solvers {
        let opts = SolveOptions {
            lam_l: lam,
            lam_t: lam,
            max_iter: args.get_usize("max-iter", 50),
            threads: args.get_usize("threads", 1),
            ..Default::default()
        };
        let res = solve(kind, data, &opts, &engine).expect("solve failed");
        let f1 = f1_edges_sym(&res.model.lambda, &prob.truth.lambda);
        println!(
            "{:<16} {:>9.2} {:>7} {:>14.4} {:>8} {:>8} {:>6.3}",
            kind.name(),
            res.trace.total_seconds,
            res.trace.records.len(),
            res.trace.final_f().unwrap_or(f64::NAN),
            res.model.lambda_nnz(),
            res.model.theta_nnz(),
            f1.f1,
        );
        if args.flag("verbose") {
            for (phase, secs, calls) in &res.trace.phases {
                println!("    {phase:<20} {secs:>8.2}s ({calls} calls)");
            }
        }
    }
}
