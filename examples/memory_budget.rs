//! The paper's memory story, live: sweep the block solver's memory budget
//! and watch the caches shrink while the answer stays identical and the
//! peak working set stays under each budget — then compare against the
//! dense working set the non-block solvers would have needed.
//!
//! ```bash
//! cargo run --release --example memory_budget -- [--q 600] [--n 100]
//! ```

use cggm::coordinator::run_fit;
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{dense_workingset_bytes, SolveOptions, SolverKind};
use cggm::util::cli::Args;
use cggm::util::membudget::{fmt_bytes, MemBudget};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let q = args.get_usize("q", 600);
    let n = args.get_usize("n", 100);
    let lam = args.get_f64("lambda", 1.5);
    let engine = NativeGemm::new(1);

    println!("== memory-budget sweep: chain p=q={q}, n={n}, lambda={lam} ==");
    let prob = datagen::chain::generate(q, q, n, 3);
    println!(
        "dense working set the non-block solvers need:  AltNewtonCD {}  /  NewtonCD {}",
        fmt_bytes(dense_workingset_bytes(SolverKind::AltNewtonCd, q, q)),
        fmt_bytes(dense_workingset_bytes(SolverKind::NewtonCd, q, q)),
    );
    println!(
        "\n{:<12} {:>12} {:>9} {:>7} {:>14} {:>10}",
        "budget", "peak used", "time(s)", "iters", "objective", "converged"
    );
    let mut reference_f = None;
    for budget_str in ["4MB", "16MB", "64MB", "unlimited"] {
        let budget = match budget_str {
            "unlimited" => MemBudget::unlimited(),
            s => MemBudget::new(cggm::util::membudget::parse_bytes(s).unwrap()),
        };
        let opts = SolveOptions {
            lam_l: lam,
            lam_t: lam,
            max_iter: 60,
            budget: budget.clone(),
            ..Default::default()
        };
        match run_fit(SolverKind::AltNewtonBcd, &prob, &opts, &engine, None) {
            Ok((sum, _)) => {
                println!(
                    "{:<12} {:>12} {:>9.2} {:>7} {:>14.4} {:>10}",
                    budget_str,
                    fmt_bytes(budget.peak()),
                    sum.seconds,
                    sum.iters,
                    sum.f,
                    sum.converged,
                );
                if budget.limit() != usize::MAX {
                    assert!(budget.peak() <= budget.limit(), "budget violated!");
                }
                let f0 = *reference_f.get_or_insert(sum.f);
                assert!(
                    (sum.f - f0).abs() < 1e-4 * f0.abs().max(1.0),
                    "objective changed under budget {budget_str}: {} vs {f0}",
                    sum.f
                );
            }
            Err(e) => println!("{budget_str:<12} FAILED: {e}"),
        }
    }
    println!("\nsame optimum under every budget — the paper's §4 claim, reproduced.");
}
