"""Layer-2 JAX model: the CGGM negative log-likelihood (paper Eq. 1), its
analytic gradients (Eq. 3), and a Pallas-backed variant whose Gram hot spots
run through the Layer-1 kernels.

These functions are AOT-lowered to HLO text by `aot.py`; the small
fixed-shape objective/gradient artifacts double as a cross-language oracle —
a Rust integration test loads them via PJRT and compares against the Rust
objective implementation bit-for-nearly.

The linear algebra (Cholesky, triangular solves, logdet) is written in pure
lax ops rather than `jnp.linalg`: LAPACK-backed primitives lower to typed-FFI
custom-calls (API v4) that the `xla` crate's xla_extension 0.5.1 rejects at
compile time. The pure versions are validated against `jnp.linalg` in
pytest.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import gemm_pallas

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# Custom-call-free dense linear algebra (small q; oracle shapes only).
# ---------------------------------------------------------------------------

def cholesky(a):
    """Lower Cholesky factor via a fori_loop — no LAPACK custom-call."""
    q = a.shape[0]
    idx = jnp.arange(q)

    def body(j, l):
        row_j = l[j, :]
        mask = idx < j
        mrow = jnp.where(mask, row_j, 0.0)
        d = a[j, j] - jnp.sum(mrow * mrow)
        dj = jnp.sqrt(d)
        dots = l @ mrow  # (q,)
        col = (a[:, j] - dots) / dj
        col = jnp.where(idx > j, col, 0.0)
        l = l.at[:, j].set(col)
        l = l.at[j, j].set(dj)
        return l

    return lax.fori_loop(0, q, body, jnp.zeros_like(a))


def solve_lower(l, b):
    """Solve L y = b (b may be (q,) or (q, m)) by forward substitution."""
    q = l.shape[0]
    idx = jnp.arange(q)
    y0 = jnp.zeros_like(b)

    def body(i, y):
        row = jnp.where(idx < i, l[i, :], 0.0)
        s = row @ y
        return y.at[i].set((b[i] - s) / l[i, i])

    return lax.fori_loop(0, q, body, y0)


def solve_upper_t(l, b):
    """Solve Lᵀ x = b by backward substitution."""
    q = l.shape[0]
    idx = jnp.arange(q)
    x0 = jnp.zeros_like(b)

    def body(t, x):
        i = q - 1 - t
        col = jnp.where(idx > i, l[:, i], 0.0)
        s = col @ x
        return x.at[i].set((b[i] - s) / l[i, i])

    return lax.fori_loop(0, q, body, x0)


def chol_solve(l, b):
    """A x = b given A = LLᵀ."""
    return solve_upper_t(l, solve_lower(l, b))


def logdet_spd(a):
    l = cholesky(a)
    return 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))


# ---------------------------------------------------------------------------
# CGGM objective and gradients (Eqs. 1 and 3).
# ---------------------------------------------------------------------------

def cggm_smooth(lam, theta, syy, sxy, sxx):
    """g(Λ,Θ) = -log|Λ| + tr(S_yy Λ + 2 S_xyᵀΘ + Λ⁻¹ΘᵀS_xxΘ)."""
    l = cholesky(lam)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    tr1 = jnp.sum(syy * lam)
    tr2 = 2.0 * jnp.sum(sxy * theta)
    m = theta.T @ sxx @ theta
    tr3 = jnp.trace(chol_solve(l, m))
    return -logdet + tr1 + tr2 + tr3


def cggm_smooth_linalg(lam, theta, syy, sxy, sxx):
    """`jnp.linalg` reference of `cggm_smooth` — used by pytest (autodiff
    cross-check); NOT lowered to artifacts (LAPACK custom-calls)."""
    sign, logdet = jnp.linalg.slogdet(lam)
    tr1 = jnp.sum(syy * lam)
    tr2 = 2.0 * jnp.sum(sxy * theta)
    m = theta.T @ sxx @ theta
    tr3 = jnp.trace(jnp.linalg.solve(lam, m))
    return -sign * logdet + tr1 + tr2 + tr3


def cggm_objective(lam, theta, syy, sxy, sxx, reg_l, reg_t):
    """f = g + λ_Λ‖Λ‖₁ + λ_Θ‖Θ‖₁."""
    return (cggm_smooth(lam, theta, syy, sxy, sxx)
            + reg_l * jnp.sum(jnp.abs(lam))
            + reg_t * jnp.sum(jnp.abs(theta)))


def cggm_grads(lam, theta, syy, sxy, sxx):
    """Analytic gradients (Eq. 3):
    ∇_Λ g = S_yy - Σ - Ψ,  ∇_Θ g = 2 S_xy + 2 S_xxΘΣ."""
    q = lam.shape[0]
    l = cholesky(lam)
    sigma = chol_solve(l, jnp.eye(q, dtype=lam.dtype))
    ts = theta @ sigma
    psi = ts.T @ sxx @ ts
    grad_l = syy - sigma - psi
    grad_t = 2.0 * sxy + 2.0 * sxx @ ts
    return grad_l, grad_t


def cggm_smooth_pallas(lam, theta, x, y, *, block=128):
    """g(Λ,Θ) with the sample-Gram hot spots computed by the L1 Pallas
    kernels (composition check: L1 lowers inside the L2 graph).

    x: (n, p), y: (n, q), n/p/q divisible by `block`.
    """
    n = x.shape[0]
    syy = gemm_pallas.gemm_tn(y, y, bm=block, bk=block, bn=block) / n
    sxy = gemm_pallas.gemm_tn(x, y, bm=block, bk=block, bn=block) / n
    rt_ = gemm_pallas.matmul(x, theta, bm=block, bk=block, bn=block)  # XΘ
    m = gemm_pallas.gemm_tn(rt_, rt_, bm=block, bk=block, bn=block) / n
    l = cholesky(lam)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    tr1 = jnp.sum(syy * lam)
    tr2 = 2.0 * jnp.sum(sxy * theta)
    tr3 = jnp.trace(chol_solve(l, m))
    return -logdet + tr1 + tr2 + tr3
