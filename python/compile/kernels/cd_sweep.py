"""Layer-1 Pallas kernel: one coordinate-descent sweep over a diagonal
Λ-block — the paper's inner loop as a VMEM-resident kernel.

Hardware-adaptation story (DESIGN.md §8): the paper's block CD exists to keep
the working set (columns of Σ, Ψ) in CPU cache; on TPU the analogous move is
to pin the B×B block working set (Σ_B, Ψ_B, S_yy,B, Λ_B, Δ_B, U_B) in VMEM
and run the inherently-sequential CD recurrence inside the kernel with
`lax.fori_loop`, leaving HBM↔VMEM transfers at block granularity.

The sweep visits the upper triangle in row-major order, solves each 1-D
subproblem exactly (soft-thresholding), and maintains U = ΔΣ — bitwise the
same recurrence as the Rust implementation and `ref.cd_sweep_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _soft(w, r):
    return jnp.sign(w) * jnp.maximum(jnp.abs(w) - r, 0.0)


def _cd_kernel(syy_ref, sigma_ref, psi_ref, lam_ref, mask_ref, reg_ref,
               delta_in_ref, u_in_ref, delta_out_ref, u_out_ref, *, b: int):
    syy = syy_ref[...]
    sigma = sigma_ref[...]
    psi = psi_ref[...]
    lam = lam_ref[...]
    mask = mask_ref[...]
    reg = reg_ref[0, 0]

    def body(t, carry):
        delta, u = carry
        i = t // b
        j = t % b
        upper = j >= i
        act = (mask[i, j] != 0) & upper

        s_ij = sigma[i, j]
        s_ii = sigma[i, i]
        s_jj = sigma[j, j]
        p_ij = psi[i, j]
        p_ii = psi[i, i]
        p_jj = psi[j, j]
        diag = i == j

        a_off = s_ij * s_ij + s_ii * s_jj + s_ii * p_jj + s_jj * p_ii \
            + 2.0 * s_ij * p_ij
        a_diag = s_ii * s_ii + 2.0 * s_ii * p_ii
        a = jnp.where(diag, a_diag, a_off)

        lin_off = (syy[i, j] - s_ij - p_ij
                   + sigma[i, :] @ u[:, j]
                   + psi[i, :] @ u[:, j]
                   + psi[j, :] @ u[:, i])
        lin_diag = (syy[i, i] - s_ii - p_ii
                    + sigma[i, :] @ u[:, i]
                    + 2.0 * (psi[i, :] @ u[:, i]))
        lin = jnp.where(diag, lin_diag, lin_off)

        c = lam[i, j] + delta[i, j]
        mu = -c + _soft(c - lin / a, reg / a)
        mu = jnp.where(act, mu, 0.0)

        delta = delta.at[i, j].add(mu)
        delta = delta.at[j, i].add(jnp.where(diag, 0.0, mu))
        u = u.at[i, :].add(mu * sigma[j, :])
        u = u.at[j, :].add(jnp.where(diag, 0.0, mu) * sigma[i, :])
        return delta, u

    delta0 = delta_in_ref[...]
    u0 = u_in_ref[...]
    delta, u = lax.fori_loop(0, b * b, body, (delta0, u0))
    delta_out_ref[...] = delta
    u_out_ref[...] = u


@functools.partial(jax.jit, static_argnames=("interpret",))
def cd_block_sweep(syy, sigma, psi, lam, mask, reg, delta, u, *,
                   interpret=True):
    """Run one CD sweep over a B×B diagonal Λ-block.

    Args: all matrices (B, B) float64 (mask any numeric 0/1); ``reg`` is the
    scalar λ_Λ reshaped to (1, 1). Returns (delta, u) after the sweep.
    """
    b = syy.shape[0]
    specs = [pl.BlockSpec((b, b), lambda: (0, 0))] * 5 + [
        pl.BlockSpec((1, 1), lambda: (0, 0))
    ] + [pl.BlockSpec((b, b), lambda: (0, 0))] * 2
    return pl.pallas_call(
        functools.partial(_cd_kernel, b=b),
        grid=(),
        in_specs=specs,
        out_specs=[pl.BlockSpec((b, b), lambda: (0, 0))] * 2,
        out_shape=[
            jax.ShapeDtypeStruct((b, b), syy.dtype),
            jax.ShapeDtypeStruct((b, b), syy.dtype),
        ],
        interpret=interpret,
    )(syy, sigma, psi, lam, mask, jnp.asarray(reg).reshape(1, 1), delta, u)
