"""Pure-jnp / numpy oracles for the Pallas kernels — the build-time
correctness signal (pytest asserts allclose against these)."""

import jax.numpy as jnp
import numpy as np


def matmul_ref(a, b):
    return jnp.dot(a, b)


def gemm_tn_ref(a, b):
    return jnp.dot(a.T, b)


def gemm_nt_ref(a, b):
    return jnp.dot(a, b.T)


def soft_threshold(w, r):
    return np.sign(w) * np.maximum(np.abs(w) - r, 0.0)


def cd_sweep_ref(syy, sigma, psi, lam_mat, delta, u, active_mask, reg):
    """Reference CD sweep over one diagonal Λ-block (numpy loop).

    Mirrors `cggm::solvers::cd_common::lambda_cd_pass` restricted to a block:
    visits the upper triangle in row-major order, solves the 1-D problem
    exactly, updates delta (symmetric) and u = delta·sigma.

    All inputs are (B, B) arrays; `active_mask` is 0/1; `reg` is λ_Λ.
    Returns (delta, u).
    """
    b = syy.shape[0]
    delta = np.array(delta, dtype=np.float64, copy=True)
    u = np.array(u, dtype=np.float64, copy=True)
    syy = np.asarray(syy, dtype=np.float64)
    sigma = np.asarray(sigma, dtype=np.float64)
    psi = np.asarray(psi, dtype=np.float64)
    lam_mat = np.asarray(lam_mat, dtype=np.float64)
    for i in range(b):
        for j in range(i, b):
            if not active_mask[i, j]:
                continue
            s_ij, s_ii, s_jj = sigma[i, j], sigma[i, i], sigma[j, j]
            p_ij, p_ii, p_jj = psi[i, j], psi[i, i], psi[j, j]
            if i == j:
                a = s_ii * s_ii + 2.0 * s_ii * p_ii
                lin = (syy[i, i] - s_ii - p_ii
                       + sigma[i, :] @ u[:, i]
                       + 2.0 * (psi[i, :] @ u[:, i]))
            else:
                a = (s_ij * s_ij + s_ii * s_jj + s_ii * p_jj
                     + s_jj * p_ii + 2.0 * s_ij * p_ij)
                lin = (syy[i, j] - s_ij - p_ij
                       + sigma[i, :] @ u[:, j]
                       + psi[i, :] @ u[:, j]
                       + psi[j, :] @ u[:, i])
            c = lam_mat[i, j] + delta[i, j]
            mu = -c + soft_threshold(c - lin / a, reg / a)
            if mu != 0.0:
                delta[i, j] += mu
                if i != j:
                    delta[j, i] += mu
                # U = ΔΣ row updates: U[i,:] += μΣ[j,:], U[j,:] += μΣ[i,:].
                u[i, :] += mu * sigma[j, :]
                if i != j:
                    u[j, :] += mu * sigma[i, :]
    return delta, u


def lambda_block_model_value(syy, sigma, psi, lam_mat, delta, reg):
    """Quadratic-model objective of the block subproblem (for the
    monotonicity property test):
    tr(∇ᵀΔ) + ½[tr(ΣΔΣΔ) + 2tr(ΨΔΣΔ)] + λ‖Λ+Δ‖₁ with ∇ = S_yy - Σ - Ψ."""
    grad = syy - sigma - psi
    ds = delta @ sigma
    quad = np.trace(sigma @ delta @ ds) + 2.0 * np.trace(psi @ delta @ ds)
    lin = float(np.sum(grad * delta))
    return lin + 0.5 * quad + reg * float(np.abs(lam_mat + delta).sum())
