"""Layer-1 Pallas GEMM kernels — the paper's flop hot spot as TPU-shaped
tiled kernels.

The CGGM optimizers spend their dense-flop budget in three contraction
layouts (DESIGN.md §8):

- ``matmul``   C = A·B     (Σ·R̃ products, blocked Cholesky updates)
- ``gemm_tn``  C = Aᵀ·B    (Gram products over samples stored row-major)
- ``gemm_nt``  C = A·Bᵀ    (covariance blocks of feature-major data:
  ``Ψ = RᵀR/n``, ``S_xx`` tiles, ``S_xy`` blocks — the O(npq + nq²) terms)

Each kernel tiles the output into (bm × bn) blocks held in VMEM while
marching over the contraction dimension in bk-sized panels (grid axis 2),
accumulating in-place — the HBM↔VMEM schedule expressed via BlockSpec that
the paper expressed via CPU cache blocking. Block shapes default to
128×128×128 (MXU-aligned); ``interpret=True`` is mandatory on CPU-PJRT
(real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _mm_kernel(a_ref, b_ref, o_ref, *, nk: int):
    """C[i,j] += A[i,k]·B[k,j] with accumulation across the k grid axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _tn_kernel(a_ref, b_ref, o_ref, *, nk: int):
    """C[i,j] += Aᵀ[i,k]·B[k,j]: A panel arrives as (bk × bm)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=o_ref.dtype
    )


def _nt_kernel(a_ref, b_ref, o_ref, *, nk: int):
    """C[i,j] += A[i,k]·Bᵀ[k,j]: B panel arrives as (bn × bk)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...].T, preferred_element_type=o_ref.dtype
    )


def _check_divisible(name, dim, block):
    if dim % block != 0:
        raise ValueError(f"{name}={dim} not divisible by block {block}")


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(a, b, *, bm=DEFAULT_BLOCK, bk=DEFAULT_BLOCK, bn=DEFAULT_BLOCK,
           interpret=True):
    """C = A·B for A (m×k), B (k×n); m/k/n divisible by the block sizes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    _check_divisible("m", m, bm)
    _check_divisible("k", k, bk)
    _check_divisible("n", n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def gemm_tn(a, b, *, bm=DEFAULT_BLOCK, bk=DEFAULT_BLOCK, bn=DEFAULT_BLOCK,
            interpret=True):
    """C = Aᵀ·B for A (k×m), B (k×n)."""
    k, m = a.shape
    k2, n = b.shape
    assert k == k2
    _check_divisible("m", m, bm)
    _check_divisible("k", k, bk)
    _check_divisible("n", n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_tn_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def gemm_nt(a, b, *, bm=DEFAULT_BLOCK, bk=DEFAULT_BLOCK, bn=DEFAULT_BLOCK,
            interpret=True):
    """C = A·Bᵀ for A (m×k), B (n×k) — the covariance-block form."""
    m, k = a.shape
    n, k2 = b.shape
    assert k == k2
    _check_divisible("m", m, bm)
    _check_divisible("k", k, bk)
    _check_divisible("n", n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_nt_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


def vmem_bytes(bm, bk, bn, dtype_bytes=8):
    """VMEM working-set estimate for one grid step (perf analysis §Perf):
    A panel + B panel + C accumulator."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
