"""AOT lowering: JAX/Pallas → HLO *text* artifacts + manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax ≥0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` crate binds) rejects; the text parser reassigns ids.

Run once by `make artifacts`; Python never appears on the request path.

Artifacts:
- ``gemm_{mm,tn,nt}_{pallas,xla}_f64_{T}`` — square-tile GEMMs in the three
  contraction layouts the solvers use, in both a Pallas-kernel variant (L1)
  and a plain ``jnp.dot`` variant (XLA-native baseline for the engine
  ablation bench);
- ``cd_sweep_pallas_f64_b{B}`` — the CD block-sweep kernel;
- ``cggm_obj_f64`` / ``cggm_grads_f64`` — small fixed-shape L2 objective and
  analytic gradients, loaded by Rust integration tests as a cross-language
  numerical oracle.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from .kernels import cd_sweep, gemm_pallas  # noqa: E402
from . import model  # noqa: E402

GEMM_TILES = (128, 256)
CD_BLOCK = 32
ORACLE_P, ORACLE_Q = 24, 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float64):
    return jax.ShapeDtypeStruct(shape, dtype)


def manifest_input(shape, dtype="f64"):
    return {"shape": list(shape), "dtype": dtype}


def build_artifacts(outdir: str, tiles=GEMM_TILES, quick=False) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {}

    def emit(name, lowered, kind, inputs, outputs, **extra):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        entry = {
            "file": fname,
            "kind": kind,
            "inputs": inputs,
            "outputs": outputs,
        }
        entry.update(extra)
        manifest[name] = entry
        print(f"  wrote {fname} ({len(text)} chars)", file=sys.stderr)

    # ---- GEMM tiles ----
    layouts = {
        "mm": (lambda a, b: (gemm_pallas.matmul(a, b),),
               lambda a, b: (jnp.dot(a, b),),
               lambda t: ((t, t), (t, t))),
        "tn": (lambda a, b: (gemm_pallas.gemm_tn(a, b),),
               lambda a, b: (jnp.dot(a.T, b),),
               lambda t: ((t, t), (t, t))),
        "nt": (lambda a, b: (gemm_pallas.gemm_nt(a, b),),
               lambda a, b: (jnp.dot(a, b.T),),
               lambda t: ((t, t), (t, t))),
    }
    tiles = tiles if not quick else (128,)
    for t in tiles:
        for lname, (pallas_fn, xla_fn, shapes) in layouts.items():
            sa, sb = shapes(t)
            for variant, fn in (("pallas", pallas_fn), ("xla", xla_fn)):
                if quick and variant == "pallas" and lname != "nt":
                    continue
                name = f"gemm_{lname}_{variant}_f64_{t}"
                lowered = jax.jit(fn).lower(spec(sa), spec(sb))
                emit(
                    name, lowered, f"gemm_{lname}",
                    [manifest_input(sa), manifest_input(sb)],
                    [manifest_input((t, t))],
                    block=t, variant=variant,
                )

    # ---- CD block sweep ----
    b = CD_BLOCK
    bb = (b, b)
    lowered = jax.jit(
        lambda syy, sg, ps, lm, mk, rg, dl, u: tuple(
            cd_sweep.cd_block_sweep(syy, sg, ps, lm, mk, rg, dl, u)
        )
    ).lower(*([spec(bb)] * 5 + [spec((1, 1))] + [spec(bb)] * 2))
    emit(
        f"cd_sweep_pallas_f64_b{b}", lowered, "cd_sweep",
        [manifest_input(bb)] * 5 + [manifest_input((1, 1))]
        + [manifest_input(bb)] * 2,
        [manifest_input(bb), manifest_input(bb)],
        block=b, variant="pallas",
    )

    # ---- L2 oracle: objective + gradients at fixed small shapes ----
    p, q = ORACLE_P, ORACLE_Q
    lowered = jax.jit(
        lambda lam, th, syy, sxy, sxx, rl, rt:
        (model.cggm_objective(lam, th, syy, sxy, sxx, rl, rt),)
    ).lower(
        spec((q, q)), spec((p, q)), spec((q, q)), spec((p, q)), spec((p, p)),
        spec(()), spec(()),
    )
    emit(
        "cggm_obj_f64", lowered, "cggm_obj",
        [manifest_input((q, q)), manifest_input((p, q)),
         manifest_input((q, q)), manifest_input((p, q)),
         manifest_input((p, p)), manifest_input(()), manifest_input(())],
        [manifest_input(())],
        p=p, q=q,
    )
    lowered = jax.jit(model.cggm_grads).lower(
        spec((q, q)), spec((p, q)), spec((q, q)), spec((p, q)), spec((p, p))
    )
    emit(
        "cggm_grads_f64", lowered, "cggm_grads",
        [manifest_input((q, q)), manifest_input((p, q)),
         manifest_input((q, q)), manifest_input((p, q)),
         manifest_input((p, p))],
        [manifest_input((q, q)), manifest_input((p, q))],
        p=p, q=q,
    )

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest, "dtype": "f64"}, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--quick", action="store_true",
                    help="small subset (CI smoke)")
    args = ap.parse_args()
    manifest = build_artifacts(args.out, quick=args.quick)
    print(f"wrote {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
