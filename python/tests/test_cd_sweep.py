"""CD block-sweep Pallas kernel vs the numpy-loop oracle, plus the
monotone-decrease property of the quadratic model."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import cd_sweep, ref


def make_block(rng, b):
    a = rng.standard_normal((b + 2, b))
    sigma = a.T @ a + np.eye(b) * b
    r = rng.standard_normal((4, b))
    psi = r.T @ r
    y = rng.standard_normal((b + 3, b))
    syy = y.T @ y / (b + 3)
    lam = np.eye(b) + 0.1 * np.diag(rng.random(b))
    mask = (rng.random((b, b)) < 0.8).astype(np.float64)
    mask = np.triu(mask)
    mask = mask + np.triu(mask, 1).T
    return syy, sigma, psi, lam, mask


@settings(max_examples=15, deadline=None)
@given(b=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31),
       reg=st.floats(0.01, 2.0))
def test_kernel_matches_loop_reference(b, seed, reg):
    rng = np.random.default_rng(seed)
    syy, sigma, psi, lam, mask = make_block(rng, b)
    delta0 = np.zeros((b, b))
    u0 = np.zeros((b, b))
    d_ref, u_ref = ref.cd_sweep_ref(syy, sigma, psi, lam, delta0, u0, mask, reg)
    d_k, u_k = cd_sweep.cd_block_sweep(
        jnp.asarray(syy), jnp.asarray(sigma), jnp.asarray(psi),
        jnp.asarray(lam), jnp.asarray(mask), reg,
        jnp.asarray(delta0), jnp.asarray(u0))
    np.testing.assert_allclose(np.asarray(d_k), d_ref, atol=1e-10)
    np.testing.assert_allclose(np.asarray(u_k), u_ref, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_sweep_decreases_quadratic_model(seed):
    b = 12
    rng = np.random.default_rng(seed)
    syy, sigma, psi, lam, mask = make_block(rng, b)
    reg = 0.25
    delta = np.zeros((b, b))
    u = np.zeros((b, b))
    prev = ref.lambda_block_model_value(syy, sigma, psi, lam, delta, reg)
    for _ in range(3):
        delta, u = ref.cd_sweep_ref(syy, sigma, psi, lam, delta, u, mask, reg)
        cur = ref.lambda_block_model_value(syy, sigma, psi, lam, delta, reg)
        assert cur <= prev + 1e-9
        prev = cur


def test_delta_stays_symmetric_and_warm_startable():
    rng = np.random.default_rng(3)
    b = 8
    syy, sigma, psi, lam, mask = make_block(rng, b)
    d1, u1 = ref.cd_sweep_ref(syy, sigma, psi, lam,
                              np.zeros((b, b)), np.zeros((b, b)), mask, 0.2)
    np.testing.assert_allclose(d1, d1.T, atol=1e-12)
    # Warm-started second sweep through the kernel matches the reference.
    d2_ref, u2_ref = ref.cd_sweep_ref(syy, sigma, psi, lam, d1, u1, mask, 0.2)
    d2_k, u2_k = cd_sweep.cd_block_sweep(
        jnp.asarray(syy), jnp.asarray(sigma), jnp.asarray(psi),
        jnp.asarray(lam), jnp.asarray(mask), 0.2,
        jnp.asarray(d1), jnp.asarray(u1))
    np.testing.assert_allclose(np.asarray(d2_k), d2_ref, atol=1e-10)
    np.testing.assert_allclose(np.asarray(u2_k), u2_ref, atol=1e-10)
