"""Layer-2 model checks: the JAX objective matches a hand-rolled numpy
computation, `jax.grad` matches the paper's analytic gradients (Eq. 3), and
the Pallas-backed variant matches the plain-jnp graph."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def make_problem(rng, n, p, q):
    x = rng.standard_normal((n, p))
    y = rng.standard_normal((n, q))
    syy = y.T @ y / n
    sxy = x.T @ y / n
    sxx = x.T @ x / n
    a = rng.standard_normal((q + 3, q))
    lam = a.T @ a / q + np.eye(q)
    theta = rng.standard_normal((p, q)) * (rng.random((p, q)) < 0.3)
    return x, y, lam, theta, syy, sxy, sxx


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_objective_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    _, _, lam, theta, syy, sxy, sxx = make_problem(rng, 20, 6, 5)
    got = float(model.cggm_objective(
        jnp.asarray(lam), jnp.asarray(theta), jnp.asarray(syy),
        jnp.asarray(sxy), jnp.asarray(sxx), 0.3, 0.2))
    sign, logdet = np.linalg.slogdet(lam)
    want = (-logdet + np.sum(syy * lam) + 2 * np.sum(sxy * theta)
            + np.trace(np.linalg.solve(lam, theta.T @ sxx @ theta))
            + 0.3 * np.abs(lam).sum() + 0.2 * np.abs(theta).sum())
    assert sign > 0
    np.testing.assert_allclose(got, want, rtol=1e-10)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_autodiff_matches_analytic_gradients(seed):
    """jax.grad of g (Eq. 1) == the paper's Eq. 3 formulas.

    Differentiates the jnp.linalg reference (the custom-call-free variant is
    loop-based and only needed for AOT; its values are cross-checked against
    this reference elsewhere)."""
    rng = np.random.default_rng(seed)
    _, _, lam, theta, syy, sxy, sxx = make_problem(rng, 15, 5, 4)
    args = [jnp.asarray(v) for v in (lam, theta, syy, sxy, sxx)]
    gl_auto, gt_auto = jax.grad(model.cggm_smooth_linalg, argnums=(0, 1))(*args)
    gl, gt = model.cggm_grads(*args)
    # jax.grad of tr-style objectives treats Λ's entries independently; the
    # analytic ∇_Λ is the same because Λ enters symmetrically.
    np.testing.assert_allclose(np.asarray(gl_auto), np.asarray(gl),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(gt_auto), np.asarray(gt),
                               rtol=1e-8, atol=1e-10)


def test_pallas_backed_objective_matches_jnp():
    rng = np.random.default_rng(0)
    n, p, q = 64, 32, 32
    x, y, lam, theta, *_ = make_problem(rng, n, p, q)
    got = float(model.cggm_smooth_pallas(
        jnp.asarray(lam), jnp.asarray(theta), jnp.asarray(x),
        jnp.asarray(y), block=32))
    syy = y.T @ y / n
    sxy = x.T @ y / n
    sxx = x.T @ x / n
    want = float(model.cggm_smooth(
        jnp.asarray(lam), jnp.asarray(theta), jnp.asarray(syy),
        jnp.asarray(sxy), jnp.asarray(sxx)))
    np.testing.assert_allclose(got, want, rtol=1e-9)


def test_gradient_zero_at_stationary_gaussian():
    """With Θ = 0 and Λ = S_yy⁻¹ the Λ-gradient vanishes (GGM stationarity),
    a closed-form sanity anchor."""
    rng = np.random.default_rng(5)
    q, p, n = 4, 3, 50
    y = rng.standard_normal((n, q))
    syy = y.T @ y / n
    lam = np.linalg.inv(syy)
    theta = np.zeros((p, q))
    sxy = np.zeros((p, q))
    sxx = np.eye(p)
    gl, gt = model.cggm_grads(*[jnp.asarray(v) for v in
                                (lam, theta, syy, sxy, sxx)])
    np.testing.assert_allclose(np.asarray(gl), 0.0, atol=1e-9)
    np.testing.assert_allclose(np.asarray(gt), 0.0, atol=1e-12)


def test_pure_linalg_matches_jnp_linalg():
    """The custom-call-free Cholesky/solve must match jnp.linalg."""
    rng = np.random.default_rng(7)
    q = 12
    a = rng.standard_normal((q + 4, q))
    spd = jnp.asarray(a.T @ a + np.eye(q) * q)
    l = model.cholesky(spd)
    np.testing.assert_allclose(np.asarray(l), np.linalg.cholesky(spd),
                               rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(float(model.logdet_spd(spd)),
                               float(np.linalg.slogdet(spd)[1]), rtol=1e-10)
    b = jnp.asarray(rng.standard_normal((q, 3)))
    x = model.chol_solve(l, b)
    np.testing.assert_allclose(np.asarray(x), np.linalg.solve(spd, b),
                               rtol=1e-8, atol=1e-10)
