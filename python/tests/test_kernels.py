"""Pallas GEMM kernels vs the pure-jnp oracle (`ref.py`) — hypothesis sweeps
shapes and dtypes, asserting allclose (the L1 correctness signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_pallas, ref

BLOCK = 32  # small blocks keep interpret-mode sweeps fast


def rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


dims = st.integers(min_value=1, max_value=3).map(lambda k: k * BLOCK)
dtypes = st.sampled_from([jnp.float32, jnp.float64])


@settings(max_examples=12, deadline=None)
@given(m=dims, k=dims, n=dims, dtype=dtypes, seed=st.integers(0, 2**31))
def test_matmul_matches_ref(m, k, n, dtype, seed):
    a = rand((m, k), dtype, seed)
    b = rand((k, n), dtype, seed + 1)
    got = gemm_pallas.matmul(a, b, bm=BLOCK, bk=BLOCK, bn=BLOCK)
    want = ref.matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(m=dims, k=dims, n=dims, dtype=dtypes, seed=st.integers(0, 2**31))
def test_gemm_tn_matches_ref(m, k, n, dtype, seed):
    a = rand((k, m), dtype, seed)
    b = rand((k, n), dtype, seed + 2)
    got = gemm_pallas.gemm_tn(a, b, bm=BLOCK, bk=BLOCK, bn=BLOCK)
    want = ref.gemm_tn_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(m=dims, k=dims, n=dims, dtype=dtypes, seed=st.integers(0, 2**31))
def test_gemm_nt_matches_ref(m, k, n, dtype, seed):
    a = rand((m, k), dtype, seed)
    b = rand((n, k), dtype, seed + 3)
    got = gemm_pallas.gemm_nt(a, b, bm=BLOCK, bk=BLOCK, bn=BLOCK)
    want = ref.gemm_nt_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_gram_via_nt_is_symmetric_psd():
    a = rand((64, 32), jnp.float64, 9)
    g = np.asarray(gemm_pallas.gemm_nt(a, a, bm=BLOCK, bk=BLOCK, bn=BLOCK))
    np.testing.assert_allclose(g, g.T, atol=1e-12)
    eigs = np.linalg.eigvalsh(g)
    assert eigs.min() > -1e-9


def test_rejects_indivisible_shapes():
    a = rand((33, 32), jnp.float64, 1)
    b = rand((32, 32), jnp.float64, 2)
    with pytest.raises(ValueError):
        gemm_pallas.matmul(a, b, bm=BLOCK, bk=BLOCK, bn=BLOCK)


def test_vmem_estimate():
    # 128³ f64 tiles: 3 buffers × 128² × 8B = 384 KiB ≪ 16 MiB VMEM.
    assert gemm_pallas.vmem_bytes(128, 128, 128) == 3 * 128 * 128 * 8
    assert gemm_pallas.vmem_bytes(128, 128, 128) < 16 * 2**20
