"""AOT pipeline checks: artifacts lower, the manifest is consistent, and the
HLO text has the entry signature the Rust runtime expects."""

import json
import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_artifacts(str(out), quick=True)
    return out, manifest


def test_manifest_lists_every_file(built):
    out, manifest = built
    with open(out / "manifest.json") as f:
        doc = json.load(f)
    assert doc["artifacts"].keys() == manifest.keys()
    for name, entry in manifest.items():
        path = out / entry["file"]
        assert path.exists(), name
        assert path.stat().st_size > 0


def test_hlo_text_has_entry_computation(built):
    out, manifest = built
    for name, entry in manifest.items():
        text = (out / entry["file"]).read_text()
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def test_gemm_artifact_shapes(built):
    _, manifest = built
    entry = manifest["gemm_nt_xla_f64_128"]
    assert entry["inputs"][0]["shape"] == [128, 128]
    assert entry["outputs"][0]["shape"] == [128, 128]
    # Entry signature mentions f64 parameters of the right rank.
    assert entry["kind"] == "gemm_nt"


def test_oracle_artifacts_present(built):
    _, manifest = built
    assert "cggm_obj_f64" in manifest
    assert "cggm_grads_f64" in manifest
    obj = manifest["cggm_obj_f64"]
    assert obj["p"] == aot.ORACLE_P
    assert obj["q"] == aot.ORACLE_Q
    # 7 inputs: Λ, Θ, S_yy, S_xy, S_xx, λ_Λ, λ_Θ.
    assert len(obj["inputs"]) == 7


def test_hlo_is_parseable_shape_line(built):
    out, manifest = built
    text = (out / manifest["gemm_nt_xla_f64_128"]["file"]).read_text()
    m = re.search(r"ENTRY.*?\((.*?)\)", text, re.S)
    assert m, "no ENTRY parameter list"
    assert "f64[128,128]" in text
