//! The serve subsystem end to end, in-process: warm-context reuse across
//! repeat fits, admission control against one shared budget, registry LRU
//! eviction, per-dataset sequencing, and `batch` ↔ standalone equivalence.

use cggm::coordinator::{self, PathOptions, RunConfig};
use cggm::datagen::Workload;
use cggm::gemm::native::NativeGemm;
use cggm::runtime::manifest::JobManifest;
use cggm::serve::engine::{fit_estimate, load_estimate};
use cggm::serve::{run_batch, ErrKind, Request, ServeEngine, ServerLine};
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::util::json::Json;
use std::sync::Arc;

fn engine(max_jobs: usize, budget: Option<usize>) -> ServeEngine {
    let cfg = RunConfig {
        serve_max_jobs: max_jobs,
        serve_budget: budget,
        ..RunConfig::default()
    };
    ServeEngine::new(cfg, Arc::new(NativeGemm::new(1)))
}

fn req(line: &str) -> Request {
    Request::parse_line(line).expect("test request must parse")
}

fn num(doc: &Json, key: &str) -> f64 {
    doc.get(key)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing number '{key}' in {}", doc.to_string()))
}

fn flag(doc: &Json, key: &str) -> bool {
    doc.get(key)
        .and_then(|v| v.as_bool())
        .unwrap_or_else(|| panic!("missing bool '{key}' in {}", doc.to_string()))
}

/// Acceptance: the second identical `fit` is a registry hit with a warm
/// start and zero statistic recomputation, and reaches the same optimum.
#[test]
fn repeat_fit_reuses_warm_context_without_stat_recompute() {
    let srv = engine(1, None);
    let load = srv.request(req(
        r#"{"op":"load","id":1,"name":"d","workload":"chain","p":14,"q":14,"n":80,"seed":5}"#,
    ));
    assert!(load.is_ok(), "{:?}", load.outcome);
    let lres = load.result().unwrap();
    assert_eq!(num(lres, "stat_computes"), 3.0, "eager warm = all 3 stats");
    assert!(num(lres, "pinned_bytes") > 0.0);
    assert!(!flag(lres, "already_loaded"));

    let fit_line =
        r#"{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.4,"tol":0.00001,"max_iter":120}"#;
    let fit1 = srv.request(req(fit_line));
    assert!(fit1.is_ok(), "{:?}", fit1.outcome);
    let r1 = fit1.result().unwrap();
    assert!(flag(r1, "registry_hit"));
    assert!(!flag(r1, "warm_started"), "first fit is cold");
    assert!(!flag(r1, "warm_model_reused"));
    assert_eq!(
        num(r1, "stat_computes"),
        0.0,
        "statistics were warmed at load; the fit must not recompute them"
    );

    let fit2 = srv.request(req(fit_line));
    let r2 = fit2.result().unwrap();
    assert!(flag(r2, "registry_hit"));
    assert!(flag(r2, "warm_started"), "second fit reuses the cached model");
    assert!(flag(r2, "warm_model_reused"));
    assert_eq!(num(r2, "stat_computes"), 0.0, "zero S_yy/S_xx/S_xy recompute");
    // The trace satellite: warm-start reuse is visible in the trace JSON.
    assert!(!flag(r1.get("trace").unwrap(), "warm_started"));
    assert!(flag(r2.get("trace").unwrap(), "warm_started"));
    // Same optimum either way.
    let (f1, f2) = (
        num(r1.get("summary").unwrap(), "f"),
        num(r2.get("summary").unwrap(), "f"),
    );
    assert!(
        (f1 - f2).abs() <= 1e-6 * f1.abs().max(1.0),
        "warm refit diverged: {f1} vs {f2}"
    );

    // Observability: per-dataset counters in `stat`.
    let stat = srv.request(req(r#"{"op":"stat","id":3}"#));
    let sres = stat.result().unwrap();
    let reg = sres.get("registry").unwrap();
    assert_eq!(num(reg, "hits"), 2.0, "both fits hit the registry");
    let ds = &reg.get("datasets").unwrap().as_arr().unwrap()[0];
    assert_eq!(num(ds, "jobs"), 2.0);
    assert_eq!(num(ds, "warm_reuses"), 1.0);
    assert_eq!(num(ds, "stat_computes"), 3.0);
    // The cached warm-start model is visible per dataset.
    let cached = ds.get("cached_models").unwrap().as_arr().unwrap();
    assert_eq!(cached.len(), 1);
    assert_eq!(cached[0].as_str(), Some("alt_newton_cd"));
    // A session that never set "stream":true has no stream subscribers,
    // no live per-job states (the probing stat itself is excluded), and
    // no cancellations.
    let jobs = sres.get("jobs").unwrap();
    assert_eq!(num(jobs, "stream_subscribers"), 0.0);
    assert_eq!(num(jobs, "cancelled"), 0.0);
    assert_eq!(num(jobs, "running"), 0.0);
    assert!(jobs.get("states").unwrap().as_arr().unwrap().is_empty());
    // Tile counters are always emitted; a dense-mode dataset reports zeros.
    assert_eq!(num(ds, "tiles_computed"), 0.0);
    assert_eq!(num(ds, "tile_hits"), 0.0);
    assert_eq!(num(ds, "tile_evictions"), 0.0);

    // Evict frees every pinned byte; the dataset is then a miss.
    let evict = srv.request(req(r#"{"op":"evict","id":4,"dataset":"d"}"#));
    assert!(evict.is_ok());
    assert!(num(evict.result().unwrap(), "freed_bytes") > 0.0);
    assert_eq!(srv.budget().live(), 0, "eviction must free every byte");
    let gone = srv.request(req(fit_line));
    assert_eq!(gone.err_kind(), Some(ErrKind::NotFound));
    srv.join();
}

/// Acceptance: an over-budget job is rejected with a structured `budget`
/// error and the session keeps serving.
#[test]
fn over_budget_jobs_fail_fast_and_session_survives() {
    let (p, q, n) = (12usize, 12usize, 60usize);
    let limit = load_estimate(p, q, n, true, 1)
        + fit_estimate(SolverKind::AltNewtonCd, p, q, 1)
        + (8 * n * (p + q));
    let srv = engine(1, Some(limit));
    let ok = srv.request(req(
        r#"{"op":"load","id":1,"name":"small","workload":"chain","p":12,"q":12,"n":60,"seed":3}"#,
    ));
    assert!(ok.is_ok(), "{:?}", ok.outcome);

    // A dataset that can never fit is rejected at submit, structurally.
    let big = srv.request(req(
        r#"{"op":"load","id":2,"name":"big","workload":"chain","p":600,"q":600,"n":50,"seed":3}"#,
    ));
    assert_eq!(big.err_kind(), Some(ErrKind::Budget), "{:?}", big.outcome);

    // So is a job whose own working set cannot fit next to its dataset.
    let wide_cv = srv.request(req(
        r#"{"op":"cv","id":3,"dataset":"small","solver":"alt","cv_folds":3,"cv_threads":64}"#,
    ));
    assert_eq!(wide_cv.err_kind(), Some(ErrKind::Budget));

    // The session keeps serving: the same fit that always fit still runs.
    let fit = srv.request(req(
        r#"{"op":"fit","id":4,"dataset":"small","solver":"alt","lambda":0.4}"#,
    ));
    assert!(fit.is_ok(), "{:?}", fit.outcome);
    let stat = srv.request(req(r#"{"op":"stat","id":5}"#));
    let jobs = stat.result().unwrap().get("jobs").unwrap();
    assert!(num(jobs, "rejected") >= 2.0);
    srv.join();
}

/// Concurrent jobs draw on one shared `MemBudget`: the cap is never
/// exceeded (enforced by the budget itself, scheduled by admission).
#[test]
fn concurrent_jobs_share_one_budget_within_cap() {
    let (p, q, n) = (12usize, 12usize, 60usize);
    let per = load_estimate(p, q, n, true, 1) + fit_estimate(SolverKind::AltNewtonCd, p, q, 1);
    let limit = 4 * per;
    let srv = engine(2, Some(limit));
    for (id, name, seed) in [(1, "a", 7), (2, "b", 8)] {
        let resp = srv.request(req(&format!(
            r#"{{"op":"load","id":{id},"name":"{name}","workload":"chain","p":{p},"q":{q},"n":{n},"seed":{seed}}}"#,
        )));
        assert!(resp.is_ok(), "{:?}", resp.outcome);
    }
    // Four fits across two datasets, two workers; all must succeed without
    // ever pushing the shared budget past its cap.
    let (tx, rx) = std::sync::mpsc::channel();
    for (id, name) in [(3, "a"), (4, "b"), (5, "a"), (6, "b")] {
        srv.submit(
            req(&format!(
                r#"{{"op":"fit","id":{id},"dataset":"{name}","solver":"alt","lambda":0.4}}"#,
            )),
            &tx,
        );
    }
    drop(tx);
    let responses: Vec<_> = rx
        .into_iter()
        .filter_map(|line| match line {
            ServerLine::Done(resp) => Some(resp),
            ServerLine::Progress(_) => None,
        })
        .collect();
    assert_eq!(responses.len(), 4);
    for resp in &responses {
        assert!(resp.is_ok(), "{:?}", resp.outcome);
    }
    assert!(srv.budget().peak() > 0);
    assert!(
        srv.budget().peak() <= limit,
        "shared budget exceeded: peak {} > limit {}",
        srv.budget().peak(),
        limit
    );
    srv.join();
}

/// Loading past the budget evicts idle LRU entries and frees their bytes.
#[test]
fn registry_lru_eviction_frees_bytes_under_pressure() {
    let (p, q, n) = (40usize, 40usize, 30usize);
    let pin = 8 * n * (p + q) + 8 * 3 * p * q; // raw data + three stats
    let limit = load_estimate(p, q, n, true, 1) + pin / 2;
    let srv = engine(1, Some(limit));
    let first = srv.request(req(&format!(
        r#"{{"op":"load","id":1,"name":"old","workload":"chain","p":{p},"q":{q},"n":{n},"seed":1}}"#,
    )));
    assert!(first.is_ok(), "{:?}", first.outcome);
    let live_one = srv.budget().live();
    assert!(live_one > 0);
    // The second dataset cannot fit next to the first: the idle LRU entry
    // is evicted to make room.
    let second = srv.request(req(&format!(
        r#"{{"op":"load","id":2,"name":"new","workload":"chain","p":{p},"q":{q},"n":{n},"seed":2}}"#,
    )));
    assert!(second.is_ok(), "{:?}", second.outcome);
    assert!(
        srv.budget().live() <= live_one + pin / 2,
        "evicted bytes were not freed: live {} after second load",
        srv.budget().live()
    );
    let stat = srv.request(req(r#"{"op":"stat","id":3}"#));
    let reg = stat.result().unwrap().get("registry").unwrap();
    assert!(num(reg, "evictions") >= 1.0);
    let datasets = reg.get("datasets").unwrap().as_arr().unwrap();
    assert_eq!(datasets.len(), 1, "only the new dataset survives");
    assert_eq!(datasets[0].get("name").unwrap().as_str(), Some("new"));
    // The evicted dataset is now a structured miss.
    let gone = srv.request(req(
        r#"{"op":"fit","id":4,"dataset":"old","solver":"alt","lambda":0.4}"#,
    ));
    assert_eq!(gone.err_kind(), Some(ErrKind::NotFound));
    srv.join();
}

/// Acceptance: `batch` on a manifest of ≥3 jobs is 1e-6-equivalent to
/// running each job standalone — the daemon and offline sweeps share one
/// code path.
#[test]
fn batch_manifest_matches_standalone_runs() {
    let manifest = JobManifest::parse(
        r#"{"defaults": {"solver": "alt", "tol": 0.00001, "max_iter": 120},
            "jobs": [
              {"op": "load", "name": "d", "workload": "chain",
               "p": 10, "q": 10, "n": 70, "seed": 9},
              {"op": "fit", "dataset": "d", "lambda": 0.5, "warm": false},
              {"op": "fit", "dataset": "d", "lambda": 0.3, "warm": false},
              {"op": "fit", "dataset": "d", "lambda": 0.3},
              {"op": "path", "dataset": "d", "path_points": 3}
            ]}"#,
    )
    .unwrap();
    let srv = engine(2, None);
    let outcome = run_batch(&srv, &manifest);
    srv.join();
    assert_eq!(outcome.failures, 0, "{}", outcome.to_jsonl());
    assert_eq!(outcome.responses.len(), 5);
    // Ordered by id == manifest position.
    let ids: Vec<u64> = outcome.responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![1, 2, 3, 4, 5]);

    // Standalone references on the identical generated dataset.
    let prob = coordinator::generate_problem(Workload::Chain, 10, 10, 70, 9);
    let eng = NativeGemm::new(1);
    let opts = |lam: f64| SolveOptions {
        lam_l: lam,
        lam_t: lam,
        tol: 0.00001,
        max_iter: 120,
        ..Default::default()
    };
    let close = |a: f64, b: f64, what: &str| {
        assert!(
            (a - b).abs() <= 1e-6 * a.abs().max(1.0),
            "{what}: batch {b} vs standalone {a}"
        );
    };
    for (idx, lam) in [(1usize, 0.5), (2, 0.3), (3, 0.3)] {
        let standalone = solve(SolverKind::AltNewtonCd, &prob.data, &opts(lam), &eng).unwrap();
        let got = num(
            outcome.responses[idx].result().unwrap().get("summary").unwrap(),
            "f",
        );
        close(
            standalone.trace.final_f().unwrap(),
            got,
            &format!("fit lambda={lam}"),
        );
    }
    let popts = PathOptions {
        points: 3,
        ..Default::default()
    };
    let standalone_path = coordinator::fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &opts(0.5),
        &popts,
        &eng,
    )
    .unwrap();
    let batch_path = outcome.responses[4].result().unwrap().get("path").unwrap();
    let batch_points = batch_path.get("points").unwrap().as_arr().unwrap();
    assert_eq!(batch_points.len(), standalone_path.points.len());
    for (sp, bp) in standalone_path.points.iter().zip(batch_points) {
        close(sp.f, num(bp, "f"), "path point");
        close(sp.lam_l, num(bp, "lambda_l"), "path grid");
    }
}

/// Shutdown stops admission but drains queued work; the engine joins
/// cleanly and later submissions get a structured `shutdown` error.
#[test]
fn shutdown_drains_and_rejects_new_work() {
    let srv = engine(1, None);
    let (tx, rx) = std::sync::mpsc::channel();
    srv.submit(
        req(r#"{"op":"load","id":1,"name":"d","workload":"chain","p":8,"q":8,"n":40,"seed":2}"#),
        &tx,
    );
    srv.submit(
        req(r#"{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.5}"#),
        &tx,
    );
    let down = srv.request(req(r#"{"op":"shutdown","id":3}"#));
    assert!(down.is_ok());
    let late = srv.request(req(r#"{"op":"stat","id":4}"#));
    assert_eq!(late.err_kind(), Some(ErrKind::Shutdown));
    drop(tx);
    let mut ids: Vec<u64> = rx
        .into_iter()
        .filter_map(|line| match line {
            ServerLine::Done(resp) => Some(resp.id),
            ServerLine::Progress(_) => None,
        })
        .collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2], "queued jobs drain through shutdown");
    srv.join();
}

/// Acceptance (serve): `append` buffers rows against the window, `refit`
/// folds them in with incremental statistic corrections and a warm start,
/// and the result matches a cold fit on the identical slid window at 1e-6
/// in no more iterations.
#[test]
fn append_then_refit_slides_window_warm_with_incremental_stats() {
    let srv = engine(1, None);
    let load = srv.request(req(
        r#"{"op":"load","id":1,"name":"d","workload":"chain","p":10,"q":10,"n":60,"seed":9}"#,
    ));
    assert!(load.is_ok(), "{:?}", load.outcome);
    let fit = srv.request(req(
        r#"{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.4,"tol":0.00001,"max_iter":120}"#,
    ));
    assert!(fit.is_ok(), "{:?}", fit.outcome);

    // Buffer 4 inline samples; the window itself is untouched until refit.
    let row: Vec<String> = (0..4)
        .map(|j| {
            let xs: Vec<String> = (0..10).map(|i| format!("{}", 0.1 * (i + j) as f64)).collect();
            let ys: Vec<String> = (0..10).map(|i| format!("{}", 0.05 * (i + 2 * j) as f64)).collect();
            format!(r#"{{"x":[{}],"y":[{}]}}"#, xs.join(","), ys.join(","))
        })
        .collect();
    let append = srv.request(req(&format!(
        r#"{{"op":"append","id":3,"dataset":"d","rows":[{}]}}"#,
        row.join(",")
    )));
    assert!(append.is_ok(), "{:?}", append.outcome);
    let ares = append.result().unwrap();
    assert_eq!(num(ares, "accepted"), 4.0);
    assert_eq!(num(ares, "pending"), 4.0);
    assert_eq!(num(ares, "n"), 60.0, "append buffers; it does not slide the window");

    // Refit with a fixed 60-sample window: 4 in, the 4 oldest out.
    let refit = srv.request(req(
        r#"{"op":"refit","id":4,"dataset":"d","solver":"alt","lambda":0.4,"tol":0.00001,"max_iter":120,"window":60}"#,
    ));
    assert!(refit.is_ok(), "{:?}", refit.outcome);
    let rres = refit.result().unwrap();
    assert!(flag(rres, "registry_hit"));
    assert!(flag(rres, "warm_started"), "refit seeds from the cached model");
    assert!(flag(rres, "warm_model_reused"));
    assert_eq!(num(rres, "appended"), 4.0);
    assert_eq!(num(rres, "evicted"), 4.0);
    assert_eq!(num(rres, "n"), 60.0, "window occupancy is capped");
    assert_eq!(
        num(rres, "stat_computes"),
        0.0,
        "refit corrects statistics in place instead of rebuilding"
    );
    assert!(num(rres, "stat_updates") >= 3.0, "all materialized blocks corrected");
    assert!(flag(rres.get("trace").unwrap(), "warm_started"));

    // Cold reference on the now-slid window: same optimum, no fewer iters.
    let cold = srv.request(req(
        r#"{"op":"fit","id":5,"dataset":"d","solver":"alt","lambda":0.4,"tol":0.00001,"max_iter":120,"warm":false}"#,
    ));
    assert!(cold.is_ok(), "{:?}", cold.outcome);
    let cres = cold.result().unwrap();
    assert!(!flag(cres, "warm_started"));
    let (fw, fc) = (
        num(rres.get("summary").unwrap(), "f"),
        num(cres.get("summary").unwrap(), "f"),
    );
    assert!(
        (fw - fc).abs() <= 1e-6 * fc.abs().max(1.0),
        "refit-after-append diverged from the cold fit: {fw} vs {fc}"
    );
    let (iw, ic) = (
        num(rres.get("summary").unwrap(), "iters"),
        num(cres.get("summary").unwrap(), "iters"),
    );
    assert!(iw <= ic, "warm refit took more iterations ({iw}) than cold ({ic})");

    // Observability: window counters surface in `stat`.
    let stat = srv.request(req(r#"{"op":"stat","id":6}"#));
    let sres = stat.result().unwrap();
    let ds = &sres.get("registry").unwrap().get("datasets").unwrap().as_arr().unwrap()[0];
    assert_eq!(num(ds, "n"), 60.0);
    assert_eq!(num(ds, "appended"), 4.0);
    assert_eq!(num(ds, "evicted"), 4.0);
    assert_eq!(num(ds, "pending"), 0.0, "refit drained the buffer");
    assert!(num(ds, "stat_updates") >= 3.0);
    assert!(num(ds, "stat_bytes") > 0.0);
    srv.join();
}
