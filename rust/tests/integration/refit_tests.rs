//! Streaming re-fit acceptance: after the sample window slides, a warm
//! refit on incrementally corrected statistics reaches the cold-fit
//! optimum on the same window.
//!
//! 1. **Dense equivalence** — carry the context across an append+evict
//!    slide, rank-k-correct the Gram blocks, re-solve seeded from the old
//!    model: 1e-6 objective agreement with a from-scratch cold fit, zero
//!    statistic recomputation, and no more iterations than the cold fit;
//! 2. **Tiled equivalence** — the same property with `StatMode::Tiled`
//!    resident tiles corrected in place;
//! 3. **Drift guard** — with `stat_rebuild_every` set, enough downdates
//!    force a full statistics rebuild, and the solve stays correct through
//!    the guard path.
//!
//! The 1e-10 statistics-exactness property tests live next to the code
//! they pin (`solvers::context` for dense, `cggm::tiles` for tiles); this
//! module is the end-to-end objective-level acceptance.

use cggm::cggm::{Dataset, SampleBlock, WindowDelta};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::linalg::dense::Mat;
use cggm::solvers::{solve_in_context, SolveOptions, SolverContext, SolverKind, StatMode};
use cggm::util::rng::Rng;

fn refit_opts(lam: f64) -> SolveOptions {
    SolveOptions {
        lam_l: lam,
        lam_t: lam,
        max_iter: 120,
        tol: 0.00001,
        ..Default::default()
    }
}

/// Slide the window: append `ka` random samples, evict the `kr` oldest,
/// returning the delta the incremental correction needs.
fn slide(data: &mut Dataset, rng: &mut Rng, ka: usize, kr: usize) -> WindowDelta {
    let (p, q) = (data.p(), data.q());
    let mut delta = WindowDelta::new(data.n());
    if ka > 0 {
        let xa = Mat::from_fn(p, ka, |_, _| rng.normal());
        let ya = Mat::from_fn(q, ka, |_, _| rng.normal());
        data.append_samples(&xa, &ya).unwrap();
        delta.record_append(SampleBlock::new(xa, ya));
    }
    if kr > 0 {
        delta.record_evict(data.evict_oldest(kr).unwrap());
    }
    delta
}

fn assert_close(warm: f64, cold: f64, what: &str) {
    assert!(
        (warm - cold).abs() <= 1e-6 * cold.abs().max(1.0),
        "{what}: warm refit {warm} vs cold fit {cold}"
    );
}

/// Acceptance (dense): refit-after-append matches a cold fit on the same
/// window at 1e-6, with zero from-scratch statistic work and no more
/// iterations than the cold start needed.
#[test]
fn warm_refit_after_window_slide_matches_cold_fit_dense() {
    let prob = datagen::chain::generate(14, 14, 90, 31);
    let eng = NativeGemm::new(1);
    let opts = refit_opts(0.3);
    let mut data = prob.data.clone();
    let ctx = SolverContext::new(&data, &opts, &eng);
    let first = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
    assert!(first.trace.converged);
    let computes = ctx.stat_computes();
    assert!(computes > 0, "the first fit materialized statistics");
    let carry = ctx.into_carry();

    // Fixed-size window: 6 new samples in, the 6 oldest out.
    let mut rng = Rng::new(77);
    let delta = slide(&mut data, &mut rng, 6, 6);
    let mut ctx = SolverContext::with_carry(&data, &opts, &eng, carry);
    ctx.update_stats(&delta).unwrap();
    assert_eq!(
        ctx.stat_computes(),
        computes,
        "incremental correction must not rebuild statistics from scratch"
    );
    assert!(ctx.stat_updates() > 0, "dense blocks corrected in place");

    let warm =
        solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, Some(&first.model)).unwrap();
    assert!(warm.trace.warm_started, "refit is seeded from the old model");
    assert!(
        warm.trace.stat_updates > 0,
        "the trace shows the solve ran on incrementally maintained statistics"
    );
    assert_eq!(ctx.stat_computes(), computes, "the warm solve recomputed nothing");

    // Cold reference on the identical slid window.
    let fresh = SolverContext::new(&data, &opts, &eng);
    let cold = solve_in_context(SolverKind::AltNewtonCd, &fresh, &opts, None).unwrap();
    assert!(!cold.trace.warm_started);
    assert_eq!(cold.trace.stat_updates, 0);
    assert_close(
        warm.trace.final_f().unwrap(),
        cold.trace.final_f().unwrap(),
        "dense",
    );
    assert!(
        warm.trace.records.len() <= cold.trace.records.len(),
        "warm refit took more iterations than the cold fit ({} vs {})",
        warm.trace.records.len(),
        cold.trace.records.len()
    );
}

/// Acceptance (tiled): the same equivalence with the block solver's
/// resident tiles corrected in place across the slide.
#[test]
fn warm_refit_after_window_slide_matches_cold_fit_tiled() {
    let prob = datagen::chain::generate(24, 10, 100, 37);
    let eng = NativeGemm::new(1);
    let mut opts = refit_opts(0.2);
    opts.stat_mode = StatMode::Tiled(7); // deliberately awkward: 7 ∤ 24
    let mut data = prob.data.clone();
    let ctx = SolverContext::new(&data, &opts, &eng);
    let first = solve_in_context(SolverKind::AltNewtonBcd, &ctx, &opts, None).unwrap();
    assert!(first.trace.converged);
    assert!(first.trace.tiles_computed > 0, "the solve ran through the tile store");
    let carry = ctx.into_carry();

    let mut rng = Rng::new(78);
    let delta = slide(&mut data, &mut rng, 5, 5);
    let mut ctx = SolverContext::with_carry(&data, &opts, &eng, carry);
    ctx.update_stats(&delta).unwrap();
    assert!(ctx.stat_updates() > 0, "resident tiles corrected in place");

    let warm =
        solve_in_context(SolverKind::AltNewtonBcd, &ctx, &opts, Some(&first.model)).unwrap();
    assert!(warm.trace.warm_started);

    let fresh = SolverContext::new(&data, &opts, &eng);
    let cold = solve_in_context(SolverKind::AltNewtonBcd, &fresh, &opts, None).unwrap();
    assert_close(
        warm.trace.final_f().unwrap(),
        cold.trace.final_f().unwrap(),
        "tiled",
    );
}

/// The downdate drift guard end to end: with `stat_rebuild_every: 2`, the
/// second evicting update invalidates the carried statistics (forcing a
/// from-scratch rebuild at next use), the counter resets, and the solve on
/// either side of the guard still matches a cold fit.
#[test]
fn downdate_drift_guard_forces_rebuild_and_stays_correct() {
    let prob = datagen::chain::generate(10, 10, 60, 41);
    let eng = NativeGemm::new(1);
    let mut opts = refit_opts(0.4);
    opts.stat_rebuild_every = 2;
    let mut data = prob.data.clone();
    let mut rng = Rng::new(5);
    let ctx = SolverContext::new(&data, &opts, &eng);
    ctx.syy().unwrap();
    ctx.sxx().unwrap();
    ctx.sxy().unwrap();
    let mut carry = ctx.into_carry();
    for round in 1..=2usize {
        let delta = slide(&mut data, &mut rng, 3, 3);
        let mut ctx = SolverContext::with_carry(&data, &opts, &eng, carry);
        ctx.update_stats(&delta).unwrap();
        if round < 2 {
            assert_eq!(ctx.downdates(), round, "downdates accumulate under the guard");
            assert!(ctx.cached_stat_bytes() > 0, "stats still cached before the trip");
        } else {
            assert_eq!(ctx.downdates(), 0, "the guard tripped and reset its counter");
            assert_eq!(
                ctx.cached_stat_bytes(),
                0,
                "tripping the guard drops the drifted statistics"
            );
        }
        let res = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
        let fresh = SolverContext::new(&data, &opts, &eng);
        let cold = solve_in_context(SolverKind::AltNewtonCd, &fresh, &opts, None).unwrap();
        assert_close(
            res.trace.final_f().unwrap(),
            cold.trace.final_f().unwrap(),
            &format!("guard round {round}"),
        );
        carry = ctx.into_carry();
    }
}
