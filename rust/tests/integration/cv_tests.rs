//! Cross-validated λ selection, end to end.

use super::common::{chain_cv, CV_SEED};
use cggm::cggm::objective::heldout_nll;
use cggm::cggm::Dataset;
use cggm::coordinator::{cross_validate, CvOptions, PathOptions};
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve, SolveOptions, SolverKind};

fn train_eval_split() -> (Dataset, Dataset) {
    let prob = chain_cv(); // p=q=15, n=360, seed CV_SEED
    let train: Vec<usize> = (0..240).collect();
    let eval: Vec<usize> = (240..360).collect();
    (
        prob.data.select_samples(&train),
        prob.data.select_samples(&eval),
    )
}

/// Acceptance: `cross_validate` selects a λ on a synthetic chain problem
/// and the full-data refit beats (or ties, within solver tolerance) every
/// single-λ fit on held-out NLL — the winner generalizes at least as well
/// as any other grid candidate, measured on data neither CV nor the refit
/// ever saw.
#[test]
fn cv_refit_beats_every_single_lambda_fit_on_heldout_nll() {
    let (train, eval) = train_eval_split();
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 80,
        ..Default::default()
    };
    let popts = PathOptions {
        points: 6,
        min_ratio: 0.05,
        ..Default::default()
    };
    let cvo = CvOptions {
        folds: 5,
        seed: CV_SEED,
        fold_threads: 2,
        refit: true,
        ..Default::default()
    };
    let res = cross_validate(SolverKind::AltNewtonCd, &train, &base, &popts, &cvo, &eng).unwrap();
    assert_eq!(res.points.len(), 6);
    assert_eq!(res.folds, 5);
    assert!(res.points.iter().all(|p| p.mean_nll.is_finite()));
    // The CV curve must actually discriminate: the winner is strictly
    // better than the worst candidate (a flat curve would make selection
    // meaningless).
    let worst = res
        .points
        .iter()
        .map(|p| p.mean_nll)
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(
        res.points[res.best].mean_nll < worst,
        "CV curve is flat: {:?}",
        res.points.iter().map(|p| p.mean_nll).collect::<Vec<_>>()
    );
    // Score the refit and every single-λ fit on the held-back eval split.
    let refit_model = res.model().expect("refit model");
    let refit_nll = heldout_nll(refit_model, &eval, &eng).unwrap();
    let mut single_nlls = Vec::new();
    for pt in &res.points {
        let opts = SolveOptions {
            lam_l: pt.lam_l,
            lam_t: pt.lam_t,
            ..base.clone()
        };
        let fit = solve(SolverKind::AltNewtonCd, &train, &opts, &eng).unwrap();
        single_nlls.push(heldout_nll(&fit.model, &eval, &eng).unwrap());
    }
    // The refit must beat every candidate up to a small statistical margin:
    // the CV ranking (training folds) and the eval ranking (independent
    // split) are different random quantities, and near the NLL minimum
    // adjacent λs are near-ties — exactly where a rank swap is harmless.
    // Away from the minimum the curve is steep, so 5% catches a genuinely
    // wrong selection.
    for (pt, &single_nll) in res.points.iter().zip(&single_nlls) {
        assert!(
            refit_nll <= single_nll + 0.05 * single_nll.abs().max(1.0),
            "refit (λ=({:.4},{:.4}), eval NLL {refit_nll:.6}) lost to the \
             single-λ fit at λ=({:.4},{:.4}) (eval NLL {single_nll:.6})",
            res.best_lambda.0,
            res.best_lambda.1,
            pt.lam_l,
            pt.lam_t,
        );
    }
    // And strictly beat the most-regularized candidate (λ_max fits an
    // essentially empty model — a robust, large-margin comparison).
    assert!(
        refit_nll < single_nlls[0],
        "refit ({refit_nll:.6}) should clearly beat the λ_max fit \
         ({:.6})",
        single_nlls[0]
    );
}

/// The fold paths reuse one context per fold: statistics computed once per
/// fold regardless of grid length, and a missing-time fold still reports
/// cleanly (NaN → +inf mean) instead of poisoning the aggregation.
#[test]
fn cv_time_budget_degrades_gracefully() {
    let (train, _) = train_eval_split();
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 80,
        time_limit: 0.02, // seconds per fold path — too little for 8 points
        ..Default::default()
    };
    let popts = PathOptions {
        points: 8,
        min_ratio: 0.05,
        ..Default::default()
    };
    let cvo = CvOptions {
        folds: 3,
        refit: false,
        ..Default::default()
    };
    let res = cross_validate(SolverKind::AltNewtonCd, &train, &base, &popts, &cvo, &eng).unwrap();
    assert_eq!(res.points.len(), 8);
    // Whatever was scored is finite-or-infinite, never NaN in the mean; the
    // best index always points at a real point.
    assert!(res.points.iter().all(|p| !p.mean_nll.is_nan()));
    assert!(res.best < res.points.len());
    assert!(res.refit.is_none());
}
