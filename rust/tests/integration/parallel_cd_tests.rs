//! Colored (conflict-free parallel) CD sweeps: the `cd_threads` gate.
//!
//! Guarantees pinned here (ISSUE-4 acceptance):
//! - the colored sweep with `cd_threads ∈ {2, 4}` reaches the same final
//!   objective as the serial sweep to 1e-6 (relative) on chain and cluster
//!   problems, for all three CD solvers;
//! - the colored sweep is **bitwise deterministic** in the thread count
//!   (2 threads and 4 threads produce the identical objective trajectory);
//! - coloring validity (no two same-color coordinates share an index) is a
//!   unit property in `graph::coloring`; here we additionally check the
//!   solver-facing cache on a live active set.

use super::common::{chain_medium, chain_opts};
use cggm::datagen::{self, Workload};
use cggm::gemm::native::NativeGemm;
use cggm::graph::coloring::{color_classes, validate_classes, ConflictSpace};
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::util::membudget::MemBudget;

fn with_cd_threads(base: &SolveOptions, t: usize) -> SolveOptions {
    SolveOptions {
        cd_threads: t,
        ..base.clone()
    }
}

/// Colored and serial sweeps take genuinely different iterate paths
/// (within-class Jacobi vs pure Gauss–Seidel), so the 1e-6 objective
/// agreement is pinned at a tight stopping tolerance where the shared
/// optimum dominates the comparison (same device as the clustering
/// persistence tests).
fn tight(lam: f64) -> SolveOptions {
    SolveOptions {
        tol: 1e-5,
        max_iter: 300,
        ..chain_opts(lam)
    }
}

/// Final objectives for serial vs colored runs; colored runs must agree
/// with serial to 1e-6 and with each other bitwise.
fn check_solver(kind: SolverKind, prob: &datagen::Problem, base: &SolveOptions) {
    let eng = NativeGemm::new(1);
    let serial = solve(kind, &prob.data, base, &eng).unwrap();
    let f_serial = serial.trace.final_f().unwrap();
    assert!(serial.trace.converged, "{}: serial did not converge", kind.name());
    let mut colored_fs = Vec::new();
    for t in [2usize, 4] {
        let res = solve(kind, &prob.data, &with_cd_threads(base, t), &eng).unwrap();
        assert!(
            res.trace.converged,
            "{}: colored cd_threads={t} did not converge",
            kind.name()
        );
        let f = res.trace.final_f().unwrap();
        assert!(
            (f - f_serial).abs() <= 1e-6 * f_serial.abs().max(1.0),
            "{} cd_threads={t}: colored {f} vs serial {f_serial}",
            kind.name()
        );
        colored_fs.push(
            res.trace
                .records
                .iter()
                .map(|r| r.f)
                .collect::<Vec<f64>>(),
        );
    }
    assert_eq!(
        colored_fs[0], colored_fs[1],
        "{}: colored sweep must be bitwise-deterministic across thread counts",
        kind.name()
    );
}

#[test]
fn alt_newton_cd_colored_matches_serial_on_chain() {
    check_solver(SolverKind::AltNewtonCd, &chain_medium(), &tight(0.15));
}

#[test]
fn alt_newton_cd_colored_matches_serial_on_cluster() {
    let prob = datagen::generate(Workload::Cluster, 18, 18, 120, 13);
    check_solver(SolverKind::AltNewtonCd, &prob, &tight(0.2));
}

#[test]
fn newton_cd_colored_matches_serial_on_chain() {
    check_solver(SolverKind::NewtonCd, &chain_medium(), &tight(0.2));
}

#[test]
fn block_solver_colored_matches_serial_on_chain() {
    let prob = datagen::chain::generate(14, 14, 80, 5);
    let base = SolveOptions {
        lam_l: 0.15,
        lam_t: 0.15,
        chol: cggm::cggm::CholKind::SparseRcm,
        ..tight(0.15)
    };
    check_solver(SolverKind::AltNewtonBcd, &prob, &base);
}

#[test]
fn prox_grad_parallel_prox_step_matches_serial_bitwise() {
    // The prox step is elementwise, so cd_threads must not change a bit.
    let prob = datagen::chain::generate(10, 10, 70, 9);
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        lam_l: 0.25,
        lam_t: 0.25,
        max_iter: 150,
        ..Default::default()
    };
    let a = solve(SolverKind::ProxGrad, &prob.data, &base, &eng).unwrap();
    let b = solve(
        SolverKind::ProxGrad,
        &prob.data,
        &with_cd_threads(&base, 4),
        &eng,
    )
    .unwrap();
    let fa: Vec<f64> = a.trace.records.iter().map(|r| r.f).collect();
    let fb: Vec<f64> = b.trace.records.iter().map(|r| r.f).collect();
    assert_eq!(fa, fb, "prox trajectory must be thread-count invariant");
}

/// The context-cached coloring on a live solve stays valid and is reused
/// across iterations rather than rebuilt every sweep.
#[test]
fn coloring_cache_reuses_across_iterations() {
    use cggm::solvers::{solve_in_context, SolverContext};
    let prob = chain_medium();
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        cd_threads: 2,
        ..chain_opts(0.15)
    };
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    let res = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
    assert!(res.trace.converged, "fixture must converge for exact counts");
    let iters = res.trace.records.len();
    assert!(iters >= 3, "need several iterations to exercise reuse");
    let colorings = ctx.coloring_caches();
    let (lr, le, lh) = (
        colorings.lambda.rebuilds,
        colorings.lambda.extensions,
        colorings.lambda.hits,
    );
    assert!(lr >= 1, "λ coloring never built");
    // The CD phase runs every iteration except the final converged-break
    // one, and consults the cache exactly once per phase — so rebuilds,
    // extensions, and hits partition those calls. (Which bucket each call
    // lands in depends on active-set churn; the *sum* is exact.)
    assert_eq!(
        lr + le + lh,
        iters - 1,
        "one cache consultation per CD phase"
    );
}

/// Coloring validity on a realistic active set (solver-facing shape): every
/// class is index-disjoint and the classes cover the set exactly.
#[test]
fn live_active_set_coloring_is_valid() {
    let prob = chain_medium();
    let q = prob.data.q();
    // Active set shaped like a screen result: support + near-threshold.
    let mut pairs = Vec::new();
    for i in 0..q {
        pairs.push((i, i));
        if i + 1 < q {
            pairs.push((i, i + 1));
        }
        if i + 3 < q {
            pairs.push((i, i + 3));
        }
    }
    let space = ConflictSpace::Symmetric(q);
    let classes = color_classes(&pairs, space);
    validate_classes(&pairs, &classes, space).unwrap();
    // Chain-ish sets color into few classes (greedy ≤ 2Δ−1; Δ here ≈ 5).
    assert!(
        classes.len() <= 10,
        "unexpectedly many classes: {}",
        classes.len()
    );
}

/// A colored solve under a tight-but-sufficient budget registers the
/// coloring buffers (they come out of the same MemBudget as everything
/// else) and releases them with the context.
#[test]
fn coloring_buffers_are_budget_tracked() {
    use cggm::solvers::SolverContext;
    let prob = datagen::chain::generate(10, 10, 60, 3);
    let eng = NativeGemm::new(1);
    let budget = MemBudget::unlimited();
    let opts = SolveOptions {
        cd_threads: 2,
        budget: budget.clone(),
        ..chain_opts(0.2)
    };
    let live_before;
    {
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let res =
            cggm::solvers::solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
        assert!(res.trace.converged);
        live_before = budget.live();
        // Cached statistics + the two colorings are the only live bytes.
        let stats = 8 * (10 * 10 * 3); // syy + sxx + sxy at p=q=10
        assert!(
            live_before >= stats,
            "expected stats + coloring live, got {live_before}"
        );
    }
    assert_eq!(budget.live(), 0, "context drop releases coloring buffers");
}
