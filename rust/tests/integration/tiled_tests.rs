//! Tiled on-demand Gram statistics (`StatMode::Tiled`) acceptance suite:
//!
//! 1. **Equivalence** — a tiled block solve reaches the dense-mode objective
//!    to 1e-6 on both chain and cluster workloads (tiling changes where
//!    statistics come from, not what they are);
//! 2. **Memory** — a tiled solve completes under a `MemBudget` strictly
//!    smaller than the dense `S_xx` footprint, with `peak() ≤ cap` and the
//!    LRU actually evicting/spilling under pressure;
//! 3. **Laziness** — a screened run computes strictly fewer tiles than an
//!    unscreened run on the same problem (only touched blocks are built),
//!    and no run ever computes more than `total_tiles`.

use cggm::cggm::active::ScreenSet;
use cggm::datagen::{self, cluster_graph::ClusterOptions};
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve, solve_in_context, SolveOptions, SolverContext, SolverKind, StatMode};
use cggm::util::membudget::MemBudget;
use std::sync::Arc;

fn bcd_opts(lam: f64) -> SolveOptions {
    SolveOptions {
        lam_l: lam,
        lam_t: lam,
        max_iter: 120,
        ..Default::default()
    }
}

/// Tiled-vs-dense 1e-6 objective equivalence on the paper's two synthetic
/// workloads, with a tile size that divides p and one that does not (ragged
/// edge tiles).
#[test]
fn tiled_bcd_matches_dense_on_chain_and_cluster() {
    let cluster_opts = ClusterOptions {
        cluster_size: 6,
        hub_coeff: 3.0,
        ..Default::default()
    };
    let problems = [
        ("chain", datagen::chain::generate(24, 24, 100, 71)),
        (
            "cluster",
            datagen::cluster_graph::generate(40, 12, 120, 73, &cluster_opts),
        ),
    ];
    let eng = NativeGemm::new(1);
    for (name, prob) in &problems {
        let dense_opts = bcd_opts(0.2);
        let dense = solve(SolverKind::AltNewtonBcd, &prob.data, &dense_opts, &eng).unwrap();
        assert!(dense.trace.converged, "{name}: dense run must converge");
        let f_dense = dense.trace.final_f().unwrap();
        assert_eq!(dense.trace.total_tiles, 0, "{name}: dense mode has no tiles");
        // 16 divides neither p; 7 is deliberately awkward.
        for tile in [7usize, 16] {
            let mut topts = bcd_opts(0.2);
            topts.stat_mode = StatMode::Tiled(tile);
            let tiled = solve(SolverKind::AltNewtonBcd, &prob.data, &topts, &eng).unwrap();
            assert!(tiled.trace.converged, "{name}/t={tile}: tiled run converges");
            let f_tiled = tiled.trace.final_f().unwrap();
            assert!(
                (f_tiled - f_dense).abs() <= 1e-6 * f_dense.abs().max(1.0),
                "{name}/t={tile}: tiled {f_tiled} vs dense {f_dense}"
            );
            assert_eq!(tiled.model.lambda_nnz(), dense.model.lambda_nnz());
            assert_eq!(tiled.model.theta_nnz(), dense.model.theta_nnz());
            assert!(
                tiled.trace.tiles_computed > 0,
                "{name}/t={tile}: sweeps must read through the tile store"
            );
            assert!(
                tiled.trace.tiles_computed <= tiled.trace.total_tiles,
                "{name}/t={tile}: computed {} of {} tiles",
                tiled.trace.tiles_computed,
                tiled.trace.total_tiles
            );
        }
    }
}

/// Acceptance: a tiled block solve completes under a budget strictly smaller
/// than the dense `S_xx` footprint (8·p² bytes), the measured peak stays
/// under the cap, the LRU evicts and spills under pressure, and the answer
/// still matches an unconstrained dense-mode run to 1e-6.
#[test]
fn budget_capped_tiled_solve_stays_under_dense_sxx_footprint() {
    // Hub Θ* spread across all of p (hub_coeff·√p ≥ p) so the sweeps touch
    // every tile block-row, not just the first.
    let cluster_opts = ClusterOptions {
        cluster_size: 4,
        hub_coeff: 100.0,
        ..Default::default()
    };
    let (p, q, n) = (48usize, 8usize, 100usize);
    let prob = datagen::cluster_graph::generate(p, q, n, 79, &cluster_opts);
    let eng = NativeGemm::new(1);
    // Reference: dense statistics, unlimited memory.
    let dense_opts = bcd_opts(0.1);
    let dense = solve(SolverKind::AltNewtonBcd, &prob.data, &dense_opts, &eng).unwrap();
    assert!(dense.trace.converged);
    let f_dense = dense.trace.final_f().unwrap();
    // Tiled run under a cap strictly below dense S_xx (8·48² = 18432 B).
    let dense_sxx_bytes = 8 * p * p;
    let cap = 12 * 1024;
    assert!(cap < dense_sxx_bytes, "cap must undercut the dense footprint");
    let budget = MemBudget::new(cap);
    let mut topts = bcd_opts(0.1);
    topts.stat_mode = StatMode::Tiled(16);
    topts.budget = budget.clone();
    let tiled = solve(SolverKind::AltNewtonBcd, &prob.data, &topts, &eng)
        .expect("tiled solve must fit under the cap");
    assert!(tiled.trace.converged);
    let f_tiled = tiled.trace.final_f().unwrap();
    assert!(
        (f_tiled - f_dense).abs() <= 1e-6 * f_dense.abs().max(1.0),
        "budget-capped tiled {f_tiled} vs dense {f_dense}"
    );
    assert!(
        budget.peak() <= cap,
        "peak {} exceeded the cap {cap}",
        budget.peak()
    );
    // All 6 S_xx + 3 S_xy tiles total ~15 KiB — they cannot all be resident
    // at once, so the LRU must have evicted, and first-time evictions write
    // the spill file.
    assert!(
        tiled.trace.tile_evictions > 0,
        "budget pressure must force evictions (computed {} tiles)",
        tiled.trace.tiles_computed
    );
    assert!(
        tiled.trace.tile_spills > 0,
        "first-time evictions must spill to disk"
    );
    assert!(tiled.trace.tiles_computed <= tiled.trace.total_tiles);
}

/// Acceptance: restricting the solve to a screen set makes it compute
/// *strictly fewer* tiles than the unrestricted run — untouched blocks are
/// never built. The screen keeps Θ rows in the first tile block-row only
/// (plus the full Λ universe), so S_xx reads stay inside block (0,0).
#[test]
fn screened_tiled_solve_computes_strictly_fewer_tiles() {
    let (p, q) = (24usize, 24usize);
    let prob = datagen::chain::generate(p, q, 100, 83);
    let eng = NativeGemm::new(1);
    // tile = 8 → 3 block-rows each way: 6 S_xx tiles + 9 S_xy tiles = 15.
    let mut opts = bcd_opts(0.15);
    opts.stat_mode = StatMode::Tiled(8);
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    let unscreened = solve_in_context(SolverKind::AltNewtonBcd, &ctx, &opts, None).unwrap();
    assert!(unscreened.trace.converged);
    // Chain Θ* is diagonal over all 24 rows, so the unrestricted active set
    // spans every block-row.
    assert!(
        unscreened.trace.tiles_computed > 2,
        "fixture must touch more than the first block-row (got {})",
        unscreened.trace.tiles_computed
    );
    let mut ropts = opts.clone();
    ropts.screen = Some(Arc::new(ScreenSet {
        lambda: (0..q).flat_map(|i| (i..q).map(move |j| (i, j))).collect(),
        theta: (0..8).flat_map(|i| (0..q).map(move |j| (i, j))).collect(),
    }));
    let ctx2 = SolverContext::new(&prob.data, &ropts, &eng);
    let screened = solve_in_context(SolverKind::AltNewtonBcd, &ctx2, &ropts, None).unwrap();
    assert!(screened.trace.converged);
    assert!(
        screened.trace.tiles_computed > 0,
        "the restricted sweep still reads through the store"
    );
    assert!(
        screened.trace.tiles_computed < unscreened.trace.tiles_computed,
        "screened run must build fewer tiles: {} vs {}",
        screened.trace.tiles_computed,
        unscreened.trace.tiles_computed
    );
    assert!(
        screened.trace.tiles_computed < screened.trace.total_tiles,
        "laziness proof: {} of {} tiles built",
        screened.trace.tiles_computed,
        screened.trace.total_tiles
    );
}
