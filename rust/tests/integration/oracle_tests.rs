//! Cross-language oracle: the Rust objective/gradients vs the AOT-compiled
//! L2 JAX objective executed through PJRT. Both tests skip (with a note)
//! when the artifacts are not built — `make artifacts` enables them.

use cggm::cggm::{CggmModel, CholKind, Dataset, Objective};
use cggm::gemm::native::NativeGemm;
use cggm::linalg::dense::Mat;
use cggm::runtime::{artifact_dir, compile_artifact, manifest::Manifest};
use cggm::util::rng::Rng;

/// Cross-language oracle: the Rust objective must match the AOT-lowered L2
/// JAX objective executed through PJRT, on random dense inputs at the
/// artifact's fixed shape.
#[test]
fn rust_objective_matches_jax_artifact() {
    let dir = artifact_dir();
    let manifest_path = dir.join("manifest.json");
    if !manifest_path.exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&manifest_path).unwrap();
    let entry = manifest.find("cggm_obj", None, None).expect("oracle artifact");
    let q = 16usize;
    let p = 24usize;
    assert_eq!(entry.inputs[0], vec![q, q]);

    let client = xla::PjRtClient::cpu().unwrap();
    let exe = compile_artifact(&client, &dir, entry).unwrap();

    let mut rng = Rng::new(44);
    // Random SPD Λ, sparse-ish Θ, covariance matrices from a random dataset.
    let n = 32;
    let data = Dataset::new(
        Mat::from_fn(p, n, |_, _| rng.normal()),
        Mat::from_fn(q, n, |_, _| rng.normal()),
    );
    let mut model = CggmModel::init(p, q);
    for i in 0..q {
        model.lambda.set(i, i, 3.0 + rng.uniform());
    }
    for _ in 0..q {
        let (i, j) = (rng.below(q), rng.below(q));
        if i != j {
            model.lambda.set_sym(i, j, 0.2 * rng.normal());
        }
    }
    for _ in 0..2 * p {
        model.theta.set(rng.below(p), rng.below(q), rng.normal() * 0.4);
    }
    let (lam_l, lam_t) = (0.37, 0.21);

    // Rust value.
    let eng = NativeGemm::new(1);
    let obj = Objective::new(&data, lam_l, lam_t).with_chol(CholKind::Dense);
    let f_rust = obj.value(&model, &eng).unwrap();

    // JAX artifact value.
    let lam_d = model.lambda.to_dense();
    let th_d = model.theta.to_dense();
    let syy = data.syy_dense(&eng);
    let sxy = data.sxy_dense(&eng);
    let sxx = data.sxx_dense(&eng);
    let lit = |m: &Mat, r: usize, c: usize| {
        xla::Literal::vec1(m.data())
            .reshape(&[r as i64, c as i64])
            .unwrap()
    };
    let args = vec![
        lit(&lam_d, q, q),
        lit(&th_d, p, q),
        lit(&syy, q, q),
        lit(&sxy, p, q),
        lit(&sxx, p, p),
        xla::Literal::scalar(lam_l),
        xla::Literal::scalar(lam_t),
    ];
    let result = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let f_jax: f64 = result
        .to_tuple1()
        .unwrap()
        .to_vec::<f64>()
        .unwrap()[0];

    let rel = (f_rust - f_jax).abs() / f_rust.abs().max(1.0);
    assert!(
        rel < 1e-9,
        "cross-language objective mismatch: rust={f_rust} jax={f_jax}"
    );
}

/// Same oracle for the analytic gradients (Eq. 3).
#[test]
fn rust_gradients_match_jax_artifact() {
    let dir = artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let entry = manifest.find("cggm_grads", None, None).expect("grads artifact");
    let (p, q) = (24usize, 16usize);
    let client = xla::PjRtClient::cpu().unwrap();
    let exe = compile_artifact(&client, &dir, entry).unwrap();

    let mut rng = Rng::new(45);
    let n = 40;
    let data = Dataset::new(
        Mat::from_fn(p, n, |_, _| rng.normal()),
        Mat::from_fn(q, n, |_, _| rng.normal()),
    );
    let mut model = CggmModel::init(p, q);
    for i in 0..q {
        model.lambda.set(i, i, 3.0);
    }
    model.lambda.set_sym(0, 5, 0.3);
    for _ in 0..p {
        model.theta.set(rng.below(p), rng.below(q), rng.normal() * 0.4);
    }
    let eng = NativeGemm::new(1);
    let obj = Objective::new(&data, 0.0, 0.0).with_chol(CholKind::Dense);
    let (_, _, factor, rt) = obj.eval(&model, &eng).unwrap();
    let sigma = factor.inverse_dense(&eng);
    let psi = obj.psi_dense(&sigma, &rt, &eng);
    let gl_rust = obj.grad_lambda_dense(&sigma, &psi, &eng);
    let gt_rust = obj.grad_theta_dense(&sigma, &rt, &eng);

    let lam_d = model.lambda.to_dense();
    let th_d = model.theta.to_dense();
    let syy = data.syy_dense(&eng);
    let sxy = data.sxy_dense(&eng);
    let sxx = data.sxx_dense(&eng);
    let lit = |m: &Mat, r: usize, c: usize| {
        xla::Literal::vec1(m.data())
            .reshape(&[r as i64, c as i64])
            .unwrap()
    };
    let args = vec![
        lit(&lam_d, q, q),
        lit(&th_d, p, q),
        lit(&syy, q, q),
        lit(&sxy, p, q),
        lit(&sxx, p, p),
    ];
    let mut result = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = result.decompose_tuple().unwrap();
    let gl_jax = parts[0].to_vec::<f64>().unwrap();
    let gt_jax = parts[1].to_vec::<f64>().unwrap();
    for (a, b) in gl_rust.data().iter().zip(&gl_jax) {
        assert!((a - b).abs() < 1e-9, "∇Λ mismatch: {a} vs {b}");
    }
    for (a, b) in gt_rust.data().iter().zip(&gt_jax) {
        assert!((a - b).abs() < 1e-9, "∇Θ mismatch: {a} vs {b}");
    }
}
