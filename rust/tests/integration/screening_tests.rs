//! Path-level strong-rule screening guarantees:
//!
//! 1. **Equivalence** — a screened path reaches the same objectives as a
//!    full-screen path (screening is an optimization, not an approximation);
//! 2. **Efficiency** — it examines at least 2× fewer coordinates doing so;
//! 3. **Safety** — the KKT post-check catches any coordinate the strong
//!    rule wrongly dropped and falls back to a full solve, so screening can
//!    never silently drop a violating coordinate.
//!
//! All three screen-honoring solvers (`alt_newton_cd`, `newton_cd`,
//! `prox_grad`) are covered: restricted-vs-full equivalence is pinned at
//! 1e-6 objective tolerance for each.

use cggm::cggm::active::{kkt_violations, ScreenRule, ScreenSet};
use cggm::coordinator::{fit_path, solve_screened, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve_in_context, SolveOptions, SolverContext, SolverKind};
use std::sync::Arc;

fn base_opts() -> SolveOptions {
    SolveOptions {
        max_iter: 100,
        ..Default::default()
    }
}

/// Acceptance: screened path ≥ 2× fewer coordinate updates than unscreened
/// at equal (1e-6) final objective.
#[test]
fn screened_path_matches_full_with_at_least_2x_fewer_coordinates() {
    let prob = datagen::chain::generate(40, 40, 120, 19);
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let mk = |screen| PathOptions {
        points: 8,
        min_ratio: 0.1,
        lambdas: None,
        warm_start: true,
        screen,
        ..Default::default()
    };
    let strong = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &mk(ScreenRule::Strong),
        &eng,
    )
    .unwrap();
    let full = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &mk(ScreenRule::Full),
        &eng,
    )
    .unwrap();
    assert_eq!(strong.points.len(), full.points.len());
    // Same grid, same objectives — point by point, to 1e-6 relative.
    for (s, f) in strong.points.iter().zip(&full.points) {
        assert_eq!(s.lam_l, f.lam_l);
        assert!(s.converged && f.converged);
        assert!(
            (s.f - f.f).abs() <= 1e-6 * f.f.abs().max(1.0),
            "objective diverged at λ={}: screened {} vs full {}",
            s.lam_l,
            s.f,
            f.f
        );
    }
    // Screening bookkeeping: the first point cannot be screened (no
    // previous solution), the rest must be.
    assert!(!strong.points[0].screened);
    assert!(strong.points[1..].iter().all(|p| p.screened));
    assert!(full.points.iter().all(|p| !p.screened));
    // The full path does no driver-side verification; the screened one
    // pays one gradient scan per point.
    assert_eq!(full.total_kkt_scans(), 0);
    assert!(strong.total_kkt_scans() > 0);
    // Efficiency: ≥ 2× fewer coordinate updates over the whole path (the
    // restricted screens examine |strong set| ≪ q²/2 + pq coordinates per
    // outer iteration; KKT verification is reported separately above).
    let (cs, cf) = (strong.total_coord_updates(), full.total_coord_updates());
    assert!(
        2 * cs <= cf,
        "screening saved too little: strong {cs} vs full {cf} coordinates"
    );
}

/// Safety: hand `solve_screened` a deliberately bad screen set (everything
/// but the diagonal dropped). The KKT post-check must detect the dropped
/// violating coordinates, fall back to a full solve, and land on the
/// unrestricted optimum — proving screening never silently drops a
/// violating coordinate.
#[test]
fn kkt_postcheck_recovers_from_a_bad_screen_set() {
    let prob = datagen::chain::generate(15, 15, 90, 23);
    let eng = NativeGemm::new(1);
    let mut opts = base_opts();
    opts.lam_l = 0.15;
    opts.lam_t = 0.15;
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    // Reference: unrestricted solve.
    let reference = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
    assert!(reference.trace.converged);
    let f_ref = reference.trace.final_f().unwrap();
    assert!(
        reference.model.theta_nnz() > 0,
        "fixture must have Θ support for the screen to drop"
    );
    // Adversarial screen: only the Λ diagonal is allowed, Θ entirely
    // dropped — the strong rule could never produce this, but the safety
    // net must not care where the set came from.
    let bad = Arc::new(ScreenSet {
        lambda: (0..15).map(|i| (i, i)).collect(),
        theta: Vec::new(),
    });
    let out = solve_screened(SolverKind::AltNewtonCd, &ctx, &opts, None, bad.clone()).unwrap();
    assert!(
        out.fell_back,
        "KKT post-check must flag the dropped coordinates"
    );
    let f_scr = out.res.trace.final_f().unwrap();
    // The fallback re-solve starts from a different iterate than the cold
    // reference, so the objectives agree to the stopping tolerance (the
    // exact-trajectory 1e-6 guarantee belongs to the screened-vs-full path
    // test, where the strong set covers every active coordinate).
    assert!(
        (f_scr - f_ref).abs() <= opts.tol * f_ref.abs().max(1.0),
        "fallback did not recover the optimum: {f_scr} vs {f_ref}"
    );
    // The returned gradients are the KKT evidence: at the recovered
    // solution no coordinate violates beyond the converged residual (every
    // off-support excess |g|−λ is bounded by the final subgradient norm, so
    // that norm over λ is the guaranteed slack).
    let final_subgrad = out.res.trace.records.last().unwrap().subgrad;
    let viol = kkt_violations(
        &out.grads.0,
        &out.grads.1,
        &out.res.model,
        opts.lam_l,
        opts.lam_t,
        &bad,
        final_subgrad / opts.lam_l.min(opts.lam_t) + 1e-9,
    );
    assert_eq!(viol, 0, "violations survived the fallback");
}

/// A *good* screen set (the full coordinate universe) must not fall back,
/// and must reproduce the unrestricted solve exactly — same iterate path,
/// same objective, same support.
#[test]
fn full_universe_screen_set_is_a_no_op() {
    let prob = datagen::chain::generate(12, 12, 70, 31);
    let eng = NativeGemm::new(1);
    let mut opts = base_opts();
    opts.lam_l = 0.2;
    opts.lam_t = 0.2;
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    let reference = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
    let (q, p) = (12usize, 12usize);
    let universe = Arc::new(ScreenSet {
        lambda: (0..q).flat_map(|i| (i..q).map(move |j| (i, j))).collect(),
        theta: (0..p).flat_map(|i| (0..q).map(move |j| (i, j))).collect(),
    });
    let out = solve_screened(SolverKind::AltNewtonCd, &ctx, &opts, None, universe).unwrap();
    assert!(!out.fell_back);
    assert_eq!(
        out.res.trace.records.len(),
        reference.trace.records.len(),
        "restricting to the full universe must not change the iterate path"
    );
    let (fa, fb) = (
        out.res.trace.final_f().unwrap(),
        reference.trace.final_f().unwrap(),
    );
    assert!((fa - fb).abs() <= 1e-9 * fb.abs().max(1.0));
    assert_eq!(out.res.model.lambda_nnz(), reference.model.lambda_nnz());
    assert_eq!(out.res.model.theta_nnz(), reference.model.theta_nnz());
}

/// `newton_cd` and `prox_grad` honor `SolveOptions::screen` now too: a
/// full-universe screen set must reproduce each solver's unrestricted run
/// exactly (same iterate path, same objective) with no KKT fallback — the
/// restriction machinery itself adds nothing.
#[test]
fn newton_and_prox_full_universe_screens_are_no_ops() {
    let prob = datagen::chain::generate(10, 10, 70, 47);
    let eng = NativeGemm::new(1);
    let (p, q) = (10usize, 10usize);
    let universe = Arc::new(ScreenSet {
        lambda: (0..q).flat_map(|i| (i..q).map(move |j| (i, j))).collect(),
        theta: (0..p).flat_map(|i| (0..q).map(move |j| (i, j))).collect(),
    });
    for kind in [SolverKind::NewtonCd, SolverKind::ProxGrad] {
        assert!(kind.supports_screen(), "{kind:?} must honor screens now");
        let mut opts = base_opts();
        opts.lam_l = 0.25;
        opts.lam_t = 0.25;
        if kind == SolverKind::ProxGrad {
            opts.max_iter = 800;
        }
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let reference = solve_in_context(kind, &ctx, &opts, None).unwrap();
        let out = solve_screened(kind, &ctx, &opts, None, universe.clone()).unwrap();
        assert!(!out.fell_back, "{kind:?}: universe set cannot fall back");
        assert_eq!(
            out.res.trace.records.len(),
            reference.trace.records.len(),
            "{kind:?}: full-universe restriction changed the iterate path"
        );
        let (fa, fb) = (
            out.res.trace.final_f().unwrap(),
            reference.trace.final_f().unwrap(),
        );
        assert!(
            (fa - fb).abs() <= 1e-9 * fb.abs().max(1.0),
            "{kind:?}: {fa} vs {fb}"
        );
        assert_eq!(out.res.model.lambda_nnz(), reference.model.lambda_nnz());
        assert_eq!(out.res.model.theta_nnz(), reference.model.theta_nnz());
    }
}

/// Satellite acceptance (`newton_cd`): a strong-rule screened path matches
/// the full-screen path point by point at 1e-6 — the strong set contains
/// every coordinate the per-iterate active rule would pick (its threshold
/// 2λ_k − λ_{k−1} < λ_k), so the restricted trajectory is the full one.
#[test]
fn newton_cd_screened_path_matches_full() {
    let prob = datagen::chain::generate(20, 20, 100, 53);
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let mk = |screen| PathOptions {
        points: 6,
        min_ratio: 0.15,
        screen,
        ..Default::default()
    };
    let strong = fit_path(
        SolverKind::NewtonCd,
        &prob.data,
        &base,
        &mk(ScreenRule::Strong),
        &eng,
    )
    .unwrap();
    let full = fit_path(
        SolverKind::NewtonCd,
        &prob.data,
        &base,
        &mk(ScreenRule::Full),
        &eng,
    )
    .unwrap();
    assert_eq!(strong.points.len(), full.points.len());
    for (s, f) in strong.points.iter().zip(&full.points) {
        assert_eq!(s.lam_l, f.lam_l);
        assert!(s.converged && f.converged);
        assert!(
            (s.f - f.f).abs() <= 1e-6 * f.f.abs().max(1.0),
            "newton_cd diverged at λ={}: screened {} vs full {}",
            s.lam_l,
            s.f,
            f.f
        );
    }
    assert!(strong.points[1..].iter().all(|p| p.screened));
    // The restriction must actually shrink the examined coordinate count.
    let (cs, cf) = (strong.total_coord_updates(), full.total_coord_updates());
    assert!(
        cs < cf,
        "newton_cd screening saved nothing: strong {cs} vs full {cf}"
    );
}

/// Satellite acceptance (`prox_grad`): restricted-vs-full equivalence at
/// 1e-6. The prox trajectory genuinely differs under restriction (frozen
/// coordinates cannot wiggle transiently), so both runs are driven to a
/// tight tolerance where the common optimum pins the comparison.
#[test]
fn prox_grad_screened_path_matches_full() {
    let prob = datagen::chain::generate(8, 8, 60, 59);
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 3000,
        tol: 1e-4,
        ..Default::default()
    };
    let mk = |screen| PathOptions {
        points: 4,
        min_ratio: 0.3,
        screen,
        ..Default::default()
    };
    let strong = fit_path(
        SolverKind::ProxGrad,
        &prob.data,
        &base,
        &mk(ScreenRule::Strong),
        &eng,
    )
    .unwrap();
    let full = fit_path(
        SolverKind::ProxGrad,
        &prob.data,
        &base,
        &mk(ScreenRule::Full),
        &eng,
    )
    .unwrap();
    assert_eq!(strong.points.len(), full.points.len());
    for (s, f) in strong.points.iter().zip(&full.points) {
        assert!(s.converged && f.converged, "prox must converge at tol 1e-4");
        assert!(
            (s.f - f.f).abs() <= 1e-6 * f.f.abs().max(1.0),
            "prox diverged at λ={}: screened {} vs full {}",
            s.lam_l,
            s.f,
            f.f
        );
    }
    assert!(strong.points[1..].iter().all(|p| p.screened));
    assert!(full.points.iter().all(|p| !p.screened));
}

/// Satellite acceptance (`alt_newton_bcd`): the block solver's panel
/// sweeps honor `SolveOptions::screen`. A full-universe restriction must
/// reproduce the unrestricted run exactly (the plumbing adds nothing).
#[test]
fn block_solver_full_universe_screen_is_a_no_op() {
    let prob = datagen::chain::generate(12, 12, 70, 61);
    let eng = NativeGemm::new(1);
    let mut opts = base_opts();
    opts.lam_l = 0.2;
    opts.lam_t = 0.2;
    opts.chol = cggm::cggm::CholKind::SparseRcm;
    let (p, q) = (12usize, 12usize);
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    let reference = solve_in_context(SolverKind::AltNewtonBcd, &ctx, &opts, None).unwrap();
    assert!(reference.trace.converged);
    let mut ropts = opts.clone();
    ropts.screen = Some(Arc::new(ScreenSet {
        lambda: (0..q).flat_map(|i| (i..q).map(move |j| (i, j))).collect(),
        theta: (0..p).flat_map(|i| (0..q).map(move |j| (i, j))).collect(),
    }));
    let ctx2 = SolverContext::new(&prob.data, &ropts, &eng);
    let restricted = solve_in_context(SolverKind::AltNewtonBcd, &ctx2, &ropts, None).unwrap();
    assert_eq!(
        restricted.trace.records.len(),
        reference.trace.records.len(),
        "full-universe restriction changed the block solver's iterate path"
    );
    let (fa, fb) = (
        restricted.trace.final_f().unwrap(),
        reference.trace.final_f().unwrap(),
    );
    assert!((fa - fb).abs() <= 1e-9 * fb.abs().max(1.0), "{fa} vs {fb}");
    assert_eq!(restricted.model.lambda_nnz(), reference.model.lambda_nnz());
    assert_eq!(restricted.model.theta_nnz(), reference.model.theta_nnz());
    // The restricted run reports the (here maximal) screened coordinate
    // count like the dense solvers do.
    assert!(restricted.trace.coords_screened > 0);
}

/// Satellite acceptance (`alt_newton_bcd`, 1e-6): a *strict* restriction —
/// the unrestricted optimum's support plus every near-threshold coordinate
/// — must land on the unrestricted objective to 1e-6. This is the shape of
/// set the strong rule would hand the solver along a path.
#[test]
fn block_solver_screened_matches_full_to_1e6() {
    let prob = datagen::chain::generate(14, 14, 90, 67);
    let eng = NativeGemm::new(1);
    let mut opts = base_opts();
    opts.lam_l = 0.18;
    opts.lam_t = 0.18;
    opts.chol = cggm::cggm::CholKind::SparseRcm;
    // Restricted and full runs take different transient trajectories (the
    // full run may briefly move coordinates outside the set), so the 1e-6
    // comparison is pinned at a tight stopping tolerance where the shared
    // optimum dominates.
    opts.tol = 1e-5;
    opts.max_iter = 300;
    let (p, q) = (14usize, 14usize);
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    let reference = solve_in_context(SolverKind::AltNewtonBcd, &ctx, &opts, None).unwrap();
    assert!(reference.trace.converged);
    let f_ref = reference.trace.final_f().unwrap();
    // Screen set from the optimum: support ∪ {|∇g| > 0.9λ} — covers every
    // KKT-active boundary coordinate, so the restricted optimum is the
    // full one. (Gradients via the dense helper — test-only; the solver
    // itself never materializes them.)
    let (gl, gt) = ctx
        .smooth_gradients(&reference.model, cggm::cggm::CholKind::Auto)
        .unwrap();
    let mut set = ScreenSet::default();
    for i in 0..q {
        for j in i..q {
            if i == j
                || reference.model.lambda.get(i, j) != 0.0
                || gl[(i, j)].abs() > 0.9 * opts.lam_l
            {
                set.lambda.push((i, j));
            }
        }
    }
    for i in 0..p {
        for j in 0..q {
            if reference.model.theta.get(i, j) != 0.0 || gt[(i, j)].abs() > 0.9 * opts.lam_t {
                set.theta.push((i, j));
            }
        }
    }
    let full_coords = q * (q + 1) / 2 + p * q;
    assert!(
        set.len() < full_coords,
        "fixture must actually restrict something ({} of {full_coords})",
        set.len()
    );
    let mut ropts = opts.clone();
    ropts.screen = Some(Arc::new(set));
    let ctx2 = SolverContext::new(&prob.data, &ropts, &eng);
    let restricted = solve_in_context(SolverKind::AltNewtonBcd, &ctx2, &ropts, None).unwrap();
    assert!(restricted.trace.converged);
    let f_res = restricted.trace.final_f().unwrap();
    assert!(
        (f_res - f_ref).abs() <= 1e-6 * f_ref.abs().max(1.0),
        "screened block solve diverged: {f_res} vs full {f_ref}"
    );
}

/// The strong rule's bet pays off on a well-spaced decreasing grid: no KKT
/// fallbacks across the whole path, and every screened point's final
/// support is contained in its screen set (which the no-fallback outcome
/// certifies via the KKT scan).
#[test]
fn well_spaced_grid_needs_no_fallbacks() {
    let prob = datagen::chain::generate(25, 25, 100, 37);
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let popts = PathOptions {
        points: 10,
        min_ratio: 0.1,
        ..Default::default()
    };
    let res = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &eng).unwrap();
    assert_eq!(res.points.len(), 10);
    assert!(res.points.iter().all(|p| p.converged));
    assert_eq!(
        res.screen_fallbacks, 0,
        "strong rule should hold on a gentle geometric grid"
    );
    // Support grows monotonically-ish along the path; the screened driver
    // must preserve that shape.
    assert!(
        res.points.last().unwrap().lambda_nnz >= res.points[0].lambda_nnz,
        "support should grow as λ decreases: {:?}",
        res.points
            .iter()
            .map(|p| p.lambda_nnz)
            .collect::<Vec<_>>()
    );
}
