//! Shared fixtures for the integration suite.
//!
//! Every fixture is deterministic: generator seeds are pinned here (and
//! documented in `docs/TESTING.md`) so failures replay exactly. Modules use
//! different seeds on purpose — a regression in one workload should not be
//! masked by another module's tuning.

#![allow(dead_code)] // each test module uses a subset of the fixtures

use cggm::datagen::{self, Problem};
use cggm::solvers::SolveOptions;

/// Seed for the "medium chain" problems (solver agreement, golden path).
pub const CHAIN_SEED: u64 = 11;

/// Seed for CV fixtures (train/eval splits stay reproducible).
pub const CV_SEED: u64 = 29;

/// Solve options shared by the chain fixtures: both penalties at `lam`,
/// enough outer iterations to converge at the default tolerance.
pub fn chain_opts(lam: f64) -> SolveOptions {
    SolveOptions {
        lam_l: lam,
        lam_t: lam,
        max_iter: 80,
        ..Default::default()
    }
}

/// The suite's workhorse problem: 20×20 chain, n=100, seed [`CHAIN_SEED`].
pub fn chain_medium() -> Problem {
    datagen::chain::generate(20, 20, 100, CHAIN_SEED)
}

/// Asymmetric golden-path problem (p=20 inputs, q=10 outputs), fixed seed 7
/// — the shape pinned by `tests/golden/path_chain_p20_q10.json`.
pub fn chain_golden() -> Problem {
    datagen::chain::generate(20, 10, 80, 7)
}

/// Larger sample for CV: p=q=15, n=360 (240 train + 120 eval in cv_tests).
pub fn chain_cv() -> Problem {
    datagen::chain::generate(15, 15, 360, CV_SEED)
}
