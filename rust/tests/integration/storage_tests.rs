//! Out-of-core dataset storage acceptance suite:
//!
//! 1. **Equivalence** — a solve over a disk-backed (`CGGMPAN1`) dataset
//!    reaches the in-memory objective to 1e-6 with the identical support,
//!    on both chain and cluster workloads (the backing changes where the
//!    samples live, not what they are);
//! 2. **Memory** — a chain problem whose raw panels alone exceed the
//!    configured `MemBudget` still solves disk-backed, with `peak() ≤ cap`
//!    and the panel cache actually evicting under pressure;
//! 3. **Streaming** — an append/evict window slide applied to the
//!    disk-backed window matches the same slide applied resident at 1e-6;
//! 4. **Hostility** — every `tests/fixtures/hostile/storage/*.pan` fixture
//!    parses (`.ok.`) or is rejected with a structured error (`.err.`),
//!    never a panic or a dimension-sized allocation;
//! 5. **Serving** — `load {"storage":"disk"}` binds the panel file
//!    out-of-core and `stat`/fit traces expose the panel-cache counters.

use cggm::coordinator::{self, RunConfig};
use cggm::cggm::Dataset;
use cggm::datagen::{self, cluster_graph::ClusterOptions};
use cggm::gemm::native::NativeGemm;
use cggm::linalg::dense::Mat;
use cggm::linalg::sparse::SpRowMat;
use cggm::serve::{Request, ServeEngine};
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::storage;
use cggm::util::membudget::MemBudget;
use cggm::util::rng::Rng;
use std::io::Cursor;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cggm_storage_it_{}_{}", name, std::process::id()))
}

fn opts(lam: f64) -> SolveOptions {
    SolveOptions {
        lam_l: lam,
        lam_t: lam,
        max_iter: 120,
        tol: 0.00001,
        ..Default::default()
    }
}

/// Write `data` as a sharded panel file and bind it disk-backed.
fn disk_mirror(data: &Dataset, name: &str, panel_rows: usize, cache: usize) -> (Dataset, PathBuf) {
    let path = tmp(name);
    coordinator::save_dataset_sharded(data, &path, 16).unwrap();
    (Dataset::open_disk(&path, panel_rows, cache).unwrap(), path)
}

fn assert_same_support(a: &SpRowMat, b: &SpRowMat, what: &str) {
    assert_eq!(a.rows(), b.rows(), "{what}: shape");
    for i in 0..a.rows() {
        let pa: Vec<usize> = a.row(i).iter().map(|e| e.0).collect();
        let pb: Vec<usize> = b.row(i).iter().map(|e| e.0).collect();
        assert_eq!(pa, pb, "{what}: support differs in row {i}");
    }
}

/// Acceptance: disk-backed and in-memory solves agree at 1e-6 with the
/// identical support, on both synthetic workloads.
#[test]
fn disk_backed_solve_matches_resident_on_chain_and_cluster() {
    let cluster_opts = ClusterOptions {
        cluster_size: 6,
        hub_coeff: 3.0,
        ..Default::default()
    };
    let problems = [
        ("chain", datagen::chain::generate(24, 24, 100, 101)),
        (
            "cluster",
            datagen::cluster_graph::generate(40, 12, 120, 103, &cluster_opts),
        ),
    ];
    let eng = NativeGemm::new(1);
    for (name, prob) in &problems {
        let mem = solve(SolverKind::AltNewtonCd, &prob.data, &opts(0.2), &eng).unwrap();
        assert!(mem.trace.converged, "{name}: resident run must converge");
        let f_mem = mem.trace.final_f().unwrap();
        // A panel granularity that divides p and one that does not.
        for panel_rows in [7usize, 16] {
            let (disk, path) = disk_mirror(&prob.data, name, panel_rows, usize::MAX);
            assert_eq!(disk.storage_name(), "disk");
            let got = solve(SolverKind::AltNewtonCd, &disk, &opts(0.2), &eng).unwrap();
            assert!(got.trace.converged, "{name}/r={panel_rows}: disk run converges");
            let f_disk = got.trace.final_f().unwrap();
            assert!(
                (f_disk - f_mem).abs() <= 1e-6 * f_mem.abs().max(1.0),
                "{name}/r={panel_rows}: disk {f_disk} vs mem {f_mem}"
            );
            assert_same_support(&got.model.lambda, &mem.model.lambda, "lambda");
            assert_same_support(&got.model.theta, &mem.model.theta, "theta");
            // The solve's I/O is visible in the trace.
            assert!(got.trace.panel_reads > 0, "{name}: no panel reads recorded");
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Acceptance: a chain problem whose raw data cannot be resident under the
/// configured budget solves disk-backed to the unconstrained answer at
/// 1e-6, with the measured `peak() ≤ cap` and panel-cache evictions > 0.
#[test]
fn budget_capped_disk_solve_stays_under_resident_data_footprint() {
    let (p, q, n) = (60usize, 60usize, 4000usize);
    let prob = datagen::chain::generate(p, q, n, 107);
    let eng = NativeGemm::new(1);
    // Reference: resident data, unlimited memory.
    let mem = solve(SolverKind::AltNewtonCd, &prob.data, &opts(0.4), &eng).unwrap();
    assert!(mem.trace.converged);
    let f_mem = mem.trace.final_f().unwrap();
    // The raw panels alone (8·n·(p+q) ≈ 3.84 MB) cannot fit the 1.5 MB cap:
    // pinning them resident would fail before any solve work started.
    let cap = 3 << 19;
    assert!(
        prob.data.bytes() > 2 * cap,
        "fixture must be infeasible fully-resident ({} bytes vs cap {cap})",
        prob.data.bytes()
    );
    let budget = MemBudget::new(cap);
    // 8-row panels ≈ 256 KB each; a 300 KB cache holds at most one, so the
    // pairwise Gram sweeps must evict (and degrade to transients).
    let (disk, path) = disk_mirror(&prob.data, "capped", 8, 300 << 10);
    disk.bind_panel_budget(&budget);
    let mut o = opts(0.4);
    o.budget = budget.clone();
    let got = solve(SolverKind::AltNewtonCd, &disk, &o, &eng)
        .expect("disk-backed solve must fit under the cap");
    assert!(got.trace.converged);
    let f_disk = got.trace.final_f().unwrap();
    assert!(
        (f_disk - f_mem).abs() <= 1e-6 * f_mem.abs().max(1.0),
        "budget-capped disk {f_disk} vs resident {f_mem}"
    );
    assert!(
        budget.peak() <= cap,
        "peak {} exceeded the cap {cap}",
        budget.peak()
    );
    let stats = disk.panel_stats().unwrap();
    assert!(stats.evictions > 0, "cache pressure must force evictions: {stats:?}");
    assert!(stats.reads > 0 && stats.misses > 0);
    let _ = std::fs::remove_file(path);
}

/// Acceptance: the same append + evict window slide applied to the
/// disk-backed window (shards appended to the file, logical evict offset)
/// and to the resident window produces 1e-6-identical solves.
#[test]
fn window_slide_on_disk_matches_resident() {
    let (p, q, n, k) = (16usize, 16usize, 80usize, 12usize);
    let prob = datagen::chain::generate(p, q, n, 109);
    let (mut disk, path) = disk_mirror(&prob.data, "slide", 5, usize::MAX);
    let mut mem = prob.data.clone();
    let mut rng = Rng::new(211);
    let xa = Mat::from_fn(p, k, |_, _| rng.normal());
    let ya = Mat::from_fn(q, k, |_, _| rng.normal());
    for d in [&mut mem, &mut disk] {
        d.append_samples(&xa, &ya).unwrap();
        let evicted = d.evict_oldest(k).unwrap();
        assert_eq!(evicted.k(), k);
        assert_eq!(d.n(), n);
    }
    let eng = NativeGemm::new(1);
    let a = solve(SolverKind::AltNewtonCd, &mem, &opts(0.3), &eng).unwrap();
    let b = solve(SolverKind::AltNewtonCd, &disk, &opts(0.3), &eng).unwrap();
    assert!(a.trace.converged && b.trace.converged);
    let (fa, fb) = (a.trace.final_f().unwrap(), b.trace.final_f().unwrap());
    assert!(
        (fa - fb).abs() <= 1e-6 * fa.abs().max(1.0),
        "slid window: resident {fa} vs disk {fb}"
    );
    assert_same_support(&a.model.lambda, &b.model.lambda, "lambda");
    assert_same_support(&a.model.theta, &b.model.theta, "theta");
    let _ = std::fs::remove_file(path);
}

/// Every hostile panel-file fixture resolves per its name — `.ok.` parses,
/// `.err.` is a structured error — through both the bare header parser and
/// the full disk-binding path (which must not panic either way).
#[test]
fn hostile_panel_fixtures_resolve_per_name() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/hostile/storage");
    let mut seen = 0usize;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if !name.ends_with(".pan") {
            continue;
        }
        seen += 1;
        let bytes = std::fs::read(&path).unwrap();
        let meta = storage::read_meta(&mut Cursor::new(bytes.as_slice()));
        if name.contains(".ok.") {
            let meta = meta.unwrap_or_else(|e| panic!("{name} must parse: {e}"));
            assert!(meta.p >= 1 && meta.q >= 1);
            // A parsed header also binds (possibly with zero samples).
            let d = Dataset::open_disk(&path, 4, usize::MAX)
                .unwrap_or_else(|e| panic!("{name} must bind: {e}"));
            assert_eq!((d.p(), d.q(), d.n()), (meta.p, meta.q, meta.n));
        } else {
            assert!(meta.is_err(), "{name} must be rejected");
            assert!(
                Dataset::open_disk(&path, 4, usize::MAX).is_err(),
                "{name} must not bind"
            );
        }
    }
    assert!(seen >= 15, "fixture sweep found only {seen} files — wrong dir?");
}

/// Serving: `load` with `"storage":"disk"` binds the panel file out-of-core
/// (pinning far less than the dense arrays), the fit's trace carries
/// nonzero panel counters, and `stat` reports the storage mode per dataset.
#[test]
fn serve_load_disk_reports_panel_counters() {
    let prob = datagen::chain::generate(20, 20, 400, 113);
    let path = tmp("serve.pan");
    coordinator::save_dataset_sharded(&prob.data, &path, 64).unwrap();
    let cfg = RunConfig {
        serve_max_jobs: 1,
        panel_rows: 6,
        panel_cache: 64 << 10,
        ..RunConfig::default()
    };
    let srv = ServeEngine::new(cfg, Arc::new(NativeGemm::new(1)));
    let req = |line: &str| Request::parse_line(line).expect("test request must parse");
    let load = srv.request(req(&format!(
        r#"{{"op":"load","id":1,"name":"ooc","path":"{}","storage":"disk"}}"#,
        path.display()
    )));
    assert!(load.is_ok(), "{:?}", load.outcome);
    let lres = load.result().unwrap();
    assert_eq!(lres.get("storage").and_then(|v| v.as_str()), Some("disk"));
    let fit = srv.request(req(
        r#"{"op":"fit","id":2,"dataset":"ooc","solver":"alt","lambda":0.4,"max_iter":80}"#,
    ));
    assert!(fit.is_ok(), "{:?}", fit.outcome);
    let trace = fit.result().unwrap().get("trace").unwrap().clone();
    let reads = trace.get("panel_reads").and_then(|v| v.as_f64()).unwrap();
    assert!(reads > 0.0, "fit on a disk dataset must read panels");
    let stat = srv.request(req(r#"{"op":"stat","id":3}"#));
    let sres = stat.result().unwrap().clone();
    let ds = &sres.get("registry").unwrap().get("datasets").unwrap().as_arr().unwrap()[0];
    assert_eq!(ds.get("storage").and_then(|v| v.as_str()), Some("disk"));
    assert!(ds.get("panel_reads").and_then(|v| v.as_f64()).unwrap() > 0.0);
    // A generated (resident) load reports "mem" and zero panel traffic.
    let load2 = srv.request(req(
        r#"{"op":"load","id":4,"name":"res","workload":"chain","p":10,"q":10,"n":40,"seed":1}"#,
    ));
    assert!(load2.is_ok(), "{:?}", load2.outcome);
    assert_eq!(
        load2.result().unwrap().get("storage").and_then(|v| v.as_str()),
        Some("mem")
    );
    srv.join();
    let _ = std::fs::remove_file(path);
}
