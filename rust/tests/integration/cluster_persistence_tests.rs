//! Block-solver clustering persistence: the graph partition lives in the
//! `SolverContext` and is rebuilt only when active-set churn crosses
//! `SolveOptions::recluster_churn` — observable via
//! `SolveTrace::reclusterings` / `PathPoint::reclusterings` — and the
//! partition choice is an optimization, never a semantic change: forced
//! re-clustering reaches 1e-6-equal objectives.
//!
//! Fixture: 24×24 chain under a 48KB budget, which forces k_Λ > 1 so the
//! clustering path actually engages (an unlimited budget yields one block
//! and no clustering at all). `tol = 1e-5` drives both runs deep enough
//! that the 1e-6 objective comparison is meaningful.

use cggm::coordinator::{fit_path_in_context, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve_in_context, SolveOptions, SolverContext, SolverKind};
use cggm::util::membudget::MemBudget;

fn bcd_opts(churn: f64) -> SolveOptions {
    SolveOptions {
        lam_l: 0.25,
        lam_t: 0.25,
        max_iter: 200,
        tol: 1e-5,
        budget: MemBudget::new(48 * 1024),
        recluster_churn: churn,
        ..Default::default()
    }
}

fn fixture() -> datagen::Problem {
    datagen::chain::generate(24, 24, 90, 2)
}

/// A single solve: the persistent partition is built once and reused across
/// outer iterations; the always-rebuild ablation reclusters every iteration
/// yet lands on a 1e-6-equal objective.
#[test]
fn solve_reuses_partition_across_iterations() {
    let prob = fixture();
    let eng = NativeGemm::new(1);

    // Never rebuild once built (churn threshold 1.0 ≥ any Jaccard distance).
    let cached_opts = bcd_opts(1.0);
    let cached_ctx = SolverContext::new(&prob.data, &cached_opts, &eng);
    let cached = solve_in_context(SolverKind::AltNewtonBcd, &cached_ctx, &cached_opts, None)
        .unwrap();
    assert!(cached.trace.converged);
    assert!(
        cached.trace.records.len() >= 3,
        "fixture must run several iterations to exercise reuse"
    );
    assert!(
        cached.trace.reclusterings >= 1,
        "the first iteration must build the partition"
    );

    // Forced: a negative threshold rebuilds at every clustering phase.
    let forced_opts = bcd_opts(-1.0);
    let forced_ctx = SolverContext::new(&prob.data, &forced_opts, &eng);
    let forced = solve_in_context(SolverKind::AltNewtonBcd, &forced_ctx, &forced_opts, None)
        .unwrap();
    assert!(forced.trace.converged);
    assert!(
        forced.trace.reclusterings >= 2,
        "forced run must recluster repeatedly ({} iterations)",
        forced.trace.records.len()
    );
    assert!(
        cached.trace.reclusterings < forced.trace.reclusterings,
        "persistence saved nothing: cached {} vs forced {}",
        cached.trace.reclusterings,
        forced.trace.reclusterings
    );
    // Partition choice changes CD update order only — same optimum.
    let (fc, ff) = (
        cached.trace.final_f().unwrap(),
        forced.trace.final_f().unwrap(),
    );
    assert!(
        (fc - ff).abs() <= 1e-6 * ff.abs().max(1.0),
        "forced re-clustering moved the objective: cached {fc} vs forced {ff}"
    );
}

/// Along a slowly-varying λ path on a shared context, adjacent points reuse
/// the partition (supports change slowly): total rebuilds stay well under
/// the always-rebuild ablation, and every point's objective matches to 1e-6.
#[test]
fn path_reclusters_only_on_churn() {
    let prob = fixture();
    let eng = NativeGemm::new(1);
    // A gently-spaced explicit grid: adjacent active sets overlap strongly,
    // which is exactly the regime the persistence targets.
    let popts = PathOptions {
        lambdas: Some(vec![(0.30, 0.30), (0.28, 0.28), (0.26, 0.26)]),
        ..Default::default()
    };

    let cached_base = bcd_opts(0.25);
    let cached_ctx = SolverContext::new(&prob.data, &cached_base, &eng);
    let cached =
        fit_path_in_context(SolverKind::AltNewtonBcd, &cached_ctx, &cached_base, &popts).unwrap();

    let forced_base = bcd_opts(-1.0);
    let forced_ctx = SolverContext::new(&prob.data, &forced_base, &eng);
    let forced =
        fit_path_in_context(SolverKind::AltNewtonBcd, &forced_ctx, &forced_base, &popts).unwrap();

    assert_eq!(cached.points.len(), 3);
    assert_eq!(forced.points.len(), 3);
    assert!(cached.points.iter().all(|p| p.converged));
    assert!(forced.points.iter().all(|p| p.converged));

    let total = |r: &cggm::coordinator::PathResult| {
        r.points.iter().map(|p| p.reclusterings).sum::<usize>()
    };
    let (tc, tf) = (total(&cached), total(&forced));
    assert!(tc >= 1, "the path's first point must build the partition");
    assert!(
        tc < tf,
        "path persistence saved nothing: cached {tc} vs forced {tf} rebuilds"
    );
    for (a, b) in cached.points.iter().zip(&forced.points) {
        assert!(
            (a.f - b.f).abs() <= 1e-6 * b.f.abs().max(1.0),
            "objectives diverged at λ={}: cached {} vs forced {}",
            a.lam_l,
            a.f,
            b.f
        );
    }
}

/// A warm path point at an unchanged λ converges at its first screen and
/// never re-derives any clustering state — the degenerate end of "supports
/// change slowly along a path".
#[test]
fn converged_warm_point_reclusters_nothing() {
    let prob = fixture();
    let eng = NativeGemm::new(1);
    let base = bcd_opts(0.25);
    let ctx = SolverContext::new(&prob.data, &base, &eng);
    let popts = PathOptions {
        lambdas: Some(vec![(0.25, 0.25), (0.25, 0.25)]),
        ..Default::default()
    };
    let res = fit_path_in_context(SolverKind::AltNewtonBcd, &ctx, &base, &popts).unwrap();
    assert_eq!(res.points.len(), 2);
    assert!(res.points[1].converged);
    assert_eq!(
        res.points[1].iters, 1,
        "warm restart at the optimum must converge at the first screen"
    );
    assert_eq!(
        res.points[1].reclusterings, 0,
        "a converged warm point must not rebuild any partition"
    );
}
