//! λ-path checkpoint/resume round-trips: an interrupted sweep, resumed from
//! its checkpoint, must reproduce the uninterrupted sweep's objectives to
//! 1e-8 — the checkpoint stores models with exact f64 round-trips, so the
//! resumed trajectory is the interrupted one continued, not a lookalike.
//! Corrupted/truncated checkpoints recover by refitting from the last valid
//! point; a header-corrupt file is treated as no checkpoint at all.

use cggm::coordinator::{checkpoint, fit_path, PathOptions, PathResult};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{SolveOptions, SolverKind};
use std::path::PathBuf;

fn fixture() -> datagen::Problem {
    datagen::chain::generate(16, 16, 80, 41)
}

fn base_opts() -> SolveOptions {
    SolveOptions {
        max_iter: 80,
        ..Default::default()
    }
}

fn popts(ck: Option<PathBuf>, resume: bool) -> PathOptions {
    PathOptions {
        points: 5,
        min_ratio: 0.1,
        checkpoint: ck,
        resume,
        ..Default::default()
    }
}

fn assert_paths_equal(reference: &PathResult, got: &PathResult) {
    assert_eq!(reference.points.len(), got.points.len());
    for (a, b) in reference.points.iter().zip(&got.points) {
        assert_eq!(a.lam_l, b.lam_l, "grids diverged");
        assert_eq!(a.lam_t, b.lam_t);
        assert!(
            (a.f - b.f).abs() <= 1e-8 * a.f.abs().max(1.0),
            "objective diverged at λ={}: reference {} vs resumed {}",
            a.lam_l,
            a.f,
            b.f
        );
        assert_eq!(a.lambda_nnz, b.lambda_nnz, "support diverged at λ={}", a.lam_l);
        assert_eq!(a.theta_nnz, b.theta_nnz);
    }
    let (ma, mb) = (
        reference.model.as_ref().unwrap(),
        got.model.as_ref().unwrap(),
    );
    assert!(
        ma.lambda
            .to_dense()
            .max_abs_diff(&mb.lambda.to_dense())
            <= 1e-8
    );
    assert!(ma.theta.to_dense().max_abs_diff(&mb.theta.to_dense()) <= 1e-8);
}

/// Keep the first `1 + points` lines (header + fitted points) of a
/// checkpoint — simulating a sweep killed after `points` points.
fn truncate_to_points(ck: &PathBuf, points: usize) {
    let text = std::fs::read_to_string(ck).unwrap();
    let prefix: String = text
        .lines()
        .take(1 + points)
        .map(|l| format!("{l}\n"))
        .collect();
    std::fs::write(ck, prefix).unwrap();
}

/// Acceptance: interrupt a sweep after 2 of 5 points, resume, and match the
/// uninterrupted sweep's per-λ objectives to 1e-8.
#[test]
fn resumed_sweep_matches_uninterrupted_run() {
    let prob = fixture();
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let reference =
        fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &popts(None, false), &eng).unwrap();
    assert_eq!(reference.points.len(), 5);
    assert_eq!(reference.resumed_points, 0);

    let ck = std::env::temp_dir().join("cggm_it_ckpt_resume.jsonl");
    let _ = std::fs::remove_file(&ck);
    let full = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), false),
        &eng,
    )
    .unwrap();
    // Checkpointing itself must not perturb the sweep.
    assert_paths_equal(&reference, &full);

    // "Interrupt" after two points, then resume with the same options.
    truncate_to_points(&ck, 2);
    let resumed = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), true),
        &eng,
    )
    .unwrap();
    assert_eq!(resumed.resumed_points, 2);
    assert_paths_equal(&reference, &resumed);

    // The resumed run appended the refitted points: the checkpoint is whole
    // again and a further resume carries all 5 points without refitting.
    let state = checkpoint::load(&ck).unwrap();
    assert_eq!(state.points.len(), 5);
    let replay = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), true),
        &eng,
    )
    .unwrap();
    assert_eq!(replay.resumed_points, 5);
    assert_paths_equal(&reference, &replay);
    let _ = std::fs::remove_file(&ck);
}

/// A checkpoint whose final line was torn mid-write (the only state an
/// interrupted flush-per-line log can leave) recovers by refitting from the
/// last *valid* point — and still reproduces the uninterrupted objectives.
#[test]
fn torn_checkpoint_recovers_from_last_valid_point() {
    let prob = fixture();
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let reference =
        fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &popts(None, false), &eng).unwrap();

    let ck = std::env::temp_dir().join("cggm_it_ckpt_torn.jsonl");
    let _ = std::fs::remove_file(&ck);
    let _ = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), false),
        &eng,
    )
    .unwrap();
    // Keep header + 3 points + half of the 4th point's line.
    let text = std::fs::read_to_string(&ck).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let mut torn: String = lines[..4].iter().map(|l| format!("{l}\n")).collect();
    torn.push_str(&lines[4][..lines[4].len() / 2]);
    std::fs::write(&ck, torn).unwrap();

    let state = checkpoint::load(&ck).unwrap();
    assert_eq!(state.points.len(), 3, "torn line must not count");

    let resumed = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), true),
        &eng,
    )
    .unwrap();
    assert_eq!(resumed.resumed_points, 3);
    assert_paths_equal(&reference, &resumed);
    let _ = std::fs::remove_file(&ck);
}

/// A file that is not a checkpoint (corrupt header) is no checkpoint: the
/// sweep starts fresh, overwrites it, and completes normally.
#[test]
fn corrupt_header_starts_fresh() {
    let prob = fixture();
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let ck = std::env::temp_dir().join("cggm_it_ckpt_corrupt.jsonl");
    std::fs::write(&ck, "this is not a checkpoint\n{\"kind\":\"garbage\"}\n").unwrap();
    let res = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), true),
        &eng,
    )
    .unwrap();
    assert_eq!(res.resumed_points, 0, "garbage must not be resumed");
    assert_eq!(res.points.len(), 5);
    // The rewritten file is a valid checkpoint of the full sweep.
    let state = checkpoint::load(&ck).unwrap();
    assert_eq!(state.points.len(), 5);
    let _ = std::fs::remove_file(&ck);
}

/// Resuming a checkpoint written by a different run (other solver or other
/// problem shape) is an error, not a silent fresh start — the file must
/// never be clobbered, and a dimensionally-wrong model must never be adopted
/// as a warm start.
#[test]
fn mismatched_checkpoint_is_refused() {
    let prob = fixture(); // 16×16
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let ck = std::env::temp_dir().join("cggm_it_ckpt_mismatch.jsonl");
    let _ = std::fs::remove_file(&ck);
    let _ = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), false),
        &eng,
    )
    .unwrap();
    let before = std::fs::read_to_string(&ck).unwrap();
    // Same data, different solver.
    let err = fit_path(
        SolverKind::NewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), true),
        &eng,
    );
    assert!(
        matches!(err, Err(cggm::solvers::SolveError::Checkpoint(_))),
        "solver mismatch must refuse to resume"
    );
    // Same solver, different shape.
    let other = datagen::chain::generate(12, 12, 60, 43);
    let err = fit_path(
        SolverKind::AltNewtonCd,
        &other.data,
        &base,
        &popts(Some(ck.clone()), true),
        &eng,
    );
    assert!(
        matches!(err, Err(cggm::solvers::SolveError::Checkpoint(_))),
        "shape mismatch must refuse to resume"
    );
    // The refused checkpoint survives untouched.
    assert_eq!(std::fs::read_to_string(&ck).unwrap(), before);
    let _ = std::fs::remove_file(&ck);
}

/// A resumed sweep's summary counters cover the carried-over points: its
/// screen_fallbacks equals the sum of `fallback` flags over *all* points,
/// exactly like an uninterrupted run's.
#[test]
fn resumed_summary_counters_cover_carried_points() {
    let prob = fixture();
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let ck = std::env::temp_dir().join("cggm_it_ckpt_counters.jsonl");
    let _ = std::fs::remove_file(&ck);
    let _ = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), false),
        &eng,
    )
    .unwrap();
    truncate_to_points(&ck, 3);
    let resumed = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &popts(Some(ck.clone()), true),
        &eng,
    )
    .unwrap();
    let from_points = resumed.points.iter().filter(|p| p.fallback).count();
    assert_eq!(
        resumed.screen_fallbacks, from_points,
        "summary must agree with the points array it summarizes"
    );
    let _ = std::fs::remove_file(&ck);
}

/// Checkpointing composes with the unscreened/cold configurations too: the
/// resume path must not assume the strong rule is active.
#[test]
fn resume_without_screening_or_warm_starts() {
    let prob = fixture();
    let eng = NativeGemm::new(1);
    let base = base_opts();
    let mk = |ck: Option<PathBuf>, resume: bool| PathOptions {
        warm_start: false,
        screen: cggm::cggm::active::ScreenRule::Full,
        ..popts(ck, resume)
    };
    let reference =
        fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &mk(None, false), &eng).unwrap();
    let ck = std::env::temp_dir().join("cggm_it_ckpt_cold.jsonl");
    let _ = std::fs::remove_file(&ck);
    let _ = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &mk(Some(ck.clone()), false),
        &eng,
    )
    .unwrap();
    truncate_to_points(&ck, 3);
    let resumed = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &mk(Some(ck.clone()), true),
        &eng,
    )
    .unwrap();
    assert_eq!(resumed.resumed_points, 3);
    assert_paths_equal(&reference, &resumed);
    let _ = std::fs::remove_file(&ck);
}

/// Hostile checkpoint fixtures (`tests/fixtures/hostile/`): adversarial
/// headers and point lines must produce errors or an empty valid prefix —
/// never panics, aborts, or header-driven giant allocations. Convention:
/// `cv_*` files go through `load_cv`, the rest through `load`; `*.err.*`
/// must be an `Err`, `*.ok.*` must be `Ok` with nothing recorded.
#[test]
fn hostile_fixtures_error_cleanly_or_record_nothing() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("hostile");
    let mut seen = 0usize;
    for entry in std::fs::read_dir(&dir).expect("hostile fixture dir exists") {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if !name.ends_with(".jsonl") {
            continue;
        }
        seen += 1;
        let expect_err = name.contains(".err.");
        assert!(
            expect_err || name.contains(".ok."),
            "fixture {name} must declare .err. or .ok."
        );
        if name.starts_with("cv_") {
            match checkpoint::load_cv(&path) {
                Err(_) => assert!(expect_err, "{name}: unexpected error"),
                Ok(state) => {
                    assert!(!expect_err, "{name}: expected an error, got Ok");
                    assert!(
                        state.nll.iter().flatten().all(|x| x.is_nan()),
                        "{name}: a hostile line recorded a score"
                    );
                    assert_eq!(state.completed_folds(), 0, "{name}");
                }
            }
        } else {
            match checkpoint::load(&path) {
                Err(_) => assert!(expect_err, "{name}: unexpected error"),
                Ok(state) => {
                    assert!(!expect_err, "{name}: expected an error, got Ok");
                    assert!(state.points.is_empty(), "{name}: a hostile line survived");
                    assert!(state.model.is_none(), "{name}");
                }
            }
        }
    }
    assert!(seen >= 9, "hostile fixture set went missing ({seen} files)");
}
