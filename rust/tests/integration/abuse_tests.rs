//! Structured-abuse property suite for the untrusted-input surface: a live
//! [`ServeEngine`] hammered with malformed, oversized, duplicate-id, and
//! immediately-disconnecting clients. The properties under every
//! interleaving:
//!
//! - the admission invariant `budget().live() + reserved_bytes() ≤ limit`
//!   holds while abuse is in flight (the estimates are deliberately
//!   conservative for these tiny probe datasets, so the strict form is
//!   sound at this limit);
//! - the daemon answers a well-formed probe after each abuse round;
//! - no request is silently dropped — every line a client gets onto the
//!   wire is answered exactly once (or the client observably lost its
//!   connection);
//! - `cancel` storms against running, queued, finished, and unknown ids
//!   always answer structurally (`ok` or `not_found`), cancelled jobs
//!   terminate with a `cancelled`-kind error, and no interleaving leaks a
//!   byte of reservation.
//!
//! The streaming surface is covered by the hostile-`append` fixture corpus
//! (`tests/fixtures/hostile/append/`) and a concurrent
//! append-vs-refit-vs-cancel interleaving under the same budget monitor.
//!
//! The three seed-crash repros live here too: a deep-nesting line (stack
//! overflow abort on the seed), hostile `load` dimensions (`{"p":-1}` made
//! a 0-dimensional dataset, `{"p":1e300}` a `usize::MAX` allocation), and
//! the unix-socket client that vanishes mid-response (daemon death on the
//! seed).

use cggm::coordinator::RunConfig;
use cggm::gemm::native::NativeGemm;
use cggm::serve::{serve_connection, ErrKind, Request, Response, ServeEngine, ServerLine};
use cggm::serve::{MAX_APPEND_ROWS, MAX_REQUEST_LINE_BYTES};
use cggm::util::json::Json;
use std::io::Cursor;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

fn engine(max_jobs: usize, budget: Option<usize>) -> ServeEngine {
    let cfg = RunConfig {
        serve_max_jobs: max_jobs,
        serve_budget: budget,
        ..RunConfig::default()
    };
    ServeEngine::new(cfg, Arc::new(NativeGemm::new(1)))
}

fn req(line: &str) -> Request {
    Request::parse_line(line).expect("test request must parse")
}

/// Run one in-process JSONL session over byte buffers and hand back the
/// parsed response lines (every line the daemon wrote must be valid JSON).
fn session(srv: &ServeEngine, input: Vec<u8>) -> Vec<Json> {
    let mut out: Vec<u8> = Vec::new();
    serve_connection(srv, Cursor::new(input), &mut out).expect("Vec writer cannot fail");
    String::from_utf8(out)
        .expect("responses are UTF-8")
        .lines()
        .map(|l| Json::parse(l).expect("every response line is valid JSON"))
        .collect()
}

fn is_parse_err(doc: &Json) -> bool {
    doc.get("ok").and_then(|v| v.as_bool()) == Some(false)
        && doc
            .get("error")
            .and_then(|e| e.get("kind"))
            .and_then(|k| k.as_str())
            == Some("parse")
}

const PROBE_LOAD: &str =
    r#"{"op":"load","id":900,"name":"probe","workload":"chain","p":10,"q":10,"n":50,"seed":3}"#;
const PROBE_FIT: &str =
    r#"{"op":"fit","id":901,"dataset":"probe","solver":"alt","lambda":0.5,"max_iter":30}"#;

/// A well-formed load + fit must succeed on this engine right now.
fn probe(srv: &ServeEngine) {
    let load = srv.request(req(PROBE_LOAD));
    assert!(load.is_ok(), "probe load failed: {:?}", load.outcome);
    let fit = srv.request(req(PROBE_FIT));
    assert!(fit.is_ok(), "probe fit failed: {:?}", fit.outcome);
}

/// Seed-crash repro 1: a line of ~100k `[` overflowed the recursive-descent
/// parser's stack — a process abort, unreachable by the engine's panic
/// isolation because it never reaches a job. Now: one `parse` error
/// response, and the same connection keeps serving.
#[test]
fn deep_nesting_line_is_answered_not_fatal() {
    let srv = engine(1, None);
    let mut input = Vec::new();
    input.extend_from_slice("[".repeat(100_000).as_bytes());
    input.push(b'\n');
    input.extend_from_slice(br#"{"op":"stat","id":2}"#);
    input.push(b'\n');
    let lines = session(&srv, input);
    assert_eq!(lines.len(), 2, "both lines answered");
    assert!(is_parse_err(&lines[0]), "bomb gets a parse error: {}", lines[0].to_string());
    assert_eq!(
        lines[1].get("ok").and_then(|v| v.as_bool()),
        Some(true),
        "the connection survives the bomb"
    );
    probe(&srv);
    srv.join();
}

/// An over-cap request line is answered with a `parse` error naming the
/// cap, its remainder is discarded, and the *next* line is served
/// normally. Invalid UTF-8 likewise.
#[test]
fn oversized_and_non_utf8_lines_are_recoverable() {
    let srv = engine(1, None);
    let mut input = Vec::new();
    // 2 MiB of junk on one line — twice the cap.
    input.extend_from_slice(&vec![b'a'; 2 * MAX_REQUEST_LINE_BYTES]);
    input.push(b'\n');
    // A line that is not UTF-8 at all.
    input.extend_from_slice(&[0xff, 0xfe, 0x80, b'\n']);
    // A well-formed request after both.
    input.extend_from_slice(br#"{"op":"stat","id":3}"#);
    input.push(b'\n');
    let lines = session(&srv, input);
    assert_eq!(lines.len(), 3, "all three lines answered");
    assert!(is_parse_err(&lines[0]));
    let msg = lines[0]
        .get("error")
        .and_then(|e| e.get("message"))
        .and_then(|m| m.as_str())
        .unwrap_or("");
    assert!(
        msg.contains(&MAX_REQUEST_LINE_BYTES.to_string()),
        "over-long error names the cap: {msg}"
    );
    assert!(is_parse_err(&lines[1]), "non-UTF-8 is a parse error");
    assert_eq!(lines[2].get("ok").and_then(|v| v.as_bool()), Some(true));
    probe(&srv);
    srv.join();
}

/// Seed-crash repro 2: hostile `load` dimensions. On the seed, the
/// saturating cast turned `{"p":-1}` into a 0-dimensional dataset and
/// `{"p":1e300}` into a `usize::MAX` allocation request. Both must be
/// clean `parse` rejects with the engine still serving.
#[test]
fn hostile_load_dimensions_are_clean_rejects() {
    let srv = engine(1, None);
    for line in [
        r#"{"op":"load","id":1,"name":"h","workload":"chain","p":-1,"q":10,"n":50}"#,
        r#"{"op":"load","id":2,"name":"h","workload":"chain","p":1e300,"q":10,"n":50}"#,
        r#"{"op":"load","id":3,"name":"h","workload":"chain","p":10,"q":2.5,"n":50}"#,
    ] {
        assert!(
            Request::parse_line(line).is_err(),
            "hostile dims must not parse: {line}"
        );
    }
    // Over the wire the reject is a structured parse-kind error response.
    let mut input = Vec::new();
    input.extend_from_slice(
        br#"{"op":"load","id":1,"name":"h","workload":"chain","p":-1,"q":10,"n":50}"#,
    );
    input.push(b'\n');
    let lines = session(&srv, input);
    assert_eq!(lines.len(), 1);
    assert!(is_parse_err(&lines[0]));
    // Nothing named "h" was created, and the engine still serves.
    let stat = srv.request(req(r#"{"op":"fit","id":4,"dataset":"h","lambda":0.5}"#));
    assert_eq!(stat.err_kind(), Some(ErrKind::NotFound));
    probe(&srv);
    srv.join();
}

/// Duplicate ids are the client's problem, not the engine's: every
/// submitted request gets exactly one response, ids echoed verbatim.
#[test]
fn duplicate_ids_each_get_exactly_one_response() {
    let srv = engine(2, None);
    let (tx, rx) = mpsc::channel::<ServerLine>();
    let n = 16;
    for _ in 0..n {
        srv.submit(req(r#"{"op":"stat","id":7}"#), &tx);
    }
    drop(tx);
    let responses: Vec<Response> = rx
        .iter()
        .filter_map(|line| match line {
            ServerLine::Done(resp) => Some(resp),
            ServerLine::Progress(_) => None,
        })
        .collect();
    assert_eq!(responses.len(), n, "one response per submission");
    for r in &responses {
        assert_eq!(r.id, 7);
        assert!(r.is_ok());
    }
    srv.join();
}

/// A client whose writer dies mid-session (the in-process stand-in for a
/// disconnecting socket peer): `serve_connection` reports the I/O error,
/// but the engine — and every other client — is untouched.
struct DyingWriter {
    writes: usize,
}

impl std::io::Write for DyingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.writes == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "peer vanished",
            ));
        }
        self.writes -= 1;
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// The tentpole property test: ≥ 3 concurrent abusive clients — a
/// malformed/hostile-dimension flood, an oversized-line + duplicate-id
/// flood, and an immediately-disconnecting client — while a monitor
/// asserts the budget invariant on every observation. After the abuse,
/// the engine serves a well-formed probe and nothing leaked.
#[test]
fn concurrent_abusive_clients_leave_the_engine_serving() {
    let limit = 256 << 20; // generous headroom: estimates ≪ limit
    let srv = engine(2, Some(limit));
    // Resident warm data so abuse runs against a non-trivial registry.
    probe(&srv);

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        // Monitor: the admission invariant under every interleaving.
        let monitor = scope.spawn(|| {
            let mut observations = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let live = srv.budget().live();
                let reserved = srv.reserved_bytes();
                assert!(
                    live + reserved <= limit,
                    "budget invariant violated: live {live} + reserved {reserved} > limit {limit}"
                );
                observations += 1;
                std::thread::yield_now();
            }
            assert!(observations > 0);
        });

        // Client 1: malformed + hostile-dimension flood, interleaved with
        // valid duplicate-id loads of the same name (admission races).
        let flood = scope.spawn(|| {
            let mut input = Vec::new();
            for k in 0..40 {
                match k % 5 {
                    0 => input.extend_from_slice(b"not json at all"),
                    1 => input.extend_from_slice(
                        br#"{"op":"load","id":5,"name":"x","workload":"chain","p":-1,"q":8,"n":40}"#,
                    ),
                    2 => input.extend_from_slice(
                        br#"{"op":"load","id":5,"name":"x","workload":"chain","p":1e300,"q":8,"n":40}"#,
                    ),
                    3 => input.extend_from_slice(
                        br#"{"op":"load","id":5,"name":"x","workload":"chain","p":8,"q":8,"n":40,"seed":2}"#,
                    ),
                    _ => input.extend_from_slice(br#"{"op":"fit","id":5,"dataset":"x","lambda":0.6}"#),
                }
                input.push(b'\n');
            }
            let lines = session(&srv, input);
            assert_eq!(lines.len(), 40, "every flood line answered");
        });

        // Client 2: oversized lines and deep nesting between valid stats.
        let bomber = scope.spawn(|| {
            let mut input = Vec::new();
            for k in 0..6 {
                if k % 2 == 0 {
                    input.extend_from_slice(&vec![b'{'; 200_000]);
                } else {
                    input.extend_from_slice(&vec![b'a'; MAX_REQUEST_LINE_BYTES + 1]);
                }
                input.push(b'\n');
                input.extend_from_slice(br#"{"op":"stat","id":6}"#);
                input.push(b'\n');
            }
            let lines = session(&srv, input);
            assert_eq!(lines.len(), 12, "every bomber line answered");
            for (k, line) in lines.iter().enumerate() {
                if k % 2 == 0 {
                    assert!(is_parse_err(line), "bomb line {k}: {}", line.to_string());
                } else {
                    assert_eq!(line.get("ok").and_then(|v| v.as_bool()), Some(true));
                }
            }
        });

        // Client 3 (× several rounds): connects, queues real work, and
        // vanishes before reading any response.
        let vanisher = scope.spawn(|| {
            for _ in 0..4 {
                let mut input = Vec::new();
                input.extend_from_slice(
                    br#"{"op":"load","id":8,"name":"v","workload":"chain","p":9,"q":9,"n":40}"#,
                );
                input.push(b'\n');
                input.extend_from_slice(br#"{"op":"fit","id":9,"dataset":"v","lambda":0.5}"#);
                input.push(b'\n');
                let mut w = DyingWriter { writes: 0 };
                let res = serve_connection(&srv, Cursor::new(input), &mut w);
                assert!(res.is_err(), "the dead writer's error is reported");
            }
        });

        flood.join().unwrap();
        bomber.join().unwrap();
        vanisher.join().unwrap();
        // A well-formed probe succeeds after the abuse, before teardown.
        probe(&srv);
        stop.store(true, Ordering::Relaxed);
        monitor.join().unwrap();
    });

    // Quiescent: no reserved bytes leaked by any interleaving.
    srv.drain();
    assert_eq!(srv.reserved_bytes(), 0, "reservation leak");
    assert!(srv.budget().live() <= limit);
    probe(&srv);
    srv.join();
}

/// Seed-crash repro 3, end to end over a real unix socket: client 1 queues
/// work and disconnects without reading; on the seed the daemon died of the
/// broken pipe (and unlinked its socket). Now it logs, survives, and serves
/// client 2.
#[cfg(unix)]
#[test]
fn unix_daemon_survives_client_disconnect_mid_response() {
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use std::time::{Duration, Instant};

    let sock = std::env::temp_dir().join(format!("cggm_abuse_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_cggm"))
        .args(["serve", "--max-jobs", "1", "--socket", sock.to_str().unwrap()])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("failed to start cggm serve --socket");

    let connect = |deadline: Instant| -> UnixStream {
        loop {
            match UnixStream::connect(&sock) {
                Ok(s) => return s,
                Err(e) => {
                    assert!(Instant::now() < deadline, "socket never came up: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    };
    let deadline = Instant::now() + Duration::from_secs(30);

    // Client 1: queue a load + a deliberately slow fit (tight tolerance on
    // a denser problem — many milliseconds of work), then vanish without
    // reading a byte. By the time the daemon writes either response, the
    // peer is long gone and the write is a broken pipe.
    {
        let mut c1 = connect(deadline);
        c1.write_all(
            concat!(
                r#"{"op":"load","id":1,"name":"d","workload":"chain","p":40,"q":40,"n":150,"seed":5}"#,
                "\n",
                r#"{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.2,"tol":0.0000001,"max_iter":300}"#,
                "\n",
            )
            .as_bytes(),
        )
        .expect("client 1 writes its requests");
        // Drop both halves: the daemon's response write hits a dead peer.
    }

    // Client 2: must get a full session — warm registry included.
    let mut c2 = connect(deadline);
    c2.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c2.write_all(
        concat!(
            r#"{"op":"stat","id":3}"#,
            "\n",
            r#"{"op":"shutdown","id":4}"#,
            "\n",
        )
        .as_bytes(),
    )
    .expect("client 2 writes (daemon must still be listening)");
    let mut lines = Vec::new();
    for line in BufReader::new(c2).lines() {
        lines.push(line.expect("client 2 reads responses"));
    }
    assert_eq!(lines.len(), 2, "stat + shutdown answered: {lines:?}");
    for l in &lines {
        let doc = Json::parse(l).expect("valid response JSON");
        assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(true), "{l}");
    }

    let output = child.wait_with_output().expect("daemon exits after shutdown");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "daemon must exit cleanly despite the vanished client\nstderr:\n{stderr}"
    );
    let _ = std::fs::remove_file(&sock);
}

// ---------------------------------------------------------------------------
// Cancel abuse: storms of `cancel` against running, queued, finished, and
// unknown ids. The properties: every cancel gets exactly one structured
// response (`ok` or `not_found`, never a hang or panic), every cancelled
// job's terminal response is a `cancelled`-kind error, the admission
// invariant holds throughout, and quiescence leaves zero reserved bytes.
// Cancel races completion by design, so these tests accept both outcomes
// where the race is real — what they never accept is a leak.
// ---------------------------------------------------------------------------

/// A deliberately long path job: many points at tight tolerance, so there
/// is a wide window in which `cancel` finds it running.
const SLOW_PATH: &str = r#"{"op":"path","id":10,"dataset":"slow","solver":"alt","path_points":24,"tol":0.00000001,"max_iter":400}"#;

fn load_slow(srv: &ServeEngine) {
    let load = srv.request(req(
        r#"{"op":"load","id":890,"name":"slow","workload":"chain","p":24,"q":24,"n":90,"seed":4}"#,
    ));
    assert!(load.is_ok(), "{:?}", load.outcome);
}

/// Drain a reply channel to its terminal responses, dropping progress.
fn terminals(rx: mpsc::Receiver<ServerLine>) -> Vec<Response> {
    rx.into_iter()
        .filter_map(|line| match line {
            ServerLine::Done(resp) => Some(resp),
            ServerLine::Progress(_) => None,
        })
        .collect()
}

/// Cancelling an id the engine has never seen — or one whose job already
/// finished — is a structured `not_found`, not a hang or a panic.
#[test]
fn cancel_of_unknown_or_finished_job_is_not_found() {
    let srv = engine(1, None);
    probe(&srv); // ids 900 (load) and 901 (fit) run to completion
    let unknown = srv.request(req(r#"{"op":"cancel","id":30,"job":12345}"#));
    assert_eq!(unknown.err_kind(), Some(ErrKind::NotFound), "{:?}", unknown.outcome);
    let finished = srv.request(req(r#"{"op":"cancel","id":31,"job":901}"#));
    assert_eq!(finished.err_kind(), Some(ErrKind::NotFound), "{:?}", finished.outcome);
    probe(&srv);
    srv.join();
}

/// Cancel a mid-path job: the cancel answers `ok` (signalled) or
/// `not_found` (lost the race to completion); the job's terminal response
/// is correspondingly a `cancelled`-kind error or a success — and either
/// way the reservation is released and a second cancel is `not_found`.
#[test]
fn cancel_mid_path_frees_reservation_and_double_cancel_is_not_found() {
    let limit = 256 << 20;
    let srv = engine(1, Some(limit));
    load_slow(&srv);
    let (tx, rx) = mpsc::channel::<ServerLine>();
    srv.submit(req(SLOW_PATH), &tx);
    drop(tx);
    // Give the worker time to claim the job (the queue is empty, so the
    // claim is immediate; the path then runs for many poll intervals).
    std::thread::sleep(std::time::Duration::from_millis(100));
    let first = srv.request(req(r#"{"op":"cancel","id":40,"job":10}"#));
    assert!(
        first.is_ok() || first.err_kind() == Some(ErrKind::NotFound),
        "cancel must answer structurally: {:?}",
        first.outcome
    );
    let done = terminals(rx);
    assert_eq!(done.len(), 1, "the path job gets exactly one terminal response");
    if first.is_ok() {
        assert_eq!(
            done[0].err_kind(),
            Some(ErrKind::Cancelled),
            "a signalled job must answer cancelled: {:?}",
            done[0].outcome
        );
    } else {
        assert!(done[0].is_ok(), "not_found means the job finished first");
    }
    // The slot is gone (cancelled or finished): a second cancel of the
    // same id is deterministically not_found.
    let second = srv.request(req(r#"{"op":"cancel","id":41,"job":10}"#));
    assert_eq!(second.err_kind(), Some(ErrKind::NotFound), "{:?}", second.outcome);
    srv.drain();
    assert_eq!(srv.reserved_bytes(), 0, "cancellation leaked a reservation");
    probe(&srv);
    srv.join();
}

/// Cancelling queued jobs reaps them before they ever reserve bytes: each
/// reaped job answers `cancelled while queued` on its own channel, and the
/// jobs that escaped the reap (already running or finished) answer
/// normally — exactly one terminal per submission either way.
#[test]
fn cancelling_queued_jobs_reaps_them_without_reservation() {
    let limit = 256 << 20;
    let srv = engine(1, Some(limit));
    load_slow(&srv);
    // One slow path occupies the single worker...
    let (tx1, rx1) = mpsc::channel::<ServerLine>();
    srv.submit(req(SLOW_PATH), &tx1);
    drop(tx1);
    // ...so these three fits sit queued behind it.
    let (tx2, rx2) = mpsc::channel::<ServerLine>();
    for _ in 0..3 {
        srv.submit(
            req(r#"{"op":"fit","id":11,"dataset":"slow","solver":"alt","lambda":0.5}"#),
            &tx2,
        );
    }
    drop(tx2);
    let reap = srv.request(req(r#"{"op":"cancel","id":50,"job":11}"#));
    if reap.is_ok() {
        let dequeued = reap
            .result()
            .and_then(|r| r.get("dequeued"))
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0);
        assert!(
            (0.0..=3.0).contains(&dequeued),
            "dequeued out of range: {:?}",
            reap.outcome
        );
    } else {
        assert_eq!(reap.err_kind(), Some(ErrKind::NotFound));
    }
    // Unblock the worker and check the terminals.
    let _ = srv.request(req(r#"{"op":"cancel","id":51,"job":10}"#));
    let fit_done = terminals(rx2);
    assert_eq!(fit_done.len(), 3, "every queued fit answered exactly once");
    for resp in &fit_done {
        assert_eq!(resp.id, 11);
        assert!(
            resp.is_ok() || resp.err_kind() == Some(ErrKind::Cancelled),
            "queued fit must finish or cancel cleanly: {:?}",
            resp.outcome
        );
    }
    let path_done = terminals(rx1);
    assert_eq!(path_done.len(), 1);
    srv.drain();
    assert_eq!(srv.reserved_bytes(), 0, "queued-cancel leaked a reservation");
    probe(&srv);
    srv.join();
}

/// The cancel-storm property test: concurrent cancel floods against
/// running, queued, finished, and unknown ids while real work flows, with
/// a monitor asserting `live + reserved ≤ limit` on every observation.
/// Quiescence: zero reserved bytes, and the engine still serves.
#[test]
fn cancel_storms_against_every_id_class_leave_engine_serving() {
    let limit = 256 << 20;
    let srv = engine(2, Some(limit));
    load_slow(&srv);
    probe(&srv); // id 901 is now a *finished* id for the storm to hit

    let stop = AtomicBool::new(false);
    let victim_done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let monitor = scope.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                let live = srv.budget().live();
                let reserved = srv.reserved_bytes();
                assert!(
                    live + reserved <= limit,
                    "budget invariant violated: live {live} + reserved {reserved} > limit {limit}"
                );
                std::thread::yield_now();
            }
        });

        // Victim stream: slow paths under id 10, re-submitted as they die.
        let victim = scope.spawn(|| {
            for _ in 0..3 {
                let (tx, rx) = mpsc::channel::<ServerLine>();
                srv.submit(req(SLOW_PATH), &tx);
                drop(tx);
                let done = terminals(rx);
                assert_eq!(done.len(), 1);
                assert!(
                    done[0].is_ok() || done[0].err_kind() == Some(ErrKind::Cancelled),
                    "victim terminal must be ok or cancelled: {:?}",
                    done[0].outcome
                );
            }
            victim_done.store(true, Ordering::Relaxed);
        });

        // Queued stream: quick fits under id 11 on the second worker.
        let queued = scope.spawn(|| {
            let (tx, rx) = mpsc::channel::<ServerLine>();
            for _ in 0..6 {
                srv.submit(
                    req(r#"{"op":"fit","id":11,"dataset":"slow","solver":"alt","lambda":0.5}"#),
                    &tx,
                );
            }
            drop(tx);
            let done = terminals(rx);
            assert_eq!(done.len(), 6, "every fit answered exactly once");
            for resp in &done {
                assert!(
                    resp.is_ok() || resp.err_kind() == Some(ErrKind::Cancelled),
                    "{:?}",
                    resp.outcome
                );
            }
        });

        // Three cancel-storm threads hitting every id class at once. They
        // run at least 40 rounds each, then keep storming until the
        // victim's last path has been answered — so no slow path is left
        // to run 24 points to completion un-cancelled.
        let storms: Vec<_> = (0..3)
            .map(|t| {
                let victim_done = &victim_done;
                scope.spawn(move || {
                    let mut k = 0u64;
                    loop {
                        if k >= 40 && victim_done.load(Ordering::Relaxed) {
                            break;
                        }
                        let target = match k % 4 {
                            0 => 10,    // probably running
                            1 => 11,    // probably queued
                            2 => 901,   // finished long ago
                            _ => 77777, // never existed
                        };
                        let resp = srv.request(req(&format!(
                            r#"{{"op":"cancel","id":{},"job":{target}}}"#,
                            600 + t * 1000 + k,
                        )));
                        assert!(
                            resp.is_ok() || resp.err_kind() == Some(ErrKind::NotFound),
                            "cancel must never fail unstructurally: {:?}",
                            resp.outcome
                        );
                        k += 1;
                        std::thread::yield_now();
                    }
                })
            })
            .collect();

        for s in storms {
            s.join().unwrap();
        }
        victim.join().unwrap();
        queued.join().unwrap();
        stop.store(true, Ordering::Relaxed);
        monitor.join().unwrap();
    });

    srv.drain();
    assert_eq!(srv.reserved_bytes(), 0, "cancel storm leaked a reservation");
    assert!(srv.budget().live() <= limit);
    probe(&srv);
    srv.join();
}

/// Hostile `append` payload corpus (`tests/fixtures/hostile/append/`):
/// every fixture line is answered structurally — `*.err.*` with a typed
/// error kind, `*.ok.*` accepted — and the same connection serves a `stat`
/// right after each payload. An inline payload over [`MAX_APPEND_ROWS`]
/// rows (built programmatically; it would be unreadable checked in) is a
/// `parse` error naming the per-request limit.
#[test]
fn hostile_append_fixtures_answer_structurally_and_connection_survives() {
    let srv = engine(1, None);
    probe(&srv); // the fixtures target "probe" (p = 10, q = 10)
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("hostile")
        .join("append");
    let mut paths: Vec<_> = std::fs::read_dir(&dir)
        .expect("hostile append fixture dir exists")
        .map(|e| e.unwrap().path())
        .collect();
    paths.sort();
    let mut seen = 0usize;
    for path in paths {
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if !name.ends_with(".jsonl") {
            continue;
        }
        seen += 1;
        let expect_err = name.contains(".err.");
        assert!(
            expect_err || name.contains(".ok."),
            "fixture {name} must declare .err. or .ok."
        );
        let mut input = std::fs::read(&path).unwrap();
        input.extend_from_slice(br#"{"op":"stat","id":960}"#);
        input.push(b'\n');
        let lines = session(&srv, input);
        assert_eq!(lines.len(), 2, "{name}: fixture line + stat both answered");
        let ok = lines[0].get("ok").and_then(|v| v.as_bool());
        if expect_err {
            assert_eq!(
                ok,
                Some(false),
                "{name}: hostile payload was accepted: {}",
                lines[0].to_string()
            );
            let kind = lines[0]
                .get("error")
                .and_then(|e| e.get("kind"))
                .and_then(|k| k.as_str());
            assert!(kind.is_some(), "{name}: error must carry a typed kind");
        } else {
            assert_eq!(
                ok,
                Some(true),
                "{name}: valid append was rejected: {}",
                lines[0].to_string()
            );
        }
        assert_eq!(
            lines[1].get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "{name}: the connection survives the payload"
        );
    }
    assert!(seen >= 8, "hostile append fixture set went missing ({seen} files)");

    // One row over the inline cap: rejected at parse, connection intact.
    let mut big = String::from(r#"{"op":"append","id":961,"dataset":"probe","rows":["#);
    for i in 0..=MAX_APPEND_ROWS {
        if i > 0 {
            big.push(',');
        }
        big.push_str(r#"{"x":[0],"y":[0]}"#);
    }
    big.push_str("]}\n");
    let mut input = big.into_bytes();
    input.extend_from_slice(br#"{"op":"stat","id":962}"#);
    input.push(b'\n');
    let lines = session(&srv, input);
    assert_eq!(lines.len(), 2);
    assert!(is_parse_err(&lines[0]), "{}", lines[0].to_string());
    let msg = lines[0]
        .get("error")
        .and_then(|e| e.get("message"))
        .and_then(|m| m.as_str())
        .unwrap_or("");
    assert!(
        msg.contains("per-request limit"),
        "over-cap error names the limit: {msg}"
    );
    assert_eq!(lines[1].get("ok").and_then(|v| v.as_bool()), Some(true));
    probe(&srv);
    srv.join();
}

/// Concurrent append vs refit vs cancel: an appender streaming valid rows
/// (every fourth deliberately shape-hostile), a refitter folding the
/// sliding window, and a cancel storm against the refit id — all under the
/// budget monitor. Afterwards: no reserved bytes leaked, a final refit
/// shows the 90-sample window cap held, and the engine still serves.
#[test]
fn concurrent_append_refit_cancel_holds_window_and_budget() {
    let limit = 256 << 20;
    let srv = engine(2, Some(limit));
    load_slow(&srv);
    // Seed the registry's cached model so refits have a warm-start source.
    let seed = srv.request(req(
        r#"{"op":"fit","id":891,"dataset":"slow","solver":"alt","lambda":0.5,"max_iter":60}"#,
    ));
    assert!(seed.is_ok(), "{:?}", seed.outcome);

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let monitor = scope.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                let live = srv.budget().live();
                let reserved = srv.reserved_bytes();
                assert!(
                    live + reserved <= limit,
                    "budget invariant violated: live {live} + reserved {reserved} > limit {limit}"
                );
                std::thread::yield_now();
            }
        });

        let appender = scope.spawn(|| {
            for round in 0..12u32 {
                // Every fourth row is shape-hostile (5 of 24 x-values).
                let width = if round % 4 == 3 { 5 } else { 24 };
                let xs = vec!["0.25"; width].join(",");
                let ys = vec!["-0.5"; 24].join(",");
                let resp = srv.request(req(&format!(
                    r#"{{"op":"append","id":20,"dataset":"slow","rows":[{{"x":[{xs}],"y":[{ys}]}}]}}"#
                )));
                if width == 24 {
                    assert!(resp.is_ok(), "valid append must land: {:?}", resp.outcome);
                } else {
                    assert_eq!(
                        resp.err_kind(),
                        Some(ErrKind::Parse),
                        "shape-hostile append must be a typed parse error: {:?}",
                        resp.outcome
                    );
                }
            }
        });

        let refitter = scope.spawn(|| {
            for _ in 0..8 {
                let resp = srv.request(req(
                    r#"{"op":"refit","id":21,"dataset":"slow","solver":"alt","lambda":0.5,"max_iter":120,"window":90}"#,
                ));
                assert!(
                    resp.is_ok() || resp.err_kind() == Some(ErrKind::Cancelled),
                    "refit terminal must be ok or cancelled: {:?}",
                    resp.outcome
                );
            }
        });

        let canceller = scope.spawn(|| {
            for _ in 0..40 {
                let resp = srv.request(req(r#"{"op":"cancel","id":22,"job":21}"#));
                assert!(
                    resp.is_ok() || resp.err_kind() == Some(ErrKind::NotFound),
                    "cancel must answer structurally: {:?}",
                    resp.outcome
                );
                std::thread::yield_now();
            }
        });

        appender.join().unwrap();
        refitter.join().unwrap();
        canceller.join().unwrap();
        stop.store(true, Ordering::Relaxed);
        monitor.join().unwrap();
    });

    srv.drain();
    assert_eq!(srv.reserved_bytes(), 0, "append/refit/cancel leaked a reservation");
    // A quiescent refit folds any rows the storm left buffered; the window
    // cap must have held through every interleaving.
    let last = srv.request(req(
        r#"{"op":"refit","id":23,"dataset":"slow","solver":"alt","lambda":0.5,"max_iter":120,"window":90}"#,
    ));
    assert!(last.is_ok(), "{:?}", last.outcome);
    let rres = last.result().unwrap();
    assert_eq!(
        rres.get("n").and_then(|v| v.as_f64()),
        Some(90.0),
        "window occupancy stayed at the cap"
    );
    probe(&srv);
    srv.join();
}
