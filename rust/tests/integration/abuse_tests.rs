//! Structured-abuse property suite for the untrusted-input surface: a live
//! [`ServeEngine`] hammered with malformed, oversized, duplicate-id, and
//! immediately-disconnecting clients. The properties under every
//! interleaving:
//!
//! - the admission invariant `budget().live() + reserved_bytes() ≤ limit`
//!   holds while abuse is in flight (the estimates are deliberately
//!   conservative for these tiny probe datasets, so the strict form is
//!   sound at this limit);
//! - the daemon answers a well-formed probe after each abuse round;
//! - no request is silently dropped — every line a client gets onto the
//!   wire is answered exactly once (or the client observably lost its
//!   connection).
//!
//! The three seed-crash repros live here too: a deep-nesting line (stack
//! overflow abort on the seed), hostile `load` dimensions (`{"p":-1}` made
//! a 0-dimensional dataset, `{"p":1e300}` a `usize::MAX` allocation), and
//! the unix-socket client that vanishes mid-response (daemon death on the
//! seed).

use cggm::coordinator::RunConfig;
use cggm::gemm::native::NativeGemm;
use cggm::serve::{serve_connection, ErrKind, Request, Response, ServeEngine};
use cggm::serve::MAX_REQUEST_LINE_BYTES;
use cggm::util::json::Json;
use std::io::Cursor;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

fn engine(max_jobs: usize, budget: Option<usize>) -> ServeEngine {
    let cfg = RunConfig {
        serve_max_jobs: max_jobs,
        serve_budget: budget,
        ..RunConfig::default()
    };
    ServeEngine::new(cfg, Arc::new(NativeGemm::new(1)))
}

fn req(line: &str) -> Request {
    Request::parse_line(line).expect("test request must parse")
}

/// Run one in-process JSONL session over byte buffers and hand back the
/// parsed response lines (every line the daemon wrote must be valid JSON).
fn session(srv: &ServeEngine, input: Vec<u8>) -> Vec<Json> {
    let mut out: Vec<u8> = Vec::new();
    serve_connection(srv, Cursor::new(input), &mut out).expect("Vec writer cannot fail");
    String::from_utf8(out)
        .expect("responses are UTF-8")
        .lines()
        .map(|l| Json::parse(l).expect("every response line is valid JSON"))
        .collect()
}

fn is_parse_err(doc: &Json) -> bool {
    doc.get("ok").and_then(|v| v.as_bool()) == Some(false)
        && doc
            .get("error")
            .and_then(|e| e.get("kind"))
            .and_then(|k| k.as_str())
            == Some("parse")
}

const PROBE_LOAD: &str =
    r#"{"op":"load","id":900,"name":"probe","workload":"chain","p":10,"q":10,"n":50,"seed":3}"#;
const PROBE_FIT: &str =
    r#"{"op":"fit","id":901,"dataset":"probe","solver":"alt","lambda":0.5,"max_iter":30}"#;

/// A well-formed load + fit must succeed on this engine right now.
fn probe(srv: &ServeEngine) {
    let load = srv.request(req(PROBE_LOAD));
    assert!(load.is_ok(), "probe load failed: {:?}", load.outcome);
    let fit = srv.request(req(PROBE_FIT));
    assert!(fit.is_ok(), "probe fit failed: {:?}", fit.outcome);
}

/// Seed-crash repro 1: a line of ~100k `[` overflowed the recursive-descent
/// parser's stack — a process abort, unreachable by the engine's panic
/// isolation because it never reaches a job. Now: one `parse` error
/// response, and the same connection keeps serving.
#[test]
fn deep_nesting_line_is_answered_not_fatal() {
    let srv = engine(1, None);
    let mut input = Vec::new();
    input.extend_from_slice("[".repeat(100_000).as_bytes());
    input.push(b'\n');
    input.extend_from_slice(br#"{"op":"stat","id":2}"#);
    input.push(b'\n');
    let lines = session(&srv, input);
    assert_eq!(lines.len(), 2, "both lines answered");
    assert!(is_parse_err(&lines[0]), "bomb gets a parse error: {}", lines[0].to_string());
    assert_eq!(
        lines[1].get("ok").and_then(|v| v.as_bool()),
        Some(true),
        "the connection survives the bomb"
    );
    probe(&srv);
    srv.join();
}

/// An over-cap request line is answered with a `parse` error naming the
/// cap, its remainder is discarded, and the *next* line is served
/// normally. Invalid UTF-8 likewise.
#[test]
fn oversized_and_non_utf8_lines_are_recoverable() {
    let srv = engine(1, None);
    let mut input = Vec::new();
    // 2 MiB of junk on one line — twice the cap.
    input.extend_from_slice(&vec![b'a'; 2 * MAX_REQUEST_LINE_BYTES]);
    input.push(b'\n');
    // A line that is not UTF-8 at all.
    input.extend_from_slice(&[0xff, 0xfe, 0x80, b'\n']);
    // A well-formed request after both.
    input.extend_from_slice(br#"{"op":"stat","id":3}"#);
    input.push(b'\n');
    let lines = session(&srv, input);
    assert_eq!(lines.len(), 3, "all three lines answered");
    assert!(is_parse_err(&lines[0]));
    let msg = lines[0]
        .get("error")
        .and_then(|e| e.get("message"))
        .and_then(|m| m.as_str())
        .unwrap_or("");
    assert!(
        msg.contains(&MAX_REQUEST_LINE_BYTES.to_string()),
        "over-long error names the cap: {msg}"
    );
    assert!(is_parse_err(&lines[1]), "non-UTF-8 is a parse error");
    assert_eq!(lines[2].get("ok").and_then(|v| v.as_bool()), Some(true));
    probe(&srv);
    srv.join();
}

/// Seed-crash repro 2: hostile `load` dimensions. On the seed, the
/// saturating cast turned `{"p":-1}` into a 0-dimensional dataset and
/// `{"p":1e300}` into a `usize::MAX` allocation request. Both must be
/// clean `parse` rejects with the engine still serving.
#[test]
fn hostile_load_dimensions_are_clean_rejects() {
    let srv = engine(1, None);
    for line in [
        r#"{"op":"load","id":1,"name":"h","workload":"chain","p":-1,"q":10,"n":50}"#,
        r#"{"op":"load","id":2,"name":"h","workload":"chain","p":1e300,"q":10,"n":50}"#,
        r#"{"op":"load","id":3,"name":"h","workload":"chain","p":10,"q":2.5,"n":50}"#,
    ] {
        assert!(
            Request::parse_line(line).is_err(),
            "hostile dims must not parse: {line}"
        );
    }
    // Over the wire the reject is a structured parse-kind error response.
    let mut input = Vec::new();
    input.extend_from_slice(
        br#"{"op":"load","id":1,"name":"h","workload":"chain","p":-1,"q":10,"n":50}"#,
    );
    input.push(b'\n');
    let lines = session(&srv, input);
    assert_eq!(lines.len(), 1);
    assert!(is_parse_err(&lines[0]));
    // Nothing named "h" was created, and the engine still serves.
    let stat = srv.request(req(r#"{"op":"fit","id":4,"dataset":"h","lambda":0.5}"#));
    assert_eq!(stat.err_kind(), Some(ErrKind::NotFound));
    probe(&srv);
    srv.join();
}

/// Duplicate ids are the client's problem, not the engine's: every
/// submitted request gets exactly one response, ids echoed verbatim.
#[test]
fn duplicate_ids_each_get_exactly_one_response() {
    let srv = engine(2, None);
    let (tx, rx) = mpsc::channel::<Response>();
    let n = 16;
    for _ in 0..n {
        srv.submit(req(r#"{"op":"stat","id":7}"#), &tx);
    }
    drop(tx);
    let responses: Vec<Response> = rx.iter().collect();
    assert_eq!(responses.len(), n, "one response per submission");
    for r in &responses {
        assert_eq!(r.id, 7);
        assert!(r.is_ok());
    }
    srv.join();
}

/// A client whose writer dies mid-session (the in-process stand-in for a
/// disconnecting socket peer): `serve_connection` reports the I/O error,
/// but the engine — and every other client — is untouched.
struct DyingWriter {
    writes: usize,
}

impl std::io::Write for DyingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.writes == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "peer vanished",
            ));
        }
        self.writes -= 1;
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// The tentpole property test: ≥ 3 concurrent abusive clients — a
/// malformed/hostile-dimension flood, an oversized-line + duplicate-id
/// flood, and an immediately-disconnecting client — while a monitor
/// asserts the budget invariant on every observation. After the abuse,
/// the engine serves a well-formed probe and nothing leaked.
#[test]
fn concurrent_abusive_clients_leave_the_engine_serving() {
    let limit = 256 << 20; // generous headroom: estimates ≪ limit
    let srv = engine(2, Some(limit));
    // Resident warm data so abuse runs against a non-trivial registry.
    probe(&srv);

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        // Monitor: the admission invariant under every interleaving.
        let monitor = scope.spawn(|| {
            let mut observations = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let live = srv.budget().live();
                let reserved = srv.reserved_bytes();
                assert!(
                    live + reserved <= limit,
                    "budget invariant violated: live {live} + reserved {reserved} > limit {limit}"
                );
                observations += 1;
                std::thread::yield_now();
            }
            assert!(observations > 0);
        });

        // Client 1: malformed + hostile-dimension flood, interleaved with
        // valid duplicate-id loads of the same name (admission races).
        let flood = scope.spawn(|| {
            let mut input = Vec::new();
            for k in 0..40 {
                match k % 5 {
                    0 => input.extend_from_slice(b"not json at all"),
                    1 => input.extend_from_slice(
                        br#"{"op":"load","id":5,"name":"x","workload":"chain","p":-1,"q":8,"n":40}"#,
                    ),
                    2 => input.extend_from_slice(
                        br#"{"op":"load","id":5,"name":"x","workload":"chain","p":1e300,"q":8,"n":40}"#,
                    ),
                    3 => input.extend_from_slice(
                        br#"{"op":"load","id":5,"name":"x","workload":"chain","p":8,"q":8,"n":40,"seed":2}"#,
                    ),
                    _ => input.extend_from_slice(br#"{"op":"fit","id":5,"dataset":"x","lambda":0.6}"#),
                }
                input.push(b'\n');
            }
            let lines = session(&srv, input);
            assert_eq!(lines.len(), 40, "every flood line answered");
        });

        // Client 2: oversized lines and deep nesting between valid stats.
        let bomber = scope.spawn(|| {
            let mut input = Vec::new();
            for k in 0..6 {
                if k % 2 == 0 {
                    input.extend_from_slice(&vec![b'{'; 200_000]);
                } else {
                    input.extend_from_slice(&vec![b'a'; MAX_REQUEST_LINE_BYTES + 1]);
                }
                input.push(b'\n');
                input.extend_from_slice(br#"{"op":"stat","id":6}"#);
                input.push(b'\n');
            }
            let lines = session(&srv, input);
            assert_eq!(lines.len(), 12, "every bomber line answered");
            for (k, line) in lines.iter().enumerate() {
                if k % 2 == 0 {
                    assert!(is_parse_err(line), "bomb line {k}: {}", line.to_string());
                } else {
                    assert_eq!(line.get("ok").and_then(|v| v.as_bool()), Some(true));
                }
            }
        });

        // Client 3 (× several rounds): connects, queues real work, and
        // vanishes before reading any response.
        let vanisher = scope.spawn(|| {
            for _ in 0..4 {
                let mut input = Vec::new();
                input.extend_from_slice(
                    br#"{"op":"load","id":8,"name":"v","workload":"chain","p":9,"q":9,"n":40}"#,
                );
                input.push(b'\n');
                input.extend_from_slice(br#"{"op":"fit","id":9,"dataset":"v","lambda":0.5}"#);
                input.push(b'\n');
                let mut w = DyingWriter { writes: 0 };
                let res = serve_connection(&srv, Cursor::new(input), &mut w);
                assert!(res.is_err(), "the dead writer's error is reported");
            }
        });

        flood.join().unwrap();
        bomber.join().unwrap();
        vanisher.join().unwrap();
        // A well-formed probe succeeds after the abuse, before teardown.
        probe(&srv);
        stop.store(true, Ordering::Relaxed);
        monitor.join().unwrap();
    });

    // Quiescent: no reserved bytes leaked by any interleaving.
    srv.drain();
    assert_eq!(srv.reserved_bytes(), 0, "reservation leak");
    assert!(srv.budget().live() <= limit);
    probe(&srv);
    srv.join();
}

/// Seed-crash repro 3, end to end over a real unix socket: client 1 queues
/// work and disconnects without reading; on the seed the daemon died of the
/// broken pipe (and unlinked its socket). Now it logs, survives, and serves
/// client 2.
#[cfg(unix)]
#[test]
fn unix_daemon_survives_client_disconnect_mid_response() {
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use std::time::{Duration, Instant};

    let sock = std::env::temp_dir().join(format!("cggm_abuse_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_cggm"))
        .args(["serve", "--max-jobs", "1", "--socket", sock.to_str().unwrap()])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("failed to start cggm serve --socket");

    let connect = |deadline: Instant| -> UnixStream {
        loop {
            match UnixStream::connect(&sock) {
                Ok(s) => return s,
                Err(e) => {
                    assert!(Instant::now() < deadline, "socket never came up: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    };
    let deadline = Instant::now() + Duration::from_secs(30);

    // Client 1: queue a load + a deliberately slow fit (tight tolerance on
    // a denser problem — many milliseconds of work), then vanish without
    // reading a byte. By the time the daemon writes either response, the
    // peer is long gone and the write is a broken pipe.
    {
        let mut c1 = connect(deadline);
        c1.write_all(
            concat!(
                r#"{"op":"load","id":1,"name":"d","workload":"chain","p":40,"q":40,"n":150,"seed":5}"#,
                "\n",
                r#"{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.2,"tol":0.0000001,"max_iter":300}"#,
                "\n",
            )
            .as_bytes(),
        )
        .expect("client 1 writes its requests");
        // Drop both halves: the daemon's response write hits a dead peer.
    }

    // Client 2: must get a full session — warm registry included.
    let mut c2 = connect(deadline);
    c2.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c2.write_all(
        concat!(
            r#"{"op":"stat","id":3}"#,
            "\n",
            r#"{"op":"shutdown","id":4}"#,
            "\n",
        )
        .as_bytes(),
    )
    .expect("client 2 writes (daemon must still be listening)");
    let mut lines = Vec::new();
    for line in BufReader::new(c2).lines() {
        lines.push(line.expect("client 2 reads responses"));
    }
    assert_eq!(lines.len(), 2, "stat + shutdown answered: {lines:?}");
    for l in &lines {
        let doc = Json::parse(l).expect("valid response JSON");
        assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(true), "{l}");
    }

    let output = child.wait_with_output().expect("daemon exits after shutdown");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "daemon must exit cleanly despite the vanished client\nstderr:\n{stderr}"
    );
    let _ = std::fs::remove_file(&sock);
}
