//! CLI-level plumbing: config files driving fits, dataset round-trips, and
//! the compiled `cggm` binary run as a subprocess (the acceptance path for
//! `cggm cv --folds 5`).

use super::common::chain_opts;
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve, SolverKind};
use cggm::util::json::Json;
use std::process::Command;

/// Run-config file → solver options → fit, end to end through the
/// coordinator (the CLI's code path).
#[test]
fn config_file_drives_a_fit() {
    let tmp = std::env::temp_dir().join("cggm_it_cfg.json");
    std::fs::write(
        &tmp,
        r#"{"workload": "chain", "p": 30, "q": 30, "n": 60, "seed": 3,
            "solver": "bcd", "lambda": 0.4, "max_iter": 40,
            "mem_budget": "1MB"}"#,
    )
    .unwrap();
    let cfg = cggm::coordinator::RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
    let prob = cggm::coordinator::generate_problem(cfg.workload, cfg.p, cfg.q, cfg.n, cfg.seed);
    let opts = cfg.solve_options();
    let eng = NativeGemm::new(1);
    let (sum, _) = cggm::coordinator::run_fit(cfg.solver, &prob, &opts, &eng, None).unwrap();
    assert!(sum.converged);
    assert!(sum.peak_bytes <= 1 << 20);
    let _ = std::fs::remove_file(tmp);
}

/// Dataset save/load through the coordinator feeds a solve identically.
#[test]
fn saved_dataset_reproduces_fit() {
    let prob = datagen::chain::generate(20, 20, 60, 8);
    let tmp = std::env::temp_dir().join("cggm_it_ds.bin");
    cggm::coordinator::save_dataset(&prob.data, &tmp).unwrap();
    let loaded = cggm::coordinator::load_dataset(&tmp).unwrap();
    let eng = NativeGemm::new(1);
    let opts = chain_opts(0.4);
    let a = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
    let b = solve(SolverKind::AltNewtonCd, &loaded, &opts, &eng).unwrap();
    assert_eq!(a.trace.final_f(), b.trace.final_f());
    let _ = std::fs::remove_file(tmp);
}

/// Acceptance: the compiled binary's `cggm cv --folds 5` selects a λ on a
/// synthetic chain problem, emits well-formed JSON (CV curve + refit), and
/// exits 0.
#[test]
fn cggm_cv_subcommand_selects_a_lambda() {
    let out_dir = std::env::temp_dir().join("cggm_cli_cv_out");
    let output = Command::new(env!("CARGO_BIN_EXE_cggm"))
        .args([
            "cv",
            "--workload",
            "chain",
            "--p",
            "12",
            "--q",
            "12",
            "--n",
            "120",
            "--seed",
            "5",
            "--solver",
            "alt",
            "--folds",
            "5",
            "--cv-threads",
            "2",
            "--path-points",
            "4",
            "--path-min-ratio",
            "0.1",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("failed to run the cggm binary");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "cggm cv failed\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    let doc = Json::parse(&stdout).expect("cv output must be JSON");
    assert_eq!(
        doc.get("folds").and_then(|v| v.as_usize()),
        Some(5),
        "bad folds in {stdout}"
    );
    let best_l = doc
        .get("best_lambda_l")
        .and_then(|v| v.as_f64())
        .expect("best_lambda_l");
    assert!(best_l.is_finite() && best_l > 0.0);
    let points = doc.get("points").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(points.len(), 4);
    // The refit ran and reports its path.
    let refit = doc.get("refit").expect("refit block");
    assert!(refit.get("points").is_some(), "refit missing in {stdout}");
    // The CV curve CSV landed in --out.
    let csv = out_dir.join("cv_alt_newton_cd.csv");
    let text = std::fs::read_to_string(&csv).expect("cv csv written");
    assert!(text.starts_with("lambda_l,lambda_t,mean_nll"));
    assert_eq!(text.lines().count(), 1 + 4);
    let _ = std::fs::remove_dir_all(out_dir);
}

/// `cggm path --checkpoint` writes a resumable JSONL sweep; truncating it
/// and rerunning with `--resume` carries the surviving points and refits the
/// rest, reproducing the original objectives exactly.
#[test]
fn cggm_path_checkpoint_resume_roundtrip() {
    let out_dir = std::env::temp_dir().join("cggm_cli_ckpt_out");
    let ck = std::env::temp_dir().join("cggm_cli_ckpt.jsonl");
    let _ = std::fs::remove_file(&ck);
    let run = |resume: bool| {
        let mut args = vec![
            "path".to_string(),
            "--workload".into(),
            "chain".into(),
            "--p".into(),
            "10".into(),
            "--q".into(),
            "10".into(),
            "--n".into(),
            "60".into(),
            "--solver".into(),
            "alt".into(),
            "--path-points".into(),
            "4".into(),
            "--out".into(),
            out_dir.to_str().unwrap().into(),
        ];
        if resume {
            args.push("--resume".into());
        } else {
            args.push("--checkpoint".into());
        }
        args.push(ck.to_str().unwrap().to_string());
        Command::new(env!("CARGO_BIN_EXE_cggm"))
            .args(&args)
            .output()
            .expect("failed to run the cggm binary")
    };
    let first = run(false);
    assert!(
        first.status.success(),
        "checkpointed path failed: {}",
        String::from_utf8_lossy(&first.stderr)
    );
    let full_doc = Json::parse(&String::from_utf8_lossy(&first.stdout)).unwrap();
    assert_eq!(
        full_doc.get("resumed_points").and_then(|v| v.as_usize()),
        Some(0)
    );
    // "Interrupt": keep the header and the first two point lines.
    let text = std::fs::read_to_string(&ck).expect("checkpoint written");
    assert_eq!(text.lines().count(), 1 + 4, "header + 4 points");
    let prefix: String = text.lines().take(3).map(|l| format!("{l}\n")).collect();
    std::fs::write(&ck, prefix).unwrap();
    // Resume: two points carried, two refitted, same objectives.
    let second = run(true);
    assert!(
        second.status.success(),
        "resumed path failed: {}",
        String::from_utf8_lossy(&second.stderr)
    );
    let doc = Json::parse(&String::from_utf8_lossy(&second.stdout)).unwrap();
    assert_eq!(doc.get("resumed_points").and_then(|v| v.as_usize()), Some(2));
    let full_points = full_doc.get("points").and_then(|v| v.as_arr()).unwrap();
    let points = doc.get("points").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(points.len(), 4);
    for (a, b) in full_points.iter().zip(points) {
        let f = |v: &Json| v.get("f").and_then(|x| x.as_f64()).unwrap();
        assert!(
            (f(a) - f(b)).abs() <= 1e-8 * f(a).abs().max(1.0),
            "resumed objective diverged"
        );
    }
    let _ = std::fs::remove_file(&ck);
    let _ = std::fs::remove_dir_all(out_dir);
}

/// Acceptance smoke: a `cggm serve` stdio session — load → fit → fit
/// (warm) → stat → evict → shutdown. The second fit must report the
/// registry hit, the warm start, and zero statistic recomputation.
#[test]
fn cggm_serve_stdio_session_smoke() {
    use std::io::Write;
    let mut child = Command::new(env!("CARGO_BIN_EXE_cggm"))
        .args(["serve", "--max-jobs", "1"])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("failed to start cggm serve");
    let script = concat!(
        r#"{"op":"load","id":1,"name":"d","workload":"chain","p":12,"q":12,"n":60,"seed":4}"#,
        "\n",
        r#"{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.4}"#,
        "\n",
        r#"{"op":"fit","id":3,"dataset":"d","solver":"alt","lambda":0.4}"#,
        "\n",
        r#"{"op":"stat","id":4}"#,
        "\n",
        r#"{"op":"evict","id":5,"dataset":"d"}"#,
        "\n",
        r#"{"op":"shutdown","id":6}"#,
        "\n",
    );
    child
        .stdin
        .take()
        .unwrap()
        .write_all(script.as_bytes())
        .expect("write requests");
    let output = child.wait_with_output().expect("serve session");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "serve exited nonzero\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    let lines: Vec<Json> = stdout
        .lines()
        .map(|l| Json::parse(l).expect("every response line is JSON"))
        .collect();
    assert_eq!(lines.len(), 6, "one response per request: {stdout}");
    // One worker → strict FIFO → responses arrive in request order.
    for (k, line) in lines.iter().enumerate() {
        assert_eq!(line.get("id").and_then(|v| v.as_usize()), Some(k + 1));
        assert_eq!(
            line.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "request {} failed: {stdout}",
            k + 1
        );
    }
    let warm_fit = lines[2].get("result").expect("fit result");
    assert_eq!(
        warm_fit.get("warm_started").and_then(|v| v.as_bool()),
        Some(true),
        "second fit must warm-start: {stdout}"
    );
    assert_eq!(
        warm_fit.get("stat_computes").and_then(|v| v.as_f64()),
        Some(0.0),
        "second fit must not recompute statistics: {stdout}"
    );
    let registry = lines[3]
        .get("result")
        .and_then(|r| r.get("registry"))
        .expect("stat registry block");
    assert_eq!(
        registry.get("hits").and_then(|v| v.as_usize()),
        Some(2),
        "both fits hit the registry: {stdout}"
    );
    assert!(
        lines[4]
            .get("result")
            .and_then(|r| r.get("freed_bytes"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
            > 0.0,
        "evict frees bytes: {stdout}"
    );
}

/// `cggm batch` runs a manifest through the serve engine and emits one
/// ordered JSONL response per job.
#[test]
fn cggm_batch_manifest_smoke() {
    let manifest = std::env::temp_dir().join("cggm_cli_batch.json");
    std::fs::write(
        &manifest,
        r#"{"defaults": {"solver": "alt", "tol": 0.001},
           "jobs": [
             {"op": "load", "name": "d", "workload": "chain",
              "p": 10, "q": 10, "n": 60, "seed": 6},
             {"op": "fit", "dataset": "d", "lambda": 0.5},
             {"op": "fit", "dataset": "d", "lambda": 0.3},
             {"op": "stat"}
           ]}"#,
    )
    .unwrap();
    // One worker keeps the fit order deterministic (the second fit must
    // find the first's cached model).
    let output = Command::new(env!("CARGO_BIN_EXE_cggm"))
        .args(["batch", manifest.to_str().unwrap(), "--max-jobs", "1"])
        .output()
        .expect("failed to run cggm batch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "batch failed\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    let lines: Vec<Json> = stdout
        .lines()
        .map(|l| Json::parse(l).expect("batch output is JSONL"))
        .collect();
    assert_eq!(lines.len(), 4);
    for (k, line) in lines.iter().enumerate() {
        assert_eq!(line.get("id").and_then(|v| v.as_usize()), Some(k + 1));
        assert_eq!(line.get("ok").and_then(|v| v.as_bool()), Some(true));
    }
    // The two fits ran against one warm context: the second reports a
    // cached-model warm start.
    assert_eq!(
        lines[2]
            .get("result")
            .and_then(|r| r.get("warm_model_reused"))
            .and_then(|v| v.as_bool()),
        Some(true),
        "{stdout}"
    );
    let _ = std::fs::remove_file(manifest);
}

/// `cggm path` honors `--screen full` (no screened points in the JSON).
#[test]
fn cggm_path_subcommand_screen_flag() {
    let out_dir = std::env::temp_dir().join("cggm_cli_path_out");
    let output = Command::new(env!("CARGO_BIN_EXE_cggm"))
        .args([
            "path",
            "--workload",
            "chain",
            "--p",
            "10",
            "--q",
            "10",
            "--n",
            "60",
            "--solver",
            "alt",
            "--path-points",
            "3",
            "--screen",
            "full",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("failed to run the cggm binary");
    assert!(output.status.success());
    let stdout = String::from_utf8_lossy(&output.stdout);
    let doc = Json::parse(&stdout).expect("path output must be JSON");
    assert_eq!(
        doc.get("total_kkt_scans").and_then(|v| v.as_f64()),
        Some(0.0),
        "--screen full must disable strong-rule scans: {stdout}"
    );
    let _ = std::fs::remove_dir_all(out_dir);
}
