//! Warm-started λ-path behavior and the golden-path regression.

use super::common::chain_golden;
use cggm::coordinator::{fit_path, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{SolveOptions, SolverKind};
use cggm::util::json::Json;
use std::path::PathBuf;

/// Satellite: on a 2-point λ path, the warm-started second solve converges
/// in at most the cold-start iteration count and reaches the same objective
/// within the stopping tolerance.
#[test]
fn warm_start_beats_cold_start_on_a_two_point_path() {
    let prob = datagen::chain::generate(20, 20, 100, 11);
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 100,
        ..Default::default()
    };
    let grid = vec![(0.5, 0.5), (0.25, 0.25)];
    let mk = |warm_start: bool| PathOptions {
        lambdas: Some(grid.clone()),
        warm_start,
        ..Default::default()
    };
    let warm = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &mk(true), &eng).unwrap();
    let cold = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &mk(false), &eng).unwrap();
    assert_eq!(warm.points.len(), 2);
    assert!(warm.points[1].converged && cold.points[1].converged);
    assert!(
        warm.points[1].iters <= cold.points[1].iters,
        "warm {} iters vs cold {} iters",
        warm.points[1].iters,
        cold.points[1].iters
    );
    let (fw, fc) = (warm.points[1].f, cold.points[1].f);
    assert!(
        (fw - fc).abs() <= base.tol * fc.abs().max(1.0),
        "objectives diverged: warm {fw} vs cold {fc}"
    );
    // The first point is identical either way (no warm start to apply yet).
    assert_eq!(warm.points[0].iters, cold.points[0].iters);
}

/// Where the golden record lives, relative to the crate root (checked in;
/// regenerate with `CGGM_REGEN_GOLDEN=1 cargo test golden_path`).
fn golden_path_file() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("path_chain_p20_q10.json")
}

fn golden_path_run() -> cggm::coordinator::PathResult {
    let prob = chain_golden(); // p=20, q=10, n=80, seed 7
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 100,
        ..Default::default()
    };
    let popts = PathOptions {
        points: 5,
        min_ratio: 0.1,
        ..Default::default() // warm starts + strong screening: the defaults
    };
    fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &eng).unwrap()
}

fn golden_record(res: &cggm::coordinator::PathResult) -> Json {
    Json::obj(vec![(
        "points",
        Json::arr(res.points.iter().map(|p| {
            Json::obj(vec![
                ("lambda_l", Json::num(p.lam_l)),
                ("lambda_t", Json::num(p.lam_t)),
                ("f", Json::num(p.f)),
                ("lambda_nnz", Json::num(p.lambda_nnz as f64)),
                ("theta_nnz", Json::num(p.theta_nnz as f64)),
            ])
        })),
    )])
}

/// Golden-path regression: a fixed-seed 20×10 problem's path must reproduce
/// the checked-in objective values and active-set sizes — so screening (or
/// any solver) refactors cannot silently change results. The record is
/// (re)generated when missing or when `CGGM_REGEN_GOLDEN=1`; commit the
/// regenerated file together with the change that legitimately moved the
/// numbers (see docs/TESTING.md).
#[test]
fn golden_path_regression() {
    let res = golden_path_run();
    assert_eq!(res.points.len(), 5);
    assert!(res.points.iter().all(|p| p.converged));
    let file = golden_path_file();
    let regen = std::env::var("CGGM_REGEN_GOLDEN").is_ok();
    if regen || !file.exists() {
        if let Some(dir) = file.parent() {
            std::fs::create_dir_all(dir).unwrap();
        }
        std::fs::write(&file, golden_record(&res).to_string_pretty()).unwrap();
        eprintln!(
            "golden_path_regression: wrote {} — commit it so future runs \
             compare against it",
            file.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&file).unwrap();
    let want = Json::parse(&text).unwrap();
    let points = want.get("points").and_then(|p| p.as_arr()).unwrap();
    assert_eq!(
        points.len(),
        res.points.len(),
        "golden point count changed — regenerate deliberately"
    );
    for (k, (gold, got)) in points.iter().zip(&res.points).enumerate() {
        let num = |key: &str| gold.get(key).and_then(|v| v.as_f64()).unwrap();
        // λ values must match almost exactly (same data ⇒ same λ_max).
        assert!(
            (num("lambda_l") - got.lam_l).abs() <= 1e-9 * got.lam_l.abs().max(1e-12),
            "point {k}: grid λ_Λ moved: {} vs {}",
            num("lambda_l"),
            got.lam_l
        );
        assert!(
            (num("lambda_t") - got.lam_t).abs() <= 1e-9 * got.lam_t.abs().max(1e-12),
            "point {k}: grid λ_Θ moved"
        );
        // Objective within 1e-6 relative; support sizes within ±2 entries
        // (platform-dependent rounding at the soft-threshold boundary).
        assert!(
            (num("f") - got.f).abs() <= 1e-6 * got.f.abs().max(1.0),
            "point {k}: objective drifted: golden {} vs got {}",
            num("f"),
            got.f
        );
        let nnz_close = |key: &str, got_nnz: usize| {
            let want_nnz = num(key);
            assert!(
                (want_nnz - got_nnz as f64).abs() <= 2.0,
                "point {k}: {key} drifted: golden {want_nnz} vs got {got_nnz}"
            );
        };
        nnz_close("lambda_nnz", got.lambda_nnz);
        nnz_close("theta_nnz", got.theta_nnz);
    }
}
