//! Solver↔solver agreement, budget enforcement, threading, and structure
//! recovery — every test drives [`cggm::solvers::solve`] end to end.

use super::common::{chain_medium, chain_opts};
use cggm::cggm::CholKind;
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::metrics::f1_edges_sym;
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::util::membudget::MemBudget;

/// All three solvers minimize the same convex objective — they must agree on
/// the final objective value and (essentially) the support.
#[test]
fn three_solvers_agree_on_chain() {
    let prob = chain_medium();
    let eng = NativeGemm::new(1);
    let opts = chain_opts(0.25);
    let mut finals = Vec::new();
    for kind in SolverKind::paper_three() {
        let res = solve(kind, &prob.data, &opts, &eng).unwrap();
        assert!(res.trace.converged, "{:?} did not converge", kind);
        finals.push((kind, res.trace.final_f().unwrap(), res.model));
    }
    let f0 = finals[0].1;
    for (kind, f, _) in &finals {
        assert!(
            (f - f0).abs() < 1e-3 * f0.abs().max(1.0),
            "{kind:?} objective {f} vs {f0}"
        );
    }
    // Supports agree closely (tolerate a few boundary entries).
    let m0 = &finals[0].2;
    for (kind, _, m) in &finals[1..] {
        let diff = m0.lambda.to_dense().max_abs_diff(&m.lambda.to_dense());
        assert!(diff < 0.05, "{kind:?} Λ differs by {diff}");
    }
}

#[test]
fn three_solvers_agree_on_cluster_graph() {
    let prob = datagen::cluster_graph::generate(
        40,
        30,
        120,
        5,
        &datagen::cluster_graph::ClusterOptions {
            cluster_size: 10,
            hub_coeff: 2.0,
            ..Default::default()
        },
    );
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.6,
        lam_t: 0.6,
        max_iter: 100,
        ..Default::default()
    };
    let mut finals = Vec::new();
    for kind in SolverKind::paper_three() {
        let res = solve(kind, &prob.data, &opts, &eng).unwrap();
        assert!(res.trace.converged, "{kind:?} did not converge");
        finals.push((kind, res.trace.final_f().unwrap()));
    }
    let f0 = finals[0].1;
    for (kind, f) in &finals {
        assert!(
            (f - f0).abs() < 2e-3 * f0.abs().max(1.0),
            "{kind:?}: {f} vs {f0}"
        );
    }
}

/// The block solver under a tiny budget must reach the same optimum while
/// never exceeding its budget (the paper's memory story).
#[test]
fn bcd_budget_enforced_and_equivalent() {
    let prob = datagen::chain::generate(24, 24, 90, 2);
    let eng = NativeGemm::new(1);
    let unlimited = solve(
        SolverKind::AltNewtonBcd,
        &prob.data,
        &chain_opts(0.3),
        &eng,
    )
    .unwrap();
    let budget = MemBudget::new(48 * 1024);
    let tight_opts = SolveOptions {
        budget: budget.clone(),
        ..chain_opts(0.3)
    };
    let tight = solve(SolverKind::AltNewtonBcd, &prob.data, &tight_opts, &eng).unwrap();
    assert!(tight.trace.converged);
    assert!(budget.peak() <= 48 * 1024, "peak {} bytes", budget.peak());
    let (fu, ft) = (
        unlimited.trace.final_f().unwrap(),
        tight.trace.final_f().unwrap(),
    );
    assert!((fu - ft).abs() < 1e-4 * fu.abs().max(1.0));
}

/// Clustering ablation: contiguous blocks give the same answer (just more
/// cache misses).
#[test]
fn clustering_ablation_same_result() {
    let prob = datagen::cluster_graph::generate(
        30,
        24,
        80,
        9,
        &datagen::cluster_graph::ClusterOptions {
            cluster_size: 8,
            hub_coeff: 2.0,
            ..Default::default()
        },
    );
    let eng = NativeGemm::new(1);
    let budget = MemBudget::new(32 * 1024);
    let base = SolveOptions {
        lam_l: 0.5,
        lam_t: 0.5,
        max_iter: 80,
        budget: budget.clone(),
        ..Default::default()
    };
    let with = solve(SolverKind::AltNewtonBcd, &prob.data, &base, &eng).unwrap();
    let without_opts = SolveOptions {
        clustering: false,
        budget: MemBudget::new(32 * 1024),
        ..base
    };
    let without = solve(SolverKind::AltNewtonBcd, &prob.data, &without_opts, &eng).unwrap();
    let (fa, fb) = (
        with.trace.final_f().unwrap(),
        without.trace.final_f().unwrap(),
    );
    assert!((fa - fb).abs() < 1e-4 * fa.abs().max(1.0));
}

/// Multithreaded solve agrees with single-threaded.
#[test]
fn threads_do_not_change_answer() {
    let prob = datagen::chain::generate(16, 16, 70, 21);
    let eng1 = NativeGemm::new(1);
    let eng4 = NativeGemm::new(4);
    let o1 = chain_opts(0.3);
    let o4 = SolveOptions {
        threads: 4,
        ..chain_opts(0.3)
    };
    let r1 = solve(SolverKind::AltNewtonBcd, &prob.data, &o1, &eng1).unwrap();
    let r4 = solve(SolverKind::AltNewtonBcd, &prob.data, &o4, &eng4).unwrap();
    let (f1, f4) = (r1.trace.final_f().unwrap(), r4.trace.final_f().unwrap());
    assert!((f1 - f4).abs() < 1e-6 * f1.abs().max(1.0));
}

/// Structure recovery improves with sample size (Fig. 5b's shape).
#[test]
fn f1_improves_with_samples() {
    let eng = NativeGemm::new(1);
    let mut scores = Vec::new();
    for n in [40, 400] {
        let prob = datagen::chain::generate(30, 30, n, 33);
        let res = solve(SolverKind::AltNewtonCd, &prob.data, &chain_opts(0.5), &eng).unwrap();
        scores.push(f1_edges_sym(&res.model.lambda, &prob.truth.lambda).f1);
    }
    assert!(
        scores[1] > scores[0] - 0.02,
        "F1 did not improve with n: {scores:?}"
    );
    assert!(scores[1] > 0.5, "F1 at n=400 too low: {scores:?}");
}

/// A budget too small for even one cached column is the true memory wall:
/// the solver reports it instead of thrashing.
#[test]
fn impossible_budget_is_an_error() {
    let prob = datagen::chain::generate(64, 64, 30, 4);
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.5,
        lam_t: 0.5,
        max_iter: 5,
        budget: MemBudget::new(256), // bytes — cannot hold one q-column
        chol: CholKind::SparseRcm,
        ..Default::default()
    };
    match solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng) {
        Err(cggm::solvers::SolveError::Budget(_)) => {}
        Ok(_) => panic!("expected budget failure"),
        Err(e) => panic!("wrong error: {e}"),
    }
}

/// The wall-clock cap stops long runs early without corrupting state.
#[test]
fn time_limit_respected() {
    let prob = datagen::chain::generate(200, 200, 100, 6);
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.05, // dense active set → slow per iteration
        lam_t: 0.05,
        max_iter: 1000,
        time_limit: 0.05,
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
    assert!(!res.trace.converged);
    assert!(res.trace.records.len() < 1000);
    assert!(res.trace.final_f().unwrap().is_finite());
}

/// At convergence the stopping statistic really satisfies the paper's rule.
#[test]
fn stopping_rule_holds_at_convergence() {
    let prob = datagen::chain::generate(25, 25, 120, 10);
    let eng = NativeGemm::new(1);
    for kind in SolverKind::paper_three() {
        let res = solve(kind, &prob.data, &chain_opts(0.3), &eng).unwrap();
        assert!(res.trace.converged, "{kind:?}");
        let ratio = res.trace.stopping_ratio().unwrap();
        assert!(ratio <= 0.01 + 1e-12, "{kind:?}: ratio {ratio}");
    }
}

/// Genomic workload through the whole pipe (simulator → block solver).
#[test]
fn genomic_pipeline_smoke() {
    let prob = datagen::genomic::generate(
        300,
        40,
        80,
        12,
        &datagen::genomic::GenomicOptions::default(),
    );
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.15,
        lam_t: 0.15,
        max_iter: 40,
        budget: MemBudget::new(8 << 20),
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng).unwrap();
    assert!(res.trace.final_f().unwrap().is_finite());
    assert!(res.model.theta_nnz() > 0, "no eQTLs recovered at all");
}
