//! The concurrency property suite for `cggm serve`: one unix daemon,
//! several threaded clients at once, each with its own connection. The
//! properties:
//!
//! - responses and streamed `progress` lines never cross connections —
//!   every line a client reads carries one of its own request ids;
//! - on a streamed `path`, every `progress` line (no `"ok"` key) precedes
//!   that job's terminal response on the same connection;
//! - a `cancel` issued on the same connection as a mid-path job answers
//!   structurally, and the job's terminal is `cancelled` or a clean
//!   success — never silence;
//! - a long job on one connection does not block `stat` on another.
//!
//! The save/export satellite lives here too: `save` a fitted model to
//! disk, `evict` the dataset, `load` it back with `"model"` seeding the
//! warm cache from the file, and refit to the same optimum at 1e-6.

use cggm::coordinator::RunConfig;
use cggm::gemm::native::NativeGemm;
use cggm::serve::{ErrKind, Request, ServeEngine};
use cggm::util::json::Json;
use std::sync::Arc;

fn engine(max_jobs: usize, budget: Option<usize>) -> ServeEngine {
    let cfg = RunConfig {
        serve_max_jobs: max_jobs,
        serve_budget: budget,
        ..RunConfig::default()
    };
    ServeEngine::new(cfg, Arc::new(NativeGemm::new(1)))
}

fn req(line: &str) -> Request {
    Request::parse_line(line).expect("test request must parse")
}

fn num(doc: &Json, key: &str) -> f64 {
    doc.get(key)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing number '{key}' in {}", doc.to_string()))
}

fn flag(doc: &Json, key: &str) -> bool {
    doc.get(key)
        .and_then(|v| v.as_bool())
        .unwrap_or_else(|| panic!("missing bool '{key}' in {}", doc.to_string()))
}

/// save → evict → load(model=…) → refit roundtrip, in-process: the model
/// written by `save` seeds the warm cache of a freshly re-loaded dataset,
/// and the warm refit lands on the original optimum at 1e-6.
#[test]
fn save_evict_load_refit_roundtrip_matches_original_optimum() {
    let srv = engine(1, None);
    let load = srv.request(req(
        r#"{"op":"load","id":1,"name":"d","workload":"chain","p":12,"q":12,"n":60,"seed":7}"#,
    ));
    assert!(load.is_ok(), "{:?}", load.outcome);
    let fit_line = r#"{"op":"fit","id":2,"dataset":"d","solver":"alt","lambda":0.4,"tol":0.0000001,"max_iter":200}"#;
    let fit1 = srv.request(req(fit_line));
    assert!(fit1.is_ok(), "{:?}", fit1.outcome);
    let f1 = num(fit1.result().unwrap().get("summary").unwrap(), "f");

    // Export first: the in-band form of the same cached model.
    let export = srv.request(req(r#"{"op":"export","id":3,"dataset":"d","solver":"alt"}"#));
    assert!(export.is_ok(), "{:?}", export.outcome);
    let eres = export.result().unwrap();
    assert_eq!(num(eres, "p"), 12.0);
    assert_eq!(num(eres, "q"), 12.0);
    assert_eq!(num(eres, "lambda_l"), 0.4);
    assert!(eres.get("model").is_some(), "export carries the weights");

    // Save to disk.
    let path = std::env::temp_dir().join(format!("cggm_roundtrip_{}.jsonl", std::process::id()));
    let save = srv.request(req(&format!(
        r#"{{"op":"save","id":4,"dataset":"d","path":"{}","solver":"alt"}}"#,
        path.display()
    )));
    assert!(save.is_ok(), "{:?}", save.outcome);
    assert_eq!(
        save.result().unwrap().get("solver").unwrap().as_str(),
        Some("alt_newton_cd")
    );

    // Evict: the dataset — and its cached model — are gone.
    let evict = srv.request(req(r#"{"op":"evict","id":5,"dataset":"d"}"#));
    assert!(evict.is_ok(), "{:?}", evict.outcome);
    assert_eq!(srv.budget().live(), 0);

    // Reload the identical dataset, seeding the warm cache from the file.
    let reload = srv.request(req(&format!(
        r#"{{"op":"load","id":6,"name":"d","workload":"chain","p":12,"q":12,"n":60,"seed":7,"model":"{}"}}"#,
        path.display()
    )));
    assert!(reload.is_ok(), "{:?}", reload.outcome);
    let rres = reload.result().unwrap();
    assert!(flag(rres, "model_loaded"), "{}", rres.to_string());
    assert_eq!(rres.get("model_solver").unwrap().as_str(), Some("alt_newton_cd"));
    assert_eq!(num(rres, "model_lambda_l"), 0.4);

    // The refit warm-starts from the seeded model and lands on the same
    // optimum.
    let fit2 = srv.request(req(fit_line.replace("\"id\":2", "\"id\":7").as_str()));
    assert!(fit2.is_ok(), "{:?}", fit2.outcome);
    let r2 = fit2.result().unwrap();
    assert!(flag(r2, "warm_started"), "seeded model must warm-start the refit");
    let f2 = num(r2.get("summary").unwrap(), "f");
    assert!(
        (f1 - f2).abs() <= 1e-6 * f1.abs().max(1.0),
        "roundtrip diverged: {f1} vs {f2}"
    );
    let _ = std::fs::remove_file(&path);
    srv.join();
}

/// Structured failure modes of save/export/load-model: unknown dataset,
/// unfitted solver, unknown solver name, shape-mismatched model file.
#[test]
fn save_export_failures_are_structured() {
    let srv = engine(1, None);
    let missing = srv.request(req(r#"{"op":"save","id":1,"dataset":"nope","path":"/tmp/x.jsonl"}"#));
    assert_eq!(missing.err_kind(), Some(ErrKind::NotFound), "{:?}", missing.outcome);
    let load = srv.request(req(
        r#"{"op":"load","id":2,"name":"d","workload":"chain","p":8,"q":8,"n":40,"seed":1}"#,
    ));
    assert!(load.is_ok());
    // Loaded but never fitted: no cached model to export.
    let unfitted = srv.request(req(r#"{"op":"export","id":3,"dataset":"d"}"#));
    assert_eq!(unfitted.err_kind(), Some(ErrKind::NotFound), "{:?}", unfitted.outcome);
    let badsolver = srv.request(req(
        r#"{"op":"export","id":4,"dataset":"d","solver":"madeup"}"#,
    ));
    assert_eq!(badsolver.err_kind(), Some(ErrKind::Parse), "{:?}", badsolver.outcome);
    // A model file for the wrong shape is rejected at load, structurally.
    let fit = srv.request(req(r#"{"op":"fit","id":5,"dataset":"d","solver":"alt","lambda":0.5}"#));
    assert!(fit.is_ok());
    let path = std::env::temp_dir().join(format!("cggm_mismatch_{}.jsonl", std::process::id()));
    let save = srv.request(req(&format!(
        r#"{{"op":"save","id":6,"dataset":"d","path":"{}"}}"#,
        path.display()
    )));
    assert!(save.is_ok(), "{:?}", save.outcome);
    let mismatch = srv.request(req(&format!(
        r#"{{"op":"load","id":7,"name":"other","workload":"chain","p":10,"q":10,"n":40,"seed":1,"model":"{}"}}"#,
        path.display()
    )));
    assert_eq!(mismatch.err_kind(), Some(ErrKind::Parse), "{:?}", mismatch.outcome);
    let _ = std::fs::remove_file(&path);
    srv.join();
}

/// The tentpole acceptance, end to end over a real unix socket: three
/// concurrent clients on one daemon — a streamed `path`, a plain
/// load+fit+stat session, and a cancel session — with per-connection line
/// isolation and progress-before-terminal ordering.
#[cfg(unix)]
#[test]
fn unix_daemon_serves_three_concurrent_clients_with_streams_and_cancel() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::Shutdown;
    use std::os::unix::net::UnixStream;
    use std::time::{Duration, Instant};

    let sock = std::env::temp_dir().join(format!("cggm_conc_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_cggm"))
        .args(["serve", "--max-jobs", "2", "--socket", sock.to_str().unwrap()])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("failed to start cggm serve --socket");

    let connect = || -> UnixStream {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match UnixStream::connect(&sock) {
                Ok(s) => return s,
                Err(e) => {
                    assert!(Instant::now() < deadline, "socket never came up: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    };

    // Write a whole session, half-close, and read every line back as
    // parsed JSON (the daemon drains this connection's jobs before EOF).
    let run_session = |requests: &str| -> Vec<Json> {
        let mut c = connect();
        c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        c.write_all(requests.as_bytes()).expect("client writes");
        c.shutdown(Shutdown::Write).expect("half-close");
        BufReader::new(c)
            .lines()
            .map(|l| Json::parse(&l.expect("client reads")).expect("valid JSON line"))
            .collect()
    };

    let own_ids = |lines: &[Json], allowed: &[f64], who: &str| {
        for line in lines {
            let id = line.get("id").and_then(|v| v.as_f64()).unwrap_or(-1.0);
            assert!(
                allowed.contains(&id),
                "{who} read a line with a foreign id {id}: {}",
                line.to_string()
            );
        }
    };

    std::thread::scope(|scope| {
        // Client A: streamed path. Every progress line has no "ok" key and
        // precedes the terminal for the same id.
        let a = scope.spawn(|| {
            let lines = run_session(concat!(
                r#"{"op":"load","id":1000,"name":"a","workload":"chain","p":10,"q":10,"n":50,"seed":1}"#,
                "\n",
                r#"{"op":"path","id":1001,"dataset":"a","solver":"alt","path_points":4,"stream":true}"#,
                "\n",
            ));
            own_ids(&lines, &[1000.0, 1001.0], "client A");
            let mut progress = 0usize;
            let mut terminal_seen = false;
            for line in &lines {
                if line.get("id").and_then(|v| v.as_f64()) != Some(1001.0) {
                    continue;
                }
                if line.get("ok").is_none() {
                    assert!(
                        !terminal_seen,
                        "progress after terminal: {}",
                        line.to_string()
                    );
                    let body = line.get("progress").expect("progress body");
                    assert!(body.get("lambda_l").is_some());
                    assert!(body.get("f").is_some());
                    progress += 1;
                } else {
                    assert!(flag(line, "ok"), "{}", line.to_string());
                    terminal_seen = true;
                }
            }
            assert!(terminal_seen, "path terminal missing: {lines:?}");
            assert_eq!(progress, 4, "one progress line per path point");
        });

        // Client B: plain session — must be served while A's path runs.
        let b = scope.spawn(|| {
            let lines = run_session(concat!(
                r#"{"op":"load","id":2000,"name":"b","workload":"chain","p":10,"q":10,"n":50,"seed":2}"#,
                "\n",
                r#"{"op":"fit","id":2001,"dataset":"b","solver":"alt","lambda":0.5}"#,
                "\n",
                r#"{"op":"stat","id":2002}"#,
                "\n",
            ));
            own_ids(&lines, &[2000.0, 2001.0, 2002.0], "client B");
            assert_eq!(lines.len(), 3, "no streaming requested, no extra lines");
            for line in &lines {
                assert!(flag(line, "ok"), "{}", line.to_string());
            }
        });

        // Client C: a long path, then a same-connection cancel of it. The
        // cancel answers structurally; the path terminates as `cancelled`
        // or (losing the race) a clean success — never silence.
        let c = scope.spawn(|| {
            let lines = run_session(concat!(
                r#"{"op":"load","id":3000,"name":"c","workload":"chain","p":20,"q":20,"n":80,"seed":3}"#,
                "\n",
                r#"{"op":"path","id":3001,"dataset":"c","solver":"alt","path_points":20,"tol":0.00000001,"max_iter":400}"#,
                "\n",
                r#"{"op":"cancel","id":3002,"job":3001}"#,
                "\n",
            ));
            own_ids(&lines, &[3000.0, 3001.0, 3002.0], "client C");
            assert_eq!(lines.len(), 3, "load, path terminal, cancel answered");
            for target in [3000.0, 3001.0, 3002.0] {
                assert_eq!(
                    lines
                        .iter()
                        .filter(|l| l.get("id").and_then(|v| v.as_f64()) == Some(target))
                        .count(),
                    1,
                    "exactly one terminal for id {target}"
                );
            }
            for line in &lines {
                let id = line.get("id").and_then(|v| v.as_f64()).unwrap();
                let ok = flag(line, "ok");
                let kind = line
                    .get("error")
                    .and_then(|e| e.get("kind"))
                    .and_then(|k| k.as_str())
                    .map(String::from);
                if id == 3000.0 {
                    assert!(ok, "{}", line.to_string());
                } else if id == 3001.0 {
                    assert!(
                        ok || kind.as_deref() == Some("cancelled"),
                        "path must finish or cancel cleanly: {}",
                        line.to_string()
                    );
                } else {
                    assert!(
                        ok || kind.as_deref() == Some("not_found"),
                        "cancel must answer structurally: {}",
                        line.to_string()
                    );
                }
            }
        });

        a.join().unwrap();
        b.join().unwrap();
        c.join().unwrap();
    });

    // A fourth connection shuts the daemon down cleanly.
    let lines = {
        let mut c = connect();
        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        c.write_all(b"{\"op\":\"shutdown\",\"id\":4000}\n")
            .expect("shutdown client writes");
        let mut out = Vec::new();
        for line in BufReader::new(c).lines() {
            out.push(line.expect("shutdown client reads"));
        }
        out
    };
    assert_eq!(lines.len(), 1, "shutdown answered: {lines:?}");
    assert!(
        flag(&Json::parse(&lines[0]).unwrap(), "ok"),
        "{}",
        lines[0]
    );

    let output = child.wait_with_output().expect("daemon exits after shutdown");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "daemon must exit cleanly\nstderr:\n{stderr}"
    );
    let _ = std::fs::remove_file(&sock);
}
