//! Memwall regression suite: `MemBudget::peak()` must account for every
//! byte the solvers touch — including the Cholesky factors that historically
//! escaped it — and an undersized budget must fail fast with a clean error
//! instead of allocating past the limit.
//!
//! The analytic model for the square dense fixture (p = q = n = m,
//! `CholKind::Dense`, m ≤ 64 so the dense factorization has no blocked
//! trailing-update scratch) enumerates the tracked working set at its peak,
//! which `alt_newton_cd` reaches inside the Armijo line search (and again in
//! the Θ step), all in units of 8·m² bytes:
//!
//! | contribution                         | units |
//! |--------------------------------------|-------|
//! | cached statistics S_yy, S_xx, S_xy   | 3     |
//! | R̃ᵀ (q×n), Σ, Ψ, ∇_Λ, W caches        | 5     |
//! | current iterate's Λ factor (L)       | 1     |
//! | line-search trial factor (L)         | 1     |
//! | trial factorization staging copy     | 1     |
//! | **total**                            | **11**|
//!
//! Every entry is the same m² doubles, so the arena's capacity-based reuse
//! introduces no slack — the measured peak must land within 10% of 88·m²
//! bytes. Before factor tracking the model stopped at 8 units; the ≥ check
//! against `dense_workingset_bytes + 2·dense_factor_bytes` pins that the
//! factor bytes specifically are now covered.

use cggm::cggm::factor::dense_factor_bytes;
use cggm::cggm::CholKind;
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{dense_workingset_bytes, solve, SolveError, SolveOptions, SolverKind};
use cggm::util::membudget::MemBudget;

/// Acceptance: the measured peak covers the Cholesky factor bytes and lands
/// within 10% of the analytic estimate on the square dense fixture.
#[test]
fn peak_accounts_for_cholesky_factors_within_estimate() {
    let m = 32;
    let prob = datagen::chain::generate(m, m, m, 7);
    let eng = NativeGemm::new(1);
    let budget = MemBudget::unlimited();
    let opts = SolveOptions {
        lam_l: 0.25,
        lam_t: 0.25,
        max_iter: 60,
        chol: CholKind::Dense,
        budget: budget.clone(),
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
    assert!(
        res.trace.records.len() >= 2,
        "fixture must run real iterations (line search included)"
    );
    assert!(res.trace.final_f().unwrap().is_finite());
    let unit = 8 * m * m;
    let est = 11 * unit;
    let peak = budget.peak();
    assert!(
        peak >= est - est / 10 && peak <= est + est / 10,
        "measured peak {peak} bytes vs analytic estimate {est} bytes (unit {unit})"
    );
    // The factor bytes specifically: peak must exceed the pre-factor-tracking
    // working-set estimate by at least the two concurrently-live factors.
    assert!(
        peak >= dense_workingset_bytes(SolverKind::AltNewtonCd, m, m)
            + 2 * dense_factor_bytes(m),
        "peak {peak} does not cover the factor bytes"
    );
    // Everything released: the context died with the solve.
    assert_eq!(budget.live(), 0);
}

/// A budget that holds the statistics but not the first Λ factor fails fast
/// at the factorization — a clean `SolveError::Budget`, nothing leaked, and
/// the accounting never exceeded the limit (tracked before allocated).
#[test]
fn undersized_budget_fails_fast_at_the_factor() {
    let m = 16;
    let prob = datagen::chain::generate(m, m, m, 3);
    let eng = NativeGemm::new(1);
    // Stats = 3·16²·8 = 6144; + factor L = 8192; + staging copy = 10240.
    // 9000 admits the stats and the resident L but not the staging copy.
    let budget = MemBudget::new(9000);
    let opts = SolveOptions {
        lam_l: 0.3,
        lam_t: 0.3,
        max_iter: 10,
        chol: CholKind::Dense,
        budget: budget.clone(),
        ..Default::default()
    };
    match solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng) {
        Err(SolveError::Budget(_)) => {}
        Ok(_) => panic!("9000-byte budget cannot hold a 16×16 dense factorization"),
        Err(e) => panic!("wrong error: {e}"),
    }
    assert!(budget.peak() <= 9000, "allocated past the limit before failing");
    assert_eq!(budget.live(), 0, "failed solve leaked tracked bytes");
}

/// Same fail-fast contract on the block solver's sparse path: the factor's
/// resident structures exceed a 1KB budget at q = 64, so the solve reports
/// the budget error before any cache is sized.
#[test]
fn block_solver_budget_error_never_allocates_past_limit() {
    let prob = datagen::chain::generate(64, 64, 30, 4);
    let eng = NativeGemm::new(1);
    let budget = MemBudget::new(1024);
    let opts = SolveOptions {
        lam_l: 0.5,
        lam_t: 0.5,
        max_iter: 5,
        chol: CholKind::SparseRcm,
        budget: budget.clone(),
        ..Default::default()
    };
    match solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng) {
        Err(SolveError::Budget(_)) => {}
        Ok(_) => panic!("expected budget failure"),
        Err(e) => panic!("wrong error: {e}"),
    }
    assert!(budget.peak() <= 1024);
    assert_eq!(budget.live(), 0);
}

/// With budget-tracked factors, a *sufficient* budget still solves and its
/// peak now strictly dominates the iterate-and-cache estimate alone — the
/// measured memwall column includes what the paper calls the factorization's
/// "additional memory during the computation".
#[test]
fn sparse_factor_bytes_visible_in_block_solver_peak() {
    let prob = datagen::chain::generate(20, 20, 80, 9);
    let eng = NativeGemm::new(1);
    let budget = MemBudget::unlimited();
    let opts = SolveOptions {
        lam_l: 0.2,
        lam_t: 0.2,
        max_iter: 50,
        chol: CholKind::SparseRcm,
        budget: budget.clone(),
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng).unwrap();
    assert!(res.trace.converged);
    // The final model's factor is representative of the factors held during
    // the sweep; the measured peak must at least cover one of them on top of
    // the q×n R̃ᵀ panel the solver always holds.
    let reference =
        cggm::cggm::factor::LambdaFactor::factor(&res.model.lambda, CholKind::SparseRcm, &eng)
            .unwrap();
    let rt_bytes = 8 * 20 * 80;
    assert!(
        budget.peak() >= rt_bytes + reference.resident_bytes(),
        "peak {} does not cover R̃ᵀ ({rt_bytes}) + factor ({})",
        budget.peak(),
        reference.resident_bytes()
    );
}
