//! `SolverContext` behavior across whole solves: statistic caching, arena
//! reuse, and the measured-vs-analytic working set.

use cggm::coordinator::{fit_path_in_context, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{
    dense_workingset_bytes, solve, solve_in_context, SolveOptions, SolverContext, SolverKind,
};
use cggm::util::membudget::MemBudget;

/// The workspace arena makes `MemBudget::peak()` report the true dense
/// working set: for a small AltNewtonCD run it must agree with the analytic
/// `dense_workingset_bytes` estimate within a tolerance (the estimate counts
/// S_yy/Σ/Ψ/W + S_xx + Vᵀ; the measured set adds the gradients and the q×n
/// R̃ᵀ panel, hence the slack).
#[test]
fn workspace_peak_matches_dense_estimate() {
    let (p, q, n) = (30, 30, 30);
    let prob = datagen::chain::generate(p, q, n, 7);
    let eng = NativeGemm::new(1);
    let budget = MemBudget::unlimited();
    let opts = SolveOptions {
        lam_l: 0.3,
        lam_t: 0.3,
        max_iter: 40,
        budget: budget.clone(),
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
    assert!(res.trace.converged);
    let est = dense_workingset_bytes(SolverKind::AltNewtonCd, p, q);
    let peak = budget.peak();
    assert!(
        peak >= est / 2 && peak <= est.saturating_mul(5) / 2,
        "measured peak {peak} bytes vs analytic estimate {est} bytes"
    );
}

/// A λ path on a shared context computes each covariance statistic exactly
/// once — including the strong-rule screening's per-point gradient
/// evaluations, which reuse the cached S_yy/S_xy — and the workspace arena
/// does not grow after the first solve.
#[test]
fn lambda_path_reuses_context_state() {
    let prob = datagen::chain::generate(16, 16, 80, 13);
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 80,
        ..Default::default()
    };
    let ctx = SolverContext::new(&prob.data, &base, &eng);
    let popts = PathOptions {
        points: 4,
        min_ratio: 0.2,
        ..Default::default()
    };
    let res = fit_path_in_context(SolverKind::AltNewtonCd, &ctx, &base, &popts).unwrap();
    assert_eq!(res.points.len(), 4);
    assert_eq!(
        ctx.stat_computes(),
        3,
        "S_yy/S_xx/S_xy must be computed once for the whole path"
    );
    let misses_after_path = ctx.workspace().misses();
    // Another solve on the same context allocates nothing new.
    let _ = solve_in_context(SolverKind::AltNewtonCd, &ctx, &base, res.model.as_ref()).unwrap();
    assert_eq!(
        ctx.workspace().misses(),
        misses_after_path,
        "a further solve on a warm context must be allocation-free"
    );
}
