//! Cross-module integration tests: solver↔solver agreement, block=non-block
//! equivalence, budget enforcement, and the cross-language oracle (Rust
//! objective vs the AOT-compiled L2 JAX objective through PJRT).

use cggm::cggm::{CggmModel, CholKind, Dataset, Objective};
use cggm::coordinator::{fit_path, fit_path_in_context, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::gemm::GemmEngine;
use cggm::linalg::dense::Mat;
use cggm::metrics::f1_edges_sym;
use cggm::runtime::{artifact_dir, compile_artifact, manifest::Manifest};
use cggm::solvers::{
    dense_workingset_bytes, solve, solve_in_context, SolveOptions, SolverContext, SolverKind,
};
use cggm::util::membudget::MemBudget;
use cggm::util::rng::Rng;

fn chain_opts(lam: f64) -> SolveOptions {
    SolveOptions {
        lam_l: lam,
        lam_t: lam,
        max_iter: 80,
        ..Default::default()
    }
}

/// All three solvers minimize the same convex objective — they must agree on
/// the final objective value and (essentially) the support.
#[test]
fn three_solvers_agree_on_chain() {
    let prob = datagen::chain::generate(20, 20, 100, 11);
    let eng = NativeGemm::new(1);
    let opts = chain_opts(0.25);
    let mut finals = Vec::new();
    for kind in SolverKind::paper_three() {
        let res = solve(kind, &prob.data, &opts, &eng).unwrap();
        assert!(res.trace.converged, "{:?} did not converge", kind);
        finals.push((kind, res.trace.final_f().unwrap(), res.model));
    }
    let f0 = finals[0].1;
    for (kind, f, _) in &finals {
        assert!(
            (f - f0).abs() < 1e-3 * f0.abs().max(1.0),
            "{kind:?} objective {f} vs {f0}"
        );
    }
    // Supports agree closely (tolerate a few boundary entries).
    let m0 = &finals[0].2;
    for (kind, _, m) in &finals[1..] {
        let diff = m0.lambda.to_dense().max_abs_diff(&m.lambda.to_dense());
        assert!(diff < 0.05, "{kind:?} Λ differs by {diff}");
    }
}

#[test]
fn three_solvers_agree_on_cluster_graph() {
    let prob = datagen::cluster_graph::generate(
        40,
        30,
        120,
        5,
        &datagen::cluster_graph::ClusterOptions {
            cluster_size: 10,
            hub_coeff: 2.0,
            ..Default::default()
        },
    );
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.6,
        lam_t: 0.6,
        max_iter: 100,
        ..Default::default()
    };
    let mut finals = Vec::new();
    for kind in SolverKind::paper_three() {
        let res = solve(kind, &prob.data, &opts, &eng).unwrap();
        assert!(res.trace.converged, "{kind:?} did not converge");
        finals.push((kind, res.trace.final_f().unwrap()));
    }
    let f0 = finals[0].1;
    for (kind, f) in &finals {
        assert!(
            (f - f0).abs() < 2e-3 * f0.abs().max(1.0),
            "{kind:?}: {f} vs {f0}"
        );
    }
}

/// The block solver under a tiny budget must reach the same optimum while
/// never exceeding its budget (the paper's memory story).
#[test]
fn bcd_budget_enforced_and_equivalent() {
    let prob = datagen::chain::generate(24, 24, 90, 2);
    let eng = NativeGemm::new(1);
    let unlimited = solve(
        SolverKind::AltNewtonBcd,
        &prob.data,
        &chain_opts(0.3),
        &eng,
    )
    .unwrap();
    let budget = MemBudget::new(48 * 1024);
    let tight_opts = SolveOptions {
        budget: budget.clone(),
        ..chain_opts(0.3)
    };
    let tight = solve(SolverKind::AltNewtonBcd, &prob.data, &tight_opts, &eng).unwrap();
    assert!(tight.trace.converged);
    assert!(budget.peak() <= 48 * 1024, "peak {} bytes", budget.peak());
    let (fu, ft) = (
        unlimited.trace.final_f().unwrap(),
        tight.trace.final_f().unwrap(),
    );
    assert!((fu - ft).abs() < 1e-4 * fu.abs().max(1.0));
}

/// Clustering ablation: contiguous blocks give the same answer (just more
/// cache misses).
#[test]
fn clustering_ablation_same_result() {
    let prob = datagen::cluster_graph::generate(
        30,
        24,
        80,
        9,
        &datagen::cluster_graph::ClusterOptions {
            cluster_size: 8,
            hub_coeff: 2.0,
            ..Default::default()
        },
    );
    let eng = NativeGemm::new(1);
    let budget = MemBudget::new(32 * 1024);
    let base = SolveOptions {
        lam_l: 0.5,
        lam_t: 0.5,
        max_iter: 80,
        budget: budget.clone(),
        ..Default::default()
    };
    let with = solve(SolverKind::AltNewtonBcd, &prob.data, &base, &eng).unwrap();
    let without_opts = SolveOptions {
        clustering: false,
        budget: MemBudget::new(32 * 1024),
        ..base
    };
    let without = solve(SolverKind::AltNewtonBcd, &prob.data, &without_opts, &eng).unwrap();
    let (fa, fb) = (
        with.trace.final_f().unwrap(),
        without.trace.final_f().unwrap(),
    );
    assert!((fa - fb).abs() < 1e-4 * fa.abs().max(1.0));
}

/// Multithreaded solve agrees with single-threaded.
#[test]
fn threads_do_not_change_answer() {
    let prob = datagen::chain::generate(16, 16, 70, 21);
    let eng1 = NativeGemm::new(1);
    let eng4 = NativeGemm::new(4);
    let o1 = chain_opts(0.3);
    let o4 = SolveOptions {
        threads: 4,
        ..chain_opts(0.3)
    };
    let r1 = solve(SolverKind::AltNewtonBcd, &prob.data, &o1, &eng1).unwrap();
    let r4 = solve(SolverKind::AltNewtonBcd, &prob.data, &o4, &eng4).unwrap();
    let (f1, f4) = (r1.trace.final_f().unwrap(), r4.trace.final_f().unwrap());
    assert!((f1 - f4).abs() < 1e-6 * f1.abs().max(1.0));
}

/// Structure recovery improves with sample size (Fig. 5b's shape).
#[test]
fn f1_improves_with_samples() {
    let eng = NativeGemm::new(1);
    let mut scores = Vec::new();
    for n in [40, 400] {
        let prob = datagen::chain::generate(30, 30, n, 33);
        let res = solve(SolverKind::AltNewtonCd, &prob.data, &chain_opts(0.5), &eng).unwrap();
        scores.push(f1_edges_sym(&res.model.lambda, &prob.truth.lambda).f1);
    }
    assert!(
        scores[1] > scores[0] - 0.02,
        "F1 did not improve with n: {scores:?}"
    );
    assert!(scores[1] > 0.5, "F1 at n=400 too low: {scores:?}");
}

/// Cross-language oracle: the Rust objective must match the AOT-lowered L2
/// JAX objective executed through PJRT, on random dense inputs at the
/// artifact's fixed shape.
#[test]
fn rust_objective_matches_jax_artifact() {
    let dir = artifact_dir();
    let manifest_path = dir.join("manifest.json");
    if !manifest_path.exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&manifest_path).unwrap();
    let entry = manifest.find("cggm_obj", None, None).expect("oracle artifact");
    let q = 16usize;
    let p = 24usize;
    assert_eq!(entry.inputs[0], vec![q, q]);

    let client = xla::PjRtClient::cpu().unwrap();
    let exe = compile_artifact(&client, &dir, entry).unwrap();

    let mut rng = Rng::new(44);
    // Random SPD Λ, sparse-ish Θ, covariance matrices from a random dataset.
    let n = 32;
    let data = Dataset::new(
        Mat::from_fn(p, n, |_, _| rng.normal()),
        Mat::from_fn(q, n, |_, _| rng.normal()),
    );
    let mut model = CggmModel::init(p, q);
    for i in 0..q {
        model.lambda.set(i, i, 3.0 + rng.uniform());
    }
    for _ in 0..q {
        let (i, j) = (rng.below(q), rng.below(q));
        if i != j {
            model.lambda.set_sym(i, j, 0.2 * rng.normal());
        }
    }
    for _ in 0..2 * p {
        model.theta.set(rng.below(p), rng.below(q), rng.normal() * 0.4);
    }
    let (lam_l, lam_t) = (0.37, 0.21);

    // Rust value.
    let eng = NativeGemm::new(1);
    let obj = Objective::new(&data, lam_l, lam_t).with_chol(CholKind::Dense);
    let f_rust = obj.value(&model, &eng).unwrap();

    // JAX artifact value.
    let lam_d = model.lambda.to_dense();
    let th_d = model.theta.to_dense();
    let syy = data.syy_dense(&eng);
    let sxy = data.sxy_dense(&eng);
    let sxx = data.sxx_dense(&eng);
    let lit = |m: &Mat, r: usize, c: usize| {
        xla::Literal::vec1(m.data())
            .reshape(&[r as i64, c as i64])
            .unwrap()
    };
    let args = vec![
        lit(&lam_d, q, q),
        lit(&th_d, p, q),
        lit(&syy, q, q),
        lit(&sxy, p, q),
        lit(&sxx, p, p),
        xla::Literal::scalar(lam_l),
        xla::Literal::scalar(lam_t),
    ];
    let result = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let f_jax: f64 = result
        .to_tuple1()
        .unwrap()
        .to_vec::<f64>()
        .unwrap()[0];

    let rel = (f_rust - f_jax).abs() / f_rust.abs().max(1.0);
    assert!(
        rel < 1e-9,
        "cross-language objective mismatch: rust={f_rust} jax={f_jax}"
    );
}

/// Same oracle for the analytic gradients (Eq. 3).
#[test]
fn rust_gradients_match_jax_artifact() {
    let dir = artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let entry = manifest.find("cggm_grads", None, None).expect("grads artifact");
    let (p, q) = (24usize, 16usize);
    let client = xla::PjRtClient::cpu().unwrap();
    let exe = compile_artifact(&client, &dir, entry).unwrap();

    let mut rng = Rng::new(45);
    let n = 40;
    let data = Dataset::new(
        Mat::from_fn(p, n, |_, _| rng.normal()),
        Mat::from_fn(q, n, |_, _| rng.normal()),
    );
    let mut model = CggmModel::init(p, q);
    for i in 0..q {
        model.lambda.set(i, i, 3.0);
    }
    model.lambda.set_sym(0, 5, 0.3);
    for _ in 0..p {
        model.theta.set(rng.below(p), rng.below(q), rng.normal() * 0.4);
    }
    let eng = NativeGemm::new(1);
    let obj = Objective::new(&data, 0.0, 0.0).with_chol(CholKind::Dense);
    let (_, _, factor, rt) = obj.eval(&model, &eng).unwrap();
    let sigma = factor.inverse_dense(&eng);
    let psi = obj.psi_dense(&sigma, &rt, &eng);
    let gl_rust = obj.grad_lambda_dense(&sigma, &psi, &eng);
    let gt_rust = obj.grad_theta_dense(&sigma, &rt, &eng);

    let lam_d = model.lambda.to_dense();
    let th_d = model.theta.to_dense();
    let syy = data.syy_dense(&eng);
    let sxy = data.sxy_dense(&eng);
    let sxx = data.sxx_dense(&eng);
    let lit = |m: &Mat, r: usize, c: usize| {
        xla::Literal::vec1(m.data())
            .reshape(&[r as i64, c as i64])
            .unwrap()
    };
    let args = vec![
        lit(&lam_d, q, q),
        lit(&th_d, p, q),
        lit(&syy, q, q),
        lit(&sxy, p, q),
        lit(&sxx, p, p),
    ];
    let mut result = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = result.decompose_tuple().unwrap();
    let gl_jax = parts[0].to_vec::<f64>().unwrap();
    let gt_jax = parts[1].to_vec::<f64>().unwrap();
    for (a, b) in gl_rust.data().iter().zip(&gl_jax) {
        assert!((a - b).abs() < 1e-9, "∇Λ mismatch: {a} vs {b}");
    }
    for (a, b) in gt_rust.data().iter().zip(&gt_jax) {
        assert!((a - b).abs() < 1e-9, "∇Θ mismatch: {a} vs {b}");
    }
}

/// A budget too small for even one cached column is the true memory wall:
/// the solver reports it instead of thrashing.
#[test]
fn impossible_budget_is_an_error() {
    let prob = datagen::chain::generate(64, 64, 30, 4);
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.5,
        lam_t: 0.5,
        max_iter: 5,
        budget: MemBudget::new(256), // bytes — cannot hold one q-column
        chol: CholKind::SparseRcm,
        ..Default::default()
    };
    match solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng) {
        Err(cggm::solvers::SolveError::Budget(_)) => {}
        Ok(_) => panic!("expected budget failure"),
        Err(e) => panic!("wrong error: {e}"),
    }
}

/// The wall-clock cap stops long runs early without corrupting state.
#[test]
fn time_limit_respected() {
    let prob = datagen::chain::generate(200, 200, 100, 6);
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.05, // dense active set → slow per iteration
        lam_t: 0.05,
        max_iter: 1000,
        time_limit: 0.05,
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
    assert!(!res.trace.converged);
    assert!(res.trace.records.len() < 1000);
    assert!(res.trace.final_f().unwrap().is_finite());
}

/// Run-config file → solver options → fit, end to end through the
/// coordinator (the CLI's code path).
#[test]
fn config_file_drives_a_fit() {
    let tmp = std::env::temp_dir().join("cggm_it_cfg.json");
    std::fs::write(
        &tmp,
        r#"{"workload": "chain", "p": 30, "q": 30, "n": 60, "seed": 3,
            "solver": "bcd", "lambda": 0.4, "max_iter": 40,
            "mem_budget": "1MB"}"#,
    )
    .unwrap();
    let cfg = cggm::coordinator::RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
    let prob = cggm::coordinator::generate_problem(cfg.workload, cfg.p, cfg.q, cfg.n, cfg.seed);
    let opts = cfg.solve_options();
    let eng = NativeGemm::new(1);
    let (sum, _) = cggm::coordinator::run_fit(cfg.solver, &prob, &opts, &eng, None).unwrap();
    assert!(sum.converged);
    assert!(sum.peak_bytes <= 1 << 20);
    let _ = std::fs::remove_file(tmp);
}

/// Dataset save/load through the coordinator feeds a solve identically.
#[test]
fn saved_dataset_reproduces_fit() {
    let prob = datagen::chain::generate(20, 20, 60, 8);
    let tmp = std::env::temp_dir().join("cggm_it_ds.bin");
    cggm::coordinator::save_dataset(&prob.data, &tmp).unwrap();
    let loaded = cggm::coordinator::load_dataset(&tmp).unwrap();
    let eng = NativeGemm::new(1);
    let opts = chain_opts(0.4);
    let a = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
    let b = solve(SolverKind::AltNewtonCd, &loaded, &opts, &eng).unwrap();
    assert_eq!(a.trace.final_f(), b.trace.final_f());
    let _ = std::fs::remove_file(tmp);
}

/// At convergence the stopping statistic really satisfies the paper's rule.
#[test]
fn stopping_rule_holds_at_convergence() {
    let prob = datagen::chain::generate(25, 25, 120, 10);
    let eng = NativeGemm::new(1);
    for kind in SolverKind::paper_three() {
        let res = solve(kind, &prob.data, &chain_opts(0.3), &eng).unwrap();
        assert!(res.trace.converged, "{kind:?}");
        let ratio = res.trace.stopping_ratio().unwrap();
        assert!(ratio <= 0.01 + 1e-12, "{kind:?}: ratio {ratio}");
    }
}

/// The workspace arena makes `MemBudget::peak()` report the true dense
/// working set: for a small AltNewtonCD run it must agree with the analytic
/// `dense_workingset_bytes` estimate within a tolerance (the estimate counts
/// S_yy/Σ/Ψ/W + S_xx + Vᵀ; the measured set adds the gradients and the q×n
/// R̃ᵀ panel, hence the slack).
#[test]
fn workspace_peak_matches_dense_estimate() {
    let (p, q, n) = (30, 30, 30);
    let prob = datagen::chain::generate(p, q, n, 7);
    let eng = NativeGemm::new(1);
    let budget = MemBudget::unlimited();
    let opts = SolveOptions {
        lam_l: 0.3,
        lam_t: 0.3,
        max_iter: 40,
        budget: budget.clone(),
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
    assert!(res.trace.converged);
    let est = dense_workingset_bytes(SolverKind::AltNewtonCd, p, q);
    let peak = budget.peak();
    assert!(
        peak >= est / 2 && peak <= est.saturating_mul(5) / 2,
        "measured peak {peak} bytes vs analytic estimate {est} bytes"
    );
}

/// Satellite: on a 2-point λ path, the warm-started second solve converges
/// in at most the cold-start iteration count and reaches the same objective
/// within the stopping tolerance.
#[test]
fn warm_start_beats_cold_start_on_a_two_point_path() {
    let prob = datagen::chain::generate(20, 20, 100, 11);
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 100,
        ..Default::default()
    };
    let grid = vec![(0.5, 0.5), (0.25, 0.25)];
    let mk = |warm_start: bool| PathOptions {
        lambdas: Some(grid.clone()),
        warm_start,
        ..Default::default()
    };
    let warm = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &mk(true), &eng).unwrap();
    let cold = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &mk(false), &eng).unwrap();
    assert_eq!(warm.points.len(), 2);
    assert!(warm.points[1].converged && cold.points[1].converged);
    assert!(
        warm.points[1].iters <= cold.points[1].iters,
        "warm {} iters vs cold {} iters",
        warm.points[1].iters,
        cold.points[1].iters
    );
    let (fw, fc) = (warm.points[1].f, cold.points[1].f);
    assert!(
        (fw - fc).abs() <= base.tol * fc.abs().max(1.0),
        "objectives diverged: warm {fw} vs cold {fc}"
    );
    // The first point is identical either way (no warm start to apply yet).
    assert_eq!(warm.points[0].iters, cold.points[0].iters);
}

/// A λ path on a shared context computes each covariance statistic exactly
/// once, and the workspace arena does not grow after the first solve.
#[test]
fn lambda_path_reuses_context_state() {
    let prob = datagen::chain::generate(16, 16, 80, 13);
    let eng = NativeGemm::new(1);
    let base = SolveOptions {
        max_iter: 80,
        ..Default::default()
    };
    let ctx = SolverContext::new(&prob.data, &base, &eng);
    let popts = PathOptions {
        points: 4,
        min_ratio: 0.2,
        ..Default::default()
    };
    let res = fit_path_in_context(SolverKind::AltNewtonCd, &ctx, &base, &popts).unwrap();
    assert_eq!(res.points.len(), 4);
    assert_eq!(
        ctx.stat_computes(),
        3,
        "S_yy/S_xx/S_xy must be computed once for the whole path"
    );
    let misses_after_path = ctx.workspace().misses();
    // Another solve on the same context allocates nothing new.
    let _ = solve_in_context(SolverKind::AltNewtonCd, &ctx, &base, res.model.as_ref()).unwrap();
    assert_eq!(
        ctx.workspace().misses(),
        misses_after_path,
        "a further solve on a warm context must be allocation-free"
    );
}

/// Genomic workload through the whole pipe (simulator → block solver).
#[test]
fn genomic_pipeline_smoke() {
    let prob = datagen::genomic::generate(
        300,
        40,
        80,
        12,
        &datagen::genomic::GenomicOptions::default(),
    );
    let eng = NativeGemm::new(1);
    let opts = SolveOptions {
        lam_l: 0.15,
        lam_t: 0.15,
        max_iter: 40,
        budget: MemBudget::new(8 << 20),
        ..Default::default()
    };
    let res = solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng).unwrap();
    assert!(res.trace.final_f().unwrap().is_finite());
    assert!(res.model.theta_nnz() > 0, "no eQTLs recovered at all");
}
