//! Cross-module integration suite, split by subsystem:
//!
//! - [`common`] — shared dataset/option fixtures (documented seeds);
//! - [`solver_tests`] — solver↔solver agreement, budget enforcement,
//!   threading, structure recovery;
//! - [`path_tests`] — warm-started λ-path behavior + the golden-path
//!   regression (checked-in JSON);
//! - [`context_tests`] — `SolverContext` statistic caching and workspace
//!   arena reuse;
//! - [`cv_tests`] — K-fold cross-validated λ selection end to end;
//! - [`screening_tests`] — sequential strong rule, KKT post-check, and the
//!   screened-vs-full equivalence/efficiency guarantees (all three
//!   screen-honoring solvers);
//! - [`memwall_tests`] — `MemBudget::peak()` covers Cholesky factor bytes
//!   (within tolerance of the analytic estimate) and undersized budgets
//!   fail fast without allocating;
//! - [`checkpoint_tests`] — λ-path checkpoint round-trips: interrupt,
//!   resume, corrupted-tail recovery, 1e-8 objective equivalence;
//! - [`cluster_persistence_tests`] — the block solver's partition cache:
//!   re-clustering only on churn, forced-rebuild equivalence;
//! - [`parallel_cd_tests`] — colored conflict-free CD sweeps
//!   (`cd_threads`): serial-vs-colored 1e-6 objective equivalence,
//!   bitwise thread-count determinism, coloring-cache reuse and budget
//!   accounting;
//! - [`tiled_tests`] — `StatMode::Tiled` acceptance: tiled-vs-dense 1e-6
//!   equivalence (chain + cluster), budget-capped solves under the dense
//!   `S_xx` footprint with LRU eviction/spill, and screened runs computing
//!   strictly fewer tiles;
//! - [`refit_tests`] — streaming re-fit acceptance: warm refit after a
//!   window slide matches a cold fit on the same window at 1e-6 (dense and
//!   tiled), with zero statistic recomputation and no extra iterations,
//!   plus the `stat_rebuild_every` downdate drift guard end to end;
//! - [`storage_tests`] — out-of-core dataset storage: disk-backed solves
//!   match resident at 1e-6 with identical support, a resident-infeasible
//!   problem solves under a capped `MemBudget` with panel-cache evictions,
//!   window slides on disk match resident, the hostile panel-file fixture
//!   sweep, and serve's `storage:"disk"` load with panel counters;
//! - [`serve_tests`] — the serve subsystem: warm-context reuse across
//!   repeat fits (registry hit + warm start + zero statistic recompute),
//!   admission control on one shared `MemBudget`, LRU eviction, and
//!   batch ↔ standalone 1e-6 equivalence;
//! - [`abuse_tests`] — the untrusted-input surface under structured abuse:
//!   concurrent malformed/oversized/duplicate-id/disconnecting clients
//!   against a live engine (budget invariant `live + reserved ≤ limit`),
//!   cancel storms against running/queued/finished/unknown ids, plus the
//!   three seed-crash regressions (deep-nesting line, hostile load
//!   dimensions, unix-socket disconnect mid-response);
//! - [`concurrent_serve_tests`] — the serve concurrency properties: three
//!   threaded clients on one unix daemon (streamed `path` progress lines
//!   precede their terminal, ids never cross connections, same-connection
//!   cancel), and the save → evict → load(model) → refit roundtrip at
//!   1e-6;
//! - [`cli_tests`] — config/dataset plumbing plus the compiled `cggm`
//!   binary run as a subprocess (incl. a `serve` stdio session and a
//!   `batch` manifest);
//! - [`oracle_tests`] — the cross-language PJRT oracle (skips when
//!   artifacts are not built).
//!
//! Layout, fixture seeds, and golden-file regeneration are documented in
//! `docs/TESTING.md`.

#[path = "integration/common.rs"]
mod common;

#[path = "integration/solver_tests.rs"]
mod solver_tests;

#[path = "integration/path_tests.rs"]
mod path_tests;

#[path = "integration/context_tests.rs"]
mod context_tests;

#[path = "integration/cv_tests.rs"]
mod cv_tests;

#[path = "integration/screening_tests.rs"]
mod screening_tests;

#[path = "integration/memwall_tests.rs"]
mod memwall_tests;

#[path = "integration/checkpoint_tests.rs"]
mod checkpoint_tests;

#[path = "integration/cluster_persistence_tests.rs"]
mod cluster_persistence_tests;

#[path = "integration/parallel_cd_tests.rs"]
mod parallel_cd_tests;

#[path = "integration/tiled_tests.rs"]
mod tiled_tests;

#[path = "integration/refit_tests.rs"]
mod refit_tests;

#[path = "integration/storage_tests.rs"]
mod storage_tests;

#[path = "integration/serve_tests.rs"]
mod serve_tests;

#[path = "integration/abuse_tests.rs"]
mod abuse_tests;

#[path = "integration/concurrent_serve_tests.rs"]
mod concurrent_serve_tests;

#[path = "integration/cli_tests.rs"]
mod cli_tests;

#[path = "integration/oracle_tests.rs"]
mod oracle_tests;
