#!/usr/bin/env python3
"""Regenerate the hostile CGGMPAN1 panel-file fixtures.

Each fixture is named `<case>.<ok|err>.pan`: `.ok.` files must pass
`cggm::storage::read_meta` (and `.err.` files must fail it) — the sweep in
`tests/integration/storage_tests.rs` asserts exactly that. The writer here
mirrors the format spec in `rust/src/storage/mod.rs` (48-byte global
header, 64-byte shard headers, FNV-1a-64 checksums) so corruption can be
applied surgically, one field at a time.
"""

import os
import struct

HERE = os.path.dirname(os.path.abspath(__file__))
GLOBAL_MAGIC = b"CGGMPAN1"
SHARD_MAGIC = b"CGGMSHRD"
VERSION = 1
DIM_CAP = 1 << 24
COL_CAP = 1 << 32


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def global_header(p, q, magic=GLOBAL_MAGIC, version=VERSION, checksum=None):
    body = magic + struct.pack("<IIQQQ", version, 0, p, q, 0)
    ck = fnv1a64(body) if checksum is None else checksum
    return body + struct.pack("<Q", ck)


def shard(space, rows, col_start, col_end, payload_bytes=None, magic=SHARD_MAGIC,
          checksum=None, payload=None):
    want = rows * (col_end - col_start) * 8
    declared = want if payload_bytes is None else payload_bytes
    body = magic + struct.pack("<IIQQQQQ", space, 0, 0, rows, col_start, col_end, declared)
    ck = fnv1a64(body) if checksum is None else checksum
    data = b"\x00" * want if payload is None else payload
    return body + struct.pack("<Q", ck) + data


def write(name, data):
    with open(os.path.join(HERE, name), "wb") as f:
        f.write(data)
    print(f"{name}: {len(data)} bytes")


P, Q, N = 2, 1, 3

# Valid files: one X/Y shard pair, and a header-only (zero-sample) file.
write("good_tiny.ok.pan",
      global_header(P, Q) + shard(0, P, 0, N) + shard(1, Q, 0, N))
write("empty_header_only.ok.pan", global_header(P, Q))

# Global-header corruption, one field at a time.
write("bad_magic.err.pan", global_header(P, Q, magic=b"CGGMXXX1"))
write("bad_version.err.pan", global_header(P, Q, version=2))
write("bad_checksum.err.pan", global_header(P, Q, checksum=0xDEADBEEF))
# Dimension bombs carry a *valid* checksum: the cap check itself must stop
# any allocation sized by them.
write("dim_bomb_p.err.pan", global_header(DIM_CAP + 1, Q))
write("dim_bomb_q.err.pan", global_header(P, 1 << 40))
write("zero_dim.err.pan", global_header(0, Q))
write("truncated_global.err.pan", global_header(P, Q)[:20])

# Shard-table corruption behind a valid global header.
write("shard_bad_magic.err.pan",
      global_header(P, Q) + shard(0, P, 0, N, magic=b"CGGMXXXX"))
write("shard_bad_checksum.err.pan",
      global_header(P, Q) + shard(0, P, 0, N, checksum=1))
write("shard_bad_space.err.pan",
      global_header(P, Q) + shard(7, P, 0, N))
write("shard_partial_row_range.err.pan",
      global_header(P, Q) + shard(0, P - 1, 0, N))
write("shard_noncontiguous.err.pan",
      global_header(P, Q) + shard(0, P, 5, 5 + N))
write("shard_empty_cols.err.pan",
      global_header(P, Q) + shard(0, P, 0, 0, payload_bytes=0))
write("shard_col_bomb.err.pan",
      global_header(P, Q) + shard(0, P, 0, COL_CAP + 1, payload=b""))
write("shard_payload_lie.err.pan",
      global_header(P, Q) + shard(0, P, 0, N, payload_bytes=8))
write("partial_shard_header.err.pan",
      global_header(P, Q) + shard(0, P, 0, N)[:30])
write("torn_payload.err.pan",
      global_header(P, Q) + shard(0, P, 0, N)[: 64 + 5])
write("unbalanced_xy.err.pan",
      global_header(P, Q) + shard(0, P, 0, N))
