//! Linear-algebra substrates: dense matrices, sparse matrices, blocked
//! Cholesky (dense + sparse), multi-RHS conjugate gradients, and fill-reducing
//! orderings.
//!
//! The paper's implementation sat on C++/BLAS/sparse-Cholesky; everything
//! here is built from scratch (see DESIGN.md §3), with the flop-dense parts
//! routed through [`crate::gemm::GemmEngine`] so they can execute either on
//! the native blocked kernels or through PJRT/XLA artifacts.

pub mod cg;
pub mod chol_dense;
pub mod chol_sparse;
pub mod dense;
pub mod ordering;
pub mod sparse;

pub use cg::CgSolver;
pub use chol_dense::DenseChol;
pub use chol_sparse::SparseChol;
pub use dense::Mat;
pub use sparse::{CsrMat, SpRowMat};
