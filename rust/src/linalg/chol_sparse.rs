//! Up-looking sparse Cholesky (CSparse-style) with elimination tree and
//! optional RCM preordering.
//!
//! This is the line-search workhorse of the block solver: each Armijo trial
//! needs "is Λ + αD positive definite?" and `log|Λ + αD|` without ever
//! forming a dense q×q matrix (paper §4, following BigQUIC). On the paper's
//! graph families (banded chains, clustered networks) fill-in after RCM is
//! modest; a fill cap guards pathological cases so callers can fall back to
//! the dense path.

use super::ordering::{permute_sym, rcm, Permutation};
use super::sparse::SpRowMat;

/// Sparse lower-triangular Cholesky factor (CSC layout: per-column lists).
pub struct SparseChol {
    n: usize,
    /// Column pointers into `rows`/`vals` (L stored column-compressed).
    colptr: Vec<usize>,
    rows: Vec<usize>,
    vals: Vec<f64>,
    diag: Vec<f64>,
    perm: Permutation,
}

#[derive(Debug, thiserror::Error)]
pub enum SparseCholError {
    #[error("matrix not positive definite (pivot {pivot} at permuted index {index})")]
    NotPositiveDefinite { index: usize, pivot: f64 },
    #[error("fill-in {fill} exceeds cap {cap}; use the dense path")]
    TooMuchFill { fill: usize, cap: usize },
}

impl SparseChol {
    /// Factor PᵀAP = LLᵀ, where P is RCM (if `use_rcm`) or identity.
    /// `fill_cap` bounds nnz(L); exceeding it aborts with `TooMuchFill`.
    pub fn factor(
        a: &SpRowMat,
        use_rcm: bool,
        fill_cap: usize,
    ) -> Result<SparseChol, SparseCholError> {
        let n = a.rows();
        assert_eq!(n, a.cols());
        let perm = if use_rcm {
            rcm(a)
        } else {
            Permutation::identity(n)
        };
        let ap = if use_rcm { permute_sym(a, &perm) } else { a.clone() };

        // Row-linked up-looking factorization. L is built row by row:
        // row i of L solves L[0..i,0..i] · l_i = A[i, 0..i], then
        // L[i,i] = sqrt(A[i,i] - ||l_i||²).
        //
        // We keep L in per-column storage so the triangular solve can walk
        // column lists (standard up-looking sparse chol with an elimination
        // tree for reach computation).
        let etree = elimination_tree(&ap);

        let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n]; // (row, val), rows > col
        let mut diag = vec![0.0; n];
        let mut x = vec![0.0; n]; // dense scratch for row i
        let mut xmark = vec![usize::MAX; n];
        let mut stack = Vec::with_capacity(n);
        let mut nnz_l = 0usize;

        for i in 0..n {
            // Compute the "reach": nonzero pattern of row i of L = nodes on
            // paths from pattern(A[i, 0..i]) up the elimination tree to i.
            stack.clear();
            let mut pattern: Vec<usize> = Vec::new();
            for &(j, v) in ap.row(i) {
                if j > i {
                    continue;
                }
                if j == i {
                    x[i] = v;
                    xmark[i] = i;
                    continue;
                }
                // walk up etree from j until hitting a marked node or i
                let mut t = j;
                let mut path_len = 0;
                while t != usize::MAX && t < i && xmark[t] != i {
                    stack.push(t);
                    xmark[t] = i;
                    t = etree[t];
                    path_len += 1;
                    debug_assert!(path_len <= n);
                }
                // stack holds the path in leaf→root order; record values
                while let Some(u) = stack.pop() {
                    pattern.push(u);
                }
                x[j] = v; // A value (others on the path stay 0 until solve)
            }
            if xmark[i] != i {
                x[i] = 0.0; // missing diagonal in A's pattern: treat as 0
                xmark[i] = i;
            }
            // pattern must be processed in increasing column order for the
            // triangular solve.
            pattern.sort_unstable();

            // Sparse triangular solve: for each j in pattern (ascending),
            //   x[j] = x[j] / L[j,j]; then x[k] -= L[k,j] * x[j] for k > j in col j.
            for &j in &pattern {
                let xj = x[j] / diag[j];
                x[j] = xj;
                for &(k, ljk) in &cols[j] {
                    if k >= i {
                        continue;
                    }
                    if xmark[k] != i {
                        // Entry outside the reach cannot receive updates when
                        // the etree is correct; guard anyway.
                        xmark[k] = i;
                        x[k] = 0.0;
                    }
                    x[k] -= ljk * xj;
                }
                // Contribution to the diagonal: x[i] -= L[i,j]², but L[i,j]=x[j]
            }
            // Diagonal pivot: A_ii - Σ_j x[j]²  (x[j] = L[i,j])
            let mut d = x[i];
            for &j in &pattern {
                d -= x[j] * x[j];
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(SparseCholError::NotPositiveDefinite { index: i, pivot: d });
            }
            diag[i] = d.sqrt();
            // Scatter row i of L into the column lists.
            for &j in &pattern {
                let lij = x[j];
                if lij != 0.0 {
                    cols[j].push((i, lij));
                    nnz_l += 1;
                    if nnz_l > fill_cap {
                        return Err(SparseCholError::TooMuchFill {
                            fill: nnz_l,
                            cap: fill_cap,
                        });
                    }
                }
                x[j] = 0.0;
            }
            x[i] = 0.0;
        }

        // Freeze to CSC arrays.
        let mut colptr = Vec::with_capacity(n + 1);
        let mut rows = Vec::with_capacity(nnz_l);
        let mut vals = Vec::with_capacity(nnz_l);
        colptr.push(0);
        for j in 0..n {
            // rows were appended in increasing i automatically
            for &(r, v) in &cols[j] {
                rows.push(r);
                vals.push(v);
            }
            colptr.push(rows.len());
        }
        Ok(SparseChol {
            n,
            colptr,
            rows,
            vals,
            diag,
            perm,
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// nnz of L including the diagonal.
    pub fn nnz(&self) -> usize {
        self.vals.len() + self.n
    }

    /// Resident bytes of the frozen factor: CSC arrays (row index + value
    /// per off-diagonal entry), column pointers, diagonal, and the two
    /// permutation vectors — what the memory budget charges for keeping
    /// this factor alive.
    pub fn bytes(&self) -> usize {
        self.vals.len() * 16 + self.colptr.len() * 8 + self.diag.len() * 8 + self.perm.len() * 16
    }

    pub fn logdet(&self) -> f64 {
        self.diag.iter().map(|d| d.ln()).sum::<f64>() * 2.0
    }

    /// Solve A x = b (applies the internal permutation on both ends).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut y = self.perm.apply(b);
        self.solve_lower_inplace(&mut y);
        self.solve_upper_inplace(&mut y);
        self.perm.apply_inv(&y)
    }

    /// ‖L⁻¹ Pb‖² = bᵀ A⁻¹ b (line-search trace terms, one triangular solve).
    pub fn quad_form_inv(&self, b: &[f64]) -> f64 {
        let mut y = self.perm.apply(b);
        self.solve_lower_inplace(&mut y);
        y.iter().map(|v| v * v).sum()
    }

    fn solve_lower_inplace(&self, y: &mut [f64]) {
        // L in CSC: forward solve walks columns.
        for j in 0..self.n {
            let yj = y[j] / self.diag[j];
            y[j] = yj;
            for k in self.colptr[j]..self.colptr[j + 1] {
                y[self.rows[k]] -= self.vals[k] * yj;
            }
        }
    }

    /// Sampling transform: returns `ε = P L⁻ᵀ w`, so that `cov(ε) = A⁻¹`
    /// when `w ~ N(0, I)` (used by the CGGM sampler).
    pub fn sample_transform(&self, w: &[f64]) -> Vec<f64> {
        let mut y = w.to_vec();
        self.solve_upper_inplace(&mut y);
        self.perm.apply_inv(&y)
    }

    fn solve_upper_inplace(&self, y: &mut [f64]) {
        // Lᵀ solve: backward over columns of L (= rows of Lᵀ).
        for j in (0..self.n).rev() {
            let mut s = y[j];
            for k in self.colptr[j]..self.colptr[j + 1] {
                s -= self.vals[k] * y[self.rows[k]];
            }
            y[j] = s / self.diag[j];
        }
    }
}

/// Elimination tree of the symmetric pattern (Liu's algorithm with path
/// compression).
fn elimination_tree(a: &SpRowMat) -> Vec<usize> {
    let n = a.rows();
    let mut parent = vec![usize::MAX; n];
    let mut ancestor = vec![usize::MAX; n];
    for i in 0..n {
        for &(j, _) in a.row(i) {
            if j >= i {
                continue;
            }
            let mut t = j;
            while t != usize::MAX && t < i {
                let next = ancestor[t];
                ancestor[t] = i;
                if next == usize::MAX {
                    parent[t] = i;
                    break;
                }
                t = next;
            }
        }
    }
    parent
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::gemm::GemmEngine;
    use crate::linalg::chol_dense::DenseChol;
    use crate::linalg::dense::Mat;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_all_close, check_close, property};

    fn random_sparse_spd(rng: &mut Rng, n: usize, extra_edges: usize) -> SpRowMat {
        let mut a = SpRowMat::zeros(n, n);
        for _ in 0..extra_edges {
            let i = rng.below(n);
            let j = rng.below(n);
            if i != j {
                a.set_sym(i, j, rng.normal() * 0.3);
            }
        }
        // diagonal dominance => SPD
        for i in 0..n {
            let rowsum: f64 = a.row(i).iter().map(|e| e.1.abs()).sum();
            a.set(i, i, rowsum + 1.0 + rng.uniform());
        }
        a
    }

    #[test]
    fn matches_dense_cholesky() {
        property(40, |rng| {
            let n = 2 + rng.below(40);
            let a = random_sparse_spd(rng, n, n * 2);
            for use_rcm in [false, true] {
                let sc = SparseChol::factor(&a, use_rcm, usize::MAX)
                    .map_err(|e| e.to_string())?;
                let eng = NativeGemm::new(1);
                let dc = DenseChol::factor(&a.to_dense(), &eng).map_err(|e| e.to_string())?;
                check_close(sc.logdet(), dc.logdet(), 1e-9, "logdet")?;
                let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let xs = sc.solve(&b);
                let mut xd = b.clone();
                dc.solve(&mut xd);
                check_all_close(&xs, &xd, 1e-7, "solve")?;
                check_close(
                    sc.quad_form_inv(&b),
                    dc.quad_form_inv(&b),
                    1e-8,
                    "quad form",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn detects_indefinite() {
        let mut a = SpRowMat::eye(4);
        a.set(2, 2, -3.0);
        match SparseChol::factor(&a, false, usize::MAX) {
            Err(SparseCholError::NotPositiveDefinite { .. }) => {}
            other => panic!("expected NotPD, got {:?}", other.is_ok()),
        }
        // A PD matrix whose indefiniteness only appears after elimination:
        let mut b = SpRowMat::zeros(2, 2);
        b.set(0, 0, 1.0);
        b.set_sym(0, 1, 2.0);
        b.set(1, 1, 1.0); // eigenvalues -1, 3
        assert!(SparseChol::factor(&b, false, usize::MAX).is_err());
    }

    #[test]
    fn chain_has_no_fill() {
        let n = 500;
        let mut a = SpRowMat::zeros(n, n);
        for i in 0..n {
            a.set(i, i, 2.25);
            if i > 0 {
                a.set_sym(i, i - 1, 1.0);
            }
        }
        let sc = SparseChol::factor(&a, false, usize::MAX).unwrap();
        // Bidiagonal factor: n-1 off-diagonal entries, no fill.
        assert_eq!(sc.nnz(), n + (n - 1));
    }

    #[test]
    fn fill_cap_enforced() {
        let mut rng = Rng::new(4);
        let a = random_sparse_spd(&mut rng, 60, 400);
        match SparseChol::factor(&a, false, 10) {
            Err(SparseCholError::TooMuchFill { .. }) => {}
            _ => panic!("expected fill cap"),
        }
    }

    #[test]
    fn solve_identity_roundtrip() {
        property(20, |rng| {
            let n = 1 + rng.below(25);
            let a = random_sparse_spd(rng, n, n);
            let sc = SparseChol::factor(&a, true, usize::MAX).map_err(|e| e.to_string())?;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x);
            check_all_close(&sc.solve(&b), &x, 1e-7, "Ax=b roundtrip")
        });
    }

    #[test]
    fn dense_vs_sparse_on_dense_pattern() {
        // Fully dense SPD matrix through the sparse path.
        let mut rng = Rng::new(8);
        let n = 20;
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let eng = NativeGemm::new(1);
        let mut ad = Mat::zeros(n, n);
        eng.gemm_tn(1.0, &b, &b, 0.0, &mut ad);
        for i in 0..n {
            ad[(i, i)] += n as f64;
        }
        ad.symmetrize();
        let asp = SpRowMat::from_dense(&ad, 0.0);
        let sc = SparseChol::factor(&asp, false, usize::MAX).unwrap();
        let dc = DenseChol::factor(&ad, &eng).unwrap();
        assert!((sc.logdet() - dc.logdet()).abs() < 1e-8);
    }
}
