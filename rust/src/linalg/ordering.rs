//! Fill-reducing orderings for sparse Cholesky.
//!
//! Reverse Cuthill–McKee (RCM): BFS from a pseudo-peripheral vertex,
//! neighbors visited in increasing-degree order, then reversed. Very
//! effective on the paper's graph families (chains are banded; clustered
//! random graphs become tightly banded per cluster).

use crate::linalg::sparse::SpRowMat;

/// Permutation `perm` such that `perm[new_index] = old_index`.
#[derive(Clone, Debug)]
pub struct Permutation {
    pub perm: Vec<usize>,
    pub inv: Vec<usize>,
}

impl Permutation {
    pub fn identity(n: usize) -> Permutation {
        Permutation {
            perm: (0..n).collect(),
            inv: (0..n).collect(),
        }
    }

    pub fn from_perm(perm: Vec<usize>) -> Permutation {
        let mut inv = vec![0; perm.len()];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        Permutation { perm, inv }
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Apply to a vector: out[new] = x[perm[new]].
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.perm.iter().map(|&old| x[old]).collect()
    }

    /// Inverse application: out[perm[new]] = x[new].
    pub fn apply_inv(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; x.len()];
        for (new, &old) in self.perm.iter().enumerate() {
            out[old] = x[new];
        }
        out
    }
}

/// Symmetric permutation of a sparse symmetric matrix: B = PᵀAP with
/// B[new_i, new_j] = A[perm[new_i], perm[new_j]].
pub fn permute_sym(a: &SpRowMat, p: &Permutation) -> SpRowMat {
    let n = a.rows();
    assert_eq!(n, p.len());
    let mut out = SpRowMat::zeros(n, n);
    for new_i in 0..n {
        let old_i = p.perm[new_i];
        for &(old_j, v) in a.row(old_i) {
            out.set(new_i, p.inv[old_j], v);
        }
    }
    out
}

/// Reverse Cuthill–McKee ordering of the symmetric pattern of `a`.
pub fn rcm(a: &SpRowMat) -> Permutation {
    let n = a.rows();
    let degree: Vec<usize> = (0..n).map(|i| a.row(i).len()).collect();
    let mut visited = vec![false; n];
    let mut order: Vec<usize> = Vec::with_capacity(n);

    while order.len() < n {
        // Start each component from its minimum-degree unvisited vertex
        // (cheap pseudo-peripheral heuristic).
        let start = (0..n)
            .filter(|&i| !visited[i])
            .min_by_key(|&i| degree[i])
            .unwrap();
        let mut queue = std::collections::VecDeque::new();
        visited[start] = true;
        queue.push_back(start);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            let mut nbrs: Vec<usize> = a
                .row(u)
                .iter()
                .map(|e| e.0)
                .filter(|&v| v != u && !visited[v])
                .collect();
            nbrs.sort_by_key(|&v| degree[v]);
            for v in nbrs {
                if !visited[v] {
                    visited[v] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    order.reverse();
    Permutation::from_perm(order)
}

/// Bandwidth of the symmetric pattern (for tests: RCM should not increase it
/// much, and should shrink it on shuffled banded matrices).
pub fn bandwidth(a: &SpRowMat) -> usize {
    let mut bw = 0;
    for i in 0..a.rows() {
        for &(j, _) in a.row(i) {
            bw = bw.max(i.abs_diff(j));
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::property;

    fn chain_pattern(n: usize) -> SpRowMat {
        let mut a = SpRowMat::zeros(n, n);
        for i in 0..n {
            a.set(i, i, 2.0);
            if i > 0 {
                a.set_sym(i, i - 1, 1.0);
            }
        }
        a
    }

    #[test]
    fn permutation_roundtrip() {
        property(50, |rng| {
            let n = 1 + rng.below(30);
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            let p = Permutation::from_perm(perm);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = p.apply_inv(&p.apply(&x));
            crate::util::testing::check_all_close(&x, &y, 0.0, "perm roundtrip")
        });
    }

    #[test]
    fn permute_sym_preserves_values() {
        property(30, |rng| {
            let n = 2 + rng.below(15);
            let mut a = SpRowMat::zeros(n, n);
            for i in 0..n {
                a.set(i, i, 1.0 + rng.uniform());
                if rng.bernoulli(0.5) {
                    let j = rng.below(n);
                    a.set_sym(i, j, rng.normal());
                }
            }
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            let p = Permutation::from_perm(perm);
            let b = permute_sym(&a, &p);
            for new_i in 0..n {
                for &(new_j, v) in b.row(new_i) {
                    let want = a.get(p.perm[new_i], p.perm[new_j]);
                    if (v - want).abs() > 0.0 {
                        return Err(format!("value mismatch at ({new_i},{new_j})"));
                    }
                }
            }
            if b.nnz() != a.nnz() {
                return Err("nnz changed".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn rcm_recovers_band_on_shuffled_chain() {
        let n = 200;
        let a = chain_pattern(n);
        // Shuffle, destroying the band.
        let mut perm: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(99);
        rng.shuffle(&mut perm);
        let shuffled = permute_sym(&a, &Permutation::from_perm(perm));
        assert!(bandwidth(&shuffled) > 10);
        // RCM should restore a narrow band.
        let p = rcm(&shuffled);
        let restored = permute_sym(&shuffled, &p);
        assert!(
            bandwidth(&restored) <= 2,
            "rcm bandwidth = {}",
            bandwidth(&restored)
        );
    }

    #[test]
    fn rcm_is_a_permutation() {
        property(30, |rng| {
            let n = 1 + rng.below(40);
            let mut a = SpRowMat::zeros(n, n);
            for i in 0..n {
                a.set(i, i, 1.0);
                if rng.bernoulli(0.3) {
                    a.set_sym(i, rng.below(n), 1.0);
                }
            }
            let p = rcm(&a);
            let mut seen = p.perm.clone();
            seen.sort_unstable();
            if seen == (0..n).collect::<Vec<_>>() {
                Ok(())
            } else {
                Err("not a permutation".to_string())
            }
        });
    }
}
