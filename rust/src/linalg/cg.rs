//! Multi-RHS conjugate gradient solver.
//!
//! The block solver obtains columns of Σ = Λ⁻¹ by solving Λ Σ_i = e_i
//! (paper §4.1: "with conjugate gradient method in O(m_Λ K) time, where K is
//! the number of conjugate gradient iterations", K ≈ 10). Multiple columns of
//! a block are solved in parallel across threads (paper §Parallelization).
//!
//! Jacobi (diagonal) preconditioning keeps K small on the paper's
//! diagonally-dominant graph families.

use super::dense::{axpy, dot, Mat};
use super::sparse::CsrMat;
use crate::util::threadpool::Parallelism;

/// Conjugate gradient configuration + the frozen system matrix.
pub struct CgSolver {
    a: CsrMat,
    /// Inverse diagonal (Jacobi preconditioner).
    dinv: Vec<f64>,
    pub tol: f64,
    pub max_iter: usize,
}

/// Per-solve statistics (K in the paper's complexity analysis).
#[derive(Debug, Default, Clone, Copy)]
pub struct CgStats {
    pub iterations: usize,
    pub converged: bool,
}

impl CgSolver {
    /// Build from a symmetric positive definite CSR matrix.
    pub fn new(a: CsrMat, tol: f64, max_iter: usize) -> CgSolver {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut dinv = vec![1.0; n];
        for i in 0..n {
            let (idx, val) = a.row(i);
            for (j, v) in idx.iter().zip(val) {
                if *j == i && *v != 0.0 {
                    dinv[i] = 1.0 / v;
                }
            }
        }
        CgSolver {
            a,
            dinv,
            tol,
            max_iter,
        }
    }

    pub fn n(&self) -> usize {
        self.a.rows
    }

    /// Solve A x = b with warm start `x` (pass zeros for a cold start).
    pub fn solve(&self, b: &[f64], x: &mut [f64]) -> CgStats {
        let n = self.n();
        assert_eq!(b.len(), n);
        assert_eq!(x.len(), n);
        let mut r = vec![0.0; n];
        self.a.matvec(x, &mut r);
        for i in 0..n {
            r[i] = b[i] - r[i];
        }
        let bnorm = dot(b, b).sqrt().max(1e-300);
        let mut z: Vec<f64> = r.iter().zip(&self.dinv).map(|(ri, di)| ri * di).collect();
        let mut p = z.clone();
        let mut rz = dot(&r, &z);
        let mut ap = vec![0.0; n];
        for it in 0..self.max_iter {
            if dot(&r, &r).sqrt() <= self.tol * bnorm {
                return CgStats {
                    iterations: it,
                    converged: true,
                };
            }
            self.a.matvec(&p, &mut ap);
            let pap = dot(&p, &ap);
            if pap <= 0.0 {
                // Not PD (or breakdown) — report non-convergence.
                return CgStats {
                    iterations: it,
                    converged: false,
                };
            }
            let alpha = rz / pap;
            axpy(alpha, &p, x);
            axpy(-alpha, &ap, &mut r);
            for i in 0..n {
                z[i] = r[i] * self.dinv[i];
            }
            let rz_new = dot(&r, &z);
            let beta = rz_new / rz;
            rz = rz_new;
            for i in 0..n {
                p[i] = z[i] + beta * p[i];
            }
        }
        let converged = dot(&r, &r).sqrt() <= self.tol * bnorm;
        CgStats {
            iterations: self.max_iter,
            converged,
        }
    }

    /// Solve A X = I[:, cols] — extract columns of A⁻¹ into the rows of
    /// `out` (row-major: `out.row(c)` = column `cols[c]` of A⁻¹, exploiting
    /// symmetry of A⁻¹). Parallel across columns. Returns the mean K.
    pub fn inverse_columns(
        &self,
        columns: &[usize],
        out: &mut Mat,
        par: &Parallelism,
    ) -> f64 {
        assert_eq!(out.rows(), columns.len());
        assert_eq!(out.cols(), self.n());
        let iters = std::sync::atomic::AtomicUsize::new(0);
        // Each output row is written by exactly one task.
        par.parallel_chunks_mut(out.data_mut(), self.n(), |c, row| {
            let col = columns[c];
            let mut b = vec![0.0; self.n()];
            b[col] = 1.0;
            let stats = self.solve(&b, row);
            iters.fetch_add(stats.iterations, std::sync::atomic::Ordering::Relaxed);
        });
        iters.load(std::sync::atomic::Ordering::Relaxed) as f64 / columns.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::linalg::chol_dense::DenseChol;
    use crate::linalg::sparse::SpRowMat;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_all_close, property};

    fn random_sparse_spd(rng: &mut Rng, n: usize) -> SpRowMat {
        let mut a = SpRowMat::zeros(n, n);
        for _ in 0..2 * n {
            let (i, j) = (rng.below(n), rng.below(n));
            if i != j {
                a.set_sym(i, j, 0.3 * rng.normal());
            }
        }
        for i in 0..n {
            let rowsum: f64 = a.row(i).iter().map(|e| e.1.abs()).sum();
            a.set(i, i, rowsum + 1.0);
        }
        a
    }

    #[test]
    fn solves_match_cholesky() {
        property(30, |rng| {
            let n = 2 + rng.below(60);
            let a = random_sparse_spd(rng, n);
            let solver = CgSolver::new(a.to_csr(), 1e-12, 10 * n);
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xtrue);
            let mut x = vec![0.0; n];
            let stats = solver.solve(&b, &mut x);
            if !stats.converged {
                return Err(format!("no convergence in {} iters", stats.iterations));
            }
            check_all_close(&x, &xtrue, 1e-7, "cg solve")
        });
    }

    #[test]
    fn warm_start_takes_fewer_iterations() {
        let mut rng = Rng::new(3);
        let n = 100;
        let a = random_sparse_spd(&mut rng, n);
        let solver = CgSolver::new(a.to_csr(), 1e-10, 1000);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut cold = vec![0.0; n];
        let k_cold = solver.solve(&b, &mut cold).iterations;
        // Warm start from the solution: should converge immediately.
        let mut warm = cold.clone();
        let k_warm = solver.solve(&b, &mut warm).iterations;
        assert!(k_warm <= 1, "warm K = {k_warm}");
        assert!(k_cold > k_warm);
    }

    #[test]
    fn inverse_columns_match_dense_inverse() {
        let mut rng = Rng::new(5);
        let n = 40;
        let a = random_sparse_spd(&mut rng, n);
        let solver = CgSolver::new(a.to_csr(), 1e-12, 1000);
        let cols = vec![0, 7, 13, 39];
        let mut out = Mat::zeros(cols.len(), n);
        let mean_k = solver.inverse_columns(&cols, &mut out, &Parallelism::new(2));
        assert!(mean_k > 0.0);
        let eng = NativeGemm::new(1);
        let inv = DenseChol::factor(&a.to_dense(), &eng).unwrap().inverse(&eng);
        for (c, &col) in cols.iter().enumerate() {
            for i in 0..n {
                assert!(
                    (out[(c, i)] - inv[(i, col)]).abs() < 1e-7,
                    "col {col} row {i}"
                );
            }
        }
    }

    #[test]
    fn chain_matrix_converges_fast() {
        // The paper's chain Λ (diag 2.25, off-diag 1) is well conditioned;
        // CG should take K ~ tens of iterations, matching the K≈10 claim's
        // order of magnitude.
        let n = 1000;
        let mut a = SpRowMat::zeros(n, n);
        for i in 0..n {
            a.set(i, i, 2.25);
            if i > 0 {
                a.set_sym(i, i - 1, 1.0);
            }
        }
        let solver = CgSolver::new(a.to_csr(), 1e-9, 10_000);
        let mut x = vec![0.0; n];
        let mut b = vec![0.0; n];
        b[n / 2] = 1.0;
        let stats = solver.solve(&b, &mut x);
        assert!(stats.converged);
        assert!(stats.iterations < 200, "K = {}", stats.iterations);
    }
}
