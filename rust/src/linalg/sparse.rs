//! Sparse matrix substrates.
//!
//! Two representations, matching how the solvers use them:
//!
//! - [`SpRowMat`]: editable per-row sorted `(col, val)` lists. The parameter
//!   matrices `Λ`, `Θ` and the Newton directions `Δ` live here — the active
//!   set fixes the pattern once per Newton iteration, after which updates are
//!   in-place value writes. Symmetric matrices store both triangles.
//! - [`CsrMat`]: frozen CSR for fast SpMV/SpMM (conjugate-gradient matvecs,
//!   `ΘΣ` products).

use super::dense::{axpy, Mat};

/// Editable sparse row matrix (sorted column lists per row).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpRowMat {
    rows: usize,
    cols: usize,
    data: Vec<Vec<(usize, f64)>>,
}

impl SpRowMat {
    pub fn zeros(rows: usize, cols: usize) -> SpRowMat {
        SpRowMat {
            rows,
            cols,
            data: vec![Vec::new(); rows],
        }
    }

    /// Identity (for Λ initialization).
    pub fn eye(n: usize) -> SpRowMat {
        let mut m = SpRowMat::zeros(n, n);
        for i in 0..n {
            m.data[i].push((i, 1.0));
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().map(|r| r.len()).sum()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[(usize, f64)] {
        &self.data[i]
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self.data[i].binary_search_by_key(&j, |e| e.0) {
            Ok(k) => self.data[i][k].1,
            Err(_) => 0.0,
        }
    }

    /// Set entry (inserting if absent; removing is done via `prune`).
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        match self.data[i].binary_search_by_key(&j, |e| e.0) {
            Ok(k) => self.data[i][k].1 = v,
            Err(k) => self.data[i].insert(k, (j, v)),
        }
    }

    /// Add to entry (inserting if absent).
    pub fn add(&mut self, i: usize, j: usize, v: f64) {
        match self.data[i].binary_search_by_key(&j, |e| e.0) {
            Ok(k) => self.data[i][k].1 += v,
            Err(k) => self.data[i].insert(k, (j, v)),
        }
    }

    /// Symmetric set: writes (i,j) and (j,i).
    pub fn set_sym(&mut self, i: usize, j: usize, v: f64) {
        self.set(i, j, v);
        if i != j {
            self.set(j, i, v);
        }
    }

    /// Symmetric add.
    pub fn add_sym(&mut self, i: usize, j: usize, v: f64) {
        self.add(i, j, v);
        if i != j {
            self.add(j, i, v);
        }
    }

    /// Ensure entry exists (value 0 if new) — used when freezing active sets.
    pub fn touch(&mut self, i: usize, j: usize) {
        if self.data[i].binary_search_by_key(&j, |e| e.0).is_err() {
            let k = self.data[i].partition_point(|e| e.0 < j);
            self.data[i].insert(k, (j, 0.0));
        }
    }

    /// Remove exact zeros (and entries below `tol` in absolute value).
    pub fn prune(&mut self, tol: f64) {
        for r in &mut self.data {
            r.retain(|e| e.1.abs() > tol);
        }
    }

    /// self += alpha * other (pattern union).
    pub fn add_scaled(&mut self, alpha: f64, other: &SpRowMat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for i in 0..self.rows {
            for &(j, v) in other.row(i) {
                self.add(i, j, alpha * v);
            }
        }
    }

    /// Sum of |values| (the l1 penalty term).
    pub fn l1_norm(&self) -> f64 {
        self.data
            .iter()
            .map(|r| r.iter().map(|e| e.1.abs()).sum::<f64>())
            .sum()
    }

    pub fn max_abs(&self) -> f64 {
        self.data
            .iter()
            .flat_map(|r| r.iter())
            .fold(0.0, |m, e| m.max(e.1.abs()))
    }

    /// Number of non-empty rows (p̃ in the paper's §4.2 analysis).
    pub fn nonempty_rows(&self) -> usize {
        self.data.iter().filter(|r| !r.is_empty()).count()
    }

    /// Indices of non-empty rows.
    pub fn nonempty_row_indices(&self) -> Vec<usize> {
        (0..self.rows).filter(|&i| !self.data[i].is_empty()).collect()
    }

    /// Zero every stored value, keeping the pattern.
    pub fn zero_values(&mut self) {
        for r in &mut self.data {
            for e in r {
                e.1 = 0.0;
            }
        }
    }

    /// Dense copy (tests / small problems only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for &(j, v) in self.row(i) {
                m[(i, j)] = v;
            }
        }
        m
    }

    pub fn from_dense(m: &Mat, tol: f64) -> SpRowMat {
        let mut s = SpRowMat::zeros(m.rows(), m.cols());
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                if m[(i, j)].abs() > tol {
                    s.data[i].push((j, m[(i, j)]));
                }
            }
        }
        s
    }

    /// Frozen CSR copy.
    pub fn to_csr(&self) -> CsrMat {
        let mut indptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        indptr.push(0);
        for r in &self.data {
            for &(j, v) in r {
                indices.push(j);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        CsrMat {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        self.data
            .iter()
            .map(|r| r.iter().map(|&(j, v)| v * x[j]).sum())
            .collect()
    }

    /// Symmetric check (tests).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for &(j, v) in self.row(i) {
                if (v - self.get(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Estimated bytes of storage.
    pub fn bytes(&self) -> usize {
        self.nnz() * std::mem::size_of::<(usize, f64)>()
            + self.rows * std::mem::size_of::<Vec<(usize, f64)>>()
    }
}

/// Frozen CSR matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMat {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub values: Vec<f64>,
}

impl CsrMat {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let mut s = 0.0;
            for (j, v) in idx.iter().zip(val) {
                s += v * x[*j];
            }
            y[i] = s;
        }
    }

    /// Y = A · X for dense row-major X (cols(A) × k) → Y (rows(A) × k).
    /// Row-axpy formulation keeps all accesses contiguous.
    pub fn spmm(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.rows(), self.cols);
        assert_eq!(y.rows(), self.rows);
        assert_eq!(y.cols(), x.cols());
        y.fill(0.0);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let yrow = y.row_mut(i);
            for (j, v) in idx.iter().zip(val) {
                axpy(*v, x.row(*j), yrow);
            }
        }
    }

    /// Dense copy (tests).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (j, v) in idx.iter().zip(val) {
                m[(i, *j)] = *v;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_all_close, property};

    fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> SpRowMat {
        let mut m = SpRowMat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                if rng.bernoulli(density) {
                    m.set(i, j, rng.normal());
                }
            }
        }
        m
    }

    #[test]
    fn set_get_add() {
        let mut m = SpRowMat::zeros(3, 3);
        m.set(0, 2, 5.0);
        m.add(0, 2, 1.0);
        m.add(1, 1, 2.0);
        assert_eq!(m.get(0, 2), 6.0);
        assert_eq!(m.get(1, 1), 2.0);
        assert_eq!(m.get(2, 2), 0.0);
        assert_eq!(m.nnz(), 2);
        m.set(0, 2, 0.0);
        m.prune(0.0);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn rows_stay_sorted() {
        property(100, |rng| {
            let mut m = SpRowMat::zeros(1, 50);
            for _ in 0..30 {
                m.set(0, rng.below(50), rng.normal());
            }
            let cols: Vec<usize> = m.row(0).iter().map(|e| e.0).collect();
            let mut sorted = cols.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if cols == sorted {
                Ok(())
            } else {
                Err(format!("row not sorted/deduped: {cols:?}"))
            }
        });
    }

    #[test]
    fn dense_roundtrip() {
        property(50, |rng| {
            let r = 1 + rng.below(8);
            let c = 1 + rng.below(8);
            let m = random_sparse(rng, r, c, 0.4);
            let back = SpRowMat::from_dense(&m.to_dense(), 0.0);
            if m == back {
                Ok(())
            } else {
                Err("roundtrip mismatch".to_string())
            }
        });
    }

    #[test]
    fn csr_matvec_matches_dense() {
        property(50, |rng| {
            let r = 1 + rng.below(10);
            let c = 1 + rng.below(10);
            let m = random_sparse(rng, r, c, 0.3);
            let x: Vec<f64> = (0..c).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; r];
            m.to_csr().matvec(&x, &mut y);
            let want = m.to_dense().matvec(&x);
            check_all_close(&y, &want, 1e-13, "csr matvec")
        });
    }

    #[test]
    fn spmm_matches_dense() {
        property(30, |rng| {
            let r = 1 + rng.below(8);
            let c = 1 + rng.below(8);
            let k = 1 + rng.below(6);
            let m = random_sparse(rng, r, c, 0.4);
            let x = Mat::from_fn(c, k, |_, _| rng.normal());
            let mut y = Mat::zeros(r, k);
            m.to_csr().spmm(&x, &mut y);
            let md = m.to_dense();
            let mut want = Mat::zeros(r, k);
            for i in 0..r {
                for jj in 0..k {
                    let mut s = 0.0;
                    for t in 0..c {
                        s += md[(i, t)] * x[(t, jj)];
                    }
                    want[(i, jj)] = s;
                }
            }
            check_all_close(y.data(), want.data(), 1e-13, "spmm")
        });
    }

    #[test]
    fn symmetric_ops() {
        let mut m = SpRowMat::zeros(4, 4);
        m.set_sym(1, 3, 2.0);
        m.add_sym(1, 3, 1.0);
        assert_eq!(m.get(3, 1), 3.0);
        assert!(m.is_symmetric(0.0));
        m.set(0, 1, 9.0);
        assert!(!m.is_symmetric(0.0));
    }

    #[test]
    fn l1_and_row_stats() {
        let mut m = SpRowMat::zeros(3, 3);
        m.set(0, 0, -2.0);
        m.set(2, 1, 3.0);
        assert_eq!(m.l1_norm(), 5.0);
        assert_eq!(m.nonempty_rows(), 2);
        assert_eq!(m.nonempty_row_indices(), vec![0, 2]);
        assert_eq!(m.max_abs(), 3.0);
    }
}
