//! Dense row-major `f64` matrices and the vector kernels the solvers lean on.
//!
//! Row-major is chosen deliberately: the coordinate-descent inner loops
//! (DESIGN.md §4) express all O(q)/O(p) work as dots/axpys over contiguous
//! rows (`Σ` is symmetric so its rows double as columns; `U`, `V`, `R` are
//! maintained rowwise).

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Build by element function.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two disjoint mutable rows (for the symmetric U update).
    pub fn two_rows_mut(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert!(i != j && i < self.rows && j < self.rows);
        let c = self.cols;
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let (a, b) = self.data.split_at_mut(hi * c);
        let lo_row = &mut a[lo * c..lo * c + c];
        let hi_row = &mut b[..c];
        if i < j {
            (lo_row, hi_row)
        } else {
            (hi_row, lo_row)
        }
    }

    /// Copy of column j.
    pub fn col_vec(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn fill(&mut self, v: f64) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Write the transpose into a preallocated matrix (workspace-arena path:
    /// no allocation).
    pub fn transpose_into(&self, out: &mut Mat) {
        assert_eq!((out.rows, out.cols), (self.cols, self.rows));
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
    }

    /// Overwrite with another matrix of the same shape.
    pub fn copy_from(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.copy_from_slice(&other.data);
    }

    /// Copy the selected full rows into a preallocated `rows.len() × cols`
    /// matrix (the block solvers' covariance panels).
    pub fn rows_into(&self, rows: &[usize], out: &mut Mat) {
        assert_eq!((out.rows, out.cols), (rows.len(), self.cols));
        for (ri, &i) in rows.iter().enumerate() {
            out.row_mut(ri).copy_from_slice(self.row(i));
        }
    }

    /// Consume into the backing row-major buffer (workspace-arena checkin).
    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Submatrix copy of the given rows and columns.
    pub fn submatrix(&self, rows: &[usize], cols: &[usize]) -> Mat {
        let mut m = Mat::zeros(rows.len(), cols.len());
        for (ri, &i) in rows.iter().enumerate() {
            for (cj, &j) in cols.iter().enumerate() {
                m[(ri, cj)] = self[(i, j)];
            }
        }
        m
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// y = Aᵀ x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            axpy(x[i], self.row(i), &mut y);
        }
        y
    }

    /// self += alpha * other.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Symmetrize in place: A ← (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Working-set size in bytes (for the memory governor).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product over contiguous slices — the CD inner-loop primitive.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation; autovectorizes with target-cpu=native.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let k = c * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for k in chunks * 4..n {
        s += a[k] * b[k];
    }
    s
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Sum of |x|.
#[inline]
pub fn asum(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{check_all_close, property};

    #[test]
    fn index_and_rows() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col_vec(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn two_rows_mut_disjoint() {
        let mut m = Mat::from_fn(4, 3, |i, _| i as f64);
        let (a, b) = m.two_rows_mut(3, 1);
        assert_eq!(a[0], 3.0);
        assert_eq!(b[0], 1.0);
        a[0] = -1.0;
        b[0] = -2.0;
        assert_eq!(m[(3, 0)], -1.0);
        assert_eq!(m[(1, 0)], -2.0);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = Mat::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(m.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
        assert_eq!(m.transposed()[(2, 1)], 6.0);
    }

    #[test]
    fn dot_matches_naive() {
        property(200, |rng| {
            let n = rng.below(50);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            crate::util::testing::check_close(dot(&a, &b), naive, 1e-12, "dot")
        });
    }

    #[test]
    fn symmetrize_works() {
        let mut m = Mat::from_rows(2, 2, vec![1.0, 2.0, 4.0, 3.0]);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn buffer_reuse_helpers() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        // transpose_into matches transposed().
        let mut t = Mat::zeros(4, 3);
        m.transpose_into(&mut t);
        assert_eq!(t, m.transposed());
        // copy_from overwrites.
        let mut c = Mat::from_fn(3, 4, |_, _| -1.0);
        c.copy_from(&m);
        assert_eq!(c, m);
        // rows_into selects full rows.
        let mut two = Mat::zeros(2, 4);
        m.rows_into(&[2, 0], &mut two);
        assert_eq!(two.row(0), m.row(2));
        assert_eq!(two.row(1), m.row(0));
        // into_data round-trips through from_rows.
        let data = m.clone().into_data();
        assert_eq!(Mat::from_rows(3, 4, data), m);
    }

    #[test]
    fn transpose_roundtrip_property() {
        property(50, |rng| {
            let r = 1 + rng.below(10);
            let c = 1 + rng.below(10);
            let m = Mat::from_fn(r, c, |_, _| rng.normal());
            let rt = m.transposed().transposed();
            check_all_close(m.data(), rt.data(), 1e-15, "transpose roundtrip")
        });
    }
}
