//! Blocked dense Cholesky factorization.
//!
//! Used by the non-block solvers (Σ = Λ⁻¹ "via Cholesky decomposition",
//! paper §2 Computational Complexity), by the line search's
//! positive-definiteness check at moderate q, and by the data generators.
//!
//! The trailing-submatrix update is routed through the [`GemmEngine`], so the
//! O(q³) work can run on either the native kernels or the PJRT artifacts.

use super::dense::{dot, Mat};
use crate::gemm::GemmEngine;
use crate::util::threadpool::Parallelism;

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct DenseChol {
    /// Lower triangle holds L; strict upper is garbage.
    l: Mat,
}

/// Factorization failure: the matrix is not positive definite.
#[derive(Debug, thiserror::Error)]
#[error("matrix not positive definite (pivot {pivot} at index {index})")]
pub struct NotPositiveDefinite {
    pub index: usize,
    pub pivot: f64,
}

const NB: usize = 64;

impl DenseChol {
    /// Factor A = L·Lᵀ (A symmetric, lower triangle read).
    pub fn factor(a: &Mat, engine: &dyn GemmEngine) -> Result<DenseChol, NotPositiveDefinite> {
        assert_eq!(a.rows(), a.cols());
        let n = a.rows();
        let mut l = a.clone();
        for j0 in (0..n).step_by(NB) {
            let jb = NB.min(n - j0);
            // Diagonal block: unblocked factor of L[j0.., j0..][..jb,..jb]
            unblocked_potrf(&mut l, j0, jb)?;
            if j0 + jb < n {
                // Panel solve: L21 = A21 · L11⁻ᵀ for rows i in (j0+jb..n).
                for i in j0 + jb..n {
                    for j in j0..j0 + jb {
                        let mut s = l[(i, j)];
                        // s -= Σ_{t<j} L[i,t] L[j,t]
                        let (ri, rj) = (i * n, j * n);
                        let li = &l.data()[ri + j0..ri + j];
                        let lj = &l.data()[rj + j0..rj + j];
                        s -= dot(li, lj);
                        l[(i, j)] = s / l[(j, j)];
                    }
                }
                // Trailing update: A22 -= L21 · L21ᵀ, via the GEMM engine.
                let m2 = n - (j0 + jb);
                let mut panel = Mat::zeros(m2, jb);
                for i in 0..m2 {
                    for j in 0..jb {
                        panel[(i, j)] = l[(j0 + jb + i, j0 + j)];
                    }
                }
                let mut update = Mat::zeros(m2, m2);
                // update = panel · panelᵀ  =  (panelᵀ)ᵀ (panelᵀ): use gemm_tn on transposed panel.
                let panel_t = panel.transposed();
                engine.gemm_tn(1.0, &panel_t, &panel_t, 0.0, &mut update);
                for i in 0..m2 {
                    for j in 0..=i {
                        l[(j0 + jb + i, j0 + jb + j)] -= update[(i, j)];
                    }
                }
            }
        }
        // Zero the strict upper triangle for cleanliness.
        for i in 0..n {
            for j in i + 1..n {
                l[(i, j)] = 0.0;
            }
        }
        Ok(DenseChol { l })
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }

    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// log |A| = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve A x = b in place (forward + backward substitution).
    pub fn solve(&self, b: &mut [f64]) {
        self.solve_lower(b);
        self.solve_upper(b);
    }

    /// Solve L y = b in place.
    pub fn solve_lower(&self, b: &mut [f64]) {
        let n = self.n();
        assert_eq!(b.len(), n);
        for i in 0..n {
            let row = &self.l.data()[i * n..i * n + i];
            let s = dot(row, &b[..i]);
            b[i] = (b[i] - s) / self.l[(i, i)];
        }
    }

    /// Solve Lᵀ x = b in place.
    pub fn solve_upper(&self, b: &mut [f64]) {
        let n = self.n();
        for i in (0..n).rev() {
            let mut s = b[i];
            for j in i + 1..n {
                s -= self.l[(j, i)] * b[j];
            }
            b[i] = s / self.l[(i, i)];
        }
    }

    /// ‖L⁻¹ b‖² — the quadratic form bᵀA⁻¹b (line-search trace terms).
    pub fn quad_form_inv(&self, b: &[f64]) -> f64 {
        let mut y = b.to_vec();
        self.solve_lower(&mut y);
        dot(&y, &y)
    }

    /// Full inverse A⁻¹ (dense q×q — the non-block solvers' Σ).
    pub fn inverse(&self, engine: &dyn GemmEngine) -> Mat {
        let n = self.n();
        let mut inv = Mat::zeros(n, n);
        self.inverse_into(engine, &mut inv);
        inv
    }

    /// Inverse written into a preallocated n×n matrix; allocates the
    /// triangular scratch internally (see [`Self::inverse_into_scratch`] for
    /// the allocation-free hot-loop variant).
    pub fn inverse_into(&self, engine: &dyn GemmEngine, inv: &mut Mat) {
        let mut w = Mat::zeros(self.n(), self.n());
        self.inverse_into_scratch(engine, &mut w, inv);
    }

    /// Inverse with a caller-provided n×n scratch `w` (overwritten) — no
    /// allocation; the solvers hand both buffers from their workspace arena
    /// so the whole Σ computation is budget-visible. Serial; see
    /// [`Self::inverse_into_scratch_par`] for the band-parallel variant the
    /// solvers use.
    pub fn inverse_into_scratch(&self, engine: &dyn GemmEngine, w: &mut Mat, inv: &mut Mat) {
        self.inverse_into_scratch_par(engine, &Parallelism::new(1), w, inv);
    }

    /// Band-parallel inverse: the columns of `W = L⁻¹` are independent
    /// triangular solves, so they are computed in parallel — column j is
    /// stored as *row* j of the scratch (i.e. the scratch holds `Wᵀ`), which
    /// makes each solve a contiguous-row recurrence and the per-column
    /// writes disjoint row slices for [`Parallelism::parallel_chunks_mut`].
    /// The TRSM phase was the one serial dense path left in Σ = Λ⁻¹
    /// (the sparse branch already solved per column in parallel).
    pub fn inverse_into_scratch_par(
        &self,
        engine: &dyn GemmEngine,
        par: &Parallelism,
        w: &mut Mat,
        inv: &mut Mat,
    ) {
        // A⁻¹ = L⁻ᵀ L⁻¹ = WᵀW. With the scratch holding Wᵀ (row j = column
        // j of W), the Gram becomes a row-dot product: gemm_nt(Wᵀ, Wᵀ).
        let n = self.n();
        assert_eq!((inv.rows(), inv.cols()), (n, n));
        assert_eq!((w.rows(), w.cols()), (n, n));
        let l = &self.l;
        let ld = l.data();
        // One row (= one triangular solve) per dynamic chunk: the cost per
        // column shrinks quadratically with j, so dynamic claiming keeps
        // the bands balanced.
        par.parallel_chunks_mut(w.data_mut(), n, |j, wrow| {
            wrow[..j].iter_mut().for_each(|x| *x = 0.0);
            wrow[j] = 1.0 / ld[j * n + j];
            for i in j + 1..n {
                let lrow = &ld[i * n + j..i * n + i];
                let s = dot(lrow, &wrow[j..i]);
                wrow[i] = -s / ld[i * n + i];
            }
        });
        engine.gemm_nt(1.0, w, w, 0.0, inv);
        inv.symmetrize();
    }
}

fn unblocked_potrf(l: &mut Mat, j0: usize, jb: usize) -> Result<(), NotPositiveDefinite> {
    let n = l.rows();
    for j in j0..j0 + jb {
        let rj = j * n;
        let mut d = l[(j, j)];
        {
            let row = &l.data()[rj + j0..rj + j];
            d -= dot(row, row);
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(NotPositiveDefinite { index: j, pivot: d });
        }
        let dj = d.sqrt();
        l[(j, j)] = dj;
        for i in j + 1..j0 + jb {
            let ri = i * n;
            let mut s = l[(i, j)];
            let (a, b) = (
                &l.data()[ri + j0..ri + j],
                &l.data()[rj + j0..rj + j],
            );
            s -= dot(a, b);
            l[(i, j)] = s / dj;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_all_close, check_close, property};

    pub(crate) fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = Mat::zeros(n, n);
        NativeGemm::new(1).gemm_tn(1.0, &b, &b, 0.0, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f64; // well conditioned
        }
        a.symmetrize();
        a
    }

    #[test]
    fn reconstructs_a() {
        property(30, |rng| {
            let n = 1 + rng.below(90);
            let a = random_spd(rng, n);
            let eng = NativeGemm::new(1);
            let ch = DenseChol::factor(&a, &eng).map_err(|e| e.to_string())?;
            // LLᵀ == A
            let l = ch.l();
            let lt = l.transposed();
            let mut rec = Mat::zeros(n, n);
            eng.gemm(1.0, l, &lt, 0.0, &mut rec);
            check_all_close(rec.data(), a.data(), 1e-9, "LLᵀ=A")
        });
    }

    #[test]
    fn solve_and_quadform() {
        property(30, |rng| {
            let n = 1 + rng.below(40);
            let a = random_spd(rng, n);
            let eng = NativeGemm::new(1);
            let ch = DenseChol::factor(&a, &eng).map_err(|e| e.to_string())?;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x);
            let mut got = b.clone();
            ch.solve(&mut got);
            check_all_close(&got, &x, 1e-8, "solve")?;
            // quad form: bᵀ A⁻¹ b = bᵀ x
            let qf = ch.quad_form_inv(&b);
            check_close(qf, dot(&b, &x), 1e-8, "quad form")
        });
    }

    #[test]
    fn inverse_and_logdet() {
        property(20, |rng| {
            let n = 1 + rng.below(30);
            let a = random_spd(rng, n);
            let eng = NativeGemm::new(1);
            let ch = DenseChol::factor(&a, &eng).map_err(|e| e.to_string())?;
            let inv = ch.inverse(&eng);
            let mut prod = Mat::zeros(n, n);
            eng.gemm(1.0, &a, &inv, 0.0, &mut prod);
            check_all_close(prod.data(), Mat::eye(n).data(), 1e-8, "A·A⁻¹=I")?;
            // logdet via eigen-free check: det of 2x2 case handled by property below
            if n == 1 {
                check_close(ch.logdet(), a[(0, 0)].ln(), 1e-12, "logdet n=1")?;
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_inverse_matches_serial_bitwise() {
        property(15, |rng| {
            let n = 1 + rng.below(70);
            let a = random_spd(rng, n);
            let eng = NativeGemm::new(1);
            let ch = DenseChol::factor(&a, &eng).map_err(|e| e.to_string())?;
            let mut w1 = Mat::zeros(n, n);
            let mut i1 = Mat::zeros(n, n);
            ch.inverse_into_scratch_par(&eng, &Parallelism::new(1), &mut w1, &mut i1);
            let mut w4 = Mat::zeros(n, n);
            let mut i4 = Mat::zeros(n, n);
            ch.inverse_into_scratch_par(&eng, &Parallelism::new(4), &mut w4, &mut i4);
            // Column solves are independent, so thread count cannot change
            // a single bit.
            if i1.data() != i4.data() {
                return Err("banded TRSM result depends on thread count".into());
            }
            // And it is actually the inverse.
            let mut prod = Mat::zeros(n, n);
            eng.gemm(1.0, &a, &i4, 0.0, &mut prod);
            check_all_close(prod.data(), Mat::eye(n).data(), 1e-8, "A·A⁻¹=I")
        });
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        let eng = NativeGemm::new(1);
        assert!(DenseChol::factor(&a, &eng).is_err());
    }

    #[test]
    fn logdet_matches_product_of_pivots_2x2() {
        let a = Mat::from_rows(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let eng = NativeGemm::new(1);
        let ch = DenseChol::factor(&a, &eng).unwrap();
        let det: f64 = 4.0 * 3.0 - 1.0;
        assert!((ch.logdet() - det.ln()).abs() < 1e-12);
    }
}
