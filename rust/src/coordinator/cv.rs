//! K-fold cross-validated λ selection over the regularization path — the
//! end-to-end model-selection pipeline on top of [`super::fit_path_with`].
//!
//! The sweep that the paper runs for speed exists, in practice, to *choose*
//! λ. [`cross_validate`] closes that loop:
//!
//! 1. one λ grid is generated from the **full** data (so every fold scores
//!    the same candidates);
//! 2. the samples are split into K shuffled folds; each fold builds its own
//!    [`SolverContext`] on the training split — covariance statistics are
//!    computed once per fold and budget-tracked through the context's
//!    workspace arena (each fold gets an independent [`MemBudget`] with the
//!    caller's limit, so a per-solve cap stays a per-solve cap). The fold
//!    *datasets* themselves are column copies of the input — raw data, not
//!    solver working set, and like the original dataset they sit outside
//!    the budget: with F folds in flight that is ~F·(p+q)·n·8 bytes of
//!    resident input data;
//! 3. folds run **in parallel across threads** ([`CvOptions::fold_threads`])
//!    — they are embarrassingly parallel: disjoint data, disjoint contexts,
//!    a shared read-only GEMM engine;
//! 4. each fold fits the warm-started, strong-rule-screened path and scores
//!    every path point's model on the held-out split via
//!    [`heldout_nll`] (average test negative log-likelihood — comparable
//!    across λ, unlike the penalized objective);
//! 5. the λ with the lowest mean held-out NLL wins, and a final
//!    warm-started path refit on the full data down to the winner produces
//!    the returned model.
//!
//! Fold progress can stream to a JSONL checkpoint
//! ([`CvOptions::checkpoint`], CLI `cggm cv --checkpoint FILE`): every
//! scored (fold, λ) point and every completed fold is a flushed line, and
//! `--resume FILE` carries completed folds over verbatim — bitwise, since
//! the recorded scores round-trip exactly — refitting only the rest.

use super::{checkpoint, fit_path_with, geometric_grid, lambda_max, PathOptions, PathResult};
use crate::cggm::objective::heldout_nll;
use crate::cggm::{CggmModel, Dataset};
use crate::gemm::GemmEngine;
use crate::solvers::{SolveError, SolveOptions, SolverContext, SolverKind};
use crate::util::json::Json;
use crate::util::membudget::MemBudget;
use crate::util::rng::Rng;
use crate::util::threadpool::Parallelism;
use crate::util::timer::Stopwatch;

/// Cross-validation configuration.
#[derive(Clone, Debug)]
pub struct CvOptions {
    /// Number of folds K (clamped to [2, n]).
    pub folds: usize,
    /// Shuffle seed for the fold assignment (deterministic splits).
    pub seed: u64,
    /// Worker threads across folds (1 = sequential). Independent of
    /// `SolveOptions::threads`, which parallelizes *inside* one solve.
    pub fold_threads: usize,
    /// Refit on the full dataset at the winning λ (warm-started down the
    /// truncated grid). `false` skips the refit (grid scoring only).
    pub refit: bool,
    /// One-standard-error rule: select the sparsest λ (largest, i.e.
    /// earliest on the decreasing grid) whose mean held-out NLL is within
    /// one standard error of the best mean — the classic bias toward
    /// parsimony when the NLL curve is flat near its minimum. `false`
    /// selects the argmin.
    pub one_se: bool,
    /// Stream fold progress to this JSONL checkpoint
    /// ([`checkpoint::CvCheckpointWriter`]): every scored (fold, λ) point
    /// and every completed fold is a flushed line, so an interrupted CV run
    /// loses at most its in-flight folds. `None` disables checkpointing.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Resume from `checkpoint`: completed folds (those with a done-marker
    /// on disk) are carried over verbatim and only the remaining folds are
    /// fitted; the header's grid governs. The header also pins solver,
    /// problem shape, fold count, and the shuffle seed — any mismatch is an
    /// error (carried scores from a different fold assignment would be
    /// meaningless). A missing or header-corrupt file starts fresh.
    pub resume: bool,
}

impl Default for CvOptions {
    fn default() -> Self {
        CvOptions {
            folds: 5,
            seed: 0x5eed,
            fold_threads: 1,
            refit: true,
            one_se: false,
            checkpoint: None,
            resume: false,
        }
    }
}

/// One λ grid point's cross-validation score.
#[derive(Clone, Debug)]
pub struct CvPoint {
    pub lam_l: f64,
    pub lam_t: f64,
    /// Held-out NLL per fold (NaN where a fold's path stopped early, e.g.
    /// on a time budget).
    pub fold_nll: Vec<f64>,
    /// Mean over the folds that scored this point.
    pub mean_nll: f64,
    /// Standard error of that mean (0 when fewer than two folds scored).
    pub se_nll: f64,
}

/// A completed cross-validation run.
pub struct CvResult {
    pub solver: SolverKind,
    pub folds: usize,
    pub points: Vec<CvPoint>,
    /// Index into `points` of the argmin λ (lowest mean held-out NLL).
    pub best: usize,
    /// Index into `points` of the *selected* λ: equals `best` under argmin
    /// selection; under [`CvOptions::one_se`] the sparsest λ within one
    /// standard error of the best mean (`selected ≤ best` on the decreasing
    /// grid). The refit and `best_lambda` follow this index.
    pub selected: usize,
    pub best_lambda: (f64, f64),
    /// Full-data refit path down to the winning λ (`None` when
    /// `CvOptions::refit` is off or every fold failed to score).
    pub refit: Option<PathResult>,
    /// KKT fallbacks summed over all fold paths (screening quality).
    pub screen_fallbacks: usize,
    /// Folds carried over from a resumed checkpoint (0 for a fresh run).
    pub resumed_folds: usize,
    pub total_seconds: f64,
}

impl CvResult {
    /// The refit model at the winning λ, when a refit ran.
    pub fn model(&self) -> Option<&CggmModel> {
        self.refit.as_ref().and_then(|r| r.model.as_ref())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("solver", Json::str(self.solver.name())),
            ("folds", Json::num(self.folds as f64)),
            ("best", Json::num(self.best as f64)),
            ("selected", Json::num(self.selected as f64)),
            ("best_lambda_l", Json::num(self.best_lambda.0)),
            ("best_lambda_t", Json::num(self.best_lambda.1)),
            (
                "screen_fallbacks",
                Json::num(self.screen_fallbacks as f64),
            ),
            ("resumed_folds", Json::num(self.resumed_folds as f64)),
            ("total_seconds", Json::num(self.total_seconds)),
            (
                "points",
                Json::arr(self.points.iter().map(|p| {
                    Json::obj(vec![
                        ("lambda_l", Json::num(p.lam_l)),
                        ("lambda_t", Json::num(p.lam_t)),
                        ("mean_nll", Json::num(p.mean_nll)),
                        ("se_nll", Json::num(p.se_nll)),
                        (
                            "fold_nll",
                            Json::arr(p.fold_nll.iter().map(|&x| Json::num(x))),
                        ),
                    ])
                })),
            ),
            (
                "refit",
                self.refit
                    .as_ref()
                    .map(|r| r.to_json())
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("lambda_l,lambda_t,mean_nll,se_nll,best,selected\n");
        for (k, p) in self.points.iter().enumerate() {
            s.push_str(&format!(
                "{},{},{},{},{},{}\n",
                p.lam_l,
                p.lam_t,
                p.mean_nll,
                p.se_nll,
                k == self.best,
                k == self.selected
            ));
        }
        s
    }
}

/// Deterministic shuffled fold assignment: `assign[s] ∈ 0..k` for each
/// sample, sizes balanced to within one.
pub(crate) fn fold_assignment(n: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    Rng::new(seed).shuffle(&mut order);
    let mut assign = vec![0usize; n];
    for (pos, &s) in order.iter().enumerate() {
        assign[s] = pos % k;
    }
    assign
}

/// Train/test split for fold `f` under `assign`.
fn split_fold(data: &Dataset, assign: &[usize], f: usize) -> (Dataset, Dataset) {
    let train: Vec<usize> = (0..assign.len()).filter(|&s| assign[s] != f).collect();
    let test: Vec<usize> = (0..assign.len()).filter(|&s| assign[s] == f).collect();
    (data.select_samples(&train), data.select_samples(&test))
}

/// Per-fold outcome: held-out NLL per grid point (NaN = not fitted) plus
/// the fold path's screening fallback count.
struct FoldScores {
    nll: Vec<f64>,
    fallbacks: usize,
}

/// K-fold cross-validation over the λ path; see the module docs for the
/// pipeline. The returned [`CvResult`] orders `points` like the grid
/// (decreasing λ).
pub fn cross_validate(
    kind: SolverKind,
    data: &Dataset,
    base: &SolveOptions,
    popts: &PathOptions,
    cv: &CvOptions,
    engine: &dyn GemmEngine,
) -> Result<CvResult, SolveError> {
    cross_validate_with(kind, data, base, popts, cv, engine, &|_, _, _| {})
}

/// [`cross_validate`] with a per-scored-point observer: `on_score(fold,
/// grid_point, heldout_nll)` fires after each fold scores a λ point, from
/// whichever fold thread produced it (`Sync` because folds run in
/// parallel). The serve engine's streamed `cv` progress lines hang off
/// this; resumed (carried-over) folds do not re-fire.
pub fn cross_validate_with(
    kind: SolverKind,
    data: &Dataset,
    base: &SolveOptions,
    popts: &PathOptions,
    cv: &CvOptions,
    engine: &dyn GemmEngine,
    on_score: &(dyn Fn(usize, usize, f64) + Sync),
) -> Result<CvResult, SolveError> {
    let sw = Stopwatch::start();
    let n = data.n();
    let k = cv.folds.clamp(2, n.max(2));
    // One full-data context shared by grid generation and the final refit,
    // so the full dataset's covariance statistics are computed at most once
    // (they are lazy: an explicit grid with refit off materializes nothing).
    let full_ctx = SolverContext::new(data, base, engine);
    // Resume: adopt the checkpoint's completed folds. Its header pins the
    // run identity — a checkpoint written under a different solver, shape,
    // fold count, or shuffle seed describes *different fold splits*, so
    // carrying its scores would silently corrupt the selection; refuse.
    let mut resumed: Option<checkpoint::CvCheckpointState> = None;
    if cv.resume {
        if let Some(ck) = &cv.checkpoint {
            if let Ok(state) = checkpoint::load_cv(ck) {
                if state.solver != kind.name()
                    || (state.p, state.q, state.n) != (data.p(), data.q(), n)
                    || state.folds != k
                    || state.seed != cv.seed
                {
                    return Err(SolveError::Checkpoint(format!(
                        "{} was written by {} for {}×{} (n={}, {} folds, seed {}); \
                         this run is {} on {}×{} (n={}, {} folds, seed {}) — \
                         refusing to resume",
                        ck.display(),
                        state.solver,
                        state.p,
                        state.q,
                        state.n,
                        state.folds,
                        state.seed,
                        kind.name(),
                        data.p(),
                        data.q(),
                        n,
                        k,
                        cv.seed
                    )));
                }
                resumed = Some(state);
            }
        }
    }
    // One grid for every fold: the resumed header's grid governs (the
    // interrupted run's candidates must be continued exactly), otherwise
    // from the full data's λ_max.
    let grid: Vec<(f64, f64)> = match (&resumed, &popts.lambdas) {
        (Some(state), _) => state.grid.clone(),
        (None, Some(g)) => g.clone(),
        (None, None) => {
            let (ml, mt) = lambda_max(&full_ctx, kind)?;
            geometric_grid(ml, mt, popts.points.max(1), popts.min_ratio)
        }
    };
    let writer = match &cv.checkpoint {
        Some(ck) => Some(match &resumed {
            Some(state) => checkpoint::CvCheckpointWriter::append_after(ck, state.valid_bytes)
                .map_err(|e| SolveError::Checkpoint(e.to_string()))?,
            None => checkpoint::CvCheckpointWriter::create(
                ck,
                kind.name(),
                data.p(),
                data.q(),
                n,
                k,
                cv.seed,
                &grid,
            )
            .map_err(|e| SolveError::Checkpoint(e.to_string()))?,
        }),
        None => None,
    };
    let (carried_nll, carried_done, carried_fallbacks) = match resumed {
        Some(state) => (state.nll, state.done, state.fallbacks),
        None => (Vec::new(), Vec::new(), Vec::new()),
    };
    let resumed_folds = carried_done.iter().filter(|&&d| d).count();
    // Folds pin the shared grid and drop any *path* checkpoint wiring: K
    // parallel folds streaming into one caller-supplied path checkpoint
    // file would corrupt it. Fold progress streams through the dedicated
    // CV writer above instead, whose line format is interleave-safe.
    let fold_popts = PathOptions {
        lambdas: Some(grid.clone()),
        checkpoint: None,
        resume: false,
        ..popts.clone()
    };
    let assign = fold_assignment(n, k, cv.seed);

    // Fit + score the folds, in parallel across threads. Each fold owns its
    // data copies, context, and budget; slots are disjoint, so the
    // chunk-parallel helper applies directly. Folds completed by a resumed
    // checkpoint are carried over verbatim and cost nothing here.
    let mut slots: Vec<Option<Result<FoldScores, SolveError>>> = (0..k).map(|_| None).collect();
    let run_fold = |f: usize| -> Result<FoldScores, SolveError> {
        if carried_done.get(f).copied().unwrap_or(false) {
            return Ok(FoldScores {
                nll: carried_nll[f].clone(),
                fallbacks: carried_fallbacks[f],
            });
        }
        let (train, test) = split_fold(data, &assign, f);
        let mut fold_base = base.clone();
        // Same cap, independent accounting: K concurrent folds must not
        // trip each other's budget, and `peak()` stays per-context.
        fold_base.budget = MemBudget::new(base.budget.limit());
        let ctx = SolverContext::new(&train, &fold_base, engine);
        let mut nll = vec![f64::NAN; grid.len()];
        let path = fit_path_with(kind, &ctx, &fold_base, &fold_popts, |j, _, model| {
            let x = heldout_nll(model, &test, engine).unwrap_or(f64::INFINITY);
            nll[j] = x;
            if let Some(w) = &writer {
                w.record_point(f, j, x);
            }
            on_score(f, j, x);
        })?;
        if let Some(w) = &writer {
            w.record_fold_done(f, path.screen_fallbacks);
        }
        Ok(FoldScores {
            nll,
            fallbacks: path.screen_fallbacks,
        })
    };
    Parallelism::new(cv.fold_threads.max(1)).parallel_chunks_mut(&mut slots, 1, |f, slot| {
        slot[0] = Some(run_fold(f));
    });

    let mut fold_scores = Vec::with_capacity(k);
    let mut screen_fallbacks = 0usize;
    for slot in slots {
        let scores = slot.expect("every fold slot is filled")?;
        screen_fallbacks += scores.fallbacks;
        fold_scores.push(scores.nll);
    }

    // Aggregate: mean ± standard error over the folds that scored each λ.
    let mut points = Vec::with_capacity(grid.len());
    for (j, &(lam_l, lam_t)) in grid.iter().enumerate() {
        let fold_nll: Vec<f64> = fold_scores.iter().map(|s| s[j]).collect();
        let scored: Vec<f64> = fold_nll.iter().copied().filter(|x| x.is_finite()).collect();
        let m = scored.len();
        let mean_nll = if m > 0 {
            scored.iter().sum::<f64>() / m as f64
        } else {
            f64::INFINITY
        };
        let se_nll = if m > 1 {
            let var = scored.iter().map(|x| (x - mean_nll).powi(2)).sum::<f64>()
                / (m as f64 - 1.0);
            (var / m as f64).sqrt()
        } else {
            0.0
        };
        points.push(CvPoint {
            lam_l,
            lam_t,
            fold_nll,
            mean_nll,
            se_nll,
        });
    }
    let best = points
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.mean_nll.total_cmp(&b.1.mean_nll))
        .map(|(j, _)| j)
        .unwrap_or(0);
    let selected = if cv.one_se {
        one_se_index(&points, best)
    } else {
        best
    };
    let best_lambda = (points[selected].lam_l, points[selected].lam_t);

    // Full-data refit: warm-started (and screened) path down the truncated
    // grid, so the winner benefits from the same path machinery the folds
    // used.
    let refit = if cv.refit && points[selected].mean_nll.is_finite() {
        let refit_popts = PathOptions {
            lambdas: Some(grid[..=selected].to_vec()),
            checkpoint: None,
            resume: false,
            ..popts.clone()
        };
        Some(fit_path_with(kind, &full_ctx, base, &refit_popts, |_, _, _| {})?)
    } else {
        None
    };

    Ok(CvResult {
        solver: kind,
        folds: k,
        points,
        best,
        selected,
        best_lambda,
        refit,
        screen_fallbacks,
        resumed_folds,
        total_seconds: sw.seconds(),
    })
}

/// One-standard-error selection: the earliest grid index (largest λ — the
/// grid decreases, so earlier is sparser) whose mean held-out NLL is within
/// one standard error of the best mean. Falls back to `best` when no
/// earlier point qualifies (including the degenerate zero-SE case).
fn one_se_index(points: &[CvPoint], best: usize) -> usize {
    if !points[best].mean_nll.is_finite() {
        return best;
    }
    let threshold = points[best].mean_nll + points[best].se_nll;
    points
        .iter()
        .enumerate()
        .take(best + 1)
        .find(|(_, p)| p.mean_nll.is_finite() && p.mean_nll <= threshold)
        .map(|(j, _)| j)
        .unwrap_or(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;

    #[test]
    fn fold_assignment_is_balanced_partition() {
        for (n, k) in [(10, 3), (17, 5), (8, 8), (9, 2)] {
            let assign = fold_assignment(n, k, 42);
            assert_eq!(assign.len(), n);
            let mut counts = vec![0usize; k];
            for &f in &assign {
                assert!(f < k);
                counts[f] += 1;
            }
            let (lo, hi) = (
                counts.iter().min().unwrap(),
                counts.iter().max().unwrap(),
            );
            assert!(hi - lo <= 1, "unbalanced folds {counts:?} for n={n} k={k}");
            // Deterministic in the seed, different across seeds (n > k).
            assert_eq!(assign, fold_assignment(n, k, 42));
            if n > k {
                assert_ne!(assign, fold_assignment(n, k, 43));
            }
        }
    }

    #[test]
    fn split_fold_partitions_samples() {
        let prob = datagen::chain::generate(4, 3, 12, 5);
        let assign = fold_assignment(12, 3, 7);
        let mut total_test = 0;
        for f in 0..3 {
            let (train, test) = split_fold(&prob.data, &assign, f);
            assert_eq!(train.n() + test.n(), 12);
            assert_eq!(train.p(), 4);
            assert_eq!(test.q(), 3);
            total_test += test.n();
        }
        assert_eq!(total_test, 12, "every sample is held out exactly once");
    }

    #[test]
    fn cv_scores_every_grid_point_and_picks_argmin() {
        let prob = datagen::chain::generate(10, 10, 90, 21);
        let eng = NativeGemm::new(1);
        let base = SolveOptions {
            max_iter: 60,
            ..Default::default()
        };
        let popts = PathOptions {
            points: 4,
            min_ratio: 0.1,
            ..Default::default()
        };
        let cv = CvOptions {
            folds: 3,
            ..Default::default()
        };
        let res = cross_validate(
            SolverKind::AltNewtonCd,
            &prob.data,
            &base,
            &popts,
            &cv,
            &eng,
        )
        .unwrap();
        assert_eq!(res.points.len(), 4);
        assert_eq!(res.folds, 3);
        for p in &res.points {
            assert_eq!(p.fold_nll.len(), 3);
            assert!(p.mean_nll.is_finite());
            assert!(p.se_nll >= 0.0);
        }
        // Argmin property: the winner's mean NLL is minimal.
        for p in &res.points {
            assert!(res.points[res.best].mean_nll <= p.mean_nll + 1e-12);
        }
        assert_eq!(
            res.best_lambda,
            (res.points[res.best].lam_l, res.points[res.best].lam_t)
        );
        // Refit ran down the truncated grid and produced a model.
        let refit = res.refit.as_ref().unwrap();
        assert_eq!(refit.points.len(), res.best + 1);
        assert!(res.model().is_some());
        let j = res.to_json().to_string();
        assert!(j.contains("best_lambda_l"));
        assert_eq!(res.to_csv().lines().count(), 1 + 4);
    }

    fn mk_point(lam: f64, mean: f64, se: f64) -> CvPoint {
        CvPoint {
            lam_l: lam,
            lam_t: lam,
            fold_nll: vec![],
            mean_nll: mean,
            se_nll: se,
        }
    }

    #[test]
    fn one_se_index_picks_sparsest_within_band() {
        // Decreasing-λ grid; best is index 3 (mean 1.0, se 0.3); indices 1
        // and 2 are within 1.3, index 0 is not → pick 1 (sparsest in band).
        let pts = vec![
            mk_point(1.0, 2.0, 0.1),
            mk_point(0.7, 1.25, 0.1),
            mk_point(0.5, 1.1, 0.1),
            mk_point(0.3, 1.0, 0.3),
            mk_point(0.1, 1.4, 0.1),
        ];
        assert_eq!(one_se_index(&pts, 3), 1);
        // Zero SE: nothing earlier is ≤ the best mean → stays at best.
        let pts0 = vec![
            mk_point(1.0, 2.0, 0.0),
            mk_point(0.5, 1.0, 0.0),
        ];
        assert_eq!(one_se_index(&pts0, 1), 1);
        // Unscored (infinite) earlier points are skipped.
        let ptsinf = vec![
            mk_point(1.0, f64::INFINITY, 0.0),
            mk_point(0.5, 1.05, 0.1),
            mk_point(0.3, 1.0, 0.1),
        ];
        assert_eq!(one_se_index(&ptsinf, 2), 1);
    }

    #[test]
    fn one_se_selection_is_sparser_and_within_band() {
        let prob = datagen::chain::generate(10, 10, 90, 33);
        let eng = NativeGemm::new(1);
        let base = SolveOptions {
            max_iter: 60,
            ..Default::default()
        };
        let popts = PathOptions {
            points: 5,
            min_ratio: 0.05,
            ..Default::default()
        };
        let argmin = CvOptions {
            folds: 3,
            ..Default::default()
        };
        let onese = CvOptions {
            one_se: true,
            ..argmin.clone()
        };
        let a = cross_validate(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &argmin, &eng)
            .unwrap();
        let b = cross_validate(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &onese, &eng)
            .unwrap();
        // Same fold scores (selection is post-processing), same argmin.
        assert_eq!(a.best, b.best);
        assert_eq!(a.selected, a.best, "argmin mode selects the argmin");
        assert!(b.selected <= b.best, "one-SE never picks a denser λ");
        let thr = b.points[b.best].mean_nll + b.points[b.best].se_nll;
        assert!(b.points[b.selected].mean_nll <= thr + 1e-12);
        assert_eq!(
            b.best_lambda,
            (b.points[b.selected].lam_l, b.points[b.selected].lam_t)
        );
        // Refit stops at the selected (sparser) point.
        assert_eq!(b.refit.as_ref().unwrap().points.len(), b.selected + 1);
        // And the selected model is at least as sparse as the argmin one.
        if b.selected < b.best {
            let ma = a.model().unwrap();
            let mb = b.model().unwrap();
            assert!(
                mb.lambda_nnz() + mb.theta_nnz() <= ma.lambda_nnz() + ma.theta_nnz(),
                "one-SE model should not be denser"
            );
        }
        let j = b.to_json().to_string();
        assert!(j.contains("\"selected\""));
    }

    #[test]
    fn cv_checkpoint_resumes_completed_folds_bitwise() {
        let prob = datagen::chain::generate(8, 8, 60, 11);
        let eng = NativeGemm::new(1);
        let base = SolveOptions {
            max_iter: 50,
            ..Default::default()
        };
        let popts = PathOptions {
            points: 3,
            min_ratio: 0.2,
            ..Default::default()
        };
        let ck = std::env::temp_dir().join("cggm_cv_resume_unit.jsonl");
        let _ = std::fs::remove_file(&ck);
        let cvo = CvOptions {
            folds: 3,
            fold_threads: 1, // sequential folds → deterministic line order
            refit: false,
            checkpoint: Some(ck.clone()),
            ..Default::default()
        };
        let full =
            cross_validate(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &cvo, &eng)
                .unwrap();
        assert_eq!(full.resumed_folds, 0);
        let text = std::fs::read_to_string(&ck).unwrap();
        // header + 3 folds × (3 points + 1 done marker)
        assert_eq!(text.lines().count(), 1 + 3 * 4);
        // "Interrupt" after fold 0 completed: keep header + its 4 lines.
        let prefix: String = text.lines().take(5).map(|l| format!("{l}\n")).collect();
        std::fs::write(&ck, prefix).unwrap();
        let resumed_opts = CvOptions {
            resume: true,
            ..cvo.clone()
        };
        let resumed = cross_validate(
            SolverKind::AltNewtonCd,
            &prob.data,
            &base,
            &popts,
            &resumed_opts,
            &eng,
        )
        .unwrap();
        assert_eq!(resumed.resumed_folds, 1);
        assert_eq!(resumed.best, full.best);
        for (a, b) in full.points.iter().zip(&resumed.points) {
            assert_eq!(a.fold_nll, b.fold_nll, "resume must be bitwise-equal");
        }
        // A checkpoint from a different fold assignment must be refused.
        let mismatched = CvOptions {
            seed: cvo.seed + 1,
            resume: true,
            ..cvo.clone()
        };
        let err = cross_validate(
            SolverKind::AltNewtonCd,
            &prob.data,
            &base,
            &popts,
            &mismatched,
            &eng,
        );
        assert!(
            matches!(err, Err(SolveError::Checkpoint(_))),
            "seed mismatch must refuse to resume"
        );
        let _ = std::fs::remove_file(&ck);
    }

    #[test]
    fn parallel_folds_match_sequential_exactly() {
        let prob = datagen::chain::generate(8, 8, 60, 3);
        let eng = NativeGemm::new(1);
        let base = SolveOptions {
            max_iter: 50,
            ..Default::default()
        };
        let popts = PathOptions {
            points: 3,
            min_ratio: 0.2,
            ..Default::default()
        };
        let seq = CvOptions {
            folds: 4,
            fold_threads: 1,
            refit: false,
            ..Default::default()
        };
        let par = CvOptions {
            fold_threads: 4,
            ..seq.clone()
        };
        let a = cross_validate(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &seq, &eng)
            .unwrap();
        let b = cross_validate(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &par, &eng)
            .unwrap();
        assert_eq!(a.best, b.best);
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.fold_nll, y.fold_nll, "fold NLLs must be bitwise equal");
        }
    }
}
