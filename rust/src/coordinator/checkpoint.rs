//! λ-path checkpointing: stream each fitted [`PathPoint`] (+ model) to a
//! JSONL file so giant sweeps survive interruption, and resume from the last
//! fitted λ (`cggm path --resume <ckpt>`).
//!
//! # Format
//!
//! One JSON object per line. The first line is a header pinning the run:
//!
//! ```text
//! {"kind":"header","version":1,"solver":"alt_newton_cd","p":20,"q":10,
//!  "grid":[[0.5,0.4],[0.25,0.2], ...]}
//! {"kind":"point","k":0,"point":{...},"model":{"lambda":{...},"theta":{...}}}
//! {"kind":"point","k":1, ...}
//! ```
//!
//! Every record is written with a trailing newline and flushed immediately,
//! so a run killed mid-write leaves at most one truncated final line.
//! [`load`] tolerates exactly that: it stops at the first malformed or
//! out-of-sequence line and returns the valid prefix — the resumed sweep
//! refits from the last *valid* point, which is the strongest guarantee an
//! append-only log can give. A file whose header is unreadable is treated as
//! no checkpoint at all (the driver starts fresh and rewrites it).
//!
//! Numbers round-trip exactly: the writer emits shortest-roundtrip f64
//! representations and the reader parses them back bit-identically, so a
//! resumed warm start is the same iterate the interrupted run held — resumed
//! objectives reproduce an uninterrupted sweep's to well under 1e-8 (pinned
//! by `checkpoint_tests`).

use super::PathPoint;
use crate::cggm::CggmModel;
use crate::linalg::sparse::SpRowMat;
use crate::util::json::Json;
use std::io::{BufRead, Write};
use std::path::Path;

/// Bump when the line format changes incompatibly.
const VERSION: f64 = 1.0;

/// Upper bound on any dimension a checkpoint header may declare (16M —
/// comfortably above the paper's million-dimensional regime). Checkpoint
/// files are a semi-trusted input (operators pass paths around, fuzzers
/// pass anything), and the loaders allocate `O(dims)` buffers from header
/// fields *before* any point line is read — without this cap a hostile
/// header like `{"p":1e15,...}` is an OOM abort, not an `Err`.
const MAX_DIM: usize = 1 << 24;

/// Upper bound on the fold count a CV header may declare (the loader
/// allocates `folds × grid` score slots up front).
const MAX_FOLDS: usize = 1 << 20;

// ---------------------------------------------------------------- encoding

fn sparse_to_json(m: &SpRowMat) -> Json {
    let mut entries = Vec::with_capacity(m.nnz());
    for i in 0..m.rows() {
        for &(j, v) in m.row(i) {
            entries.push(Json::arr([
                Json::num(i as f64),
                Json::num(j as f64),
                Json::num(v),
            ]));
        }
    }
    Json::obj(vec![
        ("rows", Json::num(m.rows() as f64)),
        ("cols", Json::num(m.cols() as f64)),
        ("entries", Json::Arr(entries)),
    ])
}

/// Decode a sparse matrix whose shape is already known from the (validated)
/// header. The declared shape must match `expect` *before* anything is
/// allocated — a hostile point line declaring `"rows":1e15` must be a
/// rejected line, not a `SpRowMat::zeros(1e15, …)` allocation.
fn sparse_from_json(j: &Json, expect: (usize, usize)) -> Option<SpRowMat> {
    let rows = j.get("rows")?.as_usize()?;
    let cols = j.get("cols")?.as_usize()?;
    if (rows, cols) != expect {
        return None;
    }
    let mut m = SpRowMat::zeros(rows, cols);
    for e in j.get("entries")?.as_arr()? {
        let e = e.as_arr()?;
        if e.len() != 3 {
            return None;
        }
        let (i, jj) = (e[0].as_usize()?, e[1].as_usize()?);
        if i >= rows || jj >= cols {
            return None;
        }
        m.set(i, jj, e[2].as_f64()?);
    }
    Some(m)
}

/// Exact-f64 JSON encoding of a fitted model — shared by the path
/// checkpoint point lines, the standalone model files, and the serve
/// `export` op (which returns it inline).
pub fn model_to_json(model: &CggmModel) -> Json {
    Json::obj(vec![
        ("lambda", sparse_to_json(&model.lambda)),
        ("theta", sparse_to_json(&model.theta)),
    ])
}

/// Decode a model for a run of shape `(p, q)`: Λ is `q×q`, Θ is `p×q`.
pub fn model_from_json(j: &Json, p: usize, q: usize) -> Option<CggmModel> {
    let lambda = sparse_from_json(j.get("lambda")?, (q, q))?;
    let theta = sparse_from_json(j.get("theta")?, (p, q))?;
    Some(CggmModel { lambda, theta })
}

fn point_to_json(p: &PathPoint) -> Json {
    Json::obj(vec![
        ("lambda_l", Json::num(p.lam_l)),
        ("lambda_t", Json::num(p.lam_t)),
        ("iters", Json::num(p.iters as f64)),
        ("converged", Json::Bool(p.converged)),
        ("f", Json::num(p.f)),
        ("lambda_nnz", Json::num(p.lambda_nnz as f64)),
        ("theta_nnz", Json::num(p.theta_nnz as f64)),
        ("seconds", Json::num(p.seconds)),
        ("coord_updates", Json::num(p.coord_updates as f64)),
        ("kkt_scans", Json::num(p.kkt_scans as f64)),
        ("screened", Json::Bool(p.screened)),
        ("fallback", Json::Bool(p.fallback)),
        ("reclusterings", Json::num(p.reclusterings as f64)),
    ])
}

fn point_from_json(j: &Json) -> Option<PathPoint> {
    Some(PathPoint {
        lam_l: j.get("lambda_l")?.as_f64()?,
        lam_t: j.get("lambda_t")?.as_f64()?,
        iters: j.get("iters")?.as_usize()?,
        converged: j.get("converged")?.as_bool()?,
        f: j.get("f")?.as_f64()?,
        lambda_nnz: j.get("lambda_nnz")?.as_usize()?,
        theta_nnz: j.get("theta_nnz")?.as_usize()?,
        seconds: j.get("seconds")?.as_f64()?,
        coord_updates: j.get("coord_updates")?.as_usize()?,
        kkt_scans: j.get("kkt_scans")?.as_usize()?,
        screened: j.get("screened")?.as_bool()?,
        fallback: j.get("fallback")?.as_bool()?,
        reclusterings: j.get("reclusterings")?.as_usize()?,
    })
}

// ------------------------------------------------------------------ writer

/// Append-only checkpoint writer; every record is flushed as one line.
pub struct CheckpointWriter {
    file: std::fs::File,
}

impl CheckpointWriter {
    /// Start a fresh checkpoint (truncates any existing file) and write the
    /// header pinning solver, problem shape, and the full λ grid.
    pub fn create(
        path: &Path,
        solver: &str,
        p: usize,
        q: usize,
        grid: &[(f64, f64)],
    ) -> std::io::Result<CheckpointWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut file = std::fs::File::create(path)?;
        let header = Json::obj(vec![
            ("kind", Json::str("header")),
            ("version", Json::num(VERSION)),
            ("solver", Json::str(solver)),
            ("p", Json::num(p as f64)),
            ("q", Json::num(q as f64)),
            (
                "grid",
                Json::arr(
                    grid.iter()
                        .map(|&(l, t)| Json::arr([Json::num(l), Json::num(t)])),
                ),
            ),
        ]);
        writeln!(file, "{}", header.to_string())?;
        file.flush()?;
        Ok(CheckpointWriter { file })
    }

    /// Reopen an existing checkpoint for appending (resume). The caller has
    /// already validated the prefix via [`load`]; anything after the last
    /// valid point (a torn final line) is truncated away first so the log
    /// stays parseable.
    pub fn append_after(path: &Path, valid_bytes: u64) -> std::io::Result<CheckpointWriter> {
        let file = std::fs::OpenOptions::new().write(true).open(path)?;
        file.set_len(valid_bytes)?;
        use std::io::Seek;
        let mut file = file;
        file.seek(std::io::SeekFrom::End(0))?;
        Ok(CheckpointWriter { file })
    }

    /// Write one fitted point (+ the model at that point) as a flushed line.
    pub fn record(
        &mut self,
        k: usize,
        point: &PathPoint,
        model: &CggmModel,
    ) -> std::io::Result<()> {
        let line = Json::obj(vec![
            ("kind", Json::str("point")),
            ("k", Json::num(k as f64)),
            ("point", point_to_json(point)),
            ("model", model_to_json(model)),
        ]);
        writeln!(self.file, "{}", line.to_string())?;
        self.file.flush()
    }
}

// ------------------------------------------------------------------ loader

/// The valid prefix of a checkpoint file.
pub struct CheckpointState {
    pub solver: String,
    /// Problem shape the header pinned — the resume path refuses a
    /// checkpoint whose shape or solver does not match the current run.
    pub p: usize,
    pub q: usize,
    /// The full grid the interrupted sweep was running (header line).
    pub grid: Vec<(f64, f64)>,
    /// Fitted points 0..k, in grid order.
    pub points: Vec<PathPoint>,
    /// Model at the last valid point (`None` when no point line survived).
    pub model: Option<CggmModel>,
    /// Byte length of the valid prefix — everything after this (a torn
    /// trailing line) is garbage to be truncated on resume.
    pub valid_bytes: u64,
}

/// Parse the valid prefix of a checkpoint. Errors only when the file cannot
/// be read or its *header* is malformed (no run to resume); a corrupt or
/// truncated point line merely ends the prefix, and the resumed sweep refits
/// from the last valid point.
pub fn load(path: &Path) -> std::io::Result<CheckpointState> {
    let file = std::fs::File::open(path)?;
    load_from(std::io::BufReader::new(file))
}

/// Reader-generic body of [`load`] — also the fuzz-target entry point, so
/// hostile bytes exercise the real loader without touching a filesystem.
pub fn load_from<R: BufRead>(mut reader: R) -> std::io::Result<CheckpointState> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let mut line = String::new();
    let mut consumed: u64 = 0;

    // Header.
    let n = reader.read_line(&mut line)?;
    if n == 0 || !line.ends_with('\n') {
        return Err(bad("missing checkpoint header"));
    }
    let header = Json::parse(line.trim_end()).map_err(|e| bad(&format!("bad header: {e}")))?;
    if header.get("kind").and_then(|v| v.as_str()) != Some("header")
        || header.get("version").and_then(|v| v.as_f64()) != Some(VERSION)
    {
        return Err(bad("not a cggm path checkpoint (kind/version mismatch)"));
    }
    let solver = header
        .get("solver")
        .and_then(|v| v.as_str())
        .ok_or_else(|| bad("header missing solver"))?
        .to_string();
    let p = header
        .get("p")
        .and_then(|v| v.as_usize())
        .filter(|&p| p <= MAX_DIM)
        .ok_or_else(|| bad("header p missing or out of range"))?;
    let q = header
        .get("q")
        .and_then(|v| v.as_usize())
        .filter(|&q| q <= MAX_DIM)
        .ok_or_else(|| bad("header q missing or out of range"))?;
    let mut grid = Vec::new();
    for pair in header
        .get("grid")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| bad("header missing grid"))?
    {
        let pair = pair.as_arr().ok_or_else(|| bad("bad grid pair"))?;
        if pair.len() != 2 {
            return Err(bad("bad grid pair"));
        }
        match (pair[0].as_f64(), pair[1].as_f64()) {
            (Some(l), Some(t)) => grid.push((l, t)),
            _ => return Err(bad("bad grid pair")),
        }
    }
    consumed += n as u64;

    // Point lines: accept while well-formed, in sequence, and on-grid.
    let mut points: Vec<PathPoint> = Vec::new();
    let mut model = None;
    loop {
        line.clear();
        let n = match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break, // unreadable tail: keep the valid prefix
        };
        if !line.ends_with('\n') {
            break; // torn final line (interrupted write)
        }
        let parsed = match Json::parse(line.trim_end()) {
            Ok(v) => v,
            Err(_) => break,
        };
        if parsed.get("kind").and_then(|v| v.as_str()) != Some("point")
            || parsed.get("k").and_then(|v| v.as_usize()) != Some(points.len())
            || points.len() >= grid.len()
        {
            break;
        }
        let (point, m) = match (
            parsed.get("point").and_then(point_from_json),
            parsed.get("model").and_then(|j| model_from_json(j, p, q)),
        ) {
            (Some(p), Some(m)) => (p, m),
            _ => break,
        };
        // The line must belong to this grid position (guards against a
        // checkpoint written by a different run being resumed by accident).
        let (gl, gt) = grid[points.len()];
        if point.lam_l != gl || point.lam_t != gt {
            break;
        }
        points.push(point);
        model = Some(m);
        consumed += n as u64;
    }

    Ok(CheckpointState {
        solver,
        p,
        q,
        grid,
        points,
        model,
        valid_bytes: consumed,
    })
}

// ------------------------------------------------------------- model files

/// Bump when the model-file line format changes incompatibly.
const MODEL_VERSION: f64 = 1.0;

/// A standalone saved model (serve `save` op / `cggm serve` restart seed):
///
/// ```text
/// {"kind":"model","version":1,"solver":"alt_newton_cd","p":20,"q":10,
///  "lambda_l":0.5,"lambda_t":0.4}
/// {"kind":"weights","model":{"lambda":{...},"theta":{...}}}
/// ```
///
/// Same exact-f64 encoding as the path checkpoint, so a model saved,
/// evicted, and re-loaded warm-starts from the *identical* iterate.
pub struct ModelFile {
    /// [`crate::solvers::SolverKind::name`] of the solver that fitted it.
    pub solver: String,
    pub p: usize,
    pub q: usize,
    /// (λ_Λ, λ_Θ) the model was fitted at — the warm-start cache key.
    pub lam: (f64, f64),
    pub model: CggmModel,
}

/// Write a fitted model (+ its identity) as a two-line JSONL file. Both
/// lines are flushed; the write is atomic enough for the serve `save` op
/// (a torn file is rejected whole by [`load_model`], never half-adopted).
pub fn save_model(
    path: &Path,
    solver: &str,
    lam: (f64, f64),
    model: &CggmModel,
) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut file = std::fs::File::create(path)?;
    let header = Json::obj(vec![
        ("kind", Json::str("model")),
        ("version", Json::num(MODEL_VERSION)),
        ("solver", Json::str(solver)),
        ("p", Json::num(model.theta.rows() as f64)),
        ("q", Json::num(model.lambda.rows() as f64)),
        ("lambda_l", Json::num(lam.0)),
        ("lambda_t", Json::num(lam.1)),
    ]);
    writeln!(file, "{}", header.to_string())?;
    let weights = Json::obj(vec![
        ("kind", Json::str("weights")),
        ("model", model_to_json(model)),
    ]);
    writeln!(file, "{}", weights.to_string())?;
    file.flush()
}

/// Load a saved model file. Unlike the append-only logs there is no
/// valid-prefix notion: a model is adopted whole or rejected whole (a
/// truncated or shape-hostile file must never seed a warm start).
pub fn load_model(path: &Path) -> std::io::Result<ModelFile> {
    let file = std::fs::File::open(path)?;
    load_model_from(std::io::BufReader::new(file))
}

/// Reader-generic body of [`load_model`] — also a fuzz entry point.
pub fn load_model_from<R: BufRead>(mut reader: R) -> std::io::Result<ModelFile> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 || !line.ends_with('\n') {
        return Err(bad("missing model header"));
    }
    let header = Json::parse(line.trim_end()).map_err(|e| bad(&format!("bad header: {e}")))?;
    if header.get("kind").and_then(|v| v.as_str()) != Some("model")
        || header.get("version").and_then(|v| v.as_f64()) != Some(MODEL_VERSION)
    {
        return Err(bad("not a cggm model file (kind/version mismatch)"));
    }
    let solver = header
        .get("solver")
        .and_then(|v| v.as_str())
        .ok_or_else(|| bad("header missing solver"))?
        .to_string();
    // Dims bounded before the weight line allocates anything (same hostile-
    // header posture as the checkpoint loaders).
    let p = header
        .get("p")
        .and_then(|v| v.as_usize())
        .filter(|&p| p <= MAX_DIM)
        .ok_or_else(|| bad("header p missing or out of range"))?;
    let q = header
        .get("q")
        .and_then(|v| v.as_usize())
        .filter(|&q| q <= MAX_DIM)
        .ok_or_else(|| bad("header q missing or out of range"))?;
    let lam = match (
        header.get("lambda_l").and_then(|v| v.as_f64()),
        header.get("lambda_t").and_then(|v| v.as_f64()),
    ) {
        (Some(l), Some(t)) => (l, t),
        _ => return Err(bad("header missing lambda_l/lambda_t")),
    };
    line.clear();
    if reader.read_line(&mut line)? == 0 || !line.ends_with('\n') {
        return Err(bad("missing or torn weights line"));
    }
    let weights = Json::parse(line.trim_end()).map_err(|e| bad(&format!("bad weights: {e}")))?;
    if weights.get("kind").and_then(|v| v.as_str()) != Some("weights") {
        return Err(bad("second line is not a weights record"));
    }
    let model = weights
        .get("model")
        .and_then(|j| model_from_json(j, p, q))
        .ok_or_else(|| bad("weights do not match the declared shape"))?;
    Ok(ModelFile {
        solver,
        p,
        q,
        lam,
        model,
    })
}

// ------------------------------------------------------------ cv streaming

/// Bump when the CV line format changes incompatibly.
const CV_VERSION: f64 = 1.0;

/// Streaming checkpoint for [`super::cv::cross_validate`]: one JSONL file
/// shared by all (possibly parallel) folds.
///
/// ```text
/// {"kind":"cv_header","version":1,"solver":"alt_newton_cd","p":10,"q":10,
///  "n":90,"folds":3,"seed":24397,"grid":[[0.5,0.4], ...]}
/// {"kind":"cv_point","fold":1,"k":0,"nll":12.25}
/// {"kind":"cv_point","fold":0,"k":0,"nll":12.5}
/// {"kind":"cv_fold","fold":1,"fallbacks":0}
/// ...
/// ```
///
/// Unlike the λ-path log, lines from different folds interleave (folds run
/// on parallel threads), so records self-describe their (fold, k) slot and
/// order carries no meaning. Resume granularity is the *fold*: a fold with
/// a `cv_fold` done-marker is carried over verbatim; a partially scored
/// fold is re-run from scratch (its stray `cv_point` lines are ignored).
/// The header pins solver, shape, fold count, and the shuffle seed — the
/// fold *assignment* must be byte-identical for carried scores to mean
/// anything, so a mismatch refuses to resume, exactly like the path log.
///
/// Writes are serialized through an internal lock and flushed per line; an
/// I/O failure mid-run disables the writer with a warning instead of
/// failing the cross-validation (the checkpoint just ends early).
pub struct CvCheckpointWriter {
    file: std::sync::Mutex<std::fs::File>,
    failed: std::sync::atomic::AtomicBool,
}

impl CvCheckpointWriter {
    /// Start a fresh CV checkpoint (truncates any existing file).
    #[allow(clippy::too_many_arguments)]
    pub fn create(
        path: &Path,
        solver: &str,
        p: usize,
        q: usize,
        n: usize,
        folds: usize,
        seed: u64,
        grid: &[(f64, f64)],
    ) -> std::io::Result<CvCheckpointWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut file = std::fs::File::create(path)?;
        let header = Json::obj(vec![
            ("kind", Json::str("cv_header")),
            ("version", Json::num(CV_VERSION)),
            ("solver", Json::str(solver)),
            ("p", Json::num(p as f64)),
            ("q", Json::num(q as f64)),
            ("n", Json::num(n as f64)),
            ("folds", Json::num(folds as f64)),
            ("seed", Json::num(seed as f64)),
            (
                "grid",
                Json::arr(
                    grid.iter()
                        .map(|&(l, t)| Json::arr([Json::num(l), Json::num(t)])),
                ),
            ),
        ]);
        writeln!(file, "{}", header.to_string())?;
        file.flush()?;
        Ok(CvCheckpointWriter {
            file: std::sync::Mutex::new(file),
            failed: std::sync::atomic::AtomicBool::new(false),
        })
    }

    /// Reopen a validated CV checkpoint for appending, truncating any torn
    /// trailing line first (same contract as [`CheckpointWriter::append_after`]).
    pub fn append_after(path: &Path, valid_bytes: u64) -> std::io::Result<CvCheckpointWriter> {
        use std::io::Seek;
        let mut file = std::fs::OpenOptions::new().write(true).open(path)?;
        file.set_len(valid_bytes)?;
        file.seek(std::io::SeekFrom::End(0))?;
        Ok(CvCheckpointWriter {
            file: std::sync::Mutex::new(file),
            failed: std::sync::atomic::AtomicBool::new(false),
        })
    }

    fn write_line(&self, line: Json) {
        use std::sync::atomic::Ordering;
        if self.failed.load(Ordering::Relaxed) {
            return;
        }
        let mut file = self.file.lock().unwrap();
        let res = writeln!(file, "{}", line.to_string()).and_then(|_| file.flush());
        if let Err(e) = res {
            // A dead checkpoint must not kill the CV run — the log simply
            // ends early and a resume re-runs the unrecorded folds.
            self.failed.store(true, Ordering::Relaxed);
            eprintln!("warning: cv checkpoint write failed: {e}");
        }
    }

    /// Record one scored grid point of one fold.
    pub fn record_point(&self, fold: usize, k: usize, nll: f64) {
        self.write_line(Json::obj(vec![
            ("kind", Json::str("cv_point")),
            ("fold", Json::num(fold as f64)),
            ("k", Json::num(k as f64)),
            // JSON has no Inf/NaN: unscored/diverged points round-trip
            // through null (see the loader).
            ("nll", Json::num(nll)),
        ]));
    }

    /// Mark a fold complete (every grid point it will ever score is on
    /// disk); resumed runs carry such folds over verbatim.
    pub fn record_fold_done(&self, fold: usize, fallbacks: usize) {
        self.write_line(Json::obj(vec![
            ("kind", Json::str("cv_fold")),
            ("fold", Json::num(fold as f64)),
            ("fallbacks", Json::num(fallbacks as f64)),
        ]));
    }
}

/// The valid prefix of a CV checkpoint file.
pub struct CvCheckpointState {
    pub solver: String,
    pub p: usize,
    pub q: usize,
    pub n: usize,
    pub folds: usize,
    pub seed: u64,
    pub grid: Vec<(f64, f64)>,
    /// Per-fold, per-grid-point held-out NLL (NaN where unrecorded).
    pub nll: Vec<Vec<f64>>,
    /// Folds whose done-marker is on disk — the resume unit.
    pub done: Vec<bool>,
    /// Screening fallbacks of each completed fold.
    pub fallbacks: Vec<usize>,
    /// Byte length of the valid prefix (torn tails are truncated on
    /// resume).
    pub valid_bytes: u64,
}

impl CvCheckpointState {
    /// Number of completed (carried-over) folds.
    pub fn completed_folds(&self) -> usize {
        self.done.iter().filter(|&&d| d).count()
    }
}

/// Parse the valid prefix of a CV checkpoint. Errors only on unreadable
/// files or a malformed *header*; a malformed line merely ends the prefix.
pub fn load_cv(path: &Path) -> std::io::Result<CvCheckpointState> {
    let file = std::fs::File::open(path)?;
    load_cv_from(std::io::BufReader::new(file))
}

/// Reader-generic body of [`load_cv`] — also the fuzz-target entry point.
pub fn load_cv_from<R: BufRead>(mut reader: R) -> std::io::Result<CvCheckpointState> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let mut line = String::new();
    let n_read = reader.read_line(&mut line)?;
    if n_read == 0 || !line.ends_with('\n') {
        return Err(bad("missing cv checkpoint header"));
    }
    let header = Json::parse(line.trim_end()).map_err(|e| bad(&format!("bad header: {e}")))?;
    if header.get("kind").and_then(|v| v.as_str()) != Some("cv_header")
        || header.get("version").and_then(|v| v.as_f64()) != Some(CV_VERSION)
    {
        return Err(bad("not a cggm cv checkpoint (kind/version mismatch)"));
    }
    let field = |key: &str| -> std::io::Result<usize> {
        header
            .get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad(&format!("header missing {key}")))
    };
    let solver = header
        .get("solver")
        .and_then(|v| v.as_str())
        .ok_or_else(|| bad("header missing solver"))?
        .to_string();
    let range = |key: &str, val: usize, cap: usize| -> std::io::Result<usize> {
        if val <= cap {
            Ok(val)
        } else {
            Err(bad(&format!("header {key} out of range")))
        }
    };
    let p = range("p", field("p")?, MAX_DIM)?;
    let q = range("q", field("q")?, MAX_DIM)?;
    let n = range("n", field("n")?, MAX_DIM)?;
    // The loader allocates folds × grid score slots below — cap it.
    let folds = range("folds", field("folds")?.max(1), MAX_FOLDS)?;
    let seed = header
        .get("seed")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| bad("header missing seed"))?;
    let mut grid = Vec::new();
    for pair in header
        .get("grid")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| bad("header missing grid"))?
    {
        match pair.as_arr() {
            Some([l, t]) => match (l.as_f64(), t.as_f64()) {
                (Some(l), Some(t)) => grid.push((l, t)),
                _ => return Err(bad("bad grid pair")),
            },
            _ => return Err(bad("bad grid pair")),
        }
    }
    let mut consumed = n_read as u64;
    // folds and grid are individually bounded, but their *product* sizes
    // the score table — bound it too before allocating.
    if folds.saturating_mul(grid.len()) > MAX_FOLDS {
        return Err(bad("header folds × grid out of range"));
    }
    let mut nll = vec![vec![f64::NAN; grid.len()]; folds];
    let mut done = vec![false; folds];
    let mut fallbacks = vec![0usize; folds];
    loop {
        line.clear();
        let n_read = match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break,
        };
        if !line.ends_with('\n') {
            break; // torn final line
        }
        let Ok(parsed) = Json::parse(line.trim_end()) else {
            break;
        };
        let fold = parsed.get("fold").and_then(|v| v.as_usize());
        match (parsed.get("kind").and_then(|v| v.as_str()), fold) {
            (Some("cv_point"), Some(f)) if f < folds => {
                let (Some(k), Some(x)) = (
                    parsed.get("k").and_then(|v| v.as_usize()),
                    // null = the writer's Inf/NaN (heldout_nll diverged).
                    parsed.get("nll").map(|v| match v {
                        Json::Null => f64::INFINITY,
                        other => other.as_f64().unwrap_or(f64::NAN),
                    }),
                ) else {
                    break;
                };
                if k >= grid.len() {
                    break;
                }
                nll[f][k] = x;
            }
            (Some("cv_fold"), Some(f)) if f < folds => {
                done[f] = true;
                fallbacks[f] = parsed
                    .get("fallbacks")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0);
            }
            _ => break,
        }
        consumed += n_read as u64;
    }
    Ok(CvCheckpointState {
        solver,
        p,
        q,
        n,
        folds,
        seed,
        grid,
        nll,
        done,
        fallbacks,
        valid_bytes: consumed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_point(lam: f64) -> PathPoint {
        PathPoint {
            lam_l: lam,
            lam_t: lam / 2.0,
            iters: 3,
            converged: true,
            f: -1.25 + lam,
            lambda_nnz: 7,
            theta_nnz: 4,
            seconds: 0.5,
            coord_updates: 100,
            kkt_scans: 10,
            screened: true,
            fallback: false,
            reclusterings: 1,
        }
    }

    fn dummy_model() -> CggmModel {
        let mut m = CggmModel::init(3, 2);
        m.lambda.set_sym(0, 1, -0.625);
        m.theta.set(2, 1, 0.1 + 0.2); // deliberately non-representable sum
        m
    }

    #[test]
    fn model_roundtrips_bit_exactly() {
        let m = dummy_model();
        let j = model_to_json(&m);
        let back = model_from_json(&Json::parse(&j.to_string()).unwrap(), 3, 2).unwrap();
        assert_eq!(back.lambda, m.lambda);
        assert_eq!(back.theta, m.theta);
        // The awkward float survived exactly.
        assert_eq!(back.theta.get(2, 1).to_bits(), (0.1f64 + 0.2).to_bits());
    }

    /// A point line may not re-declare the problem shape: the model decoder
    /// validates declared dims against the header *before* allocating.
    #[test]
    fn model_with_wrong_declared_shape_is_rejected_not_allocated() {
        let m = dummy_model();
        let j = Json::parse(&model_to_json(&m).to_string()).unwrap();
        assert!(model_from_json(&j, 3, 2).is_some());
        assert!(model_from_json(&j, 2, 3).is_none(), "shape mismatch");
        // A hostile declared shape (would be a ~PB allocation if trusted).
        let hostile = Json::obj(vec![
            (
                "lambda",
                Json::obj(vec![
                    ("rows", Json::num(1e15)),
                    ("cols", Json::num(1e15)),
                    ("entries", Json::Arr(vec![])),
                ]),
            ),
            ("theta", model_to_json(&m).get("theta").unwrap().clone()),
        ]);
        assert!(model_from_json(&hostile, 3, 2).is_none());
    }

    #[test]
    fn write_load_roundtrip_and_torn_tail() {
        let path = std::env::temp_dir().join("cggm_ckpt_unit.jsonl");
        let grid = vec![(0.5, 0.25), (0.25, 0.125), (0.125, 0.0625)];
        let mut w = CheckpointWriter::create(&path, "alt_newton_cd", 3, 2, &grid).unwrap();
        let model = dummy_model();
        w.record(0, &dummy_point(0.5), &model).unwrap();
        w.record(1, &dummy_point(0.25), &model).unwrap();
        drop(w);
        let state = load(&path).unwrap();
        assert_eq!(state.solver, "alt_newton_cd");
        assert_eq!((state.p, state.q), (3, 2));
        assert_eq!(state.grid, grid);
        assert_eq!(state.points.len(), 2);
        assert_eq!(state.points[1].lam_l, 0.25);
        assert!(state.model.is_some());
        // Tear the last line in half: the prefix survives, the tail is
        // ignored, and valid_bytes points at the end of point 0.
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let torn = format!(
            "{}\n{}\n{}",
            lines[0],
            lines[1],
            &lines[2][..lines[2].len() / 2]
        );
        std::fs::write(&path, &torn).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.points.len(), 1);
        assert_eq!(
            state.valid_bytes as usize,
            lines[0].len() + lines[1].len() + 2
        );
        // Appending after the valid prefix drops the torn tail.
        let mut w = CheckpointWriter::append_after(&path, state.valid_bytes).unwrap();
        w.record(1, &dummy_point(0.25), &model).unwrap();
        drop(w);
        let state = load(&path).unwrap();
        assert_eq!(state.points.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cv_checkpoint_roundtrip_interleaved_and_torn() {
        let path = std::env::temp_dir().join("cggm_cv_ckpt_unit.jsonl");
        let grid = vec![(0.5, 0.5), (0.25, 0.25)];
        let w = CvCheckpointWriter::create(&path, "alt_newton_cd", 4, 3, 30, 3, 99, &grid)
            .unwrap();
        // Folds interleave arbitrarily; fold 1 completes, fold 0 is partial,
        // fold 2 never starts. One diverged point round-trips through null.
        w.record_point(1, 0, 2.5);
        w.record_point(0, 0, 3.5);
        w.record_point(1, 1, f64::INFINITY);
        w.record_fold_done(1, 2);
        drop(w);
        let state = load_cv(&path).unwrap();
        assert_eq!(state.solver, "alt_newton_cd");
        assert_eq!((state.p, state.q, state.n), (4, 3, 30));
        assert_eq!((state.folds, state.seed), (3, 99));
        assert_eq!(state.grid, grid);
        assert_eq!(state.done, vec![false, true, false]);
        assert_eq!(state.completed_folds(), 1);
        assert_eq!(state.fallbacks[1], 2);
        assert_eq!(state.nll[1][0], 2.5);
        assert_eq!(state.nll[1][1], f64::INFINITY);
        assert_eq!(state.nll[0][0], 3.5);
        assert!(state.nll[0][1].is_nan());
        assert!(state.nll[2][0].is_nan());
        // Tear the done-marker line in half: fold 1 degrades to partial and
        // valid_bytes stops before the tear.
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let torn: String = lines[..4].iter().map(|l| format!("{l}\n")).collect::<String>()
            + &lines[4][..lines[4].len() / 2];
        std::fs::write(&path, &torn).unwrap();
        let state = load_cv(&path).unwrap();
        assert_eq!(state.done, vec![false, false, false]);
        assert_eq!(state.nll[1][0], 2.5, "point lines before the tear survive");
        // Appending after the valid prefix drops the torn tail and the
        // re-recorded done marker is honored.
        let w = CvCheckpointWriter::append_after(&path, state.valid_bytes).unwrap();
        w.record_fold_done(1, 2);
        drop(w);
        let state = load_cv(&path).unwrap();
        assert_eq!(state.done, vec![false, true, false]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn model_file_roundtrips_and_rejects_hostile_input() {
        let path = std::env::temp_dir().join("cggm_model_unit.jsonl");
        let m = dummy_model();
        save_model(&path, "alt_newton_cd", (0.5, 0.25), &m).unwrap();
        let back = load_model(&path).unwrap();
        assert_eq!(back.solver, "alt_newton_cd");
        assert_eq!((back.p, back.q), (3, 2));
        assert_eq!(back.lam, (0.5, 0.25));
        assert_eq!(back.model.lambda, m.lambda);
        assert_eq!(back.model.theta, m.theta);
        assert_eq!(
            back.model.theta.get(2, 1).to_bits(),
            (0.1f64 + 0.2).to_bits(),
            "exact-f64 roundtrip"
        );
        // Torn weights line: rejected whole, never half-adopted.
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        std::fs::write(&path, format!("{}\n{}", lines[0], &lines[1][..lines[1].len() / 2]))
            .unwrap();
        assert!(load_model(&path).is_err());
        // Hostile header dims: rejected before allocation.
        std::fs::write(
            &path,
            concat!(
                r#"{"kind":"model","version":1,"solver":"alt_newton_cd","#,
                r#""p":1e15,"q":2,"lambda_l":0.5,"lambda_t":0.25}"#,
                "\n{\"kind\":\"weights\",\"model\":{}}\n"
            ),
        )
        .unwrap();
        assert!(load_model(&path).is_err());
        // A path checkpoint is not a model file.
        let grid = vec![(0.5, 0.5)];
        let w = CheckpointWriter::create(&path, "alt_newton_cd", 3, 2, &grid).unwrap();
        drop(w);
        assert!(load_model(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cv_checkpoint_rejects_foreign_headers() {
        let path = std::env::temp_dir().join("cggm_cv_ckpt_bad.jsonl");
        // A λ-path checkpoint is not a CV checkpoint (and vice versa).
        let grid = vec![(0.5, 0.5)];
        let w = CheckpointWriter::create(&path, "alt_newton_cd", 3, 2, &grid).unwrap();
        drop(w);
        assert!(load_cv(&path).is_err());
        std::fs::write(&path, "garbage\n").unwrap();
        assert!(load_cv(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_header_is_an_error_and_sequence_gaps_stop_the_prefix() {
        let path = std::env::temp_dir().join("cggm_ckpt_bad.jsonl");
        std::fs::write(&path, "not json at all\n").unwrap();
        assert!(load(&path).is_err());
        // Out-of-sequence k ends the prefix instead of corrupting it.
        let grid = vec![(0.5, 0.5), (0.25, 0.25)];
        let mut w = CheckpointWriter::create(&path, "alt_newton_cd", 3, 2, &grid).unwrap();
        w.record(1, &dummy_point(0.25), &dummy_model()).unwrap(); // gap: no k=0
        drop(w);
        let state = load(&path).unwrap();
        assert_eq!(state.points.len(), 0);
        assert!(state.model.is_none());
        let _ = std::fs::remove_file(&path);
    }
}
