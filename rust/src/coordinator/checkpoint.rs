//! λ-path checkpointing: stream each fitted [`PathPoint`] (+ model) to a
//! JSONL file so giant sweeps survive interruption, and resume from the last
//! fitted λ (`cggm path --resume <ckpt>`).
//!
//! # Format
//!
//! One JSON object per line. The first line is a header pinning the run:
//!
//! ```text
//! {"kind":"header","version":1,"solver":"alt_newton_cd","p":20,"q":10,
//!  "grid":[[0.5,0.4],[0.25,0.2], ...]}
//! {"kind":"point","k":0,"point":{...},"model":{"lambda":{...},"theta":{...}}}
//! {"kind":"point","k":1, ...}
//! ```
//!
//! Every record is written with a trailing newline and flushed immediately,
//! so a run killed mid-write leaves at most one truncated final line.
//! [`load`] tolerates exactly that: it stops at the first malformed or
//! out-of-sequence line and returns the valid prefix — the resumed sweep
//! refits from the last *valid* point, which is the strongest guarantee an
//! append-only log can give. A file whose header is unreadable is treated as
//! no checkpoint at all (the driver starts fresh and rewrites it).
//!
//! Numbers round-trip exactly: the writer emits shortest-roundtrip f64
//! representations and the reader parses them back bit-identically, so a
//! resumed warm start is the same iterate the interrupted run held — resumed
//! objectives reproduce an uninterrupted sweep's to well under 1e-8 (pinned
//! by `checkpoint_tests`).

use super::PathPoint;
use crate::cggm::CggmModel;
use crate::linalg::sparse::SpRowMat;
use crate::util::json::Json;
use std::io::{BufRead, Write};
use std::path::Path;

/// Bump when the line format changes incompatibly.
const VERSION: f64 = 1.0;

// ---------------------------------------------------------------- encoding

fn sparse_to_json(m: &SpRowMat) -> Json {
    let mut entries = Vec::with_capacity(m.nnz());
    for i in 0..m.rows() {
        for &(j, v) in m.row(i) {
            entries.push(Json::arr([
                Json::num(i as f64),
                Json::num(j as f64),
                Json::num(v),
            ]));
        }
    }
    Json::obj(vec![
        ("rows", Json::num(m.rows() as f64)),
        ("cols", Json::num(m.cols() as f64)),
        ("entries", Json::Arr(entries)),
    ])
}

fn sparse_from_json(j: &Json) -> Option<SpRowMat> {
    let rows = j.get("rows")?.as_usize()?;
    let cols = j.get("cols")?.as_usize()?;
    let mut m = SpRowMat::zeros(rows, cols);
    for e in j.get("entries")?.as_arr()? {
        let e = e.as_arr()?;
        if e.len() != 3 {
            return None;
        }
        let (i, jj) = (e[0].as_usize()?, e[1].as_usize()?);
        if i >= rows || jj >= cols {
            return None;
        }
        m.set(i, jj, e[2].as_f64()?);
    }
    Some(m)
}

fn model_to_json(model: &CggmModel) -> Json {
    Json::obj(vec![
        ("lambda", sparse_to_json(&model.lambda)),
        ("theta", sparse_to_json(&model.theta)),
    ])
}

fn model_from_json(j: &Json) -> Option<CggmModel> {
    let lambda = sparse_from_json(j.get("lambda")?)?;
    let theta = sparse_from_json(j.get("theta")?)?;
    if lambda.rows() != lambda.cols() || theta.cols() != lambda.rows() {
        return None;
    }
    Some(CggmModel { lambda, theta })
}

fn point_to_json(p: &PathPoint) -> Json {
    Json::obj(vec![
        ("lambda_l", Json::num(p.lam_l)),
        ("lambda_t", Json::num(p.lam_t)),
        ("iters", Json::num(p.iters as f64)),
        ("converged", Json::Bool(p.converged)),
        ("f", Json::num(p.f)),
        ("lambda_nnz", Json::num(p.lambda_nnz as f64)),
        ("theta_nnz", Json::num(p.theta_nnz as f64)),
        ("seconds", Json::num(p.seconds)),
        ("coord_updates", Json::num(p.coord_updates as f64)),
        ("kkt_scans", Json::num(p.kkt_scans as f64)),
        ("screened", Json::Bool(p.screened)),
        ("fallback", Json::Bool(p.fallback)),
        ("reclusterings", Json::num(p.reclusterings as f64)),
    ])
}

fn point_from_json(j: &Json) -> Option<PathPoint> {
    Some(PathPoint {
        lam_l: j.get("lambda_l")?.as_f64()?,
        lam_t: j.get("lambda_t")?.as_f64()?,
        iters: j.get("iters")?.as_usize()?,
        converged: j.get("converged")?.as_bool()?,
        f: j.get("f")?.as_f64()?,
        lambda_nnz: j.get("lambda_nnz")?.as_usize()?,
        theta_nnz: j.get("theta_nnz")?.as_usize()?,
        seconds: j.get("seconds")?.as_f64()?,
        coord_updates: j.get("coord_updates")?.as_usize()?,
        kkt_scans: j.get("kkt_scans")?.as_usize()?,
        screened: j.get("screened")?.as_bool()?,
        fallback: j.get("fallback")?.as_bool()?,
        reclusterings: j.get("reclusterings")?.as_usize()?,
    })
}

// ------------------------------------------------------------------ writer

/// Append-only checkpoint writer; every record is flushed as one line.
pub struct CheckpointWriter {
    file: std::fs::File,
}

impl CheckpointWriter {
    /// Start a fresh checkpoint (truncates any existing file) and write the
    /// header pinning solver, problem shape, and the full λ grid.
    pub fn create(
        path: &Path,
        solver: &str,
        p: usize,
        q: usize,
        grid: &[(f64, f64)],
    ) -> std::io::Result<CheckpointWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut file = std::fs::File::create(path)?;
        let header = Json::obj(vec![
            ("kind", Json::str("header")),
            ("version", Json::num(VERSION)),
            ("solver", Json::str(solver)),
            ("p", Json::num(p as f64)),
            ("q", Json::num(q as f64)),
            (
                "grid",
                Json::arr(
                    grid.iter()
                        .map(|&(l, t)| Json::arr([Json::num(l), Json::num(t)])),
                ),
            ),
        ]);
        writeln!(file, "{}", header.to_string())?;
        file.flush()?;
        Ok(CheckpointWriter { file })
    }

    /// Reopen an existing checkpoint for appending (resume). The caller has
    /// already validated the prefix via [`load`]; anything after the last
    /// valid point (a torn final line) is truncated away first so the log
    /// stays parseable.
    pub fn append_after(path: &Path, valid_bytes: u64) -> std::io::Result<CheckpointWriter> {
        let file = std::fs::OpenOptions::new().write(true).open(path)?;
        file.set_len(valid_bytes)?;
        use std::io::Seek;
        let mut file = file;
        file.seek(std::io::SeekFrom::End(0))?;
        Ok(CheckpointWriter { file })
    }

    /// Write one fitted point (+ the model at that point) as a flushed line.
    pub fn record(
        &mut self,
        k: usize,
        point: &PathPoint,
        model: &CggmModel,
    ) -> std::io::Result<()> {
        let line = Json::obj(vec![
            ("kind", Json::str("point")),
            ("k", Json::num(k as f64)),
            ("point", point_to_json(point)),
            ("model", model_to_json(model)),
        ]);
        writeln!(self.file, "{}", line.to_string())?;
        self.file.flush()
    }
}

// ------------------------------------------------------------------ loader

/// The valid prefix of a checkpoint file.
pub struct CheckpointState {
    pub solver: String,
    /// Problem shape the header pinned — the resume path refuses a
    /// checkpoint whose shape or solver does not match the current run.
    pub p: usize,
    pub q: usize,
    /// The full grid the interrupted sweep was running (header line).
    pub grid: Vec<(f64, f64)>,
    /// Fitted points 0..k, in grid order.
    pub points: Vec<PathPoint>,
    /// Model at the last valid point (`None` when no point line survived).
    pub model: Option<CggmModel>,
    /// Byte length of the valid prefix — everything after this (a torn
    /// trailing line) is garbage to be truncated on resume.
    pub valid_bytes: u64,
}

/// Parse the valid prefix of a checkpoint. Errors only when the file cannot
/// be read or its *header* is malformed (no run to resume); a corrupt or
/// truncated point line merely ends the prefix, and the resumed sweep refits
/// from the last valid point.
pub fn load(path: &Path) -> std::io::Result<CheckpointState> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let file = std::fs::File::open(path)?;
    let mut reader = std::io::BufReader::new(file);
    let mut line = String::new();
    let mut consumed: u64 = 0;

    // Header.
    let n = reader.read_line(&mut line)?;
    if n == 0 || !line.ends_with('\n') {
        return Err(bad("missing checkpoint header"));
    }
    let header = Json::parse(line.trim_end()).map_err(|e| bad(&format!("bad header: {e}")))?;
    if header.get("kind").and_then(|v| v.as_str()) != Some("header")
        || header.get("version").and_then(|v| v.as_f64()) != Some(VERSION)
    {
        return Err(bad("not a cggm path checkpoint (kind/version mismatch)"));
    }
    let solver = header
        .get("solver")
        .and_then(|v| v.as_str())
        .ok_or_else(|| bad("header missing solver"))?
        .to_string();
    let p = header
        .get("p")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| bad("header missing p"))?;
    let q = header
        .get("q")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| bad("header missing q"))?;
    let mut grid = Vec::new();
    for pair in header
        .get("grid")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| bad("header missing grid"))?
    {
        let pair = pair.as_arr().ok_or_else(|| bad("bad grid pair"))?;
        if pair.len() != 2 {
            return Err(bad("bad grid pair"));
        }
        match (pair[0].as_f64(), pair[1].as_f64()) {
            (Some(l), Some(t)) => grid.push((l, t)),
            _ => return Err(bad("bad grid pair")),
        }
    }
    consumed += n as u64;

    // Point lines: accept while well-formed, in sequence, and on-grid.
    let mut points: Vec<PathPoint> = Vec::new();
    let mut model = None;
    loop {
        line.clear();
        let n = match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break, // unreadable tail: keep the valid prefix
        };
        if !line.ends_with('\n') {
            break; // torn final line (interrupted write)
        }
        let parsed = match Json::parse(line.trim_end()) {
            Ok(v) => v,
            Err(_) => break,
        };
        if parsed.get("kind").and_then(|v| v.as_str()) != Some("point")
            || parsed.get("k").and_then(|v| v.as_usize()) != Some(points.len())
            || points.len() >= grid.len()
        {
            break;
        }
        let (point, m) = match (
            parsed.get("point").and_then(point_from_json),
            parsed.get("model").and_then(model_from_json),
        ) {
            (Some(p), Some(m)) => (p, m),
            _ => break,
        };
        // The line must belong to this grid position (guards against a
        // checkpoint written by a different run being resumed by accident).
        let (gl, gt) = grid[points.len()];
        if point.lam_l != gl || point.lam_t != gt {
            break;
        }
        points.push(point);
        model = Some(m);
        consumed += n as u64;
    }

    Ok(CheckpointState {
        solver,
        p,
        q,
        grid,
        points,
        model,
        valid_bytes: consumed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_point(lam: f64) -> PathPoint {
        PathPoint {
            lam_l: lam,
            lam_t: lam / 2.0,
            iters: 3,
            converged: true,
            f: -1.25 + lam,
            lambda_nnz: 7,
            theta_nnz: 4,
            seconds: 0.5,
            coord_updates: 100,
            kkt_scans: 10,
            screened: true,
            fallback: false,
            reclusterings: 1,
        }
    }

    fn dummy_model() -> CggmModel {
        let mut m = CggmModel::init(3, 2);
        m.lambda.set_sym(0, 1, -0.625);
        m.theta.set(2, 1, 0.1 + 0.2); // deliberately non-representable sum
        m
    }

    #[test]
    fn model_roundtrips_bit_exactly() {
        let m = dummy_model();
        let j = model_to_json(&m);
        let back = model_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.lambda, m.lambda);
        assert_eq!(back.theta, m.theta);
        // The awkward float survived exactly.
        assert_eq!(back.theta.get(2, 1).to_bits(), (0.1f64 + 0.2).to_bits());
    }

    #[test]
    fn write_load_roundtrip_and_torn_tail() {
        let path = std::env::temp_dir().join("cggm_ckpt_unit.jsonl");
        let grid = vec![(0.5, 0.25), (0.25, 0.125), (0.125, 0.0625)];
        let mut w = CheckpointWriter::create(&path, "alt_newton_cd", 3, 2, &grid).unwrap();
        let model = dummy_model();
        w.record(0, &dummy_point(0.5), &model).unwrap();
        w.record(1, &dummy_point(0.25), &model).unwrap();
        drop(w);
        let state = load(&path).unwrap();
        assert_eq!(state.solver, "alt_newton_cd");
        assert_eq!((state.p, state.q), (3, 2));
        assert_eq!(state.grid, grid);
        assert_eq!(state.points.len(), 2);
        assert_eq!(state.points[1].lam_l, 0.25);
        assert!(state.model.is_some());
        // Tear the last line in half: the prefix survives, the tail is
        // ignored, and valid_bytes points at the end of point 0.
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let torn = format!(
            "{}\n{}\n{}",
            lines[0],
            lines[1],
            &lines[2][..lines[2].len() / 2]
        );
        std::fs::write(&path, &torn).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.points.len(), 1);
        assert_eq!(
            state.valid_bytes as usize,
            lines[0].len() + lines[1].len() + 2
        );
        // Appending after the valid prefix drops the torn tail.
        let mut w = CheckpointWriter::append_after(&path, state.valid_bytes).unwrap();
        w.record(1, &dummy_point(0.25), &model).unwrap();
        drop(w);
        let state = load(&path).unwrap();
        assert_eq!(state.points.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_header_is_an_error_and_sequence_gaps_stop_the_prefix() {
        let path = std::env::temp_dir().join("cggm_ckpt_bad.jsonl");
        std::fs::write(&path, "not json at all\n").unwrap();
        assert!(load(&path).is_err());
        // Out-of-sequence k ends the prefix instead of corrupting it.
        let grid = vec![(0.5, 0.5), (0.25, 0.25)];
        let mut w = CheckpointWriter::create(&path, "alt_newton_cd", 3, 2, &grid).unwrap();
        w.record(1, &dummy_point(0.25), &dummy_model()).unwrap(); // gap: no k=0
        drop(w);
        let state = load(&path).unwrap();
        assert_eq!(state.points.len(), 0);
        assert!(state.model.is_none());
        let _ = std::fs::remove_file(&path);
    }
}
