//! Run configuration: defaults ← JSON config file ← CLI flags.
//!
//! A config file (see `configs/` for committed examples) is a JSON object
//! whose keys mirror the CLI flags; unknown keys are rejected so typos fail
//! loudly.

use crate::cggm::active::ScreenRule;
use crate::cggm::factor::CholKind;
use crate::datagen::Workload;
use crate::solvers::{SolveOptions, SolverKind, StatMode};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::membudget::{parse_bytes, MemBudget};

/// Full run configuration for `cggm fit` / experiment runs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub workload: Workload,
    pub p: usize,
    pub q: usize,
    pub n: usize,
    pub seed: u64,
    pub solver: SolverKind,
    pub lam_l: f64,
    pub lam_t: f64,
    pub max_iter: usize,
    pub tol: f64,
    pub threads: usize,
    /// Threads for the colored coordinate-descent sweeps (`--cd-threads`;
    /// 1 = the serial reference sweeps). Independent of `threads`, which
    /// drives column/GEMM/fold parallelism.
    pub cd_threads: usize,
    pub engine: String,
    pub tile: usize,
    /// Gram-statistics mode (`--stat-mode dense|tiled`): `dense` is the
    /// eager cached path; `tiled` makes the block solver compute S_xx/S_xy
    /// Gram tiles on demand through the budget-bound LRU tile cache
    /// (docs/PERF.md "Tile memory model").
    pub stat_mode: String,
    /// Square tile edge for `stat_mode = tiled` (`--stat-tile`).
    pub stat_tile: usize,
    /// Incremental-statistics drift guard (`--stat-rebuild-every`): force a
    /// from-scratch rebuild of cached Gram statistics after this many
    /// sample-removing window updates (0 = never). See docs/PERF.md.
    pub stat_rebuild_every: usize,
    /// One-shot construction-time probe of native-GEMM cache-block sizes
    /// (`--gemm-autotune`). Machine-dependent by design; mutually exclusive
    /// with `gemm_blocks`, which wins when both are set.
    pub gemm_autotune: bool,
    /// Explicit native-GEMM cache blocks `(mc, kc, nc)`
    /// (`--gemm-blocks mc,kc,nc` / config string `"mc,kc,nc"`).
    pub gemm_blocks: Option<(usize, usize, usize)>,
    pub mem_budget: Option<usize>,
    pub clustering: bool,
    pub time_limit: f64,
    pub calibrate: bool,
    pub out_dir: String,
    /// λ-path sweep: number of grid points (`cggm path`).
    pub path_points: usize,
    /// λ-path sweep: λ_min as a fraction of λ_max.
    pub path_min_ratio: f64,
    /// Path-level screening rule (`cggm path` / `cggm cv`).
    pub screen_rule: ScreenRule,
    /// Cross-validation folds (`cggm cv`).
    pub cv_folds: usize,
    /// Worker threads across CV folds (`cggm cv`).
    pub cv_threads: usize,
    /// One-standard-error rule for CV selection (`--one-se`): pick the
    /// sparsest λ whose mean held-out NLL is within one standard error of
    /// the best.
    pub cv_one_se: bool,
    /// λ-path checkpoint file (`cggm path --checkpoint`; `--resume FILE`
    /// additionally warm-restarts from it).
    pub checkpoint: Option<String>,
    /// Block-solver clustering persistence: active-set churn above which the
    /// cached partition is rebuilt (negative = always rebuild).
    pub recluster_churn: f64,
    /// `cggm serve` / `cggm batch`: bounded worker pool size — at most this
    /// many admitted jobs run concurrently (`--max-jobs`).
    pub serve_max_jobs: usize,
    /// `cggm serve`: shared registry + job budget in bytes
    /// (`--serve-budget 1GB`). Warm dataset statistics, cached warm-start
    /// models, and every running job's working set draw on this one
    /// `MemBudget`; `None` = unlimited.
    pub serve_budget: Option<usize>,
    /// `cggm serve`: serve JSONL over this unix socket instead of stdio
    /// (`--socket /tmp/cggm.sock`).
    pub serve_socket: Option<String>,
    /// Dataset storage policy (`--storage mem|disk`): `mem` keeps X/Y
    /// resident; `disk` binds saved `CGGMPAN1` panel files out-of-core
    /// behind the budget-tracked panel cache (docs/PERF.md "Out-of-core
    /// datasets"). Also selects the `gen --out` format: `disk` writes
    /// sharded panels instead of the dense monolith.
    pub storage: String,
    /// Feature rows per cached panel for disk-backed datasets
    /// (`--panel-rows`).
    pub panel_rows: usize,
    /// Panel-cache budget in bytes for disk-backed datasets
    /// (`--panel-cache 64MB`).
    pub panel_cache: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: Workload::Chain,
            p: 200,
            q: 200,
            n: 100,
            seed: 1,
            solver: SolverKind::AltNewtonCd,
            lam_l: 0.5,
            lam_t: 0.5,
            max_iter: 100,
            tol: 0.01,
            threads: 1,
            cd_threads: 1,
            engine: "native".into(),
            tile: 256,
            stat_mode: "dense".into(),
            stat_tile: 256,
            stat_rebuild_every: 64,
            gemm_autotune: false,
            gemm_blocks: None,
            mem_budget: None,
            clustering: true,
            time_limit: 0.0,
            calibrate: false,
            out_dir: "results".into(),
            path_points: 10,
            path_min_ratio: 0.1,
            screen_rule: ScreenRule::Strong,
            cv_folds: 5,
            cv_threads: 1,
            cv_one_se: false,
            checkpoint: None,
            recluster_churn: 0.2,
            serve_max_jobs: 2,
            serve_budget: None,
            serve_socket: None,
            storage: "mem".into(),
            panel_rows: crate::storage::DEFAULT_PANEL_ROWS,
            panel_cache: crate::storage::DEFAULT_PANEL_CACHE,
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("config file: {0}")]
    Io(#[from] std::io::Error),
    #[error("config parse: {0}")]
    Json(String),
    #[error("unknown config key '{0}'")]
    UnknownKey(String),
    #[error("bad value for '{key}': {msg}")]
    BadValue { key: String, msg: String },
}

impl RunConfig {
    /// Layer a JSON config file over the defaults.
    pub fn from_file(path: &str) -> Result<RunConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text).map_err(|e| ConfigError::Json(e.to_string()))?;
        let mut cfg = RunConfig::default();
        let obj = doc
            .as_obj()
            .ok_or_else(|| ConfigError::Json("top level must be an object".into()))?;
        for (key, val) in obj {
            cfg.apply(key, val)?;
        }
        Ok(cfg)
    }

    /// Apply one `key: value` pair (the serve engine layers per-job request
    /// keys through this too, so jobs and config files share one schema and
    /// one set of error messages).
    pub(crate) fn apply(&mut self, key: &str, val: &Json) -> Result<(), ConfigError> {
        let bad = |msg: &str| ConfigError::BadValue {
            key: key.to_string(),
            msg: msg.to_string(),
        };
        match key {
            "workload" => {
                let s = val.as_str().ok_or_else(|| bad("expected string"))?;
                self.workload = Workload::parse(s).ok_or_else(|| bad("unknown workload"))?;
            }
            "p" => self.p = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?,
            "q" => self.q = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?,
            "n" => self.n = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?,
            "seed" => self.seed = val.as_u64().ok_or_else(|| bad("expected a non-negative integer"))?,
            "solver" => {
                let s = val.as_str().ok_or_else(|| bad("expected string"))?;
                self.solver = SolverKind::parse(s).ok_or_else(|| bad("unknown solver"))?;
            }
            "lambda" => {
                let x = val.as_f64().ok_or_else(|| bad("expected number"))?;
                self.lam_l = x;
                self.lam_t = x;
            }
            "lambda_l" => self.lam_l = val.as_f64().ok_or_else(|| bad("expected number"))?,
            "lambda_t" => self.lam_t = val.as_f64().ok_or_else(|| bad("expected number"))?,
            "max_iter" => self.max_iter = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?,
            "tol" => self.tol = val.as_f64().ok_or_else(|| bad("expected number"))?,
            "threads" => self.threads = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?,
            "cd_threads" => {
                self.cd_threads = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?
            }
            "engine" => {
                self.engine = val.as_str().ok_or_else(|| bad("expected string"))?.into()
            }
            "tile" => self.tile = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?,
            "stat_mode" => {
                let s = val.as_str().ok_or_else(|| bad("expected string"))?;
                if StatMode::parse(s, 1).is_none() {
                    return Err(bad("expected 'dense' or 'tiled'"));
                }
                self.stat_mode = s.into();
            }
            "stat_tile" => {
                let t = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?;
                if t == 0 {
                    return Err(bad("tile edge must be >= 1"));
                }
                self.stat_tile = t;
            }
            "stat_rebuild_every" => {
                self.stat_rebuild_every =
                    val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?
            }
            "gemm_autotune" => {
                self.gemm_autotune = val.as_bool().ok_or_else(|| bad("expected bool"))?
            }
            "gemm_blocks" => {
                let s = val.as_str().ok_or_else(|| bad("expected string 'mc,kc,nc'"))?;
                self.gemm_blocks =
                    Some(parse_block_triple(s).ok_or_else(|| bad("expected 'mc,kc,nc'"))?);
            }
            "mem_budget" => {
                let s = val.as_str().ok_or_else(|| bad("expected string like '512MB'"))?;
                self.mem_budget =
                    Some(parse_bytes(s).ok_or_else(|| bad("unparseable byte size"))?);
            }
            "clustering" => {
                self.clustering = val.as_bool().ok_or_else(|| bad("expected bool"))?
            }
            "time_limit" => {
                self.time_limit = val.as_f64().ok_or_else(|| bad("expected number"))?
            }
            "calibrate" => self.calibrate = val.as_bool().ok_or_else(|| bad("expected bool"))?,
            "out_dir" => {
                self.out_dir = val.as_str().ok_or_else(|| bad("expected string"))?.into()
            }
            "path_points" => {
                self.path_points = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?
            }
            "path_min_ratio" => {
                self.path_min_ratio = val.as_f64().ok_or_else(|| bad("expected number"))?
            }
            "screen_rule" => {
                let s = val.as_str().ok_or_else(|| bad("expected string"))?;
                self.screen_rule =
                    ScreenRule::parse(s).ok_or_else(|| bad("expected 'full' or 'strong'"))?;
            }
            "cv_folds" => self.cv_folds = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?,
            "cv_threads" => {
                self.cv_threads = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?
            }
            "cv_one_se" => {
                self.cv_one_se = val.as_bool().ok_or_else(|| bad("expected bool"))?
            }
            "checkpoint" => {
                self.checkpoint =
                    Some(val.as_str().ok_or_else(|| bad("expected string"))?.into())
            }
            "recluster_churn" => {
                self.recluster_churn = val.as_f64().ok_or_else(|| bad("expected number"))?
            }
            "serve_max_jobs" => {
                self.serve_max_jobs = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?
            }
            "serve_budget" => {
                let s = val.as_str().ok_or_else(|| bad("expected string like '1GB'"))?;
                self.serve_budget =
                    Some(parse_bytes(s).ok_or_else(|| bad("unparseable byte size"))?);
            }
            "serve_socket" => {
                self.serve_socket =
                    Some(val.as_str().ok_or_else(|| bad("expected string"))?.into())
            }
            "storage" => {
                let s = val.as_str().ok_or_else(|| bad("expected string"))?;
                if s != "mem" && s != "disk" {
                    return Err(bad("expected 'mem' or 'disk'"));
                }
                self.storage = s.into();
            }
            "panel_rows" => {
                let r = val.as_usize().ok_or_else(|| bad("expected a non-negative integer"))?;
                if r == 0 {
                    return Err(bad("panel rows must be >= 1"));
                }
                self.panel_rows = r;
            }
            "panel_cache" => {
                let s = val.as_str().ok_or_else(|| bad("expected string like '64MB'"))?;
                self.panel_cache = parse_bytes(s).ok_or_else(|| bad("unparseable byte size"))?;
            }
            other => return Err(ConfigError::UnknownKey(other.to_string())),
        }
        Ok(())
    }

    /// Layer CLI flags over this config.
    pub fn apply_args(&mut self, args: &Args) {
        if let Some(w) = args.opt("workload").and_then(Workload::parse) {
            self.workload = w;
        }
        self.p = args.get_usize("p", self.p);
        self.q = args.get_usize("q", self.q);
        self.n = args.get_usize("n", self.n);
        self.seed = args.get_u64("seed", self.seed);
        if let Some(s) = args.opt("solver").and_then(SolverKind::parse) {
            self.solver = s;
        }
        if let Some(l) = args.opt("lambda") {
            let x: f64 = l.parse().expect("--lambda expects a number");
            self.lam_l = x;
            self.lam_t = x;
        }
        self.lam_l = args.get_f64("lambda-l", self.lam_l);
        self.lam_t = args.get_f64("lambda-t", self.lam_t);
        self.max_iter = args.get_usize("max-iter", self.max_iter);
        self.tol = args.get_f64("tol", self.tol);
        self.threads = args.get_usize("threads", self.threads);
        self.cd_threads = args.get_usize("cd-threads", self.cd_threads);
        self.engine = args.get_str("engine", &self.engine);
        self.tile = args.get_usize("tile", self.tile);
        if let Some(s) = args.opt("stat-mode") {
            assert!(
                StatMode::parse(s, 1).is_some(),
                "--stat-mode expects 'dense' or 'tiled', got '{s}'"
            );
            self.stat_mode = s.to_string();
        }
        self.stat_tile = args.get_usize("stat-tile", self.stat_tile);
        assert!(self.stat_tile >= 1, "--stat-tile expects a tile edge >= 1");
        self.stat_rebuild_every =
            args.get_usize("stat-rebuild-every", self.stat_rebuild_every);
        if args.flag("gemm-autotune") {
            self.gemm_autotune = true;
        }
        if let Some(s) = args.opt("gemm-blocks") {
            self.gemm_blocks = Some(
                parse_block_triple(s)
                    .unwrap_or_else(|| panic!("--gemm-blocks expects mc,kc,nc, got '{s}'")),
            );
        }
        if let Some(b) = args.opt("mem-budget") {
            self.mem_budget = Some(parse_bytes(b).expect("--mem-budget like 512MB"));
        }
        if args.flag("no-clustering") {
            self.clustering = false;
        }
        self.time_limit = args.get_f64("time-limit", self.time_limit);
        if args.flag("calibrate") {
            self.calibrate = true;
        }
        self.out_dir = args.get_str("out", &self.out_dir);
        self.path_points = args.get_usize("path-points", self.path_points);
        self.path_min_ratio = args.get_f64("path-min-ratio", self.path_min_ratio);
        if let Some(s) = args.opt("screen") {
            self.screen_rule =
                ScreenRule::parse(s).expect("--screen expects 'full' or 'strong'");
        }
        self.cv_folds = args.get_usize("folds", self.cv_folds);
        self.cv_threads = args.get_usize("cv-threads", self.cv_threads);
        if args.flag("one-se") {
            self.cv_one_se = true;
        }
        if let Some(ck) = args.opt("checkpoint") {
            self.checkpoint = Some(ck.to_string());
        }
        self.recluster_churn = args.get_f64("recluster-churn", self.recluster_churn);
        self.serve_max_jobs = args.get_usize("max-jobs", self.serve_max_jobs);
        if let Some(b) = args.opt("serve-budget") {
            self.serve_budget = Some(parse_bytes(b).expect("--serve-budget like 1GB"));
        }
        if let Some(s) = args.opt("socket") {
            self.serve_socket = Some(s.to_string());
        }
        if let Some(s) = args.opt("storage") {
            assert!(
                s == "mem" || s == "disk",
                "--storage expects 'mem' or 'disk', got '{s}'"
            );
            self.storage = s.to_string();
        }
        self.panel_rows = args.get_usize("panel-rows", self.panel_rows);
        assert!(self.panel_rows >= 1, "--panel-rows expects >= 1");
        if let Some(b) = args.opt("panel-cache") {
            self.panel_cache = parse_bytes(b).expect("--panel-cache like 64MB");
        }
    }

    /// λ-path options derived from this config (`cggm path` / `cggm cv`).
    /// Resume is a CLI-level decision (`--resume FILE`), layered on by
    /// `cmd_path`.
    pub fn path_options(&self, warm_start: bool) -> crate::coordinator::PathOptions {
        crate::coordinator::PathOptions {
            points: self.path_points,
            min_ratio: self.path_min_ratio,
            lambdas: None,
            warm_start,
            screen: self.screen_rule,
            checkpoint: self.checkpoint.as_ref().map(std::path::PathBuf::from),
            resume: false,
        }
    }

    /// Cross-validation options derived from this config (`cggm cv`).
    /// Resume is a CLI-level decision (`--resume FILE`), layered on by
    /// `cmd_cv`.
    pub fn cv_options(&self) -> crate::coordinator::CvOptions {
        crate::coordinator::CvOptions {
            folds: self.cv_folds,
            seed: self.seed,
            fold_threads: self.cv_threads,
            refit: true,
            one_se: self.cv_one_se,
            checkpoint: self.checkpoint.as_ref().map(std::path::PathBuf::from),
            resume: false,
        }
    }

    /// Produce solver options.
    pub fn solve_options(&self) -> SolveOptions {
        SolveOptions {
            lam_l: self.lam_l,
            lam_t: self.lam_t,
            max_iter: self.max_iter,
            tol: self.tol,
            threads: self.threads,
            cd_threads: self.cd_threads,
            chol: if self.solver == SolverKind::AltNewtonBcd {
                CholKind::Auto
            } else {
                CholKind::Auto
            },
            budget: self
                .mem_budget
                .map(MemBudget::new)
                .unwrap_or_else(MemBudget::unlimited),
            clustering: self.clustering,
            time_limit: self.time_limit,
            seed: self.seed,
            recluster_churn: self.recluster_churn,
            stat_mode: StatMode::parse(&self.stat_mode, self.stat_tile)
                .expect("stat_mode validated at apply time"),
            stat_rebuild_every: self.stat_rebuild_every,
            ..Default::default()
        }
    }
}

/// Parse `"mc,kc,nc"` into a block triple (whitespace-tolerant).
fn parse_block_triple(s: &str) -> Option<(usize, usize, usize)> {
    let parts: Vec<usize> = s
        .split(',')
        .map(|t| t.trim().replace('_', "").parse().ok())
        .collect::<Option<Vec<_>>>()?;
    match parts[..] {
        [mc, kc, nc] => Some((mc, kc, nc)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_and_args_layering() {
        let tmp = std::env::temp_dir().join("cggm_cfg_test.json");
        std::fs::write(
            &tmp,
            r#"{"workload": "cluster", "p": 500, "lambda": 0.7,
                "mem_budget": "64MB", "solver": "bcd"}"#,
        )
        .unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.workload, Workload::Cluster);
        assert_eq!(cfg.p, 500);
        assert_eq!(cfg.lam_l, 0.7);
        assert_eq!(cfg.mem_budget, Some(64 << 20));
        assert_eq!(cfg.solver, SolverKind::AltNewtonBcd);
        // CLI overrides file.
        let args = Args::parse(
            &["--p".into(), "900".into(), "--no-clustering".into()],
            &["no-clustering"],
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.p, 900);
        assert!(!cfg.clustering);
        let opts = cfg.solve_options();
        assert_eq!(opts.lam_l, 0.7);
        assert_eq!(opts.budget.limit(), 64 << 20);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn path_keys_layer_like_the_rest() {
        let tmp = std::env::temp_dir().join("cggm_cfg_path.json");
        std::fs::write(&tmp, r#"{"path_points": 6, "path_min_ratio": 0.05}"#).unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.path_points, 6);
        assert_eq!(cfg.path_min_ratio, 0.05);
        let args = Args::parse(&["--path-points".into(), "8".into()], &[]);
        cfg.apply_args(&args);
        assert_eq!(cfg.path_points, 8);
        let popts = cfg.path_options(true);
        assert_eq!(popts.points, 8);
        assert_eq!(popts.min_ratio, 0.05);
        assert!(popts.warm_start);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn cv_and_screen_keys_layer_like_the_rest() {
        let tmp = std::env::temp_dir().join("cggm_cfg_cv.json");
        std::fs::write(
            &tmp,
            r#"{"cv_folds": 7, "cv_threads": 2, "screen_rule": "full"}"#,
        )
        .unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.cv_folds, 7);
        assert_eq!(cfg.cv_threads, 2);
        assert_eq!(cfg.screen_rule, ScreenRule::Full);
        let args = Args::parse(
            &[
                "--folds".into(),
                "3".into(),
                "--screen".into(),
                "strong".into(),
            ],
            &[],
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.cv_folds, 3);
        assert_eq!(cfg.screen_rule, ScreenRule::Strong);
        let cvo = cfg.cv_options();
        assert_eq!(cvo.folds, 3);
        assert_eq!(cvo.fold_threads, 2);
        assert!(cvo.refit);
        assert_eq!(cfg.path_options(true).screen, ScreenRule::Strong);
        // A bad rule fails loudly.
        std::fs::write(&tmp, r#"{"screen_rule": "sorta"}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn checkpoint_and_recluster_keys_layer_like_the_rest() {
        let tmp = std::env::temp_dir().join("cggm_cfg_ckpt.json");
        std::fs::write(
            &tmp,
            r#"{"checkpoint": "sweep.jsonl", "recluster_churn": 0.5}"#,
        )
        .unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.checkpoint.as_deref(), Some("sweep.jsonl"));
        assert_eq!(cfg.recluster_churn, 0.5);
        let args = Args::parse(
            &[
                "--checkpoint".into(),
                "other.jsonl".into(),
                "--recluster-churn".into(),
                "-1".into(),
            ],
            &[],
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.checkpoint.as_deref(), Some("other.jsonl"));
        assert_eq!(cfg.recluster_churn, -1.0);
        let popts = cfg.path_options(true);
        assert_eq!(
            popts.checkpoint.as_deref(),
            Some(std::path::Path::new("other.jsonl"))
        );
        assert!(!popts.resume, "resume is a CLI-level decision");
        assert_eq!(cfg.solve_options().recluster_churn, -1.0);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn cd_threads_and_one_se_keys_layer_like_the_rest() {
        let tmp = std::env::temp_dir().join("cggm_cfg_cdthreads.json");
        std::fs::write(&tmp, r#"{"cd_threads": 4, "cv_one_se": true}"#).unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.cd_threads, 4);
        assert!(cfg.cv_one_se);
        assert_eq!(cfg.solve_options().cd_threads, 4);
        assert!(cfg.cv_options().one_se);
        let args = Args::parse(&["--cd-threads".into(), "2".into()], &["one-se"]);
        cfg.apply_args(&args);
        assert_eq!(cfg.cd_threads, 2);
        assert!(cfg.cv_one_se, "flags only set, never unset");
        // Defaults: serial CD, argmin selection.
        let d = RunConfig::default();
        assert_eq!(d.cd_threads, 1);
        assert!(!d.solve_options().colored_cd());
        assert!(!d.cv_options().one_se);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn serve_keys_layer_like_the_rest() {
        let tmp = std::env::temp_dir().join("cggm_cfg_serve.json");
        std::fs::write(
            &tmp,
            r#"{"serve_max_jobs": 4, "serve_budget": "64MB",
                "serve_socket": "/tmp/cggm.sock"}"#,
        )
        .unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.serve_max_jobs, 4);
        assert_eq!(cfg.serve_budget, Some(64 << 20));
        assert_eq!(cfg.serve_socket.as_deref(), Some("/tmp/cggm.sock"));
        let args = Args::parse(
            &[
                "--max-jobs".into(),
                "1".into(),
                "--serve-budget".into(),
                "32MB".into(),
                "--socket".into(),
                "/tmp/other.sock".into(),
            ],
            &[],
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.serve_max_jobs, 1);
        assert_eq!(cfg.serve_budget, Some(32 << 20));
        assert_eq!(cfg.serve_socket.as_deref(), Some("/tmp/other.sock"));
        // Defaults: 2 workers, unlimited budget, stdio transport.
        let d = RunConfig::default();
        assert_eq!(d.serve_max_jobs, 2);
        assert_eq!(d.serve_budget, None);
        assert_eq!(d.serve_socket, None);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn storage_keys_layer_like_the_rest() {
        let tmp = std::env::temp_dir().join("cggm_cfg_storage.json");
        std::fs::write(
            &tmp,
            r#"{"storage": "disk", "panel_rows": 32, "panel_cache": "8MB"}"#,
        )
        .unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.storage, "disk");
        assert_eq!(cfg.panel_rows, 32);
        assert_eq!(cfg.panel_cache, 8 << 20);
        let args = Args::parse(
            &[
                "--storage".into(),
                "mem".into(),
                "--panel-rows".into(),
                "16".into(),
                "--panel-cache".into(),
                "4MB".into(),
            ],
            &[],
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.storage, "mem");
        assert_eq!(cfg.panel_rows, 16);
        assert_eq!(cfg.panel_cache, 4 << 20);
        // Defaults: resident datasets, library panel geometry.
        let d = RunConfig::default();
        assert_eq!(d.storage, "mem");
        assert_eq!(d.panel_rows, crate::storage::DEFAULT_PANEL_ROWS);
        assert_eq!(d.panel_cache, crate::storage::DEFAULT_PANEL_CACHE);
        // Bad values fail loudly.
        std::fs::write(&tmp, r#"{"storage": "tape"}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        std::fs::write(&tmp, r#"{"panel_rows": 0}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        std::fs::write(&tmp, r#"{"panel_cache": "lots"}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn cv_checkpoint_key_flows_into_cv_options() {
        let tmp = std::env::temp_dir().join("cggm_cfg_cvckpt.json");
        std::fs::write(&tmp, r#"{"checkpoint": "cv.jsonl", "cv_folds": 4}"#).unwrap();
        let cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        let cvo = cfg.cv_options();
        assert_eq!(
            cvo.checkpoint.as_deref(),
            Some(std::path::Path::new("cv.jsonl"))
        );
        assert!(!cvo.resume, "resume is a CLI-level decision");
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn stat_and_gemm_keys_layer_like_the_rest() {
        let tmp = std::env::temp_dir().join("cggm_cfg_stat.json");
        std::fs::write(
            &tmp,
            r#"{"stat_mode": "tiled", "stat_tile": 64, "stat_rebuild_every": 8,
                "gemm_blocks": "128,128,512", "gemm_autotune": true}"#,
        )
        .unwrap();
        let mut cfg = RunConfig::from_file(tmp.to_str().unwrap()).unwrap();
        assert_eq!(cfg.stat_mode, "tiled");
        assert_eq!(cfg.stat_tile, 64);
        assert_eq!(cfg.stat_rebuild_every, 8);
        assert_eq!(cfg.solve_options().stat_rebuild_every, 8);
        assert_eq!(cfg.gemm_blocks, Some((128, 128, 512)));
        assert!(cfg.gemm_autotune);
        assert_eq!(cfg.solve_options().stat_mode, StatMode::Tiled(64));
        let args = Args::parse(
            &[
                "--stat-mode".into(),
                "dense".into(),
                "--stat-rebuild-every".into(),
                "0".into(),
                "--gemm-blocks".into(),
                "96,192,384".into(),
            ],
            &["gemm-autotune"],
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.solve_options().stat_mode, StatMode::Dense);
        assert_eq!(cfg.solve_options().stat_rebuild_every, 0, "0 disables");
        assert_eq!(cfg.gemm_blocks, Some((96, 192, 384)));
        // Defaults: eager dense stats, compiled-in GEMM blocks, rebuild
        // guard at 64 downdates.
        let d = RunConfig::default();
        assert_eq!(d.solve_options().stat_mode, StatMode::Dense);
        assert_eq!(d.gemm_blocks, None);
        assert!(!d.gemm_autotune);
        assert_eq!(d.solve_options().stat_rebuild_every, 64);
        // Bad values fail loudly.
        std::fs::write(&tmp, r#"{"stat_mode": "sideways"}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        std::fs::write(&tmp, r#"{"stat_tile": 0}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        std::fs::write(&tmp, r#"{"stat_rebuild_every": -1}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        std::fs::write(&tmp, r#"{"gemm_blocks": "64,256"}"#).unwrap();
        assert!(RunConfig::from_file(tmp.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(tmp);
    }

    /// Regression: on the seed, `as_usize` was a saturating cast, so
    /// `{"p":-1}` configured a 0-dimensional run and `{"p":1e300}` a
    /// `usize::MAX`-dimensional one. Both must be `BadValue`.
    #[test]
    fn hostile_integer_values_are_bad_values_not_saturated() {
        let mut cfg = RunConfig::default();
        for (key, val) in [
            ("p", Json::num(-1.0)),
            ("p", Json::num(1e300)),
            ("q", Json::num(2.5)),
            ("n", Json::num(f64::NAN)),
            ("seed", Json::num(-3.0)),
            ("max_iter", Json::num(f64::INFINITY)),
            ("cv_folds", Json::num(9_007_199_254_740_992.0)), // 2^53
        ] {
            let err = cfg.apply(key, &val).unwrap_err();
            assert!(
                matches!(&err, ConfigError::BadValue { key: k, .. } if k == key),
                "{key}: {err}"
            );
        }
        // Nothing was mutated by the rejected applications.
        assert_eq!(cfg.p, RunConfig::default().p);
        assert_eq!(cfg.seed, RunConfig::default().seed);
        // In-range values still land.
        cfg.apply("p", &Json::num(7.0)).unwrap();
        assert_eq!(cfg.p, 7);
    }

    #[test]
    fn unknown_key_rejected() {
        let tmp = std::env::temp_dir().join("cggm_cfg_bad.json");
        std::fs::write(&tmp, r#"{"workloda": "chain"}"#).unwrap();
        assert!(matches!(
            RunConfig::from_file(tmp.to_str().unwrap()),
            Err(ConfigError::UnknownKey(_))
        ));
        let _ = std::fs::remove_file(tmp);
    }
}
