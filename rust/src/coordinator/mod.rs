//! Run coordination: configuration, λ calibration, dataset IO, the fit
//! driver shared by the CLI and the experiment harness, the warm-started
//! λ-path driver ([`fit_path`]) with sequential strong-rule screening
//! ([`solve_screened`]) and JSONL checkpoint/resume ([`checkpoint`]), and
//! K-fold cross-validated model selection ([`cv::cross_validate`]).

pub mod checkpoint;
pub mod config;
pub mod cv;

use crate::cggm::active::{kkt_violations, ScreenRule, ScreenSet};
use crate::cggm::{CggmModel, Dataset};
use crate::datagen::{self, Problem, Workload};
use crate::gemm::GemmEngine;
use crate::linalg::dense::Mat;
use crate::metrics::f1_edges_sym;
use crate::solvers::{
    solve, solve_in_context, SolveError, SolveOptions, SolveResult, SolverContext, SolverKind,
};
use crate::util::json::Json;
use crate::util::timer::Stopwatch;
use std::path::{Path, PathBuf};
use std::sync::Arc;

pub use config::RunConfig;
pub use cv::{cross_validate, cross_validate_with, CvOptions, CvPoint, CvResult};

/// One timed solver run with derived summary numbers (a row of Table 1).
pub struct RunSummary {
    pub solver: SolverKind,
    pub seconds: f64,
    pub iters: usize,
    pub converged: bool,
    pub f: f64,
    pub lambda_nnz: usize,
    pub theta_nnz: usize,
    pub f1_lambda: Option<f64>,
    pub peak_bytes: usize,
}

impl RunSummary {
    pub fn from_result(
        kind: SolverKind,
        res: &SolveResult,
        truth: Option<&crate::cggm::CggmModel>,
        peak_bytes: usize,
    ) -> RunSummary {
        RunSummary {
            solver: kind,
            seconds: res.trace.total_seconds,
            iters: res.trace.records.len(),
            converged: res.trace.converged,
            f: res.trace.final_f().unwrap_or(f64::NAN),
            lambda_nnz: res.model.lambda_nnz(),
            theta_nnz: res.model.theta_nnz(),
            f1_lambda: truth.map(|t| f1_edges_sym(&res.model.lambda, &t.lambda).f1),
            peak_bytes,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("solver", Json::str(self.solver.name())),
            ("seconds", Json::num(self.seconds)),
            ("iters", Json::num(self.iters as f64)),
            ("converged", Json::Bool(self.converged)),
            ("f", Json::num(self.f)),
            ("lambda_nnz", Json::num(self.lambda_nnz as f64)),
            ("theta_nnz", Json::num(self.theta_nnz as f64)),
            (
                "f1_lambda",
                self.f1_lambda.map(Json::num).unwrap_or(Json::Null),
            ),
            ("peak_bytes", Json::num(self.peak_bytes as f64)),
        ])
    }
}

/// Fit with a solver and summarize (trace CSV optionally written).
pub fn run_fit(
    kind: SolverKind,
    prob: &Problem,
    opts: &SolveOptions,
    engine: &dyn GemmEngine,
    trace_out: Option<&Path>,
) -> Result<(RunSummary, SolveResult), SolveError> {
    let res = solve(kind, &prob.data, opts, engine)?;
    if let Some(path) = trace_out {
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let _ = std::fs::write(path, res.trace.to_csv());
    }
    let summary = RunSummary::from_result(kind, &res, Some(&prob.truth), opts.budget.peak());
    Ok((summary, res))
}

// ---------------------------------------------------------------- λ paths

/// Configuration of a regularization path sweep.
#[derive(Clone, Debug)]
pub struct PathOptions {
    /// Number of grid points when the grid is auto-generated.
    pub points: usize,
    /// λ_min = `min_ratio` · λ_max for the auto-generated geometric grid.
    pub min_ratio: f64,
    /// Explicit (λ_Λ, λ_Θ) grid; should be decreasing for warm starts to
    /// help. `None` auto-generates from the data's λ_max.
    pub lambdas: Option<Vec<(f64, f64)>>,
    /// Seed each solve with the previous point's solution (the path driver's
    /// reason to exist); `false` is the cold-start ablation the `bench_path`
    /// bench measures against.
    pub warm_start: bool,
    /// Path-level screening: [`ScreenRule::Strong`] (default) carries the
    /// previous point's active set forward through the sequential strong
    /// rule with a KKT post-check; [`ScreenRule::Full`] re-screens every
    /// coordinate at every point. Strong screening requires warm starts
    /// (the rule is stated at the previous solution), so it is inert when
    /// `warm_start` is false, for the first path point, and for solvers
    /// without [`SolverKind::supports_screen`] (notably the block solver,
    /// whose memory story forbids the driver's dense gradient scans).
    pub screen: ScreenRule,
    /// Stream every fitted point (+ model) to this JSONL checkpoint file so
    /// the sweep survives interruption (see [`checkpoint`]). `None` disables
    /// checkpointing.
    pub checkpoint: Option<PathBuf>,
    /// Resume from `checkpoint` when it holds a valid prefix: the header's
    /// grid governs (any configured grid is ignored), already-fitted points
    /// are carried over verbatim, and the sweep warm-restarts from the last
    /// valid point's model — including re-seeding the strong rule's
    /// gradients there. A missing or header-corrupt file starts fresh; a
    /// torn trailing line is truncated and its point refitted; a valid
    /// checkpoint whose solver or problem shape differs from the current
    /// run is an error (never silently overwritten or adopted).
    pub resume: bool,
}

impl Default for PathOptions {
    fn default() -> Self {
        PathOptions {
            points: 10,
            min_ratio: 0.1,
            lambdas: None,
            warm_start: true,
            screen: ScreenRule::Strong,
            checkpoint: None,
            resume: false,
        }
    }
}

/// One fitted point of a λ path.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub lam_l: f64,
    pub lam_t: f64,
    pub iters: usize,
    pub converged: bool,
    pub f: f64,
    pub lambda_nnz: usize,
    pub theta_nnz: usize,
    pub seconds: f64,
    /// Solver-side coordinates examined at this point: screening scans + CD
    /// update visits from the solve trace(s), including any discarded
    /// restricted work on fallback. The screening bench's work metric.
    pub coord_updates: usize,
    /// Driver-side verification scans: the once-per-point gradient
    /// evaluation that feeds the KKT post-check and the next point's strong
    /// rule (reported separately — it replaces the full run's *per-iteration*
    /// gradient screens, and hiding it inside `coord_updates` would blur
    /// what screening actually saves).
    pub kkt_scans: usize,
    /// Whether this point ran under a strong-rule restricted screen.
    pub screened: bool,
    /// Whether the KKT post-check forced a full-screen re-solve here.
    pub fallback: bool,
    /// Graph-clustering partition rebuilds during this point's solve(s)
    /// (block solver only; the context persists the partition across
    /// points, so a warm path point is typically 0).
    pub reclusterings: usize,
}

/// A completed λ-path run.
pub struct PathResult {
    pub solver: SolverKind,
    pub points: Vec<PathPoint>,
    /// Model at the last fitted (smallest-λ) point.
    pub model: Option<CggmModel>,
    pub total_seconds: f64,
    /// How many points needed the KKT fallback (screening quality metric —
    /// near zero on a well-spaced decreasing grid).
    pub screen_fallbacks: usize,
    /// Points carried over from a resumed checkpoint (0 for a fresh sweep).
    pub resumed_points: usize,
}

impl PathResult {
    /// Total outer iterations across the path (the warm-start savings
    /// metric).
    pub fn total_iters(&self) -> usize {
        self.points.iter().map(|p| p.iters).sum()
    }

    /// Total solver-side coordinates examined across the path (screening
    /// scans + CD visits) — the quantity strong-rule screening shrinks.
    pub fn total_coord_updates(&self) -> usize {
        self.points.iter().map(|p| p.coord_updates).sum()
    }

    /// Total driver-side KKT/strong-rule verification scans (zero on an
    /// unscreened path).
    pub fn total_kkt_scans(&self) -> usize {
        self.points.iter().map(|p| p.kkt_scans).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("solver", Json::str(self.solver.name())),
            ("total_seconds", Json::num(self.total_seconds)),
            ("total_iters", Json::num(self.total_iters() as f64)),
            (
                "total_coord_updates",
                Json::num(self.total_coord_updates() as f64),
            ),
            ("total_kkt_scans", Json::num(self.total_kkt_scans() as f64)),
            ("screen_fallbacks", Json::num(self.screen_fallbacks as f64)),
            ("resumed_points", Json::num(self.resumed_points as f64)),
            (
                "points",
                Json::arr(self.points.iter().map(|p| {
                    Json::obj(vec![
                        ("lambda_l", Json::num(p.lam_l)),
                        ("lambda_t", Json::num(p.lam_t)),
                        ("iters", Json::num(p.iters as f64)),
                        ("converged", Json::Bool(p.converged)),
                        ("f", Json::num(p.f)),
                        ("lambda_nnz", Json::num(p.lambda_nnz as f64)),
                        ("theta_nnz", Json::num(p.theta_nnz as f64)),
                        ("seconds", Json::num(p.seconds)),
                        ("coord_updates", Json::num(p.coord_updates as f64)),
                        ("kkt_scans", Json::num(p.kkt_scans as f64)),
                        ("screened", Json::Bool(p.screened)),
                        ("fallback", Json::Bool(p.fallback)),
                        ("reclusterings", Json::num(p.reclusterings as f64)),
                    ])
                })),
            ),
        ])
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "lambda_l,lambda_t,iters,converged,f,lambda_nnz,theta_nnz,seconds,\
             coord_updates,kkt_scans,screened,fallback,reclusterings\n",
        );
        for p in &self.points {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{:.4},{},{},{},{},{}\n",
                p.lam_l,
                p.lam_t,
                p.iters,
                p.converged,
                p.f,
                p.lambda_nnz,
                p.theta_nnz,
                p.seconds,
                p.coord_updates,
                p.kkt_scans,
                p.screened,
                p.fallback,
                p.reclusterings
            ));
        }
        s
    }
}

/// λ_max per parameter: the largest gradient magnitude at the cold-start
/// iterate (Λ = I, Θ = 0), above which nothing enters the active set. Exact
/// from the context's cached statistics for the dense-stat solvers; for the
/// block solver (which must not materialize q×q / p×q matrices) it is
/// computed exactly but *streamed* in budget-tracked column panels — the
/// same GEMM pattern as its Λ/Θ screens.
pub(crate) fn lambda_max(ctx: &SolverContext, kind: SolverKind) -> Result<(f64, f64), SolveError> {
    let data = ctx.data();
    if kind == SolverKind::AltNewtonBcd {
        // The block solver's own streamed panels — exact, O(panel) memory.
        return crate::solvers::alt_newton_bcd::streamed_lambda_max(
            data,
            ctx.engine(),
            ctx.workspace(),
        );
    }
    let (p, q) = (data.p(), data.q());
    let syy = ctx.syy()?;
    let sxy = ctx.sxy()?;
    let mut ml = 1e-12f64;
    for i in 0..q {
        for j in 0..i {
            ml = ml.max(syy[(i, j)].abs());
        }
    }
    let mut mt = 1e-12f64;
    debug_assert_eq!(sxy.data().len(), p * q);
    for v in sxy.data() {
        mt = mt.max(2.0 * v.abs());
    }
    Ok((ml, mt))
}

/// Geometric grid from λ_max down to `min_ratio`·λ_max, per parameter.
pub(crate) fn geometric_grid(
    max_l: f64,
    max_t: f64,
    points: usize,
    min_ratio: f64,
) -> Vec<(f64, f64)> {
    let ratio = min_ratio.clamp(1e-6, 1.0);
    (0..points)
        .map(|k| {
            let t = if points <= 1 {
                0.0 // a single point sits at λ_max
            } else {
                k as f64 / (points - 1) as f64
            };
            (max_l * ratio.powf(t), max_t * ratio.powf(t))
        })
        .collect()
}

/// Outcome of [`solve_screened`]: the solve plus the bookkeeping the path
/// driver needs to chain strong rules across points.
pub struct ScreenedSolve {
    pub res: SolveResult,
    /// Smooth gradients `(∇_Λ g, ∇_Θ g)` at the returned solution — the
    /// KKT evidence, reused by the caller as the next point's strong-rule
    /// input so gradients are evaluated once per path point.
    pub grads: (Mat, Mat),
    /// Whether the KKT post-check found a dropped violating coordinate and
    /// forced an unrestricted re-solve.
    pub fell_back: bool,
    /// Discarded restricted-solve work when the fallback fired (solver-side;
    /// charged to the point's `coord_updates`).
    pub wasted_coords: usize,
    /// Driver-side KKT/strong-rule verification scans (one full coordinate
    /// scan per gradient evaluation; two on fallback).
    pub kkt_scans: usize,
}

/// One λ point under the sequential strong rule: solve restricted to `set`,
/// then KKT-check every *discarded* coordinate at the solution. A violation
/// means the strong rule's heuristic bet lost, so the point is re-solved
/// with a full screen, warm-started from the restricted solution (cheap —
/// that solution is already nearly optimal over its set). The returned
/// solution therefore always satisfies the same optimality conditions as an
/// unrestricted solve: **screening can never silently drop a violating
/// coordinate**.
pub fn solve_screened(
    kind: SolverKind,
    ctx: &SolverContext,
    opts: &SolveOptions,
    warm: Option<&CggmModel>,
    set: Arc<ScreenSet>,
) -> Result<ScreenedSolve, SolveError> {
    let sw = Stopwatch::start();
    let data = ctx.data();
    let (p, q) = (data.p(), data.q());
    let full_scan = q * (q + 1) / 2 + p * q;
    // A caller-provided set might miss part of the starting model's support
    // (the warm start's, or cold-start init's Λ = I diagonal) — those
    // coordinates would be frozen at stale values and invisible to the KKT
    // check (which only examines zeros). Merge the support in; the driver's
    // strong sets already contain it, so this is a no-op there.
    let cold_init;
    let start = match warm {
        Some(w) => w,
        None => {
            cold_init = CggmModel::init(p, q);
            &cold_init
        }
    };
    let set = match set.with_support(start) {
        Some(merged) => Arc::new(merged),
        None => set,
    };
    let mut sopts = opts.clone();
    sopts.screen = Some(set.clone());
    let res = solve_in_context(kind, ctx, &sopts, warm)?;
    let grads = ctx.smooth_gradients(&res.model, opts.chol)?;
    // Violations below λ·(1+tol) are converged noise (an unrestricted solve
    // would leave them too); anything larger forces the fallback. This
    // per-coordinate threshold is deliberately *stricter* than the solver's
    // aggregate tol·‖·‖₁ stopping rule, so a coordinate a loose full solve
    // would legitimately leave slightly above λ can occasionally trip a
    // conservative (wasted but safe) re-solve — the safe side of the trade.
    let viol = kkt_violations(
        &grads.0,
        &grads.1,
        &res.model,
        opts.lam_l,
        opts.lam_t,
        &set,
        opts.tol,
    );
    if viol == 0 {
        return Ok(ScreenedSolve {
            res,
            grads,
            fell_back: false,
            wasted_coords: 0,
            kkt_scans: full_scan,
        });
    }
    // The restricted solve's work is charged to this point even though its
    // result is discarded.
    let wasted = res.trace.coords_screened + res.trace.cd_updates;
    let mut fopts = opts.clone();
    fopts.screen = None;
    // The fallback runs on whatever is left of this point's time budget —
    // reusing the original limit would let a fallback point spend it twice
    // and overrun the whole-path cap. An exhausted budget still gets a
    // hair of time so the solver returns the (valid) warm iterate instead
    // of an error.
    if opts.time_limit > 0.0 {
        fopts.time_limit = (opts.time_limit - sw.seconds()).max(1e-3);
    }
    let res = solve_in_context(kind, ctx, &fopts, Some(&res.model))?;
    let grads = ctx.smooth_gradients(&res.model, opts.chol)?;
    Ok(ScreenedSolve {
        res,
        grads,
        fell_back: true,
        wasted_coords: wasted,
        kkt_scans: 2 * full_scan,
    })
}

/// Fit a warm-started regularization path: decreasing λ grid (auto-generated
/// from the data's λ_max unless `popts.lambdas` pins it), each solve seeded
/// with the previous solution, covariance statistics computed once for the
/// whole path (the shared [`SolverContext`]), and — under the default
/// [`ScreenRule::Strong`] — the active set carried across points by the
/// sequential strong rule with a KKT-checked fallback.
pub fn fit_path(
    kind: SolverKind,
    data: &Dataset,
    base: &SolveOptions,
    popts: &PathOptions,
    engine: &dyn GemmEngine,
) -> Result<PathResult, SolveError> {
    let ctx = SolverContext::new(data, base, engine);
    fit_path_in_context(kind, &ctx, base, popts)
}

/// [`fit_path`] on a caller-provided context (reusable across paths; tests
/// assert the statistics are computed exactly once). `base.time_limit` is a
/// budget for the *whole path*: each point receives the remaining time, and
/// the sweep stops early once it is spent. `base.lam_l`/`lam_t` are ignored
/// — the grid governs.
pub fn fit_path_in_context(
    kind: SolverKind,
    ctx: &SolverContext,
    base: &SolveOptions,
    popts: &PathOptions,
) -> Result<PathResult, SolveError> {
    fit_path_with(kind, ctx, base, popts, |_, _, _| {})
}

/// [`fit_path_in_context`] with a per-point observer: `on_point(k, point,
/// model)` fires after each grid point `k` is fitted, with the point summary
/// and the model *at that point*. This is how [`cv::cross_validate`] scores
/// held-out likelihood along the path without the driver retaining every
/// (possibly large) intermediate model.
pub fn fit_path_with(
    kind: SolverKind,
    ctx: &SolverContext,
    base: &SolveOptions,
    popts: &PathOptions,
    mut on_point: impl FnMut(usize, &PathPoint, &CggmModel),
) -> Result<PathResult, SolveError> {
    let sw = Stopwatch::start();
    let data = ctx.data();
    let (p, q) = (data.p(), data.q());
    // Resume: adopt the checkpoint's valid prefix. Its header grid governs
    // (the interrupted sweep's grid must be continued exactly); a missing or
    // header-corrupt file falls through to a fresh start.
    let mut resumed: Option<checkpoint::CheckpointState> = None;
    if popts.resume {
        if let Some(ck) = &popts.checkpoint {
            if let Ok(state) = checkpoint::load(ck) {
                // A valid checkpoint from a *different* run must not be
                // silently overwritten or adopted: the header pins solver
                // and problem shape, and resuming across either is an error
                // (the model would be dimensionally wrong, or the result
                // would mix two solvers' points under one label).
                if state.solver != kind.name() || state.p != p || state.q != q {
                    return Err(SolveError::Checkpoint(format!(
                        "{} was written by {} for a {}×{} problem; this run \
                         is {} on {}×{} — refusing to resume",
                        ck.display(),
                        state.solver,
                        state.p,
                        state.q,
                        kind.name(),
                        p,
                        q
                    )));
                }
                resumed = Some(state);
            }
        }
    }
    let grid: Vec<(f64, f64)> = match (&resumed, &popts.lambdas) {
        (Some(state), _) => state.grid.clone(),
        (None, Some(g)) => g.clone(),
        (None, None) => {
            let (ml, mt) = lambda_max(ctx, kind)?;
            geometric_grid(ml, mt, popts.points.max(1), popts.min_ratio)
        }
    };
    let full_scan = q * (q + 1) / 2 + p * q;
    let screen_on =
        popts.warm_start && popts.screen == ScreenRule::Strong && kind.supports_screen();
    let mut warm: Option<CggmModel> = None;
    // Gradients at `warm` and the λ it was fitted at — the strong rule's
    // sequential state, refreshed once per point.
    let mut prev_grads: Option<(Mat, Mat)> = None;
    let mut prev_lams = (f64::NAN, f64::NAN);
    let mut fallbacks = 0usize;
    let mut points = Vec::with_capacity(grid.len());
    let mut start_k = 0usize;
    let mut writer: Option<checkpoint::CheckpointWriter> = None;
    if let Some(state) = resumed {
        start_k = state.points.len().min(grid.len());
        points = state.points;
        // The summary counters must cover the carried-over points too, so a
        // resumed sweep reports the same screen_fallbacks as an
        // uninterrupted one.
        fallbacks = points.iter().filter(|pt| pt.fallback).count();
        warm = state.model;
        prev_lams = if start_k > 0 {
            grid[start_k - 1]
        } else {
            (f64::NAN, f64::NAN)
        };
        // Re-seed the strong rule where the interrupted run left off: the
        // checkpointed model round-trips f64s exactly, so these gradients
        // equal the ones the uninterrupted sweep would have carried.
        if screen_on && start_k > 0 && start_k < grid.len() {
            if let Some(m) = &warm {
                prev_grads = Some(ctx.smooth_gradients(m, base.chol)?);
            }
        }
        let ck = popts.checkpoint.as_ref().expect("resume implies checkpoint");
        writer = Some(
            checkpoint::CheckpointWriter::append_after(ck, state.valid_bytes)
                .map_err(|e| SolveError::Checkpoint(e.to_string()))?,
        );
    } else if let Some(ck) = &popts.checkpoint {
        writer = Some(
            checkpoint::CheckpointWriter::create(ck, kind.name(), p, q, &grid)
                .map_err(|e| SolveError::Checkpoint(e.to_string()))?,
        );
    }
    let resumed_points = start_k;
    for (k, &(lam_l, lam_t)) in grid.iter().enumerate().skip(start_k) {
        // Per-λ-point cancellation grain (the solvers also poll per outer
        // iteration); completed points are already checkpointed, the
        // in-flight one is discarded.
        if base.cancel.is_cancelled() {
            return Err(SolveError::Cancelled);
        }
        let mut opts = base.clone();
        opts.lam_l = lam_l;
        opts.lam_t = lam_t;
        if base.time_limit > 0.0 {
            let remaining = base.time_limit - sw.seconds();
            if remaining <= 0.0 {
                break;
            }
            opts.time_limit = remaining;
        }
        let t0 = sw.seconds();
        let seed = if popts.warm_start { warm.as_ref() } else { None };
        let mut wasted_coords = 0usize;
        let mut kkt_scans = 0usize;
        let mut screened = false;
        let mut fallback = false;
        let res = match (seed, prev_grads.take()) {
            (Some(seed_model), Some((gl, gt))) if screen_on => {
                let set = Arc::new(ScreenSet::strong(
                    &gl, &gt, seed_model, lam_l, lam_t, prev_lams.0, prev_lams.1,
                ));
                screened = true;
                let out = solve_screened(kind, ctx, &opts, Some(seed_model), set)?;
                fallback = out.fell_back;
                if fallback {
                    fallbacks += 1;
                }
                wasted_coords = out.wasted_coords;
                kkt_scans = out.kkt_scans;
                prev_grads = Some(out.grads);
                out.res
            }
            (seed, _) => {
                let res = solve_in_context(kind, ctx, &opts, seed)?;
                if screen_on {
                    // Seed the strong rule for the next point.
                    prev_grads = Some(ctx.smooth_gradients(&res.model, opts.chol)?);
                    kkt_scans = full_scan;
                }
                res
            }
        };
        prev_lams = (lam_l, lam_t);
        let point = PathPoint {
            lam_l,
            lam_t,
            iters: res.trace.records.len(),
            converged: res.trace.converged,
            f: res.trace.final_f().unwrap_or(f64::NAN),
            lambda_nnz: res.model.lambda_nnz(),
            theta_nnz: res.model.theta_nnz(),
            seconds: sw.seconds() - t0,
            coord_updates: res.trace.coords_screened + res.trace.cd_updates + wasted_coords,
            kkt_scans,
            screened,
            fallback,
            reclusterings: res.trace.reclusterings,
        };
        // A failed record write must not lose the fitted point — warn and
        // keep sweeping (the checkpoint simply ends earlier).
        let write_err = writer
            .as_mut()
            .and_then(|w| w.record(k, &point, &res.model).err());
        if let Some(e) = write_err {
            eprintln!("warning: checkpoint write failed at point {k}: {e}");
            writer = None;
        }
        on_point(k, &point, &res.model);
        points.push(point);
        warm = Some(res.model);
    }
    Ok(PathResult {
        solver: kind,
        points,
        model: warm,
        total_seconds: sw.seconds(),
        screen_fallbacks: fallbacks,
        resumed_points,
    })
}

/// Calibrate λ so the estimated support sizes land near the ground truth
/// (paper §5.1: "We choose λ_Λ and λ_Θ so that the number of estimated edges
/// in Λ and Θ is close to ground truth"). *Independent* geometric bisection
/// per parameter (each probe updates both brackets from its own density
/// ratio). Every probe is a deliberately truncated `AltNewtonCd` run — 6
/// outer iterations, regardless of the configured solver, because the probe
/// only needs a support-size estimate, not an optimum — on one shared
/// [`SolverContext`], bracketed by a sampled estimate of the data's λ_max.
/// The returned pair are the last probed midpoints, accurate to the final
/// bracket ratio — close to, not exactly at, the target support.
pub fn calibrate_lambda(
    prob: &Problem,
    engine: &dyn GemmEngine,
    base: &SolveOptions,
    steps: usize,
) -> (f64, f64) {
    let target_l = prob.truth.lambda_nnz() as f64;
    let target_t = prob.truth.theta_nnz().max(1) as f64;
    // Data-driven bracket: above λ_max = max |∇g| at the initial iterate
    // nothing enters the active set, so probing far below it creates huge
    // dense subproblems. Estimate λ_max from sampled gradient entries
    // (∇_Λ ≈ S_yy off-diagonal, ∇_Θ = 2S_xy at (I, 0)).
    let (p, q) = (prob.p(), prob.q());
    let mut rng = crate::util::rng::Rng::new(0x0ca1);
    let mut gmax = 1e-6f64;
    for _ in 0..4000 {
        let (i, j) = (rng.below(q), rng.below(q));
        if i != j {
            gmax = gmax.max(prob.data.syy(i, j).abs());
        }
        gmax = gmax.max(2.0 * prob.data.sxy(rng.below(p), rng.below(q)).abs());
    }
    // One context for every probe: the bisection re-solves the same dataset
    // `steps` times, so the covariance statistics are computed once here
    // instead of once per probe.
    let ctx = SolverContext::new(&prob.data, base, engine);
    let probe = |lam_l: f64, lam_t: f64| -> (f64, f64) {
        let opts = SolveOptions {
            lam_l,
            lam_t,
            max_iter: 6,
            trace_f: false,
            time_limit: 120.0,
            ..base.clone()
        };
        match solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None) {
            Ok(res) => (
                res.model.lambda_nnz() as f64,
                res.model.theta_nnz() as f64,
            ),
            Err(_) => (f64::INFINITY, f64::INFINITY),
        }
    };
    // Independent geometric bisection per parameter: each probe updates both
    // brackets using its own density ratio.
    let (mut lo_l, mut hi_l) = (0.02 * gmax, 1.2 * gmax);
    let (mut lo_t, mut hi_t) = (0.02 * gmax, 1.2 * gmax);
    let (mut best_l, mut best_t) = (0.5, 0.5);
    for _ in 0..steps {
        best_l = (lo_l * hi_l).sqrt();
        best_t = (lo_t * hi_t).sqrt();
        let (nl, nt) = probe(best_l, best_t);
        if nl > target_l {
            lo_l = best_l; // too dense → raise λ_Λ
        } else {
            hi_l = best_l;
        }
        if nt > target_t {
            lo_t = best_t;
        } else {
            hi_t = best_t;
        }
    }
    (best_l, best_t)
}

/// Generate a workload (CLI `gen` + experiments).
pub fn generate_problem(
    workload: Workload,
    p: usize,
    q: usize,
    n: usize,
    seed: u64,
) -> Problem {
    datagen::generate(workload, p, q, n, seed)
}

/// Save a dataset in a simple binary format (header + row-major f64).
/// Streams feature rows, so a disk-backed dataset can be re-exported without
/// ever being fully resident.
pub fn save_dataset(data: &Dataset, path: &Path) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(b"CGGMDS01")?;
    for dim in [data.p() as u64, data.q() as u64, data.n() as u64] {
        f.write_all(&dim.to_le_bytes())?;
    }
    for i in 0..data.p() {
        data.with_x_row(i, |row| -> std::io::Result<()> {
            for v in row {
                f.write_all(&v.to_le_bytes())?;
            }
            Ok(())
        })?;
    }
    for j in 0..data.q() {
        data.with_y_row(j, |row| -> std::io::Result<()> {
            for v in row {
                f.write_all(&v.to_le_bytes())?;
            }
            Ok(())
        })?;
    }
    Ok(())
}

/// Save a dataset in the sharded column-major panel format
/// ([`crate::storage`], magic `CGGMPAN1`) — the on-disk layout
/// [`Dataset::open_disk`] serves out-of-core. Samples are written in
/// `shard_cols`-column shards; the source may itself be disk-backed (columns
/// stream through its panel cache), so format conversion is O(shard) memory.
pub fn save_dataset_sharded(
    data: &Dataset,
    path: &Path,
    shard_cols: usize,
) -> std::io::Result<()> {
    let sc = shard_cols.max(1);
    let mut w = crate::storage::PanelWriter::create(path, data.p(), data.q())?;
    let mut s = 0usize;
    while s < data.n() {
        let e = (s + sc).min(data.n());
        let idx: Vec<usize> = (s..e).collect();
        let block = data.select_samples(&idx);
        w.append_block(&block.xt, &block.yt)?;
        s = e;
    }
    w.finish()
}

/// Read only the (p, q, n) header of a saved dataset — the serve engine's
/// admission control sizes jobs from the shape without paying for the full
/// read. Understands both the dense `CGGMDS01` format and the sharded
/// `CGGMPAN1` panel format (whose headers are checksum-validated, so a
/// corrupt shard directory is rejected here rather than at first panel read).
pub fn peek_dataset_dims(path: &Path) -> std::io::Result<(usize, usize, usize)> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let mut header = [0u8; 8 + 24];
    f.read_exact(&mut header)?;
    if header[..8] == crate::storage::GLOBAL_MAGIC {
        use std::io::Seek;
        f.seek(std::io::SeekFrom::Start(0))?;
        let meta = crate::storage::read_meta(&mut f).map_err(std::io::Error::from)?;
        return Ok((meta.p, meta.q, meta.n));
    }
    if &header[..8] != b"CGGMDS01" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic",
        ));
    }
    let dim = |k: usize| {
        u64::from_le_bytes(header[8 + 8 * k..16 + 8 * k].try_into().unwrap()) as usize
    };
    Ok((dim(0), dim(1), dim(2)))
}

/// Load a dataset fully resident. Accepts both on-disk formats: the dense
/// `CGGMDS01` layout from [`save_dataset`] and the sharded `CGGMPAN1` panel
/// layout from [`save_dataset_sharded`] (materialized through a small
/// transient panel cache, so peak memory is the resident matrices plus one
/// panel).
pub fn load_dataset(path: &Path) -> std::io::Result<Dataset> {
    use std::io::Read;
    {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if magic == crate::storage::GLOBAL_MAGIC {
            let src = Dataset::open_disk(path, crate::storage::DEFAULT_PANEL_ROWS, 0)?;
            let mut xt = crate::linalg::Mat::zeros(src.p(), src.n());
            let mut yt = crate::linalg::Mat::zeros(src.q(), src.n());
            src.x_panel_into(0..src.p(), &mut xt);
            src.y_panel_into(0..src.q(), &mut yt);
            return Ok(Dataset::new(xt, yt));
        }
    }
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != b"CGGMDS01" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic",
        ));
    }
    let mut dim = [0u8; 8];
    let mut dims = [0usize; 3];
    for d in dims.iter_mut() {
        f.read_exact(&mut dim)?;
        *d = u64::from_le_bytes(dim) as usize;
    }
    let (p, q, n) = (dims[0], dims[1], dims[2]);
    let mut read_mat = |rows: usize, cols: usize| -> std::io::Result<crate::linalg::Mat> {
        let mut data = vec![0.0f64; rows * cols];
        let mut buf = [0u8; 8];
        for v in data.iter_mut() {
            f.read_exact(&mut buf)?;
            *v = f64::from_le_bytes(buf);
        }
        Ok(crate::linalg::Mat::from_rows(rows, cols, data))
    };
    let xt = read_mat(p, n)?;
    let yt = read_mat(q, n)?;
    Ok(Dataset::new(xt, yt))
}

/// Open a saved dataset under an explicit storage policy: `"mem"` loads it
/// fully resident (either format), `"disk"` binds a `CGGMPAN1` panel file as
/// an out-of-core backend with a `cache_bytes` panel cache in `panel_rows`
/// row granules — the dataset then holds O(cache) memory regardless of n·p.
/// A dense `CGGMDS01` file cannot be served out-of-core (its X/Y halves are
/// monolithic); convert with [`save_dataset_sharded`] first.
pub fn open_dataset(
    path: &Path,
    storage: &str,
    panel_rows: usize,
    cache_bytes: usize,
) -> std::io::Result<Dataset> {
    match storage {
        "mem" | "" => load_dataset(path),
        "disk" => Dataset::open_disk(path, panel_rows, cache_bytes).map_err(|e| {
            std::io::Error::new(
                e.kind(),
                format!(
                    "cannot open {} disk-backed: {e} (only the sharded \
                     CGGMPAN1 format streams; see save_dataset_sharded)",
                    path.display()
                ),
            )
        }),
        other => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("unknown storage mode {other:?} (expected \"mem\" or \"disk\")"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;

    #[test]
    fn dataset_roundtrip() {
        let prob = datagen::chain::generate(6, 4, 5, 1);
        let dir = std::env::temp_dir().join("cggm_test_ds.bin");
        save_dataset(&prob.data, &dir).unwrap();
        let back = load_dataset(&dir).unwrap();
        assert_eq!(back.p(), 6);
        assert_eq!(back.q(), 4);
        assert_eq!(back.n(), 5);
        assert_eq!(back.xt().data(), prob.data.xt().data());
        assert_eq!(back.yt().data(), prob.data.yt().data());
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn sharded_dataset_roundtrip_and_open_modes() {
        let prob = datagen::chain::generate(6, 4, 11, 9);
        let path = std::env::temp_dir().join(format!(
            "cggm_test_ds_sharded_{}.pan",
            std::process::id()
        ));
        save_dataset_sharded(&prob.data, &path, 4).unwrap();
        // Header peek sees the panel format's dims without a full read.
        assert_eq!(peek_dataset_dims(&path).unwrap(), (6, 4, 11));
        // "mem" materializes the exact same matrices.
        let mem = open_dataset(&path, "mem", 0, 0).unwrap();
        assert!(!mem.is_disk());
        assert_eq!(mem.xt().data(), prob.data.xt().data());
        assert_eq!(mem.yt().data(), prob.data.yt().data());
        // "disk" binds the panel backend; re-export through save_dataset
        // streams it back out bit-identically.
        let disk = open_dataset(&path, "disk", 3, 1 << 16).unwrap();
        assert!(disk.is_disk());
        assert_eq!(disk.storage_name(), "disk");
        let dense = std::env::temp_dir().join(format!(
            "cggm_test_ds_sharded_{}.bin",
            std::process::id()
        ));
        save_dataset(&disk, &dense).unwrap();
        let back = load_dataset(&dense).unwrap();
        assert_eq!(back.xt().data(), prob.data.xt().data());
        assert_eq!(back.yt().data(), prob.data.yt().data());
        // Unknown modes and dense files opened "disk" are structured errors.
        assert!(open_dataset(&path, "tape", 0, 0).is_err());
        assert!(open_dataset(&dense, "disk", 4, 1 << 16).is_err());
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(dense);
    }

    #[test]
    fn calibration_moves_toward_truth_density() {
        let prob = datagen::chain::generate(20, 20, 150, 3);
        let eng = NativeGemm::new(1);
        let base = SolveOptions::default();
        let (lam_l, _) = calibrate_lambda(&prob, &eng, &base, 5);
        // Run at the calibrated λ and check the support is within 3× truth.
        let opts = SolveOptions {
            lam_l,
            lam_t: lam_l,
            max_iter: 40,
            ..Default::default()
        };
        let res = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
        let truth = prob.truth.lambda_nnz() as f64;
        let got = res.model.lambda_nnz() as f64;
        assert!(
            got < 4.0 * truth && got > truth / 4.0,
            "calibrated nnz {got} vs truth {truth} (λ={lam_l})"
        );
    }

    #[test]
    fn geometric_grid_is_decreasing_and_bracketed() {
        let g = geometric_grid(2.0, 1.0, 5, 0.1);
        assert_eq!(g.len(), 5);
        assert!((g[0].0 - 2.0).abs() < 1e-12);
        assert!((g[4].0 - 0.2).abs() < 1e-12);
        assert!((g[4].1 - 0.1).abs() < 1e-12);
        for k in 1..g.len() {
            assert!(g[k].0 < g[k - 1].0);
            assert!(g[k].1 < g[k - 1].1);
        }
        // Degenerate single-point grid sits at λ_max.
        let one = geometric_grid(3.0, 3.0, 1, 0.1);
        assert_eq!(one, vec![(3.0, 3.0)]);
    }

    #[test]
    fn fit_path_shares_statistics_across_points() {
        let prob = datagen::chain::generate(12, 12, 70, 4);
        let eng = NativeGemm::new(1);
        let base = SolveOptions {
            max_iter: 60,
            ..Default::default()
        };
        let ctx = SolverContext::new(&prob.data, &base, &eng);
        let popts = PathOptions {
            points: 3,
            min_ratio: 0.3,
            ..Default::default()
        };
        let res = fit_path_in_context(SolverKind::AltNewtonCd, &ctx, &base, &popts).unwrap();
        assert_eq!(res.points.len(), 3);
        assert!(res.points.iter().all(|p| p.converged));
        // S_yy, S_xx, S_xy each materialized exactly once for the whole path.
        assert_eq!(ctx.stat_computes(), 3);
        // Sparsity decreases (support grows) as λ shrinks along the path.
        assert!(
            res.points[2].lambda_nnz >= res.points[0].lambda_nnz,
            "support should grow as λ decreases: {:?}",
            res.points
        );
        assert!(res.model.is_some());
        // Serialization round-trips the point count.
        assert_eq!(res.to_csv().lines().count(), 1 + 3);
        assert!(res.to_json().to_string().contains("alt_newton_cd"));
    }

    #[test]
    fn path_time_budget_is_for_the_whole_path() {
        let prob = datagen::chain::generate(60, 60, 80, 6);
        let eng = NativeGemm::new(1);
        let base = SolveOptions {
            max_iter: 200,
            time_limit: 0.05, // seconds for the *entire* sweep
            ..Default::default()
        };
        let popts = PathOptions {
            points: 40,
            min_ratio: 0.01,
            ..Default::default()
        };
        let sw = std::time::Instant::now();
        let res = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &popts, &eng).unwrap();
        // The driver must stop early rather than giving every point the full
        // budget (40 × 0.05s would blow far past the cap).
        assert!(res.points.len() <= 40);
        assert!(
            sw.elapsed().as_secs_f64() < 2.0,
            "path ignored the shared time budget"
        );
    }

    #[test]
    fn run_fit_summary() {
        let prob = datagen::chain::generate(8, 8, 60, 2);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.3,
            lam_t: 0.3,
            max_iter: 30,
            ..Default::default()
        };
        let (sum, _) = run_fit(SolverKind::AltNewtonCd, &prob, &opts, &eng, None).unwrap();
        assert!(sum.converged);
        assert!(sum.f.is_finite());
        assert!(sum.f1_lambda.unwrap() >= 0.0);
        let j = sum.to_json().to_string();
        assert!(j.contains("alt_newton_cd"));
    }
}
