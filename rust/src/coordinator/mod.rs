//! Run coordination: configuration, λ calibration, dataset IO, and the
//! fit driver shared by the CLI and the experiment harness.

pub mod config;

use crate::cggm::Dataset;
use crate::datagen::{self, Problem, Workload};
use crate::gemm::GemmEngine;
use crate::metrics::f1_edges_sym;
use crate::solvers::{solve, SolveError, SolveOptions, SolveResult, SolverKind};
use crate::util::json::Json;
use std::path::Path;

pub use config::RunConfig;

/// One timed solver run with derived summary numbers (a row of Table 1).
pub struct RunSummary {
    pub solver: SolverKind,
    pub seconds: f64,
    pub iters: usize,
    pub converged: bool,
    pub f: f64,
    pub lambda_nnz: usize,
    pub theta_nnz: usize,
    pub f1_lambda: Option<f64>,
    pub peak_bytes: usize,
}

impl RunSummary {
    pub fn from_result(
        kind: SolverKind,
        res: &SolveResult,
        truth: Option<&crate::cggm::CggmModel>,
        peak_bytes: usize,
    ) -> RunSummary {
        RunSummary {
            solver: kind,
            seconds: res.trace.total_seconds,
            iters: res.trace.records.len(),
            converged: res.trace.converged,
            f: res.trace.final_f().unwrap_or(f64::NAN),
            lambda_nnz: res.model.lambda_nnz(),
            theta_nnz: res.model.theta_nnz(),
            f1_lambda: truth.map(|t| f1_edges_sym(&res.model.lambda, &t.lambda).f1),
            peak_bytes,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("solver", Json::str(self.solver.name())),
            ("seconds", Json::num(self.seconds)),
            ("iters", Json::num(self.iters as f64)),
            ("converged", Json::Bool(self.converged)),
            ("f", Json::num(self.f)),
            ("lambda_nnz", Json::num(self.lambda_nnz as f64)),
            ("theta_nnz", Json::num(self.theta_nnz as f64)),
            (
                "f1_lambda",
                self.f1_lambda.map(Json::num).unwrap_or(Json::Null),
            ),
            ("peak_bytes", Json::num(self.peak_bytes as f64)),
        ])
    }
}

/// Fit with a solver and summarize (trace CSV optionally written).
pub fn run_fit(
    kind: SolverKind,
    prob: &Problem,
    opts: &SolveOptions,
    engine: &dyn GemmEngine,
    trace_out: Option<&Path>,
) -> Result<(RunSummary, SolveResult), SolveError> {
    let res = solve(kind, &prob.data, opts, engine)?;
    if let Some(path) = trace_out {
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let _ = std::fs::write(path, res.trace.to_csv());
    }
    let summary = RunSummary::from_result(kind, &res, Some(&prob.truth), opts.budget.peak());
    Ok((summary, res))
}

/// Calibrate λ so the estimated support sizes land near the ground truth
/// (paper §5.1: "We choose λ_Λ and λ_Θ so that the number of estimated edges
/// in Λ and Θ is close to ground truth"). Geometric bisection on a shared
/// scale factor using short AltNewtonCD runs.
pub fn calibrate_lambda(
    prob: &Problem,
    engine: &dyn GemmEngine,
    base: &SolveOptions,
    steps: usize,
) -> (f64, f64) {
    let target_l = prob.truth.lambda_nnz() as f64;
    let target_t = prob.truth.theta_nnz().max(1) as f64;
    // Data-driven bracket: above λ_max = max |∇g| at the initial iterate
    // nothing enters the active set, so probing far below it creates huge
    // dense subproblems. Estimate λ_max from sampled gradient entries
    // (∇_Λ ≈ S_yy off-diagonal, ∇_Θ = 2S_xy at (I, 0)).
    let (p, q) = (prob.p(), prob.q());
    let mut rng = crate::util::rng::Rng::new(0x0ca1);
    let mut gmax = 1e-6f64;
    for _ in 0..4000 {
        let (i, j) = (rng.below(q), rng.below(q));
        if i != j {
            gmax = gmax.max(prob.data.syy(i, j).abs());
        }
        gmax = gmax.max(2.0 * prob.data.sxy(rng.below(p), rng.below(q)).abs());
    }
    let probe = |lam_l: f64, lam_t: f64| -> (f64, f64) {
        let opts = SolveOptions {
            lam_l,
            lam_t,
            max_iter: 6,
            trace_f: false,
            time_limit: 120.0,
            ..base.clone()
        };
        match solve(SolverKind::AltNewtonCd, &prob.data, &opts, engine) {
            Ok(res) => (
                res.model.lambda_nnz() as f64,
                res.model.theta_nnz() as f64,
            ),
            Err(_) => (f64::INFINITY, f64::INFINITY),
        }
    };
    // Independent geometric bisection per parameter: each probe updates both
    // brackets using its own density ratio.
    let (mut lo_l, mut hi_l) = (0.02 * gmax, 1.2 * gmax);
    let (mut lo_t, mut hi_t) = (0.02 * gmax, 1.2 * gmax);
    let (mut best_l, mut best_t) = (0.5, 0.5);
    for _ in 0..steps {
        best_l = (lo_l * hi_l).sqrt();
        best_t = (lo_t * hi_t).sqrt();
        let (nl, nt) = probe(best_l, best_t);
        if nl > target_l {
            lo_l = best_l; // too dense → raise λ_Λ
        } else {
            hi_l = best_l;
        }
        if nt > target_t {
            lo_t = best_t;
        } else {
            hi_t = best_t;
        }
    }
    (best_l, best_t)
}

/// Generate a workload (CLI `gen` + experiments).
pub fn generate_problem(
    workload: Workload,
    p: usize,
    q: usize,
    n: usize,
    seed: u64,
) -> Problem {
    datagen::generate(workload, p, q, n, seed)
}

/// Save a dataset in a simple binary format (header + row-major f64).
pub fn save_dataset(data: &Dataset, path: &Path) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(b"CGGMDS01")?;
    for dim in [data.p() as u64, data.q() as u64, data.n() as u64] {
        f.write_all(&dim.to_le_bytes())?;
    }
    for v in data.xt.data() {
        f.write_all(&v.to_le_bytes())?;
    }
    for v in data.yt.data() {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load a dataset saved by [`save_dataset`].
pub fn load_dataset(path: &Path) -> std::io::Result<Dataset> {
    use std::io::Read;
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != b"CGGMDS01" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic",
        ));
    }
    let mut dim = [0u8; 8];
    let mut dims = [0usize; 3];
    for d in dims.iter_mut() {
        f.read_exact(&mut dim)?;
        *d = u64::from_le_bytes(dim) as usize;
    }
    let (p, q, n) = (dims[0], dims[1], dims[2]);
    let mut read_mat = |rows: usize, cols: usize| -> std::io::Result<crate::linalg::Mat> {
        let mut data = vec![0.0f64; rows * cols];
        let mut buf = [0u8; 8];
        for v in data.iter_mut() {
            f.read_exact(&mut buf)?;
            *v = f64::from_le_bytes(buf);
        }
        Ok(crate::linalg::Mat::from_rows(rows, cols, data))
    };
    let xt = read_mat(p, n)?;
    let yt = read_mat(q, n)?;
    Ok(Dataset::new(xt, yt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;

    #[test]
    fn dataset_roundtrip() {
        let prob = datagen::chain::generate(6, 4, 5, 1);
        let dir = std::env::temp_dir().join("cggm_test_ds.bin");
        save_dataset(&prob.data, &dir).unwrap();
        let back = load_dataset(&dir).unwrap();
        assert_eq!(back.p(), 6);
        assert_eq!(back.q(), 4);
        assert_eq!(back.n(), 5);
        assert_eq!(back.xt.data(), prob.data.xt.data());
        assert_eq!(back.yt.data(), prob.data.yt.data());
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn calibration_moves_toward_truth_density() {
        let prob = datagen::chain::generate(20, 20, 150, 3);
        let eng = NativeGemm::new(1);
        let base = SolveOptions::default();
        let (lam_l, _) = calibrate_lambda(&prob, &eng, &base, 5);
        // Run at the calibrated λ and check the support is within 3× truth.
        let opts = SolveOptions {
            lam_l,
            lam_t: lam_l,
            max_iter: 40,
            ..Default::default()
        };
        let res = solve(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
        let truth = prob.truth.lambda_nnz() as f64;
        let got = res.model.lambda_nnz() as f64;
        assert!(
            got < 4.0 * truth && got > truth / 4.0,
            "calibrated nnz {got} vs truth {truth} (λ={lam_l})"
        );
    }

    #[test]
    fn run_fit_summary() {
        let prob = datagen::chain::generate(8, 8, 60, 2);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.3,
            lam_t: 0.3,
            max_iter: 30,
            ..Default::default()
        };
        let (sum, _) = run_fit(SolverKind::AltNewtonCd, &prob, &opts, &eng, None).unwrap();
        assert!(sum.converged);
        assert!(sum.f.is_finite());
        assert!(sum.f1_lambda.unwrap() >= 0.0);
        let j = sum.to_json().to_string();
        assert!(j.contains("alt_newton_cd"));
    }
}
