//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§5) at `--scale`-able sizes. See DESIGN.md §5 for the
//! experiment index and EXPERIMENTS.md for recorded runs.
//!
//! Every experiment prints a markdown table (the paper's rows/series) and
//! writes CSV series under `results/` for plotting.

pub mod figs;
pub mod table1;

use crate::coordinator::calibrate_lambda;
use crate::datagen::{self, Problem, Workload};
use crate::gemm::GemmEngine;
use crate::solvers::SolveOptions;
use crate::util::cli::Args;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// All registered experiments.
pub fn registry() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig1a", "chain graphs, p = q: time vs problem size (3 methods)"),
        ("fig1b", "chain graphs, p = 2q (irrelevant inputs): time vs size"),
        ("fig1c", "chain convergence: suboptimality vs time"),
        ("fig2a", "clustered random graphs: vary p at fixed q"),
        ("fig2b", "clustered random graphs: vary q at fixed p"),
        ("fig2c", "active-set size vs time (clustered graphs)"),
        ("fig3", "parallel speedup of AltNewtonBCD vs worker count"),
        ("fig4", "genomic-sim convergence: suboptimality + active set"),
        ("fig5", "chain, vary n: time (5a) and F1 recovery (5b)"),
        ("table1", "genomic-sim timings at three (p, q) scales, 3 methods"),
        ("memwall", "memory wall: non-block working sets vs the budget"),
    ]
}

/// Dispatch an experiment by id.
pub fn run(id: &str, args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    match id {
        "fig1a" => figs::fig1a(args, engine),
        "fig1b" => figs::fig1b(args, engine),
        "fig1c" => figs::fig1c(args, engine),
        "fig2a" => figs::fig2a(args, engine),
        "fig2b" => figs::fig2b(args, engine),
        "fig2c" => figs::fig2c(args, engine),
        "fig3" => figs::fig3(args, engine),
        "fig4" => figs::fig4(args, engine),
        "fig5" => figs::fig5(args, engine),
        "table1" => table1::run(args, engine),
        "memwall" => table1::memwall(args, engine),
        other => anyhow::bail!("unknown experiment '{other}' (see `cggm exp --list`)"),
    }
}

// ---------------------------------------------------------------- helpers

pub(crate) fn results_dir(args: &Args) -> PathBuf {
    let dir = PathBuf::from(args.get_str("out", "results"));
    let _ = std::fs::create_dir_all(&dir);
    dir
}

pub(crate) fn write_csv(dir: &Path, name: &str, header: &str, rows: &[String]) {
    let mut s = String::from(header);
    if !s.ends_with('\n') {
        s.push('\n');
    }
    for r in rows {
        s.push_str(r);
        if !r.ends_with('\n') {
            s.push('\n');
        }
    }
    let path = dir.join(name);
    match std::fs::write(&path, s) {
        Ok(()) => println!("-> {}", path.display()),
        Err(e) => eprintln!("warning: cannot write {}: {e}", path.display()),
    }
}

/// λ calibration cache (results/lambda_cache.json): keyed by
/// workload/p/q/n/seed so repeated experiments skip the probe runs.
pub(crate) fn cached_lambda(
    args: &Args,
    workload: Workload,
    prob: &Problem,
    engine: &dyn GemmEngine,
) -> (f64, f64) {
    if let Some(l) = args.opt("lambda") {
        let v: f64 = l.parse().expect("--lambda expects a number");
        return (v, v);
    }
    if args.opt("lambda-l").is_some() || args.opt("lambda-t").is_some() {
        return (args.get_f64("lambda-l", 0.5), args.get_f64("lambda-t", 0.5));
    }
    let dir = results_dir(args);
    let cache_path = dir.join("lambda_cache.json");
    let key = format!(
        "{:?}/{}/{}/{}",
        workload,
        prob.p(),
        prob.q(),
        prob.n()
    );
    let mut cache: BTreeMap<String, Json> = std::fs::read_to_string(&cache_path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    if let Some(arr) = cache.get(&key).and_then(|j| j.as_arr()) {
        if let (Some(l), Some(t)) = (arr[0].as_f64(), arr[1].as_f64()) {
            return (l, t);
        }
    }
    eprintln!("calibrating λ for {key} ...");
    let base = SolveOptions {
        threads: args.get_usize("threads", 1),
        ..Default::default()
    };
    let (lam_l, lam_t) = calibrate_lambda(prob, engine, &base, 6);
    eprintln!("  λ_Λ = {lam_l:.4}, λ_Θ = {lam_t:.4}");
    cache.insert(key, Json::arr([Json::num(lam_l), Json::num(lam_t)]));
    let _ = std::fs::write(&cache_path, Json::Obj(cache).to_string());
    (lam_l, lam_t)
}

/// Scale a default dimension by `--scale` (default 1.0).
pub(crate) fn scaled(args: &Args, v: usize) -> usize {
    let s = args.get_f64("scale", 1.0);
    ((v as f64 * s).round() as usize).max(8)
}

pub(crate) fn cluster_opts_scaled() -> datagen::cluster_graph::ClusterOptions {
    datagen::cluster_graph::ClusterOptions {
        cluster_size: 50,
        hub_coeff: 4.0,
        ..Default::default()
    }
}

pub(crate) fn genomic_opts_scaled() -> datagen::genomic::GenomicOptions {
    datagen::genomic::GenomicOptions::default()
}

/// Render one markdown table row.
pub(crate) fn md_row(cells: &[String]) -> String {
    format!("| {} |", cells.join(" | "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_dispatchable() {
        // Unknown ids must fail; known ids exist in the registry.
        let ids: Vec<&str> = registry().iter().map(|(id, _)| *id).collect();
        assert!(ids.contains(&"fig1a"));
        assert!(ids.contains(&"table1"));
        let eng = crate::gemm::native::NativeGemm::new(1);
        let args = Args::default();
        assert!(run("nope", &args, &eng).is_err());
    }

    #[test]
    fn scaling_helper() {
        let args = Args::parse(&["--scale".into(), "0.5".into()], &[]);
        assert_eq!(scaled(&args, 1000), 500);
        assert_eq!(scaled(&Args::default(), 1000), 1000);
    }
}
