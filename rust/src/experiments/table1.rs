//! Table 1 (genomic timings) and the memory-wall experiment.

use super::{genomic_opts_scaled, md_row, results_dir, write_csv};
use crate::coordinator::run_fit;
use crate::datagen;
use crate::gemm::GemmEngine;
use crate::solvers::{dense_workingset_bytes, SolveOptions, SolverKind};
use crate::util::cli::Args;
use crate::util::membudget::{fmt_bytes, parse_bytes, MemBudget};

/// Table 1: computation time on the genomic simulator at three (p, q)
/// scales. The paper's sizes (34249/3268 … 442440/3268 at n = 171) are
/// scaled by `--scale` (default 1/10); the third row's non-block methods hit
/// the memory wall exactly as in the paper — detected from their dense
/// working-set estimate against `--machine-ram`.
pub fn run(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let scale = args.get_f64("scale", 0.1);
    let n = args.get_usize("n", 171);
    let sizes: Vec<(usize, usize)> = vec![
        (
            (34249.0 * scale) as usize,
            (3268.0 * scale) as usize,
        ),
        (
            (34249.0 * scale) as usize,
            (10256.0 * scale) as usize,
        ),
        (
            (442440.0 * scale) as usize,
            (3268.0 * scale) as usize,
        ),
    ];
    // Emulated machine RAM for the OOM column (the paper's machine: 104 GB).
    let machine_ram = parse_bytes(&args.get_str("machine-ram", "2GB")).unwrap();
    let lam = args.get_f64("lambda", 0.14);
    let time_limit = args.get_f64("time-limit", 1800.0);

    println!("\n## table1 — genomic-sim timings (n={n}, scale={scale}, λ={lam}, RAM cap {})\n", fmt_bytes(machine_ram));
    println!(
        "{}",
        md_row(&["p".into(), "q".into(), "‖Λ*‖₀".into(), "‖Θ*‖₀".into(),
                 "NewtonCD".into(), "AltNewtonCD".into(), "AltNewtonBCD".into()])
    );
    println!("|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for &(p, q) in &sizes {
        let prob = datagen::genomic::generate(p, q, n, args.get_u64("seed", 20), &genomic_opts_scaled());
        let mut cells = vec![
            p.to_string(),
            q.to_string(),
            prob.truth.lambda_nnz().to_string(),
            prob.truth.theta_nnz().to_string(),
        ];
        let mut csv = format!("{p},{q}");
        for kind in [
            SolverKind::NewtonCd,
            SolverKind::AltNewtonCd,
            SolverKind::AltNewtonBcd,
        ] {
            let ws = dense_workingset_bytes(kind, p, q);
            if ws > machine_ram {
                // The paper's '*' — the dense working set does not fit.
                cells.push(format!("* ({})", fmt_bytes(ws)));
                csv.push_str(",oom");
                continue;
            }
            let budget = MemBudget::new(machine_ram);
            let opts = SolveOptions {
                lam_l: lam,
                lam_t: lam,
                max_iter: args.get_usize("max-iter", 60),
                threads: args.get_usize("threads", 1),
                time_limit,
                budget,
                ..Default::default()
            };
            match run_fit(kind, &prob, &opts, engine, None) {
                Ok((sum, _)) => {
                    let mark = if sum.converged { "" } else { " (cap)" };
                    cells.push(format!("{:.0}s{mark}", sum.seconds));
                    csv.push_str(&format!(",{:.2}", sum.seconds));
                }
                // The measured working set (solvers now track everything
                // through the workspace arena) can exceed the analytic
                // estimate near the boundary — that is the paper's '*' too,
                // not a harness failure.
                Err(crate::solvers::SolveError::Budget(_)) => {
                    cells.push("* (measured)".into());
                    csv.push_str(",oom");
                }
                Err(e) => return Err(e.into()),
            }
        }
        println!("{}", md_row(&cells));
        rows.push(csv);
    }
    write_csv(&results_dir(args), "table1.csv", "p,q,newton_cd,alt_newton_cd,alt_newton_bcd", &rows);
    Ok(())
}

/// Memory wall: where the non-block solvers exceed RAM (analytic working
/// set) vs the block solver's *measured* peak under a budget.
pub fn memwall(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let sizes = args.get_usize_list("sizes", &[500, 1000, 2000, 4000, 8000, 16000, 40000]);
    let ram = parse_bytes(&args.get_str("machine-ram", "2GB")).unwrap();
    let bcd_budget = parse_bytes(&args.get_str("mem-budget", "64MB")).unwrap();
    let run_cap = args.get_usize("run-cap", 1000);
    println!("\n## memwall — dense working sets vs budget (RAM cap {}, bcd budget {})\n",
        fmt_bytes(ram), fmt_bytes(bcd_budget));
    println!(
        "{}",
        md_row(&["p=q".into(), "NewtonCD ws".into(), "AltNewtonCD ws".into(),
                 "fits RAM?".into(), "BCD peak (measured)".into()])
    );
    println!("|---|---|---|---|---|");
    let mut rows = Vec::new();
    for &q in &sizes {
        let ws_n = dense_workingset_bytes(SolverKind::NewtonCd, q, q);
        let ws_a = dense_workingset_bytes(SolverKind::AltNewtonCd, q, q);
        let fits = ws_a <= ram;
        // Measure the block solver's true peak on the smaller sizes.
        let measured = if q <= run_cap {
            let prob = datagen::chain::generate(q, q, 100, 21);
            let budget = MemBudget::new(bcd_budget);
            let opts = SolveOptions {
                lam_l: 1.5,
                lam_t: 1.5,
                max_iter: 30,
                budget: budget.clone(),
                time_limit: 600.0,
                ..Default::default()
            };
            let _ = run_fit(SolverKind::AltNewtonBcd, &prob, &opts, engine, None)?;
            fmt_bytes(budget.peak())
        } else {
            "—".into()
        };
        println!(
            "{}",
            md_row(&[
                q.to_string(),
                fmt_bytes(ws_n),
                fmt_bytes(ws_a),
                fits.to_string(),
                measured.clone(),
            ])
        );
        rows.push(format!("{q},{ws_n},{ws_a},{fits},{measured}"));
    }
    write_csv(&results_dir(args), "memwall.csv", "q,newton_ws,alt_ws,fits_ram,bcd_peak", &rows);
    Ok(())
}
