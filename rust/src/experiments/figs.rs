//! Figure experiments (paper §5.1 Figures 1–3, §5.2 Figure 4, App. Figure 5).

use super::{
    cached_lambda, cluster_opts_scaled, genomic_opts_scaled, md_row, results_dir, scaled,
    write_csv,
};
use crate::coordinator::run_fit;
use crate::datagen::{self, Problem, Workload};
use crate::gemm::GemmEngine;
use crate::metrics::f1_edges_sym;
use crate::solvers::{solve, SolveOptions, SolverKind};
use crate::util::cli::Args;

fn base_opts(args: &Args, lam: (f64, f64)) -> SolveOptions {
    SolveOptions {
        lam_l: lam.0,
        lam_t: lam.1,
        max_iter: args.get_usize("max-iter", 100),
        tol: args.get_f64("tol", 0.01),
        threads: args.get_usize("threads", 1),
        time_limit: args.get_f64("time-limit", 1800.0),
        seed: args.get_u64("seed", 7),
        ..Default::default()
    }
}

/// Methods to run per size, respecting per-method size caps (the paper's
/// "could not be run beyond the problem sizes shown due to memory
/// constraint" — here a time/size guard so the sweep finishes).
fn methods_for(q: usize, p: usize, newton_cap: usize, dense_cap: usize) -> Vec<SolverKind> {
    let mut v = Vec::new();
    if q.max(p) <= newton_cap {
        v.push(SolverKind::NewtonCd);
    }
    if q.max(p) <= dense_cap {
        v.push(SolverKind::AltNewtonCd);
    }
    v.push(SolverKind::AltNewtonBcd);
    v
}

fn scaling_sweep(
    args: &Args,
    engine: &dyn GemmEngine,
    id: &str,
    workload: Workload,
    sizes: &[usize],
    mk_problem: impl Fn(usize) -> Problem,
) -> anyhow::Result<()> {
    let dir = results_dir(args);
    let newton_cap = args.get_usize("newton-cap", 1200);
    let dense_cap = args.get_usize("dense-cap", 4000);
    println!("\n## {id} — {workload:?} scaling sweep\n");
    println!("{}", md_row(&["method".into(), "p".into(), "q".into(), "n".into(),
        "time(s)".into(), "iters".into(), "converged".into(), "f".into()]));
    println!("|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for &size in sizes {
        let prob = mk_problem(size);
        let lam = cached_lambda(args, workload, &prob, engine);
        for kind in methods_for(prob.q(), prob.p(), newton_cap, dense_cap) {
            let opts = base_opts(args, lam);
            let (sum, _) = run_fit(kind, &prob, &opts, engine, None)?;
            println!(
                "{}",
                md_row(&[
                    kind.name().into(),
                    prob.p().to_string(),
                    prob.q().to_string(),
                    prob.n().to_string(),
                    format!("{:.2}", sum.seconds),
                    sum.iters.to_string(),
                    sum.converged.to_string(),
                    format!("{:.4}", sum.f),
                ])
            );
            rows.push(format!(
                "{},{},{},{},{:.4},{},{},{:.6}",
                kind.name(),
                prob.p(),
                prob.q(),
                prob.n(),
                sum.seconds,
                sum.iters,
                sum.converged,
                sum.f
            ));
        }
    }
    write_csv(&dir, &format!("{id}.csv"), "method,p,q,n,seconds,iters,converged,f", &rows);
    Ok(())
}

/// Fig 1(a): chain, p = q.
pub fn fig1a(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let sizes = args.get_usize_list("sizes", &[scaled(args, 250), scaled(args, 500), scaled(args, 1000)]);
    let n = args.get_usize("n", 100);
    let seed = args.get_u64("seed", 11);
    scaling_sweep(args, engine, "fig1a", Workload::Chain, &sizes, |q| {
        datagen::chain::generate(q, q, n, seed)
    })
}

/// Fig 1(b): chain, p = 2q (q irrelevant inputs).
pub fn fig1b(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let sizes = args.get_usize_list("sizes", &[scaled(args, 250), scaled(args, 500), scaled(args, 1000)]);
    let n = args.get_usize("n", 100);
    let seed = args.get_u64("seed", 12);
    scaling_sweep(args, engine, "fig1b", Workload::ChainIrrelevant, &sizes, |q| {
        datagen::chain::generate(2 * q, q, n, seed)
    })
}

/// Convergence traces: suboptimality (f - f*) vs wall time for all methods.
fn convergence_traces(
    args: &Args,
    engine: &dyn GemmEngine,
    id: &str,
    prob: &Problem,
    workload: Workload,
) -> anyhow::Result<()> {
    let dir = results_dir(args);
    let lam = cached_lambda(args, workload, prob, engine);
    // f*: run AltNewtonCD to high precision.
    let fstar_opts = SolveOptions {
        tol: 1e-6,
        max_iter: 400,
        ..base_opts(args, lam)
    };
    let fstar_res = solve(SolverKind::AltNewtonCd, &prob.data, &fstar_opts, engine)?;
    let mut fstar = fstar_res.trace.final_f().unwrap();
    println!("\n## {id} — convergence traces (λ=({:.3},{:.3}), f*={fstar:.6})\n", lam.0, lam.1);
    let mut all = Vec::new();
    for kind in [
        SolverKind::NewtonCd,
        SolverKind::AltNewtonCd,
        SolverKind::AltNewtonBcd,
    ] {
        let opts = SolveOptions {
            tol: args.get_f64("tol", 1e-4),
            ..base_opts(args, lam)
        };
        let res = solve(kind, &prob.data, &opts, engine)?;
        if let Some(f) = res.trace.final_f() {
            fstar = fstar.min(f);
        }
        all.push((kind, res));
    }
    println!(
        "{}",
        md_row(&["method".into(), "time-to-1e-2".into(), "time-to-1e-4".into(),
                 "final subopt".into(), "iters".into()])
    );
    println!("|---|---|---|---|---|");
    let mut rows = Vec::new();
    for (kind, res) in &all {
        let t_at = |eps: f64| {
            res.trace
                .records
                .iter()
                .find(|r| r.f - fstar <= eps * fstar.abs().max(1.0))
                .map(|r| format!("{:.2}", r.time))
                .unwrap_or_else(|| "—".into())
        };
        let last = res.trace.records.last().unwrap();
        println!(
            "{}",
            md_row(&[
                kind.name().into(),
                t_at(1e-2),
                t_at(1e-4),
                format!("{:.2e}", last.f - fstar),
                res.trace.records.len().to_string(),
            ])
        );
        for r in &res.trace.records {
            rows.push(format!(
                "{},{:.4},{:.10e},{},{}",
                kind.name(),
                r.time,
                (r.f - fstar).max(0.0),
                r.active_lambda,
                r.active_theta
            ));
        }
    }
    write_csv(
        &results_dir(args),
        &format!("{id}.csv"),
        "method,time,subopt,active_lambda,active_theta",
        &rows,
    );
    let _ = dir;
    Ok(())
}

/// Fig 1(c): chain q, p = 2q convergence.
pub fn fig1c(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let q = args.get_usize("q", scaled(args, 500));
    let p = args.get_usize("p", 2 * q);
    let n = args.get_usize("n", 100);
    let prob = datagen::chain::generate(p, q, n, args.get_u64("seed", 13));
    convergence_traces(args, engine, "fig1c", &prob, Workload::Chain)
}

/// Fig 2(a): clustered random graphs, vary p at fixed q.
pub fn fig2a(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let q = args.get_usize("q", scaled(args, 400));
    let sizes = args.get_usize_list(
        "sizes",
        &[scaled(args, 400), scaled(args, 800), scaled(args, 1600), scaled(args, 3200)],
    );
    let n = args.get_usize("n", 200);
    let seed = args.get_u64("seed", 14);
    let opts = cluster_opts_scaled();
    scaling_sweep(args, engine, "fig2a", Workload::Cluster, &sizes, |p| {
        datagen::cluster_graph::generate(p, q, n, seed, &opts)
    })
}

/// Fig 2(b): clustered random graphs, vary q at fixed p.
pub fn fig2b(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let p = args.get_usize("p", scaled(args, 1000));
    let sizes = args.get_usize_list(
        "sizes",
        &[scaled(args, 200), scaled(args, 400), scaled(args, 800)],
    );
    let n = args.get_usize("n", 200);
    let seed = args.get_u64("seed", 15);
    let opts = cluster_opts_scaled();
    scaling_sweep(args, engine, "fig2b", Workload::Cluster, &sizes, |q| {
        datagen::cluster_graph::generate(p, q, n, seed, &opts)
    })
}

/// Fig 2(c): active-set size vs time.
pub fn fig2c(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let p = args.get_usize("p", scaled(args, 1000));
    let q = args.get_usize("q", scaled(args, 500));
    let n = args.get_usize("n", 200);
    let prob =
        datagen::cluster_graph::generate(p, q, n, args.get_u64("seed", 16), &cluster_opts_scaled());
    convergence_traces(args, engine, "fig2c", &prob, Workload::Cluster)
}

/// Fig 3: parallel speedup of AltNewtonBCD.
///
/// NOTE: this container exposes a single physical core; the measured curve
/// quantifies threading *overhead* here and real speedup on multi-core
/// hardware (documented in EXPERIMENTS.md).
pub fn fig3(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let q = args.get_usize("q", scaled(args, 500));
    let p = args.get_usize("p", 2 * q);
    let n = args.get_usize("n", 100);
    let prob = datagen::chain::generate(p, q, n, args.get_u64("seed", 17));
    let lam = cached_lambda(args, Workload::Chain, &prob, engine);
    let threads = args.get_usize_list("threads-list", &[1, 2, 4, 8]);
    println!("\n## fig3 — AltNewtonBCD parallel scaling (1 physical core!)\n");
    println!("{}", md_row(&["threads".into(), "time(s)".into(), "speedup".into()]));
    println!("|---|---|---|");
    let mut rows = Vec::new();
    let mut t1 = None;
    for &t in &threads {
        let opts = SolveOptions {
            threads: t,
            ..base_opts(args, lam)
        };
        let (sum, _) = run_fit(SolverKind::AltNewtonBcd, &prob, &opts, engine, None)?;
        let base = *t1.get_or_insert(sum.seconds);
        println!(
            "{}",
            md_row(&[
                t.to_string(),
                format!("{:.2}", sum.seconds),
                format!("{:.2}x", base / sum.seconds),
            ])
        );
        rows.push(format!("{},{:.4},{:.4}", t, sum.seconds, base / sum.seconds));
    }
    write_csv(&results_dir(args), "fig3.csv", "threads,seconds,speedup", &rows);
    Ok(())
}

/// Fig 4: genomic-sim convergence (suboptimality + active set vs time).
pub fn fig4(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let p = args.get_usize("p", scaled(args, 3000));
    let q = args.get_usize("q", scaled(args, 300));
    let n = args.get_usize("n", 171);
    let prob =
        datagen::genomic::generate(p, q, n, args.get_u64("seed", 18), &genomic_opts_scaled());
    convergence_traces(args, engine, "fig4", &prob, Workload::Genomic)
}

/// Fig 5: chain p = q, vary n — (a) time and (b) F1 edge recovery.
pub fn fig5(args: &Args, engine: &dyn GemmEngine) -> anyhow::Result<()> {
    let q = args.get_usize("q", scaled(args, 400));
    let ns = args.get_usize_list("n-list", &[50, 100, 200, 400]);
    let seed = args.get_u64("seed", 19);
    println!("\n## fig5 — chain p=q={q}, varying sample size n\n");
    println!(
        "{}",
        md_row(&["method".into(), "n".into(), "time(s)".into(), "F1(Λ)".into(),
                 "F1(Θ)".into(), "converged".into()])
    );
    println!("|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for &n in &ns {
        let prob = datagen::chain::generate(q, q, n, seed);
        let lam = cached_lambda(args, Workload::Chain, &prob, engine);
        for kind in [
            SolverKind::NewtonCd,
            SolverKind::AltNewtonCd,
            SolverKind::AltNewtonBcd,
        ] {
            let opts = base_opts(args, lam);
            let (sum, res) = run_fit(kind, &prob, &opts, engine, None)?;
            let f1l = f1_edges_sym(&res.model.lambda, &prob.truth.lambda).f1;
            let f1t = crate::metrics::f1_entries(&res.model.theta, &prob.truth.theta).f1;
            println!(
                "{}",
                md_row(&[
                    kind.name().into(),
                    n.to_string(),
                    format!("{:.2}", sum.seconds),
                    format!("{:.3}", f1l),
                    format!("{:.3}", f1t),
                    sum.converged.to_string(),
                ])
            );
            rows.push(format!(
                "{},{},{:.4},{:.4},{:.4},{}",
                kind.name(),
                n,
                sum.seconds,
                f1l,
                f1t,
                sum.converged
            ));
        }
    }
    write_csv(&results_dir(args), "fig5.csv", "method,n,seconds,f1_lambda,f1_theta,converged", &rows);
    Ok(())
}
