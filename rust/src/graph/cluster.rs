//! Multilevel k-way graph partitioner (METIS substitute, DESIGN.md S7).
//!
//! Pipeline: (1) coarsen by heavy-edge matching until the graph is small,
//! (2) greedy region-growing initial partition on the coarsest graph,
//! (3) project back up, running boundary gain refinement at every level.
//!
//! The objective is the paper's: minimize edge cut (≈ active entries in
//! off-diagonal blocks) subject to balanced part sizes, so that the block
//! solver's cache misses B = Σ|B_zr| stay small (§4.1) and Θ's row blocks
//! concentrate in few parts (§4.2).

use super::Graph;
use crate::util::rng::Rng;

/// Partitioner configuration.
#[derive(Clone, Copy, Debug)]
pub struct ClusterOptions {
    /// Allowed imbalance: max part weight ≤ balance · (total/k).
    pub balance: f64,
    /// Refinement passes per level.
    pub refine_passes: usize,
    /// Stop coarsening at this many vertices (≥ 4k).
    pub coarsen_target: usize,
    pub seed: u64,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            balance: 1.10,
            refine_passes: 4,
            coarsen_target: 256,
            seed: 1,
        }
    }
}

/// Partition `g` into `k` parts. Returns `part[v] ∈ 0..k` for every vertex.
pub fn cluster(g: &Graph, k: usize, opts: &ClusterOptions) -> Vec<usize> {
    assert!(k >= 1);
    let n = g.n();
    if k == 1 || n <= k {
        return (0..n).map(|v| v % k.max(1)).collect();
    }
    let mut rng = Rng::new(opts.seed);
    // ---- Coarsening ----
    let mut levels: Vec<(Graph, Vec<usize>)> = Vec::new(); // (fine graph, fine→coarse map)
    let mut cur = g.clone();
    let target = opts.coarsen_target.max(4 * k);
    while cur.n() > target {
        let (coarse, map) = coarsen_once(&cur, &mut rng);
        if coarse.n() as f64 > 0.95 * cur.n() as f64 {
            break; // matching stalled (e.g. edgeless graph)
        }
        levels.push((cur, map));
        cur = coarse;
    }
    // ---- Initial partition on coarsest ----
    let mut part = initial_partition(&cur, k, opts, &mut rng);
    refine(&cur, &mut part, k, opts);
    // ---- Uncoarsen + refine ----
    while let Some((fine, map)) = levels.pop() {
        let mut fine_part = vec![0usize; fine.n()];
        for v in 0..fine.n() {
            fine_part[v] = part[map[v]];
        }
        part = fine_part;
        refine(&fine, &mut part, k, opts);
        cur = fine;
    }
    debug_assert_eq!(cur.n(), n);
    part
}

/// One round of heavy-edge matching; returns the coarse graph and the
/// fine→coarse vertex map.
fn coarsen_once(g: &Graph, rng: &mut Rng) -> (Graph, Vec<usize>) {
    let n = g.n();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![usize::MAX; n];
    for &u in &order {
        if mate[u] != usize::MAX {
            continue;
        }
        // Match u with its heaviest unmatched neighbor.
        let mut best = usize::MAX;
        let mut best_w = f64::NEG_INFINITY;
        for &(v, w) in g.neighbors(u) {
            if mate[v] == usize::MAX && v != u && w > best_w {
                best = v;
                best_w = w;
            }
        }
        if best != usize::MAX {
            mate[u] = best;
            mate[best] = u;
        } else {
            mate[u] = u; // stays single
        }
    }
    // Assign coarse ids.
    let mut map = vec![usize::MAX; n];
    let mut next = 0usize;
    for v in 0..n {
        if map[v] != usize::MAX {
            continue;
        }
        map[v] = next;
        let m = mate[v];
        if m != usize::MAX && m != v {
            map[m] = next;
        }
        next += 1;
    }
    // Build coarse graph.
    let mut coarse = Graph::empty(next);
    for c in coarse.vwgt.iter_mut() {
        *c = 0.0;
    }
    for v in 0..n {
        coarse.vwgt[map[v]] += g.vwgt[v];
        for &(u, w) in g.neighbors(v) {
            if u > v && map[u] != map[v] {
                coarse.add_edge(map[v], map[u], w);
            }
        }
    }
    (coarse, map)
}

/// Greedy region growing: k seeds spread by repeated farthest-BFS, then grow
/// parts by absorbing the frontier vertex with the strongest connection.
fn initial_partition(g: &Graph, k: usize, opts: &ClusterOptions, rng: &mut Rng) -> Vec<usize> {
    let n = g.n();
    let total_w: f64 = g.vwgt.iter().sum();
    let cap = opts.balance * total_w / k as f64;
    let mut part = vec![usize::MAX; n];
    let mut wgt = vec![0.0; k];

    // Seeds: first random, each next = unassigned vertex farthest (BFS hops)
    // from all previous seeds.
    let mut seeds = vec![rng.below(n)];
    while seeds.len() < k {
        let dist = multi_bfs(g, &seeds);
        let far = (0..n)
            .filter(|v| !seeds.contains(v))
            .max_by_key(|&v| if dist[v] == usize::MAX { n + 1 } else { dist[v] });
        match far {
            Some(v) => seeds.push(v),
            None => seeds.push(rng.below(n)),
        }
    }
    // Grow: priority = connection weight to the part; simple repeated scan
    // queue (coarsest graph is small, O(n²·deg) is fine).
    let mut frontier_gain = vec![vec![0.0f64; k]; n];
    for (p, &s) in seeds.iter().enumerate() {
        if part[s] == usize::MAX {
            part[s] = p;
            wgt[p] += g.vwgt[s];
            for &(u, w) in g.neighbors(s) {
                frontier_gain[u][p] += w;
            }
        }
    }
    loop {
        // Pick (v, p): unassigned v with max gain to a non-full part p.
        let mut best: Option<(usize, usize, f64)> = None;
        for v in 0..n {
            if part[v] != usize::MAX {
                continue;
            }
            for p in 0..k {
                if wgt[p] + g.vwgt[v] > cap {
                    continue;
                }
                let gain = frontier_gain[v][p];
                if best.map(|b| gain > b.2).unwrap_or(true) {
                    best = Some((v, p, gain));
                }
            }
        }
        match best {
            None => break,
            Some((v, p, _)) => {
                part[v] = p;
                wgt[p] += g.vwgt[v];
                for &(u, w) in g.neighbors(v) {
                    if part[u] == usize::MAX {
                        frontier_gain[u][p] += w;
                    }
                }
            }
        }
    }
    // Any stragglers (capacity edge cases): lightest part.
    for v in 0..n {
        if part[v] == usize::MAX {
            let p = (0..k)
                .min_by(|&a, &b| wgt[a].partial_cmp(&wgt[b]).unwrap())
                .unwrap();
            part[v] = p;
            wgt[p] += g.vwgt[v];
        }
    }
    part
}

fn multi_bfs(g: &Graph, sources: &[usize]) -> Vec<usize> {
    let mut dist = vec![usize::MAX; g.n()];
    let mut queue = std::collections::VecDeque::new();
    for &s in sources {
        dist[s] = 0;
        queue.push_back(s);
    }
    while let Some(u) = queue.pop_front() {
        for &(v, _) in g.neighbors(u) {
            if dist[v] == usize::MAX {
                dist[v] = dist[u] + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

/// Boundary refinement: greedy positive-gain moves subject to balance.
fn refine(g: &Graph, part: &mut [usize], k: usize, opts: &ClusterOptions) {
    let n = g.n();
    let total_w: f64 = g.vwgt.iter().sum();
    let cap = opts.balance * total_w / k as f64;
    let mut wgt = vec![0.0; k];
    for v in 0..n {
        wgt[part[v]] += g.vwgt[v];
    }
    for _ in 0..opts.refine_passes {
        let mut moved = 0usize;
        for v in 0..n {
            let home = part[v];
            // Connection weight to each part among neighbors.
            let mut conn = vec![0.0f64; k];
            let mut boundary = false;
            for &(u, w) in g.neighbors(v) {
                conn[part[u]] += w;
                if part[u] != home {
                    boundary = true;
                }
            }
            if !boundary {
                continue;
            }
            let (mut best_p, mut best_gain) = (home, 0.0);
            for p in 0..k {
                if p == home || wgt[p] + g.vwgt[v] > cap {
                    continue;
                }
                let gain = conn[p] - conn[home];
                if gain > best_gain {
                    best_gain = gain;
                    best_p = p;
                }
            }
            if best_p != home {
                wgt[home] -= g.vwgt[v];
                wgt[best_p] += g.vwgt[v];
                part[v] = best_p;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Convert a partition label vector into index lists per part, dropping
/// empty parts (the C_1..C_k of Algorithms 1–2).
pub fn parts_to_blocks(part: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut blocks = vec![Vec::new(); k];
    for (v, &p) in part.iter().enumerate() {
        blocks[p].push(v);
    }
    blocks.retain(|b| !b.is_empty());
    blocks
}

/// Fraction of a sorted pair set that changed between two snapshots:
/// |symmetric difference| / |union| (Jaccard distance). Both inputs must be
/// sorted and deduplicated; 0.0 for two empty sets.
pub fn pair_set_churn(old: &[(usize, usize)], new: &[(usize, usize)]) -> f64 {
    let (mut i, mut j) = (0usize, 0usize);
    let mut common = 0usize;
    while i < old.len() && j < new.len() {
        match old[i].cmp(&new[j]) {
            std::cmp::Ordering::Equal => {
                common += 1;
                i += 1;
                j += 1;
            }
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
        }
    }
    let union = old.len() + new.len() - common;
    if union == 0 {
        0.0
    } else {
        (union - common) as f64 / union as f64
    }
}

/// Churn-gated partition cache — the block solver's clustering, persisted
/// across outer iterations *and* adjacent λ-path points (supports change
/// slowly along a path, so the partition that minimized cross-block active
/// entries at λ_k is almost always still good at λ_{k+1}).
///
/// The cache is keyed on the structural inputs that shaped the partition:
/// vertex count, block count `k`, clustering seed, and the sorted active
/// pair set it was built from. [`PersistentPartition::blocks_cached`]
/// recomputes only when any key changes beyond the churn threshold; callers
/// count the `reclustered` flag into `SolveTrace::reclusterings` so tests
/// (and the path CLI) can observe the reuse.
#[derive(Clone, Debug, Default)]
pub struct PersistentPartition {
    k: usize,
    seed: u64,
    part: Vec<usize>,
    /// Sorted, deduplicated pair signature the cached partition was built
    /// from.
    sig: Vec<(usize, usize)>,
}

impl PersistentPartition {
    pub fn new() -> PersistentPartition {
        PersistentPartition::default()
    }

    /// True once a partition has been computed.
    pub fn is_built(&self) -> bool {
        !self.part.is_empty()
    }

    /// Blocks for the active structure summarized by `sig` (sorted, deduped
    /// pairs) over `n` vertices, split `k` ways. Reuses the cached partition
    /// unless (a) it does not exist or its shape/seed/k changed, or (b) the
    /// signature churn exceeds `churn_threshold` (negative ⇒ always
    /// rebuild). `build_graph` is invoked only on a rebuild. Returns the
    /// per-part index lists and whether a rebuild happened.
    pub fn blocks_cached(
        &mut self,
        n: usize,
        k: usize,
        opts: &ClusterOptions,
        sig: Vec<(usize, usize)>,
        churn_threshold: f64,
        build_graph: impl FnOnce() -> Graph,
    ) -> (Vec<Vec<usize>>, bool) {
        debug_assert!(sig.windows(2).all(|w| w[0] < w[1]), "signature not sorted");
        let reusable = self.part.len() == n
            && self.k == k
            && self.seed == opts.seed
            && pair_set_churn(&self.sig, &sig) <= churn_threshold;
        if reusable {
            return (parts_to_blocks(&self.part, k), false);
        }
        let g = build_graph();
        debug_assert_eq!(g.n(), n);
        self.part = cluster(&g, k, opts);
        self.k = k;
        self.seed = opts.seed;
        self.sig = sig;
        (parts_to_blocks(&self.part, k), true)
    }
}

/// Contiguous fallback partition (no clustering): splits 0..n into k ranges.
/// Used by the `--no-clustering` ablation.
pub fn contiguous_blocks(n: usize, k: usize) -> Vec<Vec<usize>> {
    let k = k.max(1);
    let size = n.div_ceil(k);
    (0..n)
        .collect::<Vec<_>>()
        .chunks(size.max(1))
        .map(|c| c.to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::property;

    /// Two dense clusters joined by one edge.
    fn two_cluster_graph(m: usize) -> Graph {
        let mut g = Graph::empty(2 * m);
        for c in 0..2 {
            let base = c * m;
            for i in 0..m {
                for j in i + 1..m {
                    g.add_edge(base + i, base + j, 1.0);
                }
            }
        }
        g.add_edge(0, m, 1.0);
        g
    }

    #[test]
    fn separates_obvious_clusters() {
        let g = two_cluster_graph(20);
        let part = cluster(&g, 2, &ClusterOptions::default());
        assert!(g.edge_cut(&part) <= 2.0, "cut = {}", g.edge_cut(&part));
        // Each cluster ends up homogeneous.
        for c in 0..2 {
            let base = c * 20;
            let p0 = part[base];
            assert!((0..20).all(|i| part[base + i] == p0));
        }
    }

    #[test]
    fn partition_is_valid_and_balanced() {
        property(20, |rng| {
            let n = 10 + rng.below(200);
            let k = 2 + rng.below(6);
            let mut g = Graph::empty(n);
            for _ in 0..3 * n {
                let (u, v) = (rng.below(n), rng.below(n));
                if u != v {
                    g.add_edge(u, v, 1.0 + rng.uniform());
                }
            }
            let opts = ClusterOptions {
                seed: rng.next_u64(),
                ..Default::default()
            };
            let part = cluster(&g, k, &opts);
            if part.len() != n {
                return Err("wrong length".into());
            }
            if part.iter().any(|&p| p >= k) {
                return Err("label out of range".into());
            }
            // balance within a loose factor (refinement may drift slightly)
            let mut wgt = vec![0.0; k];
            for v in 0..n {
                wgt[part[v]] += g.vwgt[v];
            }
            let cap = 1.5 * (n as f64) / k as f64 + 2.0;
            for (p, w) in wgt.iter().enumerate() {
                if *w > cap {
                    return Err(format!("part {p} weight {w} > cap {cap}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chain_partition_is_mostly_contiguous() {
        // On the paper's chain graph, a good partition cuts O(k) edges.
        let n = 400;
        let mut g = Graph::empty(n);
        for i in 1..n {
            g.add_edge(i - 1, i, 1.0);
        }
        let part = cluster(&g, 4, &ClusterOptions::default());
        let cut = g.edge_cut(&part);
        assert!(cut <= 12.0, "chain cut = {cut}");
    }

    #[test]
    fn blocks_cover_everything() {
        let part = vec![2, 0, 2, 1, 0];
        let blocks = parts_to_blocks(&part, 3);
        let mut all: Vec<usize> = blocks.concat();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        let cont = contiguous_blocks(10, 3);
        assert_eq!(cont.len(), 3);
        assert_eq!(cont.concat(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn churn_is_jaccard_distance() {
        let a = vec![(0, 1), (1, 2), (2, 3)];
        assert_eq!(pair_set_churn(&a, &a), 0.0);
        assert_eq!(pair_set_churn(&[], &[]), 0.0);
        // One of four union elements differs: distance 2/4 (one dropped, one
        // added out of union size 4).
        let b = vec![(0, 1), (1, 2), (3, 4)];
        assert!((pair_set_churn(&a, &b) - 0.5).abs() < 1e-12);
        // Disjoint sets: distance 1.
        assert_eq!(pair_set_churn(&a, &[(7, 8)]), 1.0);
        // Empty vs non-empty: everything changed.
        assert_eq!(pair_set_churn(&[], &a), 1.0);
    }

    #[test]
    fn persistent_partition_reuses_until_churn_threshold() {
        let g = two_cluster_graph(10);
        let mk_graph = || two_cluster_graph(10);
        let sig: Vec<(usize, usize)> = (0..g.n())
            .flat_map(|u| {
                g.neighbors(u)
                    .iter()
                    .filter(move |&&(v, _)| v > u)
                    .map(move |&(v, _)| (u, v))
            })
            .collect();
        let mut sig = sig;
        sig.sort_unstable();
        sig.dedup();
        let opts = ClusterOptions::default();
        let mut cache = PersistentPartition::new();
        let (blocks, rebuilt) =
            cache.blocks_cached(20, 2, &opts, sig.clone(), 0.2, mk_graph);
        assert!(rebuilt, "first use must build");
        assert!(cache.is_built());
        assert_eq!(blocks.concat().len(), 20);
        // Identical signature: reused, and the builder must not run.
        let (same, rebuilt) = cache.blocks_cached(20, 2, &opts, sig.clone(), 0.2, || {
            panic!("builder must not run on a cache hit")
        });
        assert!(!rebuilt);
        assert_eq!(same, blocks);
        // Small churn (1 edge of many): still under a 0.2 threshold.
        let mut near = sig.clone();
        near.pop();
        let (_, rebuilt) = cache.blocks_cached(20, 2, &opts, near, 0.2, || {
            panic!("small churn must not trigger a rebuild")
        });
        assert!(!rebuilt);
        // k change always rebuilds.
        let (_, rebuilt) = cache.blocks_cached(20, 3, &opts, sig.clone(), 0.2, mk_graph);
        assert!(rebuilt);
        // Negative threshold forces a rebuild even with zero churn.
        let (_, rebuilt) = cache.blocks_cached(20, 3, &opts, sig, -1.0, mk_graph);
        assert!(rebuilt);
    }

    #[test]
    fn k1_and_tiny_graphs() {
        let g = Graph::empty(5);
        assert_eq!(cluster(&g, 1, &ClusterOptions::default()), vec![0; 5]);
        let g2 = Graph::empty(2);
        let p = cluster(&g2, 5, &ClusterOptions::default());
        assert_eq!(p.len(), 2);
    }
}
