//! Graph substrate: weighted undirected graphs and the multilevel k-way
//! partitioner the block solver uses in place of METIS.
//!
//! Paper §4.1: "We use the METIS graph clustering library" to pick a
//! partition {C_1, …, C_k} that minimizes active-set entries in off-diagonal
//! blocks. METIS is unavailable here, so [`cluster`] implements the same
//! multilevel scheme METIS pioneered: heavy-edge-matching coarsening, greedy
//! region-growing initial partition, and boundary gain refinement
//! (Kernighan–Lin/Fiduccia–Mattheyses style) projected back up the levels.

pub mod cluster;
pub mod coloring;

use crate::linalg::sparse::SpRowMat;

/// Undirected weighted graph (adjacency lists; both directions stored).
#[derive(Clone, Debug)]
pub struct Graph {
    adj: Vec<Vec<(usize, f64)>>,
    /// Vertex weights (coarsened supernodes accumulate weight).
    pub vwgt: Vec<f64>,
}

impl Graph {
    pub fn empty(n: usize) -> Graph {
        Graph {
            adj: vec![Vec::new(); n],
            vwgt: vec![1.0; n],
        }
    }

    pub fn n(&self) -> usize {
        self.adj.len()
    }

    pub fn neighbors(&self, u: usize) -> &[(usize, f64)] {
        &self.adj[u]
    }

    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// Add (or accumulate) an undirected edge u—v with weight w.
    pub fn add_edge(&mut self, u: usize, v: usize, w: f64) {
        if u == v {
            return;
        }
        Self::add_half(&mut self.adj, u, v, w);
        Self::add_half(&mut self.adj, v, u, w);
    }

    fn add_half(adj: &mut [Vec<(usize, f64)>], u: usize, v: usize, w: f64) {
        match adj[u].binary_search_by_key(&v, |e| e.0) {
            Ok(k) => adj[u][k].1 += w,
            Err(k) => adj[u].insert(k, (v, w)),
        }
    }

    /// Graph of the off-diagonal pattern of a symmetric sparse matrix
    /// (the active-set graph of Λ).
    pub fn from_sym_pattern(a: &SpRowMat) -> Graph {
        let mut g = Graph::empty(a.rows());
        for i in 0..a.rows() {
            for &(j, _) in a.row(i) {
                if j > i {
                    g.add_edge(i, j, 1.0);
                }
            }
        }
        g
    }

    /// Column co-occurrence graph of Θ's active set (paper §4.2): vertices
    /// are the q columns; columns j,k are connected when some row has active
    /// entries in both — the nonzero pattern of ΘᵀΘ. Rows with many active
    /// entries contribute a path instead of a clique to keep the graph sparse
    /// (same clustering pressure, O(m_Θ) edges).
    pub fn theta_column_graph(active_cols_per_row: &[Vec<usize>], q: usize) -> Graph {
        let mut g = Graph::empty(q);
        const CLIQUE_CAP: usize = 8;
        for cols in active_cols_per_row {
            if cols.len() < 2 {
                continue;
            }
            if cols.len() <= CLIQUE_CAP {
                for (a, &ca) in cols.iter().enumerate() {
                    for &cb in &cols[a + 1..] {
                        g.add_edge(ca, cb, 1.0);
                    }
                }
            } else {
                for w in cols.windows(2) {
                    g.add_edge(w[0], w[1], 1.0);
                }
            }
        }
        g
    }

    /// Total weight of edges crossing between parts (the clustering
    /// objective — proxy for the paper's Σ|B_zr| cache-miss count).
    pub fn edge_cut(&self, part: &[usize]) -> f64 {
        let mut cut = 0.0;
        for u in 0..self.n() {
            for &(v, w) in &self.adj[u] {
                if v > u && part[u] != part[v] {
                    cut += w;
                }
            }
        }
        cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_edge_accumulates_and_sorts() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 2, 1.0);
        g.add_edge(2, 0, 0.5);
        g.add_edge(0, 1, 2.0);
        g.add_edge(3, 3, 9.0); // self loop ignored
        assert_eq!(g.neighbors(0), &[(1, 2.0), (2, 1.5)]);
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.edge_count(), 2);
    }

    #[test]
    fn from_sym_pattern_matches() {
        let mut a = SpRowMat::zeros(3, 3);
        a.set_sym(0, 1, 5.0);
        a.set(2, 2, 1.0);
        let g = Graph::from_sym_pattern(&a);
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.neighbors(0), &[(1, 1.0)]);
    }

    #[test]
    fn edge_cut_counts_cross_edges() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        g.add_edge(1, 2, 7.0);
        assert_eq!(g.edge_cut(&[0, 0, 1, 1]), 7.0);
        assert_eq!(g.edge_cut(&[0, 0, 0, 0]), 0.0);
    }

    #[test]
    fn theta_graph_clique_and_path() {
        let rows = vec![vec![0, 1, 2], (0..20).collect::<Vec<_>>()];
        let g = Graph::theta_column_graph(&rows, 20);
        // Clique on {0,1,2} plus path 0-1-...-19; edge 0-1 accumulated.
        assert!(g.neighbors(0).iter().any(|&(v, _)| v == 2));
        assert!(g.neighbors(5).iter().any(|&(v, _)| v == 6));
        assert!(!g.neighbors(5).iter().any(|&(v, _)| v == 7));
    }
}
