//! Conflict-graph coloring for parallel coordinate-descent sweeps
//! (paper §Parallelization).
//!
//! A CD update of coordinate `(i, j)` writes the shared ring caches along
//! the pair's row/column indices (`w`'s columns i and j for Λ, `vt`'s
//! column i for Θ), so two updates can run concurrently only when they
//! share **no** index. This module greedily colors the active set's
//! conflict graph — pairs are edges, indices are vertices, two pairs
//! conflict iff they share an endpoint — so each color class is a set of
//! index-disjoint coordinates the solvers can update data-parallel
//! (`cd_common::*_colored`), while classes run Gauss–Seidel in sequence.
//!
//! Greedy edge coloring uses at most `2Δ − 1` colors (Δ = the hottest
//! index's degree), and on the sparse active sets the solvers see it is
//! near-optimal in practice. Coloring is deterministic in the pair order,
//! which is what makes colored sweeps bitwise-reproducible across thread
//! counts.
//!
//! [`ColoringCache`] persists a coloring across inner sweeps and outer
//! iterations (the active set changes slowly near convergence and along a
//! λ path): an identical pair list is reused outright, small churn extends
//! the previous coloring incrementally (surviving pairs keep their colors
//! — removals can never invalidate a proper coloring), and only large
//! churn triggers a full rebuild. The cache's buffers are registered
//! against the [`MemBudget`] for as long as they are cached.

use crate::util::membudget::{BudgetExceeded, MemBudget, Tracked};
use std::collections::HashMap;

/// Which index spaces a coordinate pair's endpoints live in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConflictSpace {
    /// Λ coordinates: both endpoints index the same q columns (a diagonal
    /// pair `(i, i)` occupies a single vertex).
    Symmetric(usize),
    /// Θ coordinates `(i, j)`: rows `0..p` and columns `0..q` are distinct
    /// index spaces — `(i, j)` and `(k, l)` conflict iff `i == k` or
    /// `j == l`.
    Bipartite(usize, usize),
}

impl ConflictSpace {
    fn vertices(&self) -> usize {
        match *self {
            ConflictSpace::Symmetric(q) => q,
            ConflictSpace::Bipartite(p, q) => p + q,
        }
    }

    #[inline]
    fn endpoints(&self, pair: (usize, usize)) -> (usize, usize) {
        match *self {
            ConflictSpace::Symmetric(_) => (pair.0, pair.1),
            ConflictSpace::Bipartite(p, _) => (pair.0, p + pair.1),
        }
    }
}

/// Per-vertex used-color bitset (lazily grown words).
fn set_bit(words: &mut Vec<u64>, c: u32) {
    let w = (c / 64) as usize;
    if words.len() <= w {
        words.resize(w + 1, 0);
    }
    words[w] |= 1u64 << (c % 64);
}

fn lowest_free(ua: &[u64], ub: &[u64]) -> u32 {
    let mut w = 0usize;
    loop {
        let a = ua.get(w).copied().unwrap_or(0);
        let b = ub.get(w).copied().unwrap_or(0);
        let comb = a | b;
        if comb != u64::MAX {
            return (w as u32) * 64 + comb.trailing_ones();
        }
        w += 1;
    }
}

/// Greedily color `pairs` in order; returns one color per pair. Two pairs
/// sharing an endpoint (per `space`) never receive the same color.
pub fn greedy_color(pairs: &[(usize, usize)], space: ConflictSpace) -> Vec<u32> {
    let mut used: Vec<Vec<u64>> = vec![Vec::new(); space.vertices()];
    let mut colors = Vec::with_capacity(pairs.len());
    for &pr in pairs {
        let (a, b) = space.endpoints(pr);
        let c = lowest_free(&used[a], &used[b]);
        set_bit(&mut used[a], c);
        if b != a {
            set_bit(&mut used[b], c);
        }
        colors.push(c);
    }
    colors
}

/// Bucket `pairs` into color classes, preserving pair order within a class.
pub fn classes_from(pairs: &[(usize, usize)], colors: &[u32]) -> Vec<Vec<(usize, usize)>> {
    debug_assert_eq!(pairs.len(), colors.len());
    let nc = colors.iter().map(|&c| c as usize + 1).max().unwrap_or(0);
    let mut classes: Vec<Vec<(usize, usize)>> = vec![Vec::new(); nc];
    for (&pr, &c) in pairs.iter().zip(colors) {
        classes[c as usize].push(pr);
    }
    classes
}

/// One-shot convenience: color and bucket (ephemeral colorings, e.g. the
/// block solver's per-bucket sweeps).
pub fn color_classes(pairs: &[(usize, usize)], space: ConflictSpace) -> Vec<Vec<(usize, usize)>> {
    let colors = greedy_color(pairs, space);
    classes_from(pairs, &colors)
}

/// Greedy coloring of *items that each occupy a set of resource indices*:
/// two items sharing any resource never share a color. The block solver's
/// Θ row sweep uses this with items = active row-blocks and resources =
/// the block's columns, so same-column rows (whose Hessian coupling is
/// first-order, `2·S_xx[i1,i2]·Σ[jj]`) are serialized across classes while
/// disjoint-column rows run data-parallel — the same guarantee the
/// pair-coloring above gives the elementwise sweeps. Returns one color per
/// item; deterministic in item order.
pub fn greedy_color_groups<'a>(
    items: impl Iterator<Item = &'a [usize]>,
    resources: usize,
) -> Vec<u32> {
    let mut used: Vec<Vec<u64>> = vec![Vec::new(); resources];
    let mut colors = Vec::new();
    for occ in items {
        let mut c = 0u32;
        'search: loop {
            for &r in occ {
                let w = (c / 64) as usize;
                if used[r].get(w).copied().unwrap_or(0) & (1u64 << (c % 64)) != 0 {
                    c += 1;
                    continue 'search;
                }
            }
            break;
        }
        for &r in occ {
            set_bit(&mut used[r], c);
        }
        colors.push(c);
    }
    colors
}

/// Jaccard distance between two pair lists (order-insensitive).
fn churn(old: &[(usize, usize)], new: &[(usize, usize)]) -> f64 {
    let mut a: Vec<(usize, usize)> = old.to_vec();
    let mut b: Vec<(usize, usize)> = new.to_vec();
    a.sort_unstable();
    a.dedup();
    b.sort_unstable();
    b.dedup();
    super::cluster::pair_set_churn(&a, &b)
}

/// Churn-gated coloring cache, owned by the
/// [`crate::solvers::SolverContext`] next to the block solver's
/// [`super::cluster::PersistentPartition`]. Rebuilt only when the active
/// set churns past the caller's threshold; its buffers count against the
/// memory budget while cached.
#[derive(Default)]
pub struct ColoringCache {
    /// Pair list the cached classes cover, in solver order.
    sig: Vec<(usize, usize)>,
    colors: Vec<u32>,
    classes: Vec<Vec<(usize, usize)>>,
    space: Option<ConflictSpace>,
    /// Full greedy recolorings performed (observability for tests).
    pub rebuilds: usize,
    /// Incremental extensions (small churn: survivors kept their colors).
    pub extensions: usize,
    /// Calls served with the cached classes untouched.
    pub hits: usize,
    _track: Option<Tracked>,
}

impl ColoringCache {
    pub fn new() -> ColoringCache {
        ColoringCache::default()
    }

    /// Color classes covering exactly `pairs`. Reuses the cached coloring
    /// when the pair list is unchanged; extends it incrementally when the
    /// Jaccard churn is within `churn_limit` (negative ⇒ always rebuild);
    /// rebuilds from scratch otherwise. The returned classes partition
    /// `pairs` and no class contains two pairs sharing an index.
    pub fn classes_for(
        &mut self,
        pairs: &[(usize, usize)],
        space: ConflictSpace,
        churn_limit: f64,
        budget: &MemBudget,
    ) -> Result<&[Vec<(usize, usize)>], BudgetExceeded> {
        if self.space == Some(space) && self.sig == pairs {
            self.hits += 1;
            return Ok(&self.classes);
        }
        let incremental = self.space == Some(space)
            && !self.sig.is_empty()
            && churn_limit >= 0.0
            && churn(&self.sig, pairs) <= churn_limit;
        let colors = if incremental {
            // Surviving pairs keep their colors (removals cannot break a
            // proper coloring); fresh pairs are greedily colored around
            // them.
            let old: HashMap<(usize, usize), u32> = self
                .sig
                .iter()
                .copied()
                .zip(self.colors.iter().copied())
                .collect();
            let mut used: Vec<Vec<u64>> = vec![Vec::new(); space.vertices()];
            let mut colors: Vec<u32> = Vec::with_capacity(pairs.len());
            // First pass: pin survivors and seed the per-vertex masks.
            for &pr in pairs {
                match old.get(&pr) {
                    Some(&c) => {
                        let (a, b) = space.endpoints(pr);
                        set_bit(&mut used[a], c);
                        if b != a {
                            set_bit(&mut used[b], c);
                        }
                        colors.push(c);
                    }
                    None => colors.push(u32::MAX),
                }
            }
            // Second pass: color the newcomers.
            for (k, &pr) in pairs.iter().enumerate() {
                if colors[k] == u32::MAX {
                    let (a, b) = space.endpoints(pr);
                    let c = lowest_free(&used[a], &used[b]);
                    set_bit(&mut used[a], c);
                    if b != a {
                        set_bit(&mut used[b], c);
                    }
                    colors[k] = c;
                }
            }
            self.extensions += 1;
            colors
        } else {
            self.rebuilds += 1;
            greedy_color(pairs, space)
        };
        // Re-register the cache's bytes: release the old registration first
        // so the swap is not transiently double-counted, and leave the cache
        // empty (not stale) if the new registration does not fit.
        self._track = None;
        let bytes = pairs.len()
            * (2 * std::mem::size_of::<(usize, usize)>() + std::mem::size_of::<u32>());
        let track = match budget.track(bytes) {
            Ok(t) => t,
            Err(e) => {
                self.sig.clear();
                self.colors.clear();
                self.classes.clear();
                self.space = None;
                return Err(e);
            }
        };
        self.classes = classes_from(pairs, &colors);
        self.sig = pairs.to_vec();
        self.colors = colors;
        self.space = Some(space);
        self._track = Some(track);
        Ok(&self.classes)
    }
}

/// Debug-check a class partition: every class is index-disjoint and the
/// classes cover `pairs` exactly. Used by tests (and cheap enough for
/// assertions in benches).
pub fn validate_classes(
    pairs: &[(usize, usize)],
    classes: &[Vec<(usize, usize)>],
    space: ConflictSpace,
) -> Result<(), String> {
    let mut seen = 0usize;
    for (ci, class) in classes.iter().enumerate() {
        let mut used = vec![false; space.vertices()];
        for &pr in class {
            let (a, b) = space.endpoints(pr);
            if used[a] || (b != a && used[b]) {
                return Err(format!("class {ci} has two pairs sharing an index: {pr:?}"));
            }
            used[a] = true;
            used[b] = true;
            seen += 1;
        }
    }
    if seen != pairs.len() {
        return Err(format!(
            "classes cover {seen} pairs, expected {}",
            pairs.len()
        ));
    }
    let mut a: Vec<(usize, usize)> = pairs.to_vec();
    let mut b: Vec<(usize, usize)> = classes.iter().flatten().copied().collect();
    a.sort_unstable();
    b.sort_unstable();
    if a != b {
        return Err("classes are not a permutation of the input pairs".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::property;

    fn random_lambda_pairs(rng: &mut Rng, q: usize) -> Vec<(usize, usize)> {
        let mut pairs = Vec::new();
        for i in 0..q {
            for j in i..q {
                if i == j || rng.bernoulli(0.3) {
                    pairs.push((i, j));
                }
            }
        }
        pairs
    }

    #[test]
    fn symmetric_coloring_is_valid() {
        property(30, |rng| {
            let q = 2 + rng.below(30);
            let pairs = random_lambda_pairs(rng, q);
            let classes = color_classes(&pairs, ConflictSpace::Symmetric(q));
            validate_classes(&pairs, &classes, ConflictSpace::Symmetric(q))
        });
    }

    #[test]
    fn bipartite_coloring_is_valid() {
        property(30, |rng| {
            let p = 1 + rng.below(20);
            let q = 1 + rng.below(20);
            let mut pairs = Vec::new();
            for i in 0..p {
                for j in 0..q {
                    if rng.bernoulli(0.3) {
                        pairs.push((i, j));
                    }
                }
            }
            let classes = color_classes(&pairs, ConflictSpace::Bipartite(p, q));
            validate_classes(&pairs, &classes, ConflictSpace::Bipartite(p, q))
        });
    }

    #[test]
    fn shared_row_or_column_conflicts() {
        // Θ-space: (0,0)/(1,0) share a column, (0,0)/(0,1) share a row —
        // both must split; (0,0)/(1,1) are disjoint and may share a color.
        let space = ConflictSpace::Bipartite(2, 2);
        let c = greedy_color(&[(0, 0), (1, 0)], space);
        assert_ne!(c[0], c[1]);
        let c = greedy_color(&[(0, 0), (0, 1)], space);
        assert_ne!(c[0], c[1]);
        let c = greedy_color(&[(0, 0), (1, 1)], space);
        assert_eq!(c[0], c[1], "disjoint pairs share the first color");
    }

    #[test]
    fn diagonal_pairs_occupy_one_vertex() {
        // (i,i) conflicts with every pair touching i but not with (j,j).
        let space = ConflictSpace::Symmetric(3);
        let pairs = [(0, 0), (1, 1), (0, 1)];
        let c = greedy_color(&pairs, space);
        assert_eq!(c[0], c[1]);
        assert_ne!(c[0], c[2]);
        assert_ne!(c[1], c[2]);
    }

    #[test]
    fn coloring_is_deterministic_in_pair_order() {
        let mut rng = Rng::new(9);
        let pairs = random_lambda_pairs(&mut rng, 25);
        let a = greedy_color(&pairs, ConflictSpace::Symmetric(25));
        let b = greedy_color(&pairs, ConflictSpace::Symmetric(25));
        assert_eq!(a, b);
    }

    #[test]
    fn group_coloring_separates_shared_resources() {
        property(30, |rng| {
            let nres = 2 + rng.below(20);
            let nitems = 1 + rng.below(25);
            let items: Vec<Vec<usize>> = (0..nitems)
                .map(|_| {
                    let k = 1 + rng.below(4);
                    (0..k).map(|_| rng.below(nres)).collect()
                })
                .collect();
            let colors = greedy_color_groups(items.iter().map(|v| v.as_slice()), nres);
            if colors.len() != nitems {
                return Err("one color per item".into());
            }
            for a in 0..nitems {
                for b in a + 1..nitems {
                    let shares = items[a].iter().any(|r| items[b].contains(r));
                    if shares && colors[a] == colors[b] {
                        return Err(format!(
                            "items {a},{b} share a resource but share color {}",
                            colors[a]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cache_reuses_extends_and_rebuilds() {
        let mut rng = Rng::new(3);
        let q = 20;
        let space = ConflictSpace::Symmetric(q);
        let budget = MemBudget::unlimited();
        let mut cache = ColoringCache::new();
        let pairs = random_lambda_pairs(&mut rng, q);
        {
            let classes = cache.classes_for(&pairs, space, 0.2, &budget).unwrap();
            validate_classes(&pairs, classes, space).unwrap();
        }
        assert_eq!((cache.rebuilds, cache.extensions, cache.hits), (1, 0, 0));
        // Identical pair list: served from cache.
        cache.classes_for(&pairs, space, 0.2, &budget).unwrap();
        assert_eq!(cache.hits, 1);
        // Small churn: drop one pair, add one — incremental extension, and
        // the result is still a valid coloring of the new list.
        let mut churned = pairs.clone();
        churned.retain(|&pr| pr != (0, 0));
        churned.push((0, 0)); // moved to the end: same set, new order-tail
        let extra = (0, q - 1);
        if !churned.contains(&extra) {
            churned.push(extra);
        }
        {
            let classes = cache.classes_for(&churned, space, 0.5, &budget).unwrap();
            validate_classes(&churned, classes, space).unwrap();
        }
        assert_eq!(cache.extensions, 1);
        // Negative threshold forces a full rebuild even for tiny churn.
        let classes = cache.classes_for(&pairs, space, -1.0, &budget).unwrap();
        validate_classes(&pairs, classes, space).unwrap();
        assert_eq!(cache.rebuilds, 2);
    }

    #[test]
    fn cache_registers_against_the_budget() {
        let q = 10;
        let space = ConflictSpace::Symmetric(q);
        let budget = MemBudget::unlimited();
        let mut cache = ColoringCache::new();
        let pairs: Vec<(usize, usize)> = (0..q).map(|i| (i, i)).collect();
        cache.classes_for(&pairs, space, 0.2, &budget).unwrap();
        let per_pair = 2 * std::mem::size_of::<(usize, usize)>() + std::mem::size_of::<u32>();
        assert_eq!(budget.live(), q * per_pair);
        drop(cache);
        assert_eq!(budget.live(), 0);
        // An impossible budget is a clean error and empties the cache.
        let tiny = MemBudget::new(8);
        let mut cache = ColoringCache::new();
        assert!(cache.classes_for(&pairs, space, 0.2, &tiny).is_err());
        assert_eq!(tiny.live(), 0);
    }
}
