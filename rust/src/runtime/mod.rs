//! PJRT runtime: load the AOT-compiled JAX/Pallas HLO-text artifacts and
//! execute them from the Rust hot path (the L1/L2 ↔ L3 bridge).
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) describes
//! each artifact's entry shapes. [`XlaGemm`] implements
//! [`crate::gemm::GemmEngine`] by tiling arbitrary GEMMs over fixed-shape
//! compiled executables (padding edge tiles with zeros), falling back to the
//! native engine below a crossover size where PJRT call overhead dominates
//! (measured in `bench_gemm`).

pub mod manifest;

use crate::gemm::{native::NativeGemm, GemmEngine};
use crate::linalg::dense::Mat;
use manifest::{ArtifactEntry, Manifest};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Contraction layouts the solvers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Layout {
    /// C = A·B.
    Mm,
    /// C = Aᵀ·B.
    Tn,
    /// C = A·Bᵀ.
    Nt,
}

impl Layout {
    fn kind_str(&self) -> &'static str {
        match self {
            Layout::Mm => "gemm_mm",
            Layout::Tn => "gemm_tn",
            Layout::Nt => "gemm_nt",
        }
    }
}

/// Which compiled GEMM variant to prefer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmVariant {
    /// Plain `jnp.dot` lowered through XLA (fast CPU baseline).
    Xla,
    /// The Pallas L1 kernels in interpret mode (TPU-shaped; slower on CPU —
    /// quantified by the engine ablation bench).
    Pallas,
}

impl GemmVariant {
    fn as_str(&self) -> &'static str {
        match self {
            GemmVariant::Xla => "xla",
            GemmVariant::Pallas => "pallas",
        }
    }
    pub fn parse(s: &str) -> Option<GemmVariant> {
        match s {
            "xla" => Some(GemmVariant::Xla),
            "pallas" => Some(GemmVariant::Pallas),
            _ => None,
        }
    }
}

struct TileExe {
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT-backed engine. Thread-safe via an execution mutex: the PJRT CPU
/// client is internally synchronized, but the `xla` crate types carry no
/// Send/Sync markers, so we serialize calls ourselves.
pub struct XlaGemm {
    inner: Mutex<Inner>,
    /// Below this max-dimension, dispatch to native (call overhead).
    pub crossover: usize,
    native: NativeGemm,
    variant: GemmVariant,
    tile: usize,
}

struct Inner {
    _client: xla::PjRtClient,
    exes: BTreeMap<Layout, TileExe>,
}

// SAFETY: all PJRT interaction happens under the `inner` mutex; the PJRT CPU
// client itself is thread-safe. The raw pointers inside the xla crate types
// are never aliased across threads without the lock.
unsafe impl Send for XlaGemm {}
unsafe impl Sync for XlaGemm {}

#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("artifact dir {0} missing or unreadable")]
    MissingArtifacts(PathBuf),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("artifact {name} missing for layout {layout:?}")]
    MissingKernel { name: String, layout: Layout },
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

impl XlaGemm {
    /// Load the engine from an artifact directory, choosing tile size and
    /// kernel variant.
    pub fn load(
        dir: &Path,
        tile: usize,
        variant: GemmVariant,
        threads: usize,
    ) -> Result<XlaGemm, RuntimeError> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for layout in [Layout::Mm, Layout::Tn, Layout::Nt] {
            let entry = manifest
                .find(layout.kind_str(), Some(variant.as_str()), Some(tile))
                .or_else(|| manifest.find(layout.kind_str(), Some("xla"), Some(tile)))
                .ok_or_else(|| RuntimeError::MissingKernel {
                    name: format!("{}_{}_f64_{}", layout.kind_str(), variant.as_str(), tile),
                    layout,
                })?;
            let exe = compile_artifact(&client, dir, entry)?;
            exes.insert(layout, TileExe { exe });
        }
        Ok(XlaGemm {
            inner: Mutex::new(Inner {
                _client: client,
                exes,
            }),
            crossover: tile / 2,
            native: NativeGemm::new(threads),
            variant,
            tile,
        })
    }

    /// Load with defaults (tile 256, XLA variant) from `artifacts/`.
    pub fn load_default(dir: &Path) -> Result<XlaGemm, RuntimeError> {
        XlaGemm::load(dir, 256, GemmVariant::Xla, 1)
    }

    pub fn variant(&self) -> GemmVariant {
        self.variant
    }

    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Tiled execution: pads (m, k, n) up to multiples of the tile, runs one
    /// PJRT call per (i, j, k) tile triple, accumulates into C.
    fn tiled(&self, layout: Layout, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (m, k) = match layout {
            Layout::Mm | Layout::Nt => (a.rows(), a.cols()),
            Layout::Tn => (a.cols(), a.rows()),
        };
        let n = match layout {
            Layout::Mm | Layout::Tn => b.cols(),
            Layout::Nt => b.rows(),
        };
        assert_eq!((c.rows(), c.cols()), (m, n), "tiled gemm output shape");
        if beta == 0.0 {
            c.fill(0.0);
        } else if beta != 1.0 {
            c.scale(beta);
        }
        let t = self.tile;
        let (mt, nt, kt) = (m.div_ceil(t), n.div_ceil(t), k.div_ceil(t));
        let mut abuf = vec![0.0f64; t * t];
        let mut bbuf = vec![0.0f64; t * t];
        for it in 0..mt {
            for kt_i in 0..kt {
                fill_tile_a(layout, a, it, kt_i, t, &mut abuf);
                for jt in 0..nt {
                    fill_tile_b(layout, b, kt_i, jt, t, &mut bbuf);
                    let out = self.execute_tile(layout, &abuf, &bbuf, t);
                    // C[it, jt] += alpha * out.
                    let i0 = it * t;
                    let j0 = jt * t;
                    let ib = t.min(m - i0);
                    let jb = t.min(n - j0);
                    for di in 0..ib {
                        let crow = &mut c.row_mut(i0 + di)[j0..j0 + jb];
                        let orow = &out[di * t..di * t + jb];
                        for (cv, ov) in crow.iter_mut().zip(orow) {
                            *cv += alpha * ov;
                        }
                    }
                }
            }
        }
    }

    fn execute_tile(&self, layout: Layout, a: &[f64], b: &[f64], t: usize) -> Vec<f64> {
        let inner = self.inner.lock().unwrap();
        let exe = &inner.exes[&layout].exe;
        let ta = xla::Literal::vec1(a).reshape(&[t as i64, t as i64]).unwrap();
        let tb = xla::Literal::vec1(b).reshape(&[t as i64, t as i64]).unwrap();
        let result = exe.execute::<xla::Literal>(&[ta, tb]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let out = result.to_tuple1().unwrap();
        out.to_vec::<f64>().unwrap()
    }

    fn small(&self, m: usize, k: usize, n: usize) -> bool {
        m.max(k).max(n) < self.crossover
    }
}

/// Fill the A tile for logical block (it, kt): the executable expects the
/// artifact's own input layout (m×k for Mm/Nt, k×m panel for Tn).
fn fill_tile_a(layout: Layout, a: &Mat, it: usize, kt: usize, t: usize, buf: &mut [f64]) {
    buf.iter_mut().for_each(|x| *x = 0.0);
    let (m, k) = match layout {
        Layout::Mm | Layout::Nt => (a.rows(), a.cols()),
        Layout::Tn => (a.cols(), a.rows()),
    };
    let i0 = it * t;
    let k0 = kt * t;
    let ib = t.min(m.saturating_sub(i0));
    let kb = t.min(k.saturating_sub(k0));
    match layout {
        Layout::Mm | Layout::Nt => {
            for di in 0..ib {
                let src = &a.row(i0 + di)[k0..k0 + kb];
                buf[di * t..di * t + kb].copy_from_slice(src);
            }
        }
        Layout::Tn => {
            for dk in 0..kb {
                let src = &a.row(k0 + dk)[i0..i0 + ib];
                buf[dk * t..dk * t + ib].copy_from_slice(src);
            }
        }
    }
}

/// Fill the B tile for logical block (kt, jt) (k×n for Mm/Tn, n×k for Nt).
fn fill_tile_b(layout: Layout, b: &Mat, kt: usize, jt: usize, t: usize, buf: &mut [f64]) {
    buf.iter_mut().for_each(|x| *x = 0.0);
    let (k, n) = match layout {
        Layout::Mm | Layout::Tn => (b.rows(), b.cols()),
        Layout::Nt => (b.cols(), b.rows()),
    };
    let k0 = kt * t;
    let j0 = jt * t;
    let kb = t.min(k.saturating_sub(k0));
    let jb = t.min(n.saturating_sub(j0));
    match layout {
        Layout::Mm | Layout::Tn => {
            for dk in 0..kb {
                let src = &b.row(k0 + dk)[j0..j0 + jb];
                buf[dk * t..dk * t + jb].copy_from_slice(src);
            }
        }
        Layout::Nt => {
            for dj in 0..jb {
                let src = &b.row(j0 + dj)[k0..k0 + kb];
                buf[dj * t..dj * t + kb].copy_from_slice(src);
            }
        }
    }
}

impl GemmEngine for XlaGemm {
    fn gemm(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        if self.small(a.rows(), a.cols(), b.cols()) {
            return self.native.gemm(alpha, a, b, beta, c);
        }
        self.tiled(Layout::Mm, alpha, a, b, beta, c);
    }

    fn gemm_tn(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        if self.small(a.cols(), a.rows(), b.cols()) {
            return self.native.gemm_tn(alpha, a, b, beta, c);
        }
        self.tiled(Layout::Tn, alpha, a, b, beta, c);
    }

    fn gemm_nt(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        if self.small(a.rows(), a.cols(), b.rows()) {
            return self.native.gemm_nt(alpha, a, b, beta, c);
        }
        self.tiled(Layout::Nt, alpha, a, b, beta, c);
    }

    fn name(&self) -> &'static str {
        match self.variant {
            GemmVariant::Xla => "xla",
            GemmVariant::Pallas => "pallas",
        }
    }
}

/// Compile one artifact on a PJRT client.
pub fn compile_artifact(
    client: &xla::PjRtClient,
    dir: &Path,
    entry: &ArtifactEntry,
) -> Result<xla::PjRtLoadedExecutable, RuntimeError> {
    let path = dir.join(&entry.file);
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| RuntimeError::MissingArtifacts(path.clone()))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Default artifact directory: `$CGGM_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> PathBuf {
    std::env::var("CGGM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// How the native engine picks its cache-block sizes (`--gemm-blocks` /
/// `--gemm-autotune`; ignored by the PJRT engines, whose tiling is fixed by
/// the compiled artifacts).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GemmBlocks {
    /// Compiled-in defaults — deterministic across machines.
    #[default]
    Default,
    /// Explicit `(mc, kc, nc)` triple.
    Explicit(usize, usize, usize),
    /// One-shot construction-time probe ([`NativeGemm::autotuned`]).
    Autotune,
}

/// Build the configured engine: `native`, `xla`, or `pallas`.
pub fn make_engine(
    kind: &str,
    threads: usize,
    tile: usize,
) -> Result<std::sync::Arc<dyn GemmEngine>, RuntimeError> {
    make_engine_with(kind, threads, tile, GemmBlocks::Default)
}

/// [`make_engine`] with a native-engine block-size policy.
pub fn make_engine_with(
    kind: &str,
    threads: usize,
    tile: usize,
    blocks: GemmBlocks,
) -> Result<std::sync::Arc<dyn GemmEngine>, RuntimeError> {
    match kind {
        "native" => Ok(match blocks {
            GemmBlocks::Default => std::sync::Arc::new(NativeGemm::new(threads)),
            GemmBlocks::Explicit(mc, kc, nc) => {
                std::sync::Arc::new(NativeGemm::with_blocks(threads, mc, kc, nc))
            }
            GemmBlocks::Autotune => std::sync::Arc::new(NativeGemm::autotuned(threads)),
        }),
        "xla" | "pallas" => {
            let variant = GemmVariant::parse(kind).unwrap();
            Ok(std::sync::Arc::new(XlaGemm::load(
                &artifact_dir(),
                tile,
                variant,
                threads,
            )?))
        }
        other => Err(RuntimeError::Manifest(format!("unknown engine '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::check_all_close;

    fn artifacts_available() -> Option<PathBuf> {
        let dir = artifact_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn xla_engine_matches_native() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = XlaGemm::load(&dir, 128, GemmVariant::Xla, 1).unwrap();
        let nat = NativeGemm::new(1);
        let mut rng = Rng::new(3);
        // Odd sizes exercise padding.
        for (m, k, n) in [(130, 257, 190), (256, 128, 128), (300, 40, 170)] {
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c1 = Mat::zeros(m, n);
            let mut c2 = Mat::zeros(m, n);
            eng.tiled(Layout::Mm, 1.5, &a, &b, 0.0, &mut c1);
            nat.gemm(1.5, &a, &b, 0.0, &mut c2);
            check_all_close(c1.data(), c2.data(), 1e-10, "mm").unwrap();
            // tn: A stored (k×m)
            let at = a.transposed();
            let mut c3 = Mat::zeros(m, n);
            eng.tiled(Layout::Tn, 1.5, &at, &b, 0.0, &mut c3);
            check_all_close(c3.data(), c2.data(), 1e-9, "tn").unwrap();
            // nt: B stored (n×k)
            let bt = b.transposed();
            let mut c4 = Mat::zeros(m, n);
            eng.tiled(Layout::Nt, 1.5, &a, &bt, 0.0, &mut c4);
            check_all_close(c4.data(), c2.data(), 1e-9, "nt").unwrap();
        }
    }

    #[test]
    fn pallas_variant_matches_native() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = match XlaGemm::load(&dir, 128, GemmVariant::Pallas, 1) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping: pallas artifacts not built ({e})");
                return;
            }
        };
        let nat = NativeGemm::new(1);
        let mut rng = Rng::new(5);
        let a = Mat::from_fn(140, 150, |_, _| rng.normal());
        let b = Mat::from_fn(160, 150, |_, _| rng.normal());
        let mut c1 = Mat::zeros(140, 160);
        let mut c2 = Mat::zeros(140, 160);
        eng.gemm_nt(1.0, &a, &b, 0.0, &mut c1);
        nat.gemm_nt(1.0, &a, &b, 0.0, &mut c2);
        check_all_close(c1.data(), c2.data(), 1e-9, "pallas nt").unwrap();
    }

    #[test]
    fn beta_accumulation() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = XlaGemm::load(&dir, 128, GemmVariant::Xla, 1).unwrap();
        let nat = NativeGemm::new(1);
        let mut rng = Rng::new(7);
        let a = Mat::from_fn(129, 131, |_, _| rng.normal());
        let b = Mat::from_fn(131, 133, |_, _| rng.normal());
        let mut c1 = Mat::from_fn(129, 133, |_, _| rng.normal());
        let mut c2 = c1.clone();
        eng.tiled(Layout::Mm, 0.5, &a, &b, 2.0, &mut c1);
        nat.gemm(0.5, &a, &b, 2.0, &mut c2);
        check_all_close(c1.data(), c2.data(), 1e-10, "beta").unwrap();
    }
}
