//! `artifacts/manifest.json` parsing (written by `python/compile/aot.py`).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// e.g. "gemm_nt", "cd_sweep", "cggm_obj".
    pub kind: String,
    /// "pallas" / "xla" where applicable.
    pub variant: Option<String>,
    /// Tile/block size where applicable.
    pub block: Option<usize>,
    /// Entry parameter shapes.
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactEntry>,
}

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse: {0}")]
    Parse(String),
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest, ManifestError> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, ManifestError> {
        let doc = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let arts = doc
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| ManifestError::Parse("missing 'artifacts' object".into()))?;
        let mut entries = BTreeMap::new();
        for (name, entry) in arts {
            let shapes = |key: &str| -> Vec<Vec<usize>> {
                entry
                    .get(key)
                    .and_then(|v| v.as_arr())
                    .map(|items| {
                        items
                            .iter()
                            .filter_map(|it| {
                                it.get("shape").and_then(|s| s.as_arr()).map(|dims| {
                                    dims.iter().filter_map(|d| d.as_usize()).collect()
                                })
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: entry
                        .get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| ManifestError::Parse(format!("{name}: no file")))?
                        .to_string(),
                    kind: entry
                        .get("kind")
                        .and_then(|k| k.as_str())
                        .unwrap_or("unknown")
                        .to_string(),
                    variant: entry
                        .get("variant")
                        .and_then(|v| v.as_str())
                        .map(String::from),
                    block: entry.get("block").and_then(|b| b.as_usize()),
                    inputs: shapes("inputs"),
                    outputs: shapes("outputs"),
                },
            );
        }
        Ok(Manifest { entries })
    }

    /// Find an artifact by kind, optionally filtered by variant and block.
    pub fn find(
        &self,
        kind: &str,
        variant: Option<&str>,
        block: Option<usize>,
    ) -> Option<&ArtifactEntry> {
        self.entries.values().find(|e| {
            e.kind == kind
                && variant.map(|v| e.variant.as_deref() == Some(v)).unwrap_or(true)
                && block.map(|b| e.block == Some(b)).unwrap_or(true)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "gemm_nt_xla_f64_128": {
          "file": "gemm_nt_xla_f64_128.hlo.txt",
          "kind": "gemm_nt", "variant": "xla", "block": 128,
          "inputs": [{"shape": [128,128], "dtype": "f64"},
                     {"shape": [128,128], "dtype": "f64"}],
          "outputs": [{"shape": [128,128], "dtype": "f64"}]
        },
        "cggm_obj_f64": {
          "file": "cggm_obj_f64.hlo.txt", "kind": "cggm_obj",
          "p": 24, "q": 16,
          "inputs": [{"shape": [16,16], "dtype": "f64"}],
          "outputs": [{"shape": [], "dtype": "f64"}]
        }
      },
      "dtype": "f64"
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("gemm_nt", Some("xla"), Some(128)).unwrap();
        assert_eq!(e.file, "gemm_nt_xla_f64_128.hlo.txt");
        assert_eq!(e.inputs[0], vec![128, 128]);
        assert!(m.find("gemm_nt", Some("pallas"), None).is_none());
        let o = m.find("cggm_obj", None, None).unwrap();
        assert!(o.outputs[0].is_empty());
    }

    #[test]
    fn rejects_bad_docs() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
