//! Manifest parsing: the AOT artifact manifest (`artifacts/manifest.json`,
//! written by `python/compile/aot.py`) and the batch *job* manifest
//! consumed by `cggm batch` ([`JobManifest`]).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// e.g. "gemm_nt", "cd_sweep", "cggm_obj".
    pub kind: String,
    /// "pallas" / "xla" where applicable.
    pub variant: Option<String>,
    /// Tile/block size where applicable.
    pub block: Option<usize>,
    /// Entry parameter shapes.
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactEntry>,
}

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse: {0}")]
    Parse(String),
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest, ManifestError> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, ManifestError> {
        let doc = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let arts = doc
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| ManifestError::Parse("missing 'artifacts' object".into()))?;
        let mut entries = BTreeMap::new();
        for (name, entry) in arts {
            let shapes = |key: &str| -> Vec<Vec<usize>> {
                entry
                    .get(key)
                    .and_then(|v| v.as_arr())
                    .map(|items| {
                        items
                            .iter()
                            .filter_map(|it| {
                                it.get("shape").and_then(|s| s.as_arr()).map(|dims| {
                                    dims.iter().filter_map(|d| d.as_usize()).collect()
                                })
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: entry
                        .get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| ManifestError::Parse(format!("{name}: no file")))?
                        .to_string(),
                    kind: entry
                        .get("kind")
                        .and_then(|k| k.as_str())
                        .unwrap_or("unknown")
                        .to_string(),
                    variant: entry
                        .get("variant")
                        .and_then(|v| v.as_str())
                        .map(String::from),
                    block: entry.get("block").and_then(|b| b.as_usize()),
                    inputs: shapes("inputs"),
                    outputs: shapes("outputs"),
                },
            );
        }
        Ok(Manifest { entries })
    }

    /// Find an artifact by kind, optionally filtered by variant and block.
    pub fn find(
        &self,
        kind: &str,
        variant: Option<&str>,
        block: Option<usize>,
    ) -> Option<&ArtifactEntry> {
        self.entries.values().find(|e| {
            e.kind == kind
                && variant.map(|v| e.variant.as_deref() == Some(v)).unwrap_or(true)
                && block.map(|b| e.block == Some(b)).unwrap_or(true)
        })
    }
}

// ------------------------------------------------------------ job manifest

/// A batch job manifest (`cggm batch FILE`): serve-protocol request
/// objects, optionally layered over shared defaults.
///
/// Accepted shapes:
///
/// ```text
/// [ {"op":"load", ...}, {"op":"fit", ...} ]
///
/// {"defaults": {"solver": "alt", "tol": 0.001},
///  "jobs": [ {"op":"load", ...}, {"op":"fit", ...} ]}
/// ```
///
/// Defaults merge *under* each job object (job keys win). Jobs without an
/// `"id"` get their 1-based manifest position, so responses are
/// correlatable and orderable even for terse manifests.
#[derive(Clone, Debug, Default)]
pub struct JobManifest {
    jobs: Vec<Json>,
}

impl JobManifest {
    pub fn load(path: &Path) -> Result<JobManifest, ManifestError> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<JobManifest, ManifestError> {
        let doc = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let (defaults, raw_jobs) = match &doc {
            Json::Arr(items) => (None, items.as_slice()),
            Json::Obj(_) => {
                let jobs = doc
                    .get("jobs")
                    .and_then(|j| j.as_arr())
                    .ok_or_else(|| ManifestError::Parse("missing 'jobs' array".into()))?;
                (doc.get("defaults"), jobs)
            }
            _ => {
                return Err(ManifestError::Parse(
                    "manifest must be an array or an object with 'jobs'".into(),
                ))
            }
        };
        if let Some(d) = defaults {
            if d.as_obj().is_none() {
                return Err(ManifestError::Parse("'defaults' must be an object".into()));
            }
        }
        let mut jobs = Vec::with_capacity(raw_jobs.len());
        for (k, job) in raw_jobs.iter().enumerate() {
            let obj = job.as_obj().ok_or_else(|| {
                ManifestError::Parse(format!("job {} must be an object", k + 1))
            })?;
            let mut merged: BTreeMap<String, Json> = defaults
                .and_then(|d| d.as_obj())
                .cloned()
                .unwrap_or_default();
            for (key, val) in obj {
                merged.insert(key.clone(), val.clone());
            }
            merged
                .entry("id".to_string())
                .or_insert(Json::num((k + 1) as f64));
            jobs.push(Json::Obj(merged));
        }
        Ok(JobManifest { jobs })
    }

    /// The merged request objects, in manifest order.
    pub fn jobs(&self) -> &[Json] {
        &self.jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "gemm_nt_xla_f64_128": {
          "file": "gemm_nt_xla_f64_128.hlo.txt",
          "kind": "gemm_nt", "variant": "xla", "block": 128,
          "inputs": [{"shape": [128,128], "dtype": "f64"},
                     {"shape": [128,128], "dtype": "f64"}],
          "outputs": [{"shape": [128,128], "dtype": "f64"}]
        },
        "cggm_obj_f64": {
          "file": "cggm_obj_f64.hlo.txt", "kind": "cggm_obj",
          "p": 24, "q": 16,
          "inputs": [{"shape": [16,16], "dtype": "f64"}],
          "outputs": [{"shape": [], "dtype": "f64"}]
        }
      },
      "dtype": "f64"
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("gemm_nt", Some("xla"), Some(128)).unwrap();
        assert_eq!(e.file, "gemm_nt_xla_f64_128.hlo.txt");
        assert_eq!(e.inputs[0], vec![128, 128]);
        assert!(m.find("gemm_nt", Some("pallas"), None).is_none());
        let o = m.find("cggm_obj", None, None).unwrap();
        assert!(o.outputs[0].is_empty());
    }

    #[test]
    fn rejects_bad_docs() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn job_manifest_merges_defaults_and_assigns_ids() {
        let m = JobManifest::parse(
            r#"{"defaults": {"solver": "alt", "tol": 0.001},
                "jobs": [
                  {"op": "load", "name": "d", "workload": "chain",
                   "p": 8, "q": 8, "n": 40},
                  {"op": "fit", "dataset": "d", "solver": "prox", "id": 9}
                ]}"#,
        )
        .unwrap();
        assert_eq!(m.jobs().len(), 2);
        let load = &m.jobs()[0];
        assert_eq!(load.get("id").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(load.get("solver").and_then(|v| v.as_str()), Some("alt"));
        let fit = &m.jobs()[1];
        // Explicit values win over defaults; explicit ids are kept.
        assert_eq!(fit.get("solver").and_then(|v| v.as_str()), Some("prox"));
        assert_eq!(fit.get("tol").and_then(|v| v.as_f64()), Some(0.001));
        assert_eq!(fit.get("id").and_then(|v| v.as_usize()), Some(9));
        // A bare array works too.
        let bare = JobManifest::parse(r#"[{"op": "stat"}]"#).unwrap();
        assert_eq!(
            bare.jobs()[0].get("id").and_then(|v| v.as_usize()),
            Some(1)
        );
    }

    #[test]
    fn job_manifest_rejects_malformed_docs() {
        assert!(JobManifest::parse("3").is_err());
        assert!(JobManifest::parse(r#"{"defaults": 1, "jobs": []}"#).is_err());
        assert!(JobManifest::parse(r#"{"jobs": [42]}"#).is_err());
        assert!(JobManifest::parse(r#"{"no_jobs": []}"#).is_err());
    }

    /// Manifests come from disk but may be mangled or adversarial: hostile
    /// bytes must be `Parse` errors, never panics, OOM, or stack overflow.
    #[test]
    fn hostile_manifests_error_cleanly() {
        // Deep-nesting bomb (an abort on the seed parser).
        let bomb = format!("{{\"jobs\": {}1{}}}", "[".repeat(100_000), "]".repeat(100_000));
        assert!(JobManifest::parse(&bomb).is_err());
        assert!(Manifest::parse(&bomb).is_err());
        // Hostile numeric fields: checked extraction drops them instead of
        // saturating (shape dims are filter_map'd; blocks become None).
        let m = Manifest::parse(
            r#"{"artifacts": {"a": {"file": "a.hlo", "block": -1,
                "inputs": [{"shape": [-1, 1e300, 4]}]}}}"#,
        )
        .unwrap();
        let e = m.entries.get("a").unwrap();
        assert_eq!(e.block, None);
        assert_eq!(e.inputs, vec![vec![4]]);
        // Truncated \u escape and non-object jobs are parse errors.
        assert!(JobManifest::parse(r#"{"jobs": [{"name": "\u12"}]}"#).is_err());
        assert!(JobManifest::parse(r#"{"jobs": ["\ud800"]}"#).is_err());
        // Every job always ends up with an id, hostile or not.
        let m = JobManifest::parse(r#"[{"op": "stat"}, {"op": "stat", "id": -1}]"#).unwrap();
        for job in m.jobs() {
            assert!(job.get("id").is_some());
        }
    }
}
