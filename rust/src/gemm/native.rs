//! Native GEMM kernels: BLIS-style packed panels + a register-blocked
//! micro-kernel.
//!
//! Row-major f64 throughout. Large products run through one packed driver:
//! A is packed into MR-row tiles of an MC×KC panel, B into NR-column tiles
//! of a KC×NC panel, and a 4×8 micro-kernel with f64 register accumulators
//! walks the panels — the packing makes every micro-kernel read contiguous
//! and lets LLVM keep the 32 accumulators in vector registers under
//! `-C target-cpu=native`. The transposed layouts (`gemm_tn`, `gemm_nt`)
//! differ **only in their pack routines**, so all three contractions share
//! the same hot loop (and the blocked dense Cholesky, `Ψ = RᵀR/n`, and the
//! screen panels all speed up together).
//!
//! Small products (`m·n·k ≤ SMALL`) keep simple serial kernels — packing
//! overhead dominates below the cache-blocking regime.
//!
//! Parallelism: MC-row bands of C are data-parallel
//! ([`Parallelism::parallel_chunks_mut`]); every C element accumulates its
//! k-terms in the same order regardless of the band split, so results are
//! bitwise-identical across thread counts. Pack buffers are bounded
//! (MC·KC + NC·KC doubles per in-flight band worker, ≈1.1 MiB) and
//! recycled through a small internal pool — engine-internal scratch,
//! deliberately outside the solvers' [`crate::util::membudget::MemBudget`]
//! accounting (like the dataset itself, it is not solver working set; the
//! bound is documented in docs/PERF.md).
//!
//! Serves as (a) the fallback engine when PJRT artifacts are absent,
//! (b) the baseline for the engine-ablation bench, and (c) the building
//! block of the blocked dense Cholesky.

use super::GemmEngine;
use crate::linalg::dense::{axpy, dot, Mat};
use crate::util::threadpool::Parallelism;
use std::sync::Mutex;

/// Micro-kernel tile: MR×NR C block with register accumulators.
const MR: usize = 4;
const NR: usize = 8;
/// Default cache-block sizes: MC×KC packed panel of A (L2-resident), KC×NC
/// packed panel of B (streamed through L1 in NR-column tiles). MC is a
/// multiple of MR and NC of NR so tiles never straddle a panel edge.
/// Per-instance overrides ([`NativeGemm::with_blocks`], `autotuned`) must
/// keep the packed-panel footprint under this default's, so
/// [`NativeGemm::scratch_bytes_bound`] stays a valid bound for every engine.
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 512;
/// Below this flop-volume (`m·n·k`), packing costs more than it saves.
const SMALL: usize = 1 << 14;
/// Pack-pool retention cap in f64 elements (~4 MiB): enough for two full
/// A+B panel sets in flight, a hard bound on idle engine-internal scratch.
const POOL_MAX_ELEMS: usize = 4 * (MC * KC + NC * KC);

/// Native engine with a configurable thread count (paper §Parallelization).
pub struct NativeGemm {
    par: Parallelism,
    /// Cache-block sizes for this instance (defaults MC/KC/NC; see
    /// [`Self::with_blocks`] for the invariants).
    mc: usize,
    kc: usize,
    nc: usize,
    /// Recycled pack buffers (byte-bounded; see module docs).
    pool: Mutex<Vec<Vec<f64>>>,
}

impl NativeGemm {
    pub fn new(threads: usize) -> Self {
        Self::with_blocks(threads, MC, KC, NC)
    }

    /// Engine with explicit cache-block sizes (config key `gemm_blocks` /
    /// CLI `--gemm-blocks mc,kc,nc`). Invariants: `mc` a multiple of MR and
    /// `nc` of NR (tiles never straddle a panel edge), and the packed-panel
    /// footprint `(mc+nc)·kc` no larger than the default's so
    /// [`Self::scratch_bytes_bound`] remains valid for every instance.
    /// Results stay bitwise deterministic for a *fixed* block choice (the
    /// band split does not affect summation order), but different `kc`
    /// groupings legitimately round differently at ~1e-15.
    pub fn with_blocks(threads: usize, mc: usize, kc: usize, nc: usize) -> Self {
        assert!(mc >= MR && mc % MR == 0, "mc must be a positive multiple of {MR}");
        assert!(nc >= NR && nc % NR == 0, "nc must be a positive multiple of {NR}");
        assert!(kc >= 1, "kc must be >= 1");
        assert!(
            (mc + nc) * kc <= (MC + NC) * KC,
            "block footprint (mc+nc)*kc = {} exceeds the scratch bound {}",
            (mc + nc) * kc,
            (MC + NC) * KC
        );
        NativeGemm {
            par: Parallelism::new(threads),
            mc,
            kc,
            nc,
            pool: Mutex::new(Vec::new()),
        }
    }

    /// One-shot construction-time autotune (config key `gemm_autotune` / CLI
    /// `--gemm-autotune`): time a warm mid-sized `gemm_nt` — the Gram-product
    /// shape every statistics build uses — for each candidate block triple
    /// and keep the fastest. Candidates all satisfy the `with_blocks`
    /// footprint invariant. Cost is a few tens of MFLOPs once per engine;
    /// the probe result is machine-dependent by design, so benches that
    /// need run-to-run reproducibility should pass explicit blocks instead.
    pub fn autotuned(threads: usize) -> Self {
        const CANDIDATES: [(usize, usize, usize); 4] = [
            (MC, KC, NC),
            (128, 128, 512),
            (32, 512, 256),
            (96, 192, 384),
        ];
        let (m, k, n) = (160, 320, 320);
        let mut rng = crate::util::rng::Rng::new(7);
        let a = Mat::from_fn(m, k, |_, _| rng.normal());
        let b = Mat::from_fn(n, k, |_, _| rng.normal());
        let mut c = Mat::zeros(m, n);
        let mut best = CANDIDATES[0];
        let mut best_t = f64::INFINITY;
        for &(mc, kc, nc) in &CANDIDATES {
            let eng = Self::with_blocks(threads, mc, kc, nc);
            eng.gemm_nt(1.0, &a, &b, 0.0, &mut c); // warm pool + caches
            let mut t = f64::INFINITY;
            for _ in 0..2 {
                let start = std::time::Instant::now();
                eng.gemm_nt(1.0, &a, &b, 0.0, &mut c);
                t = t.min(start.elapsed().as_secs_f64());
            }
            if t < best_t {
                best_t = t;
                best = (mc, kc, nc);
            }
        }
        Self::with_blocks(threads, best.0, best.1, best.2)
    }

    /// This instance's cache-block sizes `(mc, kc, nc)`.
    pub fn blocks(&self) -> (usize, usize, usize) {
        (self.mc, self.kc, self.nc)
    }

    /// Worst-case engine-internal scratch in bytes for `threads` workers:
    /// one A + one B pack panel per in-flight band worker, plus the pool's
    /// idle retention cap. Valid for every instance — `with_blocks` rejects
    /// block triples whose panels exceed the default footprint. This scratch
    /// is outside [`crate::util::membudget`] accounting (the `GemmEngine`
    /// trait carries no budget handle and the workspace arena is
    /// single-owner); callers that need an airtight memory plan can register
    /// this bound against their budget up front.
    pub fn scratch_bytes_bound(threads: usize) -> usize {
        let f = std::mem::size_of::<f64>();
        threads.max(1) * (MC * KC + NC * KC) * f + POOL_MAX_ELEMS * f
    }

    /// Best-fit checkout. Recycled contents are NOT zeroed: every slot the
    /// micro-kernel reads is overwritten by the pack routines (edge padding
    /// included), so the memset would be pure wasted bandwidth.
    fn take_buf(&self, len: usize) -> Vec<f64> {
        let mut pool = self.pool.lock().expect("pack pool lock");
        let mut best: Option<(usize, usize)> = None;
        for (k, b) in pool.iter().enumerate() {
            let cap = b.capacity();
            if cap >= len && best.map_or(true, |(_, bc)| cap < bc) {
                best = Some((k, cap));
            }
        }
        if let Some((k, _)) = best {
            let mut b = pool.swap_remove(k);
            if b.len() < len {
                b.resize(len, 0.0);
            } else {
                b.truncate(len);
            }
            return b;
        }
        drop(pool);
        vec![0.0; len]
    }

    fn put_buf(&self, b: Vec<f64>) {
        let mut pool = self.pool.lock().expect("pack pool lock");
        let pooled: usize = pool.iter().map(|p| p.capacity()).sum();
        if pooled + b.capacity() <= POOL_MAX_ELEMS {
            pool.push(b);
        }
    }

    /// The shared packed driver. `kind` selects the pack routines (i.e. the
    /// logical transposition); everything downstream of packing is
    /// layout-agnostic. C has already been beta-scaled by the caller.
    #[allow(clippy::too_many_arguments)]
    fn packed(
        &self,
        kind: PackKind,
        alpha: f64,
        a: &Mat,
        b: &Mat,
        c: &mut Mat,
        n: usize,
        kdim: usize,
    ) {
        if alpha == 0.0 || kdim == 0 {
            return;
        }
        let (mc, kc, nc) = (self.mc, self.kc, self.nc);
        // mc-row bands of C are disjoint; each band worker packs its own A
        // panel (band-local) and B panel (shared values, re-packed per band
        // — an O(k·n) cost against the band's O(mc·n·k) compute, ≈1/mc).
        self.par.parallel_chunks_mut(c.data_mut(), mc * n, |band, cband| {
            let i0 = band * mc;
            let ib = cband.len() / n;
            let mut apack = self.take_buf(mc * kc);
            let mut bpack = self.take_buf(nc * kc);
            for p0 in (0..kdim).step_by(kc) {
                let kb = kc.min(kdim - p0);
                match kind {
                    PackKind::Tn => pack_a_tn(a, i0, ib, p0, kb, &mut apack),
                    _ => pack_a_nn(a, i0, ib, p0, kb, &mut apack),
                }
                for j0 in (0..n).step_by(nc) {
                    let jb = nc.min(n - j0);
                    match kind {
                        PackKind::Nt => pack_b_nt(b, p0, kb, j0, jb, &mut bpack),
                        _ => pack_b_nn(b, p0, kb, j0, jb, &mut bpack),
                    }
                    let mtiles = ib.div_ceil(MR);
                    let ntiles = jb.div_ceil(NR);
                    for t in 0..mtiles {
                        let atile = &apack[t * kb * MR..(t + 1) * kb * MR];
                        let iw = MR.min(ib - t * MR);
                        for u in 0..ntiles {
                            let btile = &bpack[u * kb * NR..(u + 1) * kb * NR];
                            let jw = NR.min(jb - u * NR);
                            let acc = micro_4x8(kb, atile, btile);
                            for (ir, acc_row) in acc.iter().enumerate().take(iw) {
                                let crow =
                                    &mut cband[(t * MR + ir) * n + j0 + u * NR..][..jw];
                                for (jr, cv) in crow.iter_mut().enumerate() {
                                    *cv += alpha * acc_row[jr];
                                }
                            }
                        }
                    }
                }
            }
            self.put_buf(apack);
            self.put_buf(bpack);
        });
    }
}

/// Which logical transposition the pack routines realize.
#[derive(Clone, Copy)]
enum PackKind {
    /// C = A·B.
    Nn,
    /// C = Aᵀ·B (A stored k×m).
    Tn,
    /// C = A·Bᵀ (B stored n×k).
    Nt,
}

/// The register-blocked inner kernel: an MR×NR block of AᵖBᵖ over `kb`
/// packed depth steps. Accumulates in locals so the `k` loop is a pure
/// FMA sweep; padding (zeros packed beyond the edge) keeps it branch-free.
#[inline(always)]
fn micro_4x8(kb: usize, a: &[f64], b: &[f64]) -> [[f64; NR]; MR] {
    let mut acc = [[0.0f64; NR]; MR];
    for k in 0..kb {
        let ak = &a[k * MR..k * MR + MR];
        let bk = &b[k * NR..k * NR + NR];
        for ir in 0..MR {
            let av = ak[ir];
            for (jr, acc_v) in acc[ir].iter_mut().enumerate() {
                *acc_v += av * bk[jr];
            }
        }
    }
    acc
}

/// Pack rows `i0..i0+ib`, depth `p0..p0+kb` of row-major A into MR-row,
/// k-major tiles (zero-padded past `ib`).
fn pack_a_nn(a: &Mat, i0: usize, ib: usize, p0: usize, kb: usize, buf: &mut [f64]) {
    for t in 0..ib.div_ceil(MR) {
        let base = t * kb * MR;
        for ir in 0..MR {
            let i = i0 + t * MR + ir;
            if i < i0 + ib {
                let arow = &a.row(i)[p0..p0 + kb];
                for (k, &v) in arow.iter().enumerate() {
                    buf[base + k * MR + ir] = v;
                }
            } else {
                for k in 0..kb {
                    buf[base + k * MR + ir] = 0.0;
                }
            }
        }
    }
}

/// Same tile layout for the transposed A of `gemm_tn` (stored k×m): the
/// pack absorbs the transpose — reads are contiguous MR-chunks of A's rows.
fn pack_a_tn(a: &Mat, i0: usize, ib: usize, p0: usize, kb: usize, buf: &mut [f64]) {
    for t in 0..ib.div_ceil(MR) {
        let base = t * kb * MR;
        let iw = MR.min(ib - t * MR);
        for k in 0..kb {
            let arow = a.row(p0 + k);
            let dst = &mut buf[base + k * MR..base + (k + 1) * MR];
            for (ir, d) in dst.iter_mut().enumerate() {
                *d = if ir < iw { arow[i0 + t * MR + ir] } else { 0.0 };
            }
        }
    }
}

/// Pack depth `p0..p0+kb`, columns `j0..j0+jb` of row-major B into NR-col,
/// k-major tiles (zero-padded past `jb`).
fn pack_b_nn(b: &Mat, p0: usize, kb: usize, j0: usize, jb: usize, buf: &mut [f64]) {
    for u in 0..jb.div_ceil(NR) {
        let base = u * kb * NR;
        let jw = NR.min(jb - u * NR);
        for k in 0..kb {
            let brow = b.row(p0 + k);
            let dst = &mut buf[base + k * NR..base + (k + 1) * NR];
            for (jr, d) in dst.iter_mut().enumerate() {
                *d = if jr < jw { brow[j0 + u * NR + jr] } else { 0.0 };
            }
        }
    }
}

/// Same tile layout for the transposed B of `gemm_nt` (stored n×k): rows of
/// B are contiguous in the depth dimension.
fn pack_b_nt(b: &Mat, p0: usize, kb: usize, j0: usize, jb: usize, buf: &mut [f64]) {
    for u in 0..jb.div_ceil(NR) {
        let base = u * kb * NR;
        let jw = NR.min(jb - u * NR);
        for jr in 0..NR {
            if jr < jw {
                let brow = &b.row(j0 + u * NR + jr)[p0..p0 + kb];
                for (k, &v) in brow.iter().enumerate() {
                    buf[base + k * NR + jr] = v;
                }
            } else {
                for k in 0..kb {
                    buf[base + k * NR + jr] = 0.0;
                }
            }
        }
    }
}

impl GemmEngine for NativeGemm {
    fn gemm(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        assert_eq!(b.rows(), k, "gemm shape mismatch");
        assert_eq!((c.rows(), c.cols()), (m, n), "gemm output shape mismatch");
        scale_c(beta, c);
        if m * n * k <= SMALL {
            return small_nn(alpha, a, b, c);
        }
        self.packed(PackKind::Nn, alpha, a, b, c, n, k);
    }

    fn gemm_tn(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (k, m) = (a.rows(), a.cols());
        let n = b.cols();
        assert_eq!(b.rows(), k, "gemm_tn shape mismatch");
        assert_eq!((c.rows(), c.cols()), (m, n), "gemm_tn output shape mismatch");
        scale_c(beta, c);
        if m * n * k <= SMALL {
            return small_tn(alpha, a, b, c);
        }
        self.packed(PackKind::Tn, alpha, a, b, c, n, k);
    }

    fn gemm_nt(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.rows();
        assert_eq!(b.cols(), k, "gemm_nt shape mismatch");
        assert_eq!((c.rows(), c.cols()), (m, n), "gemm_nt output shape mismatch");
        scale_c(beta, c);
        if m * n * k <= SMALL {
            return small_nt(alpha, a, b, c);
        }
        // The packed path handles the transpose in pack_b_nt — no O(n·k)
        // materialized transpose (which the pre-packing kernel needed to
        // escape its dot-product layout).
        self.packed(PackKind::Nt, alpha, a, b, c, n, k);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

// ------------------------------------------------------------ small kernels
//
// Below the packing threshold: serial, allocation-free, axpy/dot based.

fn small_nn(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    for i in 0..a.rows() {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (kk, &aik) in arow.iter().enumerate() {
            let x = alpha * aik;
            if x != 0.0 {
                axpy(x, b.row(kk), crow);
            }
        }
    }
}

fn small_tn(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    // C[i,:] += alpha·A[t,i]·B[t,:] — rank-1 panels over t.
    for t in 0..a.rows() {
        let arow = a.row(t);
        let brow = b.row(t);
        for (i, &ati) in arow.iter().enumerate() {
            let x = alpha * ati;
            if x != 0.0 {
                axpy(x, brow, c.row_mut(i));
            }
        }
    }
}

fn small_nt(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    // C[i,j] += alpha·dot(A[i,:], B[j,:]) — both rows contiguous.
    for i in 0..a.rows() {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv += alpha * dot(arow, b.row(j));
        }
    }
}

fn scale_c(beta: f64, c: &mut Mat) {
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        c.scale(beta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::reference_gemm;
    use crate::util::testing::{check_all_close, property};

    #[test]
    fn gemm_matches_reference() {
        property(60, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c = Mat::from_fn(m, n, |_, _| rng.normal());
            let mut want = c.clone();
            let (alpha, beta) = (rng.normal(), rng.normal());
            NativeGemm::new(1).gemm(alpha, &a, &b, beta, &mut c);
            reference_gemm(alpha, &a, &b, beta, &mut want);
            check_all_close(c.data(), want.data(), 1e-11, "gemm")
        });
    }

    #[test]
    fn gemm_tn_matches_reference() {
        property(60, |rng| {
            let k = 1 + rng.below(40);
            let m = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::from_fn(k, m, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c = Mat::from_fn(m, n, |_, _| rng.normal());
            let mut want = c.clone();
            let at = a.transposed();
            let (alpha, beta) = (rng.normal(), rng.normal());
            NativeGemm::new(1).gemm_tn(alpha, &a, &b, beta, &mut c);
            reference_gemm(alpha, &at, &b, beta, &mut want);
            check_all_close(c.data(), want.data(), 1e-11, "gemm_tn")
        });
    }

    /// Shapes chosen to cross every packing edge: m not a multiple of MR,
    /// n not a multiple of NR, k spanning multiple KC panels, n spanning
    /// multiple NC panels.
    #[test]
    fn packed_path_matches_reference_across_panel_edges() {
        let mut rng = crate::util::rng::Rng::new(42);
        for (m, k, n) in [
            (67, 300, 530),
            (64, 257, 512),
            (5, 600, 9),
            (130, 31, 17),
            (33, 513, 100),
        ] {
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c = Mat::from_fn(m, n, |_, _| rng.normal());
            let mut want = c.clone();
            NativeGemm::new(2).gemm(0.7, &a, &b, -1.3, &mut c);
            reference_gemm(0.7, &a, &b, -1.3, &mut want);
            check_all_close(c.data(), want.data(), 1e-10, &format!("{m}x{k}x{n}"))
                .unwrap();
            // And the transposed layouts on the same shapes.
            let at = a.transposed();
            let mut ct = Mat::zeros(m, n);
            NativeGemm::new(2).gemm_tn(1.0, &at, &b, 0.0, &mut ct);
            let mut want_t = Mat::zeros(m, n);
            reference_gemm(1.0, &a, &b, 0.0, &mut want_t);
            check_all_close(ct.data(), want_t.data(), 1e-10, "tn edge").unwrap();
            let bt = b.transposed();
            let mut cn = Mat::zeros(m, n);
            NativeGemm::new(2).gemm_nt(1.0, &a, &bt, 0.0, &mut cn);
            check_all_close(cn.data(), want_t.data(), 1e-10, "nt edge").unwrap();
        }
    }

    #[test]
    fn multithreaded_agrees_with_single() {
        property(20, |rng| {
            let m = 1 + rng.below(100);
            let k = 1 + rng.below(60);
            let n = 1 + rng.below(60);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c1 = Mat::zeros(m, n);
            let mut c4 = Mat::zeros(m, n);
            NativeGemm::new(1).gemm(1.0, &a, &b, 0.0, &mut c1);
            NativeGemm::new(4).gemm(1.0, &a, &b, 0.0, &mut c4);
            check_all_close(c1.data(), c4.data(), 1e-12, "threads")
        });
    }

    #[test]
    fn scratch_bound_is_monotone_in_threads() {
        let b1 = NativeGemm::scratch_bytes_bound(1);
        let b4 = NativeGemm::scratch_bytes_bound(4);
        assert!(b1 > 0 && b4 > b1);
        // Pool retention cap is part of the bound.
        assert!(b1 >= POOL_MAX_ELEMS * 8);
    }

    /// Non-default block triples stay correct across packing edges — the
    /// invariant the autotuner relies on to swap triples freely.
    #[test]
    fn custom_blocks_match_reference() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (m, k, n) = (67, 300, 530);
        let a = Mat::from_fn(m, k, |_, _| rng.normal());
        let b = Mat::from_fn(k, n, |_, _| rng.normal());
        let mut want = Mat::zeros(m, n);
        reference_gemm(1.0, &a, &b, 0.0, &mut want);
        for (mc, kc, nc) in [(128, 128, 512), (32, 512, 256), (96, 192, 384), (4, 1, 8)] {
            let eng = NativeGemm::with_blocks(2, mc, kc, nc);
            assert_eq!(eng.blocks(), (mc, kc, nc));
            let mut c = Mat::zeros(m, n);
            eng.gemm(1.0, &a, &b, 0.0, &mut c);
            check_all_close(c.data(), want.data(), 1e-10, &format!("{mc},{kc},{nc}"))
                .unwrap();
        }
    }

    #[test]
    fn autotuned_engine_is_valid_and_correct() {
        let eng = NativeGemm::autotuned(2);
        let (mc, kc, nc) = eng.blocks();
        assert!(mc % MR == 0 && nc % NR == 0 && kc >= 1);
        assert!((mc + nc) * kc <= (MC + NC) * KC);
        let mut rng = crate::util::rng::Rng::new(3);
        let a = Mat::from_fn(20, 50, |_, _| rng.normal());
        let b = Mat::from_fn(50, 30, |_, _| rng.normal());
        let mut c = Mat::zeros(20, 30);
        let mut want = Mat::zeros(20, 30);
        eng.gemm(1.0, &a, &b, 0.0, &mut c);
        reference_gemm(1.0, &a, &b, 0.0, &mut want);
        check_all_close(c.data(), want.data(), 1e-10, "autotuned").unwrap();
    }

    #[test]
    #[should_panic(expected = "block footprint")]
    fn oversized_blocks_rejected() {
        let _ = NativeGemm::with_blocks(1, 256, 512, 512);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Mat::from_fn(30, 12, |_, _| rng.normal());
        let mut c = Mat::zeros(12, 12);
        NativeGemm::new(1).gemm_tn(1.0, &a, &a, 0.0, &mut c);
        for i in 0..12 {
            assert!(c[(i, i)] >= 0.0);
            for j in 0..12 {
                assert!((c[(i, j)] - c[(j, i)]).abs() < 1e-10);
            }
        }
    }
}

#[cfg(test)]
mod nt_tests {
    use super::*;
    use crate::gemm::{reference_gemm, GemmEngine};
    use crate::util::testing::{check_all_close, property};

    #[test]
    fn gemm_nt_matches_reference() {
        property(60, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(n, k, |_, _| rng.normal());
            let mut c = Mat::from_fn(m, n, |_, _| rng.normal());
            let mut want = c.clone();
            let bt = b.transposed();
            let (alpha, beta) = (rng.normal(), rng.normal());
            NativeGemm::new(1).gemm_nt(alpha, &a, &b, beta, &mut c);
            reference_gemm(alpha, &a, &bt, beta, &mut want);
            check_all_close(c.data(), want.data(), 1e-11, "gemm_nt")
        });
    }

    #[test]
    fn gemm_nt_multithreaded_agrees() {
        property(15, |rng| {
            let m = 1 + rng.below(120);
            let k = 1 + rng.below(50);
            let n = 1 + rng.below(50);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(n, k, |_, _| rng.normal());
            let mut c1 = Mat::zeros(m, n);
            let mut c4 = Mat::zeros(m, n);
            NativeGemm::new(1).gemm_nt(1.0, &a, &b, 0.0, &mut c1);
            NativeGemm::new(4).gemm_nt(1.0, &a, &b, 0.0, &mut c4);
            check_all_close(c1.data(), c4.data(), 1e-12, "nt threads")
        });
    }
}
