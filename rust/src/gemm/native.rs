//! Native blocked GEMM kernels.
//!
//! Row-major, cache-blocked, with the inner loop expressed as contiguous
//! row-axpys so LLVM autovectorizes it under `-C target-cpu=native`. Serves
//! as (a) the fallback engine when PJRT artifacts are absent, (b) the
//! baseline for the engine-ablation bench, and (c) the building block of the
//! blocked dense Cholesky.

use super::GemmEngine;
use crate::linalg::dense::{axpy, Mat};
use crate::util::threadpool::Parallelism;

/// Cache-block sizes: MC×KC panel of A, KC×NC panel of B.
const MC: usize = 64;
const KC: usize = 256;

/// Native engine with a configurable thread count (paper §Parallelization).
pub struct NativeGemm {
    par: Parallelism,
}

impl NativeGemm {
    pub fn new(threads: usize) -> Self {
        NativeGemm {
            par: Parallelism::new(threads),
        }
    }
}

impl GemmEngine for NativeGemm {
    fn gemm(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        assert_eq!(b.rows(), k, "gemm shape mismatch");
        assert_eq!((c.rows(), c.cols()), (m, n), "gemm output shape mismatch");
        scale_c(beta, c);
        // Parallelize across MC-row bands of C; each band is disjoint.
        self.par.parallel_chunks_mut(c.data_mut(), MC * n, |band, cband| {
            let i0 = band * MC;
            let ib = cband.len() / n;
            for k0 in (0..k).step_by(KC) {
                let kb = KC.min(k - k0);
                for di in 0..ib {
                    let i = i0 + di;
                    let arow = &a.row(i)[k0..k0 + kb];
                    let crow = &mut cband[di * n..(di + 1) * n];
                    for (dk, &aik) in arow.iter().enumerate() {
                        let x = alpha * aik;
                        if x != 0.0 {
                            axpy(x, b.row(k0 + dk), crow);
                        }
                    }
                }
            }
        });
    }

    fn gemm_tn(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (k, m) = (a.rows(), a.cols());
        let n = b.cols();
        assert_eq!(b.rows(), k, "gemm_tn shape mismatch");
        assert_eq!((c.rows(), c.cols()), (m, n), "gemm_tn output shape mismatch");
        scale_c(beta, c);
        // C[i, :] += alpha * A[t, i] * B[t, :]  — rank-1 panels over t.
        // Parallel over MC-row bands of C (bands index columns of A).
        self.par.parallel_chunks_mut(c.data_mut(), MC * n, |band, cband| {
            let i0 = band * MC;
            let ib = cband.len() / n;
            for t0 in (0..k).step_by(KC) {
                let tb = KC.min(k - t0);
                for dt in 0..tb {
                    let t = t0 + dt;
                    let arow = &a.row(t)[i0..i0 + ib];
                    let brow = b.row(t);
                    for (di, &ati) in arow.iter().enumerate() {
                        let x = alpha * ati;
                        if x != 0.0 {
                            axpy(x, brow, &mut cband[di * n..(di + 1) * n]);
                        }
                    }
                }
            }
        });
    }

    fn gemm_nt(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.rows();
        assert_eq!(b.cols(), k, "gemm_nt shape mismatch");
        assert_eq!((c.rows(), c.cols()), (m, n), "gemm_nt output shape mismatch");
        // Perf (EXPERIMENTS.md §Perf iter 1): the dot-based kernel below
        // runs ~2.5 GF/s (horizontal reductions defeat vectorization); the
        // axpy-based `gemm` kernel reaches ~8 GF/s. For compute-heavy
        // shapes, paying an O(n·k) transpose to use it is a large net win.
        if m * n * k > (1 << 18) {
            let bt = b.transposed();
            return self.gemm(alpha, a, &bt, beta, c);
        }
        scale_c(beta, c);
        // C[i,j] += alpha * dot(A[i,:], B[j,:]) — both rows contiguous.
        // Parallel over row bands of C; j blocked for B-panel reuse in cache.
        const NBJ: usize = 32;
        self.par.parallel_chunks_mut(c.data_mut(), MC * n, |band, cband| {
            let i0 = band * MC;
            let ib = cband.len() / n;
            for j0 in (0..n).step_by(NBJ) {
                let jb = NBJ.min(n - j0);
                for di in 0..ib {
                    let arow = a.row(i0 + di);
                    let crow = &mut cband[di * n..(di + 1) * n];
                    for dj in 0..jb {
                        let j = j0 + dj;
                        crow[j] += alpha * crate::linalg::dense::dot(arow, b.row(j));
                    }
                }
            }
        });
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

fn scale_c(beta: f64, c: &mut Mat) {
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        c.scale(beta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::reference_gemm;
    use crate::util::testing::{check_all_close, property};

    #[test]
    fn gemm_matches_reference() {
        property(60, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c = Mat::from_fn(m, n, |_, _| rng.normal());
            let mut want = c.clone();
            let (alpha, beta) = (rng.normal(), rng.normal());
            NativeGemm::new(1).gemm(alpha, &a, &b, beta, &mut c);
            reference_gemm(alpha, &a, &b, beta, &mut want);
            check_all_close(c.data(), want.data(), 1e-11, "gemm")
        });
    }

    #[test]
    fn gemm_tn_matches_reference() {
        property(60, |rng| {
            let k = 1 + rng.below(40);
            let m = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::from_fn(k, m, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c = Mat::from_fn(m, n, |_, _| rng.normal());
            let mut want = c.clone();
            let at = a.transposed();
            let (alpha, beta) = (rng.normal(), rng.normal());
            NativeGemm::new(1).gemm_tn(alpha, &a, &b, beta, &mut c);
            reference_gemm(alpha, &at, &b, beta, &mut want);
            check_all_close(c.data(), want.data(), 1e-11, "gemm_tn")
        });
    }

    #[test]
    fn multithreaded_agrees_with_single() {
        property(20, |rng| {
            let m = 1 + rng.below(100);
            let k = 1 + rng.below(60);
            let n = 1 + rng.below(60);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let mut c1 = Mat::zeros(m, n);
            let mut c4 = Mat::zeros(m, n);
            NativeGemm::new(1).gemm(1.0, &a, &b, 0.0, &mut c1);
            NativeGemm::new(4).gemm(1.0, &a, &b, 0.0, &mut c4);
            check_all_close(c1.data(), c4.data(), 1e-12, "threads")
        });
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Mat::from_fn(30, 12, |_, _| rng.normal());
        let mut c = Mat::zeros(12, 12);
        NativeGemm::new(1).gemm_tn(1.0, &a, &a, 0.0, &mut c);
        for i in 0..12 {
            assert!(c[(i, i)] >= 0.0);
            for j in 0..12 {
                assert!((c[(i, j)] - c[(j, i)]).abs() < 1e-10);
            }
        }
    }
}

#[cfg(test)]
mod nt_tests {
    use super::*;
    use crate::gemm::{reference_gemm, GemmEngine};
    use crate::util::testing::{check_all_close, property};

    #[test]
    fn gemm_nt_matches_reference() {
        property(60, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(n, k, |_, _| rng.normal());
            let mut c = Mat::from_fn(m, n, |_, _| rng.normal());
            let mut want = c.clone();
            let bt = b.transposed();
            let (alpha, beta) = (rng.normal(), rng.normal());
            NativeGemm::new(1).gemm_nt(alpha, &a, &b, beta, &mut c);
            reference_gemm(alpha, &a, &bt, beta, &mut want);
            check_all_close(c.data(), want.data(), 1e-11, "gemm_nt")
        });
    }

    #[test]
    fn gemm_nt_multithreaded_agrees() {
        property(15, |rng| {
            let m = 1 + rng.below(120);
            let k = 1 + rng.below(50);
            let n = 1 + rng.below(50);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(n, k, |_, _| rng.normal());
            let mut c1 = Mat::zeros(m, n);
            let mut c4 = Mat::zeros(m, n);
            NativeGemm::new(1).gemm_nt(1.0, &a, &b, 0.0, &mut c1);
            NativeGemm::new(4).gemm_nt(1.0, &a, &b, 0.0, &mut c4);
            check_all_close(c1.data(), c4.data(), 1e-12, "nt threads")
        });
    }
}
