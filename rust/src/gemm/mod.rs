//! GEMM engine abstraction — the flop hot spot of the paper
//! (`Ψ = RᵀR/n`, `S_xx` tiles, `Xᵀ(XV)` active-set screens, blocked Cholesky
//! updates all reduce to GEMM / Gram products).
//!
//! Two engines implement [`GemmEngine`]:
//! - [`native::NativeGemm`] — packed-panel (BLIS-style) thread-parallel
//!   Rust with a register-blocked 4×8 micro-kernel;
//! - [`crate::runtime::XlaGemm`] — tiled execution through AOT-compiled
//!   JAX/Pallas HLO artifacts on the PJRT CPU client (L1/L2 of the stack).
//!
//! The runtime engine falls back to native below a crossover size (PJRT call
//! overhead; measured in `bench_gemm`), so solvers just call the trait.

pub mod native;

use crate::linalg::dense::Mat;
use std::sync::Arc;

/// Abstract dense-matmul provider.
pub trait GemmEngine: Send + Sync {
    /// C = alpha * A·B + beta * C. Shapes: A (m×k), B (k×n), C (m×n).
    fn gemm(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat);

    /// C = alpha * Aᵀ·B + beta * C. Shapes: A (k×m), B (k×n), C (m×n).
    ///
    /// This is the paper's Gram form (`Ψ = RᵀR`, `S_xx = XᵀX/n`); engines
    /// implement it directly to avoid materializing transposes.
    fn gemm_tn(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat);

    /// C = alpha * A·Bᵀ + beta * C. Shapes: A (m×k), B (n×k), C (m×n).
    ///
    /// The row-Gram form: matrices stored features-by-samples (`xt`, `yt`,
    /// `rt`) produce covariance blocks as contiguous row dots.
    fn gemm_nt(&self, alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat);

    /// Engine label for logs/benches.
    fn name(&self) -> &'static str;
}

/// Shared handle used throughout the solvers.
pub type Engine = Arc<dyn GemmEngine>;

/// Default engine: native kernels, single thread.
pub fn default_engine() -> Engine {
    Arc::new(native::NativeGemm::new(1))
}

/// Symmetric rank-k: C = alpha·AᵀA + beta·C (convenience over `gemm_tn`).
pub fn gram(engine: &dyn GemmEngine, alpha: f64, a: &Mat, beta: f64, c: &mut Mat) {
    engine.gemm_tn(alpha, a, a, beta, c);
}

#[cfg(test)]
pub(crate) fn reference_gemm(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows());
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()));
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for k in 0..a.cols() {
                s += a[(i, k)] * b[(k, j)];
            }
            c[(i, j)] = alpha * s + beta * c[(i, j)];
        }
    }
}
