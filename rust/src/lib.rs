//! # cggm — large-scale sparse Conditional Gaussian Graphical Model estimation
//!
//! Reproduction of McCarter & Kim (2015), *Large-Scale Optimization Algorithms
//! for Sparse Conditional Gaussian Graphical Models*, as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the optimization coordinator — the paper's
//!   contribution. Three solvers ([`solvers::newton_cd`] baseline,
//!   [`solvers::alt_newton_cd`] = Algorithm 1, [`solvers::alt_newton_bcd`] =
//!   Algorithm 2), plus every substrate they need: dense/sparse linear
//!   algebra, conjugate gradients, Cholesky factorizations, graph
//!   clustering (METIS substitute), active-set screening, line search,
//!   memory-budgeted column caches, data generators, metrics, experiment
//!   harness.
//! - **L2/L1 (python/, build-time only)**: JAX model of the CGGM objective and
//!   Pallas GEMM/Gram/CD-sweep kernels, AOT-lowered to HLO text artifacts.
//! - **runtime**: PJRT CPU client ([`runtime`]) loading those artifacts so the
//!   flop hot spots (the paper's `O(npq + nq²)` Gram products) can execute
//!   through XLA from the Rust hot path.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod bench;
pub mod cggm;
pub mod coordinator;
pub mod datagen;
pub mod experiments;
pub mod gemm;
pub mod graph;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod storage;
pub mod util;
