//! Chain-graph workload (paper §5.1):
//!
//! "the true sparse parameter Λ is set with Λ_{i,i-1} = 1 and Λ_{i,i} = 2.25
//! and the ground truth Θ is set with Θ_{i,i} = 1. […] one set of chain graph
//! experiments where p = q, and another with an additional q irrelevant
//! features unconnected to any outputs, so that p = 2q."

use super::sampler::{gaussian_x, sample_dataset};
use super::Problem;
use crate::cggm::CggmModel;
use crate::linalg::sparse::SpRowMat;
use crate::util::rng::Rng;

/// Ground-truth chain Λ* (q×q).
pub fn chain_lambda(q: usize) -> SpRowMat {
    let mut lambda = SpRowMat::zeros(q, q);
    for i in 0..q {
        lambda.set(i, i, 2.25);
        if i > 0 {
            lambda.set_sym(i, i - 1, 1.0);
        }
    }
    lambda
}

/// Generate the chain problem. `p ≥ q`; inputs beyond the first q are the
/// "irrelevant features unconnected to any outputs".
pub fn generate(p: usize, q: usize, n: usize, seed: u64) -> Problem {
    assert!(p >= q, "chain workload requires p ≥ q (got p={p}, q={q})");
    let mut truth = CggmModel::init(p, q);
    truth.lambda = chain_lambda(q);
    for i in 0..q {
        truth.theta.set(i, i, 1.0);
    }
    let mut rng = Rng::new(seed);
    let data = sample_dataset(&truth, n, &mut rng, gaussian_x);
    Problem { truth, data }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_truth_pattern() {
        let prob = generate(10, 5, 8, 1);
        assert_eq!(prob.p(), 10);
        assert_eq!(prob.q(), 5);
        assert_eq!(prob.n(), 8);
        assert_eq!(prob.truth.lambda_edges(), 4);
        assert_eq!(prob.truth.theta_nnz(), 5);
        assert_eq!(prob.truth.lambda.get(3, 3), 2.25);
        assert_eq!(prob.truth.lambda.get(3, 2), 1.0);
        // Irrelevant inputs have empty Θ rows.
        assert!(prob.truth.theta.row(7).is_empty());
    }

    #[test]
    fn lambda_is_positive_definite() {
        let lam = chain_lambda(50);
        assert!(crate::linalg::chol_sparse::SparseChol::factor(&lam, false, usize::MAX).is_ok());
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(6, 6, 5, 42);
        let b = generate(6, 6, 5, 42);
        assert_eq!(a.data.yt().data(), b.data.yt().data());
        let c = generate(6, 6, 5, 43);
        assert_ne!(a.data.yt().data(), c.data.yt().data());
    }
}
