//! Genomic (eQTL) data simulator — the substitution for the paper's private
//! asthma dataset (§5.2: 442,440 SNPs × 10,256 expressions × 171 individuals).
//!
//! Structure preserved (DESIGN.md §7):
//! - **X**: SNP genotypes in {0,1,2}, minor-allele frequency ~ U(0.05, 0.5),
//!   organized in LD blocks — neighboring SNPs are correlated through a
//!   shared latent haplotype signal. Columns standardized, so p ≫ q with
//!   strongly correlated input groups (what makes S_xx rows expensive and
//!   clustered).
//! - **Λ***: clustered gene co-expression network (modules), reusing the
//!   clustered-graph generator.
//! - **Θ***: *cis* effects (a gene regulated by a few nearby SNPs) plus a few
//!   *trans* hotspot SNPs that each regulate many genes — producing the
//!   row-sparse Θ with non-empty-row count p̃ ≪ p that §4.2 exploits.
//!
//! At the paper's shape (p ≈ 4.4·10⁵) the dense `S_xx` alone is 8·p² ≈
//! 1.5 TiB — far past any single-machine budget — so paper-scale runs of
//! this workload require `--stat-mode tiled` (the row-sparse Θ means a
//! screened solve touches a small fraction of the tile grid; see
//! docs/PERF.md). The LD-block structure is also the adversarial case for
//! the tile cache: correlated neighboring SNPs concentrate reads inside
//! block-diagonal tiles, which is exactly the access pattern the LRU keeps
//! resident.

use super::cluster_graph::{clustered_lambda, ClusterOptions};
use super::sampler::{sample_dataset, sample_dataset_to_panels};
use super::Problem;
use crate::cggm::CggmModel;
use crate::linalg::sparse::SpRowMat;
use crate::util::rng::Rng;

/// Simulator constants.
#[derive(Clone, Copy, Debug)]
pub struct GenomicOptions {
    /// SNPs per LD block.
    pub ld_block: usize,
    /// Within-block genotype correlation strength (0..1).
    pub ld_rho: f64,
    /// cis SNPs per gene.
    pub cis_per_gene: usize,
    /// Number of trans hotspot SNPs.
    pub hotspots: usize,
    /// Genes regulated per hotspot.
    pub genes_per_hotspot: usize,
    /// Gene-module size for Λ*.
    pub module_size: usize,
    /// Effect size of eQTL edges.
    pub effect: f64,
}

impl Default for GenomicOptions {
    fn default() -> Self {
        GenomicOptions {
            ld_block: 20,
            ld_rho: 0.7,
            cis_per_gene: 2,
            hotspots: 10,
            genes_per_hotspot: 30,
            module_size: 50,
            effect: 0.8,
        }
    }
}

/// Ground truth drawn from `rng` (shared by the resident and streamed
/// generators so both see identical draws for a given seed).
fn build_truth(p: usize, q: usize, rng: &mut Rng, opts: &GenomicOptions) -> CggmModel {
    let mut truth = CggmModel::init(p, q);
    truth.lambda = clustered_lambda(
        q,
        rng,
        &ClusterOptions {
            cluster_size: opts.module_size,
            avg_degree: 8,
            ..Default::default()
        },
    );
    truth.theta = eqtl_theta(p, q, rng, opts);
    truth
}

/// Genotype model: per individual, per LD block, a latent haplotype dosage
/// h ~ N(0,1); SNP i has genotype Binomial(2, sigmoid-ish pi) where pi mixes
/// its MAF with the block signal. MAFs are drawn from `rng` here, so calling
/// this advances the generator state identically for every consumer.
fn genotype_sampler(
    p: usize,
    rng: &mut Rng,
    opts: &GenomicOptions,
) -> impl FnMut(&mut Rng, &mut [f64]) {
    let mafs: Vec<f64> = (0..p).map(|_| rng.uniform_in(0.05, 0.5)).collect();
    // Standardization constants under Hardy–Weinberg: mean 2·maf,
    // var ≈ 2·maf·(1-maf) (approximate; post-standardized empirically below).
    let ld_block = opts.ld_block.max(1);
    let ld_rho = opts.ld_rho.clamp(0.0, 0.99);
    move |rng: &mut Rng, x: &mut [f64]| {
        let nblocks = x.len().div_ceil(ld_block);
        for b in 0..nblocks {
            let h = rng.normal();
            let lo = b * ld_block;
            let hi = ((b + 1) * ld_block).min(x.len());
            for (i, xi) in x[lo..hi].iter_mut().enumerate() {
                let snp = lo + i;
                let maf = mafs[snp];
                // shift allele probability by the block haplotype
                let z = maf.ln() - (1.0 - maf).ln()
                    + ld_rho * h
                    + (1.0 - ld_rho) * rng.normal();
                let prob = 1.0 / (1.0 + (-z).exp());
                let geno = rng.binomial(2, prob) as f64;
                let mean = 2.0 * maf;
                let sd = (2.0 * maf * (1.0 - maf)).sqrt().max(0.05);
                *xi = (geno - mean) / sd;
            }
        }
    }
}

/// Generate the genomic problem.
pub fn generate(p: usize, q: usize, n: usize, seed: u64, opts: &GenomicOptions) -> Problem {
    let mut rng = Rng::new(seed);
    let truth = build_truth(p, q, &mut rng, opts);
    let draw_x = genotype_sampler(p, &mut rng, opts);
    let data = sample_dataset(&truth, n, &mut rng, draw_x);
    Problem { truth, data }
}

/// Generate the genomic workload straight to a sharded `CGGMPAN1` panel file
/// — the paper-scale path (p ≈ 4.4·10⁵ SNPs would need ~560 GB resident for
/// the asthma shape before a single solve): peak memory is one shard plus
/// the truth model. Identical RNG schedule to [`generate`], so the written
/// samples equal `generate(..).data` bit-for-bit; returns the ground truth.
pub fn generate_to_panels(
    p: usize,
    q: usize,
    n: usize,
    seed: u64,
    opts: &GenomicOptions,
    path: &std::path::Path,
    shard_cols: usize,
) -> std::io::Result<CggmModel> {
    let mut rng = Rng::new(seed);
    let truth = build_truth(p, q, &mut rng, opts);
    let draw_x = genotype_sampler(p, &mut rng, opts);
    sample_dataset_to_panels(&truth, n, &mut rng, draw_x, path, shard_cols)?;
    Ok(truth)
}

/// cis + trans-hotspot eQTL map.
fn eqtl_theta(p: usize, q: usize, rng: &mut Rng, opts: &GenomicOptions) -> SpRowMat {
    let mut theta = SpRowMat::zeros(p, q);
    // cis: gene j regulated by a few SNPs near position j·(p/q).
    let stride = (p as f64 / q as f64).max(1.0);
    for j in 0..q {
        let center = ((j as f64 * stride) as usize).min(p - 1);
        for _ in 0..opts.cis_per_gene {
            let offset = rng.below(2 * opts.ld_block + 1);
            let i = (center + offset).saturating_sub(opts.ld_block).min(p - 1);
            theta.set(i, j, opts.effect * if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
        }
    }
    // trans hotspots.
    let hotspots = rng.sample_distinct(p, opts.hotspots.min(p));
    for &h in &hotspots {
        for _ in 0..opts.genes_per_hotspot {
            let j = rng.below(q);
            theta.set(h, j, opts.effect * if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
        }
    }
    theta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_is_row_sparse() {
        let prob = generate(800, 60, 30, 7, &GenomicOptions::default());
        let ptilde = prob.truth.theta.nonempty_rows();
        assert!(ptilde < 800 / 2, "Θ should be row-sparse, p̃ = {ptilde}");
        assert!(ptilde > 0);
        assert_eq!(prob.data.n(), 30);
    }

    #[test]
    fn genotypes_standardized_and_ld_correlated() {
        let prob = generate(200, 20, 400, 11, &GenomicOptions::default());
        let d = &prob.data;
        // Standardized-ish: mean near 0, sd near 1.
        let mut worst_mean = 0.0f64;
        for i in 0..d.p() {
            let row = d.xt().row(i);
            let mean: f64 = row.iter().sum::<f64>() / row.len() as f64;
            worst_mean = worst_mean.max(mean.abs());
        }
        assert!(worst_mean < 0.6, "genotype means too far from 0: {worst_mean}");
        // Adjacent SNPs in a block correlate more than cross-block pairs.
        let within = d.sxx(0, 1).abs() + d.sxx(2, 3).abs() + d.sxx(4, 5).abs();
        let across = d.sxx(0, 150).abs() + d.sxx(1, 100).abs() + d.sxx(2, 60).abs();
        assert!(
            within > across,
            "LD structure missing: within={within} across={across}"
        );
    }

    /// The tile cache must agree with direct Gram reads on this generator's
    /// LD-correlated, standardized design — the p ≫ q shape tiled mode
    /// exists for (chain/cluster equivalence lives in the integration
    /// suite; this pins the datagen-specific input statistics).
    #[test]
    fn tiled_reads_match_direct_gram_on_ld_design() {
        use crate::cggm::tiles::TileStore;
        use crate::gemm::native::NativeGemm;
        use crate::util::membudget::MemBudget;
        let prob = generate(90, 12, 60, 13, &GenomicOptions::default());
        let d = &prob.data;
        let eng = NativeGemm::new(1);
        let ts = TileStore::new(d, &eng, MemBudget::unlimited(), 16);
        for &(i, j) in &[(0usize, 1usize), (5, 40), (83, 2), (89, 89)] {
            assert!(
                (ts.sxx_entry(i, j) - d.sxx(i, j)).abs() < 1e-12,
                "S_xx({i},{j}) disagrees through the tile cache"
            );
        }
        for &(i, j) in &[(0usize, 0usize), (47, 11), (89, 3)] {
            assert!(
                (ts.sxy_entry(i, j) - d.sxy(i, j)).abs() < 1e-12,
                "S_xy({i},{j}) disagrees through the tile cache"
            );
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(50, 10, 5, 3, &GenomicOptions::default());
        let b = generate(50, 10, 5, 3, &GenomicOptions::default());
        assert_eq!(a.data.xt().data(), b.data.xt().data());
    }

    #[test]
    fn streamed_generation_matches_resident() {
        // The out-of-core generator must produce the same truth and the same
        // samples as the resident one — the whole point of sharing the RNG
        // schedule through build_truth/genotype_sampler.
        let want = generate(60, 8, 17, 21, &GenomicOptions::default());
        let path = std::env::temp_dir().join(format!(
            "cggm_genomic_stream_{}.pan",
            std::process::id()
        ));
        let truth =
            generate_to_panels(60, 8, 17, 21, &GenomicOptions::default(), &path, 5).unwrap();
        assert_eq!(truth.theta.nnz(), want.truth.theta.nnz());
        let got = crate::coordinator::load_dataset(&path).unwrap();
        assert_eq!(got.xt().data(), want.data.xt().data());
        assert_eq!(got.yt().data(), want.data.yt().data());
        let _ = std::fs::remove_file(path);
    }
}
