//! Synthetic data generators reproducing the paper's §5 workloads.
//!
//! - [`chain`]: chain-graph Λ*, diagonal Θ* (§5.1, Figures 1, 3, 5);
//! - [`cluster_graph`]: random clustered Λ* + hub-sparse Θ* (§5.1, Figure 2);
//! - [`genomic`]: SNP/expression simulator substituting the private asthma
//!   dataset (§5.2, Table 1, Figure 4) — see DESIGN.md §7;
//! - [`energy`]: wind-farm forecasting generator (Wytock & Kolter's
//!   motivating domain) for the `energy_forecast` example;
//! - [`sampler`]: exact CGGM sampling `y|x ~ N(-Λ⁻¹Θᵀx, Λ⁻¹)` shared by all.

pub mod chain;
pub mod cluster_graph;
pub mod energy;
pub mod genomic;
pub mod sampler;

use crate::cggm::{CggmModel, Dataset};

/// A generated problem: ground truth + sampled data.
pub struct Problem {
    pub truth: CggmModel,
    pub data: Dataset,
}

impl Problem {
    pub fn p(&self) -> usize {
        self.truth.p()
    }
    pub fn q(&self) -> usize {
        self.truth.q()
    }
    pub fn n(&self) -> usize {
        self.data.n()
    }
}

/// Workload families from the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Chain Λ, Θ = I; p = q.
    Chain,
    /// Chain Λ with q extra irrelevant inputs; p = 2q.
    ChainIrrelevant,
    /// Random clustered Λ (Fig. 2 family).
    Cluster,
    /// Genomic simulator (Table 1 / Fig. 4 family).
    Genomic,
}

impl Workload {
    pub fn parse(s: &str) -> Option<Workload> {
        match s {
            "chain" => Some(Workload::Chain),
            "chain2" | "chain-irrelevant" => Some(Workload::ChainIrrelevant),
            "cluster" | "random" => Some(Workload::Cluster),
            "genomic" => Some(Workload::Genomic),
            _ => None,
        }
    }
}

/// Generate a problem by workload family with the paper's defaults.
pub fn generate(w: Workload, p: usize, q: usize, n: usize, seed: u64) -> Problem {
    match w {
        Workload::Chain | Workload::ChainIrrelevant => chain::generate(p, q, n, seed),
        Workload::Cluster => cluster_graph::generate(p, q, n, seed, &Default::default()),
        Workload::Genomic => genomic::generate(p, q, n, seed, &Default::default()),
    }
}
