//! Wind-power forecasting generator — the application domain that motivated
//! sparse Gaussian CRFs in Wytock & Kolter (2013), used by the
//! `energy_forecast` example.
//!
//! q wind farms on a √q×√q grid; outputs are next-hour power deviations with
//! a spatial neighbor network Λ* (adjacent farms co-vary). Inputs are, per
//! farm, `lags` autoregressive wind-speed features plus a few global weather
//! regime features, so p = q·lags + extras and Θ* maps each farm's own lags
//! (plus upwind neighbors) to its output — banded, row-sparse.

use super::sampler::sample_dataset;
use super::Problem;
use crate::cggm::CggmModel;
use crate::linalg::sparse::SpRowMat;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct EnergyOptions {
    /// Autoregressive lags per farm.
    pub lags: usize,
    /// Global weather-regime features.
    pub globals: usize,
    /// Spatial coupling weight in Λ*.
    pub coupling: f64,
}

impl Default for EnergyOptions {
    fn default() -> Self {
        EnergyOptions {
            lags: 3,
            globals: 8,
            coupling: 0.4,
        }
    }
}

/// Number of inputs for a given farm count.
pub fn input_dim(q: usize, opts: &EnergyOptions) -> usize {
    q * opts.lags + opts.globals
}

/// Generate the wind-farm problem with q farms.
pub fn generate(q: usize, n: usize, seed: u64, opts: &EnergyOptions) -> Problem {
    let p = input_dim(q, opts);
    let side = (q as f64).sqrt().ceil() as usize;
    let mut rng = Rng::new(seed);
    let mut truth = CggmModel::init(p, q);

    // Λ*: grid adjacency.
    let mut lambda = SpRowMat::zeros(q, q);
    for j in 0..q {
        let (r, c) = (j / side, j % side);
        if c + 1 < side && j + 1 < q {
            lambda.set_sym(j, j + 1, opts.coupling);
        }
        if r + 1 < side && j + side < q {
            lambda.set_sym(j, j + side, opts.coupling);
        }
    }
    for j in 0..q {
        let rowsum: f64 = lambda.row(j).iter().map(|e| e.1.abs()).sum();
        lambda.set(j, j, rowsum + 1.0);
    }
    truth.lambda = lambda;

    // Θ*: own lags with decaying weights + first lag of the east/south
    // neighbors (upwind transport) + a couple of globals.
    for j in 0..q {
        for l in 0..opts.lags {
            truth.theta.set(j * opts.lags + l, j, 0.8 / (l + 1) as f64);
        }
        let (r, c) = (j / side, j % side);
        if c + 1 < side && j + 1 < q {
            truth.theta.set((j + 1) * opts.lags, j, 0.3);
        }
        if r + 1 < side && j + side < q {
            truth.theta.set((j + side) * opts.lags, j, 0.2);
        }
        // A global regime feature per row of the grid.
        let g = q * opts.lags + (r % opts.globals.max(1));
        truth.theta.set(g, j, 0.25);
    }

    // Inputs: lag features share a farm-level AR signal; globals are N(0,1).
    let lags = opts.lags;
    let nglob = opts.globals;
    let draw_x = move |rng: &mut Rng, x: &mut [f64]| {
        let nf = (x.len() - nglob) / lags;
        for f in 0..nf {
            let base = rng.normal();
            for l in 0..lags {
                // Lagged copies decorrelate with distance.
                let w = 0.7f64.powi(l as i32);
                x[f * lags + l] = w * base + (1.0 - w * w).sqrt() * rng.normal();
            }
        }
        for g in 0..nglob {
            x[x.len() - nglob + g] = rng.normal();
        }
    };
    let data = sample_dataset(&truth, n, &mut rng, draw_x);
    Problem { truth, data }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_grid_structure() {
        let opts = EnergyOptions::default();
        let prob = generate(16, 25, 5, &opts);
        assert_eq!(prob.q(), 16);
        assert_eq!(prob.p(), 16 * 3 + 8);
        // Farm 0 couples to farm 1 (east) and farm 4 (south) on a 4×4 grid.
        assert!(prob.truth.lambda.get(0, 1) > 0.0);
        assert!(prob.truth.lambda.get(0, 4) > 0.0);
        assert_eq!(prob.truth.lambda.get(0, 5), 0.0);
        // Own-lag mapping present.
        assert!(prob.truth.theta.get(0, 0) > 0.0);
    }

    #[test]
    fn lag_features_are_correlated() {
        let prob = generate(9, 800, 6, &EnergyOptions::default());
        let d = &prob.data;
        // lag0 and lag1 of farm 0 correlate strongly; farm 0 lag0 vs farm 5
        // lag0 do not.
        let c01 = d.sxx(0, 1);
        let c_far = d.sxx(0, 5 * 3);
        assert!(c01 > 0.4, "lag correlation {c01}");
        assert!(c_far.abs() < 0.2, "cross-farm correlation {c_far}");
    }
}
