//! Random clustered-graph workload (paper §5.1, following BigQUIC's GGM
//! generator):
//!
//! "we set the true Λ to a graph with clusters of nodes of size 250 and with
//! 90% of edges connecting randomly-selected nodes within clusters. We set
//! the number of edges so that the average degree of each node is 10, with
//! edge weights set to 1. We then set the diagonal values so that Λ is
//! positive definite. To set the sparse patterns for Θ, we randomly select
//! 100√p input variables as having edges to at least one output and
//! distribute total 10q edges among those selected inputs […] edge weights 1."
//!
//! Cluster size, degree, and hub constants are configurable so scaled-down
//! runs keep the same *structure* at smaller q (DESIGN.md §7).

use super::sampler::{gaussian_x, sample_dataset};
use super::Problem;
use crate::cggm::CggmModel;
use crate::linalg::sparse::SpRowMat;
use crate::util::rng::Rng;

/// Generator constants (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct ClusterOptions {
    pub cluster_size: usize,
    /// Average node degree in Λ.
    pub avg_degree: usize,
    /// Fraction of edges kept within clusters.
    pub within_frac: f64,
    /// Θ hubs = hub_coeff·√p inputs with edges.
    pub hub_coeff: f64,
    /// Θ edges = theta_edges_per_q·q.
    pub theta_edges_per_q: usize,
    /// Λ edge weight.
    pub weight: f64,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            cluster_size: 250,
            avg_degree: 10,
            within_frac: 0.9,
            hub_coeff: 100.0,
            theta_edges_per_q: 10,
            weight: 1.0,
        }
    }
}

/// Ground-truth clustered Λ* (q×q), positive definite by diagonal dominance.
pub fn clustered_lambda(q: usize, rng: &mut Rng, opts: &ClusterOptions) -> SpRowMat {
    let mut lambda = SpRowMat::zeros(q, q);
    let csize = opts.cluster_size.min(q).max(2);
    let nclusters = q.div_ceil(csize);
    let target_edges = q * opts.avg_degree / 2;
    let mut added = 0usize;
    let mut guard = 0usize;
    while added < target_edges && guard < 50 * target_edges {
        guard += 1;
        let (i, j) = if rng.bernoulli(opts.within_frac) {
            // Within a random cluster.
            let c = rng.below(nclusters);
            let lo = c * csize;
            let hi = ((c + 1) * csize).min(q);
            if hi - lo < 2 {
                continue;
            }
            (lo + rng.below(hi - lo), lo + rng.below(hi - lo))
        } else {
            (rng.below(q), rng.below(q))
        };
        if i == j || lambda.get(i, j) != 0.0 {
            continue;
        }
        lambda.set_sym(i, j, opts.weight);
        added += 1;
    }
    // Diagonal: strict dominance ⇒ PD.
    for i in 0..q {
        let rowsum: f64 = lambda.row(i).iter().map(|e| e.1.abs()).sum();
        lambda.set(i, i, rowsum + 1.0);
    }
    lambda
}

/// Ground-truth hub-sparse Θ* (p×q).
pub fn hub_theta(p: usize, q: usize, rng: &mut Rng, opts: &ClusterOptions) -> SpRowMat {
    let mut theta = SpRowMat::zeros(p, q);
    let nhubs = ((opts.hub_coeff * (p as f64).sqrt()) as usize).clamp(1, p);
    let hubs = rng.sample_distinct(p, nhubs);
    let target = opts.theta_edges_per_q * q;
    let mut added = 0;
    let mut guard = 0;
    while added < target && guard < 50 * target + 100 {
        guard += 1;
        let i = hubs[rng.below(nhubs)];
        let j = rng.below(q);
        if theta.get(i, j) != 0.0 {
            continue;
        }
        theta.set(i, j, opts.weight);
        added += 1;
    }
    theta
}

/// Generate the clustered random-graph problem.
pub fn generate(p: usize, q: usize, n: usize, seed: u64, opts: &ClusterOptions) -> Problem {
    let mut rng = Rng::new(seed);
    let mut truth = CggmModel::init(p, q);
    truth.lambda = clustered_lambda(q, &mut rng, opts);
    truth.theta = hub_theta(p, q, &mut rng, opts);
    let data = sample_dataset(&truth, n, &mut rng, gaussian_x);
    Problem { truth, data }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> ClusterOptions {
        ClusterOptions {
            cluster_size: 25,
            hub_coeff: 3.0,
            ..Default::default()
        }
    }

    #[test]
    fn lambda_structure() {
        let mut rng = Rng::new(3);
        let q = 200;
        let opts = small_opts();
        let lam = clustered_lambda(q, &mut rng, &opts);
        assert!(lam.is_symmetric(0.0));
        // Average degree ≈ 10.
        let edges: usize = (0..q)
            .map(|i| lam.row(i).iter().filter(|&&(j, _)| j > i).count())
            .sum();
        let avg_deg = 2.0 * edges as f64 / q as f64;
        assert!((avg_deg - 10.0).abs() < 1.5, "avg degree {avg_deg}");
        // Mostly within-cluster edges.
        let mut within = 0usize;
        for i in 0..q {
            for &(j, _) in lam.row(i) {
                if j > i && i / 25 == j / 25 {
                    within += 1;
                }
            }
        }
        assert!(
            within as f64 / edges as f64 > 0.8,
            "within fraction {}",
            within as f64 / edges as f64
        );
        // PD check.
        assert!(crate::linalg::chol_sparse::SparseChol::factor(&lam, true, usize::MAX).is_ok());
    }

    #[test]
    fn theta_hub_structure() {
        let mut rng = Rng::new(4);
        let (p, q) = (400, 100);
        let opts = small_opts();
        let th = hub_theta(p, q, &mut rng, &opts);
        let nhubs_expected = (3.0 * (p as f64).sqrt()) as usize;
        assert!(th.nonempty_rows() <= nhubs_expected);
        assert_eq!(th.nnz(), opts.theta_edges_per_q * q);
    }

    #[test]
    fn generate_end_to_end() {
        let prob = generate(60, 40, 20, 9, &small_opts());
        assert_eq!(prob.data.n(), 20);
        assert_eq!(prob.data.p(), 60);
        assert_eq!(prob.data.q(), 40);
        assert!(prob.data.yt().frob_norm() > 0.0);
    }
}
