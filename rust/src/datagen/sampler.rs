//! Exact sampling from a CGGM.
//!
//! Under the paper's density `p(y|x) ∝ exp{-yᵀΛy - 2xᵀΘy}` with the
//! log-likelihood of Eq. (1), the consistent sampling model is
//! `y = -Λ⁻¹Θᵀx + ε`, `ε ~ N(0, Λ⁻¹)`: at the ground truth,
//! `E[S_yy] = Σ* + Ψ*` and `E[S_xy] = -S_xxΘ*Λ*⁻¹`, which zero the gradients
//! (Eq. 3) exactly — verified by `tests::truth_is_near_stationary`.
//!
//! `ε` is drawn via the sparse Cholesky of Λ: if PᵀΛP = LLᵀ then
//! `ε = P L⁻ᵀ w`, `w ~ N(0, I)`.

use crate::cggm::{CggmModel, Dataset};
use crate::linalg::chol_sparse::SparseChol;
use crate::linalg::dense::Mat;
use crate::util::rng::Rng;

/// Sample n (x, y) pairs in feature-major column blocks of at most
/// `block_cols` samples, handing each completed block to `sink`. The
/// streaming core behind [`sample_dataset`] (one block) and
/// [`sample_dataset_to_panels`] (one shard per block): per-sample RNG draws
/// are identical for every blocking, so all of them produce bit-identical
/// data for a given seed — blocking only bounds resident memory.
pub fn sample_dataset_blocks(
    truth: &CggmModel,
    n: usize,
    rng: &mut Rng,
    mut draw_x: impl FnMut(&mut Rng, &mut [f64]),
    block_cols: usize,
    mut sink: impl FnMut(&Mat, &Mat) -> std::io::Result<()>,
) -> std::io::Result<()> {
    let (p, q) = (truth.p(), truth.q());
    let chol = SparseChol::factor(&truth.lambda, true, usize::MAX)
        .expect("ground-truth Λ must be positive definite");
    let bc = block_cols.max(1);
    let mut x = vec![0.0; p];
    let mut w = vec![0.0; q];
    let mut s = 0usize;
    while s < n {
        let m = bc.min(n - s);
        let mut xt = Mat::zeros(p, m);
        let mut yt = Mat::zeros(q, m);
        for k in 0..m {
            draw_x(rng, &mut x);
            for (i, xi) in x.iter().enumerate() {
                xt[(i, k)] = *xi;
            }
            // t = Θᵀ x (sparse).
            let mut t = vec![0.0; q];
            for i in 0..p {
                let xi = x[i];
                if xi == 0.0 {
                    continue;
                }
                for &(j, v) in truth.theta.row(i) {
                    t[j] += v * xi;
                }
            }
            // mean = -Λ⁻¹ t.
            let mean = chol.solve(&t);
            // ε = P L⁻ᵀ w.
            for wi in w.iter_mut() {
                *wi = rng.normal();
            }
            let eps = chol.sample_transform(&w);
            for j in 0..q {
                yt[(j, k)] = -mean[j] + eps[j];
            }
        }
        sink(&xt, &yt)?;
        s += m;
    }
    Ok(())
}

/// Sample n (x, y) pairs given ground-truth parameters and an input sampler.
pub fn sample_dataset(
    truth: &CggmModel,
    n: usize,
    rng: &mut Rng,
    draw_x: impl FnMut(&mut Rng, &mut [f64]),
) -> Dataset {
    let (p, q) = (truth.p(), truth.q());
    let mut xt = Mat::zeros(p, n);
    let mut yt = Mat::zeros(q, n);
    let mut at = 0usize;
    sample_dataset_blocks(truth, n, rng, draw_x, n.max(1), |xb, yb| {
        for i in 0..p {
            xt.row_mut(i)[at..at + xb.cols()].copy_from_slice(xb.row(i));
        }
        for j in 0..q {
            yt.row_mut(j)[at..at + yb.cols()].copy_from_slice(yb.row(j));
        }
        at += xb.cols();
        Ok(())
    })
    .expect("in-memory sink cannot fail");
    Dataset::new(xt, yt)
}

/// Sample n (x, y) pairs straight into a sharded `CGGMPAN1` panel file
/// (`shard_cols` samples per shard) without ever materializing the full
/// dataset — the paper-scale datagen path: peak memory is one shard, and the
/// written file loads resident (`coordinator::load_dataset`) or binds
/// out-of-core (`Dataset::open_disk`). Same per-sample RNG order as
/// [`sample_dataset`], so the file contents equal the in-memory dataset for
/// a given seed.
pub fn sample_dataset_to_panels(
    truth: &CggmModel,
    n: usize,
    rng: &mut Rng,
    draw_x: impl FnMut(&mut Rng, &mut [f64]),
    path: &std::path::Path,
    shard_cols: usize,
) -> std::io::Result<()> {
    let mut w = crate::storage::PanelWriter::create(path, truth.p(), truth.q())?;
    sample_dataset_blocks(truth, n, rng, draw_x, shard_cols, |xb, yb| {
        w.append_block(xb, yb)
    })?;
    w.finish()
}

/// Standard normal inputs (the synthetic experiments' X).
pub fn gaussian_x(rng: &mut Rng, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi = rng.normal();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::gemm::GemmEngine;
    use crate::linalg::sparse::SpRowMat;

    fn small_truth() -> CggmModel {
        let q = 6;
        let p = 8;
        let mut m = CggmModel::init(p, q);
        m.lambda = SpRowMat::zeros(q, q);
        for i in 0..q {
            m.lambda.set(i, i, 2.25);
            if i > 0 {
                m.lambda.set_sym(i, i - 1, 1.0);
            }
        }
        for i in 0..q {
            m.theta.set(i, i, 1.0);
        }
        m
    }

    #[test]
    fn sample_moments_match_model() {
        let truth = small_truth();
        let mut rng = Rng::new(77);
        let n = 40_000;
        let data = sample_dataset(&truth, n, &mut rng, gaussian_x);
        let eng = NativeGemm::new(1);
        // E[S_yy] = Σ* + Σ*Θ*ᵀS_xxΘ*Σ* with S_xx → I (x standard normal):
        // = Σ + ΣΘᵀΘΣ.
        let lam_d = truth.lambda.to_dense();
        let chol = crate::linalg::chol_dense::DenseChol::factor(&lam_d, &eng).unwrap();
        let sigma = chol.inverse(&eng);
        let th = truth.theta.to_dense();
        let mut ts = Mat::zeros(truth.p(), truth.q());
        eng.gemm(1.0, &th, &sigma, 0.0, &mut ts);
        let mut want = sigma.clone();
        eng.gemm_tn(1.0, &ts, &ts, 1.0, &mut want);
        let syy = data.syy_dense(&eng);
        let err = syy.max_abs_diff(&want);
        assert!(err < 0.15, "S_yy deviates from model: {err}");
        // E[S_xy] = -Θ*Σ* (with S_xx = I).
        let sxy = data.sxy_dense(&eng);
        let mut want_xy = Mat::zeros(truth.p(), truth.q());
        eng.gemm(-1.0, &th, &sigma, 0.0, &mut want_xy);
        let err2 = sxy.max_abs_diff(&want_xy);
        assert!(err2 < 0.1, "S_xy deviates: {err2}");
    }

    #[test]
    fn streamed_panel_sampling_is_bit_identical() {
        // The blocking must not perturb the per-sample RNG order: a sharded
        // on-disk generation equals the in-memory dataset bit-for-bit.
        let truth = small_truth();
        let n = 23;
        let mut rng = Rng::new(91);
        let want = sample_dataset(&truth, n, &mut rng, gaussian_x);
        let path = std::env::temp_dir().join(format!(
            "cggm_sampler_stream_{}.pan",
            std::process::id()
        ));
        let mut rng2 = Rng::new(91);
        sample_dataset_to_panels(&truth, n, &mut rng2, gaussian_x, &path, 7).unwrap();
        let got = crate::coordinator::load_dataset(&path).unwrap();
        assert_eq!(got.xt().data(), want.xt().data());
        assert_eq!(got.yt().data(), want.yt().data());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn truth_is_near_stationary() {
        // The smooth gradient at the truth should vanish as n grows —
        // validates the sampling convention against the paper's likelihood.
        let truth = small_truth();
        let mut rng = Rng::new(5);
        let data = sample_dataset(&truth, 60_000, &mut rng, gaussian_x);
        let eng = NativeGemm::new(1);
        let obj = crate::cggm::Objective::new(&data, 0.0, 0.0);
        let (_, _, factor, rt) = obj.eval(&truth, &eng).unwrap();
        let sigma = factor.inverse_dense(&eng);
        let psi = obj.psi_dense(&sigma, &rt, &eng);
        let gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
        let gt = obj.grad_theta_dense(&sigma, &rt, &eng);
        assert!(gl.max_abs() < 0.1, "∇Λ at truth = {}", gl.max_abs());
        assert!(gt.max_abs() < 0.1, "∇Θ at truth = {}", gt.max_abs());
    }
}
