//! Exact sampling from a CGGM.
//!
//! Under the paper's density `p(y|x) ∝ exp{-yᵀΛy - 2xᵀΘy}` with the
//! log-likelihood of Eq. (1), the consistent sampling model is
//! `y = -Λ⁻¹Θᵀx + ε`, `ε ~ N(0, Λ⁻¹)`: at the ground truth,
//! `E[S_yy] = Σ* + Ψ*` and `E[S_xy] = -S_xxΘ*Λ*⁻¹`, which zero the gradients
//! (Eq. 3) exactly — verified by `tests::truth_is_near_stationary`.
//!
//! `ε` is drawn via the sparse Cholesky of Λ: if PᵀΛP = LLᵀ then
//! `ε = P L⁻ᵀ w`, `w ~ N(0, I)`.

use crate::cggm::{CggmModel, Dataset};
use crate::linalg::chol_sparse::SparseChol;
use crate::linalg::dense::Mat;
use crate::util::rng::Rng;

/// Sample n (x, y) pairs given ground-truth parameters and an input sampler.
pub fn sample_dataset(
    truth: &CggmModel,
    n: usize,
    rng: &mut Rng,
    mut draw_x: impl FnMut(&mut Rng, &mut [f64]),
) -> Dataset {
    let (p, q) = (truth.p(), truth.q());
    let chol = SparseChol::factor(&truth.lambda, true, usize::MAX)
        .expect("ground-truth Λ must be positive definite");
    let mut xt = Mat::zeros(p, n);
    let mut yt = Mat::zeros(q, n);
    let mut x = vec![0.0; p];
    let mut w = vec![0.0; q];
    for k in 0..n {
        draw_x(rng, &mut x);
        for (i, xi) in x.iter().enumerate() {
            xt[(i, k)] = *xi;
        }
        // t = Θᵀ x (sparse).
        let mut t = vec![0.0; q];
        for i in 0..p {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for &(j, v) in truth.theta.row(i) {
                t[j] += v * xi;
            }
        }
        // mean = -Λ⁻¹ t.
        let mean = chol.solve(&t);
        // ε = P L⁻ᵀ w.
        for wi in w.iter_mut() {
            *wi = rng.normal();
        }
        let eps = chol.sample_transform(&w);
        for j in 0..q {
            yt[(j, k)] = -mean[j] + eps[j];
        }
    }
    Dataset::new(xt, yt)
}

/// Standard normal inputs (the synthetic experiments' X).
pub fn gaussian_x(rng: &mut Rng, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi = rng.normal();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::gemm::GemmEngine;
    use crate::linalg::sparse::SpRowMat;

    fn small_truth() -> CggmModel {
        let q = 6;
        let p = 8;
        let mut m = CggmModel::init(p, q);
        m.lambda = SpRowMat::zeros(q, q);
        for i in 0..q {
            m.lambda.set(i, i, 2.25);
            if i > 0 {
                m.lambda.set_sym(i, i - 1, 1.0);
            }
        }
        for i in 0..q {
            m.theta.set(i, i, 1.0);
        }
        m
    }

    #[test]
    fn sample_moments_match_model() {
        let truth = small_truth();
        let mut rng = Rng::new(77);
        let n = 40_000;
        let data = sample_dataset(&truth, n, &mut rng, gaussian_x);
        let eng = NativeGemm::new(1);
        // E[S_yy] = Σ* + Σ*Θ*ᵀS_xxΘ*Σ* with S_xx → I (x standard normal):
        // = Σ + ΣΘᵀΘΣ.
        let lam_d = truth.lambda.to_dense();
        let chol = crate::linalg::chol_dense::DenseChol::factor(&lam_d, &eng).unwrap();
        let sigma = chol.inverse(&eng);
        let th = truth.theta.to_dense();
        let mut ts = Mat::zeros(truth.p(), truth.q());
        eng.gemm(1.0, &th, &sigma, 0.0, &mut ts);
        let mut want = sigma.clone();
        eng.gemm_tn(1.0, &ts, &ts, 1.0, &mut want);
        let syy = data.syy_dense(&eng);
        let err = syy.max_abs_diff(&want);
        assert!(err < 0.15, "S_yy deviates from model: {err}");
        // E[S_xy] = -Θ*Σ* (with S_xx = I).
        let sxy = data.sxy_dense(&eng);
        let mut want_xy = Mat::zeros(truth.p(), truth.q());
        eng.gemm(-1.0, &th, &sigma, 0.0, &mut want_xy);
        let err2 = sxy.max_abs_diff(&want_xy);
        assert!(err2 < 0.1, "S_xy deviates: {err2}");
    }

    #[test]
    fn truth_is_near_stationary() {
        // The smooth gradient at the truth should vanish as n grows —
        // validates the sampling convention against the paper's likelihood.
        let truth = small_truth();
        let mut rng = Rng::new(5);
        let data = sample_dataset(&truth, 60_000, &mut rng, gaussian_x);
        let eng = NativeGemm::new(1);
        let obj = crate::cggm::Objective::new(&data, 0.0, 0.0);
        let (_, _, factor, rt) = obj.eval(&truth, &eng).unwrap();
        let sigma = factor.inverse_dense(&eng);
        let psi = obj.psi_dense(&sigma, &rt, &eng);
        let gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
        let gt = obj.grad_theta_dense(&sigma, &rt, &eng);
        assert!(gl.max_abs() < 0.1, "∇Λ at truth = {}", gl.max_abs());
        assert!(gt.max_abs() < 0.1, "∇Θ at truth = {}", gt.max_abs());
    }
}
