//! Unified handle over the two Cholesky paths for Λ, with memory-budget
//! accounting.
//!
//! The non-block solvers factor Λ densely (paper §2: "Initializing Σ = Λ⁻¹
//! via Cholesky decomposition"); the block solver must stay sparse (§4,
//! following BigQUIC). [`LambdaFactor`] gives line search and the objective
//! one interface for logdet / PD checks / solves / the n-RHS trace term.
//!
//! # Budget accounting
//!
//! Factorization scratch — not the sparse iterates — dominates the peak
//! working set of every solver: a dense factor is a q×q `L` plus a q×q
//! staging copy of Λ, a sparse factor is nnz(L) of fill, and the line search
//! builds one *per Armijo trial* while the previous iteration's factor is
//! still live. [`LambdaFactor::factor_tracked`] registers all of it against
//! the caller's [`MemBudget`] *before* allocating, so
//!
//! - `MemBudget::peak()` covers every factor byte the four solvers touch
//!   (closing the gap the `memwall` experiment used to under-report), and
//! - a factorization the budget cannot hold fails fast with a clean
//!   [`FactorError::Budget`] instead of allocating past the limit — the
//!   sparse path registers its O(q) per-column structures up front and
//!   converts the remaining budget into a fill cap, so the factorization
//!   aborts the moment its fill outgrows the budget.
//!
//! The resident bytes stay registered for as long as the factor is alive
//! (RAII [`Tracked`] inside the handle); staging/scratch bytes are released
//! when `factor_tracked` returns. The untracked [`LambdaFactor::factor`]
//! remains for data generation and tests, where no budget is in force.

use crate::gemm::GemmEngine;
use crate::linalg::chol_dense::DenseChol;
use crate::linalg::chol_sparse::{SparseChol, SparseCholError};
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;
use crate::util::membudget::{BudgetExceeded, MemBudget, Tracked};

/// Which factorization to use for Λ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CholKind {
    /// Always dense (O(q²) memory) — matches the paper's non-block solvers.
    Dense,
    /// Always sparse with RCM preordering (block solver).
    SparseRcm,
    /// Sparse first; fall back to dense if fill explodes and q is moderate.
    Auto,
}

/// The concrete factorization behind a [`LambdaFactor`].
pub enum FactorRepr {
    Dense(DenseChol),
    Sparse(SparseChol),
}

/// A successful Λ factorization (+ its budget registration, when tracked).
pub struct LambdaFactor {
    repr: FactorRepr,
    /// Registration of the factor's resident bytes; `None` for the
    /// untracked [`LambdaFactor::factor`] path.
    _track: Option<Tracked>,
}

/// Factorization failure — `NotPd` doubles as the line-search PD probe.
#[derive(Debug, thiserror::Error)]
pub enum FactorError {
    #[error("Λ is not positive definite")]
    NotPd,
    #[error("sparse factor fill exceeded and dense fallback is disabled (q={q})")]
    FillExceeded { q: usize },
    #[error("memory budget cannot hold the Λ factor: {0}")]
    Budget(BudgetExceeded),
}

/// Threshold under which the Auto dense fallback is allowed.
const AUTO_DENSE_MAX_Q: usize = 4096;

/// Blocked dense Cholesky panel width (`chol_dense::NB`) — mirrored here so
/// the scratch estimate matches the factorization's largest trailing-update
/// allocation.
const DENSE_NB: usize = 64;

/// Bytes each sparse-factor fill entry costs while resident *and* during
/// factorization: 16 for the frozen CSC (row index + value) plus ~16 for the
/// up-looking builder's per-entry column-list storage.
const SPARSE_FILL_BYTES: usize = 32;

/// Resident bytes of a dense q×q factor (the lower-triangular `L` buffer).
pub fn dense_factor_bytes(q: usize) -> usize {
    8 * q * q
}

/// Transient scratch `DenseChol::factor` allocates beyond the held `L`: the
/// first (largest) blocked trailing-update round keeps `update` (m×m),
/// `panel` (m×NB), and its transposed copy `panel_t` (NB×m) alive
/// concurrently. Zero for q ≤ NB, where the factorization is a single
/// unblocked sweep.
pub fn dense_factor_scratch_bytes(q: usize) -> usize {
    if q <= DENSE_NB {
        0
    } else {
        let m = q - DENSE_NB;
        8 * (m * m + 2 * DENSE_NB * m)
    }
}

impl LambdaFactor {
    /// Factor a sparse symmetric Λ without budget accounting (tests, data
    /// generation, callers with no budget in force). Prefer
    /// [`Self::factor_tracked`] anywhere a [`MemBudget`] exists.
    pub fn factor(
        lambda: &SpRowMat,
        kind: CholKind,
        engine: &dyn GemmEngine,
    ) -> Result<LambdaFactor, FactorError> {
        Self::factor_tracked(lambda, kind, engine, &MemBudget::unlimited())
    }

    /// Factor with every byte registered against `budget` (see the module
    /// docs): resident factor bytes stay tracked for the factor's lifetime,
    /// staging/scratch bytes for the duration of this call, and a plan the
    /// budget cannot hold is rejected *before* the allocation happens.
    pub fn factor_tracked(
        lambda: &SpRowMat,
        kind: CholKind,
        engine: &dyn GemmEngine,
        budget: &MemBudget,
    ) -> Result<LambdaFactor, FactorError> {
        let q = lambda.rows();
        match kind {
            CholKind::Dense => Self::dense_tracked(lambda, engine, budget),
            CholKind::SparseRcm => Self::sparse_tracked(lambda, budget, usize::MAX),
            CholKind::Auto => {
                // Cap fill at ~64·nnz(Λ) before considering dense fallback.
                let cap = lambda.nnz().saturating_mul(64).max(1 << 22);
                match Self::sparse_tracked(lambda, budget, cap) {
                    Ok(f) => Ok(f),
                    Err(FactorError::FillExceeded { .. }) => {
                        let dense_need =
                            2 * dense_factor_bytes(q) + dense_factor_scratch_bytes(q);
                        if q <= AUTO_DENSE_MAX_Q && dense_need <= budget.available() {
                            Self::dense_tracked(lambda, engine, budget)
                        } else {
                            // Very large + very filled: retry sparse with only
                            // the budget as the cap rather than allocating q²
                            // (slow but bounded memory).
                            Self::sparse_tracked(lambda, budget, usize::MAX)
                        }
                    }
                    Err(e) => Err(e),
                }
            }
        }
    }

    fn dense_tracked(
        lambda: &SpRowMat,
        engine: &dyn GemmEngine,
        budget: &MemBudget,
    ) -> Result<LambdaFactor, FactorError> {
        let q = lambda.rows();
        // Register before allocating: the resident L, then the staging dense
        // copy of Λ plus the blocked factorization's trailing-update scratch.
        let held = budget
            .track(dense_factor_bytes(q))
            .map_err(FactorError::Budget)?;
        let staging = budget
            .track(dense_factor_bytes(q) + dense_factor_scratch_bytes(q))
            .map_err(FactorError::Budget)?;
        let dense = lambda.to_dense();
        let res = DenseChol::factor(&dense, engine);
        drop(dense);
        drop(staging);
        match res {
            Ok(f) => Ok(LambdaFactor {
                repr: FactorRepr::Dense(f),
                _track: Some(held),
            }),
            Err(_) => Err(FactorError::NotPd),
        }
    }

    fn sparse_tracked(
        lambda: &SpRowMat,
        budget: &MemBudget,
        cap: usize,
    ) -> Result<LambdaFactor, FactorError> {
        let q = lambda.rows();
        // Register the O(q) per-column structures (colptr + diag + the two
        // permutation vectors, plus the builder's dense scratch rows) before
        // factoring — a budget that cannot even hold those must reject the
        // plan up front, not after allocating them.
        let base = budget
            .track(8 * (q + 1) + 8 * q + 16 * q + 16 * q)
            .map_err(FactorError::Budget)?;
        // The rest of the budget, expressed as a fill cap: factorization
        // aborts the moment fill outgrows what the budget can hold — fail
        // fast, no allocation past the limit.
        let budget_cap = (budget.available() / SPARSE_FILL_BYTES).max(1);
        let eff_cap = cap.min(budget_cap);
        match SparseChol::factor(lambda, true, eff_cap) {
            Ok(f) => {
                // Register the frozen factor while the builder registration
                // is still live (both genuinely coexist during the freeze),
                // then release the builder's share.
                let track = budget.track(f.bytes()).map_err(FactorError::Budget)?;
                drop(base);
                Ok(LambdaFactor {
                    repr: FactorRepr::Sparse(f),
                    _track: Some(track),
                })
            }
            Err(SparseCholError::NotPositiveDefinite { .. }) => Err(FactorError::NotPd),
            Err(SparseCholError::TooMuchFill { fill, .. }) => {
                if budget_cap < cap {
                    // The budget was the binding cap.
                    Err(FactorError::Budget(BudgetExceeded {
                        requested: fill.saturating_mul(SPARSE_FILL_BYTES),
                        live: budget.live(),
                        limit: budget.limit(),
                    }))
                } else {
                    Err(FactorError::FillExceeded { q })
                }
            }
        }
    }

    /// The concrete dense/sparse factorization.
    pub fn repr(&self) -> &FactorRepr {
        &self.repr
    }

    /// Bytes this factor keeps resident (0 when untracked — the accounting
    /// itself, not the structure, is what is absent).
    pub fn resident_bytes(&self) -> usize {
        match &self.repr {
            FactorRepr::Dense(f) => dense_factor_bytes(f.n()),
            FactorRepr::Sparse(f) => f.bytes(),
        }
    }

    pub fn logdet(&self) -> f64 {
        match &self.repr {
            FactorRepr::Dense(f) => f.logdet(),
            FactorRepr::Sparse(f) => f.logdet(),
        }
    }

    /// Solve Λ x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        match &self.repr {
            FactorRepr::Dense(f) => {
                let mut x = b.to_vec();
                f.solve(&mut x);
                x
            }
            FactorRepr::Sparse(f) => f.solve(b),
        }
    }

    /// bᵀ Λ⁻¹ b.
    pub fn quad_form_inv(&self, b: &[f64]) -> f64 {
        match &self.repr {
            FactorRepr::Dense(f) => f.quad_form_inv(b),
            FactorRepr::Sparse(f) => f.quad_form_inv(b),
        }
    }

    /// tr(Λ⁻¹ R̃ᵀR̃)/n for R̃ᵀ given as a q×n matrix — the objective's trace
    /// term, computed as Σ_k ‖L⁻¹ r̃_k‖²/n without forming Λ⁻¹.
    pub fn trace_quad(&self, rt: &Mat) -> f64 {
        let (q, n) = (rt.rows(), rt.cols());
        let mut total = 0.0;
        let mut col = vec![0.0; q];
        for k in 0..n {
            for j in 0..q {
                col[j] = rt[(j, k)];
            }
            total += self.quad_form_inv(&col);
        }
        total / n as f64
    }

    /// Dense Σ = Λ⁻¹ (non-block solvers).
    pub fn inverse_dense(&self, engine: &dyn GemmEngine) -> Mat {
        match &self.repr {
            FactorRepr::Dense(f) => f.inverse(engine),
            FactorRepr::Sparse(f) => {
                // Solve against identity columns (used only in tests/small q).
                let q = f.n();
                let mut inv = Mat::zeros(q, q);
                let mut e = vec![0.0; q];
                for j in 0..q {
                    e[j] = 1.0;
                    let x = f.solve(&e);
                    for i in 0..q {
                        inv[(i, j)] = x[i];
                    }
                    e[j] = 0.0;
                }
                inv.symmetrize();
                inv
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_close, property};

    fn chain_lambda(q: usize) -> SpRowMat {
        let mut a = SpRowMat::zeros(q, q);
        for i in 0..q {
            a.set(i, i, 2.25);
            if i > 0 {
                a.set_sym(i, i - 1, 1.0);
            }
        }
        a
    }

    #[test]
    fn dense_and_sparse_agree() {
        property(20, |rng| {
            let q = 2 + rng.below(30);
            let lam = chain_lambda(q);
            let eng = NativeGemm::new(1);
            let fd = LambdaFactor::factor(&lam, CholKind::Dense, &eng).map_err(|e| e.to_string())?;
            let fs =
                LambdaFactor::factor(&lam, CholKind::SparseRcm, &eng).map_err(|e| e.to_string())?;
            check_close(fd.logdet(), fs.logdet(), 1e-9, "logdet")?;
            let b: Vec<f64> = (0..q).map(|_| rng.normal()).collect();
            check_close(fd.quad_form_inv(&b), fs.quad_form_inv(&b), 1e-8, "quad")?;
            let n = 3;
            let rt = Mat::from_fn(q, n, |_, _| rng.normal());
            check_close(fd.trace_quad(&rt), fs.trace_quad(&rt), 1e-8, "trace")?;
            Ok(())
        });
    }

    #[test]
    fn not_pd_detected_by_all_kinds() {
        let mut lam = SpRowMat::eye(4);
        lam.set(1, 1, -1.0);
        let eng = NativeGemm::new(1);
        for kind in [CholKind::Dense, CholKind::SparseRcm, CholKind::Auto] {
            assert!(matches!(
                LambdaFactor::factor(&lam, kind, &eng),
                Err(FactorError::NotPd)
            ));
        }
    }

    #[test]
    fn trace_quad_matches_explicit() {
        let mut rng = Rng::new(7);
        let q = 10;
        let n = 5;
        let lam = chain_lambda(q);
        let eng = NativeGemm::new(1);
        let f = LambdaFactor::factor(&lam, CholKind::Dense, &eng).unwrap();
        let rt = Mat::from_fn(q, n, |_, _| rng.normal());
        // Explicit: tr(Λ⁻¹ R̃ᵀR̃)/n with R̃ᵀR̃ = rt·rtᵀ.
        let inv = f.inverse_dense(&eng);
        let mut gram = Mat::zeros(q, q);
        eng.gemm_nt(1.0, &rt, &rt, 0.0, &mut gram);
        let mut want = 0.0;
        for i in 0..q {
            for j in 0..q {
                want += inv[(i, j)] * gram[(j, i)];
            }
        }
        want /= n as f64;
        assert!((f.trace_quad(&rt) - want).abs() < 1e-9);
    }

    #[test]
    fn dense_factor_bytes_tracked_for_factor_lifetime() {
        let q = 12;
        let lam = chain_lambda(q);
        let eng = NativeGemm::new(1);
        let budget = MemBudget::unlimited();
        let f = LambdaFactor::factor_tracked(&lam, CholKind::Dense, &eng, &budget).unwrap();
        // Resident: exactly the q×q L. Staging (dense Λ copy) released.
        assert_eq!(budget.live(), dense_factor_bytes(q));
        assert_eq!(f.resident_bytes(), dense_factor_bytes(q));
        // Peak saw L + the staging copy concurrently (q ≤ NB: no blocked
        // trailing-update scratch on top).
        assert_eq!(budget.peak(), 2 * dense_factor_bytes(q));
        drop(f);
        assert_eq!(budget.live(), 0);
    }

    #[test]
    fn sparse_factor_bytes_tracked_for_factor_lifetime() {
        let q = 30;
        let lam = chain_lambda(q);
        let budget = MemBudget::unlimited();
        let eng = NativeGemm::new(1);
        let f = LambdaFactor::factor_tracked(&lam, CholKind::SparseRcm, &eng, &budget).unwrap();
        assert!(matches!(f.repr(), FactorRepr::Sparse(_)));
        assert_eq!(budget.live(), f.resident_bytes());
        assert!(f.resident_bytes() > 0);
        drop(f);
        assert_eq!(budget.live(), 0);
    }

    #[test]
    fn undersized_budget_rejects_before_allocating() {
        let q = 40;
        let lam = chain_lambda(q);
        let eng = NativeGemm::new(1);
        // Dense: L alone is 12800 bytes — a 1KB budget must fail fast.
        let budget = MemBudget::new(1024);
        match LambdaFactor::factor_tracked(&lam, CholKind::Dense, &eng, &budget) {
            Err(FactorError::Budget(_)) => {}
            other => panic!("expected Budget error, got ok={}", other.is_ok()),
        }
        // Nothing leaked, and the accounting never exceeded the limit.
        assert_eq!(budget.live(), 0);
        assert!(budget.peak() <= 1024);
        // Sparse: the per-column structures alone exceed a 64-byte budget.
        let tiny = MemBudget::new(64);
        match LambdaFactor::factor_tracked(&lam, CholKind::SparseRcm, &eng, &tiny) {
            Err(FactorError::Budget(_)) => {}
            other => panic!("expected Budget error, got ok={}", other.is_ok()),
        }
        assert_eq!(tiny.live(), 0);
        assert!(tiny.peak() <= 64);
    }

    #[test]
    fn auto_respects_budget_on_both_paths() {
        let q = 20;
        let lam = chain_lambda(q);
        let eng = NativeGemm::new(1);
        // Plenty of budget: Auto picks sparse on a chain and tracks it.
        let budget = MemBudget::new(1 << 20);
        let f = LambdaFactor::factor_tracked(&lam, CholKind::Auto, &eng, &budget).unwrap();
        assert_eq!(budget.live(), f.resident_bytes());
        assert!(budget.peak() <= 1 << 20);
    }
}
