//! Unified handle over the two Cholesky paths for Λ.
//!
//! The non-block solvers factor Λ densely (paper §2: "Initializing Σ = Λ⁻¹
//! via Cholesky decomposition"); the block solver must stay sparse (§4,
//! following BigQUIC). [`LambdaFactor`] gives line search and the objective
//! one interface for logdet / PD checks / solves / the n-RHS trace term.

use crate::gemm::GemmEngine;
use crate::linalg::chol_dense::DenseChol;
use crate::linalg::chol_sparse::{SparseChol, SparseCholError};
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;

/// Which factorization to use for Λ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CholKind {
    /// Always dense (O(q²) memory) — matches the paper's non-block solvers.
    Dense,
    /// Always sparse with RCM preordering (block solver).
    SparseRcm,
    /// Sparse first; fall back to dense if fill explodes and q is moderate.
    Auto,
}

/// A successful Λ factorization.
pub enum LambdaFactor {
    Dense(DenseChol),
    Sparse(SparseChol),
}

/// Factorization failure — `NotPd` doubles as the line-search PD probe.
#[derive(Debug, thiserror::Error)]
pub enum FactorError {
    #[error("Λ is not positive definite")]
    NotPd,
    #[error("sparse factor fill exceeded and dense fallback is disabled (q={q})")]
    FillExceeded { q: usize },
}

/// Threshold under which the Auto dense fallback is allowed.
const AUTO_DENSE_MAX_Q: usize = 4096;

impl LambdaFactor {
    /// Factor a sparse symmetric Λ.
    pub fn factor(
        lambda: &SpRowMat,
        kind: CholKind,
        engine: &dyn GemmEngine,
    ) -> Result<LambdaFactor, FactorError> {
        let q = lambda.rows();
        match kind {
            CholKind::Dense => DenseChol::factor(&lambda.to_dense(), engine)
                .map(LambdaFactor::Dense)
                .map_err(|_| FactorError::NotPd),
            CholKind::SparseRcm => match SparseChol::factor(lambda, true, usize::MAX) {
                Ok(f) => Ok(LambdaFactor::Sparse(f)),
                Err(SparseCholError::NotPositiveDefinite { .. }) => Err(FactorError::NotPd),
                Err(SparseCholError::TooMuchFill { .. }) => unreachable!("no cap set"),
            },
            CholKind::Auto => {
                // Cap fill at ~64·nnz(Λ) before considering dense fallback.
                let cap = lambda.nnz().saturating_mul(64).max(1 << 22);
                match SparseChol::factor(lambda, true, cap) {
                    Ok(f) => Ok(LambdaFactor::Sparse(f)),
                    Err(SparseCholError::NotPositiveDefinite { .. }) => Err(FactorError::NotPd),
                    Err(SparseCholError::TooMuchFill { .. }) => {
                        if q <= AUTO_DENSE_MAX_Q {
                            DenseChol::factor(&lambda.to_dense(), engine)
                                .map(LambdaFactor::Dense)
                                .map_err(|_| FactorError::NotPd)
                        } else {
                            // Very large + very filled: retry sparse uncapped
                            // rather than allocating q² (slow but bounded mem).
                            match SparseChol::factor(lambda, true, usize::MAX) {
                                Ok(f) => Ok(LambdaFactor::Sparse(f)),
                                Err(_) => Err(FactorError::NotPd),
                            }
                        }
                    }
                }
            }
        }
    }

    pub fn logdet(&self) -> f64 {
        match self {
            LambdaFactor::Dense(f) => f.logdet(),
            LambdaFactor::Sparse(f) => f.logdet(),
        }
    }

    /// Solve Λ x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        match self {
            LambdaFactor::Dense(f) => {
                let mut x = b.to_vec();
                f.solve(&mut x);
                x
            }
            LambdaFactor::Sparse(f) => f.solve(b),
        }
    }

    /// bᵀ Λ⁻¹ b.
    pub fn quad_form_inv(&self, b: &[f64]) -> f64 {
        match self {
            LambdaFactor::Dense(f) => f.quad_form_inv(b),
            LambdaFactor::Sparse(f) => f.quad_form_inv(b),
        }
    }

    /// tr(Λ⁻¹ R̃ᵀR̃)/n for R̃ᵀ given as a q×n matrix — the objective's trace
    /// term, computed as Σ_k ‖L⁻¹ r̃_k‖²/n without forming Λ⁻¹.
    pub fn trace_quad(&self, rt: &Mat) -> f64 {
        let (q, n) = (rt.rows(), rt.cols());
        let mut total = 0.0;
        let mut col = vec![0.0; q];
        for k in 0..n {
            for j in 0..q {
                col[j] = rt[(j, k)];
            }
            total += self.quad_form_inv(&col);
        }
        total / n as f64
    }

    /// Dense Σ = Λ⁻¹ (non-block solvers).
    pub fn inverse_dense(&self, engine: &dyn GemmEngine) -> Mat {
        match self {
            LambdaFactor::Dense(f) => f.inverse(engine),
            LambdaFactor::Sparse(f) => {
                // Solve against identity columns (used only in tests/small q).
                let q = f.n();
                let mut inv = Mat::zeros(q, q);
                let mut e = vec![0.0; q];
                for j in 0..q {
                    e[j] = 1.0;
                    let x = f.solve(&e);
                    for i in 0..q {
                        inv[(i, j)] = x[i];
                    }
                    e[j] = 0.0;
                }
                inv.symmetrize();
                inv
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_close, property};

    fn chain_lambda(q: usize) -> SpRowMat {
        let mut a = SpRowMat::zeros(q, q);
        for i in 0..q {
            a.set(i, i, 2.25);
            if i > 0 {
                a.set_sym(i, i - 1, 1.0);
            }
        }
        a
    }

    #[test]
    fn dense_and_sparse_agree() {
        property(20, |rng| {
            let q = 2 + rng.below(30);
            let lam = chain_lambda(q);
            let eng = NativeGemm::new(1);
            let fd = LambdaFactor::factor(&lam, CholKind::Dense, &eng).map_err(|e| e.to_string())?;
            let fs =
                LambdaFactor::factor(&lam, CholKind::SparseRcm, &eng).map_err(|e| e.to_string())?;
            check_close(fd.logdet(), fs.logdet(), 1e-9, "logdet")?;
            let b: Vec<f64> = (0..q).map(|_| rng.normal()).collect();
            check_close(fd.quad_form_inv(&b), fs.quad_form_inv(&b), 1e-8, "quad")?;
            let n = 3;
            let rt = Mat::from_fn(q, n, |_, _| rng.normal());
            check_close(fd.trace_quad(&rt), fs.trace_quad(&rt), 1e-8, "trace")?;
            Ok(())
        });
    }

    #[test]
    fn not_pd_detected_by_all_kinds() {
        let mut lam = SpRowMat::eye(4);
        lam.set(1, 1, -1.0);
        let eng = NativeGemm::new(1);
        for kind in [CholKind::Dense, CholKind::SparseRcm, CholKind::Auto] {
            assert!(matches!(
                LambdaFactor::factor(&lam, kind, &eng),
                Err(FactorError::NotPd)
            ));
        }
    }

    #[test]
    fn trace_quad_matches_explicit() {
        let mut rng = Rng::new(7);
        let q = 10;
        let n = 5;
        let lam = chain_lambda(q);
        let eng = NativeGemm::new(1);
        let f = LambdaFactor::factor(&lam, CholKind::Dense, &eng).unwrap();
        let rt = Mat::from_fn(q, n, |_, _| rng.normal());
        // Explicit: tr(Λ⁻¹ R̃ᵀR̃)/n with R̃ᵀR̃ = rt·rtᵀ.
        let inv = f.inverse_dense(&eng);
        let mut gram = Mat::zeros(q, q);
        eng.gemm_nt(1.0, &rt, &rt, 0.0, &mut gram);
        let mut want = 0.0;
        for i in 0..q {
            for j in 0..q {
                want += inv[(i, j)] * gram[(j, i)];
            }
        }
        want /= n as f64;
        assert!((f.trace_quad(&rt) - want).abs() < 1e-9);
    }
}
