//! Active-set screening (paper §2):
//!
//! ```text
//! S_Λ = {(i,j) : |(∇_Λ g)_ij| > λ_Λ  ∨  Λ_ij ≠ 0}
//! S_Θ = {(i,j) : |(∇_Θ g)_ij| > λ_Θ  ∨  Θ_ij ≠ 0}
//! ```
//!
//! Coordinates outside the active set provably stay zero for the current
//! quadratic model, so CD updates are restricted to S — the active sets
//! shrink toward the solution support over Newton iterations, which is the
//! main speedup lever of the QUIC family.
//!
//! These helpers take *dense* gradients (non-block solvers). The block
//! solver screens blockwise during its sweeps (see `solvers::alt_newton_bcd`)
//! and shares [`ActiveStats`] so the stopping rule comes free.
//!
//! # Path-level screening (sequential strong rule)
//!
//! Along a decreasing λ path the active set changes slowly, so re-screening
//! all q²/pq coordinates at every point (and every outer iteration) is
//! wasted work. The sequential strong rule (Tibshirani et al., in the spirit
//! of the safe-bound analyses of Banerjee et al.) keeps, at path point λ_k,
//! only the coordinates
//!
//! ```text
//! E = supp(x̂(λ_{k-1})) ∪ {(i,j) : |∇g(x̂(λ_{k-1}))_ij| > 2λ_k − λ_{k-1}}
//! ```
//!
//! and restricts *all* screening and CD work to E ([`ScreenSet`]). The rule
//! is a heuristic, so after the restricted solve a KKT post-check
//! ([`kkt_violations`]) scans the discarded coordinates once; any violation
//! sends the path driver back to an unrestricted solve (warm-started from
//! the restricted solution, so the fallback is cheap). See
//! `coordinator::solve_screened`.

use super::model::CggmModel;
use super::objective::min_norm_subgrad;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;

/// How the λ-path driver screens coordinates across path points.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScreenRule {
    /// Re-screen every coordinate at every point (the pre-screening driver).
    Full,
    /// Sequential strong rule with KKT post-check (default).
    #[default]
    Strong,
}

impl ScreenRule {
    pub fn parse(s: &str) -> Option<ScreenRule> {
        match s {
            "full" | "none" | "off" => Some(ScreenRule::Full),
            "strong" | "seq" | "sequential" => Some(ScreenRule::Strong),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScreenRule::Full => "full",
            ScreenRule::Strong => "strong",
        }
    }
}

/// Candidate coordinates a restricted solve is allowed to touch: Λ pairs in
/// the upper triangle (i ≤ j) and Θ pairs, both row-major sorted. Built once
/// per path point from the previous point's solution and gradients.
#[derive(Clone, Debug, Default)]
pub struct ScreenSet {
    /// Allowed Λ coordinates, i ≤ j, always including the diagonal.
    pub lambda: Vec<(usize, usize)>,
    /// Allowed Θ coordinates.
    pub theta: Vec<(usize, usize)>,
}

impl ScreenSet {
    /// Sequential strong rule at (λ_Λ, λ_Θ) given the gradients `gl`/`gt`
    /// and support of the *previous* path point's solution at
    /// (λ_Λ', λ_Θ') = (`prev_l`, `prev_t`). An aggressive λ drop makes the
    /// threshold `2λ − λ'` negative, in which case every coordinate passes
    /// — the rule degrades gracefully to a full screen.
    pub fn strong(
        gl: &Mat,
        gt: &Mat,
        model: &CggmModel,
        lam_l: f64,
        lam_t: f64,
        prev_l: f64,
        prev_t: f64,
    ) -> ScreenSet {
        let q = gl.rows();
        let p = gt.rows();
        debug_assert_eq!(gt.cols(), q);
        let thr_l = 2.0 * lam_l - prev_l;
        let thr_t = 2.0 * lam_t - prev_t;
        let mut lambda = Vec::new();
        for i in 0..q {
            let grow = gl.row(i);
            for j in i..q {
                if i == j || model.lambda.get(i, j) != 0.0 || grow[j].abs() > thr_l {
                    lambda.push((i, j));
                }
            }
        }
        let mut theta = Vec::new();
        for i in 0..p {
            let grow = gt.row(i);
            // Merge the sparse support row with the dense gradient row.
            let srow = model.theta.row(i);
            let mut s_iter = srow.iter().peekable();
            for j in 0..q {
                let supported = match s_iter.peek() {
                    Some(&&(jj, v)) if jj == j => {
                        s_iter.next();
                        v != 0.0
                    }
                    _ => false,
                };
                if supported || grow[j].abs() > thr_t {
                    theta.push((i, j));
                }
            }
        }
        ScreenSet { lambda, theta }
    }

    /// Total allowed coordinates (the per-iteration screening cost of a
    /// restricted solve).
    pub fn len(&self) -> usize {
        self.lambda.len() + self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lambda.is_empty() && self.theta.is_empty()
    }

    /// The set extended with any of `model`'s support coordinates it is
    /// missing, or `None` when it already covers the support (the common
    /// case — [`ScreenSet::strong`] includes the support by construction).
    /// A restricted solve can only move coordinates it screens, so a warm
    /// start whose support pokes outside the set would otherwise be frozen
    /// at stale values — and exempted from the KKT post-check, which only
    /// examines zero coordinates ([`crate::coordinator::solve_screened`]
    /// calls this to keep its safety guarantee for arbitrary caller sets).
    pub fn with_support(&self, model: &CggmModel) -> Option<ScreenSet> {
        let (p, q) = (model.p(), model.q());
        let (ml, mt) = self.masks(p, q);
        let mut extra_l = Vec::new();
        for i in 0..q {
            // Symmetric Λ: every unordered pair has its (i, j ≥ i)
            // representative in row i.
            for &(j, v) in model.lambda.row(i) {
                if j >= i && v != 0.0 && !ml[i * q + j] {
                    extra_l.push((i, j));
                }
            }
        }
        let mut extra_t = Vec::new();
        for i in 0..p {
            for &(j, v) in model.theta.row(i) {
                if v != 0.0 && !mt[i * q + j] {
                    extra_t.push((i, j));
                }
            }
        }
        if extra_l.is_empty() && extra_t.is_empty() {
            return None;
        }
        let mut out = self.clone();
        out.lambda.extend(extra_l);
        out.theta.extend(extra_t);
        Some(out)
    }

    /// Dense membership masks (row-major q×q upper-tri for Λ, p×q for Θ) for
    /// the KKT post-check's O(1) lookups.
    fn masks(&self, p: usize, q: usize) -> (Vec<bool>, Vec<bool>) {
        let mut ml = vec![false; q * q];
        for &(i, j) in &self.lambda {
            ml[i * q + j] = true;
        }
        let mut mt = vec![false; p * q];
        for &(i, j) in &self.theta {
            mt[i * q + j] = true;
        }
        (ml, mt)
    }
}

/// KKT post-check for a restricted solve: count coordinates *outside* the
/// screen set whose gradient violates optimality — |g| > λ·(1 + `rel_slack`)
/// for a zero coordinate. Coordinates inside the set are covered by the
/// solver's own stopping rule, and the restricted solve can never grow
/// support outside the set. `rel_slack` is the tolerance scale below which
/// a "violation" is indistinguishable from converged noise (the path driver
/// passes the solver's stopping tolerance); anything larger forces the
/// full-screen fallback.
pub fn kkt_violations(
    gl: &Mat,
    gt: &Mat,
    model: &CggmModel,
    lam_l: f64,
    lam_t: f64,
    set: &ScreenSet,
    rel_slack: f64,
) -> usize {
    let q = gl.rows();
    let p = gt.rows();
    let (ml, mt) = set.masks(p, q);
    let thr_l = lam_l * (1.0 + rel_slack);
    let thr_t = lam_t * (1.0 + rel_slack);
    let mut viol = 0usize;
    for i in 0..q {
        let grow = gl.row(i);
        for j in i..q {
            if !ml[i * q + j] && model.lambda.get(i, j) == 0.0 && grow[j].abs() > thr_l {
                viol += 1;
            }
        }
    }
    for i in 0..p {
        let grow = gt.row(i);
        for j in 0..q {
            if !mt[i * q + j] && model.theta.get(i, j) == 0.0 && grow[j].abs() > thr_t {
                viol += 1;
            }
        }
    }
    viol
}

/// Output of a screen: the active coordinate list plus the convergence
/// statistics that fall out of the same pass.
#[derive(Clone, Debug, Default)]
pub struct ActiveStats {
    /// ‖grad^S f‖₁ accumulated over screened coordinates.
    pub subgrad_l1: f64,
    /// Active coordinate count.
    pub count: usize,
}

/// Λ screen over the upper triangle (including diagonal). Returns active
/// (i,j) pairs with i ≤ j, and stats over the whole triangle.
pub fn lambda_active_dense(
    grad: &Mat,
    lambda: &SpRowMat,
    lam_l: f64,
) -> (Vec<(usize, usize)>, ActiveStats) {
    let q = grad.rows();
    let mut act = Vec::new();
    let mut stats = ActiveStats::default();
    for i in 0..q {
        let grow = grad.row(i);
        for j in i..q {
            let g = grow[j];
            let x = lambda.get(i, j);
            let s = min_norm_subgrad(g, x, lam_l);
            // Count both triangles in the norm (paper's ‖·‖₁ is over the
            // full matrix); diagonal once.
            stats.subgrad_l1 += if i == j { s.abs() } else { 2.0 * s.abs() };
            if x != 0.0 || g.abs() > lam_l {
                act.push((i, j));
            }
        }
    }
    stats.count = act.len();
    (act, stats)
}

/// Θ screen over all p×q coordinates.
pub fn theta_active_dense(
    grad: &Mat,
    theta: &SpRowMat,
    lam_t: f64,
) -> (Vec<(usize, usize)>, ActiveStats) {
    let (p, q) = (grad.rows(), grad.cols());
    let mut act = Vec::new();
    let mut stats = ActiveStats::default();
    for i in 0..p {
        let grow = grad.row(i);
        // Merge the sparse row with the dense gradient row.
        let srow = theta.row(i);
        let mut s_iter = srow.iter().peekable();
        for j in 0..q {
            let x = match s_iter.peek() {
                Some(&&(jj, v)) if jj == j => {
                    s_iter.next();
                    v
                }
                _ => 0.0,
            };
            let g = grow[j];
            stats.subgrad_l1 += min_norm_subgrad(g, x, lam_t).abs();
            if x != 0.0 || g.abs() > lam_t {
                act.push((i, j));
            }
        }
    }
    stats.count = act.len();
    (act, stats)
}

/// Λ screen restricted to an allowed coordinate list (path-level strong-rule
/// screening): identical decision rule to [`lambda_active_dense`], but only
/// `allowed` pairs (i ≤ j) are examined — O(|allowed|) instead of O(q²).
/// Coordinates outside `allowed` are presumed zero with |g| ≤ λ (the strong
/// rule's bet), so their subgradient contribution is 0; the KKT post-check
/// validates the bet after the solve.
pub fn lambda_active_within(
    grad: &Mat,
    lambda: &SpRowMat,
    lam_l: f64,
    allowed: &[(usize, usize)],
) -> (Vec<(usize, usize)>, ActiveStats) {
    let mut act = Vec::new();
    let mut stats = ActiveStats::default();
    for &(i, j) in allowed {
        let g = grad[(i, j)];
        let x = lambda.get(i, j);
        let s = min_norm_subgrad(g, x, lam_l);
        stats.subgrad_l1 += if i == j { s.abs() } else { 2.0 * s.abs() };
        if x != 0.0 || g.abs() > lam_l {
            act.push((i, j));
        }
    }
    stats.count = act.len();
    (act, stats)
}

/// Θ screen restricted to an allowed coordinate list. Takes the gradient as
/// a per-coordinate closure so callers can evaluate only the |allowed|
/// entries (O(n) each from the shared `Σ·R̃ᵀ` panel) instead of forming the
/// dense p×q gradient — the screened path's hot-path win: the O(npq) GEMM
/// is skipped entirely.
pub fn theta_active_within(
    grad: impl Fn(usize, usize) -> f64,
    theta: &SpRowMat,
    lam_t: f64,
    allowed: &[(usize, usize)],
) -> (Vec<(usize, usize)>, ActiveStats) {
    let mut act = Vec::new();
    let mut stats = ActiveStats::default();
    for &(i, j) in allowed {
        let g = grad(i, j);
        let x = theta.get(i, j);
        stats.subgrad_l1 += min_norm_subgrad(g, x, lam_t).abs();
        if x != 0.0 || g.abs() > lam_t {
            act.push((i, j));
        }
    }
    stats.count = act.len();
    (act, stats)
}

/// Active Λ pairs grouped by (block_z, block_r) for the block solver:
/// entry (i,j), i≤j goes to the (part[i], part[j]) bucket (unordered pair).
pub fn group_pairs_by_block(
    pairs: &[(usize, usize)],
    part: &[usize],
    k: usize,
) -> Vec<Vec<(usize, usize)>> {
    let mut buckets = vec![Vec::new(); k * k];
    for &(i, j) in pairs {
        let (a, b) = (part[i].min(part[j]), part[i].max(part[j]));
        buckets[a * k + b].push((i, j));
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_screen_picks_gradient_violators_and_support() {
        let q = 3;
        let mut grad = Mat::zeros(q, q);
        grad[(0, 1)] = 0.9; // above λ=0.5 → active
        grad[(1, 2)] = 0.2; // below → inactive unless supported
        let mut lam = SpRowMat::eye(q);
        lam.set_sym(1, 2, 0.7); // supported → active
        let (act, stats) = lambda_active_dense(&grad, &lam, 0.5);
        assert!(act.contains(&(0, 1)));
        assert!(act.contains(&(1, 2)));
        // diagonal always in support (Λ=I)
        assert!(act.contains(&(0, 0)));
        assert_eq!(stats.count, act.len());
        assert!(stats.subgrad_l1 > 0.0);
    }

    #[test]
    fn theta_screen() {
        let mut grad = Mat::zeros(2, 3);
        grad[(0, 0)] = 1.0;
        grad[(1, 2)] = -0.4;
        let mut th = SpRowMat::zeros(2, 3);
        th.set(1, 1, 0.3);
        let (act, _) = theta_active_dense(&grad, &th, 0.5);
        assert_eq!(act, vec![(0, 0), (1, 1)]);
    }

    #[test]
    fn subgrad_zero_at_optimum_like_point() {
        // grad within ±λ everywhere and empty support → subgrad 0.
        let grad = Mat::from_fn(4, 4, |_, _| 0.1);
        let th = SpRowMat::zeros(4, 4);
        let (act, stats) = theta_active_dense(&grad, &th, 0.5);
        assert!(act.is_empty());
        assert_eq!(stats.subgrad_l1, 0.0);
    }

    #[test]
    fn restricted_screens_match_dense_on_full_universe() {
        // With `allowed` = every coordinate, the restricted screens must
        // reproduce the dense screens exactly (active lists and stats).
        let (p, q) = (3, 4);
        let mut rng = crate::util::rng::Rng::new(17);
        let gl = Mat::from_fn(q, q, |_, _| rng.normal());
        let gt = Mat::from_fn(p, q, |_, _| rng.normal());
        let mut lam = SpRowMat::eye(q);
        lam.set_sym(0, 2, 0.4);
        let mut th = SpRowMat::zeros(p, q);
        th.set(1, 3, -0.2);
        let all_l: Vec<(usize, usize)> =
            (0..q).flat_map(|i| (i..q).map(move |j| (i, j))).collect();
        let all_t: Vec<(usize, usize)> =
            (0..p).flat_map(|i| (0..q).map(move |j| (i, j))).collect();
        let (da, ds) = lambda_active_dense(&gl, &lam, 0.5);
        let (ra, rs) = lambda_active_within(&gl, &lam, 0.5, &all_l);
        assert_eq!(da, ra);
        assert!((ds.subgrad_l1 - rs.subgrad_l1).abs() < 1e-12);
        let (da, ds) = theta_active_dense(&gt, &th, 0.5);
        let (ra, rs) = theta_active_within(|i, j| gt[(i, j)], &th, 0.5, &all_t);
        assert_eq!(da, ra);
        assert!((ds.subgrad_l1 - rs.subgrad_l1).abs() < 1e-12);
    }

    #[test]
    fn strong_rule_keeps_support_and_large_gradients() {
        let q = 4;
        let p = 3;
        let mut gl = Mat::zeros(q, q);
        gl[(0, 1)] = 0.9; // above thr 0.6 → kept
        gl[(1, 2)] = 0.3; // below thr → dropped unless supported
        let mut gt = Mat::zeros(p, q);
        gt[(2, 0)] = 0.7;
        let mut model = CggmModel::init(p, q);
        model.lambda.set_sym(1, 2, 0.5); // supported → kept regardless
        model.theta.set(0, 3, -0.1);
        // λ_k = 0.4, λ_{k−1} = 0.2 → thr = 2·0.4 − 0.2 = 0.6.
        let set = ScreenSet::strong(&gl, &gt, &model, 0.4, 0.4, 0.2, 0.2);
        assert!(set.lambda.contains(&(0, 1)));
        assert!(set.lambda.contains(&(1, 2)));
        for i in 0..q {
            assert!(set.lambda.contains(&(i, i)), "diag ({i},{i}) must be kept");
        }
        assert!(!set.lambda.contains(&(0, 2)), "zero-gradient pair dropped");
        assert!(set.theta.contains(&(2, 0)));
        assert!(set.theta.contains(&(0, 3)));
        assert_eq!(set.theta.len(), 2);
        assert_eq!(set.len(), set.lambda.len() + set.theta.len());
        // An aggressive λ drop (2λ_k < λ_{k−1}) sends the threshold
        // negative and the rule keeps everything.
        let wide = ScreenSet::strong(&gl, &gt, &model, 0.1, 0.1, 0.9, 0.9);
        assert_eq!(wide.lambda.len(), q * (q + 1) / 2);
        assert_eq!(wide.theta.len(), p * q);
    }

    #[test]
    fn with_support_merges_only_missing_coordinates() {
        let (p, q) = (2, 3);
        let mut model = CggmModel::init(p, q);
        model.lambda.set_sym(0, 2, 0.4);
        model.theta.set(1, 1, -0.3);
        let covering = ScreenSet {
            lambda: vec![(0, 0), (0, 2), (1, 1), (2, 2)],
            theta: vec![(1, 1)],
        };
        assert!(
            covering.with_support(&model).is_none(),
            "a covering set needs no merge"
        );
        // Drop (0,2) and the Θ entry: both must come back, nothing else.
        let partial = ScreenSet {
            lambda: vec![(0, 0), (1, 1), (2, 2)],
            theta: vec![],
        };
        let merged = partial.with_support(&model).expect("support was missing");
        assert!(merged.lambda.contains(&(0, 2)));
        assert_eq!(merged.lambda.len(), 4);
        assert_eq!(merged.theta, vec![(1, 1)]);
    }

    #[test]
    fn kkt_check_flags_dropped_violators_only() {
        let (p, q) = (2, 3);
        let mut gl = Mat::zeros(q, q);
        gl[(0, 1)] = 0.8; // violates λ=0.5 if outside the set
        let mut gt = Mat::zeros(p, q);
        gt[(1, 2)] = -0.9;
        let model = CggmModel::init(p, q);
        // Set containing both hot coordinates → no violations.
        let full = ScreenSet {
            lambda: vec![(0, 0), (0, 1), (1, 1), (2, 2)],
            theta: vec![(1, 2)],
        };
        assert_eq!(kkt_violations(&gl, &gt, &model, 0.5, 0.5, &full, 1e-9), 0);
        // Dropping them must be detected — one violation each.
        let bad = ScreenSet {
            lambda: vec![(0, 0), (1, 1), (2, 2)],
            theta: vec![],
        };
        assert_eq!(kkt_violations(&gl, &gt, &model, 0.5, 0.5, &bad, 1e-9), 2);
        // Larger λ silences them again (gradient within the λ tube).
        assert_eq!(kkt_violations(&gl, &gt, &model, 1.0, 1.0, &bad, 1e-9), 0);
    }

    #[test]
    fn screen_rule_parse_roundtrip() {
        assert_eq!(ScreenRule::parse("full"), Some(ScreenRule::Full));
        assert_eq!(ScreenRule::parse("strong"), Some(ScreenRule::Strong));
        assert_eq!(ScreenRule::parse("bogus"), None);
        assert_eq!(ScreenRule::default(), ScreenRule::Strong);
        assert_eq!(ScreenRule::Strong.name(), "strong");
    }

    #[test]
    fn grouping_covers_all_pairs() {
        let pairs = vec![(0, 1), (2, 3), (0, 3), (1, 1)];
        let part = vec![0, 0, 1, 1];
        let buckets = group_pairs_by_block(&pairs, &part, 2);
        let total: usize = buckets.iter().map(|b| b.len()).sum();
        assert_eq!(total, pairs.len());
        assert_eq!(buckets[0 * 2 + 0], vec![(0, 1), (1, 1)]);
        assert_eq!(buckets[0 * 2 + 1], vec![(0, 3)]);
        assert_eq!(buckets[1 * 2 + 1], vec![(2, 3)]);
    }
}
