//! Active-set screening (paper §2):
//!
//! ```text
//! S_Λ = {(i,j) : |(∇_Λ g)_ij| > λ_Λ  ∨  Λ_ij ≠ 0}
//! S_Θ = {(i,j) : |(∇_Θ g)_ij| > λ_Θ  ∨  Θ_ij ≠ 0}
//! ```
//!
//! Coordinates outside the active set provably stay zero for the current
//! quadratic model, so CD updates are restricted to S — the active sets
//! shrink toward the solution support over Newton iterations, which is the
//! main speedup lever of the QUIC family.
//!
//! These helpers take *dense* gradients (non-block solvers). The block
//! solver screens blockwise during its sweeps (see `solvers::alt_newton_bcd`)
//! and shares [`ActiveStats`] so the stopping rule comes free.

use super::objective::min_norm_subgrad;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;

/// Output of a screen: the active coordinate list plus the convergence
/// statistics that fall out of the same pass.
#[derive(Clone, Debug, Default)]
pub struct ActiveStats {
    /// ‖grad^S f‖₁ accumulated over screened coordinates.
    pub subgrad_l1: f64,
    /// Active coordinate count.
    pub count: usize,
}

/// Λ screen over the upper triangle (including diagonal). Returns active
/// (i,j) pairs with i ≤ j, and stats over the whole triangle.
pub fn lambda_active_dense(
    grad: &Mat,
    lambda: &SpRowMat,
    lam_l: f64,
) -> (Vec<(usize, usize)>, ActiveStats) {
    let q = grad.rows();
    let mut act = Vec::new();
    let mut stats = ActiveStats::default();
    for i in 0..q {
        let grow = grad.row(i);
        for j in i..q {
            let g = grow[j];
            let x = lambda.get(i, j);
            let s = min_norm_subgrad(g, x, lam_l);
            // Count both triangles in the norm (paper's ‖·‖₁ is over the
            // full matrix); diagonal once.
            stats.subgrad_l1 += if i == j { s.abs() } else { 2.0 * s.abs() };
            if x != 0.0 || g.abs() > lam_l {
                act.push((i, j));
            }
        }
    }
    stats.count = act.len();
    (act, stats)
}

/// Θ screen over all p×q coordinates.
pub fn theta_active_dense(
    grad: &Mat,
    theta: &SpRowMat,
    lam_t: f64,
) -> (Vec<(usize, usize)>, ActiveStats) {
    let (p, q) = (grad.rows(), grad.cols());
    let mut act = Vec::new();
    let mut stats = ActiveStats::default();
    for i in 0..p {
        let grow = grad.row(i);
        // Merge the sparse row with the dense gradient row.
        let srow = theta.row(i);
        let mut s_iter = srow.iter().peekable();
        for j in 0..q {
            let x = match s_iter.peek() {
                Some(&&(jj, v)) if jj == j => {
                    s_iter.next();
                    v
                }
                _ => 0.0,
            };
            let g = grow[j];
            stats.subgrad_l1 += min_norm_subgrad(g, x, lam_t).abs();
            if x != 0.0 || g.abs() > lam_t {
                act.push((i, j));
            }
        }
    }
    stats.count = act.len();
    (act, stats)
}

/// Active Λ pairs grouped by (block_z, block_r) for the block solver:
/// entry (i,j), i≤j goes to the (part[i], part[j]) bucket (unordered pair).
pub fn group_pairs_by_block(
    pairs: &[(usize, usize)],
    part: &[usize],
    k: usize,
) -> Vec<Vec<(usize, usize)>> {
    let mut buckets = vec![Vec::new(); k * k];
    for &(i, j) in pairs {
        let (a, b) = (part[i].min(part[j]), part[i].max(part[j]));
        buckets[a * k + b].push((i, j));
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_screen_picks_gradient_violators_and_support() {
        let q = 3;
        let mut grad = Mat::zeros(q, q);
        grad[(0, 1)] = 0.9; // above λ=0.5 → active
        grad[(1, 2)] = 0.2; // below → inactive unless supported
        let mut lam = SpRowMat::eye(q);
        lam.set_sym(1, 2, 0.7); // supported → active
        let (act, stats) = lambda_active_dense(&grad, &lam, 0.5);
        assert!(act.contains(&(0, 1)));
        assert!(act.contains(&(1, 2)));
        // diagonal always in support (Λ=I)
        assert!(act.contains(&(0, 0)));
        assert_eq!(stats.count, act.len());
        assert!(stats.subgrad_l1 > 0.0);
    }

    #[test]
    fn theta_screen() {
        let mut grad = Mat::zeros(2, 3);
        grad[(0, 0)] = 1.0;
        grad[(1, 2)] = -0.4;
        let mut th = SpRowMat::zeros(2, 3);
        th.set(1, 1, 0.3);
        let (act, _) = theta_active_dense(&grad, &th, 0.5);
        assert_eq!(act, vec![(0, 0), (1, 1)]);
    }

    #[test]
    fn subgrad_zero_at_optimum_like_point() {
        // grad within ±λ everywhere and empty support → subgrad 0.
        let grad = Mat::from_fn(4, 4, |_, _| 0.1);
        let th = SpRowMat::zeros(4, 4);
        let (act, stats) = theta_active_dense(&grad, &th, 0.5);
        assert!(act.is_empty());
        assert_eq!(stats.subgrad_l1, 0.0);
    }

    #[test]
    fn grouping_covers_all_pairs() {
        let pairs = vec![(0, 1), (2, 3), (0, 3), (1, 1)];
        let part = vec![0, 0, 1, 1];
        let buckets = group_pairs_by_block(&pairs, &part, 2);
        let total: usize = buckets.iter().map(|b| b.len()).sum();
        assert_eq!(total, pairs.len());
        assert_eq!(buckets[0 * 2 + 0], vec![(0, 1), (1, 1)]);
        assert_eq!(buckets[0 * 2 + 1], vec![(0, 3)]);
        assert_eq!(buckets[1 * 2 + 1], vec![(2, 3)]);
    }
}
