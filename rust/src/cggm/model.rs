//! CGGM parameters: the sparse output network Λ (q×q, symmetric positive
//! definite) and the sparse input→output map Θ (p×q).

use crate::linalg::sparse::SpRowMat;

/// Sparse CGGM parameter pair.
#[derive(Clone, Debug)]
pub struct CggmModel {
    /// Output-network precision-like matrix, q×q symmetric, PD.
    pub lambda: SpRowMat,
    /// Input→output mapping, p×q.
    pub theta: SpRowMat,
}

impl CggmModel {
    /// Paper initialization: Θ ← 0, Λ ← I_q.
    pub fn init(p: usize, q: usize) -> CggmModel {
        CggmModel {
            lambda: SpRowMat::eye(q),
            theta: SpRowMat::zeros(p, q),
        }
    }

    pub fn p(&self) -> usize {
        self.theta.rows()
    }

    pub fn q(&self) -> usize {
        self.lambda.rows()
    }

    /// ‖Λ‖₀ — paper's Table 1 reports this including both triangles + diag.
    pub fn lambda_nnz(&self) -> usize {
        self.lambda.nnz()
    }

    pub fn theta_nnz(&self) -> usize {
        self.theta.nnz()
    }

    /// Number of off-diagonal edges in the Λ network (each counted once).
    pub fn lambda_edges(&self) -> usize {
        let mut e = 0;
        for i in 0..self.q() {
            e += self.lambda.row(i).iter().filter(|&&(j, _)| j > i).count();
        }
        e
    }

    /// h(Λ,Θ) = λ_Λ‖Λ‖₁ + λ_Θ‖Θ‖₁.
    pub fn penalty(&self, lam_l: f64, lam_t: f64) -> f64 {
        lam_l * self.lambda.l1_norm() + lam_t * self.theta.l1_norm()
    }

    /// Drop exact zeros from both patterns.
    pub fn prune(&mut self) {
        self.lambda.prune(0.0);
        self.theta.prune(0.0);
    }

    pub fn bytes(&self) -> usize {
        self.lambda.bytes() + self.theta.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes() {
        let m = CggmModel::init(5, 3);
        assert_eq!(m.p(), 5);
        assert_eq!(m.q(), 3);
        assert_eq!(m.lambda_nnz(), 3);
        assert_eq!(m.theta_nnz(), 0);
        assert_eq!(m.lambda_edges(), 0);
    }

    #[test]
    fn penalty_and_edges() {
        let mut m = CggmModel::init(2, 3);
        m.lambda.set_sym(0, 1, -2.0);
        m.theta.set(1, 2, 3.0);
        // ‖Λ‖₁ = 3 (diag) + 2·2 (sym pair) = 7; ‖Θ‖₁ = 3.
        assert_eq!(m.penalty(1.0, 10.0), 7.0 + 30.0);
        assert_eq!(m.lambda_edges(), 1);
        m.theta.set(1, 2, 0.0);
        m.prune();
        assert_eq!(m.theta_nnz(), 0);
    }
}
