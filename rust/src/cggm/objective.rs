//! The l1-regularized CGGM objective (paper Eq. 1) and its gradients (Eq. 3).
//!
//! ```text
//! f(Λ,Θ) = g(Λ,Θ) + h(Λ,Θ)
//! g = -log|Λ| + tr(S_yy Λ + 2 S_xyᵀΘ + Λ⁻¹ΘᵀS_xxΘ)
//! h = λ_Λ‖Λ‖₁ + λ_Θ‖Θ‖₁
//! ∇_Λ g = S_yy - Σ - Ψ,   ∇_Θ g = 2 S_xy + 2 Γ
//! Σ = Λ⁻¹, Ψ = ΣΘᵀS_xxΘΣ, Γ = S_xxΘΣ
//! ```
//!
//! Everything is evaluated without dense p×p / p×q intermediates: sparse
//! patterns drive the trace terms and the q×n matrix `rt = (XΘ)ᵀ` carries
//! all S_xx interactions (n ≪ p, q).

use super::dataset::Dataset;
use super::factor::{CholKind, FactorError, LambdaFactor};
use super::model::CggmModel;
use crate::gemm::GemmEngine;
use crate::linalg::dense::Mat;
use crate::util::membudget::MemBudget;

/// Problem definition: data + regularization.
pub struct Objective<'a> {
    pub data: &'a Dataset,
    /// λ_Λ.
    pub lam_l: f64,
    /// λ_Θ.
    pub lam_t: f64,
    pub chol: CholKind,
    /// Budget every Λ factorization this objective performs is tracked
    /// against — including the per-trial factors of the line searches, which
    /// historically escaped `MemBudget::peak()`. Unlimited by default;
    /// solvers wire in their context's budget via [`Self::with_budget`].
    pub budget: MemBudget,
}

/// The smooth terms of f, kept separate so line search can update the linear
/// pieces in α analytically.
#[derive(Clone, Copy, Debug, Default)]
pub struct SmoothParts {
    pub logdet: f64,
    /// tr(S_yy Λ).
    pub tr_syy_lambda: f64,
    /// 2 tr(S_xyᵀ Θ).
    pub tr_sxy_theta: f64,
    /// tr(Λ⁻¹ Θᵀ S_xx Θ).
    pub tr_quad: f64,
}

impl SmoothParts {
    /// g(Λ,Θ).
    pub fn g(&self) -> f64 {
        -self.logdet + self.tr_syy_lambda + self.tr_sxy_theta + self.tr_quad
    }
}

impl<'a> Objective<'a> {
    pub fn new(data: &'a Dataset, lam_l: f64, lam_t: f64) -> Objective<'a> {
        Objective {
            data,
            lam_l,
            lam_t,
            chol: CholKind::Auto,
            budget: MemBudget::unlimited(),
        }
    }

    pub fn with_chol(mut self, kind: CholKind) -> Self {
        self.chol = kind;
        self
    }

    /// Track every factorization this objective performs against `budget`
    /// (see [`LambdaFactor::factor_tracked`]).
    pub fn with_budget(mut self, budget: MemBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Factor Λ with this objective's Cholesky strategy, budget-tracked.
    /// This is the one factorization entry point for the solvers and the
    /// line searches, so trial factors can never escape the accounting.
    pub fn factor_lambda(
        &self,
        lambda: &crate::linalg::sparse::SpRowMat,
        engine: &dyn GemmEngine,
    ) -> Result<LambdaFactor, FactorError> {
        LambdaFactor::factor_tracked(lambda, self.chol, engine, &self.budget)
    }

    /// tr(S_yy A) for sparse symmetric A — O(nnz(A)·n).
    pub fn tr_syy_sparse(&self, a: &crate::linalg::sparse::SpRowMat) -> f64 {
        let mut t = 0.0;
        for i in 0..a.rows() {
            for &(j, v) in a.row(i) {
                t += v * self.data.syy(i, j);
            }
        }
        t
    }

    /// 2 tr(S_xyᵀ A) for sparse A (p×q) — O(nnz(A)·n).
    pub fn tr_sxy_sparse(&self, a: &crate::linalg::sparse::SpRowMat) -> f64 {
        let mut t = 0.0;
        for i in 0..a.rows() {
            for &(j, v) in a.row(i) {
                t += v * self.data.sxy(i, j);
            }
        }
        2.0 * t
    }

    /// Full objective evaluation. Returns (f, parts, factor, rt).
    pub fn eval(
        &self,
        model: &CggmModel,
        engine: &dyn GemmEngine,
    ) -> Result<(f64, SmoothParts, LambdaFactor, Mat), FactorError> {
        let factor = self.factor_lambda(&model.lambda, engine)?;
        let rt = self.data.xtheta_t(&model.theta);
        let parts = SmoothParts {
            logdet: factor.logdet(),
            tr_syy_lambda: self.tr_syy_sparse(&model.lambda),
            tr_sxy_theta: self.tr_sxy_sparse(&model.theta),
            tr_quad: factor.trace_quad(&rt),
        };
        let f = parts.g() + model.penalty(self.lam_l, self.lam_t);
        Ok((f, parts, factor, rt))
    }

    /// Objective value only.
    pub fn value(&self, model: &CggmModel, engine: &dyn GemmEngine) -> Result<f64, FactorError> {
        Ok(self.eval(model, engine)?.0)
    }

    /// Dense ∇_Λ g = S_yy - Σ - Ψ given precomputed Σ and Ψ.
    pub fn grad_lambda_dense(&self, sigma: &Mat, psi: &Mat, engine: &dyn GemmEngine) -> Mat {
        let mut g = self.data.syy_dense(engine);
        g.add_scaled(-1.0, sigma);
        g.add_scaled(-1.0, psi);
        g
    }

    /// Dense ∇_Θ g = 2 S_xy + 2 Γ, Γ = S_xxΘΣ computed n-factored:
    /// Γ = Xᵀ(XΘΣ)/n = gemm_nt(xt, Σ·rt)/n. O(npq) but pure GEMM.
    pub fn grad_theta_dense(&self, sigma: &Mat, rt: &Mat, engine: &dyn GemmEngine) -> Mat {
        let d = self.data;
        let sxy = d.sxy_dense(engine);
        let mut sr = Mat::zeros(d.q(), d.n());
        let mut g = Mat::zeros(d.p(), d.q());
        self.grad_theta_into(&sxy, sigma, rt, engine, &mut sr, &mut g);
        g
    }

    /// Allocation-free ∇_Θ g given the cached `sxy` and two workspace
    /// buffers: `sr` (q×n, overwritten with Σ·rt) and `gt` (p×q, the result).
    pub fn grad_theta_into(
        &self,
        sxy: &Mat,
        sigma: &Mat,
        rt: &Mat,
        engine: &dyn GemmEngine,
        sr: &mut Mat,
        gt: &mut Mat,
    ) {
        // sr = Σ · rt  (q×n)
        engine.gemm(1.0, sigma, rt, 0.0, sr);
        self.grad_theta_from_sr(sxy, sr, engine, gt);
    }

    /// ∇_Θ g from an already-computed `sr = Σ·rt` panel (solvers that also
    /// build Ψ share one panel and skip the second O(q²n) GEMM).
    pub fn grad_theta_from_sr(&self, sxy: &Mat, sr: &Mat, engine: &dyn GemmEngine, gt: &mut Mat) {
        let d = self.data;
        // ∇_Θ = 2S_xy + 2Γ, Γ = gemm_nt(xt, sr)/n  (p×q)
        gt.copy_from(sxy);
        gt.scale(2.0);
        d.gemm_nt_x(engine, 2.0 * d.inv_n(), sr, 1.0, gt);
    }

    /// Single ∇_Λ entry from the dense pieces the CD loop already holds:
    /// `(∇_Λ g)_ij = (S_yy)_ij − Σ_ij − Ψ_ij`. The screening path's
    /// per-coordinate form of [`Self::grad_lambda_dense`].
    #[inline]
    pub fn grad_lambda_entry(syy: &Mat, sigma: &Mat, psi: &Mat, i: usize, j: usize) -> f64 {
        syy[(i, j)] - sigma[(i, j)] - psi[(i, j)]
    }

    /// Single ∇_Θ entry from the shared `sr = Σ·R̃ᵀ` panel:
    /// `(∇_Θ g)_ij = 2(S_xy)_ij + 2Γ_ij`, `Γ_ij = x_iᵀ(XΘΣ)_j / n =
    /// ⟨xt_i, sr_j⟩ / n` — O(n) per coordinate, so restricted screens touch
    /// only their allowed entries instead of paying the dense O(npq) GEMM
    /// of [`Self::grad_theta_dense`].
    #[inline]
    pub fn grad_theta_entry(&self, sxy: &Mat, sr: &Mat, i: usize, j: usize) -> f64 {
        2.0 * sxy[(i, j)]
            + 2.0
                * self.data.inv_n()
                * self
                    .data
                    .with_x_row(i, |xi| crate::linalg::dense::dot(xi, sr.row(j)))
    }

    /// [`Self::grad_theta_entry`] reading `(S_xy)_ij` through the demand-
    /// driven tile cache instead of a dense p×q matrix — the screening paths'
    /// entry point under [`crate::solvers::StatMode::Tiled`]: a restricted
    /// screen touches only the `S_xy` tiles its allowed coordinates live in.
    #[inline]
    pub fn grad_theta_entry_tiled(
        &self,
        tiles: &crate::cggm::tiles::TileStore,
        sr: &Mat,
        i: usize,
        j: usize,
    ) -> f64 {
        2.0 * tiles.sxy_entry(i, j)
            + 2.0
                * self.data.inv_n()
                * self
                    .data
                    .with_x_row(i, |xi| crate::linalg::dense::dot(xi, sr.row(j)))
    }

    /// Ψ = ΣΘᵀS_xxΘΣ computed as Gram of rows of `sr = Σ·rt` divided by n.
    pub fn psi_dense(&self, sigma: &Mat, rt: &Mat, engine: &dyn GemmEngine) -> Mat {
        let d = self.data;
        let mut sr = Mat::zeros(d.q(), d.n());
        let mut psi = Mat::zeros(d.q(), d.q());
        self.psi_into(sigma, rt, engine, &mut sr, &mut psi);
        psi
    }

    /// Allocation-free Ψ into workspace buffers: `sr` (q×n) receives Σ·rt
    /// (callers may reuse it, e.g. for Γ), `psi` (q×q) the result.
    pub fn psi_into(
        &self,
        sigma: &Mat,
        rt: &Mat,
        engine: &dyn GemmEngine,
        sr: &mut Mat,
        psi: &mut Mat,
    ) {
        engine.gemm(1.0, sigma, rt, 0.0, sr);
        engine.gemm_nt(self.data.inv_n(), sr, sr, 0.0, psi);
        psi.symmetrize();
    }
}

/// Average held-out negative log-likelihood of a fitted CGGM on `data`:
/// the conditional density is y|x ~ N(−Λ⁻¹Θᵀx, Λ⁻¹), whose per-sample NLL
/// averages to
///
/// ```text
/// NLL = ½ [ g(Λ,Θ; S_test) + q·log 2π ]
/// ```
///
/// — the *smooth* objective evaluated with the held-out covariance
/// statistics (no penalty term). This is the model-selection score of
/// [`crate::coordinator::cross_validate`]: lower is better, and unlike the
/// penalized objective it is comparable across λ values.
pub fn heldout_nll(
    model: &CggmModel,
    data: &Dataset,
    engine: &dyn GemmEngine,
) -> Result<f64, FactorError> {
    let obj = Objective::new(data, 0.0, 0.0);
    let (g, _, _, _) = obj.eval(model, engine)?;
    Ok(0.5 * (g + data.q() as f64 * (2.0 * std::f64::consts::PI).ln()))
}

/// Minimum-norm subgradient contribution of one coordinate (paper §5 stopping
/// rule): `g + λ·sign(x)` on the support, `max(|g|-λ, 0)` off it.
#[inline]
pub fn min_norm_subgrad(grad: f64, x: f64, lam: f64) -> f64 {
    if x != 0.0 {
        grad + lam * x.signum()
    } else {
        (grad.abs() - lam).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::linalg::sparse::SpRowMat;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_close, property};

    fn small_problem(rng: &mut Rng, n: usize, p: usize, q: usize) -> (Dataset, CggmModel) {
        let data = Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        );
        let mut model = CggmModel::init(p, q);
        // Random sparse Λ (diagonally dominant) and Θ.
        for i in 0..q {
            model.lambda.set(i, i, 2.0 + rng.uniform());
        }
        for _ in 0..q {
            let (i, j) = (rng.below(q), rng.below(q));
            if i != j {
                model.lambda.set_sym(i, j, 0.2 * rng.normal());
            }
        }
        for i in 0..q {
            let rowsum: f64 = model.lambda.row(i).iter().map(|e| e.1.abs()).sum();
            let d = model.lambda.get(i, i).abs();
            model.lambda.set(i, i, rowsum - d + 1.0 + rng.uniform());
        }
        for _ in 0..p {
            model.theta.set(rng.below(p), rng.below(q), rng.normal() * 0.5);
        }
        (data, model)
    }

    /// Brute-force objective via dense algebra.
    fn dense_objective(
        data: &Dataset,
        model: &CggmModel,
        lam_l: f64,
        lam_t: f64,
        eng: &dyn GemmEngine,
    ) -> f64 {
        let q = data.q();
        let lam_d = model.lambda.to_dense();
        let th_d = model.theta.to_dense();
        let chol = crate::linalg::chol_dense::DenseChol::factor(&lam_d, eng).unwrap();
        let sigma = chol.inverse(eng);
        let syy = data.syy_dense(eng);
        let sxx = data.sxx_dense(eng);
        let sxy = data.sxy_dense(eng);
        let mut tr1 = 0.0;
        for i in 0..q {
            for j in 0..q {
                tr1 += syy[(i, j)] * lam_d[(j, i)];
            }
        }
        let mut tr2 = 0.0;
        for i in 0..data.p() {
            for j in 0..q {
                tr2 += sxy[(i, j)] * th_d[(i, j)];
            }
        }
        // tr(Σ Θᵀ S_xx Θ)
        let mut sxt = Mat::zeros(data.p(), q);
        eng.gemm(1.0, &sxx, &th_d, 0.0, &mut sxt);
        let mut tts = Mat::zeros(q, q);
        eng.gemm_tn(1.0, &th_d, &sxt, 0.0, &mut tts);
        let mut tr3 = 0.0;
        for i in 0..q {
            for j in 0..q {
                tr3 += sigma[(i, j)] * tts[(j, i)];
            }
        }
        -chol.logdet() + tr1 + 2.0 * tr2 + tr3
            + lam_l * model.lambda.l1_norm()
            + lam_t * model.theta.l1_norm()
    }

    #[test]
    fn objective_matches_dense_bruteforce() {
        property(25, |rng| {
            let (n, p, q) = (3 + rng.below(8), 2 + rng.below(6), 2 + rng.below(6));
            let (data, model) = small_problem(rng, n, p, q);
            let eng = NativeGemm::new(1);
            let obj = Objective::new(&data, 0.3, 0.2);
            let (f, _, _, _) = obj.eval(&model, &eng).map_err(|e| e.to_string())?;
            let want = dense_objective(&data, &model, 0.3, 0.2, &eng);
            check_close(f, want, 1e-9, "objective")
        });
    }

    #[test]
    fn gradients_match_finite_differences() {
        property(10, |rng| {
            let (n, p, q) = (6, 3, 3);
            let (data, model) = small_problem(rng, n, p, q);
            let eng = NativeGemm::new(1);
            let obj = Objective::new(&data, 0.0, 0.0); // smooth part only
            let (_, _, factor, rt) = obj.eval(&model, &eng).map_err(|e| e.to_string())?;
            let sigma = factor.inverse_dense(&eng);
            let psi = obj.psi_dense(&sigma, &rt, &eng);
            let gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
            let gt = obj.grad_theta_dense(&sigma, &rt, &eng);
            let h = 1e-6;
            // Λ finite difference (symmetric pair perturbation / diagonal).
            for i in 0..q {
                for j in i..q {
                    let mut mp = model.clone();
                    mp.lambda.add_sym(i, j, h);
                    let mut mm = model.clone();
                    mm.lambda.add_sym(i, j, -h);
                    let fp = obj.value(&mp, &eng).map_err(|e| e.to_string())?;
                    let fm = obj.value(&mm, &eng).map_err(|e| e.to_string())?;
                    let fd = (fp - fm) / (2.0 * h);
                    // Symmetric perturbation hits both (i,j) and (j,i).
                    let want = if i == j { gl[(i, i)] } else { 2.0 * gl[(i, j)] };
                    check_close(fd, want, 2e-4, &format!("∇Λ[{i},{j}]"))?;
                }
            }
            // Θ finite difference.
            for i in 0..p {
                for j in 0..q {
                    let mut mp = model.clone();
                    mp.theta.add(i, j, h);
                    let mut mm = model.clone();
                    mm.theta.add(i, j, -h);
                    let fp = obj.value(&mp, &eng).map_err(|e| e.to_string())?;
                    let fm = obj.value(&mm, &eng).map_err(|e| e.to_string())?;
                    let fd = (fp - fm) / (2.0 * h);
                    check_close(fd, gt[(i, j)], 2e-4, &format!("∇Θ[{i},{j}]"))?;
                }
            }
            Ok(())
        });
    }

    /// The per-coordinate gradient entries used by path-level screening
    /// ([`Objective::grad_lambda_entry`] / [`Objective::grad_theta_entry`])
    /// must match (a) the dense gradients and (b) central finite differences
    /// of the smooth objective — the directional derivative along one
    /// coordinate — over random small problems.
    #[test]
    fn grad_entries_match_dense_and_finite_differences() {
        property(10, |rng| {
            let (n, p, q) = (5 + rng.below(4), 2 + rng.below(3), 2 + rng.below(3));
            let (data, model) = small_problem(rng, n, p, q);
            let eng = NativeGemm::new(1);
            let obj = Objective::new(&data, 0.0, 0.0);
            let (_, _, factor, rt) = obj.eval(&model, &eng).map_err(|e| e.to_string())?;
            let sigma = factor.inverse_dense(&eng);
            let syy = data.syy_dense(&eng);
            let sxy = data.sxy_dense(&eng);
            let mut sr = Mat::zeros(q, n);
            let mut psi = Mat::zeros(q, q);
            obj.psi_into(&sigma, &rt, &eng, &mut sr, &mut psi);
            let gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
            let gt = obj.grad_theta_dense(&sigma, &rt, &eng);
            let h = 1e-6;
            for i in 0..q {
                for j in i..q {
                    let e = Objective::grad_lambda_entry(&syy, &sigma, &psi, i, j);
                    check_close(e, gl[(i, j)], 1e-12, &format!("Λ entry vs dense [{i},{j}]"))?;
                    // Directional derivative along the symmetric pair.
                    let mut mp = model.clone();
                    mp.lambda.add_sym(i, j, h);
                    let mut mm = model.clone();
                    mm.lambda.add_sym(i, j, -h);
                    let fp = obj.value(&mp, &eng).map_err(|e| e.to_string())?;
                    let fm = obj.value(&mm, &eng).map_err(|e| e.to_string())?;
                    let fd = (fp - fm) / (2.0 * h);
                    let want = if i == j { e } else { 2.0 * e };
                    check_close(fd, want, 2e-4, &format!("Λ entry FD [{i},{j}]"))?;
                }
            }
            let budget = crate::util::membudget::MemBudget::unlimited();
            let tiles = crate::cggm::tiles::TileStore::new(&data, &eng, budget, 2);
            for i in 0..p {
                for j in 0..q {
                    let e = obj.grad_theta_entry(&sxy, &sr, i, j);
                    check_close(e, gt[(i, j)], 1e-10, &format!("Θ entry vs dense [{i},{j}]"))?;
                    // The tiled read is the same entry through the tile cache.
                    let et = obj.grad_theta_entry_tiled(&tiles, &sr, i, j);
                    check_close(et, e, 1e-12, &format!("Θ entry tiled [{i},{j}]"))?;
                    let mut mp = model.clone();
                    mp.theta.add(i, j, h);
                    let mut mm = model.clone();
                    mm.theta.add(i, j, -h);
                    let fp = obj.value(&mp, &eng).map_err(|e| e.to_string())?;
                    let fm = obj.value(&mm, &eng).map_err(|e| e.to_string())?;
                    let fd = (fp - fm) / (2.0 * h);
                    check_close(fd, e, 2e-4, &format!("Θ entry FD [{i},{j}]"))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn heldout_nll_matches_direct_density_evaluation() {
        // NLL from the smooth objective must equal the per-sample Gaussian
        // density −log N(y; −Λ⁻¹Θᵀx, Λ⁻¹) averaged directly.
        property(10, |rng| {
            let (n, p, q) = (4 + rng.below(5), 2 + rng.below(3), 2 + rng.below(3));
            let (data, model) = small_problem(rng, n, p, q);
            let eng = NativeGemm::new(1);
            let lam_d = model.lambda.to_dense();
            let th_d = model.theta.to_dense();
            let chol = crate::linalg::chol_dense::DenseChol::factor(&lam_d, &eng)
                .map_err(|e| e.to_string())?;
            let sigma = chol.inverse(&eng);
            let mut total = 0.0;
            for s in 0..n {
                // residual r = y + Λ⁻¹Θᵀx; NLL_s = ½(q log 2π − log|Λ| + rᵀΛr)
                let x: Vec<f64> = (0..p).map(|i| data.xt()[(i, s)]).collect();
                let tx: Vec<f64> = (0..q)
                    .map(|j| (0..p).map(|i| th_d[(i, j)] * x[i]).sum::<f64>())
                    .collect();
                let mu: Vec<f64> = (0..q)
                    .map(|j| -(0..q).map(|k| sigma[(j, k)] * tx[k]).sum::<f64>())
                    .collect();
                let r: Vec<f64> = (0..q).map(|j| data.yt()[(j, s)] - mu[j]).collect();
                let mut quad = 0.0;
                for a in 0..q {
                    for b in 0..q {
                        quad += r[a] * lam_d[(a, b)] * r[b];
                    }
                }
                total += 0.5
                    * (q as f64 * (2.0 * std::f64::consts::PI).ln() - chol.logdet() + quad);
            }
            let want = total / n as f64;
            let got = heldout_nll(&model, &data, &eng).map_err(|e| e.to_string())?;
            check_close(got, want, 1e-9, "held-out NLL")
        });
    }

    #[test]
    fn min_norm_subgrad_cases() {
        assert_eq!(min_norm_subgrad(2.0, 1.0, 0.5), 2.5);
        assert_eq!(min_norm_subgrad(2.0, -1.0, 0.5), 1.5);
        assert_eq!(min_norm_subgrad(2.0, 0.0, 0.5), 1.5);
        assert_eq!(min_norm_subgrad(0.3, 0.0, 0.5), 0.0);
    }

    #[test]
    fn psi_positive_semidefinite_diag() {
        let mut rng = Rng::new(11);
        let (data, model) = small_problem(&mut rng, 8, 4, 5);
        let eng = NativeGemm::new(1);
        let obj = Objective::new(&data, 0.1, 0.1);
        let (_, _, factor, rt) = obj.eval(&model, &eng).unwrap();
        let sigma = factor.inverse_dense(&eng);
        let psi = obj.psi_dense(&sigma, &rt, &eng);
        for i in 0..data.q() {
            assert!(psi[(i, i)] >= -1e-12);
        }
        let mut s = SpRowMat::from_dense(&psi, 0.0);
        s.prune(1e-12);
        assert!(s.is_symmetric(1e-9));
    }
}
