//! Datasets: inputs X (n×p) and outputs Y (n×q), stored feature-major.
//!
//! `xt` is p×n and `yt` is q×n so that every covariance entry the CD loops
//! need — `(S_xx)_ij = x_iᵀx_j/n`, `(S_yy)_ij`, `(S_xy)_ij` — is a dot of two
//! contiguous rows, and covariance *blocks* are `gemm_nt` row-Gram products.
//! n is small relative to p, q in all of the paper's workloads, which is why
//! rows of `xt` work as an implicit representation of the huge `S_xx`
//! (§4.2: "we store only one row of S_xx at a time").

use crate::gemm::GemmEngine;
use crate::linalg::dense::{dot, Mat};
use crate::linalg::sparse::SpRowMat;

/// A regression dataset for CGGM estimation.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Inputs, feature-major: p × n.
    pub xt: Mat,
    /// Outputs, feature-major: q × n.
    pub yt: Mat,
}

/// A contiguous feature-major block of k samples — the unit of the sliding
/// window's append/evict API and the rank-k panels of the incremental Gram
/// corrections (`S ← (n·S + X_a·X_aᵀ − X_r·X_rᵀ)/n'`).
#[derive(Clone, Debug)]
pub struct SampleBlock {
    /// Inputs, feature-major: p × k.
    pub xt: Mat,
    /// Outputs, feature-major: q × k.
    pub yt: Mat,
}

impl SampleBlock {
    pub fn new(xt: Mat, yt: Mat) -> SampleBlock {
        assert_eq!(xt.cols(), yt.cols(), "sample count mismatch");
        SampleBlock { xt, yt }
    }

    /// Number of samples in the block.
    #[inline]
    pub fn k(&self) -> usize {
        self.xt.cols()
    }

    /// Horizontal concatenation (self's samples first) — how a delta merges
    /// two appends (or two evictions) into one rank-k panel.
    pub fn concat(&self, other: &SampleBlock) -> SampleBlock {
        assert_eq!(self.xt.rows(), other.xt.rows(), "p mismatch");
        assert_eq!(self.yt.rows(), other.yt.rows(), "q mismatch");
        let k = self.k();
        let xt = Mat::from_fn(self.xt.rows(), k + other.k(), |i, c| {
            if c < k {
                self.xt[(i, c)]
            } else {
                other.xt[(i, c - k)]
            }
        });
        let yt = Mat::from_fn(self.yt.rows(), k + other.k(), |j, c| {
            if c < k {
                self.yt[(j, c)]
            } else {
                other.yt[(j, c - k)]
            }
        });
        SampleBlock::new(xt, yt)
    }
}

/// One window transition: the samples that entered, the samples that left,
/// and the sample count the statistics were computed at *before* the
/// transition. `SolverContext::update_stats` consumes this to apply the
/// symmetric rank-k correction to whatever statistics are materialized.
#[derive(Clone, Debug)]
pub struct WindowDelta {
    /// Samples appended (rank-k update panel), if any.
    pub added: Option<SampleBlock>,
    /// Samples evicted (rank-k downdate panel), if any.
    pub removed: Option<SampleBlock>,
    /// Window occupancy before the transition.
    pub old_n: usize,
}

impl WindowDelta {
    /// An empty delta starting from a window of `old_n` samples.
    pub fn new(old_n: usize) -> WindowDelta {
        WindowDelta {
            added: None,
            removed: None,
            old_n,
        }
    }

    /// Fold an appended block into the delta.
    pub fn record_append(&mut self, block: SampleBlock) {
        if block.k() == 0 {
            return;
        }
        self.added = Some(match self.added.take() {
            Some(prev) => prev.concat(&block),
            None => block,
        });
    }

    /// Fold an evicted block into the delta.
    pub fn record_evict(&mut self, block: SampleBlock) {
        if block.k() == 0 {
            return;
        }
        self.removed = Some(match self.removed.take() {
            Some(prev) => prev.concat(&block),
            None => block,
        });
    }

    /// Samples appended / removed across the transition.
    pub fn added_k(&self) -> usize {
        self.added.as_ref().map_or(0, SampleBlock::k)
    }
    pub fn removed_k(&self) -> usize {
        self.removed.as_ref().map_or(0, SampleBlock::k)
    }

    /// Window occupancy after the transition.
    pub fn new_n(&self) -> usize {
        self.old_n + self.added_k() - self.removed_k()
    }

    /// True when nothing entered or left (the identity correction).
    pub fn is_empty(&self) -> bool {
        self.added_k() == 0 && self.removed_k() == 0
    }
}

impl Dataset {
    pub fn new(xt: Mat, yt: Mat) -> Dataset {
        assert_eq!(xt.cols(), yt.cols(), "sample count mismatch");
        Dataset { xt, yt }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.xt.cols()
    }
    #[inline]
    pub fn p(&self) -> usize {
        self.xt.rows()
    }
    #[inline]
    pub fn q(&self) -> usize {
        self.yt.rows()
    }

    #[inline]
    pub fn inv_n(&self) -> f64 {
        1.0 / self.n() as f64
    }

    /// (S_yy)_ij on demand — O(n).
    #[inline]
    pub fn syy(&self, i: usize, j: usize) -> f64 {
        dot(self.yt.row(i), self.yt.row(j)) * self.inv_n()
    }

    /// (S_xy)_ij on demand — O(n).
    #[inline]
    pub fn sxy(&self, i: usize, j: usize) -> f64 {
        dot(self.xt.row(i), self.yt.row(j)) * self.inv_n()
    }

    /// (S_xx)_ij on demand — O(n).
    #[inline]
    pub fn sxx(&self, i: usize, j: usize) -> f64 {
        dot(self.xt.row(i), self.xt.row(j)) * self.inv_n()
    }

    /// Row i of S_xx restricted to `cols`, appended into `out`
    /// (the paper's §4.2 row-wise-sparsity trick: skip entries whose Θ row
    /// is empty). O(n·|cols|).
    pub fn sxx_row_restricted(&self, i: usize, cols: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(cols.len());
        let xi = self.xt.row(i);
        let inv_n = self.inv_n();
        for &k in cols {
            out.push(dot(xi, self.xt.row(k)) * inv_n);
        }
    }

    /// Dense S_yy (q×q) — non-block solvers only.
    pub fn syy_dense(&self, engine: &dyn GemmEngine) -> Mat {
        let mut s = Mat::zeros(self.q(), self.q());
        engine.gemm_nt(self.inv_n(), &self.yt, &self.yt, 0.0, &mut s);
        s.symmetrize();
        s
    }

    /// Dense S_xx (p×p) — small p only.
    pub fn sxx_dense(&self, engine: &dyn GemmEngine) -> Mat {
        let mut s = Mat::zeros(self.p(), self.p());
        engine.gemm_nt(self.inv_n(), &self.xt, &self.xt, 0.0, &mut s);
        s.symmetrize();
        s
    }

    /// Dense S_xy (p×q).
    pub fn sxy_dense(&self, engine: &dyn GemmEngine) -> Mat {
        let mut s = Mat::zeros(self.p(), self.q());
        engine.gemm_nt(self.inv_n(), &self.xt, &self.yt, 0.0, &mut s);
        s
    }

    /// Stream the feature rows `rows` of X into `panel` (which must be
    /// `rows.len() × n`). This is the tile layer's *only* access to X during
    /// tile construction: builders that go through it never need a second
    /// resident copy of X, and an out-of-core `Dataset` variant can later
    /// satisfy the same contract by reading the panel from storage.
    pub fn x_panel_into(&self, rows: std::ops::Range<usize>, panel: &mut Mat) {
        assert!(rows.end <= self.p(), "X panel rows out of range");
        assert_eq!((panel.rows(), panel.cols()), (rows.len(), self.n()));
        for (k, i) in rows.enumerate() {
            panel.row_mut(k).copy_from_slice(self.xt.row(i));
        }
    }

    /// Stream the feature rows `rows` of Y into `panel` (`rows.len() × n`);
    /// the Y-side counterpart of [`Self::x_panel_into`].
    pub fn y_panel_into(&self, rows: std::ops::Range<usize>, panel: &mut Mat) {
        assert!(rows.end <= self.q(), "Y panel rows out of range");
        assert_eq!((panel.rows(), panel.cols()), (rows.len(), self.n()));
        for (k, i) in rows.enumerate() {
            panel.row_mut(k).copy_from_slice(self.yt.row(i));
        }
    }

    /// R̃ᵀ = (XΘ)ᵀ as a q×n matrix (`rt.row(j)` = j-th column of XΘ).
    /// O(nnz(Θ)·n); the basis of every Ψ/trace computation.
    pub fn xtheta_t(&self, theta: &SpRowMat) -> Mat {
        let mut rt = Mat::zeros(self.q(), self.n());
        self.xtheta_t_into(theta, &mut rt);
        rt
    }

    /// [`Self::xtheta_t`] into a preallocated q×n buffer (overwritten) — the
    /// workspace-arena path used by the solvers' iteration loops.
    pub fn xtheta_t_into(&self, theta: &SpRowMat, rt: &mut Mat) {
        assert_eq!(theta.rows(), self.p());
        assert_eq!(theta.cols(), self.q());
        assert_eq!((rt.rows(), rt.cols()), (self.q(), self.n()));
        rt.fill(0.0);
        for i in 0..self.p() {
            let row = theta.row(i);
            if row.is_empty() {
                continue;
            }
            let xi = self.xt.row(i);
            for &(j, v) in row {
                crate::linalg::dense::axpy(v, xi, rt.row_mut(j));
            }
        }
    }

    /// Copy out the sample columns in `idx` (order preserved, duplicates
    /// allowed) — the K-fold splitter of [`crate::coordinator::cross_validate`].
    /// O((p+q)·|idx|); feature-major layout means each sample is a strided
    /// column gather.
    pub fn select_samples(&self, idx: &[usize]) -> Dataset {
        let m = idx.len();
        for &s in idx {
            assert!(s < self.n(), "sample index {s} out of range (n={})", self.n());
        }
        let xt = Mat::from_fn(self.p(), m, |i, k| self.xt[(i, idx[k])]);
        let yt = Mat::from_fn(self.q(), m, |j, k| self.yt[(j, idx[k])]);
        Dataset::new(xt, yt)
    }

    /// Append `k` samples given as feature-major panels (`xa`: p × k,
    /// `ya`: q × k); the new samples become the window's newest columns.
    /// O((p+q)·(n+k)) copy — lower-order against the O(k·(p+q)²) statistics
    /// correction the append is paired with, and it keeps `xt`/`yt`
    /// contiguous, which every GEMM consumer relies on.
    pub fn append_samples(&mut self, xa: &Mat, ya: &Mat) {
        assert_eq!(xa.rows(), self.p(), "appended X feature count mismatch");
        assert_eq!(ya.rows(), self.q(), "appended Y feature count mismatch");
        assert_eq!(xa.cols(), ya.cols(), "appended sample count mismatch");
        let (n, k) = (self.n(), xa.cols());
        if k == 0 {
            return;
        }
        let grow = |old: &Mat, add: &Mat| {
            let mut out = Mat::zeros(old.rows(), n + k);
            for i in 0..old.rows() {
                let dst = out.row_mut(i);
                dst[..n].copy_from_slice(old.row(i));
                dst[n..].copy_from_slice(add.row(i));
            }
            out
        };
        self.xt = grow(&self.xt, xa);
        self.yt = grow(&self.yt, ya);
    }

    /// Append the samples of a [`SampleBlock`] (convenience over
    /// [`Self::append_samples`]).
    pub fn append_block(&mut self, block: &SampleBlock) {
        self.append_samples(&block.xt, &block.yt);
    }

    /// Drop the `k` oldest samples (the window's leftmost columns), returning
    /// them as the rank-k downdate panel. O((p+q)·n).
    pub fn evict_oldest(&mut self, k: usize) -> SampleBlock {
        let k = k.min(self.n());
        let n = self.n();
        let split = |old: &Mat| {
            let head = Mat::from_fn(old.rows(), k, |i, c| old[(i, c)]);
            let mut tail = Mat::zeros(old.rows(), n - k);
            for i in 0..old.rows() {
                tail.row_mut(i).copy_from_slice(&old.row(i)[k..]);
            }
            (head, tail)
        };
        let (xh, xtail) = split(&self.xt);
        let (yh, ytail) = split(&self.yt);
        self.xt = xtail;
        self.yt = ytail;
        SampleBlock::new(xh, yh)
    }

    pub fn bytes(&self) -> usize {
        self.xt.bytes() + self.yt.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_close, property};

    fn random_dataset(rng: &mut Rng, n: usize, p: usize, q: usize) -> Dataset {
        Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        )
    }

    #[test]
    fn covariance_entries_match_dense() {
        property(20, |rng| {
            let (n, p, q) = (2 + rng.below(10), 1 + rng.below(8), 1 + rng.below(8));
            let d = random_dataset(rng, n, p, q);
            let eng = NativeGemm::new(1);
            let syy = d.syy_dense(&eng);
            let sxx = d.sxx_dense(&eng);
            let sxy = d.sxy_dense(&eng);
            for i in 0..q {
                for j in 0..q {
                    check_close(d.syy(i, j), syy[(i, j)], 1e-12, "syy")?;
                }
            }
            for i in 0..p {
                for j in 0..p {
                    check_close(d.sxx(i, j), sxx[(i, j)], 1e-12, "sxx")?;
                }
                for j in 0..q {
                    check_close(d.sxy(i, j), sxy[(i, j)], 1e-12, "sxy")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sxx_row_restricted_matches() {
        let mut rng = Rng::new(2);
        let d = random_dataset(&mut rng, 7, 10, 3);
        let cols = vec![0, 3, 9];
        let mut out = Vec::new();
        d.sxx_row_restricted(4, &cols, &mut out);
        for (k, &c) in cols.iter().enumerate() {
            assert!((out[k] - d.sxx(4, c)).abs() < 1e-14);
        }
    }

    #[test]
    fn select_samples_gathers_columns() {
        let mut rng = Rng::new(9);
        let d = random_dataset(&mut rng, 6, 4, 3);
        let sub = d.select_samples(&[5, 0, 2]);
        assert_eq!((sub.p(), sub.q(), sub.n()), (4, 3, 3));
        for i in 0..4 {
            assert_eq!(sub.xt[(i, 0)], d.xt[(i, 5)]);
            assert_eq!(sub.xt[(i, 1)], d.xt[(i, 0)]);
            assert_eq!(sub.xt[(i, 2)], d.xt[(i, 2)]);
        }
        for j in 0..3 {
            assert_eq!(sub.yt[(j, 0)], d.yt[(j, 5)]);
        }
        // Complementary splits partition the covariance mass:
        // n·S_full = n₁·S₁ + n₂·S₂ entrywise.
        let a = d.select_samples(&[0, 1, 2]);
        let b = d.select_samples(&[3, 4, 5]);
        let full = d.syy(1, 2) * d.n() as f64;
        let split = a.syy(1, 2) * a.n() as f64 + b.syy(1, 2) * b.n() as f64;
        assert!((full - split).abs() < 1e-10);
    }

    #[test]
    fn panel_loaders_stream_feature_rows() {
        let mut rng = Rng::new(4);
        let d = random_dataset(&mut rng, 6, 9, 5);
        let mut px = Mat::zeros(3, 6);
        d.x_panel_into(4..7, &mut px);
        for k in 0..3 {
            assert_eq!(px.row(k), d.xt.row(4 + k));
        }
        let mut py = Mat::zeros(2, 6);
        d.y_panel_into(3..5, &mut py);
        for k in 0..2 {
            assert_eq!(py.row(k), d.yt.row(3 + k));
        }
    }

    #[test]
    fn append_and_evict_slide_the_window() {
        let mut rng = Rng::new(12);
        let base = random_dataset(&mut rng, 5, 4, 3);
        let add = random_dataset(&mut rng, 2, 4, 3);
        let mut d = base.clone();
        d.append_samples(&add.xt, &add.yt);
        assert_eq!(d.n(), 7);
        for i in 0..4 {
            assert_eq!(&d.xt.row(i)[..5], base.xt.row(i));
            assert_eq!(&d.xt.row(i)[5..], add.xt.row(i));
        }
        for j in 0..3 {
            assert_eq!(&d.yt.row(j)[5..], add.yt.row(j));
        }
        let evicted = d.evict_oldest(2);
        assert_eq!((d.n(), evicted.k()), (5, 2));
        for i in 0..4 {
            assert_eq!(evicted.xt.row(i), &base.xt.row(i)[..2]);
            assert_eq!(&d.xt.row(i)[..3], &base.xt.row(i)[2..]);
        }
        // The slid window equals a from-scratch gather of the same samples.
        let naive = {
            let mut m = base.clone();
            m.append_samples(&add.xt, &add.yt);
            m.select_samples(&[2, 3, 4, 5, 6])
        };
        assert_eq!(d.xt.max_abs_diff(&naive.xt), 0.0);
        assert_eq!(d.yt.max_abs_diff(&naive.yt), 0.0);
    }

    #[test]
    fn window_delta_merges_blocks_and_counts() {
        let mut rng = Rng::new(14);
        let a = random_dataset(&mut rng, 2, 3, 2);
        let b = random_dataset(&mut rng, 3, 3, 2);
        let mut delta = WindowDelta::new(10);
        assert!(delta.is_empty());
        delta.record_append(SampleBlock::new(a.xt.clone(), a.yt.clone()));
        delta.record_append(SampleBlock::new(b.xt.clone(), b.yt.clone()));
        delta.record_evict(SampleBlock::new(
            Mat::zeros(3, 1),
            Mat::zeros(2, 1),
        ));
        assert_eq!((delta.added_k(), delta.removed_k()), (5, 1));
        assert_eq!(delta.new_n(), 14);
        let added = delta.added.as_ref().unwrap();
        assert_eq!(added.xt.cols(), 5);
        // Concatenation preserves order: a's samples first, then b's.
        for i in 0..3 {
            assert_eq!(&added.xt.row(i)[..2], a.xt.row(i));
            assert_eq!(&added.xt.row(i)[2..], b.xt.row(i));
        }
    }

    #[test]
    fn xtheta_matches_dense_product() {
        property(20, |rng| {
            let (n, p, q) = (2 + rng.below(8), 1 + rng.below(8), 1 + rng.below(8));
            let d = random_dataset(rng, n, p, q);
            let mut theta = SpRowMat::zeros(p, q);
            for _ in 0..p {
                theta.set(rng.below(p), rng.below(q), rng.normal());
            }
            let rt = d.xtheta_t(&theta);
            // dense check: (XΘ)ᵀ[j, k] = Σ_i X[k,i]Θ[i,j]
            let td = theta.to_dense();
            for j in 0..q {
                for k in 0..n {
                    let mut want = 0.0;
                    for i in 0..p {
                        want += d.xt[(i, k)] * td[(i, j)];
                    }
                    check_close(rt[(j, k)], want, 1e-12, "xtheta")?;
                }
            }
            Ok(())
        });
    }
}
