//! Datasets: inputs X (n×p) and outputs Y (n×q), stored feature-major.
//!
//! `xt` is p×n and `yt` is q×n so that every covariance entry the CD loops
//! need — `(S_xx)_ij = x_iᵀx_j/n`, `(S_yy)_ij`, `(S_xy)_ij` — is a dot of two
//! contiguous rows, and covariance *blocks* are `gemm_nt` row-Gram products.
//! n is small relative to p, q in all of the paper's workloads, which is why
//! rows of `xt` work as an implicit representation of the huge `S_xx`
//! (§4.2: "we store only one row of S_xx at a time").
//!
//! A [`Dataset`] is backend-polymorphic: **resident** (the two dense
//! feature-major buffers above) or **disk-backed** (a sharded
//! [`crate::storage`] panel file read through a budget-tracked LRU panel
//! cache). Consumers never see the difference — every access goes through
//! row/panel accessors and the streaming GEMM helpers below, which a
//! resident dataset forwards straight to the engine and a disk dataset
//! satisfies panel-by-panel. Because the panels split only the *feature*
//! rows (the contraction dimension n is never split), the row-Gram products
//! are computed by the same engine kernels over the same contiguous sample
//! ranges either way.
//!
//! Disk-backed datasets treat I/O errors *after* a successful open as fatal
//! (panic): the file is assumed stable for the lifetime of the process, the
//! same contract the tile spill file has. Operations that change the sample
//! window (`append_samples`, `evict_oldest`) do return `io::Result`, since
//! they are the natural places for a caller to observe a full disk or a
//! read-only file.

use std::io;
use std::path::Path;

use crate::gemm::GemmEngine;
use crate::linalg::dense::{axpy, dot, Mat};
use crate::linalg::sparse::SpRowMat;
use crate::storage::{DiskSource, Panel, PanelStats, Space};
use crate::util::membudget::MemBudget;

const PANEL_IO: &str = "panel file read failed mid-solve (storage contract: file stable after open)";

/// A regression dataset for CGGM estimation.
#[derive(Clone, Debug)]
pub struct Dataset {
    backing: Backing,
}

#[derive(Clone, Debug)]
enum Backing {
    /// Fully resident feature-major buffers: `xt` p×n, `yt` q×n.
    Resident { xt: Mat, yt: Mat },
    /// Sharded panel file behind the budget-tracked panel cache.
    Disk(DiskSource),
}

/// A contiguous feature-major block of k samples — the unit of the sliding
/// window's append/evict API and the rank-k panels of the incremental Gram
/// corrections (`S ← (n·S + X_a·X_aᵀ − X_r·X_rᵀ)/n'`).
#[derive(Clone, Debug)]
pub struct SampleBlock {
    /// Inputs, feature-major: p × k.
    pub xt: Mat,
    /// Outputs, feature-major: q × k.
    pub yt: Mat,
}

impl SampleBlock {
    pub fn new(xt: Mat, yt: Mat) -> SampleBlock {
        assert_eq!(xt.cols(), yt.cols(), "sample count mismatch");
        SampleBlock { xt, yt }
    }

    /// Number of samples in the block.
    #[inline]
    pub fn k(&self) -> usize {
        self.xt.cols()
    }

    /// Horizontal concatenation (self's samples first) — how a delta merges
    /// two appends (or two evictions) into one rank-k panel.
    pub fn concat(&self, other: &SampleBlock) -> SampleBlock {
        assert_eq!(self.xt.rows(), other.xt.rows(), "p mismatch");
        assert_eq!(self.yt.rows(), other.yt.rows(), "q mismatch");
        let k = self.k();
        let xt = Mat::from_fn(self.xt.rows(), k + other.k(), |i, c| {
            if c < k {
                self.xt[(i, c)]
            } else {
                other.xt[(i, c - k)]
            }
        });
        let yt = Mat::from_fn(self.yt.rows(), k + other.k(), |j, c| {
            if c < k {
                self.yt[(j, c)]
            } else {
                other.yt[(j, c - k)]
            }
        });
        SampleBlock::new(xt, yt)
    }
}

/// One window transition: the samples that entered, the samples that left,
/// and the sample count the statistics were computed at *before* the
/// transition. `SolverContext::update_stats` consumes this to apply the
/// symmetric rank-k correction to whatever statistics are materialized.
#[derive(Clone, Debug)]
pub struct WindowDelta {
    /// Samples appended (rank-k update panel), if any.
    pub added: Option<SampleBlock>,
    /// Samples evicted (rank-k downdate panel), if any.
    pub removed: Option<SampleBlock>,
    /// Window occupancy before the transition.
    pub old_n: usize,
}

impl WindowDelta {
    /// An empty delta starting from a window of `old_n` samples.
    pub fn new(old_n: usize) -> WindowDelta {
        WindowDelta {
            added: None,
            removed: None,
            old_n,
        }
    }

    /// Fold an appended block into the delta.
    pub fn record_append(&mut self, block: SampleBlock) {
        if block.k() == 0 {
            return;
        }
        self.added = Some(match self.added.take() {
            Some(prev) => prev.concat(&block),
            None => block,
        });
    }

    /// Fold an evicted block into the delta.
    pub fn record_evict(&mut self, block: SampleBlock) {
        if block.k() == 0 {
            return;
        }
        self.removed = Some(match self.removed.take() {
            Some(prev) => prev.concat(&block),
            None => block,
        });
    }

    /// Samples appended / removed across the transition.
    pub fn added_k(&self) -> usize {
        self.added.as_ref().map_or(0, SampleBlock::k)
    }
    pub fn removed_k(&self) -> usize {
        self.removed.as_ref().map_or(0, SampleBlock::k)
    }

    /// Window occupancy after the transition.
    pub fn new_n(&self) -> usize {
        self.old_n + self.added_k() - self.removed_k()
    }

    /// True when nothing entered or left (the identity correction).
    pub fn is_empty(&self) -> bool {
        self.added_k() == 0 && self.removed_k() == 0
    }
}

/// `beta`-scale `out` in place before a panel-accumulation loop.
fn scale_out(out: &mut Mat, beta: f64) {
    if beta == 0.0 {
        out.fill(0.0);
    } else if beta != 1.0 {
        out.scale(beta);
    }
}

/// Run `f` over every cached panel of `space` in row order.
fn for_panels(src: &DiskSource, space: Space, mut f: impl FnMut(&Panel)) {
    for idx in 0..src.n_panels(space) {
        let panel = src.panel(space, idx).expect(PANEL_IO);
        f(&panel);
    }
}

impl Dataset {
    /// A fully resident dataset from feature-major buffers.
    pub fn new(xt: Mat, yt: Mat) -> Dataset {
        assert_eq!(xt.cols(), yt.cols(), "sample count mismatch");
        Dataset {
            backing: Backing::Resident { xt, yt },
        }
    }

    /// Open a sharded panel file ([`crate::storage`], magic `CGGMPAN1`) as a
    /// disk-backed dataset. `panel_rows` is the cached-panel granularity in
    /// feature rows; `cache_bytes` caps the resident panel set. Clones share
    /// the backing store: window mutations are visible through every clone.
    pub fn open_disk(path: &Path, panel_rows: usize, cache_bytes: usize) -> io::Result<Dataset> {
        Ok(Dataset {
            backing: Backing::Disk(DiskSource::open(path, panel_rows, cache_bytes)?),
        })
    }

    /// The resident p×n X buffer. Panics for disk-backed datasets — callers
    /// on this path (legacy dense save, datagen post-processing, tests) are
    /// resident-only by construction.
    pub fn xt(&self) -> &Mat {
        match &self.backing {
            Backing::Resident { xt, .. } => xt,
            Backing::Disk(_) => panic!("resident-only access (xt) on disk-backed dataset"),
        }
    }

    /// The resident q×n Y buffer (panics for disk-backed datasets).
    pub fn yt(&self) -> &Mat {
        match &self.backing {
            Backing::Resident { yt, .. } => yt,
            Backing::Disk(_) => panic!("resident-only access (yt) on disk-backed dataset"),
        }
    }

    pub fn is_disk(&self) -> bool {
        matches!(self.backing, Backing::Disk(_))
    }

    /// `"mem"` or `"disk"` — the serve `stat` storage-mode label.
    pub fn storage_name(&self) -> &'static str {
        match &self.backing {
            Backing::Resident { .. } => "mem",
            Backing::Disk(_) => "disk",
        }
    }

    /// Panel-cache traffic counters (disk-backed only).
    pub fn panel_stats(&self) -> Option<PanelStats> {
        match &self.backing {
            Backing::Resident { .. } => None,
            Backing::Disk(s) => Some(s.stats()),
        }
    }

    /// Configured panel-cache capacity (disk-backed only) — what admission
    /// control prices instead of dense data bytes.
    pub fn panel_cache_bytes(&self) -> Option<usize> {
        match &self.backing {
            Backing::Resident { .. } => None,
            Backing::Disk(s) => Some(s.cache_bytes()),
        }
    }

    /// Bind the budget that resident panels register against (no-op for
    /// resident datasets and for a rebind to the already-bound budget).
    pub fn bind_panel_budget(&self, budget: &MemBudget) {
        if let Backing::Disk(s) = &self.backing {
            s.bind_budget(budget);
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        match &self.backing {
            Backing::Resident { xt, .. } => xt.cols(),
            Backing::Disk(s) => s.n(),
        }
    }
    #[inline]
    pub fn p(&self) -> usize {
        match &self.backing {
            Backing::Resident { xt, .. } => xt.rows(),
            Backing::Disk(s) => s.p(),
        }
    }
    #[inline]
    pub fn q(&self) -> usize {
        match &self.backing {
            Backing::Resident { yt, .. } => yt.rows(),
            Backing::Disk(s) => s.q(),
        }
    }

    #[inline]
    pub fn inv_n(&self) -> f64 {
        1.0 / self.n() as f64
    }

    /// Borrow feature row `i` of X for the duration of `f` — the
    /// out-of-core-safe form of `xt.row(i)`. Disk-backed datasets pin the
    /// covering panel in the cache for the call.
    pub fn with_x_row<R>(&self, i: usize, f: impl FnOnce(&[f64]) -> R) -> R {
        match &self.backing {
            Backing::Resident { xt, .. } => f(xt.row(i)),
            Backing::Disk(s) => {
                let (panel, li) = s.row_panel(Space::X, i).expect(PANEL_IO);
                f(panel.mat.row(li))
            }
        }
    }

    /// Borrow feature row `j` of Y for the duration of `f`.
    pub fn with_y_row<R>(&self, j: usize, f: impl FnOnce(&[f64]) -> R) -> R {
        match &self.backing {
            Backing::Resident { yt, .. } => f(yt.row(j)),
            Backing::Disk(s) => {
                let (panel, lj) = s.row_panel(Space::Y, j).expect(PANEL_IO);
                f(panel.mat.row(lj))
            }
        }
    }

    /// (S_yy)_ij on demand — O(n).
    #[inline]
    pub fn syy(&self, i: usize, j: usize) -> f64 {
        match &self.backing {
            Backing::Resident { yt, .. } => dot(yt.row(i), yt.row(j)) * self.inv_n(),
            Backing::Disk(s) => {
                let (pi, li) = s.row_panel(Space::Y, i).expect(PANEL_IO);
                let (pj, lj) = s.row_panel(Space::Y, j).expect(PANEL_IO);
                dot(pi.mat.row(li), pj.mat.row(lj)) * self.inv_n()
            }
        }
    }

    /// (S_xy)_ij on demand — O(n).
    #[inline]
    pub fn sxy(&self, i: usize, j: usize) -> f64 {
        match &self.backing {
            Backing::Resident { xt, yt } => dot(xt.row(i), yt.row(j)) * self.inv_n(),
            Backing::Disk(s) => {
                let (pi, li) = s.row_panel(Space::X, i).expect(PANEL_IO);
                let (pj, lj) = s.row_panel(Space::Y, j).expect(PANEL_IO);
                dot(pi.mat.row(li), pj.mat.row(lj)) * self.inv_n()
            }
        }
    }

    /// (S_xx)_ij on demand — O(n).
    #[inline]
    pub fn sxx(&self, i: usize, j: usize) -> f64 {
        match &self.backing {
            Backing::Resident { xt, .. } => dot(xt.row(i), xt.row(j)) * self.inv_n(),
            Backing::Disk(s) => {
                let (pi, li) = s.row_panel(Space::X, i).expect(PANEL_IO);
                let (pj, lj) = s.row_panel(Space::X, j).expect(PANEL_IO);
                dot(pi.mat.row(li), pj.mat.row(lj)) * self.inv_n()
            }
        }
    }

    /// Row i of S_xx restricted to `cols`, appended into `out`
    /// (the paper's §4.2 row-wise-sparsity trick: skip entries whose Θ row
    /// is empty). O(n·|cols|).
    pub fn sxx_row_restricted(&self, i: usize, cols: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(cols.len());
        let inv_n = self.inv_n();
        match &self.backing {
            Backing::Resident { xt, .. } => {
                let xi = xt.row(i);
                for &k in cols {
                    out.push(dot(xi, xt.row(k)) * inv_n);
                }
            }
            Backing::Disk(s) => {
                // Pin row i's panel across the sweep; row k's panel comes
                // from the cache (hot under the row-cluster access pattern).
                let (pi, li) = s.row_panel(Space::X, i).expect(PANEL_IO);
                let xi = pi.mat.row(li);
                for &k in cols {
                    let (pk, lk) = s.row_panel(Space::X, k).expect(PANEL_IO);
                    out.push(dot(xi, pk.mat.row(lk)) * inv_n);
                }
            }
        }
    }

    /// Dense row-Gram between panels of two spaces: S = X_a·X_bᵀ/n blockwise.
    fn gram_dense_disk(
        src: &DiskSource,
        engine: &dyn GemmEngine,
        sa: Space,
        sb: Space,
        inv_n: f64,
    ) -> Mat {
        let mut s = Mat::zeros(src.dim(sa), src.dim(sb));
        for ia in 0..src.n_panels(sa) {
            let pa = src.panel(sa, ia).expect(PANEL_IO);
            for ib in 0..src.n_panels(sb) {
                let pb = src.panel(sb, ib).expect(PANEL_IO);
                let mut tmp = Mat::zeros(pa.mat.rows(), pb.mat.rows());
                engine.gemm_nt(inv_n, &pa.mat, &pb.mat, 0.0, &mut tmp);
                for r in 0..tmp.rows() {
                    s.row_mut(pa.row_start + r)[pb.row_start..pb.row_start + tmp.cols()]
                        .copy_from_slice(tmp.row(r));
                }
            }
        }
        s
    }

    /// Dense S_yy (q×q) — non-block solvers only.
    pub fn syy_dense(&self, engine: &dyn GemmEngine) -> Mat {
        let mut s = match &self.backing {
            Backing::Resident { yt, .. } => {
                let mut s = Mat::zeros(self.q(), self.q());
                engine.gemm_nt(self.inv_n(), yt, yt, 0.0, &mut s);
                s
            }
            Backing::Disk(src) => {
                Self::gram_dense_disk(src, engine, Space::Y, Space::Y, self.inv_n())
            }
        };
        s.symmetrize();
        s
    }

    /// Dense S_xx (p×p) — small p only.
    pub fn sxx_dense(&self, engine: &dyn GemmEngine) -> Mat {
        let mut s = match &self.backing {
            Backing::Resident { xt, .. } => {
                let mut s = Mat::zeros(self.p(), self.p());
                engine.gemm_nt(self.inv_n(), xt, xt, 0.0, &mut s);
                s
            }
            Backing::Disk(src) => {
                Self::gram_dense_disk(src, engine, Space::X, Space::X, self.inv_n())
            }
        };
        s.symmetrize();
        s
    }

    /// Dense S_xy (p×q).
    pub fn sxy_dense(&self, engine: &dyn GemmEngine) -> Mat {
        match &self.backing {
            Backing::Resident { xt, yt } => {
                let mut s = Mat::zeros(self.p(), self.q());
                engine.gemm_nt(self.inv_n(), xt, yt, 0.0, &mut s);
                s
            }
            Backing::Disk(src) => {
                Self::gram_dense_disk(src, engine, Space::X, Space::Y, self.inv_n())
            }
        }
    }

    /// `out = alpha · X̃·Bᵀ + beta·out` where X̃ is the p×n feature-major X
    /// and B is m×n: the Γ/S_xy-panel product every solver's Θ gradient
    /// needs, streamed panel-by-panel when X lives on disk. Output feature
    /// rows are partitioned by panel, so the engine's per-element contraction
    /// over the unsplit sample dimension is identical to the resident call.
    pub fn gemm_nt_x(
        &self,
        engine: &dyn GemmEngine,
        alpha: f64,
        b: &Mat,
        beta: f64,
        out: &mut Mat,
    ) {
        match &self.backing {
            Backing::Resident { xt, .. } => engine.gemm_nt(alpha, xt, b, beta, out),
            Backing::Disk(s) => {
                scale_out(out, beta);
                for_panels(s, Space::X, |panel| {
                    let mut tmp = Mat::zeros(panel.mat.rows(), b.rows());
                    engine.gemm_nt(alpha, &panel.mat, b, 0.0, &mut tmp);
                    for r in 0..tmp.rows() {
                        axpy(1.0, tmp.row(r), out.row_mut(panel.row_start + r));
                    }
                });
            }
        }
    }

    /// `out = alpha · Ỹ·Bᵀ + beta·out` (Ỹ q×n, B m×n) — the Y-side
    /// counterpart of [`Self::gemm_nt_x`].
    pub fn gemm_nt_y(
        &self,
        engine: &dyn GemmEngine,
        alpha: f64,
        b: &Mat,
        beta: f64,
        out: &mut Mat,
    ) {
        match &self.backing {
            Backing::Resident { yt, .. } => engine.gemm_nt(alpha, yt, b, beta, out),
            Backing::Disk(s) => {
                scale_out(out, beta);
                for_panels(s, Space::Y, |panel| {
                    let mut tmp = Mat::zeros(panel.mat.rows(), b.rows());
                    engine.gemm_nt(alpha, &panel.mat, b, 0.0, &mut tmp);
                    for r in 0..tmp.rows() {
                        axpy(1.0, tmp.row(r), out.row_mut(panel.row_start + r));
                    }
                });
            }
        }
    }

    /// `out = alpha · X̃·B + beta·out` (X̃ p×n, B n×m) — the BCD bucket
    /// gradient's Γ panel.
    pub fn gemm_x(&self, engine: &dyn GemmEngine, alpha: f64, b: &Mat, beta: f64, out: &mut Mat) {
        match &self.backing {
            Backing::Resident { xt, .. } => engine.gemm(alpha, xt, b, beta, out),
            Backing::Disk(s) => {
                scale_out(out, beta);
                for_panels(s, Space::X, |panel| {
                    let mut tmp = Mat::zeros(panel.mat.rows(), b.cols());
                    engine.gemm(alpha, &panel.mat, b, 0.0, &mut tmp);
                    for r in 0..tmp.rows() {
                        axpy(1.0, tmp.row(r), out.row_mut(panel.row_start + r));
                    }
                });
            }
        }
    }

    /// `out = alpha · Aᵀ·X̃ + beta·out` (A p×m, X̃ p×n, out m×n) — the dense
    /// proximal-gradient residual (XΘ)ᵀ. The contraction here runs over the
    /// *split* feature dimension, so disk-backed results agree with resident
    /// ones to rounding (not bitwise) — accumulation order differs.
    pub fn gemm_tn_x(
        &self,
        engine: &dyn GemmEngine,
        alpha: f64,
        a: &Mat,
        beta: f64,
        out: &mut Mat,
    ) {
        match &self.backing {
            Backing::Resident { xt, .. } => engine.gemm_tn(alpha, a, xt, beta, out),
            Backing::Disk(s) => {
                scale_out(out, beta);
                for_panels(s, Space::X, |panel| {
                    let a_sub =
                        Mat::from_fn(panel.mat.rows(), a.cols(), |r, c| a[(panel.row_start + r, c)]);
                    engine.gemm_tn(alpha, &a_sub, &panel.mat, 1.0, out);
                });
            }
        }
    }

    /// Gather arbitrary feature rows of X into `out` (`rows.len() × n`).
    pub fn x_rows_into(&self, rows: &[usize], out: &mut Mat) {
        match &self.backing {
            Backing::Resident { xt, .. } => xt.rows_into(rows, out),
            Backing::Disk(s) => {
                assert_eq!((out.rows(), out.cols()), (rows.len(), self.n()));
                for (k, &i) in rows.iter().enumerate() {
                    let (panel, li) = s.row_panel(Space::X, i).expect(PANEL_IO);
                    out.row_mut(k).copy_from_slice(panel.mat.row(li));
                }
            }
        }
    }

    /// Gather arbitrary feature rows of Y into `out` (`rows.len() × n`).
    pub fn y_rows_into(&self, rows: &[usize], out: &mut Mat) {
        match &self.backing {
            Backing::Resident { yt, .. } => yt.rows_into(rows, out),
            Backing::Disk(s) => {
                assert_eq!((out.rows(), out.cols()), (rows.len(), self.n()));
                for (k, &j) in rows.iter().enumerate() {
                    let (panel, lj) = s.row_panel(Space::Y, j).expect(PANEL_IO);
                    out.row_mut(k).copy_from_slice(panel.mat.row(lj));
                }
            }
        }
    }

    /// Copy sample column `s` of X into `out` (`out.len() == p`).
    pub fn x_col_into(&self, s: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.p());
        match &self.backing {
            Backing::Resident { xt, .. } => {
                for i in 0..xt.rows() {
                    out[i] = xt[(i, s)];
                }
            }
            Backing::Disk(src) => {
                for_panels(src, Space::X, |panel| {
                    for r in 0..panel.mat.rows() {
                        out[panel.row_start + r] = panel.mat[(r, s)];
                    }
                });
            }
        }
    }

    /// Copy sample column `s` of Y into `out` (`out.len() == q`).
    pub fn y_col_into(&self, s: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.q());
        match &self.backing {
            Backing::Resident { yt, .. } => {
                for j in 0..yt.rows() {
                    out[j] = yt[(j, s)];
                }
            }
            Backing::Disk(src) => {
                for_panels(src, Space::Y, |panel| {
                    for r in 0..panel.mat.rows() {
                        out[panel.row_start + r] = panel.mat[(r, s)];
                    }
                });
            }
        }
    }

    /// Stream the feature rows `rows` of X into `panel` (which must be
    /// `rows.len() × n`). This is the tile layer's *only* access to X during
    /// tile construction; disk-backed datasets satisfy it through the panel
    /// cache, so tile builds count as panel reads/hits.
    pub fn x_panel_into(&self, rows: std::ops::Range<usize>, panel: &mut Mat) {
        assert!(rows.end <= self.p(), "X panel rows out of range");
        assert_eq!((panel.rows(), panel.cols()), (rows.len(), self.n()));
        match &self.backing {
            Backing::Resident { xt, .. } => {
                for (k, i) in rows.enumerate() {
                    panel.row_mut(k).copy_from_slice(xt.row(i));
                }
            }
            Backing::Disk(s) => {
                let mut i = rows.start;
                while i < rows.end {
                    let (cp, li) = s.row_panel(Space::X, i).expect(PANEL_IO);
                    let take = (cp.row_start + cp.mat.rows()).min(rows.end) - i;
                    for t in 0..take {
                        panel
                            .row_mut(i - rows.start + t)
                            .copy_from_slice(cp.mat.row(li + t));
                    }
                    i += take;
                }
            }
        }
    }

    /// Stream the feature rows `rows` of Y into `panel` (`rows.len() × n`);
    /// the Y-side counterpart of [`Self::x_panel_into`].
    pub fn y_panel_into(&self, rows: std::ops::Range<usize>, panel: &mut Mat) {
        assert!(rows.end <= self.q(), "Y panel rows out of range");
        assert_eq!((panel.rows(), panel.cols()), (rows.len(), self.n()));
        match &self.backing {
            Backing::Resident { yt, .. } => {
                for (k, i) in rows.enumerate() {
                    panel.row_mut(k).copy_from_slice(yt.row(i));
                }
            }
            Backing::Disk(s) => {
                let mut i = rows.start;
                while i < rows.end {
                    let (cp, li) = s.row_panel(Space::Y, i).expect(PANEL_IO);
                    let take = (cp.row_start + cp.mat.rows()).min(rows.end) - i;
                    for t in 0..take {
                        panel
                            .row_mut(i - rows.start + t)
                            .copy_from_slice(cp.mat.row(li + t));
                    }
                    i += take;
                }
            }
        }
    }

    /// R̃ᵀ = (XΘ)ᵀ as a q×n matrix (`rt.row(j)` = j-th column of XΘ).
    /// O(nnz(Θ)·n); the basis of every Ψ/trace computation.
    pub fn xtheta_t(&self, theta: &SpRowMat) -> Mat {
        let mut rt = Mat::zeros(self.q(), self.n());
        self.xtheta_t_into(theta, &mut rt);
        rt
    }

    /// [`Self::xtheta_t`] into a preallocated q×n buffer (overwritten) — the
    /// workspace-arena path used by the solvers' iteration loops. Disk-backed
    /// datasets skip panels whose Θ rows are all empty, so a sparse Θ touches
    /// only the panels its support lives in.
    pub fn xtheta_t_into(&self, theta: &SpRowMat, rt: &mut Mat) {
        assert_eq!(theta.rows(), self.p());
        assert_eq!(theta.cols(), self.q());
        assert_eq!((rt.rows(), rt.cols()), (self.q(), self.n()));
        rt.fill(0.0);
        match &self.backing {
            Backing::Resident { xt, .. } => {
                for i in 0..xt.rows() {
                    let row = theta.row(i);
                    if row.is_empty() {
                        continue;
                    }
                    let xi = xt.row(i);
                    for &(j, v) in row {
                        axpy(v, xi, rt.row_mut(j));
                    }
                }
            }
            Backing::Disk(s) => {
                let pr = s.panel_rows();
                let p = self.p();
                for idx in 0..s.n_panels(Space::X) {
                    let base = idx * pr;
                    let hi = (base + pr).min(p);
                    if (base..hi).all(|i| theta.row(i).is_empty()) {
                        continue;
                    }
                    let panel = s.panel(Space::X, idx).expect(PANEL_IO);
                    for i in base..hi {
                        let row = theta.row(i);
                        if row.is_empty() {
                            continue;
                        }
                        let xi = panel.mat.row(i - base);
                        for &(j, v) in row {
                            axpy(v, xi, rt.row_mut(j));
                        }
                    }
                }
            }
        }
    }

    /// Copy out the sample columns in `idx` (order preserved, duplicates
    /// allowed) — the K-fold splitter of [`crate::coordinator::cross_validate`].
    /// O((p+q)·|idx|); feature-major layout means each sample is a strided
    /// column gather. Always returns a *resident* dataset: folds are small.
    pub fn select_samples(&self, idx: &[usize]) -> Dataset {
        let m = idx.len();
        for &s in idx {
            assert!(s < self.n(), "sample index {s} out of range (n={})", self.n());
        }
        match &self.backing {
            Backing::Resident { xt, yt } => {
                let sx = Mat::from_fn(self.p(), m, |i, k| xt[(i, idx[k])]);
                let sy = Mat::from_fn(self.q(), m, |j, k| yt[(j, idx[k])]);
                Dataset::new(sx, sy)
            }
            Backing::Disk(src) => {
                let mut sx = Mat::zeros(self.p(), m);
                let mut sy = Mat::zeros(self.q(), m);
                for_panels(src, Space::X, |panel| {
                    for r in 0..panel.mat.rows() {
                        let dst = sx.row_mut(panel.row_start + r);
                        for (k, &s) in idx.iter().enumerate() {
                            dst[k] = panel.mat[(r, s)];
                        }
                    }
                });
                for_panels(src, Space::Y, |panel| {
                    for r in 0..panel.mat.rows() {
                        let dst = sy.row_mut(panel.row_start + r);
                        for (k, &s) in idx.iter().enumerate() {
                            dst[k] = panel.mat[(r, s)];
                        }
                    }
                });
                Dataset::new(sx, sy)
            }
        }
    }

    /// Append `k` samples given as feature-major panels (`xa`: p × k,
    /// `ya`: q × k); the new samples become the window's newest columns.
    /// Resident: O((p+q)·(n+k)) reallocating copy. Disk: an X/Y shard pair
    /// appended to the panel file (and the panel cache flushed — every
    /// panel's column extent changed). Note a disk-backed append is visible
    /// through every clone sharing the store.
    pub fn append_samples(&mut self, xa: &Mat, ya: &Mat) -> io::Result<()> {
        match &mut self.backing {
            Backing::Resident { xt, yt } => {
                assert_eq!(xa.rows(), xt.rows(), "appended X feature count mismatch");
                assert_eq!(ya.rows(), yt.rows(), "appended Y feature count mismatch");
                assert_eq!(xa.cols(), ya.cols(), "appended sample count mismatch");
                let (n, k) = (xt.cols(), xa.cols());
                if k == 0 {
                    return Ok(());
                }
                let grow = |old: &Mat, add: &Mat| {
                    let mut out = Mat::zeros(old.rows(), n + k);
                    for i in 0..old.rows() {
                        let dst = out.row_mut(i);
                        dst[..n].copy_from_slice(old.row(i));
                        dst[n..].copy_from_slice(add.row(i));
                    }
                    out
                };
                *xt = grow(xt, xa);
                *yt = grow(yt, ya);
                Ok(())
            }
            Backing::Disk(s) => s.append(xa, ya),
        }
    }

    /// Append the samples of a [`SampleBlock`] (convenience over
    /// [`Self::append_samples`]).
    pub fn append_block(&mut self, block: &SampleBlock) -> io::Result<()> {
        self.append_samples(&block.xt, &block.yt)
    }

    /// Drop the `k` oldest samples (the window's leftmost columns), returning
    /// them as the rank-k downdate panel. Resident: O((p+q)·n). Disk: a
    /// transient read of the evicted columns plus a logical-offset bump —
    /// the file itself is append-only.
    pub fn evict_oldest(&mut self, k: usize) -> io::Result<SampleBlock> {
        match &mut self.backing {
            Backing::Resident { xt, yt } => {
                let n = xt.cols();
                let k = k.min(n);
                let split = |old: &Mat| {
                    let head = Mat::from_fn(old.rows(), k, |i, c| old[(i, c)]);
                    let mut tail = Mat::zeros(old.rows(), n - k);
                    for i in 0..old.rows() {
                        tail.row_mut(i).copy_from_slice(&old.row(i)[k..]);
                    }
                    (head, tail)
                };
                let (xh, xtail) = split(xt);
                let (yh, ytail) = split(yt);
                *xt = xtail;
                *yt = ytail;
                Ok(SampleBlock::new(xh, yh))
            }
            Backing::Disk(s) => {
                let (xh, yh) = s.evict_oldest(k)?;
                Ok(SampleBlock::new(xh, yh))
            }
        }
    }

    /// Heap bytes this handle itself pins: the dense buffers when resident,
    /// only the shard-table overhead when disk-backed (panels self-register
    /// against the bound budget — do not double-count them here).
    pub fn bytes(&self) -> usize {
        match &self.backing {
            Backing::Resident { xt, yt } => xt.bytes() + yt.bytes(),
            Backing::Disk(s) => s.overhead_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_close, property};

    fn random_dataset(rng: &mut Rng, n: usize, p: usize, q: usize) -> Dataset {
        Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        )
    }

    /// Mirror `d` into a disk-backed dataset (sharded panel file).
    fn disk_mirror(d: &Dataset, name: &str, panel_rows: usize) -> Dataset {
        let path = std::env::temp_dir().join(format!(
            "cggm_ds_mirror_{}_{}.pan",
            name,
            std::process::id()
        ));
        crate::storage::write_panel_dataset(&path, d.xt(), d.yt(), 3).unwrap();
        Dataset::open_disk(&path, panel_rows, usize::MAX).unwrap()
    }

    #[test]
    fn covariance_entries_match_dense() {
        property(20, |rng| {
            let (n, p, q) = (2 + rng.below(10), 1 + rng.below(8), 1 + rng.below(8));
            let d = random_dataset(rng, n, p, q);
            let eng = NativeGemm::new(1);
            let syy = d.syy_dense(&eng);
            let sxx = d.sxx_dense(&eng);
            let sxy = d.sxy_dense(&eng);
            for i in 0..q {
                for j in 0..q {
                    check_close(d.syy(i, j), syy[(i, j)], 1e-12, "syy")?;
                }
            }
            for i in 0..p {
                for j in 0..p {
                    check_close(d.sxx(i, j), sxx[(i, j)], 1e-12, "sxx")?;
                }
                for j in 0..q {
                    check_close(d.sxy(i, j), sxy[(i, j)], 1e-12, "sxy")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sxx_row_restricted_matches() {
        let mut rng = Rng::new(2);
        let d = random_dataset(&mut rng, 7, 10, 3);
        let cols = vec![0, 3, 9];
        let mut out = Vec::new();
        d.sxx_row_restricted(4, &cols, &mut out);
        for (k, &c) in cols.iter().enumerate() {
            assert!((out[k] - d.sxx(4, c)).abs() < 1e-14);
        }
    }

    #[test]
    fn select_samples_gathers_columns() {
        let mut rng = Rng::new(9);
        let d = random_dataset(&mut rng, 6, 4, 3);
        let sub = d.select_samples(&[5, 0, 2]);
        assert_eq!((sub.p(), sub.q(), sub.n()), (4, 3, 3));
        for i in 0..4 {
            assert_eq!(sub.xt()[(i, 0)], d.xt()[(i, 5)]);
            assert_eq!(sub.xt()[(i, 1)], d.xt()[(i, 0)]);
            assert_eq!(sub.xt()[(i, 2)], d.xt()[(i, 2)]);
        }
        for j in 0..3 {
            assert_eq!(sub.yt()[(j, 0)], d.yt()[(j, 5)]);
        }
        // Complementary splits partition the covariance mass:
        // n·S_full = n₁·S₁ + n₂·S₂ entrywise.
        let a = d.select_samples(&[0, 1, 2]);
        let b = d.select_samples(&[3, 4, 5]);
        let full = d.syy(1, 2) * d.n() as f64;
        let split = a.syy(1, 2) * a.n() as f64 + b.syy(1, 2) * b.n() as f64;
        assert!((full - split).abs() < 1e-10);
    }

    #[test]
    fn panel_loaders_stream_feature_rows() {
        let mut rng = Rng::new(4);
        let d = random_dataset(&mut rng, 6, 9, 5);
        let mut px = Mat::zeros(3, 6);
        d.x_panel_into(4..7, &mut px);
        for k in 0..3 {
            assert_eq!(px.row(k), d.xt().row(4 + k));
        }
        let mut py = Mat::zeros(2, 6);
        d.y_panel_into(3..5, &mut py);
        for k in 0..2 {
            assert_eq!(py.row(k), d.yt().row(3 + k));
        }
    }

    #[test]
    fn append_and_evict_slide_the_window() {
        let mut rng = Rng::new(12);
        let base = random_dataset(&mut rng, 5, 4, 3);
        let add = random_dataset(&mut rng, 2, 4, 3);
        let mut d = base.clone();
        d.append_samples(add.xt(), add.yt()).unwrap();
        assert_eq!(d.n(), 7);
        for i in 0..4 {
            assert_eq!(&d.xt().row(i)[..5], base.xt().row(i));
            assert_eq!(&d.xt().row(i)[5..], add.xt().row(i));
        }
        for j in 0..3 {
            assert_eq!(&d.yt().row(j)[5..], add.yt().row(j));
        }
        let evicted = d.evict_oldest(2).unwrap();
        assert_eq!((d.n(), evicted.k()), (5, 2));
        for i in 0..4 {
            assert_eq!(evicted.xt.row(i), &base.xt().row(i)[..2]);
            assert_eq!(&d.xt().row(i)[..3], &base.xt().row(i)[2..]);
        }
        // The slid window equals a from-scratch gather of the same samples.
        let naive = {
            let mut m = base.clone();
            m.append_samples(add.xt(), add.yt()).unwrap();
            m.select_samples(&[2, 3, 4, 5, 6])
        };
        assert_eq!(d.xt().max_abs_diff(naive.xt()), 0.0);
        assert_eq!(d.yt().max_abs_diff(naive.yt()), 0.0);
    }

    #[test]
    fn window_delta_merges_blocks_and_counts() {
        let mut rng = Rng::new(14);
        let a = random_dataset(&mut rng, 2, 3, 2);
        let b = random_dataset(&mut rng, 3, 3, 2);
        let mut delta = WindowDelta::new(10);
        assert!(delta.is_empty());
        delta.record_append(SampleBlock::new(a.xt().clone(), a.yt().clone()));
        delta.record_append(SampleBlock::new(b.xt().clone(), b.yt().clone()));
        delta.record_evict(SampleBlock::new(Mat::zeros(3, 1), Mat::zeros(2, 1)));
        assert_eq!((delta.added_k(), delta.removed_k()), (5, 1));
        assert_eq!(delta.new_n(), 14);
        let added = delta.added.as_ref().unwrap();
        assert_eq!(added.xt.cols(), 5);
        // Concatenation preserves order: a's samples first, then b's.
        for i in 0..3 {
            assert_eq!(&added.xt.row(i)[..2], a.xt().row(i));
            assert_eq!(&added.xt.row(i)[2..], b.xt().row(i));
        }
    }

    #[test]
    fn xtheta_matches_dense_product() {
        property(20, |rng| {
            let (n, p, q) = (2 + rng.below(8), 1 + rng.below(8), 1 + rng.below(8));
            let d = random_dataset(rng, n, p, q);
            let mut theta = SpRowMat::zeros(p, q);
            for _ in 0..p {
                theta.set(rng.below(p), rng.below(q), rng.normal());
            }
            let rt = d.xtheta_t(&theta);
            // dense check: (XΘ)ᵀ[j, k] = Σ_i X[k,i]Θ[i,j]
            let td = theta.to_dense();
            for j in 0..q {
                for k in 0..n {
                    let mut want = 0.0;
                    for i in 0..p {
                        want += d.xt()[(i, k)] * td[(i, j)];
                    }
                    check_close(rt[(j, k)], want, 1e-12, "xtheta")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn disk_backend_matches_resident_everywhere() {
        let mut rng = Rng::new(21);
        let (n, p, q) = (11, 9, 5);
        let d = random_dataset(&mut rng, n, p, q);
        let dd = disk_mirror(&d, "parity", 4);
        assert!(dd.is_disk());
        assert_eq!(dd.storage_name(), "disk");
        assert_eq!((dd.n(), dd.p(), dd.q()), (n, p, q));
        let eng = NativeGemm::new(1);

        // Entry + dense statistics.
        for i in 0..p {
            for j in 0..p {
                assert!((dd.sxx(i, j) - d.sxx(i, j)).abs() < 1e-14);
            }
            for j in 0..q {
                assert!((dd.sxy(i, j) - d.sxy(i, j)).abs() < 1e-14);
            }
        }
        for i in 0..q {
            for j in 0..q {
                assert!((dd.syy(i, j) - d.syy(i, j)).abs() < 1e-14);
            }
        }
        assert!(dd.syy_dense(&eng).max_abs_diff(&d.syy_dense(&eng)) < 1e-13);
        assert!(dd.sxx_dense(&eng).max_abs_diff(&d.sxx_dense(&eng)) < 1e-13);
        assert!(dd.sxy_dense(&eng).max_abs_diff(&d.sxy_dense(&eng)) < 1e-13);

        // Panel / row / column accessors.
        let mut pa = Mat::zeros(5, n);
        let mut pb = Mat::zeros(5, n);
        d.x_panel_into(2..7, &mut pa);
        dd.x_panel_into(2..7, &mut pb);
        assert_eq!(pa.max_abs_diff(&pb), 0.0);
        let rows = [8usize, 0, 3];
        let mut ra = Mat::zeros(3, n);
        let mut rb = Mat::zeros(3, n);
        d.x_rows_into(&rows, &mut ra);
        dd.x_rows_into(&rows, &mut rb);
        assert_eq!(ra.max_abs_diff(&rb), 0.0);
        d.y_rows_into(&[4, 1], &mut Mat::zeros(2, n));
        dd.with_x_row(6, |xi| assert_eq!(xi, d.xt().row(6)));
        dd.with_y_row(2, |yj| assert_eq!(yj, d.yt().row(2)));
        let mut ca = vec![0.0; p];
        let mut cb = vec![0.0; p];
        d.x_col_into(7, &mut ca);
        dd.x_col_into(7, &mut cb);
        assert_eq!(ca, cb);
        let mut cy = vec![0.0; q];
        dd.y_col_into(3, &mut cy);
        for j in 0..q {
            assert_eq!(cy[j], d.yt()[(j, 3)]);
        }

        // Streaming GEMM helpers against the resident engine calls.
        let b = Mat::from_fn(4, n, |_, _| rng.normal());
        let mut oa = Mat::from_fn(p, 4, |_, _| rng.normal());
        let mut ob = oa.clone();
        d.gemm_nt_x(&eng, 1.3, &b, 0.7, &mut oa);
        dd.gemm_nt_x(&eng, 1.3, &b, 0.7, &mut ob);
        assert!(oa.max_abs_diff(&ob) < 1e-13);
        let mut oa = Mat::zeros(q, 4);
        let mut ob = Mat::zeros(q, 4);
        d.gemm_nt_y(&eng, 2.0, &b, 0.0, &mut oa);
        dd.gemm_nt_y(&eng, 2.0, &b, 0.0, &mut ob);
        assert!(oa.max_abs_diff(&ob) < 1e-13);
        let bn = Mat::from_fn(n, 3, |_, _| rng.normal());
        let mut oa = Mat::zeros(p, 3);
        let mut ob = Mat::zeros(p, 3);
        d.gemm_x(&eng, 0.5, &bn, 0.0, &mut oa);
        dd.gemm_x(&eng, 0.5, &bn, 0.0, &mut ob);
        assert!(oa.max_abs_diff(&ob) < 1e-13);
        let ap = Mat::from_fn(p, 2, |_, _| rng.normal());
        let mut oa = Mat::zeros(2, n);
        let mut ob = Mat::zeros(2, n);
        d.gemm_tn_x(&eng, 1.0, &ap, 0.0, &mut oa);
        dd.gemm_tn_x(&eng, 1.0, &ap, 0.0, &mut ob);
        assert!(oa.max_abs_diff(&ob) < 1e-12);

        // XΘ, select, restricted S_xx row.
        let mut theta = SpRowMat::zeros(p, q);
        theta.set(0, 1, 0.8);
        theta.set(6, 3, -1.1);
        assert!(dd.xtheta_t(&theta).max_abs_diff(&d.xtheta_t(&theta)) < 1e-14);
        let sel = dd.select_samples(&[9, 2, 2, 0]);
        let want = d.select_samples(&[9, 2, 2, 0]);
        assert_eq!(sel.xt().max_abs_diff(want.xt()), 0.0);
        assert_eq!(sel.yt().max_abs_diff(want.yt()), 0.0);
        let mut oa = Vec::new();
        let mut ob = Vec::new();
        d.sxx_row_restricted(5, &[0, 8, 2], &mut oa);
        dd.sxx_row_restricted(5, &[0, 8, 2], &mut ob);
        assert_eq!(oa, ob);

        // Counters moved, and the window slides on disk too.
        let st = dd.panel_stats().unwrap();
        assert!(st.reads > 0 && st.hits > 0);
        let add = random_dataset(&mut rng, 3, p, q);
        let mut dm = d.clone();
        let mut ddm = dd.clone();
        dm.append_samples(add.xt(), add.yt()).unwrap();
        ddm.append_samples(add.xt(), add.yt()).unwrap();
        let ea = dm.evict_oldest(4).unwrap();
        let eb = ddm.evict_oldest(4).unwrap();
        assert_eq!(ea.xt.max_abs_diff(&eb.xt), 0.0);
        assert_eq!(ea.yt.max_abs_diff(&eb.yt), 0.0);
        assert_eq!(ddm.n(), dm.n());
        assert!(ddm.syy_dense(&eng).max_abs_diff(&dm.syy_dense(&eng)) < 1e-13);
        std::fs::remove_file(
            std::env::temp_dir().join(format!("cggm_ds_mirror_parity_{}.pan", std::process::id())),
        )
        .ok();
    }
}
