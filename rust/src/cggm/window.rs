//! Ring-buffered sliding sample window: O(k) append/evict over a fixed
//! capacity, feature-major like [`Dataset`] so panel streaming still works.
//!
//! [`Dataset`] stores the *exact current window* contiguously (every GEMM
//! consumer reads whole sample ranges, so the dataset itself cannot carry
//! ring offsets). The ring lives one layer up: [`SampleWindow`] owns the
//! capacity-bounded circular storage, absorbs appends in O(p+q) per sample
//! without shifting history, hands back evicted samples so callers can build
//! the rank-k downdate panels, and materializes a contiguous [`Dataset`] (or
//! wraparound-aware panels mirroring [`Dataset::x_panel_into`]) on demand.
//! The serve layer's `append` op buffers rows here until a `refit`
//! materializes them; `examples/energy_forecast.rs` drives its live
//! forecasting loop off the same type.

use crate::cggm::dataset::{Dataset, SampleBlock};
use crate::linalg::dense::Mat;

/// A fixed-capacity circular buffer of (x, y) samples, feature-major.
///
/// Sample `s` (logical order: 0 = oldest) lives in ring column
/// `(head + s) % cap`. Appending when full evicts the oldest sample and
/// returns it, so a steady-state window never reallocates.
#[derive(Clone, Debug)]
pub struct SampleWindow {
    /// Inputs, feature-major: p × cap (ring columns).
    xt: Mat,
    /// Outputs, feature-major: q × cap (ring columns).
    yt: Mat,
    head: usize,
    len: usize,
    /// Lifetime counters: samples ever pushed / ever evicted by overflow.
    appended: usize,
    evicted: usize,
}

impl SampleWindow {
    /// An empty window holding at most `cap` samples of shape (p, q).
    pub fn new(p: usize, q: usize, cap: usize) -> SampleWindow {
        assert!(cap >= 1, "window capacity must be positive");
        SampleWindow {
            xt: Mat::zeros(p, cap),
            yt: Mat::zeros(q, cap),
            head: 0,
            len: 0,
            appended: 0,
            evicted: 0,
        }
    }

    /// A full window seeded from an existing dataset (capacity = its n).
    /// Works for disk-backed datasets too — columns stream through the
    /// panel cache.
    pub fn from_dataset(data: &Dataset) -> SampleWindow {
        let mut w = SampleWindow::new(data.p(), data.q(), data.n().max(1));
        let mut x = vec![0.0; data.p()];
        let mut y = vec![0.0; data.q()];
        for s in 0..data.n() {
            data.x_col_into(s, &mut x);
            data.y_col_into(s, &mut y);
            let _ = w.push(&x, &y);
        }
        w
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.xt.rows()
    }
    #[inline]
    pub fn q(&self) -> usize {
        self.yt.rows()
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    #[inline]
    pub fn capacity(&self) -> usize {
        self.xt.cols()
    }
    /// Samples ever pushed into the window.
    pub fn appended(&self) -> usize {
        self.appended
    }
    /// Samples evicted by capacity overflow.
    pub fn evicted(&self) -> usize {
        self.evicted
    }

    #[inline]
    fn slot(&self, s: usize) -> usize {
        debug_assert!(s < self.len);
        (self.head + s) % self.capacity()
    }

    /// Append one sample; when the window is full the oldest sample is
    /// evicted and returned (its x then y values) so the caller can fold it
    /// into a rank-1 downdate panel. O(p + q), no shifting.
    pub fn push(&mut self, x: &[f64], y: &[f64]) -> Option<(Vec<f64>, Vec<f64>)> {
        assert_eq!(x.len(), self.p(), "x length mismatch");
        assert_eq!(y.len(), self.q(), "y length mismatch");
        let cap = self.capacity();
        let out = if self.len == cap {
            let (ox, oy) = self.sample(0);
            self.head = (self.head + 1) % cap;
            self.len -= 1;
            self.evicted += 1;
            Some((ox, oy))
        } else {
            None
        };
        let col = (self.head + self.len) % cap;
        for i in 0..self.p() {
            self.xt[(i, col)] = x[i];
        }
        for j in 0..self.q() {
            self.yt[(j, col)] = y[j];
        }
        self.len += 1;
        self.appended += 1;
        out
    }

    /// Copy out logical sample `s` (0 = oldest).
    pub fn sample(&self, s: usize) -> (Vec<f64>, Vec<f64>) {
        assert!(s < self.len, "sample {s} out of range (len {})", self.len);
        let col = self.slot(s);
        let x = (0..self.p()).map(|i| self.xt[(i, col)]).collect();
        let y = (0..self.q()).map(|j| self.yt[(j, col)]).collect();
        (x, y)
    }

    /// Drop the `k` oldest samples, returning them as a feature-major block
    /// (the downdate panel). O((p+q)·k).
    pub fn evict_oldest(&mut self, k: usize) -> SampleBlock {
        let k = k.min(self.len);
        let block = self.block(0..k);
        self.head = (self.head + k) % self.capacity();
        self.len -= k;
        self.evicted += k;
        block
    }

    /// Copy logical samples `range` into a contiguous feature-major block.
    pub fn block(&self, range: std::ops::Range<usize>) -> SampleBlock {
        assert!(range.end <= self.len, "window block out of range");
        let cols: Vec<usize> = range.map(|s| self.slot(s)).collect();
        let xt = Mat::from_fn(self.p(), cols.len(), |i, k| self.xt[(i, cols[k])]);
        let yt = Mat::from_fn(self.q(), cols.len(), |j, k| self.yt[(j, cols[k])]);
        SampleBlock::new(xt, yt)
    }

    /// Stream feature rows `rows` of the window's X into `panel`
    /// (`rows.len() × len()`, columns in logical order) — the wraparound-aware
    /// mirror of [`Dataset::x_panel_into`]. At most two contiguous segment
    /// copies per feature row.
    pub fn x_panel_into(&self, rows: std::ops::Range<usize>, panel: &mut Mat) {
        assert!(rows.end <= self.p(), "X panel rows out of range");
        Self::ring_panel(&self.xt, self.head, self.len, rows, panel);
    }

    /// The Y-side counterpart of [`Self::x_panel_into`].
    pub fn y_panel_into(&self, rows: std::ops::Range<usize>, panel: &mut Mat) {
        assert!(rows.end <= self.q(), "Y panel rows out of range");
        Self::ring_panel(&self.yt, self.head, self.len, rows, panel);
    }

    fn ring_panel(
        ring: &Mat,
        head: usize,
        len: usize,
        rows: std::ops::Range<usize>,
        panel: &mut Mat,
    ) {
        assert_eq!((panel.rows(), panel.cols()), (rows.len(), len));
        let cap = ring.cols();
        let first = (cap - head).min(len); // contiguous tail of the ring
        for (k, i) in rows.enumerate() {
            let src = ring.row(i);
            let dst = panel.row_mut(k);
            dst[..first].copy_from_slice(&src[head..head + first]);
            dst[first..].copy_from_slice(&src[..len - first]);
        }
    }

    /// Materialize the current window as a contiguous [`Dataset`]
    /// (oldest-first), i.e. exactly what a from-scratch fit would see.
    pub fn to_dataset(&self) -> Dataset {
        let mut xt = Mat::zeros(self.p(), self.len);
        let mut yt = Mat::zeros(self.q(), self.len);
        self.x_panel_into(0..self.p(), &mut xt);
        self.y_panel_into(0..self.q(), &mut yt);
        Dataset::new(xt, yt)
    }

    /// Ring storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.xt.bytes() + self.yt.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::property;

    fn sample(rng: &mut Rng, p: usize, q: usize) -> (Vec<f64>, Vec<f64>) {
        (
            (0..p).map(|_| rng.normal()).collect(),
            (0..q).map(|_| rng.normal()).collect(),
        )
    }

    #[test]
    fn push_evicts_oldest_at_capacity() {
        let mut w = SampleWindow::new(2, 1, 3);
        assert!(w.push(&[1.0, 1.0], &[10.0]).is_none());
        assert!(w.push(&[2.0, 2.0], &[20.0]).is_none());
        assert!(w.push(&[3.0, 3.0], &[30.0]).is_none());
        let (ox, oy) = w.push(&[4.0, 4.0], &[40.0]).expect("full window evicts");
        assert_eq!(ox, vec![1.0, 1.0]);
        assert_eq!(oy, vec![10.0]);
        assert_eq!(w.len(), 3);
        assert_eq!((w.appended(), w.evicted()), (4, 1));
        // Logical order is oldest-first across the wraparound.
        assert_eq!(w.sample(0).1, vec![20.0]);
        assert_eq!(w.sample(2).1, vec![40.0]);
    }

    #[test]
    fn window_matches_naive_sliding_dataset() {
        // Property: after any mix of pushes and evictions, to_dataset() and
        // the ring panels equal a naively maintained Vec of samples.
        property(25, |rng| {
            let (p, q) = (1 + rng.below(6), 1 + rng.below(4));
            let cap = 2 + rng.below(6);
            let mut w = SampleWindow::new(p, q, cap);
            let mut naive: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
            for _ in 0..30 {
                if rng.uniform() < 0.7 || naive.is_empty() {
                    let (x, y) = sample(rng, p, q);
                    let evicted = w.push(&x, &y);
                    naive.push((x, y));
                    if naive.len() > cap {
                        let old = naive.remove(0);
                        let got = evicted.ok_or("missing eviction")?;
                        if got != old {
                            return Err("evicted wrong sample".into());
                        }
                    } else if evicted.is_some() {
                        return Err("eviction below capacity".into());
                    }
                } else {
                    let k = 1 + rng.below(naive.len());
                    let block = w.evict_oldest(k);
                    for c in 0..k {
                        let old = naive.remove(0);
                        for i in 0..p {
                            if block.xt[(i, c)] != old.0[i] {
                                return Err("evict_oldest block mismatch".into());
                            }
                        }
                        for j in 0..q {
                            if block.yt[(j, c)] != old.1[j] {
                                return Err("evict_oldest block mismatch".into());
                            }
                        }
                    }
                }
                let d = w.to_dataset();
                if d.n() != naive.len() {
                    return Err(format!("n {} vs naive {}", d.n(), naive.len()));
                }
                for (s, (x, y)) in naive.iter().enumerate() {
                    for i in 0..p {
                        if d.xt()[(i, s)] != x[i] {
                            return Err("dataset X mismatch".into());
                        }
                    }
                    for j in 0..q {
                        if d.yt()[(j, s)] != y[j] {
                            return Err("dataset Y mismatch".into());
                        }
                    }
                }
                // Panels mirror the dataset contract across the wraparound.
                let mut px = Mat::zeros(p, d.n());
                w.x_panel_into(0..p, &mut px);
                if px.max_abs_diff(d.xt()) != 0.0 {
                    return Err("x panel mismatch".into());
                }
                let mut py = Mat::zeros(q, d.n());
                w.y_panel_into(0..q, &mut py);
                if py.max_abs_diff(d.yt()) != 0.0 {
                    return Err("y panel mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn from_dataset_roundtrips() {
        let mut rng = Rng::new(3);
        let d = Dataset::new(
            Mat::from_fn(4, 6, |_, _| rng.normal()),
            Mat::from_fn(2, 6, |_, _| rng.normal()),
        );
        let w = SampleWindow::from_dataset(&d);
        assert_eq!((w.len(), w.capacity()), (6, 6));
        assert_eq!(w.to_dataset().xt().max_abs_diff(d.xt()), 0.0);
        assert_eq!(w.to_dataset().yt().max_abs_diff(d.yt()), 0.0);
    }
}
