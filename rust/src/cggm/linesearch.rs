//! Armijo backtracking line search for the Λ (and joint) Newton steps.
//!
//! Following QUIC/the paper: accept the largest α ∈ {1, ½, ¼, …} with
//! Λ + αD_Λ ≻ 0 (Cholesky succeeds) and
//!
//! ```text
//! f(x + αD) ≤ f(x) + σ·α·δ,   δ = tr(∇gᵀD) + h(x + D) - h(x),  σ = 1e-3
//! ```
//!
//! Per-α cost: one sparse/dense Cholesky of Λ + αD (the PD probe + logdet)
//! and one n-RHS triangular solve for the tr(Λ⁻¹ΘᵀS_xxΘ) term; all terms
//! linear in α are updated analytically. Every trial factor is built through
//! [`Objective::factor_lambda`], so its bytes are registered against the
//! solver's memory budget while the trial is alive — the line search is where
//! factorization scratch peaks (the previous iteration's factor is still
//! live), and `MemBudget::peak()` must see it.

use super::dataset::Dataset;
use super::factor::{FactorError, LambdaFactor};
use super::objective::{Objective, SmoothParts};
use crate::gemm::GemmEngine;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;
use crate::util::membudget::BudgetExceeded;

/// Accepted step.
pub struct LineSearchResult {
    pub alpha: f64,
    /// f at the accepted point.
    pub f_new: f64,
    /// Smooth parts at the accepted point.
    pub parts: SmoothParts,
    /// Λ⁺ factor (reusable by the caller for the next iteration).
    pub factor: LambdaFactor,
    /// Number of α trials (for traces).
    pub trials: usize,
}

#[derive(Debug, thiserror::Error)]
pub enum LineSearchError {
    #[error("line search failed to find a positive-definite sufficient-decrease step")]
    NoStep,
    /// The memory budget cannot hold a trial factor — aborts the search
    /// (backtracking further cannot shrink the factor's footprint).
    #[error("memory budget cannot hold the line-search trial factor: {0}")]
    Budget(#[from] BudgetExceeded),
}

pub struct LineSearchOptions {
    pub sigma: f64,
    pub beta: f64,
    pub max_trials: usize,
}

impl Default for LineSearchOptions {
    fn default() -> Self {
        LineSearchOptions {
            sigma: 1e-3,
            beta: 0.5,
            max_trials: 30,
        }
    }
}

/// Context for a Λ-only step (AltNewtonCD / AltNewtonBCD): Θ is fixed, so
/// `rt = (XΘ)ᵀ` is constant across α.
#[allow(clippy::too_many_arguments)]
pub fn lambda_line_search(
    obj: &Objective,
    lambda: &SpRowMat,
    dir: &SpRowMat,
    rt: &Mat,
    f_cur: f64,
    parts_cur: &SmoothParts,
    // δ = tr(∇_Λgᵀ D) + λ_Λ(‖Λ+D‖₁ - ‖Λ‖₁) computed by the caller.
    delta: f64,
    theta_l1: f64,
    engine: &dyn GemmEngine,
    opts: &LineSearchOptions,
) -> Result<LineSearchResult, LineSearchError> {
    debug_assert!(delta <= 1e-8, "descent direction must have δ ≤ 0, got {delta}");
    // Linear-in-α pieces.
    let tr_syy_d = obj.tr_syy_sparse(dir);
    let mut alpha = 1.0;
    let mut trial_lambda = lambda.clone();
    for trial in 0..opts.max_trials {
        // Λ(α) = Λ + αD built by pattern union (reuse buffer).
        trial_lambda.clone_from(lambda);
        trial_lambda.add_scaled(alpha, dir);
        match obj.factor_lambda(&trial_lambda, engine) {
            Err(FactorError::NotPd) | Err(FactorError::FillExceeded { .. }) => {}
            Err(FactorError::Budget(b)) => return Err(LineSearchError::Budget(b)),
            Ok(factor) => {
                let parts = SmoothParts {
                    logdet: factor.logdet(),
                    tr_syy_lambda: parts_cur.tr_syy_lambda + alpha * tr_syy_d,
                    tr_sxy_theta: parts_cur.tr_sxy_theta,
                    tr_quad: factor.trace_quad(rt),
                };
                let f_new =
                    parts.g() + obj.lam_l * trial_lambda.l1_norm() + obj.lam_t * theta_l1;
                if f_new <= f_cur + opts.sigma * alpha * delta {
                    return Ok(LineSearchResult {
                        alpha,
                        f_new,
                        parts,
                        factor,
                        trials: trial + 1,
                    });
                }
            }
        }
        alpha *= opts.beta;
    }
    Err(LineSearchError::NoStep)
}

/// Joint line search for the Newton CD baseline: x = (Λ, Θ), D = (D_Λ, D_Θ),
/// stepping both with the same α (Wytock & Kolter).
#[allow(clippy::too_many_arguments)]
pub fn joint_line_search(
    obj: &Objective,
    data: &Dataset,
    lambda: &SpRowMat,
    theta: &SpRowMat,
    dir_l: &SpRowMat,
    dir_t: &SpRowMat,
    rt: &Mat,
    f_cur: f64,
    parts_cur: &SmoothParts,
    delta: f64,
    engine: &dyn GemmEngine,
    opts: &LineSearchOptions,
) -> Result<(LineSearchResult, f64), LineSearchError> {
    debug_assert!(delta <= 1e-8, "descent direction must have δ ≤ 0, got {delta}");
    let tr_syy_d = obj.tr_syy_sparse(dir_l);
    let tr_sxy_d = obj.tr_sxy_sparse(dir_t); // already ×2
    // rt(α) = rt + α·(X D_Θ)ᵀ.
    let drt = data.xtheta_t(dir_t);
    let mut alpha = 1.0;
    let mut trial_lambda = lambda.clone();
    let mut trial_theta = theta.clone();
    let mut rt_trial = rt.clone();
    for trial in 0..opts.max_trials {
        trial_lambda.clone_from(lambda);
        trial_lambda.add_scaled(alpha, dir_l);
        match obj.factor_lambda(&trial_lambda, engine) {
            Err(FactorError::Budget(b)) => return Err(LineSearchError::Budget(b)),
            Err(_) => {}
            Ok(factor) => {
                rt_trial.clone_from(rt);
                rt_trial.add_scaled(alpha, &drt);
                trial_theta.clone_from(theta);
                trial_theta.add_scaled(alpha, dir_t);
                let parts = SmoothParts {
                    logdet: factor.logdet(),
                    tr_syy_lambda: parts_cur.tr_syy_lambda + alpha * tr_syy_d,
                    tr_sxy_theta: parts_cur.tr_sxy_theta + alpha * tr_sxy_d,
                    tr_quad: factor.trace_quad(&rt_trial),
                };
                let f_new = parts.g()
                    + obj.lam_l * trial_lambda.l1_norm()
                    + obj.lam_t * trial_theta.l1_norm();
                if f_new <= f_cur + opts.sigma * alpha * delta {
                    return Ok((
                        LineSearchResult {
                            alpha,
                            f_new,
                            parts,
                            factor,
                            trials: trial + 1,
                        },
                        alpha,
                    ));
                }
            }
        }
        alpha *= opts.beta;
    }
    Err(LineSearchError::NoStep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cggm::model::CggmModel;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng, n: usize, p: usize, q: usize) -> (Dataset, CggmModel) {
        let data = Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        );
        let mut model = CggmModel::init(p, q);
        model.theta.set(0, 0, 0.4);
        (data, model)
    }

    #[test]
    fn accepts_descent_direction() {
        let mut rng = Rng::new(21);
        let (data, model) = setup(&mut rng, 10, 4, 5);
        let eng = NativeGemm::new(1);
        let obj = Objective::new(&data, 0.2, 0.2);
        let (f, parts, factor, rt) = obj.eval(&model, &eng).unwrap();
        // Direction: a small multiple of the negative smooth gradient,
        // soft-thresholded onto a sparse pattern.
        let sigma = factor.inverse_dense(&eng);
        let psi = obj.psi_dense(&sigma, &rt, &eng);
        let gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
        let mut dir = SpRowMat::zeros(5, 5);
        for i in 0..5 {
            for j in i..5 {
                let g = gl[(i, j)];
                if g.abs() > 1e-12 {
                    dir.set_sym(i, j, -0.1 * g);
                }
            }
        }
        // δ = tr(∇gᵀD) + λ(‖Λ+D‖₁-‖Λ‖₁)
        let mut tr_gd = 0.0;
        for i in 0..5 {
            for &(j, v) in dir.row(i) {
                tr_gd += gl[(i, j)] * v;
            }
        }
        let mut lpd = model.lambda.clone();
        lpd.add_scaled(1.0, &dir);
        let delta = tr_gd + obj.lam_l * (lpd.l1_norm() - model.lambda.l1_norm());
        assert!(delta < 0.0, "test setup should give descent, δ={delta}");
        let res = lambda_line_search(
            &obj,
            &model.lambda,
            &dir,
            &rt,
            f,
            &parts,
            delta,
            model.theta.l1_norm(),
            &eng,
            &LineSearchOptions::default(),
        )
        .unwrap();
        assert!(res.f_new < f, "objective must decrease: {} vs {f}", res.f_new);
        assert!(res.alpha > 0.0 && res.alpha <= 1.0);
    }

    #[test]
    fn shrinks_alpha_to_keep_pd() {
        let mut rng = Rng::new(22);
        let (data, model) = setup(&mut rng, 10, 3, 4);
        let eng = NativeGemm::new(1);
        let obj = Objective::new(&data, 0.5, 0.5);
        let (f, parts, _, rt) = obj.eval(&model, &eng).unwrap();
        // A huge negative-definite direction: α=1 makes Λ+D indefinite.
        let mut dir = SpRowMat::zeros(4, 4);
        for i in 0..4 {
            dir.set(i, i, -3.0);
        }
        // Fake a strongly-negative δ (descent in smooth model).
        let delta = -1.0;
        let res = lambda_line_search(
            &obj,
            &model.lambda,
            &dir,
            &rt,
            f,
            &parts,
            delta,
            model.theta.l1_norm(),
            &eng,
            &LineSearchOptions::default(),
        );
        if let Ok(r) = res {
            assert!(r.alpha < 1.0, "α must backtrack below 1, got {}", r.alpha);
        }
        // (NoStep is also acceptable for this adversarial direction.)
    }
}
