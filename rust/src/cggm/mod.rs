//! The CGGM model layer: parameters, data, objective, active sets, and line
//! search — shared by all three solvers.

pub mod active;
pub mod dataset;
pub mod factor;
pub mod linesearch;
pub mod model;
pub mod objective;
pub mod tiles;
pub mod window;

pub use dataset::{Dataset, SampleBlock, WindowDelta};
pub use window::SampleWindow;
pub use factor::{CholKind, LambdaFactor};
pub use model::CggmModel;
pub use objective::Objective;

/// Soft-thresholding operator `S_r(w) = sign(w)·max(|w|-r, 0)` — the scalar
/// engine of every coordinate-descent update (paper Appendix A).
#[inline]
pub fn soft_threshold(w: f64, r: f64) -> f64 {
    if w > r {
        w - r
    } else if w < -r {
        w + r
    } else {
        0.0
    }
}

/// Exact minimizer of `½aμ² + bμ + λ|c + μ|` over μ (paper's CD update):
/// `μ = -c + S_{λ/a}(c - b/a)`.
#[inline]
pub fn cd_minimizer(a: f64, b: f64, c: f64, lam: f64) -> f64 {
    debug_assert!(a > 0.0);
    -c + soft_threshold(c - b / a, lam / a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::property;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn cd_minimizer_is_exact_minimum() {
        // Property: the returned μ minimizes φ(μ) = ½aμ² + bμ + λ|c+μ|
        // against a grid of perturbations.
        property(300, |rng| {
            let a = 0.1 + rng.uniform() * 5.0;
            let b = rng.normal() * 3.0;
            let c = rng.normal() * 3.0;
            let lam = rng.uniform() * 2.0;
            let phi = |mu: f64| 0.5 * a * mu * mu + b * mu + lam * (c + mu).abs();
            let mu = cd_minimizer(a, b, c, lam);
            let fmin = phi(mu);
            for k in -60..=60 {
                let trial = mu + k as f64 * 0.05;
                if phi(trial) < fmin - 1e-12 {
                    return Err(format!(
                        "phi({trial}) = {} < phi({mu}) = {fmin} (a={a},b={b},c={c},λ={lam})",
                        phi(trial)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cd_minimizer_stationarity() {
        // At the minimum: either c+μ = 0 and |b - a·c| ≤ λ, or
        // a·μ + b + λ·sign(c+μ) = 0.
        property(300, |rng| {
            let a = 0.1 + rng.uniform() * 5.0;
            let b = rng.normal() * 3.0;
            let c = rng.normal() * 3.0;
            let lam = rng.uniform() * 2.0;
            let mu = cd_minimizer(a, b, c, lam);
            let x = c + mu;
            if x == 0.0 {
                if (b - a * c).abs() <= lam + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("subgradient violated: |{}| > {lam}", b - a * c))
                }
            } else {
                let g = a * mu + b + lam * x.signum();
                if g.abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("stationarity violated: {g}"))
                }
            }
        });
    }
}
