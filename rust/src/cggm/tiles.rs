//! Tiled on-demand Gram statistics: lazy `S_xx`/`S_xy` blocks behind a
//! budget-driven LRU cache with file spill.
//!
//! The paper's scaling story (§4.2, p + q ≈ 10⁶ on one machine) depends on
//! never materializing the dense O(p²) Gram matrix: the block solver touches
//! `S_xx` sub-blocks for the *active* blocks only. [`TileStore`] makes that
//! access pattern first-class. The p×p `S_xx` and p×q `S_xy` are carved into
//! fixed-size `tile × tile` blocks; a block is computed — one packed
//! [`GemmEngine::gemm_nt`] row-Gram over streamed column panels of X/Y
//! ([`Dataset::x_panel_into`]) — only when a solver first reads an entry
//! inside it. Hot tiles stay resident in an LRU keyed against the shared
//! [`MemBudget`]; under budget pressure cold tiles are *spilled* to a
//! page-cache-backed slot file instead of failing the solve, and reload from
//! disk (cheap, O(t²) I/O) instead of recomputing (O(t²·n) FLOPs). Tiles are
//! pure functions of the data, so a disk copy stays valid until the window
//! moves ([`TileStore::apply_update`] invalidates every spill slot):
//! re-evicting a previously spilled tile is free between window updates.
//!
//! Budget accounting: only *resident* tiles are tracked (RAII [`Tracked`],
//! same discipline as the workspace arena), so `MemBudget::peak()` keeps
//! measuring the true concurrent working set. Transient panel scratch during
//! a tile build is bounded by `2·tile·n·8` bytes and treated like the GEMM
//! engine's pack buffers: outside the budget, bounded by construction. If
//! even a single tile cannot fit in the budget after spilling everything, the
//! store degrades to serving the requested entries from an uncached transient
//! tile — strictly the paper's "store only one row of S_xx at a time" mode —
//! so tiled reads never fail and never change numerics.
//!
//! Concurrency: the store is `Sync` (one internal mutex), so the block
//! solver's colored parallel sweeps read tiles from worker threads. The lock
//! is held across a tile build, serializing concurrent *misses*; hits are a
//! map probe. This is the right trade for the access pattern — misses are
//! O(t²·n) GEMMs where serialization is amortized, and the alternative
//! (per-tile locks) would let concurrent misses overshoot the budget.

use std::collections::HashMap;
use std::fs::File;
use std::io;
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cggm::dataset::WindowDelta;
use crate::cggm::Dataset;
use crate::gemm::GemmEngine;
use crate::linalg::dense::Mat;
use crate::util::membudget::{MemBudget, Tracked};

/// Identity of one Gram tile. `Sxx(bi, bj)` is stored canonically with
/// `bi ≤ bj` (the mirror block is the transpose); `Sxy` has no symmetry.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum TileKey {
    Sxx(u32, u32),
    Sxy(u32, u32),
}

impl TileKey {
    fn tag(&self) -> u32 {
        match self {
            TileKey::Sxx(..) => 1,
            TileKey::Sxy(..) => 2,
        }
    }

    fn blocks(&self) -> (u32, u32) {
        match *self {
            TileKey::Sxx(a, b) | TileKey::Sxy(a, b) => (a, b),
        }
    }
}

/// Counters describing the cache's behavior over its lifetime — surfaced on
/// `SolveTrace` and the serve `stat` op so tiled-vs-dense compute savings are
/// machine-readable.
#[derive(Clone, Copy, Default, Debug, PartialEq, Eq)]
pub struct TileStats {
    /// Tiles actually built (one `gemm_nt` each). The tiled perf claim is
    /// `computes < total_tiles` on screened solves.
    pub computes: usize,
    /// Reads served by a resident tile.
    pub hits: usize,
    /// Reads that found no resident tile (reload or compute followed).
    pub misses: usize,
    /// Resident tiles dropped under budget pressure.
    pub evictions: usize,
    /// Evicted tiles written to the spill file (≤ evictions: a tile with a
    /// still-valid disk copy re-evicts for free).
    pub spills: usize,
    /// Spilled tiles read back from disk instead of recomputed.
    pub reloads: usize,
    /// Resident tiles corrected in place by an incremental window update
    /// (rank-k, O(t·k·t) each) instead of recomputed (O(t²·n)).
    pub updates: usize,
}

struct ResidentTile {
    mat: Mat,
    last_used: u64,
    _track: Tracked,
}

#[derive(Clone, Copy)]
struct DiskSlot {
    slot: u64,
    rows: u32,
    cols: u32,
}

struct SpillFile {
    file: File,
    path: PathBuf,
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

struct TileInner {
    resident: HashMap<TileKey, ResidentTile>,
    disk: HashMap<TileKey, DiskSlot>,
    spill: Option<SpillFile>,
    next_slot: u64,
    clock: u64,
    stats: TileStats,
}

/// Slot header: MAGIC (8) + tag, bi, bj, rows, cols (4 each) + pad to 32.
const SPILL_MAGIC: u64 = 0x4347_474d_5449_4c45; // "CGGMTILE"
const HEADER_BYTES: u64 = 32;

static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// The demand-driven Gram statistics layer; see the module docs.
pub struct TileStore<'a> {
    data: &'a Dataset,
    engine: &'a dyn GemmEngine,
    budget: MemBudget,
    tile: usize,
    inner: Mutex<TileInner>,
}

/// Result of resolving a tile: resident in the cache, or a transient copy
/// that could not be admitted under the budget.
enum Got {
    Resident,
    Transient(Mat),
}

impl<'a> TileStore<'a> {
    pub fn new(
        data: &'a Dataset,
        engine: &'a dyn GemmEngine,
        budget: MemBudget,
        tile: usize,
    ) -> TileStore<'a> {
        assert!(tile >= 1, "tile size must be positive");
        TileStore {
            data,
            engine,
            budget,
            tile,
            inner: Mutex::new(TileInner {
                resident: HashMap::new(),
                disk: HashMap::new(),
                spill: None,
                next_slot: 0,
                clock: 0,
                stats: TileStats::default(),
            }),
        }
    }

    /// Edge length of a full tile (boundary tiles are smaller).
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Number of distinct tiles the full statistics decompose into:
    /// upper-triangular `S_xx` blocks plus all `S_xy` blocks. The screened-
    /// path perf claim is `stats().computes < total_tiles()`.
    pub fn total_tiles(&self) -> usize {
        let nbx = self.data.p().div_ceil(self.tile);
        let nby = self.data.q().div_ceil(self.tile);
        nbx * (nbx + 1) / 2 + nbx * nby
    }

    /// Snapshot of the lifetime counters.
    pub fn stats(&self) -> TileStats {
        self.inner.lock().unwrap().stats
    }

    /// Bytes currently pinned by resident tiles (what the cache "costs" in
    /// the budget right now — feeds `SolverContext::cached_stat_bytes` and
    /// hence the serve registry's pinned-byte accounting).
    pub fn resident_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.resident.values().map(|t| t.mat.bytes()).sum()
    }

    /// Number of tiles currently resident.
    pub fn resident_tiles(&self) -> usize {
        self.inner.lock().unwrap().resident.len()
    }

    /// Path of the spill file, once budget pressure has created one
    /// (tests corrupt it to exercise torn-file recovery).
    pub fn spill_path(&self) -> Option<PathBuf> {
        self.inner
            .lock()
            .unwrap()
            .spill
            .as_ref()
            .map(|s| s.path.clone())
    }

    /// `(S_xx)_ij` through the tile cache. Never fails: under an impossible
    /// budget the entry is served from an uncached transient tile.
    pub fn sxx_entry(&self, i: usize, j: usize) -> f64 {
        let mut inner = self.inner.lock().unwrap();
        self.sxx_at(&mut inner, i, j)
    }

    /// `(S_xy)_ij` through the tile cache.
    pub fn sxy_entry(&self, i: usize, j: usize) -> f64 {
        let t = self.tile;
        let key = TileKey::Sxy((i / t) as u32, (j / t) as u32);
        let (li, lj) = (i % t, j % t);
        let mut inner = self.inner.lock().unwrap();
        match self.ensure(&mut inner, key) {
            Got::Resident => inner.resident[&key].mat[(li, lj)],
            Got::Transient(m) => m[(li, lj)],
        }
    }

    /// Row `i` of `S_xx` restricted to `cols`, appended into `out` — the
    /// tile-cache counterpart of [`Dataset::sxx_row_restricted`], resolving
    /// each needed tile at most once per miss under a single lock.
    pub fn sxx_row_restricted(&self, i: usize, cols: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(cols.len());
        let mut inner = self.inner.lock().unwrap();
        for &k in cols {
            let v = self.sxx_at(&mut inner, i, k);
            out.push(v);
        }
    }

    fn sxx_at(&self, inner: &mut TileInner, i: usize, j: usize) -> f64 {
        let t = self.tile;
        let (bi, bj) = (i / t, j / t);
        // Canonical upper-triangular block; the mirror entry reads the
        // transposed local position (S_xx is symmetric).
        let (key, li, lj) = if bi <= bj {
            (TileKey::Sxx(bi as u32, bj as u32), i % t, j % t)
        } else {
            (TileKey::Sxx(bj as u32, bi as u32), j % t, i % t)
        };
        match self.ensure(inner, key) {
            Got::Resident => inner.resident[&key].mat[(li, lj)],
            Got::Transient(m) => m[(li, lj)],
        }
    }

    /// Make `key` resident (hit, reload, or compute), spilling LRU tiles
    /// under budget pressure. Returns the tile by value only when the budget
    /// cannot hold it even with every other tile evicted.
    fn ensure(&self, inner: &mut TileInner, key: TileKey) -> Got {
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(tile) = inner.resident.get_mut(&key) {
            tile.last_used = clock;
            inner.stats.hits += 1;
            return Got::Resident;
        }
        inner.stats.misses += 1;
        // Disk before FLOPs: a previously spilled tile reloads in O(t²) I/O.
        let mat = match self.try_reload(inner, key) {
            Some(m) => {
                inner.stats.reloads += 1;
                m
            }
            None => {
                inner.stats.computes += 1;
                self.compute_tile(key)
            }
        };
        let bytes = mat.bytes();
        loop {
            match self.budget.track(bytes) {
                Ok(track) => {
                    inner.resident.insert(
                        key,
                        ResidentTile {
                            mat,
                            last_used: clock,
                            _track: track,
                        },
                    );
                    return Got::Resident;
                }
                Err(_) => {
                    if !self.spill_lru(inner) {
                        // Nothing left to evict: serve the read from the
                        // transient tile (§4.2's one-row-at-a-time mode).
                        return Got::Transient(mat);
                    }
                }
            }
        }
    }

    /// Evict the least-recently-used resident tile, writing a disk copy
    /// first unless one already exists (tiles are immutable, so an old spill
    /// stays valid). Returns false when nothing is resident.
    fn spill_lru(&self, inner: &mut TileInner) -> bool {
        let Some((&key, _)) = inner
            .resident
            .iter()
            .min_by_key(|(_, tile)| tile.last_used)
        else {
            return false;
        };
        let tile = inner.resident.remove(&key).expect("key just found");
        inner.stats.evictions += 1;
        if !inner.disk.contains_key(&key) {
            match self.write_spill(inner, key, &tile.mat) {
                Ok(()) => inner.stats.spills += 1,
                // A failed write just drops the tile; the next touch
                // recomputes it — slower, never wrong.
                Err(_) => {}
            }
        }
        true // dropping `tile` releases its Tracked bytes
    }

    fn slot_bytes(&self) -> u64 {
        HEADER_BYTES + (self.tile * self.tile * 8) as u64
    }

    fn write_spill(&self, inner: &mut TileInner, key: TileKey, mat: &Mat) -> io::Result<()> {
        if inner.spill.is_none() {
            let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
            let path = std::env::temp_dir().join(format!(
                "cggm-tiles-{}-{}.spill",
                std::process::id(),
                seq
            ));
            let file = File::options()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(&path)?;
            inner.spill = Some(SpillFile { file, path });
        }
        let slot = match inner.disk.get(&key) {
            Some(d) => d.slot,
            None => {
                let s = inner.next_slot;
                inner.next_slot += 1;
                s
            }
        };
        let (rows, cols) = (mat.rows(), mat.cols());
        let (bi, bj) = key.blocks();
        let mut buf = Vec::with_capacity(HEADER_BYTES as usize + rows * cols * 8);
        buf.extend_from_slice(&SPILL_MAGIC.to_le_bytes());
        buf.extend_from_slice(&key.tag().to_le_bytes());
        buf.extend_from_slice(&bi.to_le_bytes());
        buf.extend_from_slice(&bj.to_le_bytes());
        buf.extend_from_slice(&(rows as u32).to_le_bytes());
        buf.extend_from_slice(&(cols as u32).to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]); // pad header to 32 bytes
        for &v in mat.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let file = &inner.spill.as_ref().expect("spill just ensured").file;
        file.write_all_at(&buf, slot * self.slot_bytes())?;
        inner.disk.insert(
            key,
            DiskSlot {
                slot,
                rows: rows as u32,
                cols: cols as u32,
            },
        );
        Ok(())
    }

    /// Read a spilled tile back, verifying the slot header. Any torn,
    /// truncated, or mismatched slot invalidates the disk copy and falls
    /// back to recomputation — corruption costs time, never correctness.
    fn try_reload(&self, inner: &mut TileInner, key: TileKey) -> Option<Mat> {
        let slot = *inner.disk.get(&key)?;
        let mat = self.read_slot(inner, key, slot);
        if mat.is_none() {
            inner.disk.remove(&key);
        }
        mat
    }

    fn read_slot(&self, inner: &TileInner, key: TileKey, d: DiskSlot) -> Option<Mat> {
        let file = &inner.spill.as_ref()?.file;
        let off = d.slot * self.slot_bytes();
        let mut head = [0u8; HEADER_BYTES as usize];
        file.read_exact_at(&mut head, off).ok()?;
        let magic = u64::from_le_bytes(head[0..8].try_into().unwrap());
        let tag = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let bi = u32::from_le_bytes(head[12..16].try_into().unwrap());
        let bj = u32::from_le_bytes(head[16..20].try_into().unwrap());
        let rows = u32::from_le_bytes(head[20..24].try_into().unwrap());
        let cols = u32::from_le_bytes(head[24..28].try_into().unwrap());
        let want = key.blocks();
        if magic != SPILL_MAGIC
            || tag != key.tag()
            || (bi, bj) != want
            || rows != d.rows
            || cols != d.cols
        {
            return None;
        }
        let elems = rows as usize * cols as usize;
        let mut payload = vec![0u8; elems * 8];
        file.read_exact_at(&mut payload, off + HEADER_BYTES).ok()?;
        let data = payload
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Some(Mat::from_rows(rows as usize, cols as usize, data))
    }

    /// Build one tile: stream the two column panels and run the packed
    /// row-Gram product (the same idiom as the dense `S` builders, restricted
    /// to the block).
    fn compute_tile(&self, key: TileKey) -> Mat {
        let (t, n, inv_n) = (self.tile, self.data.n(), self.data.inv_n());
        let range = |b: u32, dim: usize| {
            let lo = b as usize * t;
            lo..(lo + t).min(dim)
        };
        match key {
            TileKey::Sxx(bi, bj) => {
                let (ri, rj) = (range(bi, self.data.p()), range(bj, self.data.p()));
                let mut pa = Mat::zeros(ri.len(), n);
                self.data.x_panel_into(ri, &mut pa);
                let mut out = Mat::zeros(pa.rows(), rj.len());
                if bi == bj {
                    self.engine.gemm_nt(inv_n, &pa, &pa, 0.0, &mut out);
                } else {
                    let mut pb = Mat::zeros(rj.len(), n);
                    self.data.x_panel_into(rj, &mut pb);
                    self.engine.gemm_nt(inv_n, &pa, &pb, 0.0, &mut out);
                }
                out
            }
            TileKey::Sxy(bi, bj) => {
                let (ri, rj) = (range(bi, self.data.p()), range(bj, self.data.q()));
                let mut pa = Mat::zeros(ri.len(), n);
                self.data.x_panel_into(ri, &mut pa);
                let mut pb = Mat::zeros(rj.len(), n);
                self.data.y_panel_into(rj, &mut pb);
                let mut out = Mat::zeros(pa.rows(), pb.rows());
                self.engine.gemm_nt(inv_n, &pa, &pb, 0.0, &mut out);
                out
            }
        }
    }

    /// Apply a sliding-window transition to the cache *in place*: every
    /// resident tile gets the symmetric rank-k correction
    /// `T ← (n·T + A_i·A_jᵀ − R_i·R_jᵀ)/n'` (O(t²·k) per tile instead of an
    /// O(t²·n) rebuild), and every spilled disk copy is invalidated — the
    /// window moved, so stale slots must never be reloaded. The store's
    /// `data` reference must already point at the *post-transition* dataset.
    /// Returns the number of tiles corrected (also accumulated into
    /// [`TileStats::updates`]).
    pub fn apply_update(&self, delta: &WindowDelta) -> usize {
        let mut inner = self.inner.lock().unwrap();
        // The old window's spill slots are stale under any non-empty delta.
        inner.disk.clear();
        inner.next_slot = 0;
        if delta.is_empty() {
            return 0;
        }
        debug_assert_eq!(delta.new_n(), self.data.n(), "delta out of sync");
        let keys: Vec<TileKey> = inner.resident.keys().copied().collect();
        for &key in &keys {
            let tile = inner.resident.get_mut(&key).expect("key just listed");
            correct_tile_mat(&mut tile.mat, key, self.tile, self.engine, delta);
        }
        inner.stats.updates += keys.len();
        keys.len()
    }

    /// Tear the store down into its carryable parts: the resident tiles
    /// (budget registrations released — the adopting store re-registers) and
    /// the lifetime counters. Spilled copies are dropped with the spill file.
    pub fn into_parts(self) -> (Vec<(TileKey, Mat)>, TileStats) {
        let inner = self.inner.into_inner().unwrap();
        let tiles = inner
            .resident
            .into_iter()
            .map(|(key, t)| (key, t.mat))
            .collect();
        (tiles, inner.stats)
    }

    /// Seed a fresh store from a predecessor's [`Self::into_parts`] output:
    /// counters carry forward and each tile is re-registered against this
    /// store's budget (a tile that no longer fits is silently dropped — it is
    /// only a cache). Tiles must describe the same (p, q, tile) geometry and
    /// the *current* window contents (correct them first when the window
    /// moved between teardown and adoption).
    pub fn adopt(&self, tiles: Vec<(TileKey, Mat)>, stats: TileStats) {
        let mut inner = self.inner.lock().unwrap();
        inner.stats = stats;
        for (key, mat) in tiles {
            debug_assert!(
                mat.rows() <= self.tile && mat.cols() <= self.tile,
                "adopted tile larger than the store's tile size"
            );
            inner.clock += 1;
            let clock = inner.clock;
            let bytes = mat.bytes();
            if let Ok(track) = self.budget.track(bytes) {
                inner.resident.insert(
                    key,
                    ResidentTile {
                        mat,
                        last_used: clock,
                        _track: track,
                    },
                );
            }
        }
    }
}

/// Copy feature rows `rows` of a delta panel (`src` is features × k) into a
/// contiguous sub-panel for the tile-local GEMM.
fn sub_panel(src: &Mat, rows: std::ops::Range<usize>) -> Mat {
    Mat::from_fn(rows.len(), src.cols(), |r, c| src[(rows.start + r, c)])
}

/// The rank-k window correction for one tile, shared by [`TileStore`]'s
/// in-place path and `SolverContext`'s pending-carry path:
/// `T ← (old_n·T + A_i·A_jᵀ − R_i·R_jᵀ)/new_n`, where `A`/`R` are the
/// appended/evicted panels restricted to the tile's feature ranges.
/// Transient scratch is two sub-panels, bounded by `2·t·k·8` bytes — the
/// same policy as the build panels. Diagonal `S_xx` tiles are re-symmetrized
/// so mirror reads stay exact.
pub(crate) fn correct_tile_mat(
    mat: &mut Mat,
    key: TileKey,
    tile: usize,
    engine: &dyn GemmEngine,
    delta: &WindowDelta,
) {
    let new_n = delta.new_n();
    assert!(new_n > 0, "window update emptied the dataset");
    let (bi, bj) = key.blocks();
    let ri = bi as usize * tile..bi as usize * tile + mat.rows();
    let rj = bj as usize * tile..bj as usize * tile + mat.cols();
    mat.scale(delta.old_n as f64 / new_n as f64);
    let inv = 1.0 / new_n as f64;
    let mut apply = |block: &crate::cggm::dataset::SampleBlock, sign: f64| {
        let pa = sub_panel(&block.xt, ri.clone());
        let pb = match key {
            TileKey::Sxx(..) => sub_panel(&block.xt, rj.clone()),
            TileKey::Sxy(..) => sub_panel(&block.yt, rj.clone()),
        };
        engine.gemm_nt(sign * inv, &pa, &pb, 1.0, mat);
    };
    if let Some(a) = &delta.added {
        apply(a, 1.0);
    }
    if let Some(r) = &delta.removed {
        apply(r, -1.0);
    }
    if matches!(key, TileKey::Sxx(..)) && bi == bj {
        mat.symmetrize();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;
    use crate::util::testing::{check_close, property};

    fn random_dataset(rng: &mut Rng, n: usize, p: usize, q: usize) -> Dataset {
        Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        )
    }

    #[test]
    fn tiled_entries_match_dense() {
        property(15, |rng| {
            let (n, p, q) = (2 + rng.below(9), 1 + rng.below(12), 1 + rng.below(9));
            let tile = 1 + rng.below(5);
            let d = random_dataset(rng, n, p, q);
            let eng = NativeGemm::new(1);
            let ts = TileStore::new(&d, &eng, MemBudget::unlimited(), tile);
            for i in 0..p {
                for j in 0..p {
                    check_close(ts.sxx_entry(i, j), d.sxx(i, j), 1e-12, "sxx")?;
                }
                for j in 0..q {
                    check_close(ts.sxy_entry(i, j), d.sxy(i, j), 1e-12, "sxy")?;
                }
            }
            // Every tile computed at most once under an unlimited budget.
            let st = ts.stats();
            if st.computes > ts.total_tiles() {
                return Err(format!(
                    "computed {} tiles, only {} exist",
                    st.computes,
                    ts.total_tiles()
                ));
            }
            if st.evictions != 0 || st.spills != 0 {
                return Err("unlimited budget must never evict".into());
            }
            Ok(())
        });
    }

    #[test]
    fn row_restricted_matches_dataset() {
        let mut rng = Rng::new(11);
        let d = random_dataset(&mut rng, 7, 13, 3);
        let eng = NativeGemm::new(1);
        let ts = TileStore::new(&d, &eng, MemBudget::unlimited(), 4);
        let cols = vec![0, 3, 9, 12, 5];
        let (mut got, mut want) = (Vec::new(), Vec::new());
        ts.sxx_row_restricted(6, &cols, &mut got);
        d.sxx_row_restricted(6, &cols, &mut want);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn lru_eviction_keeps_peak_under_budget() {
        let mut rng = Rng::new(5);
        let d = random_dataset(&mut rng, 10, 16, 4);
        let eng = NativeGemm::new(1);
        // tile 4 → a full S_xx tile is 4·4·8 = 128 bytes; allow two.
        let budget = MemBudget::new(256);
        let ts = TileStore::new(&d, &eng, budget.clone(), 4);
        for i in 0..16 {
            for j in 0..16 {
                assert!((ts.sxx_entry(i, j) - d.sxx(i, j)).abs() < 1e-12);
            }
        }
        assert!(budget.peak() <= 256, "peak {} over cap", budget.peak());
        let st = ts.stats();
        assert!(st.evictions > 0, "16 blocks cannot fit in 2 slots");
        assert!(st.spills > 0);
        assert!(ts.resident_bytes() <= 256);
    }

    #[test]
    fn spill_reload_roundtrip_avoids_recompute() {
        let mut rng = Rng::new(8);
        let d = random_dataset(&mut rng, 9, 8, 2);
        let eng = NativeGemm::new(1);
        // Exactly one resident 4×4 tile (128 bytes).
        let ts = TileStore::new(&d, &eng, MemBudget::new(128), 4);
        let a = ts.sxx_entry(0, 0); // tile (0,0) computed
        let _ = ts.sxx_entry(4, 4); // tile (1,1) computed; (0,0) spilled
        assert_eq!(ts.stats().computes, 2);
        assert_eq!(ts.stats().spills, 1);
        let a2 = ts.sxx_entry(0, 0); // (0,0) reloads from disk, (1,1) spills
        assert_eq!(a, a2);
        let st = ts.stats();
        assert_eq!(st.computes, 2, "reload must not recompute");
        assert_eq!(st.reloads, 1);
        // Re-evicting a tile whose disk copy is still valid writes nothing
        // new: ping-ponging between the two tiles leaves spills at 2 (one
        // fresh write per tile) while evictions keep climbing.
        let _ = ts.sxx_entry(4, 4);
        let _ = ts.sxx_entry(0, 0);
        let st = ts.stats();
        assert_eq!(st.spills, 2, "each tile spills fresh exactly once");
        assert_eq!(st.reloads, 3);
        assert!(st.evictions >= 3);
    }

    #[test]
    fn torn_spill_file_recomputes_correctly() {
        let mut rng = Rng::new(13);
        let d = random_dataset(&mut rng, 9, 8, 2);
        let eng = NativeGemm::new(1);
        let ts = TileStore::new(&d, &eng, MemBudget::new(128), 4);
        let a = ts.sxx_entry(0, 0);
        let _ = ts.sxx_entry(4, 4); // spills (0,0)
        let path = ts.spill_path().expect("eviction created a spill file");
        // Truncate mid-header: the reload must detect the torn slot.
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(5)
            .unwrap();
        let a2 = ts.sxx_entry(0, 0);
        assert_eq!(a, a2, "recomputed tile must match");
        let st = ts.stats();
        assert_eq!(st.reloads, 0, "torn slot must not count as a reload");
        assert_eq!(st.computes, 3, "torn slot falls back to recompute");
    }

    #[test]
    fn impossible_budget_serves_transient_reads() {
        let mut rng = Rng::new(21);
        let d = random_dataset(&mut rng, 6, 9, 3);
        let eng = NativeGemm::new(1);
        let budget = MemBudget::new(8); // smaller than any tile
        let ts = TileStore::new(&d, &eng, budget.clone(), 4);
        for i in 0..9 {
            for j in 0..3 {
                assert!((ts.sxy_entry(i, j) - d.sxy(i, j)).abs() < 1e-12);
            }
        }
        assert_eq!(ts.resident_tiles(), 0);
        assert_eq!(budget.peak(), 0, "transient tiles are never tracked");
    }

    #[test]
    fn adopt_then_apply_update_matches_fresh_store() {
        // The carry path used by warm refit: compute every tile on the old
        // window, tear the store down, adopt the tiles into a store over the
        // slid window, apply the rank-k correction, and compare against both
        // a fresh store and the dense statistics of the new window.
        use crate::cggm::dataset::{SampleBlock, WindowDelta};
        property(10, |rng| {
            let (n, p, q) = (4 + rng.below(8), 1 + rng.below(10), 1 + rng.below(6));
            let tile = 1 + rng.below(4);
            let k = 1 + rng.below(3);
            let d_old = random_dataset(rng, n, p, q);
            let added = SampleBlock::new(
                Mat::from_fn(p, k, |_, _| rng.normal()),
                Mat::from_fn(q, k, |_, _| rng.normal()),
            );
            let mut d_new = d_old.clone();
            let removed = d_new.evict_oldest(k).unwrap();
            d_new.append_block(&added).unwrap();
            let mut delta = WindowDelta::new(d_old.n());
            delta.record_evict(removed);
            delta.record_append(added);

            let eng = NativeGemm::new(1);
            let old_store = TileStore::new(&d_old, &eng, MemBudget::unlimited(), tile);
            for i in 0..p {
                for j in 0..p {
                    let _ = old_store.sxx_entry(i, j);
                }
                for j in 0..q {
                    let _ = old_store.sxy_entry(i, j);
                }
            }
            let computes_before = old_store.stats().computes;
            let (tiles, stats) = old_store.into_parts();

            let store = TileStore::new(&d_new, &eng, MemBudget::unlimited(), tile);
            store.adopt(tiles, stats);
            let corrected = store.apply_update(&delta);
            if corrected == 0 {
                return Err("no resident tiles were corrected".into());
            }
            for i in 0..p {
                for j in 0..p {
                    check_close(store.sxx_entry(i, j), d_new.sxx(i, j), 1e-10, "sxx")?;
                }
                for j in 0..q {
                    check_close(store.sxy_entry(i, j), d_new.sxy(i, j), 1e-10, "sxy")?;
                }
            }
            let st = store.stats();
            if st.computes != computes_before {
                return Err(format!(
                    "adopted tiles must serve reads without recompute: {} vs {}",
                    st.computes, computes_before
                ));
            }
            if st.updates != corrected {
                return Err("updates counter out of sync with corrected count".into());
            }
            Ok(())
        });
    }

    #[test]
    fn empty_delta_update_is_a_noop() {
        use crate::cggm::dataset::WindowDelta;
        let mut rng = Rng::new(17);
        let d = random_dataset(&mut rng, 6, 8, 3);
        let eng = NativeGemm::new(1);
        let ts = TileStore::new(&d, &eng, MemBudget::unlimited(), 4);
        let a = ts.sxx_entry(0, 0);
        assert_eq!(ts.apply_update(&WindowDelta::new(d.n())), 0);
        assert_eq!(ts.stats().updates, 0);
        assert_eq!(ts.sxx_entry(0, 0), a);
    }

    #[test]
    fn total_tiles_counts_triangle_plus_cross() {
        let mut rng = Rng::new(2);
        let d = random_dataset(&mut rng, 5, 10, 6);
        let eng = NativeGemm::new(1);
        // p=10, q=6, tile 4 → nbx=3, nby=2 → 3·4/2 + 3·2 = 12.
        let ts = TileStore::new(&d, &eng, MemBudget::unlimited(), 4);
        assert_eq!(ts.total_tiles(), 12);
    }
}
