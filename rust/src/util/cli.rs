//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Typed getters with defaults keep call sites short.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments. Flags listed in `bool_flags` take no value.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if i + 1 < raw.len() {
                    out.options.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|s| {
                s.replace('_', "")
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|s| {
                s.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .map(|s| {
                s.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--sizes 250,500,1000`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.opt(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.trim()
                        .replace('_', "")
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixture() {
        let a = Args::parse(
            &s(&["fit", "--p", "100", "--q=50", "--verbose", "data.bin"]),
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["fit", "data.bin"]);
        assert_eq!(a.get_usize("p", 0), 100);
        assert_eq!(a.get_usize("q", 0), 50);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&s(&[]), &[]);
        assert_eq!(a.get_f64("lambda", 0.5), 0.5);
        assert_eq!(a.get_str("solver", "alt"), "alt");
        assert_eq!(a.get_usize_list("sizes", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn lists_and_underscores() {
        let a = Args::parse(&s(&["--sizes", "1_000,2_000", "--n", "10_000"]), &[]);
        assert_eq!(a.get_usize_list("sizes", &[]), vec![1000, 2000]);
        assert_eq!(a.get_usize("n", 0), 10_000);
    }

    #[test]
    fn trailing_option_without_value_is_flag() {
        let a = Args::parse(&s(&["--dry-run"]), &[]);
        assert!(a.flag("dry-run"));
    }
}
