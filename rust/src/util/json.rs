//! Minimal JSON value model, parser, and writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), run configs,
//! experiment result files, and — since the `serve` subsystem — for
//! **untrusted** bytes arriving over the wire. The parser is therefore
//! hardened against hostile input:
//!
//! - nesting is capped at [`MAX_DEPTH`] levels (the recursive-descent
//!   `value`→`object`/`array` cycle would otherwise overflow the stack on a
//!   line of ~100k `[`, an abort no panic handler can catch);
//! - numbers follow the RFC 8259 grammar exactly (no `1.`, `01`, or bare
//!   `-`; Rust's more permissive `f64` parser only sees pre-validated text);
//! - [`Json::as_usize`]/[`Json::as_u64`] are *checked* extractions — NaN,
//!   infinities, negatives, fractions, and magnitudes past 2⁵³−1 return
//!   `None` instead of a silently saturated `as` cast.
//!
//! Documented lossy cases: JSON has no Inf/NaN, so non-finite numbers
//! serialize as `null`; and surrogate pairs in `\u` escapes are *not*
//! combined — each half decodes to U+FFFD (we never emit surrogate escapes,
//! and a hostile half-pair cannot smuggle arbitrary scalars this way).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    /// Checked index/count extraction: `Some` only for non-negative
    /// integral values that fit (see [`Self::as_u64`] for the range).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|x| usize::try_from(x).ok())
    }
    /// Checked integer extraction. `Some(x)` only when the number is
    /// finite, integral, non-negative, and at most 2⁵³ − 1 — the largest
    /// range where every integer has a unique f64 representation. 2⁵³
    /// itself is excluded because 2⁵³ + 1 rounds onto it, so a value of
    /// exactly 2⁵³ is ambiguous (this is what used to corrupt serve client
    /// ids above 2⁵³). NaN, ±∞, negatives, and fractions are `None`,
    /// never a saturated cast.
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_SAFE: f64 = 9_007_199_254_740_991.0; // 2^53 - 1
        let x = self.as_f64()?;
        if x.is_finite() && x.fract() == 0.0 && x >= 0.0 && x <= MAX_SAFE {
            Some(x as u64)
        } else {
            None
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object member access, `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Parse a JSON document. Hostile-input guarantees: errors, never
    /// panics or stack overflow, on any input (nesting past [`MAX_DEPTH`]
    /// is a [`JsonError`]).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossy case).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Maximum container nesting [`Json::parse`] accepts. The parser is
/// recursive descent, so depth is stack: without a cap, a line of ~100k
/// `[` overflows the thread stack — an *abort*, which no
/// `catch_unwind`-based job isolation (e.g. the serve engine's) can turn
/// into an error response. 128 is far beyond anything we emit (checkpoint
/// and response documents nest < 10 deep).
pub const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting, capped at [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    /// Enter one `[`/`{` level; errors past [`MAX_DEPTH`]. The matching
    /// decrement happens on the container's success path only — an error
    /// aborts the whole parse, so a stale count cannot leak.
    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Consume a run of ASCII digits; error if there is none. RFC 8259
    /// requires at least one digit after `.` and after `e`/`E[+-]`, which
    /// Rust's own f64 parser does not (it accepts `1.`, `1e`, …).
    fn digits(&mut self) -> Result<(), JsonError> {
        if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            return Err(self.err("expected a digit"));
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        Ok(())
    }

    /// RFC 8259 `number`: `-? (0 | [1-9][0-9]*) (\.[0-9]+)? ([eE][+-]?[0-9]+)?`.
    /// Leading zeros (`01`) fall out as a trailing-character error at the
    /// caller; `1.`, `.5`, `+1`, `1e`, and bare `-` are rejected here.
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("expected a digit")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            self.digits()?;
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            self.digits()?;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            // Overflow (e.g. `1e400` → ±∞) is rejected: JSON cannot
            // represent the result, so accepting it would break the
            // parse∘write round-trip (non-finite serializes as null).
            Ok(x) if x.is_finite() => Ok(Json::Num(x)),
            Ok(_) => Err(self.err("number overflows f64")),
            Err(_) => Err(self.err("bad number")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let raw = &self.bytes[self.pos + 1..self.pos + 5];
                            // Exactly four hex digits — from_str_radix alone
                            // would also accept a sign (e.g. "+1ff").
                            if !raw.iter().all(|b| b.is_ascii_hexdigit()) {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(raw).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not combined: each half is
                            // a non-scalar, so it decodes to U+FFFD (the
                            // documented lossy case — we never emit surrogate
                            // escapes ourselves).
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.descend()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.descend()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-1e3").unwrap(), Json::Num(-1000.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("gram_f64")),
            ("shape", Json::arr([Json::num(128.0), Json::num(256.0)])),
            ("ok", Json::Bool(true)),
            ("weird", Json::str("a\"b\\c\nd")),
        ]);
        for s in [v.to_string(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn integers_stay_integral_in_output() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::str("héllo ☃");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    /// Regression for the crash class the fuzz harness targets: on the
    /// seed parser a line of ~100k `[` overflowed the recursion stack —
    /// an abort, not a catchable panic. Must now be a plain error.
    #[test]
    fn deep_nesting_is_an_error_not_a_crash() {
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
        let bomb = "{\"a\":".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());

        // Boundary: exactly MAX_DEPTH levels parse, one more errors.
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let too_deep = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let err = Json::parse(&too_deep).unwrap_err();
        assert!(err.msg.contains("nesting"), "unexpected error: {err}");

        // Sibling (non-nested) containers are unlimited: depth is
        // released on each container's close.
        let wide = format!("[{}0]", "[0],".repeat(10_000));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn number_grammar_rfc8259() {
        for ok in [
            "0", "-0", "1", "20", "3.25", "-0.5", "1e3", "1E+3", "2e-2", "0.0",
            "123.456e-7",
        ] {
            assert!(Json::parse(ok).is_ok(), "should accept {ok:?}");
        }
        for bad in [
            "1.", ".5", "01", "-01", "+1", "1e", "1e+", "1.e3", "-", "--1",
            "0x10", "NaN", "Infinity", "1e+-3",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
        // Overflow to ±∞ is a parse error (null round-trip hazard);
        // underflow to zero is harmless and accepted.
        assert!(Json::parse("1e400").is_err());
        assert!(Json::parse("-1e400").is_err());
        assert_eq!(Json::parse("1e-400").unwrap(), Json::Num(0.0));
    }

    /// `as_usize`/`as_u64` are checked: the seed's saturating `as` cast
    /// turned `{"p":-1}` into 0 and `{"p":1e300}` into `usize::MAX`.
    #[test]
    fn as_usize_rejects_unsafe_numbers() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(f64::NEG_INFINITY).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
        // 2^53 - 1 is the largest exactly-representable safe integer;
        // 2^53 itself is ambiguous (2^53 + 1 rounds onto it) → None.
        assert_eq!(Json::Num(9_007_199_254_740_991.0).as_u64(), Some(9_007_199_254_740_991));
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), None);
    }

    #[test]
    fn unicode_escape_strictness() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
        // A lone surrogate half decodes to U+FFFD (documented lossy case).
        assert_eq!(
            Json::parse("\"\\ud800\"").unwrap(),
            Json::Str("\u{fffd}".into())
        );
        // Exactly four hex digits required; signs and truncation rejected.
        assert!(Json::parse("\"\\u+123\"").is_err());
        assert!(Json::parse("\"\\u12g4\"").is_err());
        assert!(Json::parse("\"\\u12\"").is_err());
        assert!(Json::parse("\"\\u123").is_err());
    }
}
