//! Minimal JSON value model, parser, and writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), run configs,
//! and experiment result files. serde is unavailable offline; this
//! implementation covers the full JSON grammar (RFC 8259) minus some
//! exotic escapes we never emit.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object member access, `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossy case).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (never emitted by us).
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-1e3").unwrap(), Json::Num(-1000.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("gram_f64")),
            ("shape", Json::arr([Json::num(128.0), Json::num(256.0)])),
            ("ok", Json::Bool(true)),
            ("weird", Json::str("a\"b\\c\nd")),
        ]);
        for s in [v.to_string(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn integers_stay_integral_in_output() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::str("héllo ☃");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
