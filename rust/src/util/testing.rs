//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Usage pattern, mirroring proptest's ergonomics at reduced power:
//!
//! ```ignore
//! property(100, |rng| {
//!     let n = 1 + rng.below(20);
//!     let m = random_spd(rng, n);
//!     // ... assert invariants, returning Err(msg) on failure ...
//!     Ok(())
//! });
//! ```
//!
//! Each case gets a deterministic per-case seed; failures report the seed so
//! the case can be replayed with `replay(seed, f)`.

use super::rng::Rng;

/// Run `cases` random test cases. Panics with the failing seed + message.
pub fn property<F>(cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let base = std::env::var("CGGM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xc99a_2015_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e37_79b9));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at case {case} (replay seed {seed}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay seed {seed}: {msg}");
    }
}

/// Assert two floats are close; returns Err for use inside properties.
pub fn check_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!(
            "{what}: {a} vs {b} (|Δ|={}, tol={tol}, scale={scale})",
            (a - b).abs()
        ))
    }
}

/// Assert slices are elementwise close.
pub fn check_all_close(a: &[f64], b: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        check_close(*x, *y, tol, &format!("{what}[{i}]"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes_trivially() {
        property(50, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn property_failure_reports_seed() {
        property(10, |rng| {
            let x = rng.uniform();
            if x < 2.0 {
                // Force a failure deterministically on case 3.
                if rng.below(10) == usize::MAX {
                    return Ok(());
                }
            }
            Err("forced".into())
        });
    }

    #[test]
    fn close_checks() {
        assert!(check_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(check_close(1.0, 1.1, 1e-9, "x").is_err());
        assert!(check_all_close(&[1.0, 2.0], &[1.0, 2.0], 1e-12, "v").is_ok());
        assert!(check_all_close(&[1.0], &[1.0, 2.0], 1e-12, "v").is_err());
    }
}
