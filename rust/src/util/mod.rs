//! Self-contained utility substrates.
//!
//! The offline build environment vendors only the `xla` crate, so the
//! conveniences a crates.io project would pull in (rand, serde, clap, rayon,
//! proptest) are implemented here from scratch.

pub mod cli;
pub mod json;
pub mod membudget;
pub mod rng;
pub mod testing;
pub mod threadpool;
pub mod timer;
