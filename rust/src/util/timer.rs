//! Timing helpers: a stopwatch and a hierarchical phase profiler used by the
//! solvers to attribute time to the paper's cost centers (Σ columns, Ψ/Gram
//! products, CD sweeps, line search, active-set screening).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Accumulates wall time per named phase. Cheap enough to leave in the hot
/// path (one `Instant::now()` pair per phase enter/exit, phases are coarse).
#[derive(Default)]
pub struct PhaseProfiler {
    totals: Mutex<BTreeMap<&'static str, (f64, u64)>>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase name.
    pub fn time<T>(&self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        let mut m = self.totals.lock().unwrap();
        let e = m.entry(phase).or_insert((0.0, 0));
        e.0 += dt;
        e.1 += 1;
        out
    }

    /// Add externally measured time.
    pub fn add(&self, phase: &'static str, seconds: f64) {
        let mut m = self.totals.lock().unwrap();
        let e = m.entry(phase).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += 1;
    }

    /// (phase, total seconds, call count), sorted by descending time.
    pub fn report(&self) -> Vec<(&'static str, f64, u64)> {
        let m = self.totals.lock().unwrap();
        let mut v: Vec<_> = m.iter().map(|(k, (s, c))| (*k, *s, *c)).collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (phase, secs, calls) in self.report() {
            out.push_str(&format!("{phase:<24} {secs:>10.3}s  ({calls} calls)\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiler_accumulates() {
        let p = PhaseProfiler::new();
        let x = p.time("work", || 21 * 2);
        assert_eq!(x, 42);
        p.time("work", || ());
        p.add("ext", 1.5);
        let rep = p.report();
        let work = rep.iter().find(|r| r.0 == "work").unwrap();
        assert_eq!(work.2, 2);
        let ext = rep.iter().find(|r| r.0 == "ext").unwrap();
        assert!((ext.1 - 1.5).abs() < 1e-12);
        assert!(!p.render().is_empty());
    }
}
