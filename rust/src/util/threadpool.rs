//! Scoped data-parallel helpers over `std::thread::scope`, plus a
//! persistent worker pool for long-lived drivers.
//!
//! Implements the paper's §Parallelization ("embarrassingly-parallelizable"
//! column computations: multiple columns of Σ via CG, elements of S_xx rows,
//! GEMM tiles). rayon is unavailable offline, so this provides the
//! primitives the solvers need: `parallel_for` over an index range,
//! `parallel_chunks_mut` over disjoint output slices, `parallel_fill` as a
//! deterministic parallel map, and `team` — a worker group with a spin
//! barrier for multi-phase work (the colored CD sweeps, which rendezvous
//! hundreds of times per pass).
//!
//! `team` historically spawned a fresh scoped thread group per call — per
//! CD *pass*, so a serving process paid thread spawn/join latency hundreds
//! of times per solve. [`TeamPool`] removes that: a fixed set of parked
//! worker threads that any number of sequential `team` calls reuse. A pool
//! is opt-in and thread-scoped: [`TeamPool::install`] binds it to the
//! current thread (RAII guard), and every [`Parallelism::team`] call made
//! from that thread runs on the pool when it fits (enough workers, not
//! already busy) and silently falls back to the scoped spawn otherwise —
//! numerics are identical either way, only the spawn cost changes. The
//! serve engine installs one shared pool around every job it runs.
//!
//! The thread count is a runtime parameter (`Parallelism`), which is how the
//! Fig. 3 speedup experiment sweeps 1..16 workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Spin barrier for compute-bound team phases. `std::sync::Barrier` parks
/// on a mutex/condvar (micro­seconds per rendezvous under contention); the
/// colored CD sweeps synchronize twice per color class — hundreds of times
/// per pass — so the ~100ns spin rendezvous is what keeps fine-grained
/// Gauss–Seidel phases profitable. Spins briefly, then yields (teams may be
/// oversubscribed in CI).
struct SpinBarrier {
    nt: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    /// Set when a team member panicked: every current and future `wait`
    /// returns immediately so the surviving members can drain out of the
    /// (now meaningless) phase protocol instead of spinning forever for an
    /// arrival that will never come. Only consulted on the panic path —
    /// the job's result is discarded and the panic re-raised by the
    /// dispatcher.
    poisoned: std::sync::atomic::AtomicBool,
}

impl SpinBarrier {
    fn new(nt: usize) -> SpinBarrier {
        SpinBarrier {
            nt,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            poisoned: std::sync::atomic::AtomicBool::new(false),
        }
    }

    fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    fn wait(&self) {
        if self.poisoned.load(Ordering::Acquire) {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.nt {
            // Last arriver resets the count, then opens the next generation;
            // waiters only proceed after observing the generation bump, which
            // orders the reset before any re-entry.
            self.count.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::Release);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                if self.poisoned.load(Ordering::Acquire) {
                    return;
                }
                spins = spins.wrapping_add(1);
                if spins < 1 << 10 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Handle given to each member of a [`Parallelism::team`]: the member count
/// and a barrier. Members partition shared work by their thread id and call
/// [`Team::sync`] between phases; every member must reach every `sync`
/// (classic barrier discipline).
pub struct Team<'a> {
    barrier: Option<&'a SpinBarrier>,
    nt: usize,
}

impl Team<'_> {
    pub fn threads(&self) -> usize {
        self.nt
    }

    /// Rendezvous with the rest of the team. No-op for a team of one.
    #[inline]
    pub fn sync(&self) {
        if let Some(b) = self.barrier {
            b.wait();
        }
    }
}

// ------------------------------------------------------------ worker pool

/// The closure shape every team member runs (elided lifetimes are
/// higher-ranked, so one alias covers all borrows).
type TeamBody = dyn Fn(usize, &Team) + Sync;

/// A dispatched team job, type-erased. The borrow lifetimes of `body` and
/// `barrier` are erased; soundness comes from the dispatch protocol: the
/// dispatcher blocks in [`TeamPool::dispatch`] until every worker has
/// decremented `remaining`, so both pointees strictly outlive every
/// dereference.
#[derive(Clone, Copy)]
struct RawJob {
    body: *const TeamBody,
    barrier: *const SpinBarrier,
    nt: usize,
}

// SAFETY: the raw pointers are only dereferenced by pool workers while the
// dispatching thread is blocked waiting for them (see `RawJob` docs); the
// pointee body is `Sync`, so shared cross-thread calls are allowed.
unsafe impl Send for RawJob {}

struct PoolState {
    /// Job generation counter; workers run one job per observed bump.
    gen: u64,
    job: Option<RawJob>,
    /// Workers that have not yet finished the current generation.
    remaining: usize,
    /// First panic payload a worker caught during the current generation;
    /// re-raised on the dispatching thread (scoped-spawn parity).
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    start: Condvar,
    /// The dispatcher parks here until `remaining == 0`.
    done: Condvar,
}

/// Persistent team-worker pool: `threads - 1` parked OS threads plus the
/// calling thread as member 0. One [`TeamPool::run`] at a time; concurrent
/// callers either block ([`TeamPool::run`]) or fall back to a scoped spawn
/// ([`TeamPool::try_run`] returning `false` — the path
/// [`Parallelism::team`] takes, so a busy pool degrades to the old
/// behavior instead of serializing unrelated solves).
pub struct TeamPool {
    shared: Arc<PoolShared>,
    /// Serializes dispatches; `try_lock` is the busy probe.
    run_lock: Mutex<()>,
    /// Total team members a run may use (workers + the caller).
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl TeamPool {
    /// A pool supporting teams of up to `threads` members (spawns
    /// `threads - 1` workers; the dispatching thread is member 0).
    pub fn new(threads: usize) -> TeamPool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                gen: 0,
                job: None,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|tid| {
                let shared = shared.clone();
                std::thread::spawn(move || Self::worker_loop(shared, tid))
            })
            .collect();
        TeamPool {
            shared,
            run_lock: Mutex::new(()),
            threads,
            handles,
        }
    }

    /// Largest team this pool can host.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn worker_loop(shared: Arc<PoolShared>, tid: usize) {
        let mut seen = 0u64;
        loop {
            let job = {
                let mut st = shared.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.gen != seen {
                        seen = st.gen;
                        break st.job;
                    }
                    st = shared.start.wait(st).unwrap();
                }
            };
            // `job` is always `Some` here: the dispatcher clears it only
            // after every worker decremented `remaining` for its generation
            // (the guard below is defensive, not a reachable path).
            let Some(job) = job else { continue };
            let mut caught = None;
            if tid < job.nt {
                // SAFETY: the dispatcher blocks until `remaining == 0`
                // before returning (and before dropping body/barrier), so
                // both pointers are live for the duration of this call.
                let body = unsafe { &*job.body };
                let barrier = unsafe { &*job.barrier };
                // The body may panic (solver asserts). The decrement below
                // MUST still happen or the dispatcher waits forever, and
                // the barrier must be poisoned so sibling members stop
                // spinning for this member's arrivals; the payload is
                // re-raised on the dispatching thread.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    body(
                        tid,
                        &Team {
                            barrier: Some(barrier),
                            nt: job.nt,
                        },
                    )
                }));
                if let Err(payload) = result {
                    barrier.poison();
                    caught = Some(payload);
                }
            }
            let mut st = shared.state.lock().unwrap();
            if st.panic.is_none() {
                st.panic = caught;
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                shared.done.notify_one();
            }
        }
    }

    /// Dispatch `body` as a team of `nt` (preconditions checked by the
    /// callers; the run lock is held by them).
    ///
    /// Panic-safe: member 0's body runs under `catch_unwind` so this
    /// function *always* waits for every worker before the stack-allocated
    /// barrier and the borrowed body go away (the soundness contract of
    /// [`RawJob`]); any member's panic poisons the barrier (so siblings
    /// drain instead of spinning forever) and is re-raised here afterwards
    /// — the same observable behavior as a panicking scoped spawn.
    fn dispatch(&self, nt: usize, body: &TeamBody) {
        let barrier = SpinBarrier::new(nt);
        let raw = RawJob {
            // SAFETY (transmute): erases the borrow lifetime from the fat
            // reference; this function does not return until every worker
            // has finished calling through it.
            body: unsafe { std::mem::transmute::<&TeamBody, *const TeamBody>(body) },
            barrier: &barrier,
            nt,
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.gen += 1;
            st.job = Some(raw);
            st.remaining = self.handles.len();
            st.panic = None;
            self.shared.start.notify_all();
        }
        let result0 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(
                0,
                &Team {
                    barrier: Some(&barrier),
                    nt,
                },
            )
        }));
        if result0.is_err() {
            barrier.poison();
        }
        let worker_panic = {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panic.take()
        };
        if let Err(payload) = result0 {
            std::panic::resume_unwind(payload);
        }
        if let Some(payload) = worker_panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Run a team of `nt` on the pool, blocking if another run is in
    /// flight. Panics if `nt` exceeds the pool size.
    pub fn run(&self, nt: usize, body: &TeamBody) {
        assert!(
            nt >= 1 && nt <= self.threads,
            "team of {nt} on a {}-thread pool",
            self.threads
        );
        if nt == 1 {
            body(0, &Team { barrier: None, nt: 1 });
            return;
        }
        let _guard = self.run_lock.lock().unwrap();
        self.dispatch(nt, body);
    }

    /// Non-blocking [`Self::run`]: `false` when the pool is too small for
    /// `nt` or currently busy (including a nested call from a thread that
    /// is already dispatching) — the caller should fall back to a scoped
    /// spawn.
    pub fn try_run(&self, nt: usize, body: &TeamBody) -> bool {
        if nt < 2 || nt > self.threads {
            return false;
        }
        let Ok(_guard) = self.run_lock.try_lock() else {
            return false;
        };
        self.dispatch(nt, body);
        true
    }

    /// Bind `pool` to the current thread until the guard drops: subsequent
    /// [`Parallelism::team`] calls from this thread reuse it when they fit.
    pub fn install(pool: &Arc<TeamPool>) -> PoolInstallGuard {
        let prev = CURRENT_POOL.with(|c| c.borrow_mut().replace(pool.clone()));
        PoolInstallGuard { prev }
    }

    fn current() -> Option<Arc<TeamPool>> {
        CURRENT_POOL.with(|c| c.borrow().clone())
    }
}

impl Drop for TeamPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

thread_local! {
    static CURRENT_POOL: std::cell::RefCell<Option<Arc<TeamPool>>> =
        std::cell::RefCell::new(None);
}

/// RAII guard of [`TeamPool::install`]; restores the previously installed
/// pool (if any) on drop.
pub struct PoolInstallGuard {
    prev: Option<Arc<TeamPool>>,
}

impl Drop for PoolInstallGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT_POOL.with(|c| *c.borrow_mut() = prev);
    }
}

/// Raw shared view of a mutable f64 buffer for [`Team`] phases. Barrier
/// discipline (compute phases only read, apply phases write disjoint
/// targets, a `sync` between them) is the caller's obligation — every
/// accessor is `unsafe` and states its requirement.
pub struct SharedSlice {
    ptr: *mut f64,
    len: usize,
}

unsafe impl Sync for SharedSlice {}
unsafe impl Send for SharedSlice {}

impl SharedSlice {
    pub fn new(s: &mut [f64]) -> SharedSlice {
        SharedSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
        }
    }

    /// SAFETY: caller guarantees no concurrent writes overlap this range.
    pub unsafe fn slice(&self, start: usize, len: usize) -> &[f64] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts(self.ptr.add(start), len)
    }

    /// SAFETY: caller guarantees this range is written by exactly one
    /// thread and read by none until the next barrier.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [f64] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// SAFETY: slot `i` is written by exactly one thread this phase.
    pub unsafe fn write(&self, i: usize, v: f64) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }
}

/// Raw shared handle to a structure only one designated thread mutates
/// (e.g. the colored CD passes' sparse direction), with read access for
/// everyone between mutation phases.
pub struct SharedMut<T> {
    ptr: *mut T,
}

unsafe impl<T> Sync for SharedMut<T> {}
unsafe impl<T> Send for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(p: &mut T) -> SharedMut<T> {
        SharedMut { ptr: p }
    }

    /// SAFETY: no `get_mut` borrow may be live concurrently.
    pub unsafe fn get_ref(&self) -> &T {
        &*self.ptr
    }

    /// SAFETY: designated-thread-only, with no concurrent `get_ref` borrows.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self) -> &mut T {
        &mut *self.ptr
    }
}

/// Degree of parallelism for a solver run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    pub threads: usize,
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism { threads: 1 }
    }
}

impl Parallelism {
    pub fn new(threads: usize) -> Self {
        Parallelism {
            threads: threads.max(1),
        }
    }

    /// Run `body(i)` for every `i` in `0..n`, dynamically load-balanced in
    /// chunks. `body` must be safe to call concurrently for distinct `i`.
    pub fn parallel_for<F>(&self, n: usize, chunk: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        let nt = self.threads.min(n.max(1));
        if nt <= 1 || n <= chunk {
            for i in 0..n {
                body(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        std::thread::scope(|s| {
            for _ in 0..nt {
                s.spawn(|| loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        body(i);
                    }
                });
            }
        });
    }

    /// Run `body(tid, &team)` on this handle's worker count as one team.
    /// Unlike [`Self::parallel_for`], which spawns per call, a team spawns
    /// once and coordinates arbitrarily many phases through [`Team::sync`]
    /// — the primitive behind the colored CD sweeps, whose Gauss–Seidel
    /// class sequence needs hundreds of cheap barriers per pass.
    /// Shared-state partitioning (disjoint index ranges per `tid`) is the
    /// body's responsibility.
    ///
    /// When a [`TeamPool`] is installed on the calling thread (long-lived
    /// drivers: the serve engine installs one around every job) and it can
    /// host this team right now, the pool's parked workers are reused
    /// instead of spawning; otherwise the call spawns a scoped group
    /// exactly as before. The two paths are numerically identical — same
    /// member count, same barrier discipline.
    pub fn team<F>(&self, body: F)
    where
        F: Fn(usize, &Team) + Sync,
    {
        let nt = self.threads.max(1);
        if nt == 1 {
            body(0, &Team { barrier: None, nt: 1 });
            return;
        }
        if let Some(pool) = TeamPool::current() {
            if pool.try_run(nt, &body) {
                return;
            }
        }
        let barrier = SpinBarrier::new(nt);
        std::thread::scope(|s| {
            for tid in 0..nt {
                let barrier = &barrier;
                let body = &body;
                s.spawn(move || {
                    body(
                        tid,
                        &Team {
                            barrier: Some(barrier),
                            nt,
                        },
                    )
                });
            }
        });
    }

    /// Fill `out[i] = f(i)` for every `i`, data-parallel with static
    /// chunking — a deterministic parallel map: slots are disjoint, so the
    /// result is identical for every thread count.
    pub fn parallel_fill<T: Send, F>(&self, out: &mut [T], chunk: usize, f: F)
    where
        F: Fn(usize) -> T + Sync,
    {
        let chunk = chunk.max(1);
        self.parallel_chunks_mut(out, chunk, |ci, slots| {
            let base = ci * chunk;
            for (k, slot) in slots.iter_mut().enumerate() {
                *slot = f(base + k);
            }
        });
    }

    /// Split `out` into contiguous chunks of `chunk_len` and run
    /// `body(chunk_index, chunk)` in parallel. Chunks are disjoint, so `body`
    /// may mutate freely.
    pub fn parallel_chunks_mut<T: Send, F>(&self, out: &mut [T], chunk_len: usize, body: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let nchunks = out.len().div_ceil(chunk_len);
        let nt = self.threads.min(nchunks.max(1));
        if nt <= 1 {
            for (ci, chunk) in out.chunks_mut(chunk_len).enumerate() {
                body(ci, chunk);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        // Collect raw chunk bounds; each worker claims chunk indices.
        let base = out.as_mut_ptr() as usize;
        let total = out.len();
        let elem = std::mem::size_of::<T>();
        std::thread::scope(|s| {
            for _ in 0..nt {
                s.spawn(|| loop {
                    let ci = next.fetch_add(1, Ordering::Relaxed);
                    let start = ci * chunk_len;
                    if start >= total {
                        break;
                    }
                    let len = chunk_len.min(total - start);
                    // SAFETY: chunks [start, start+len) are disjoint across ci,
                    // and `out` outlives the scope.
                    let chunk = unsafe {
                        std::slice::from_raw_parts_mut((base + start * elem) as *mut T, len)
                    };
                    body(ci, chunk);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        for threads in [1, 2, 4, 8] {
            let par = Parallelism::new(threads);
            let n = 1000;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            par.parallel_for(n, 16, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_chunks_mut_writes_disjointly() {
        for threads in [1, 3, 8] {
            let par = Parallelism::new(threads);
            let mut v = vec![0usize; 257];
            par.parallel_chunks_mut(&mut v, 10, |ci, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = ci * 10 + k;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i);
            }
        }
    }

    #[test]
    fn zero_len_ok() {
        let par = Parallelism::new(4);
        par.parallel_for(0, 8, |_| panic!("should not run"));
        let mut v: Vec<u8> = vec![];
        par.parallel_chunks_mut(&mut v, 4, |_, _| panic!("should not run"));
        let mut w: Vec<f64> = vec![];
        par.parallel_fill(&mut w, 4, |_| panic!("should not run"));
    }

    #[test]
    fn team_barriers_order_phases() {
        // Phase 1 writes disjoint slots; phase 2 (after sync) reads ALL
        // slots — correct only if the barrier actually separates phases.
        for threads in [1, 2, 4] {
            let par = Parallelism::new(threads);
            let n = 64;
            let slots: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let sums: Vec<AtomicU64> = (0..threads).map(|_| AtomicU64::new(0)).collect();
            par.team(|tid, team| {
                let nt = team.threads();
                for round in 1..=3u64 {
                    for k in (tid..n).step_by(nt) {
                        slots[k].store(k as u64 * round, Ordering::Relaxed);
                    }
                    team.sync();
                    let s: u64 = slots.iter().map(|x| x.load(Ordering::Relaxed)).sum();
                    sums[tid].fetch_add(s, Ordering::Relaxed);
                    team.sync();
                }
            });
            let base: u64 = (0..n as u64).sum();
            let want = base * (1 + 2 + 3);
            for (tid, s) in sums.iter().enumerate() {
                assert_eq!(
                    s.load(Ordering::Relaxed),
                    want,
                    "threads={threads} tid={tid}"
                );
            }
        }
    }

    #[test]
    fn team_pool_reuses_workers_across_runs() {
        // The same phase-ordering property as `team_barriers_order_phases`,
        // but run repeatedly on one pool: correctness must hold on parked
        // workers exactly as on fresh scoped spawns.
        let pool = TeamPool::new(4);
        for round in 0..20 {
            for nt in [2usize, 3, 4] {
                let n = 48;
                let slots: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                let sums: Vec<AtomicU64> = (0..nt).map(|_| AtomicU64::new(0)).collect();
                pool.run(nt, &|tid, team| {
                    assert_eq!(team.threads(), nt);
                    for k in (tid..n).step_by(nt) {
                        slots[k].store(k as u64 + round, Ordering::Relaxed);
                    }
                    team.sync();
                    let s: u64 = slots.iter().map(|x| x.load(Ordering::Relaxed)).sum();
                    sums[tid].fetch_add(s, Ordering::Relaxed);
                    team.sync();
                });
                let want: u64 = (0..n as u64).map(|k| k + round).sum();
                for s in &sums {
                    assert_eq!(s.load(Ordering::Relaxed), want, "nt={nt} round={round}");
                }
            }
        }
    }

    #[test]
    fn installed_pool_serves_parallelism_team() {
        let pool = Arc::new(TeamPool::new(3));
        let _guard = TeamPool::install(&pool);
        let par = Parallelism::new(3);
        let hits: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        for _ in 0..5 {
            par.team(|tid, team| {
                assert_eq!(team.threads(), 3);
                hits[tid].fetch_add(1, Ordering::Relaxed);
                team.sync();
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 5);
        }
        // Oversized teams fall back to the scoped spawn and still work.
        let par8 = Parallelism::new(8);
        let wide: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
        par8.team(|tid, _| {
            wide[tid].fetch_add(1, Ordering::Relaxed);
        });
        assert!(wide.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_team_on_pooled_caller_falls_back() {
        // The outer team runs on the pool; the inner call happens while the
        // pool's run lock is held (on the dispatcher) or on a worker thread
        // with no installed pool — both must fall back to a scoped spawn
        // rather than deadlock.
        let pool = Arc::new(TeamPool::new(2));
        let _guard = TeamPool::install(&pool);
        let par = Parallelism::new(2);
        let inner_runs = AtomicU64::new(0);
        par.team(|_, team| {
            let inner = Parallelism::new(2);
            inner.team(|_, _| {
                inner_runs.fetch_add(1, Ordering::Relaxed);
            });
            team.sync();
        });
        assert_eq!(inner_runs.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn pooled_team_propagates_panics_and_survives() {
        let pool = Arc::new(TeamPool::new(2));
        let _guard = TeamPool::install(&pool);
        let par = Parallelism::new(2);
        // A panicking member must re-raise on the caller (scoped-spawn
        // parity), not hang the dispatcher or leave dangling job pointers.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par.team(|tid, team| {
                if tid == 1 {
                    panic!("member panic");
                }
                // Poisoned barrier: returns instead of spinning forever
                // for the panicked member's arrival.
                team.sync();
            });
        }));
        assert!(result.is_err(), "the member's panic must propagate");
        // The pool survives and serves the next team normally.
        let hits: Vec<AtomicU64> = (0..2).map(|_| AtomicU64::new(0)).collect();
        par.team(|tid, team| {
            hits[tid].fetch_add(1, Ordering::Relaxed);
            team.sync();
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn install_guard_restores_previous_pool() {
        let a = Arc::new(TeamPool::new(2));
        let b = Arc::new(TeamPool::new(2));
        let _ga = TeamPool::install(&a);
        {
            let _gb = TeamPool::install(&b);
            assert!(Arc::ptr_eq(&TeamPool::current().unwrap(), &b));
        }
        assert!(Arc::ptr_eq(&TeamPool::current().unwrap(), &a));
        drop(_ga);
        assert!(TeamPool::current().is_none());
    }

    #[test]
    fn concurrent_pool_users_complete() {
        // Two threads hammer one shared pool; whoever finds it busy takes
        // the scoped fallback. Every team invocation must still cover all
        // member ids exactly once.
        let pool = Arc::new(TeamPool::new(2));
        std::thread::scope(|s| {
            for _ in 0..2 {
                let pool = pool.clone();
                s.spawn(move || {
                    let _g = TeamPool::install(&pool);
                    let par = Parallelism::new(2);
                    for _ in 0..50 {
                        let hits: Vec<AtomicU64> =
                            (0..2).map(|_| AtomicU64::new(0)).collect();
                        par.team(|tid, team| {
                            hits[tid].fetch_add(1, Ordering::Relaxed);
                            team.sync();
                        });
                        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
                    }
                });
            }
        });
    }

    #[test]
    fn parallel_fill_matches_serial_map() {
        for threads in [1, 2, 4, 8] {
            let par = Parallelism::new(threads);
            let mut out = vec![0.0f64; 333];
            par.parallel_fill(&mut out, 7, |i| (i as f64).sqrt());
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, (i as f64).sqrt(), "threads={threads} i={i}");
            }
        }
    }
}
