//! Scoped data-parallel helpers over `std::thread::scope`.
//!
//! Implements the paper's §Parallelization ("embarrassingly-parallelizable"
//! column computations: multiple columns of Σ via CG, elements of S_xx rows,
//! GEMM tiles). rayon is unavailable offline, so this provides the two
//! primitives the solvers need: `parallel_for` over an index range with
//! static chunking, and `parallel_chunks_mut` over disjoint output slices.
//!
//! The thread count is a runtime parameter (`Parallelism`), which is how the
//! Fig. 3 speedup experiment sweeps 1..16 workers.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Degree of parallelism for a solver run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    pub threads: usize,
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism { threads: 1 }
    }
}

impl Parallelism {
    pub fn new(threads: usize) -> Self {
        Parallelism {
            threads: threads.max(1),
        }
    }

    /// Run `body(i)` for every `i` in `0..n`, dynamically load-balanced in
    /// chunks. `body` must be safe to call concurrently for distinct `i`.
    pub fn parallel_for<F>(&self, n: usize, chunk: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        let nt = self.threads.min(n.max(1));
        if nt <= 1 || n <= chunk {
            for i in 0..n {
                body(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        std::thread::scope(|s| {
            for _ in 0..nt {
                s.spawn(|| loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        body(i);
                    }
                });
            }
        });
    }

    /// Split `out` into contiguous chunks of `chunk_len` and run
    /// `body(chunk_index, chunk)` in parallel. Chunks are disjoint, so `body`
    /// may mutate freely.
    pub fn parallel_chunks_mut<T: Send, F>(&self, out: &mut [T], chunk_len: usize, body: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let nchunks = out.len().div_ceil(chunk_len);
        let nt = self.threads.min(nchunks.max(1));
        if nt <= 1 {
            for (ci, chunk) in out.chunks_mut(chunk_len).enumerate() {
                body(ci, chunk);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        // Collect raw chunk bounds; each worker claims chunk indices.
        let base = out.as_mut_ptr() as usize;
        let total = out.len();
        let elem = std::mem::size_of::<T>();
        std::thread::scope(|s| {
            for _ in 0..nt {
                s.spawn(|| loop {
                    let ci = next.fetch_add(1, Ordering::Relaxed);
                    let start = ci * chunk_len;
                    if start >= total {
                        break;
                    }
                    let len = chunk_len.min(total - start);
                    // SAFETY: chunks [start, start+len) are disjoint across ci,
                    // and `out` outlives the scope.
                    let chunk = unsafe {
                        std::slice::from_raw_parts_mut((base + start * elem) as *mut T, len)
                    };
                    body(ci, chunk);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        for threads in [1, 2, 4, 8] {
            let par = Parallelism::new(threads);
            let n = 1000;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            par.parallel_for(n, 16, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_chunks_mut_writes_disjointly() {
        for threads in [1, 3, 8] {
            let par = Parallelism::new(threads);
            let mut v = vec![0usize; 257];
            par.parallel_chunks_mut(&mut v, 10, |ci, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = ci * 10 + k;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i);
            }
        }
    }

    #[test]
    fn zero_len_ok() {
        let par = Parallelism::new(4);
        par.parallel_for(0, 8, |_| panic!("should not run"));
        let mut v: Vec<u8> = vec![];
        par.parallel_chunks_mut(&mut v, 4, |_, _| panic!("should not run"));
    }
}
