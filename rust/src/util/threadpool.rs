//! Scoped data-parallel helpers over `std::thread::scope`.
//!
//! Implements the paper's §Parallelization ("embarrassingly-parallelizable"
//! column computations: multiple columns of Σ via CG, elements of S_xx rows,
//! GEMM tiles). rayon is unavailable offline, so this provides the
//! primitives the solvers need: `parallel_for` over an index range,
//! `parallel_chunks_mut` over disjoint output slices, `parallel_fill` as a
//! deterministic parallel map, and `team` — a scoped worker group with a
//! spin barrier for multi-phase work (the colored CD sweeps, which
//! rendezvous hundreds of times per pass).
//!
//! The thread count is a runtime parameter (`Parallelism`), which is how the
//! Fig. 3 speedup experiment sweeps 1..16 workers.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Spin barrier for compute-bound team phases. `std::sync::Barrier` parks
/// on a mutex/condvar (micro­seconds per rendezvous under contention); the
/// colored CD sweeps synchronize twice per color class — hundreds of times
/// per pass — so the ~100ns spin rendezvous is what keeps fine-grained
/// Gauss–Seidel phases profitable. Spins briefly, then yields (teams may be
/// oversubscribed in CI).
struct SpinBarrier {
    nt: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    fn new(nt: usize) -> SpinBarrier {
        SpinBarrier {
            nt,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
        }
    }

    fn wait(&self) {
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.nt {
            // Last arriver resets the count, then opens the next generation;
            // waiters only proceed after observing the generation bump, which
            // orders the reset before any re-entry.
            self.count.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::Release);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                spins = spins.wrapping_add(1);
                if spins < 1 << 10 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Handle given to each member of a [`Parallelism::team`]: the member count
/// and a barrier. Members partition shared work by their thread id and call
/// [`Team::sync`] between phases; every member must reach every `sync`
/// (classic barrier discipline).
pub struct Team<'a> {
    barrier: Option<&'a SpinBarrier>,
    nt: usize,
}

impl Team<'_> {
    pub fn threads(&self) -> usize {
        self.nt
    }

    /// Rendezvous with the rest of the team. No-op for a team of one.
    #[inline]
    pub fn sync(&self) {
        if let Some(b) = self.barrier {
            b.wait();
        }
    }
}

/// Raw shared view of a mutable f64 buffer for [`Team`] phases. Barrier
/// discipline (compute phases only read, apply phases write disjoint
/// targets, a `sync` between them) is the caller's obligation — every
/// accessor is `unsafe` and states its requirement.
pub struct SharedSlice {
    ptr: *mut f64,
    len: usize,
}

unsafe impl Sync for SharedSlice {}
unsafe impl Send for SharedSlice {}

impl SharedSlice {
    pub fn new(s: &mut [f64]) -> SharedSlice {
        SharedSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
        }
    }

    /// SAFETY: caller guarantees no concurrent writes overlap this range.
    pub unsafe fn slice(&self, start: usize, len: usize) -> &[f64] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts(self.ptr.add(start), len)
    }

    /// SAFETY: caller guarantees this range is written by exactly one
    /// thread and read by none until the next barrier.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [f64] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// SAFETY: slot `i` is written by exactly one thread this phase.
    pub unsafe fn write(&self, i: usize, v: f64) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }
}

/// Raw shared handle to a structure only one designated thread mutates
/// (e.g. the colored CD passes' sparse direction), with read access for
/// everyone between mutation phases.
pub struct SharedMut<T> {
    ptr: *mut T,
}

unsafe impl<T> Sync for SharedMut<T> {}
unsafe impl<T> Send for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(p: &mut T) -> SharedMut<T> {
        SharedMut { ptr: p }
    }

    /// SAFETY: no `get_mut` borrow may be live concurrently.
    pub unsafe fn get_ref(&self) -> &T {
        &*self.ptr
    }

    /// SAFETY: designated-thread-only, with no concurrent `get_ref` borrows.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self) -> &mut T {
        &mut *self.ptr
    }
}

/// Degree of parallelism for a solver run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    pub threads: usize,
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism { threads: 1 }
    }
}

impl Parallelism {
    pub fn new(threads: usize) -> Self {
        Parallelism {
            threads: threads.max(1),
        }
    }

    /// Run `body(i)` for every `i` in `0..n`, dynamically load-balanced in
    /// chunks. `body` must be safe to call concurrently for distinct `i`.
    pub fn parallel_for<F>(&self, n: usize, chunk: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        let nt = self.threads.min(n.max(1));
        if nt <= 1 || n <= chunk {
            for i in 0..n {
                body(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        std::thread::scope(|s| {
            for _ in 0..nt {
                s.spawn(|| loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        body(i);
                    }
                });
            }
        });
    }

    /// Run `body(tid, &team)` on this handle's worker count as one scoped
    /// team. Unlike [`Self::parallel_for`], which spawns per call, a team
    /// spawns once and coordinates arbitrarily many phases through
    /// [`Team::sync`] — the primitive behind the colored CD sweeps, whose
    /// Gauss–Seidel class sequence needs hundreds of cheap barriers per
    /// pass. Shared-state partitioning (disjoint index ranges per `tid`)
    /// is the body's responsibility.
    pub fn team<F>(&self, body: F)
    where
        F: Fn(usize, &Team) + Sync,
    {
        let nt = self.threads.max(1);
        if nt == 1 {
            body(0, &Team { barrier: None, nt: 1 });
            return;
        }
        let barrier = SpinBarrier::new(nt);
        std::thread::scope(|s| {
            for tid in 0..nt {
                let barrier = &barrier;
                let body = &body;
                s.spawn(move || {
                    body(
                        tid,
                        &Team {
                            barrier: Some(barrier),
                            nt,
                        },
                    )
                });
            }
        });
    }

    /// Fill `out[i] = f(i)` for every `i`, data-parallel with static
    /// chunking — a deterministic parallel map: slots are disjoint, so the
    /// result is identical for every thread count.
    pub fn parallel_fill<T: Send, F>(&self, out: &mut [T], chunk: usize, f: F)
    where
        F: Fn(usize) -> T + Sync,
    {
        let chunk = chunk.max(1);
        self.parallel_chunks_mut(out, chunk, |ci, slots| {
            let base = ci * chunk;
            for (k, slot) in slots.iter_mut().enumerate() {
                *slot = f(base + k);
            }
        });
    }

    /// Split `out` into contiguous chunks of `chunk_len` and run
    /// `body(chunk_index, chunk)` in parallel. Chunks are disjoint, so `body`
    /// may mutate freely.
    pub fn parallel_chunks_mut<T: Send, F>(&self, out: &mut [T], chunk_len: usize, body: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let nchunks = out.len().div_ceil(chunk_len);
        let nt = self.threads.min(nchunks.max(1));
        if nt <= 1 {
            for (ci, chunk) in out.chunks_mut(chunk_len).enumerate() {
                body(ci, chunk);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        // Collect raw chunk bounds; each worker claims chunk indices.
        let base = out.as_mut_ptr() as usize;
        let total = out.len();
        let elem = std::mem::size_of::<T>();
        std::thread::scope(|s| {
            for _ in 0..nt {
                s.spawn(|| loop {
                    let ci = next.fetch_add(1, Ordering::Relaxed);
                    let start = ci * chunk_len;
                    if start >= total {
                        break;
                    }
                    let len = chunk_len.min(total - start);
                    // SAFETY: chunks [start, start+len) are disjoint across ci,
                    // and `out` outlives the scope.
                    let chunk = unsafe {
                        std::slice::from_raw_parts_mut((base + start * elem) as *mut T, len)
                    };
                    body(ci, chunk);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        for threads in [1, 2, 4, 8] {
            let par = Parallelism::new(threads);
            let n = 1000;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            par.parallel_for(n, 16, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_chunks_mut_writes_disjointly() {
        for threads in [1, 3, 8] {
            let par = Parallelism::new(threads);
            let mut v = vec![0usize; 257];
            par.parallel_chunks_mut(&mut v, 10, |ci, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = ci * 10 + k;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i);
            }
        }
    }

    #[test]
    fn zero_len_ok() {
        let par = Parallelism::new(4);
        par.parallel_for(0, 8, |_| panic!("should not run"));
        let mut v: Vec<u8> = vec![];
        par.parallel_chunks_mut(&mut v, 4, |_, _| panic!("should not run"));
        let mut w: Vec<f64> = vec![];
        par.parallel_fill(&mut w, 4, |_| panic!("should not run"));
    }

    #[test]
    fn team_barriers_order_phases() {
        // Phase 1 writes disjoint slots; phase 2 (after sync) reads ALL
        // slots — correct only if the barrier actually separates phases.
        for threads in [1, 2, 4] {
            let par = Parallelism::new(threads);
            let n = 64;
            let slots: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let sums: Vec<AtomicU64> = (0..threads).map(|_| AtomicU64::new(0)).collect();
            par.team(|tid, team| {
                let nt = team.threads();
                for round in 1..=3u64 {
                    for k in (tid..n).step_by(nt) {
                        slots[k].store(k as u64 * round, Ordering::Relaxed);
                    }
                    team.sync();
                    let s: u64 = slots.iter().map(|x| x.load(Ordering::Relaxed)).sum();
                    sums[tid].fetch_add(s, Ordering::Relaxed);
                    team.sync();
                }
            });
            let base: u64 = (0..n as u64).sum();
            let want = base * (1 + 2 + 3);
            for (tid, s) in sums.iter().enumerate() {
                assert_eq!(
                    s.load(Ordering::Relaxed),
                    want,
                    "threads={threads} tid={tid}"
                );
            }
        }
    }

    #[test]
    fn parallel_fill_matches_serial_map() {
        for threads in [1, 2, 4, 8] {
            let par = Parallelism::new(threads);
            let mut out = vec![0.0f64; 333];
            par.parallel_fill(&mut out, 7, |i| (i as f64).sqrt());
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, (i as f64).sqrt(), "threads={threads} i={i}");
            }
        }
    }
}
