//! Memory-budget accounting for the block coordinate descent solver.
//!
//! The paper's Algorithm 2 exists because the dense matrices Σ, Ψ (q×q) and
//! Γ (p×q) exceed RAM for large p, q ("the Newton coordinate descent method
//! exhausted memory when p+q exceeded 80,000" on 104 GB). The block solver
//! "picks the smallest possible k such that we can store 2q/k columns of Σ
//! and Ψ in memory".
//!
//! [`MemBudget`] makes that policy explicit and testable: solvers ask it to
//! size their caches, and it tracks live allocations so tests (and the
//! `memwall` experiment) can assert the working set never exceeds the budget
//! — which is how we reproduce the paper's OOM boundary on a machine with
//! plenty of physical RAM.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Byte budget with live accounting and a high-water mark.
#[derive(Clone)]
pub struct MemBudget {
    inner: Arc<Inner>,
}

struct Inner {
    limit: usize,
    live: AtomicUsize,
    peak: AtomicUsize,
}

/// RAII registration of a tracked allocation.
pub struct Tracked {
    inner: Arc<Inner>,
    bytes: usize,
}

impl Drop for Tracked {
    fn drop(&mut self) {
        self.inner.live.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

#[derive(Debug, thiserror::Error)]
#[error("memory budget exceeded: requested {requested} bytes, live {live}, limit {limit}")]
pub struct BudgetExceeded {
    pub requested: usize,
    pub live: usize,
    pub limit: usize,
}

impl MemBudget {
    /// A budget of `limit` bytes. `usize::MAX` = unlimited.
    pub fn new(limit: usize) -> Self {
        MemBudget {
            inner: Arc::new(Inner {
                limit,
                live: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
            }),
        }
    }

    pub fn unlimited() -> Self {
        Self::new(usize::MAX)
    }

    pub fn limit(&self) -> usize {
        self.inner.limit
    }

    pub fn live(&self) -> usize {
        self.inner.live.load(Ordering::Relaxed)
    }

    /// Largest live total ever observed.
    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed)
    }

    /// Register `bytes` of working-set memory. Fails if it would exceed the
    /// limit — the solver treats that as the paper's "out of memory".
    pub fn track(&self, bytes: usize) -> Result<Tracked, BudgetExceeded> {
        let prev = self.inner.live.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        if now > self.inner.limit {
            self.inner.live.fetch_sub(bytes, Ordering::Relaxed);
            return Err(BudgetExceeded {
                requested: bytes,
                live: prev,
                limit: self.inner.limit,
            });
        }
        self.inner.peak.fetch_max(now, Ordering::Relaxed);
        Ok(Tracked {
            inner: self.inner.clone(),
            bytes,
        })
    }

    /// Bytes still available.
    pub fn available(&self) -> usize {
        self.inner.limit.saturating_sub(self.live())
    }

    /// True when `other` is a clone of this budget (shares the same
    /// counters) — lets caches detect a redundant rebind without clearing.
    pub fn same(&self, other: &MemBudget) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }
}

/// Parse "512MB", "2GB", "1048576", "64KB" into bytes.
pub fn parse_bytes(s: &str) -> Option<usize> {
    let s = s.trim();
    let (num, mult) = if let Some(t) = s.strip_suffix("GB").or(s.strip_suffix("gb")) {
        (t, 1usize << 30)
    } else if let Some(t) = s.strip_suffix("MB").or(s.strip_suffix("mb")) {
        (t, 1usize << 20)
    } else if let Some(t) = s.strip_suffix("KB").or(s.strip_suffix("kb")) {
        (t, 1usize << 10)
    } else if let Some(t) = s.strip_suffix('B').or(s.strip_suffix('b')) {
        (t, 1)
    } else {
        (s, 1)
    };
    num.trim().parse::<f64>().ok().map(|x| (x * mult as f64) as usize)
}

/// Render a byte count human-readably.
pub fn fmt_bytes(bytes: usize) -> String {
    let b = bytes as f64;
    if b >= (1u64 << 30) as f64 {
        format!("{:.2}GB", b / (1u64 << 30) as f64)
    } else if b >= (1u64 << 20) as f64 {
        format!("{:.2}MB", b / (1u64 << 20) as f64)
    } else if b >= 1024.0 {
        format!("{:.2}KB", b / 1024.0)
    } else {
        format!("{bytes}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_and_releases() {
        let b = MemBudget::new(1000);
        let t1 = b.track(600).unwrap();
        assert_eq!(b.live(), 600);
        assert!(b.track(500).is_err());
        let t2 = b.track(400).unwrap();
        assert_eq!(b.live(), 1000);
        drop(t1);
        assert_eq!(b.live(), 400);
        drop(t2);
        assert_eq!(b.live(), 0);
        assert_eq!(b.peak(), 1000);
    }

    #[test]
    fn unlimited_never_fails() {
        let b = MemBudget::unlimited();
        let _t = b.track(usize::MAX / 4).unwrap();
    }

    #[test]
    fn parse_and_format() {
        assert_eq!(parse_bytes("512MB"), Some(512 << 20));
        assert_eq!(parse_bytes("2GB"), Some(2 << 30));
        assert_eq!(parse_bytes("64kb"), Some(64 << 10));
        assert_eq!(parse_bytes("123"), Some(123));
        assert_eq!(parse_bytes("1.5GB"), Some((1.5 * (1u64 << 30) as f64) as usize));
        assert_eq!(parse_bytes("xyz"), None);
        assert_eq!(fmt_bytes(512 << 20), "512.00MB");
    }
}
